"""Generate EXPERIMENTS.md §Dry-run + §Roofline tables from results/, plus
the cross-suite ``BENCH_*.json`` summary table (one row per benchmark file:
its headline scalars, with the regression-gated overhead/slowdown ratios
flagged — the same keys ``obs_report baseline`` exits non-zero on)."""
from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

REPO = Path(__file__).resolve().parents[1]
RESULTS = REPO / "results" / "dryrun"

#: top-level BENCH keys that are configuration, not results
_CONFIG_KEYS = {"bench", "backend", "db", "fast", "reps", "block_tx",
                "n_blocks", "P", "window_blocks", "support", "meta"}


def bench_meta(backend: str = "", ts: str | None = None,
               sha: str | None = None) -> dict:
    """The shared provenance stamp every ``BENCH_*.json`` write carries.

    One helper so all five suite writers (and ``serve_load.merge_bench``)
    agree on the shape: ``{"git_sha", "backend", "ts"}``.  The caller
    passes its backend (and may pin ts/sha for determinism in tests);
    SHA/timestamp default to the surrounding checkout and current UTC
    time via :mod:`repro.obs.perfdb` — the same stamp the
    ``BENCH_HISTORY.jsonl`` rows carry, so a BENCH file and its history
    row are mutually attributable.
    """
    from repro.obs import perfdb

    return {
        "git_sha": sha if sha is not None else perfdb.git_sha(),
        "backend": backend,
        "ts": ts if ts is not None else perfdb.utc_stamp(),
    }


def _is_ratio(key: str) -> bool:
    """Measured-vs-baseline ratio keys (printed with an 'x' suffix)."""
    return "overhead" in key or "slowdown" in key


def _is_gate(key: str) -> bool:
    """Parity-type ratios (expected ≈1.0) flagged against the threshold —
    what CI gates via ``obs_report baseline --match overhead``; slowdown
    factors are bounded-by-design and only displayed."""
    return "overhead" in key


def _is_burn(key: str) -> bool:
    """SLO error-budget burn rates (BENCH_serve ``slo_burn_rate``): a
    sustained burn > 1.0 exhausts the budget within the window, so flag it
    directly against 1.0 rather than the ratio threshold."""
    return "burn_rate" in key


def _is_speedup(key: str) -> bool:
    """Higher-is-better multipliers (e.g. ``slo_microbatch_speedup``)."""
    return key.endswith("_speedup")


def bench_summary(root: Path = REPO, threshold: float = 0.05) -> str:
    """One markdown table over every ``BENCH_*.json`` under ``root``."""
    files = sorted(root.glob("BENCH_*.json"))
    if not files:
        return "(no BENCH_*.json files found)"
    out = ["| file | backend | entries | headline results |",
           "|---|---|---|---|"]
    n_gates = n_bad = 0
    for f in files:
        try:
            d = json.loads(f.read_text())
        except (OSError, json.JSONDecodeError) as e:
            out.append(f"| {f.name} | | | UNREADABLE: {e} |")
            continue
        cells = []
        for k, v in d.items():
            if k in _CONFIG_KEYS or isinstance(v, (bool, str, list, dict)):
                continue
            if isinstance(v, (int, float)):
                if _is_gate(k):
                    n_gates += 1
                    bad = v > 1.0 + threshold
                    n_bad += bad
                    cells.append(f"{k}={v:.3f}x"
                                 + (" ⚠" if bad else " ✓"))
                elif _is_burn(k):
                    n_gates += 1
                    bad = v > 1.0
                    n_bad += bad
                    cells.append(f"{k}={v:.3f}"
                                 + (" ⚠" if bad else " ✓"))
                elif _is_ratio(k) or _is_speedup(k):
                    cells.append(f"{k}={v:.3f}x")
                else:
                    cells.append(f"{k}={v:.4g}")
        n_entries = len(d.get("entries") or [])
        out.append(f"| {f.name} | {d.get('backend', '?')} | {n_entries} | "
                   f"{'  '.join(cells) or '—'} |")
    out.append(
        f"\n**{len(files)} benchmark files; {n_gates - n_bad}/{n_gates} "
        f"overhead/burn gates ok (overhead <= {1 + threshold:.2f}x, "
        f"burn <= 1.0)** "
        f"(gate mechanically: `python -m repro.launch.obs_report baseline "
        f"--match overhead --bench BENCH_*.json`)."
    )
    return "\n".join(out)


def dryrun_table(mesh: str) -> str:
    from repro.configs.base import SHAPES, shapes_for
    from repro.configs.registry import all_archs, get_config

    out = [
        "| arch | shape | fits 16GB | per-dev GB | args GB | HLO-wire GB/dev | "
        "compile s | collectives |",
        "|---|---|---|---|---|---|---|---|",
    ]
    n_fit = n_tot = 0
    for arch in all_archs():
        for shape in shapes_for(get_config(arch)):
            f = RESULTS / f"{arch}__{shape}__{mesh}.json"
            if not f.exists():
                out.append(f"| {arch} | {shape} | MISSING | | | | | |")
                continue
            r = json.loads(f.read_text())
            if "skipped" in r:
                out.append(f"| {arch} | {shape} | skipped | | | | | |")
                continue
            m, c = r["memory"], r["collectives"]
            n_tot += 1
            n_fit += bool(m["fits_16GB"])
            ops = ", ".join(
                f"{k.split('-')[-1][:4]}:{int(v/1e6)}M"
                for k, v in sorted(c.items())
                if k not in ("total_wire_bytes_per_device", "count")
            )
            out.append(
                f"| {arch} | {shape} | {'✅' if m['fits_16GB'] else '❌'} | "
                f"{m['per_device_total_bytes']/1e9:.1f} | "
                f"{m['argument_bytes']/1e9:.1f} | "
                f"{c['total_wire_bytes_per_device']/1e9:.2f} | "
                f"{r['timing']['compile_s']:.0f} | {c['count']} ops |"
            )
    out.append(f"\n**{n_fit}/{n_tot} cells fit 16 GB/chip on the {mesh} mesh.**")
    return "\n".join(out)


def main():
    from benchmarks import roofline

    print("## §Dry-run — single pod (16×16 = 256 chips)\n")
    print(dryrun_table("single"))
    print("\n## §Dry-run — multi-pod (2×16×16 = 512 chips)\n")
    print(dryrun_table("multi"))
    print("\n## §Roofline — single pod\n")
    rows = roofline.full_table("single")
    print(roofline.render_markdown(rows))
    print("\n## Benchmark suite summary (BENCH_*.json)\n")
    print(bench_summary())


if __name__ == "__main__":
    main()
