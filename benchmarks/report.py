"""Generate EXPERIMENTS.md §Dry-run + §Roofline tables from results/."""
from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def dryrun_table(mesh: str) -> str:
    from repro.configs.base import SHAPES, shapes_for
    from repro.configs.registry import all_archs, get_config

    out = [
        "| arch | shape | fits 16GB | per-dev GB | args GB | HLO-wire GB/dev | "
        "compile s | collectives |",
        "|---|---|---|---|---|---|---|---|",
    ]
    n_fit = n_tot = 0
    for arch in all_archs():
        for shape in shapes_for(get_config(arch)):
            f = RESULTS / f"{arch}__{shape}__{mesh}.json"
            if not f.exists():
                out.append(f"| {arch} | {shape} | MISSING | | | | | |")
                continue
            r = json.loads(f.read_text())
            if "skipped" in r:
                out.append(f"| {arch} | {shape} | skipped | | | | | |")
                continue
            m, c = r["memory"], r["collectives"]
            n_tot += 1
            n_fit += bool(m["fits_16GB"])
            ops = ", ".join(
                f"{k.split('-')[-1][:4]}:{int(v/1e6)}M"
                for k, v in sorted(c.items())
                if k not in ("total_wire_bytes_per_device", "count")
            )
            out.append(
                f"| {arch} | {shape} | {'✅' if m['fits_16GB'] else '❌'} | "
                f"{m['per_device_total_bytes']/1e9:.1f} | "
                f"{m['argument_bytes']/1e9:.1f} | "
                f"{c['total_wire_bytes_per_device']/1e9:.2f} | "
                f"{r['timing']['compile_s']:.0f} | {c['count']} ops |"
            )
    out.append(f"\n**{n_fit}/{n_tot} cells fit 16 GB/chip on the {mesh} mesh.**")
    return "\n".join(out)


def main():
    from benchmarks import roofline

    print("## §Dry-run — single pod (16×16 = 256 chips)\n")
    print(dryrun_table("single"))
    print("\n## §Dry-run — multi-pod (2×16×16 = 512 chips)\n")
    print(dryrun_table("multi"))
    print("\n## §Roofline — single pod\n")
    rows = roofline.full_table("single")
    print(roofline.render_markdown(rows))


if __name__ == "__main__":
    main()
