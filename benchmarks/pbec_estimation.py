"""PBEC size-estimation error — thesis §11.3 (Figs 11.1–11.12).

Experiment 2 (the thesis' "most important" graph): for P processors, after the
double sampling (D̃ → F̃s) and Phase-2 partitioning, measure the error

    err_i = | 1/P − |∪_{k∈L_i}[U_k] ∩ F| / |F| |

of each processor's *real* share of the FIs, and report error quantiles over
repeated runs — plus Experiment-1-style union errors of the sample estimate
against F̃.  Prints the empirical P[err > ε] curve per (|D̃|, |F̃s|).
"""
from __future__ import annotations

import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import bitmap as bm, eclat, fimi, pbec  # noqa: E402
from repro.data.ibm_gen import IBMParams, generate_dense  # noqa: E402


def real_share(classes, assignment, P, all_masks):
    """Real relative size of each processor's union of PBECs within F."""
    shares = np.zeros(P)
    N = len(all_masks)
    for cid, c in enumerate(classes):
        m = pbec.member_mask(all_masks, c.prefix, c.ext).sum()
        shares[assignment[cid]] += m
    return shares / max(N, 1)


def run(fast: bool = False):
    p = IBMParams(n_tx=2048, n_items=32, n_patterns=30, avg_pattern_len=8,
                  avg_tx_len=12, seed=4)
    dense = generate_dense(p)
    sup = 0.08
    minsup = int(np.ceil(sup * dense.shape[0]))
    oracle = eclat.brute_force_fis(dense, minsup)
    multi = {f for f in oracle if len(f) >= 2}
    all_masks = np.zeros((len(multi), p.n_items), bool)
    for i, s in enumerate(sorted(multi, key=lambda x: sorted(x))):
        all_masks[i, sorted(s)] = True
    print(f"db={p.name} |F|={len(oracle)} (|F≥2|={len(multi)})")

    grids = [(256, 128), (256, 512), (1024, 128), (1024, 512)]
    if fast:
        grids = grids[:2]
    trials = 5 if fast else 15
    print("| |D̃| | |F̃s| | P | mean err | p90 err | max err | P[err>0.05] |")
    print("|---|---|---|---|---|---|---|---|")
    rows = []
    for n_db, n_fs in grids:
        for P in ([5] if fast else [5, 10]):
            errs = []
            for t in range(trials):
                shards = fimi.shard_db(dense, P)
                params = fimi.FimiParams(
                    variant="reservoir", min_support_rel=sup,
                    n_db_sample=n_db, n_fi_sample=n_fs, alpha=0.5,
                    eclat=eclat.EclatConfig(max_out=1, max_stack=4096,
                                            count_only=True),
                )
                res = fimi.run(shards, p.n_items, params, jax.random.PRNGKey(t))
                shares = real_share(res.classes, res.assignment, P, all_masks)
                errs.extend(np.abs(shares - 1.0 / P))
            errs = np.asarray(errs)
            rows.append((n_db, n_fs, P, errs))
            print(
                f"| {n_db} | {n_fs} | {P} | {errs.mean():.4f} | "
                f"{np.quantile(errs, 0.9):.4f} | {errs.max():.4f} | "
                f"{(errs > 0.05).mean():.2f} |",
                flush=True,
            )
    return rows


if __name__ == "__main__":
    run(fast="--fast" in sys.argv)
