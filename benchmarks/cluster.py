"""Distributed-executor benchmarks: speedup curve + rebalancing payoff.

Two claims of the cluster subsystem (DESIGN.md, "Distributed mining"):

  1. **Speedup scales with the mesh** — the sample-planned partition keeps
     shards busy, so the makespan falls as devices are added.  Measured as
     the *modeled makespan* Σ_r max_p trips(r, p): DFS trips are the
     device-independent work unit (``Phase4Out.work_iters``), rounds are
     barriers, and the model is deterministic — CPU wall-clock of simulated
     miners would only add noise.  The curve runs P ∈ {1, 2, 4, 8} virtual
     miners on an IBM-gen DB with ``frontier_size=1`` so one trip = one PBEC
     node and per-class work is conserved across assignments.
  2. **Rebalancing beats static LPT when the estimates are wrong** — with a
     deliberately tiny FI sample the static assignment is skewed; the
     telemetry-driven donation pass recovers most of the gap at identical
     round structure (same chunk, donations on vs off).

Results print as CSV lines and land in ``BENCH_cluster.json``; the CI smoke
gate asserts the speedup curve is monotone 1→4 and that rebalancing is never
slower than static LPT on the skewed workload.
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax  # noqa: E402

from repro import cluster  # noqa: E402
from repro.core import eclat, fimi  # noqa: E402
from repro.data.ibm_gen import IBMParams, generate_dense  # noqa: E402

from benchmarks.report import bench_meta  # noqa: E402

SUPPORT = 0.1
SEED = 7


def _params(*, rebalance: bool, chunk=None, n_fi_sample: int = 512,
            scheduler: str = "lpt") -> cluster.ClusterParams:
    return cluster.ClusterParams(
        planner=cluster.PlannerParams(
            min_support_rel=SUPPORT,
            n_db_sample=256,
            n_fi_sample=n_fi_sample,
            scheduler=scheduler,
        ),
        # frontier_size=1: one while_loop trip = one DFS node, so per-class
        # cost is assignment-independent and makespans compare cleanly
        eclat=eclat.EclatConfig(
            max_out=1 << 14, max_stack=4096, frontier_size=1
        ),
        chunk=chunk,
        rebalance=rebalance,
    )


def _run(dense, n_items, P, params):
    shards = fimi.shard_db(dense, P)
    t0 = time.perf_counter()
    res = cluster.execute(
        shards, n_items, params, jax.random.PRNGKey(SEED)
    )
    return res, time.perf_counter() - t0


def run(fast: bool = False, out_path: str = "BENCH_cluster.json"):
    n_tx = 512 if fast else 1024
    p = IBMParams(
        n_tx=n_tx, n_items=32, n_patterns=12, avg_pattern_len=5,
        avg_tx_len=9, seed=SEED,
    )
    dense = generate_dense(p)
    print(f"cluster-bench: db={p.name} |D|={n_tx} |B|={p.n_items} "
          f"sup={SUPPORT}")

    # ---- claim 1: speedup-vs-devices curve (well-sampled planner) ---------
    entries = []
    base = None
    speedups = {}
    for P in (1, 2, 4, 8):
        res, wall = _run(dense, p.n_items, P, _params(rebalance=True))
        mk = res.report.makespan_trips
        if base is None:
            base = mk
        speedups[P] = base / max(mk, 1.0)
        entries.append(dict(
            name="cluster_speedup", P=P, makespan_trips=mk,
            speedup=speedups[P], wall_s=wall,
            imbalance=res.report.imbalance, rounds=res.report.n_rounds,
            n_fis=res.table.n_fis,
        ))
        print(f"cluster.speedup[P={P}],{mk:.0f},speedup={speedups[P]:.2f}x,"
              f"imbalance={res.report.imbalance:.2f},wall={wall:.2f}s",
              flush=True)

    # ---- claim 2: static LPT vs +rebalancing on a skewed workload ---------
    # a tiny FI sample makes the static estimates unreliable → skewed loads;
    # both runs share the round structure (chunk) so only donations differ
    P_skew, chunk = 4, 2
    res_static, _ = _run(
        dense, p.n_items, P_skew,
        _params(rebalance=False, chunk=chunk, n_fi_sample=32),
    )
    res_rebal, _ = _run(
        dense, p.n_items, P_skew,
        _params(rebalance=True, chunk=chunk, n_fi_sample=32),
    )
    mk_s = res_static.report.makespan_trips
    mk_r = res_rebal.report.makespan_trips
    assert res_static.table.to_dict() == res_rebal.table.to_dict(), \
        "rebalancing changed the mined FI set"
    improvement = mk_s / max(mk_r, 1.0)
    entries.append(dict(
        name="cluster_static_lpt", P=P_skew, chunk=chunk,
        makespan_trips=mk_s, imbalance=res_static.report.imbalance,
    ))
    entries.append(dict(
        name="cluster_rebalanced", P=P_skew, chunk=chunk,
        makespan_trips=mk_r, imbalance=res_rebal.report.imbalance,
        donations=len(res_rebal.report.donations),
        improvement_vs_static=improvement,
    ))
    print(f"cluster.static_lpt[P={P_skew}],{mk_s:.0f},"
          f"imbalance={res_static.report.imbalance:.2f}")
    print(f"cluster.rebalanced[P={P_skew}],{mk_r:.0f},"
          f"improvement={improvement:.2f}x,"
          f"donations={len(res_rebal.report.donations)}", flush=True)

    # the speedup-loss decomposition of every curve point (additive:
    # inflation + imbalance = ideal − measured, exactly) — flat loss_* keys
    # so the perf ledger tracks WHY the speedup moves, not just that it did
    from repro.obs import speedup as speedup_mod

    loss_keys = speedup_mod.bench_loss_keys(entries)
    for P_wf, wf in sorted(speedup_mod.from_bench_entries(entries).items()):
        print(f"cluster.loss[P={P_wf}]," + ",".join(
            f"{t.name}={t.loss_x:.3f}x" for t in wf.terms), flush=True)

    payload = {
        "bench": "cluster",
        "backend": jax.default_backend(),
        "db": p.name,
        "support": SUPPORT,
        "fast": fast,
        "speedup_1_to_4": speedups[4],
        "rebalance_improvement": improvement,
        **loss_keys,
        "meta": bench_meta(backend=jax.default_backend()),
        "entries": entries,
    }
    Path(out_path).write_text(json.dumps(payload, indent=2) + "\n")
    print(f"[wrote {out_path}: {len(entries)} entries, "
          f"speedup@4={speedups[4]:.2f}x, rebalance {improvement:.2f}x "
          f"vs static]", flush=True)

    # the CI gate (acceptance criteria of the subsystem)
    assert speedups[2] > speedups[1] and speedups[4] > speedups[2], (
        f"speedup not monotone 1→4: {speedups}"
    )
    assert mk_r <= mk_s, (
        f"rebalancing slower than static LPT: {mk_r:.0f} > {mk_s:.0f} trips"
    )
    return entries


if __name__ == "__main__":
    run(fast=("--fast" in sys.argv) or ("--smoke" in sys.argv))
