"""Roofline analysis: three terms per (arch × shape × mesh) cell.

Hardware model (TPU v5e, from the brief):
  peak = 197 TFLOP/s bf16/chip, HBM = 819 GB/s/chip, ICI ≈ 50 GB/s/link.

Term sources:
  * compute  = executed_FLOPs_per_chip / peak
  * memory   = HBM_bytes_per_chip / bw
  * collective = wire_bytes_per_chip / link_bw

FLOPs/bytes come from an **analytic cost model** (this file) parameterized by
the exact ModelConfig + the schedule the dry-run lowered (accum, remat,
sharding policy).  Reason: XLA's ``cost_analysis()`` counts while-loop bodies
ONCE (verified in tests/test_roofline_model.py), so raw HLO numbers
undercount scanned programs by the trip counts; the dry-run JSON still
supplies the *measured* per-device memory image (``memory_analysis``) and the
full collective inventory (op types/bytes/groups) against which the analytic
model is cross-checked.  The analytic model itself is validated against an
*unrolled* compile of a small config (same test).

MODEL_FLOPS convention: 6·N·D dense / 6·N_active·D MoE (N excl. embeddings).
"""
from __future__ import annotations

import dataclasses
import json
import sys
from pathlib import Path
from typing import Dict, Optional

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, shapes_for  # noqa: E402
from repro.configs.registry import all_archs, get_config  # noqa: E402
from repro.obs.machine import TPU_V5E  # noqa: E402

# Machine constants live in repro.obs.machine (shared with the kernel
# profiler); the module-level names are kept for existing consumers/tests.
PEAK = TPU_V5E.peak_flops
HBM = TPU_V5E.hbm_bw
LINK = TPU_V5E.link_bw
RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"

WHISPER_DEC = 448


# ---------------------------------------------------------------------------
# Analytic FLOPs (forward), per GLOBAL step
# ---------------------------------------------------------------------------


def _attn_proj_flops(cfg: ModelConfig, T: float) -> float:
    d, hd = cfg.d_model, cfg.hd
    if cfg.mla is not None:
        m = cfg.mla
        qk = m.qk_nope_dim + m.qk_rope_dim
        per_tok = (
            d * m.q_lora_rank + m.q_lora_rank * cfg.n_heads * qk
            + d * (m.kv_lora_rank + m.qk_rope_dim)
            + m.kv_lora_rank * cfg.n_heads * (m.qk_nope_dim + m.v_head_dim)
            + cfg.n_heads * m.v_head_dim * d
        )
    else:
        per_tok = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d
    return 2.0 * T * per_tok


def _attn_score_flops(cfg: ModelConfig, T: float, ctx: float, causal=True) -> float:
    hd_qk = cfg.hd
    hd_v = cfg.hd
    if cfg.mla is not None:
        hd_qk = cfg.mla.qk_nope_dim + cfg.mla.qk_rope_dim
        hd_v = cfg.mla.v_head_dim
    f = 2.0 * T * ctx * cfg.n_heads * (hd_qk + hd_v)
    return f / 2 if causal and T == ctx else f


def _mlp_flops(cfg: ModelConfig, T: float, layer: int) -> float:
    d = cfg.d_model
    if cfg.moe and cfg.moe.n_experts and layer % cfg.moe.every == 0:
        m = cfg.moe
        routed = 2.0 * T * m.top_k * 3 * d * m.expert_d_ff
        shared = 2.0 * T * 3 * d * (m.n_shared * m.expert_d_ff)
        router = 2.0 * T * d * m.n_experts
        return routed + shared + router
    k = 2 if cfg.mlp_type == "gelu" else 3
    return 2.0 * T * k * d * cfg.d_ff


def _ssm_flops(cfg: ModelConfig, T: float, decode: bool = False) -> float:
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    H = di // s.head_dim
    gN = s.n_groups * s.d_state
    proj = 2.0 * T * (2 * d * di + d * 2 * gN + d * H) + 2.0 * T * di * d
    if decode:
        ssd = 2.0 * T * H * s.head_dim * s.d_state * 2  # state update + readout
    else:
        Q = s.chunk
        ssd = 2.0 * T * (Q * gN + Q * di) + 4.0 * T * di * s.d_state
    return proj + ssd


def fwd_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Forward FLOPs for one global step of this cell."""
    B, S = shape.global_batch, shape.seq_len
    decode = shape.kind == "decode"
    if cfg.family == "encdec":
        enc_T, dec_T = B * S, B * (1 if decode else WHISPER_DEC)
        ctx_self = WHISPER_DEC if decode else WHISPER_DEC
        total = 0.0
        for _ in range(cfg.n_enc_layers):
            if decode:
                continue  # encoder output cached during decode
            total += _attn_proj_flops(cfg, enc_T)
            total += _attn_score_flops(cfg, enc_T, S, causal=False)
            total += _mlp_flops(cfg, enc_T, 1)
        for _ in range(cfg.n_layers):
            total += _attn_proj_flops(cfg, dec_T) * 2  # self + cross proj≈q,o only
            total += _attn_score_flops(cfg, dec_T, ctx_self)
            total += _attn_score_flops(cfg, dec_T, S, causal=False)  # cross
            total += _mlp_flops(cfg, dec_T, 1)
        total += 2.0 * dec_T * cfg.d_model * cfg.vocab_padded
        return total

    T = B * (1 if decode else S)
    ctx = S
    total = 0.0
    for l in range(cfg.n_layers):
        if cfg.family == "ssm":
            total += _ssm_flops(cfg, T, decode)
        elif cfg.family == "hybrid":
            if l % cfg.attn_every == 0:
                total += _attn_proj_flops(cfg, T) + _attn_score_flops(
                    cfg, T, ctx, causal=not decode
                )
            else:
                total += _ssm_flops(cfg, T, decode)
            total += _mlp_flops(cfg, T, l)
        else:
            total += _attn_proj_flops(cfg, T) + _attn_score_flops(
                cfg, T, ctx, causal=not decode
            )
            total += _mlp_flops(cfg, T, l)
    total += 2.0 * T * cfg.d_model * cfg.vocab_padded  # logits
    return total


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """The brief's MODEL_FLOPS: 6·N(active, excl. embed)·D tokens."""
    from repro.models import model as M

    n = M.n_params(cfg)
    emb = cfg.vocab_padded * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    n_active = n - emb
    if cfg.moe and cfg.moe.n_experts:
        m = cfg.moe
        n_moe_layers = sum(
            1 for l in range(cfg.n_layers) if l % m.every == 0
        )
        routed_total = n_moe_layers * m.n_experts * 3 * cfg.d_model * m.expert_d_ff
        routed_active = routed_total * m.top_k / m.n_experts
        n_active = n_active - routed_total + routed_active
    B, S = shape.global_batch, shape.seq_len
    D = B * (1 if shape.kind == "decode" else S)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * D


# ---------------------------------------------------------------------------
# Analytic HBM + collective traffic, per chip per step
# ---------------------------------------------------------------------------


def _policy(cfg, n_par, shape):
    big = n_par > 50e9
    small = n_par < 1e9
    accum = 1
    if shape.kind == "train":
        if small:
            accum = 1
        elif big or (cfg.moe and cfg.moe.n_experts) or n_par > 10e9:
            accum = 16
        else:
            accum = 8
    return {"accum": accum, "big": big, "small": small}


def traffic_model(cfg: ModelConfig, shape: ShapeConfig, world: int, rec: Optional[dict]) -> Dict[str, float]:
    from repro.models import model as M

    n_par = M.n_params(cfg)
    pol = _policy(cfg, n_par, shape)
    A = pol["accum"]
    dshard = 1 if pol["small"] else (world // 16)   # data(-pod) shards
    mshard = 1 if pol["small"] else 16
    p_bytes_dev = 2.0 * n_par / (1 if pol["small"] else world)  # bf16, sharded
    opt_bytes = (2.0 if pol["big"] else 4.0) * 2 * n_par / (1 if pol["small"] else world)
    B, S = shape.global_batch, shape.seq_len
    tok_dev = B * (1 if shape.kind == "decode" else S) / (
        world if pol["small"] else dshard
    )
    d = cfg.d_model

    if shape.kind == "train":
        # weights: fwd + remat-fwd + bwd reads per microbatch; grads+opt once
        w_traffic = p_bytes_dev * 3 * A + p_bytes_dev * 2 + opt_bytes * 2
        act_traffic = 30.0 * tok_dev * d * 2 * cfg.n_layers  # r/w per sublayer set
        hbm = w_traffic + act_traffic
        # collectives: FSDP all-gather per microbatch + TP ARs + grad sync
        fsdp = A * p_bytes_dev * max(dshard - 1, 0) / max(dshard, 1) * (
            0 if pol["small"] else 1
        ) * dshard  # gather the full model shard set each microbatch
        mb_act = tok_dev / A * d * 2
        tp = 0.0 if mshard == 1 else A * cfg.n_layers * 4 * mb_act * 2 * (mshard - 1) / mshard
        grad = 2.0 * (4.0 * n_par / world) * max(dshard - 1, 0) / max(dshard, 1)
        if pol["small"]:
            grad = 2.0 * 4.0 * n_par * (world - 1) / world  # DP all-reduce, replicated
        wire = fsdp + tp + grad
    elif shape.kind == "prefill":
        w_traffic = p_bytes_dev
        act_traffic = 14.0 * tok_dev * d * 2 * cfg.n_layers
        hbm = w_traffic + act_traffic
        act = tok_dev * d * 2
        tp = 0.0 if mshard == 1 else cfg.n_layers * 2 * act * 2 * (mshard - 1) / mshard
        wire = tp
    else:  # decode
        cache_dev = _cache_bytes(cfg, shape) / world
        w_traffic = _active_param_bytes(cfg) * 2.0 / (1 if pol["small"] else world)
        hbm = w_traffic + cache_dev + 20.0 * tok_dev * d * 2 * cfg.n_layers
        act = tok_dev * d * 2
        tp = 0.0 if mshard == 1 else cfg.n_layers * 2 * act * 2 * (mshard - 1) / mshard
        # seq-sharded attention: per layer all-reduce of [B,H,1] stats + ctx
        wire = tp + cfg.n_layers * act
    return {"hbm_bytes_dev": hbm, "wire_bytes_dev": wire, "accum": A,
            "params_bytes_dev": p_bytes_dev + opt_bytes}


def _active_param_bytes(cfg: ModelConfig) -> float:
    from repro.models import model as M

    n = M.n_params(cfg)
    if cfg.moe and cfg.moe.n_experts:
        m = cfg.moe
        n_moe_layers = sum(1 for l in range(cfg.n_layers) if l % m.every == 0)
        routed_total = n_moe_layers * m.n_experts * 3 * cfg.d_model * m.expert_d_ff
        n = n - routed_total + routed_total * m.top_k / m.n_experts
    return 2.0 * n


def _cache_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        self_c = cfg.n_layers * 2 * B * WHISPER_DEC * cfg.n_kv_heads * cfg.hd * 2
        cross = cfg.n_layers * 2 * B * S * cfg.n_kv_heads * cfg.hd * 2
        return self_c + cross
    if cfg.mla is not None:
        return cfg.n_layers * B * S * (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim) * 2
    if cfg.family == "ssm":
        s = cfg.ssm
        di = s.expand * cfg.d_model
        H = di // s.head_dim
        return cfg.n_layers * B * (H * s.head_dim * s.d_state * 4 + 3 * di * 2)
    if cfg.family == "hybrid":
        nb = cfg.n_layers // cfg.attn_every
        s = cfg.ssm
        di = s.expand * cfg.d_model
        H = di // s.head_dim
        attn_c = nb * 2 * B * S * cfg.n_kv_heads * cfg.hd * 2
        ssm_c = (cfg.n_layers - nb) * B * (H * s.head_dim * s.d_state * 4 + 3 * di * 2)
        return attn_c + ssm_c
    return cfg.n_layers * 2 * B * S * cfg.n_kv_heads * cfg.hd * 2


# ---------------------------------------------------------------------------
# Report
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    exec_flops: float
    useful_ratio: float
    fits: Optional[bool]
    mem_gb: Optional[float]
    hlo_wire_gb: Optional[float]
    note: str = ""


def analyze_cell(arch: str, shape_name: str, mesh: str = "single", tag: str = "") -> Cell:
    cfg0 = get_config(arch)
    shape = SHAPES[shape_name]
    from repro.launch.input_specs import shape_adjusted_config

    cfg = shape_adjusted_config(cfg0, shape)
    world = 512 if mesh == "multi" else 256
    f = fwd_flops(cfg, shape)
    if shape.kind == "train":
        execf = 4.0 * f  # fwd + remat-fwd + bwd(2×)
    else:
        execf = f
    n_par_small = None
    from repro.models import model as M

    pol = _policy(cfg, M.n_params(cfg), shape)
    exec_dev = execf / world
    mf = model_flops(cfg, shape)

    rec = None
    t = f"__{tag}" if tag else ""
    path = RESULTS / f"{arch}__{shape_name}__{mesh}{t}.json"
    if path.exists():
        rec = json.loads(path.read_text())
        if "skipped" in rec:
            rec = None
    tm = traffic_model(cfg, shape, world, rec)

    compute_s = exec_dev / PEAK
    memory_s = tm["hbm_bytes_dev"] / HBM
    collective_s = tm["wire_bytes_dev"] / LINK
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    return Cell(
        arch=arch,
        shape=shape_name,
        mesh=mesh,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=mf,
        exec_flops=execf,
        useful_ratio=mf / execf,
        fits=(rec or {}).get("memory", {}).get("fits_16GB") if rec else None,
        mem_gb=(rec or {}).get("memory", {}).get("per_device_total_bytes", 0) / 1e9
        if rec
        else None,
        hlo_wire_gb=(rec or {}).get("collectives", {}).get(
            "total_wire_bytes_per_device", 0
        )
        / 1e9
        if rec
        else None,
    )


def roofline_fraction(c: Cell) -> float:
    """Achievable fraction of compute peak: compute / max(all terms)."""
    worst = max(c.compute_s, c.memory_s, c.collective_s)
    return c.compute_s / worst if worst > 0 else 0.0


def full_table(mesh: str = "single", tag: str = ""):
    rows = []
    for arch in all_archs():
        for shape in shapes_for(get_config(arch)):
            rows.append(analyze_cell(arch, shape, mesh, tag))
    return rows


def render_markdown(rows) -> str:
    out = [
        "| arch | shape | compute s | memory s | coll s | bound | frac | "
        "useful/exec | fits16G | memGB | HLO-wire GB |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in rows:
        out.append(
            f"| {c.arch} | {c.shape} | {c.compute_s:.2e} | {c.memory_s:.2e} | "
            f"{c.collective_s:.2e} | {c.dominant} | {roofline_fraction(c):.2f} | "
            f"{c.useful_ratio:.2f} | {c.fits} | "
            f"{'' if c.mem_gb is None else f'{c.mem_gb:.1f}'} | "
            f"{'' if c.hlo_wire_gb is None else f'{c.hlo_wire_gb:.1f}'} |"
        )
    return "\n".join(out)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    rows = full_table(args.mesh, args.tag)
    print(render_markdown(rows))
    worst = min(rows, key=roofline_fraction)
    coll = max(rows, key=lambda c: c.collective_s / max(c.compute_s, 1e-12))
    print(f"\nworst-fraction cell: {worst.arch} × {worst.shape} "
          f"({roofline_fraction(worst):.2f}, {worst.dominant}-bound)")
    print(f"most collective-bound: {coll.arch} × {coll.shape}")


if __name__ == "__main__":
    main()
