"""Serving micro-benchmarks: the batched subset-query sweep.

Mines an IBM database once, builds the FI/rule indexes, then measures the
``[Q, F]`` membership sweep three ways:

  * **batched**   — ONE fused ``subset_superset_counts`` dispatch over the
    whole query batch (the serving engine's shape; Pallas kernel on TPU,
    jnp reference on CPU — on CPU this measures the algorithmic
    reformulation only, as in ``benchmarks/kernels.py``);
  * **per-query** — Q dispatches of ``[1, F]`` (the no-batching strawman: a
    server answering queries as they arrive);
  * **host numpy**— dense bool index + numpy bit-ops per query, the
    conventional host-side implementation a TPU index replaces.

plus end-to-end engine query types (support / rules / superset) at the
configured batch width.  Results are printed as CSV lines and written to
``BENCH_serve.json`` so the serving-perf trajectory is machine-readable
across PRs.
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import bitmap as bm  # noqa: E402
from repro.core import eclat  # noqa: E402
from repro.data.ibm_gen import IBMParams, generate_dense  # noqa: E402
from repro.kernels import ops  # noqa: E402
from repro.serve import QueryEngine  # noqa: E402
from repro.serve.index import build_indexes  # noqa: E402

from benchmarks.report import bench_meta  # noqa: E402

REPS = 5


def _time(f, *args, reps=REPS):
    jax.block_until_ready(f(*args))  # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def _host_numpy_sweep(fi_dense: np.ndarray, query_dense: np.ndarray):
    """Per-query host loop over a dense bool index: (miss, extra) counts."""
    miss = np.empty((query_dense.shape[0], fi_dense.shape[0]), np.int32)
    extra = np.empty_like(miss)
    for q in range(query_dense.shape[0]):
        only_f = fi_dense & ~query_dense[q]
        only_q = query_dense[q] & ~fi_dense
        miss[q] = only_f.sum(axis=1)
        extra[q] = only_q.sum(axis=1)
    return miss, extra


def run(fast: bool = False, out_path: str = "BENCH_serve.json"):
    p = IBMParams(
        n_tx=1024 if fast else 4096, n_items=48, n_patterns=30,
        avg_pattern_len=6, avg_tx_len=10, seed=7,
    )
    dense = generate_dense(p)
    minsup = int(np.ceil(0.05 * p.n_tx))
    db = bm.BitmapDB.from_dense(jnp.asarray(dense))
    res = eclat.mine_all(
        db, minsup,
        config=eclat.EclatConfig(max_out=1 << 15, max_stack=8192,
                                 frontier_size=16),
    )
    # a truncated FI table is not downward closed -> rules would KeyError
    assert int(res.stack_overflow) == 0 and int(res.n_total) == int(res.n_out)
    fis = {}
    n = int(res.n_out)
    items = np.asarray(res.items[:n])
    supps = np.asarray(res.supports[:n])
    for row, s in zip(items, supps):
        mask = np.asarray(bm.unpack_bool(jnp.asarray(row), p.n_items))
        fis[frozenset(np.nonzero(mask)[0].tolist())] = int(s)
    fi_index, rule_index = build_indexes(fis, p.n_items, p.n_tx,
                                         min_confidence=0.6)
    F, R = fi_index.n_fis, rule_index.n_rules
    print(f"serve-bench: db={p.name} F={F} R={R} minsup={minsup}")

    rng = np.random.default_rng(1)
    entries = []
    q_widths = [64, 256] if fast else [64, 256, 1024]
    fi_dense = np.asarray(bm.unpack_bool(fi_index.masks, p.n_items))

    for Q in q_widths:
        rows = rng.choice(p.n_tx, size=Q, replace=True)
        query_dense = dense[rows]
        qp = jnp.asarray(np.asarray(bm.pack_bool(jnp.asarray(query_dense))))
        shape = {"Q": Q, "F": F, "n_items": p.n_items}

        # batched: one fused sweep
        batched = jax.jit(lambda q: ops.subset_superset_counts(q, fi_index.masks))
        us_batch = _time(batched, qp)

        # per-query: Q dispatches of [1, F]
        one = jax.jit(lambda q: ops.subset_superset_counts(q, fi_index.masks))
        jax.block_until_ready(one(qp[:1]))

        def per_query(qp=qp, Q=Q):
            outs = [one(qp[j: j + 1]) for j in range(Q)]
            jax.block_until_ready(outs[-1])
            return outs

        t0 = time.perf_counter()
        reps = max(1, REPS // 2)
        for _ in range(reps):
            per_query()
        us_loop = (time.perf_counter() - t0) / reps * 1e6

        # host numpy over the dense index
        t0 = time.perf_counter()
        _host_numpy_sweep(fi_dense, query_dense)
        us_host = (time.perf_counter() - t0) * 1e6

        entries.append(dict(name="subset_query_batched", **shape, us=us_batch))
        entries.append(dict(name="subset_query_per_query", **shape, us=us_loop,
                            slowdown_vs_batched=us_loop / us_batch))
        entries.append(dict(name="subset_query_host_numpy", **shape,
                            us=us_host, slowdown_vs_batched=us_host / us_batch))
        print(f"serve.subset_query_batched[Q={Q},F={F}],{us_batch:.1f},")
        print(f"serve.subset_query_per_query[Q={Q},F={F}],{us_loop:.1f},"
              f"slowdown_vs_batched={us_loop / us_batch:.2f}x")
        print(f"serve.subset_query_host_numpy[Q={Q},F={F}],{us_host:.1f},"
              f"slowdown_vs_batched={us_host / us_batch:.2f}x", flush=True)

    # ---- end-to-end engine query types at one batch width -------------------
    Q = q_widths[0]
    engine = QueryEngine(fi_index, rule_index, batch=Q, top_k=5)
    basket_masks = np.asarray(
        bm.pack_bool(jnp.asarray(dense[rng.choice(p.n_tx, size=Q)]))
    )
    fi_rows = rng.choice(F, size=Q)
    fi_masks = np.asarray(fi_index.masks)[fi_rows]

    for name, fn, masks in [
        ("engine_support", engine.support, fi_masks),
        ("engine_rules_for", engine.rules_for, basket_masks),
        ("engine_supersets", engine.supersets, fi_masks),
    ]:
        us = _time(lambda m=masks, f=fn: f(m), reps=max(1, REPS // 2))
        entries.append(dict(name=name, Q=Q, F=F, R=R, us=us,
                            us_per_query=us / Q))
        print(f"serve.{name}[Q={Q}],{us:.1f},us_per_query={us / Q:.2f}",
              flush=True)

    payload = {
        "bench": "serve",
        "backend": jax.default_backend(),
        "db": p.name,
        "n_fis": F,
        "n_rules": R,
        "reps": REPS,
        "fast": fast,
        "meta": bench_meta(backend=jax.default_backend()),
        "entries": entries,
    }
    # serve_load merges its slo_* keys into the same file; keep them across
    # microbenchmark reruns so the SLO gate history survives.
    try:
        prev = json.loads(Path(out_path).read_text())
        payload.update({k: v for k, v in prev.items()
                        if k.startswith("slo_") and k not in payload})
    except (OSError, json.JSONDecodeError):
        pass
    Path(out_path).write_text(json.dumps(payload, indent=2) + "\n")
    print(f"[wrote {out_path}: {len(entries)} entries]", flush=True)
    return entries


if __name__ == "__main__":
    run(fast="--fast" in sys.argv)
