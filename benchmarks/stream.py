"""Streaming micro-benchmarks: fused delta-update vs full recompute.

The streaming subsystem's claim (DESIGN.md, "Streaming subsystem"): when a
block arrives and a block expires, updating the F mined supports via the
fused ``[2, F]`` arrive/expire sweep (``kernels/delta_support.py``) beats
recomputing all F supports over the whole B-block window — the naive
per-block cost a stream server would otherwise pay.  The work ratio is
B/2, so the window length is the speedup lever; measured here per admitted
block on the IBM bench DB:

  * **delta**     — ONE fused sweep over the arrive+expire pair
    (``ops.delta_supports``; Pallas on TPU, jnp reference on CPU — on CPU
    this measures the algorithmic reformulation, as in
    ``benchmarks/kernels.py``);
  * **full**      — recompute every FI's support over all B resident blocks
    (``ops.block_itemset_supports`` on the whole stacked window);
  * **host numpy**— dense-bool containment over the whole window on host,
    the conventional implementation both device paths replace.

Results print as CSV lines and land in ``BENCH_stream.json`` (the CI smoke
gate asserts the delta path's speedup there).
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import bitmap as bm  # noqa: E402
from repro.core import eclat  # noqa: E402
from repro.data.ibm_gen import IBMParams, drifting_stream  # noqa: E402
from repro.kernels import ops  # noqa: E402
from repro.serve.index import FIIndex  # noqa: E402
from repro.stream import SlidingWindow  # noqa: E402

from benchmarks.report import bench_meta  # noqa: E402

REPS = 5


def _time(f, *args, reps=REPS):
    jax.block_until_ready(f(*args))  # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def _host_numpy_window(window_dense: np.ndarray, fi_dense: np.ndarray):
    """Full-window recompute on host: dense-bool containment counts."""
    counts = np.zeros(fi_dense.shape[0], np.int64)
    for f in range(fi_dense.shape[0]):
        counts[f] = (~(fi_dense[f][None, :] & ~window_dense).any(axis=1)).sum()
    return counts


def run(fast: bool = False, out_path: str = "BENCH_stream.json"):
    n_blocks = 32                      # window length B -> work ratio B/2
    block_tx = 32 if fast else 128
    p = IBMParams(
        n_tx=n_blocks * block_tx, n_items=48, n_patterns=30,
        avg_pattern_len=6, avg_tx_len=10, seed=7,
    )

    # fill a window from the (drift-free) stream and mine it once
    window = SlidingWindow.empty(n_blocks, block_tx, p.n_items)
    blocks = []
    for dense_block, _ in drifting_stream(
        p, n_blocks=n_blocks + 1, block_tx=block_tx
    ):
        packed = np.asarray(bm.pack_bool(jnp.asarray(dense_block)))
        blocks.append((dense_block, packed))
        if len(blocks) <= n_blocks:
            window, _ = window.admit(jnp.asarray(packed))
    db = window.to_bitmap_db()
    minsup = int(np.ceil(0.05 * window.n_tx))
    res = eclat.mine_all(
        db, minsup,
        config=eclat.EclatConfig(max_out=1 << 15, max_stack=8192,
                                 frontier_size=16),
    )
    assert int(res.stack_overflow) == 0 and int(res.n_total) == int(res.n_out)
    fis = {}
    items = np.asarray(res.items[: int(res.n_out)])
    supps = np.asarray(res.supports[: int(res.n_out)])
    for row, s in zip(items, supps):
        mask = np.asarray(bm.unpack_bool(jnp.asarray(row), p.n_items))
        fis[frozenset(np.nonzero(mask)[0].tolist())] = int(s)
    index = FIIndex.from_fi_dict(fis, p.n_items, window.n_tx)
    F = index.n_fis
    fi_masks = index.masks[:F]
    print(f"stream-bench: db={p.name} window={n_blocks}x{block_tx}tx "
          f"F={F} minsup={minsup}")

    arrive = jnp.asarray(blocks[-1][1])            # the next stream block
    expire = window.blocks[window.head]            # the one it would evict
    stacked = window.stacked()

    # delta: one fused [2, F] sweep per admitted block
    delta_fn = jax.jit(lambda a, e: ops.delta_supports(a, e, fi_masks))
    us_delta = _time(delta_fn, arrive, expire)

    # full: recompute all F supports over the whole resident window
    full_fn = jax.jit(
        lambda w: ops.block_itemset_supports(w, fi_masks).sum(axis=0)
    )
    us_full = _time(full_fn, stacked)

    # host numpy over the dense window
    window_dense = np.asarray(db.dense())
    fi_dense = np.asarray(bm.unpack_bool(fi_masks, p.n_items))
    t0 = time.perf_counter()
    host_counts = _host_numpy_window(window_dense, fi_dense)
    us_host = (time.perf_counter() - t0) * 1e6

    # correctness cross-check: all three paths agree on window supports
    np.testing.assert_array_equal(
        np.asarray(full_fn(stacked)), host_counts
    )
    d = np.asarray(delta_fn(arrive, expire))
    assert d.shape == (2, F)

    speedup = us_full / us_delta
    entries = [
        dict(name="stream_delta_update", B=n_blocks, T_blk=block_tx, F=F,
             us=us_delta),
        dict(name="stream_full_recompute", B=n_blocks, T_blk=block_tx, F=F,
             us=us_full, slowdown_vs_delta=speedup),
        dict(name="stream_host_numpy", B=n_blocks, T_blk=block_tx, F=F,
             us=us_host, slowdown_vs_delta=us_host / us_delta),
    ]
    print(f"stream.delta_update[B={n_blocks},F={F}],{us_delta:.1f},")
    print(f"stream.full_recompute[B={n_blocks},F={F}],{us_full:.1f},"
          f"slowdown_vs_delta={speedup:.2f}x")
    print(f"stream.host_numpy[B={n_blocks},F={F}],{us_host:.1f},"
          f"slowdown_vs_delta={us_host / us_delta:.2f}x", flush=True)

    payload = {
        "bench": "stream",
        "backend": jax.default_backend(),
        "db": p.name,
        "window_blocks": n_blocks,
        "block_tx": block_tx,
        "n_fis": F,
        "reps": REPS,
        "fast": fast,
        "delta_speedup_vs_full": speedup,
        "meta": bench_meta(backend=jax.default_backend()),
        "entries": entries,
    }
    Path(out_path).write_text(json.dumps(payload, indent=2) + "\n")
    print(f"[wrote {out_path}: {len(entries)} entries, "
          f"delta {speedup:.1f}x vs full recompute]", flush=True)
    # the CI gate: the whole subsystem exists for this ratio (work ratio is
    # B/2 = 16x by construction at B=32, so 10x leaves measurement headroom)
    assert speedup >= 10.0, (
        f"delta-update speedup regressed to {speedup:.1f}x (< 10x) — "
        f"see {out_path} entries"
    )
    return entries


if __name__ == "__main__":
    run(fast=("--fast" in sys.argv) or ("--smoke" in sys.argv))
