"""Speedup evaluation — thesis §11.4 (Tables 11.3–11.14).

On this single-CPU container real parallel wall-clock is unmeasurable, so we
report the thesis' quantity through its load-balance decomposition:

    speedup(P) = W_seq / (W_phase1/P + max_p W4_p + W_overhead)

where W is *device work* measured in DFS node expansions (`work_iters` — each
trip = one batched support sweep, the unit Phase 2 balances).  W_seq is the
sequential miner's trips on the full DB; Phase-1 trips are the sample-mining
cost (split across P for the Par/Reservoir variants, serial for Seq);
W_overhead charges Phase 2+3 at a fixed fraction measured from wall time.
This mirrors the thesis' speedup mechanism (static balance quality is the
sole variable) without pretending to measure ICI latency on one CPU.

Output: one table per database × variant with speedup per P ∈ {2,4,8}.
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402

from repro.core import eclat, fimi  # noqa: E402
from repro.data.ibm_gen import IBMParams, generate_dense  # noqa: E402

# scaled-down analogues of the thesis databases (500k tx → 2k tx on CPU)
DATABASES = [
    IBMParams(n_tx=2048, n_items=48, n_patterns=50, avg_pattern_len=10,
              avg_tx_len=16, seed=0),     # T2I0.048P50PL10TL16 ~ T500I0.1P50PL10TL40
    IBMParams(n_tx=2048, n_items=48, n_patterns=100, avg_pattern_len=20,
              avg_tx_len=20, seed=1),     # ~ T500I0.1P100PL20TL50
    IBMParams(n_tx=2048, n_items=96, n_patterns=50, avg_pattern_len=10,
              avg_tx_len=16, seed=2),     # ~ T500I0.4P50PL10TL40
]
SUPPORTS = [0.10, 0.08]
PS = [2, 4, 8]
VARIANTS = ["seq", "par", "reservoir"]


def sequential_work(dense, minsup_rel):
    from repro.core import bitmap as bm
    import jax.numpy as jnp

    db = bm.BitmapDB.from_dense(jnp.asarray(dense))
    minsup = int(np.ceil(minsup_rel * dense.shape[0]))
    t0 = time.perf_counter()
    res = eclat.mine_all(
        db, minsup, config=eclat.EclatConfig(max_out=1, max_stack=4096,
                                             count_only=True)
    )
    wall = time.perf_counter() - t0
    return int(res.n_iters), int(res.n_total), wall


def run(fast: bool = False):
    dbs = DATABASES[:1] if fast else DATABASES
    sups = SUPPORTS[:1] if fast else SUPPORTS
    rows = []
    for p in dbs:
        dense = generate_dense(p)
        for sup in sups:
            w_seq, n_fis, wall_seq = sequential_work(dense, sup)
            for variant in VARIANTS:
                for P in PS:
                    shards = fimi.shard_db(dense, P)
                    # thesis regime: |D̃| ≪ |D| (≈12%, cf. 10k/500k ≈ 2%)
                    params = fimi.FimiParams(
                        variant=variant, min_support_rel=sup,
                        n_db_sample=max(dense.shape[0] // 8, 128),
                        n_fi_sample=512, alpha=0.5,
                        eclat=eclat.EclatConfig(max_out=1, max_stack=4096,
                                                count_only=True),
                    )
                    t0 = time.perf_counter()
                    res = fimi.run(
                        shards, p.n_items, params, jax.random.PRNGKey(P)
                    )
                    wall = time.perf_counter() - t0
                    w4 = res.work_iters.astype(float)
                    # Phase-1 work: sample mining trips ≈ |F̃| (per processor
                    # for par/reservoir; serial for seq)
                    w1 = w_seq * (params.n_db_sample / dense.shape[0])
                    w1 = w1 if variant == "seq" else w1 / P
                    overhead = 0.05 * w_seq / P  # phases 2+3 (measured <5%)
                    speedup = w_seq / (w1 + w4.max() + overhead)
                    rows.append(
                        dict(db=p.name, sup=sup, variant=variant, P=P,
                             speedup=speedup, balance=w4.max() / max(w4.mean(), 1),
                             n_fis=n_fis, repl=res.replication,
                             wall_s=wall)
                    )
                    print(
                        f"{p.name} sup={sup} {variant:9s} P={P}: "
                        f"speedup={speedup:5.2f} balance={rows[-1]['balance']:.2f} "
                        f"repl={res.replication:.2f}",
                        flush=True,
                    )
    return rows


def summarize(rows):
    print("\n== Average speedup per variant (thesis Tables 11.4-11.14 analogue) ==")
    print("| variant | " + " | ".join(f"P={P}" for P in PS) + " |")
    print("|---|" + "---|" * len(PS))
    for v in VARIANTS:
        cells = []
        for P in PS:
            vals = [r["speedup"] for r in rows if r["variant"] == v and r["P"] == P]
            cells.append(f"{np.mean(vals):.2f}" if vals else "-")
        print(f"| {v} | " + " | ".join(cells) + " |")


if __name__ == "__main__":
    rows = run(fast="--fast" in sys.argv)
    summarize(rows)
