"""Kernel micro-benchmarks: the Eclat support-counting hot spot.

CPU wall times compare the pure-jnp reference against the MXU-form (unpacked
dot) — on CPU this measures the *algorithmic* reformulation only; the Pallas
kernels themselves are validated in interpret mode (tests) and their VMEM
working sets are reported structurally here.
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import bitmap as bm  # noqa: E402
from repro.kernels import ref  # noqa: E402


def _time(f, *args, reps=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run(fast: bool = False):
    shapes = [(4096, 128), (16384, 256)] if not fast else [(4096, 128)]
    rows = []
    for n_tx, n_items in shapes:
        rng = np.random.default_rng(0)
        dense = rng.random((n_tx, n_items)) < 0.2
        db = bm.BitmapDB.from_dense(jnp.asarray(dense))
        tid = db.all_tids()

        ext = jax.jit(ref.extension_supports_ref)
        us_ext = _time(ext, db.item_bits, tid)
        pair_v = jax.jit(ref.pair_supports_ref)
        us_pv = _time(pair_v, db.item_bits, tid)
        pair_m = jax.jit(ref.pair_supports_mxu_ref)
        us_pm = _time(pair_m, db.item_bits, tid)
        w = db.item_bits.shape[1]
        vmem_ext = 256 * min(512, w) * 4 / 1024
        rows.append((n_tx, n_items, us_ext, us_pv, us_pm))
        print(f"kernels.extension_supports[{n_tx}x{n_items}],{us_ext:.1f},"
              f"vmem_tile_KiB={vmem_ext:.0f}")
        print(f"kernels.pair_supports_vpu[{n_tx}x{n_items}],{us_pv:.1f},")
        print(f"kernels.pair_supports_mxu[{n_tx}x{n_items}],{us_pm:.1f},"
              f"speedup_vs_vpu={us_pv/us_pm:.2f}x", flush=True)
    return rows


if __name__ == "__main__":
    run(fast="--fast" in sys.argv)
