"""Kernel micro-benchmarks: the Eclat support-counting hot spot.

CPU wall times compare the pure-jnp reference forms — on CPU this measures the
*algorithmic* reformulation only; the Pallas kernels themselves are validated
in interpret mode (tests) and their VMEM working sets are reported
structurally here.

Sections
  * single-prefix vs. multi-prefix: K per-prefix ``extension_supports`` calls
    (the seed miner's inner loop, one launch per DFS node) against ONE fused
    ``multi_extension_supports`` sweep over the K-node frontier;
  * pair supports VPU vs. MXU form;
  * frontier-batched miner: while_loop trips and wall time at K=1 vs K=64 on
    an IBM-generator database.

Results are printed as CSV lines and written machine-readably to
``BENCH_kernels.json`` (shapes, reps, µs) so the perf trajectory is
comparable across PRs.
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from benchmarks.report import bench_meta  # noqa: E402

from repro.core import bitmap as bm  # noqa: E402
from repro.core import eclat  # noqa: E402
from repro.data.ibm_gen import IBMParams, generate_dense  # noqa: E402
from repro.kernels import ref  # noqa: E402

REPS = 5


def _time(f, *args, reps=REPS):
    jax.block_until_ready(f(*args))  # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def _time_per_prefix_looped(ext_jit, item_bits, tids, reps=REPS):
    """The seed miner's cost model: one dispatch per prefix, strictly
    sequential (each DFS trip depends on the previous one's tidlists), K
    dispatches to cover a K-node frontier."""
    K = tids.shape[0]
    jax.block_until_ready(ext_jit(item_bits, tids[0]))  # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        for k in range(K):
            jax.block_until_ready(ext_jit(item_bits, tids[k]))
    return (time.perf_counter() - t0) / reps * 1e6


def run(fast: bool = False, out_path: str = "BENCH_kernels.json"):
    shapes = [(4096, 128), (16384, 256)] if not fast else [(4096, 128)]
    frontier_ks = [8, 64]
    entries = []

    for n_tx, n_items in shapes:
        rng = np.random.default_rng(0)
        dense = rng.random((n_tx, n_items)) < 0.2
        db = bm.BitmapDB.from_dense(jnp.asarray(dense))
        tid = db.all_tids()
        shape = {"n_tx": n_tx, "n_items": n_items}

        ext = jax.jit(ref.extension_supports_ref)
        us_ext = _time(ext, db.item_bits, tid)
        w = db.item_bits.shape[1]
        vmem_ext = 256 * min(512, w) * 4 / 1024
        entries.append(dict(name="extension_supports", **shape, us=us_ext,
                            vmem_tile_kib=vmem_ext))
        print(f"kernels.extension_supports[{n_tx}x{n_items}],{us_ext:.1f},"
              f"vmem_tile_KiB={vmem_ext:.0f}")

        # ---- single-prefix loop vs fused K-prefix batch --------------------
        for K in frontier_ks:
            tids = jnp.broadcast_to(tid, (K, tid.shape[0]))
            us_loop = _time_per_prefix_looped(ext, db.item_bits, tids)
            batched = jax.jit(ref.multi_extension_supports_ref)
            us_batch = _time(batched, db.item_bits, tids)
            entries.append(dict(name="multi_supports_looped", **shape, K=K,
                                us=us_loop))
            entries.append(dict(name="multi_supports_batched", **shape, K=K,
                                us=us_batch, speedup_vs_looped=us_loop / us_batch))
            print(f"kernels.multi_supports_looped[{n_tx}x{n_items},K={K}],"
                  f"{us_loop:.1f},")
            print(f"kernels.multi_supports_batched[{n_tx}x{n_items},K={K}],"
                  f"{us_batch:.1f},speedup_vs_looped={us_loop/us_batch:.2f}x",
                  flush=True)

        # ---- all-pairs VPU vs MXU form -------------------------------------
        pair_v = jax.jit(ref.pair_supports_ref)
        us_pv = _time(pair_v, db.item_bits, tid)
        pair_m = jax.jit(ref.pair_supports_mxu_ref)
        us_pm = _time(pair_m, db.item_bits, tid)
        entries.append(dict(name="pair_supports_vpu", **shape, us=us_pv))
        entries.append(dict(name="pair_supports_mxu", **shape, us=us_pm,
                            speedup_vs_vpu=us_pv / us_pm))
        print(f"kernels.pair_supports_vpu[{n_tx}x{n_items}],{us_pv:.1f},")
        print(f"kernels.pair_supports_mxu[{n_tx}x{n_items}],{us_pm:.1f},"
              f"speedup_vs_vpu={us_pv/us_pm:.2f}x", flush=True)

    # ---- frontier-batched miner: trips + wall time at K=1 vs 64 ------------
    p = IBMParams(n_tx=2048 if fast else 8192, n_items=32, n_patterns=10,
                  avg_pattern_len=6, avg_tx_len=10, seed=5)
    dense = generate_dense(p)
    db = bm.BitmapDB.from_dense(jnp.asarray(dense))
    minsup = int(np.ceil(0.05 * p.n_tx))
    miner = {}
    for K in (1, 64):
        cfg = eclat.EclatConfig(max_out=1 << 14, max_stack=4096, frontier_size=K)

        def mine(_k=K, _cfg=cfg):
            return eclat.mine_all(db, minsup, config=_cfg)

        res = mine()
        trips = int(jax.device_get(res.n_iters))
        n_total = int(jax.device_get(res.n_total))
        overflow = int(jax.device_get(res.stack_overflow))
        # an overflowed run mines a truncated tree — its trip count would be
        # incomparable, so fail loudly instead of recording a bogus speedup
        assert overflow == 0, f"stack overflow at K={K}: {overflow} drops"
        us = _time(lambda: jax.block_until_ready(mine().n_iters), reps=3)
        miner[K] = dict(trips=trips, us=us, n_fis=n_total)
        entries.append(dict(name="eclat_mine_all", db=p.name,
                            min_support=minsup, frontier_size=K,
                            trips=trips, n_fis=n_total,
                            stack_overflow=overflow, us=us))
        print(f"kernels.eclat_mine_all[{p.name},K={K}],{us:.1f},"
              f"trips={trips} n_fis={n_total}", flush=True)
    print(f"kernels.eclat_trip_reduction[{p.name}],,"
          f"{miner[1]['trips'] / max(miner[64]['trips'], 1):.1f}x_fewer_trips",
          flush=True)

    payload = {
        "bench": "kernels",
        "backend": jax.default_backend(),
        "reps": REPS,
        "fast": fast,
        "meta": bench_meta(backend=jax.default_backend()),
        "entries": entries,
    }
    Path(out_path).write_text(json.dumps(payload, indent=2) + "\n")
    print(f"[wrote {out_path}: {len(entries)} entries]", flush=True)
    return entries


if __name__ == "__main__":
    run(fast="--fast" in sys.argv)
