"""Database replication factor — thesis §11.5 (Tables 11.15–11.21).

Measures Σ|D'_i|/|D| after Phase 3 under (a) LPT scheduling and (b) the
greedy-QKP DB-Repl-Min (Alg. 23), reporting the improvement — the thesis'
replication experiment on our scaled databases.
"""
from __future__ import annotations

import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402

from repro.core import eclat, fimi  # noqa: E402
from repro.data.ibm_gen import IBMParams, generate_dense  # noqa: E402

DATABASES = [
    IBMParams(n_tx=1024, n_items=40, n_patterns=30, avg_pattern_len=8,
              avg_tx_len=12, seed=0),   # ~ mushroom-ish density
    IBMParams(n_tx=1024, n_items=64, n_patterns=60, avg_pattern_len=12,
              avg_tx_len=20, seed=1),   # ~ pumsb-ish
    IBMParams(n_tx=2048, n_items=32, n_patterns=20, avg_pattern_len=6,
              avg_tx_len=10, seed=2),   # ~ chess-ish
]


def run(fast: bool = False):
    dbs = DATABASES[:1] if fast else DATABASES
    print("| db | P | repl(LPT) | repl(DB-Repl-Min) | improvement | balance cost |")
    print("|---|---|---|---|---|---|")
    rows = []
    for p in dbs:
        dense = generate_dense(p)
        for P in [4] if fast else [4, 8]:
            out = {}
            work = {}
            for sched in ["lpt", "repl_min"]:
                shards = fimi.shard_db(dense, P)
                params = fimi.FimiParams(
                    variant="reservoir", min_support_rel=0.1,
                    n_db_sample=512, n_fi_sample=256, alpha=0.5,
                    scheduler=sched,
                    eclat=eclat.EclatConfig(max_out=1, max_stack=4096,
                                            count_only=True),
                )
                res = fimi.run(shards, p.n_items, params, jax.random.PRNGKey(7))
                out[sched] = res.replication
                w = res.work_iters.astype(float)
                work[sched] = w.max() / max(w.mean(), 1.0)
            imp = (out["lpt"] - out["repl_min"]) / max(out["lpt"], 1e-9)
            rows.append((p.name, P, out["lpt"], out["repl_min"], imp))
            print(
                f"| {p.name} | {P} | {out['lpt']:.3f} | {out['repl_min']:.3f} | "
                f"{imp*100:+.1f}% | {work['repl_min']/max(work['lpt'],1e-9):.2f}× |",
                flush=True,
            )
    return rows


if __name__ == "__main__":
    run(fast="--fast" in sys.argv)
