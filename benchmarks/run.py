"""Benchmark orchestrator — one section per thesis table/figure family.

  speedup      → §11.4 Tables 11.3–11.14   (three Parallel-FIMI variants)
  pbec         → §11.3 Figs 11.1–11.12     (double-sampling estimation error)
  replication  → §11.5 Tables 11.15–11.21  (LPT vs DB-Repl-Min)
  kernels      → Eclat support-counting hot spot (B.3.1)
  serve        → batched subset-query serving sweep (DESIGN.md §Serving)
  stream       → fused delta-update vs full window recompute (§Streaming)
  cluster      → distributed-executor speedup curve + rebalancing payoff
                 (§Distributed mining)
  io           → out-of-core store: streamed vs in-RAM mine throughput +
                 host high-water marks, O(block) residency gates (§Storage)
  roofline     → EXPERIMENTS.md §Roofline  (reads results/dryrun/*.json)

``python -m benchmarks.run [--fast|--full|--smoke] [--only NAME]``.  Prints
``name,us_per_call,derived`` CSV lines where applicable.  Defaults to the
fast variant so the whole suite stays CPU-friendly; ``--smoke`` runs only
the kernels + serve + stream + cluster + io sections in fast mode (the CI
gate, tools/check.sh).  The kernels, serve, stream, cluster, and io
sections additionally write ``BENCH_kernels.json`` / ``BENCH_serve.json`` /
``BENCH_stream.json`` / ``BENCH_cluster.json`` / ``BENCH_io.json``
(shapes, reps, µs) so the perf trajectory is machine-readable across PRs.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path as _Path

sys.path.insert(0, str(_Path(__file__).resolve().parents[1] / "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--full", action="store_true")
    mode.add_argument("--fast", action="store_true",
                      help="explicit fast mode (the default)")
    mode.add_argument("--smoke", action="store_true",
                      help="CI gate: kernels + serve sections, fast mode")
    ap.add_argument("--only", default="")
    args, _ = ap.parse_known_args()
    fast = not args.full

    sections = ["kernels", "serve", "stream", "cluster", "io", "speedup",
                "pbec", "replication", "roofline"]
    if args.smoke:
        sections = ["kernels", "serve", "stream", "cluster", "io"]
    if args.only:
        sections = [args.only]

    for name in sections:
        print(f"\n===== {name} =====", flush=True)
        t0 = time.perf_counter()
        if name == "kernels":
            from benchmarks import kernels

            kernels.run(fast=fast)
        elif name == "serve":
            from benchmarks import serve

            serve.run(fast=fast)
        elif name == "stream":
            from benchmarks import stream

            stream.run(fast=fast)
        elif name == "cluster":
            from benchmarks import cluster

            cluster.run(fast=fast)
        elif name == "io":
            from benchmarks import io

            io.run(fast=fast)
        elif name == "speedup":
            from benchmarks import speedup

            rows = speedup.run(fast=fast)
            speedup.summarize(rows)
        elif name == "pbec":
            from benchmarks import pbec_estimation

            pbec_estimation.run(fast=fast)
        elif name == "replication":
            from benchmarks import replication

            replication.run(fast=fast)
        elif name == "roofline":
            from benchmarks import roofline

            rows = roofline.full_table("single")
            print(roofline.render_markdown(rows))
        print(f"[{name}: {time.perf_counter()-t0:.1f}s]", flush=True)

    # one cross-suite digest over everything the sections just wrote
    from benchmarks.report import bench_summary

    print("\n===== summary (BENCH_*.json) =====", flush=True)
    print(bench_summary())

    # persistent perf trajectory: one stamped BENCH_HISTORY.jsonl row per
    # suite this invocation (re)wrote — the obs_report history/regress input
    import json
    from pathlib import Path

    from repro.obs import perfdb  # noqa: E402 (src on sys.path above)

    bench_suites = [s for s in sections
                    if s in ("kernels", "serve", "stream", "cluster", "io")]
    for suite in bench_suites:
        f = Path(__file__).resolve().parents[1] / f"BENCH_{suite}.json"
        try:
            payload = json.loads(f.read_text())
        except (OSError, json.JSONDecodeError):
            continue
        meta = payload.get("meta") or {}
        row = perfdb.append(
            str(f.parent / perfdb.DEFAULT_PATH), suite,
            perfdb.bench_result_keys(payload),
            sha=meta.get("git_sha"), backend=meta.get("backend", ""),
            ts=meta.get("ts"),
        )
        print(f"[history += {suite}: {len(row['keys'])} keys @ "
              f"{row['sha'] or '?'}]", flush=True)


if __name__ == "__main__":
    main()
