"""Out-of-core store benchmarks: streamed vs in-RAM mining, host residency.

The storage subsystem's claim (DESIGN.md, "Storage subsystem"): mining from
disk through the double-buffered :class:`repro.store.BlockReader` costs a
bounded throughput factor while the **host high-water mark stays O(block)**
— independent of database size — where the in-RAM pipeline materializes the
whole dense ``[N, I]`` matrix before packing.  Measured here:

  * **spill**      — IBM-generator synthesis straight to disk, one block at
    a time (``write_ibm_store``), vs generating the full dense matrix;
  * **assembly**   — building the ``[P, T, IW]`` device shards from disk
    (``to_device_shards``, block-streamed) vs from the in-RAM dense matrix
    (``fimi.shard_db``); host peaks via ``tracemalloc``, and the streamed
    peak is re-measured on a 2× database to assert it does **not** grow
    with N (the O(block) residency gate);
  * **mine**       — end-to-end ``fimi.run`` throughput (tx/s) over the
    store vs over the in-RAM shards, with bit-exact FITable parity.

Results print as CSV lines and land in ``BENCH_io.json`` (the CI smoke gate
asserts the residency bounds and parity there).
"""
from __future__ import annotations

import dataclasses
import json
import sys
import tempfile
import time
import tracemalloc
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax  # noqa: E402

from repro.core import eclat, fimi  # noqa: E402
from repro.data.ibm_gen import IBMParams, generate_blocks  # noqa: E402
from repro.store import TxStore, write_ibm_store  # noqa: E402
from repro.store.reader import to_device_shards  # noqa: E402

from benchmarks.report import bench_meta  # noqa: E402

P = 4


def _traced(fn, warm: bool = False):
    """(wall seconds, traced-peak bytes, result) of one host-side call.

    ``warm=True`` runs the call once first so jit tracing (a python-side
    allocation spike proportional to program size, not data) is cached and
    the measured peak reflects actual data residency.
    """
    if warm:
        fn()
    tracemalloc.start()
    t0 = time.perf_counter()
    out = fn()
    dt = time.perf_counter() - t0
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    return dt, peak, out


def _fimi_params(n_tx: int) -> fimi.FimiParams:
    return fimi.FimiParams(
        min_support_rel=0.15,
        n_db_sample=min(1024, n_tx), n_fi_sample=512,
        eclat=eclat.EclatConfig(max_out=1 << 15, max_stack=4096,
                                frontier_size=16),
    )


def run(fast: bool = False, out_path: str = "BENCH_io.json"):
    # blocks sized so payload dominates the O(n_blocks) manifest metadata
    block_tx = 512
    n_blocks = 6 if fast else 24
    p = IBMParams(
        n_tx=n_blocks * block_tx, n_items=48, n_patterns=30,
        avg_pattern_len=6, avg_tx_len=10, seed=7,
    )
    p2 = dataclasses.replace(p, n_tx=2 * p.n_tx)  # the 2x database
    key = jax.random.PRNGKey(0)
    tmp = tempfile.mkdtemp(prefix="bench_io_")

    # ---- spill: generate straight to disk vs the full dense matrix --------
    # The residency claim is *scale-independence*: every streamed peak is
    # re-measured on the 2x database and must stay flat, while the in-RAM
    # pipeline's peak (the dense [N, I] materialization) grows with N.
    write_ibm_store(p, f"{tmp}/warm", block_tx=block_tx)  # np.save lazy imports
    s_spill, peak_spill, store = _traced(
        lambda: write_ibm_store(p, f"{tmp}/db", block_tx=block_tx)
    )
    _, peak_spill2, store2 = _traced(
        lambda: write_ibm_store(p2, f"{tmp}/db2", block_tx=block_tx)
    )
    s_gen, peak_gen, dense = _traced(
        lambda: np.concatenate(list(generate_blocks(p, block_tx))), warm=True
    )
    _, peak_gen2, _ = _traced(
        lambda: np.concatenate(list(generate_blocks(p2, block_tx)))
    )
    assert np.array_equal(store.to_dense(), dense)  # same database
    print(f"io-bench: db={p.name} blocks={store.n_blocks}x{block_tx}tx "
          f"disk={store.total_bytes}B dense={dense.nbytes}B")

    # ---- assembly: block-streamed device shards vs in-RAM shard_db --------
    s_asm_ram, peak_asm_ram, shards_ram = _traced(
        lambda: jax.block_until_ready(fimi.shard_db(dense, P)), warm=True
    )
    s_asm_st, peak_asm_st, shards_st = _traced(
        lambda: jax.block_until_ready(to_device_shards(store, P)), warm=True
    )
    assert np.array_equal(np.asarray(shards_st), np.asarray(shards_ram))
    _, peak_asm_st2, _ = _traced(
        lambda: jax.block_until_ready(to_device_shards(store2, P)), warm=True
    )

    # ---- mine: end-to-end throughput + bit-exact parity -------------------
    params = _fimi_params(p.n_tx)
    s_mine_ram, _, res_ram = _traced(
        lambda: fimi.run(shards_ram, p.n_items, params, key,
                         materialize=True),
        warm=True,  # both mines measured post-compile (same executables)
    )
    s_mine_st, _, res_st = _traced(
        lambda: fimi.run(store, None, params, key, materialize=True, P=P)
    )
    assert res_st.fi_dict == res_ram.fi_dict and res_ram.n_fis > 0, (
        "out-of-core mine lost bit-exactness vs the in-RAM path"
    )

    # ---- checksum overhead: verify-on vs verify-off streamed mine ---------
    # Every block read CRC32Cs its payload (DESIGN.md, "Failure model"); the
    # vectorized host checksum must stay in the noise next to the device
    # mine.  Interleaved best-of-3 on both sides: the mine's run-to-run
    # jitter is larger than the checksum itself, and min-of-interleaved
    # runs is the standard way to compare two sub-jitter costs.
    store_nv = TxStore.open(store.directory, verify=False)
    s_mine_v, s_mine_nv = float("inf"), float("inf")
    for _ in range(3):
        s_mine_v = min(s_mine_v, _traced(
            lambda: fimi.run(store, None, params, key, materialize=True, P=P)
        )[0])
        s_mine_nv = min(s_mine_nv, _traced(
            lambda: fimi.run(store_nv, None, params, key,
                             materialize=True, P=P)
        )[0])
    checksum_overhead = s_mine_v / s_mine_nv

    # ---- observability overhead: tracer+metrics on vs off, streamed mine --
    # The obs layer's contract (DESIGN.md, "Observability"): the enabled
    # tracer + registry cost <5% of a streamed mine, and the disabled path
    # is in the noise (it is one attribute check).  Same interleaved
    # best-of-3 min protocol as the checksum gate.
    from repro.obs import metrics as obs_metrics
    from repro.obs import trace as obs_trace

    def _mine_obs(enabled: bool) -> float:
        tr = obs_trace.TRACER
        obs_metrics.reset()
        if enabled:
            tr.clear()
            tr.enable()
        try:
            return _traced(
                lambda: fimi.run(store, None, params, key,
                                 materialize=True, P=P)
            )[0]
        finally:
            tr.disable()

    s_mine_obs, s_mine_base = float("inf"), float("inf")
    for _ in range(3):
        s_mine_obs = min(s_mine_obs, _mine_obs(True))
        s_mine_base = min(s_mine_base, _mine_obs(False))
    obs_overhead = s_mine_obs / s_mine_base
    obs_metrics.reset()
    obs_trace.TRACER.clear()

    tput_ram = p.n_tx / s_mine_ram
    tput_st = p.n_tx / s_mine_st
    block_bytes = block_tx * p.n_items  # one dense generation block
    entries = [
        dict(name="io_spill_generate", s=s_spill, peak_bytes=peak_spill,
             peak_bytes_2x_db=peak_spill2),
        dict(name="io_inram_generate", s=s_gen, peak_bytes=peak_gen,
             peak_bytes_2x_db=peak_gen2),
        dict(name="io_assembly_streamed", s=s_asm_st, peak_bytes=peak_asm_st,
             peak_bytes_2x_db=peak_asm_st2),
        dict(name="io_assembly_inram", s=s_asm_ram, peak_bytes=peak_asm_ram),
        dict(name="io_mine_streamed", s=s_mine_st, tx_per_s=tput_st,
             n_fis=res_st.n_fis),
        dict(name="io_mine_inram", s=s_mine_ram, tx_per_s=tput_ram,
             n_fis=res_ram.n_fis),
        dict(name="io_mine_noverify", s=s_mine_nv,
             checksum_overhead=checksum_overhead),
        dict(name="io_mine_observed", s=s_mine_obs,
             obs_overhead=obs_overhead),
    ]
    for e in entries:
        extra = ",".join(f"{k}={v:.0f}" if isinstance(v, float) else f"{k}={v}"
                         for k, v in e.items() if k not in ("name", "s"))
        print(f"io.{e['name']},{e['s'] * 1e6:.0f},{extra}")

    payload = {
        "bench": "io",
        "backend": jax.default_backend(),
        "db": p.name,
        "block_tx": block_tx,
        "n_blocks": store.n_blocks,
        "P": P,
        "fast": fast,
        "dense_bytes": int(dense.nbytes),
        "block_dense_bytes": int(block_bytes),
        "mine_slowdown_streamed": s_mine_st / s_mine_ram,
        "checksum_overhead_streamed": checksum_overhead,
        "obs_overhead_streamed": obs_overhead,
        "parity": True,
        "meta": bench_meta(backend=jax.default_backend()),
        "entries": entries,
    }
    Path(out_path).write_text(json.dumps(payload, indent=2) + "\n")
    print(f"[wrote {out_path}: 1x->2x db peaks — streamed assembly "
          f"{peak_asm_st}->{peak_asm_st2}B, spill {peak_spill}->"
          f"{peak_spill2}B, in-RAM generate {peak_gen}->{peak_gen2}B]",
          flush=True)

    # The CI gates — O(block) means the streamed peaks are flat in |D|
    # (the manifest is O(n_blocks) metadata, hence the small slack term):
    # (1) block-streamed shard assembly does not scale with the database;
    assert peak_asm_st2 <= 1.5 * peak_asm_st + 8192, (
        f"streamed assembly peak grew with |D|: "
        f"{peak_asm_st}B -> {peak_asm_st2}B"
    )
    # (2) spill-to-store generation does not scale with the database;
    assert peak_spill2 <= 1.5 * peak_spill + 8192, (
        f"spill peak grew with |D|: {peak_spill}B -> {peak_spill2}B"
    )
    # (3) the dense in-RAM pipeline DOES scale (the contrast that makes the
    #     store worth its disk), and at 2x the streamed peak is well below it.
    assert peak_gen2 >= 1.6 * peak_gen, (
        f"in-RAM generation peak unexpectedly flat: "
        f"{peak_gen}B -> {peak_gen2}B (bench miscalibrated?)"
    )
    assert peak_asm_st2 * 3 <= peak_gen2, (
        f"streamed peak {peak_asm_st2}B not O(block) vs dense "
        f"materialization {peak_gen2}B"
    )
    # (4) per-block CRC32C verification costs <5% of the streamed mine
    #     (a small absolute floor absorbs sub-millisecond timer jitter).
    assert s_mine_v <= 1.05 * s_mine_nv + 0.05, (
        f"checksum verification too expensive: verify-on {s_mine_v:.3f}s vs "
        f"verify-off {s_mine_nv:.3f}s ({(checksum_overhead - 1) * 1e2:.1f}%)"
    )
    # (5) full observability (span tracer + metrics registry + device syncs)
    #     costs <5% of the streamed mine (same jitter floor as the checksum
    #     gate; `obs_report baseline` re-gates this key from BENCH_io.json).
    assert s_mine_obs <= 1.05 * s_mine_base + 0.05, (
        f"observability too expensive: enabled {s_mine_obs:.3f}s vs "
        f"disabled {s_mine_base:.3f}s ({(obs_overhead - 1) * 1e2:.1f}%)"
    )
    return entries


if __name__ == "__main__":
    run(fast=("--fast" in sys.argv) or ("--smoke" in sys.argv))
