"""Train an LM end-to-end with checkpoint/restart (driver example).

Reduced config by default so it runs on CPU in minutes; pass --full --arch X
on real hardware.

    PYTHONPATH=src python examples/train_lm.py --steps 120
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch import train


if __name__ == "__main__":
    if "--full" not in sys.argv:
        sys.argv += ["--smoke"]
    else:
        sys.argv.remove("--full")
    train.main()
