"""End-to-end Parallel-FIMI on 8 virtual devices (shard_map) — the paper's
whole pipeline: double sampling → PBEC partition → LPT → exchange → Eclat.

    PYTHONPATH=src python examples/parallel_mining.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np
import jax

from repro.core import eclat, fimi, rules
from repro.data.ibm_gen import IBMParams, generate_dense
from repro.launch.mesh import make_miner_mesh
from repro.serve.index import build_indexes


def main():
    P = 8
    p = IBMParams(n_tx=4096, n_items=48, n_patterns=40, avg_pattern_len=8,
                  avg_tx_len=12, seed=1)
    dense = generate_dense(p)
    shards = fimi.shard_db(dense, P)
    print(f"{p.name}: {dense.shape[0]} tx × {p.n_items} items on {P} miners "
          f"({len(jax.devices())} devices)")

    res = None
    for variant in ("reservoir", "par"):
        params = fimi.FimiParams(
            variant=variant, min_support_rel=0.08,
            n_db_sample=1024, n_fi_sample=512, alpha=0.5,
            # frontier_size=16: each miner pops 16 DFS nodes per trip and
            # counts their extensions in one fused [16, I] sweep (PR 1)
            eclat=eclat.EclatConfig(max_out=1 << 14, max_stack=4096,
                                    frontier_size=16),
        )
        res = fimi.run(
            shards, p.n_items, params, jax.random.PRNGKey(0),
            spmd=fimi.shard_map_spmd, mesh=make_miner_mesh(P),
            materialize=(variant == "par"),
        )
        w = res.work_iters.astype(float)
        print(f"[{variant:9s}] |F|={res.n_fis}  classes={len(res.classes)}  "
              f"replication={res.replication:.2f}  "
              f"balance(max/mean)={w.max()/max(w.mean(),1):.2f}")
        print(f"            est. loads/proc: {np.round(res.est_loads, 1).tolist()}")
        print(f"            real work/proc:  {res.work_iters.tolist()}")

    # ---- mined -> served: the distributed FI table as rules ----------------
    _, rule_index = build_indexes(res.fi_dict, p.n_items, dense.shape[0],
                                  min_confidence=0.6)
    print(f"\n{rule_index.n_rules} association rules at conf>=0.6; top-5:")
    for j in range(min(5, rule_index.n_rules)):
        print("  " + rules.format_rule(rule_index.rule(j), dense.shape[0]))


if __name__ == "__main__":
    main()
