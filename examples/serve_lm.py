"""Serve a small model with batched requests + KV cache (driver example).

    PYTHONPATH=src python examples/serve_lm.py --arch minicpm3-4b
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch import serve


if __name__ == "__main__":
    if "--smoke" not in sys.argv:
        sys.argv += ["--smoke"]
    serve.main()
