"""Quickstart: mine frequent itemsets, then ask the store-owner question.

Mines a synthetic market-basket database with the frontier-batched Eclat,
then turns the FI table into association rules and serves a sample query
through the `repro.serve` subsystem — the full mine-once/serve-many loop.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bitmap as bm, eclat, rules
from repro.data.ibm_gen import IBMParams, generate_dense
from repro.serve import QueryEngine
from repro.serve.index import build_indexes


def main():
    params = IBMParams(n_tx=2048, n_items=40, n_patterns=25,
                       avg_pattern_len=6, avg_tx_len=10, seed=0)
    dense = generate_dense(params)
    db = bm.BitmapDB.from_dense(jnp.asarray(dense))
    min_support = int(0.05 * params.n_tx)
    print(f"database {params.name}: {params.n_tx} transactions, "
          f"{params.n_items} items, min_support={min_support}")

    # frontier_size=16: 16 DFS nodes per while_loop trip, one fused [16, I]
    # support sweep each (PR 1) — same FI set as K=1, ~16x fewer trips.
    res = eclat.mine_all(
        db, min_support,
        config=eclat.EclatConfig(max_out=1 << 14, max_stack=4096,
                                 frontier_size=16),
    )
    n = int(res.n_out)
    print(f"|F| = {int(res.n_total)} frequent itemsets "
          f"({int(res.n_iters)} frontier trips, overflow={int(res.stack_overflow)})")

    supports = np.asarray(res.supports[:n])
    order = np.argsort(-supports)[:10]
    print("top itemsets by support:")
    for k in order:
        mask = np.asarray(bm.unpack_bool(res.items[k], params.n_items))
        items = np.nonzero(mask)[0].tolist()
        print(f"  {items}  supp={supports[k]} ({supports[k]/params.n_tx:.1%})")

    # ---- mined -> served: rules + indexes + a basket query ------------------
    # a truncated FI table is not downward closed and rules would KeyError
    assert int(res.stack_overflow) == 0 and int(res.n_total) == n, \
        "FI buffer overflow: raise max_out/max_stack or min_support"
    fis = {}
    for k in range(n):
        mask = np.asarray(bm.unpack_bool(res.items[k], params.n_items))
        fis[frozenset(np.nonzero(mask)[0].tolist())] = int(supports[k])
    fi_index, rule_index = build_indexes(fis, params.n_items, params.n_tx,
                                         min_confidence=0.6)
    print(f"\n{rule_index.n_rules} association rules at conf>=0.6; top-5:")
    # rule-index rows are sorted by (confidence, support) descending
    for j in range(min(5, rule_index.n_rules)):
        print("  " + rules.format_rule(rule_index.rule(j), params.n_tx))

    engine = QueryEngine(fi_index, rule_index, batch=8, top_k=3)
    basket = frozenset(np.nonzero(dense[0])[0].tolist())
    rows, conf = engine.rules_for(engine.pack([basket]))
    print(f"\nbasket {sorted(basket)} -> recommendations:")
    for row, c in zip(rows[0], conf[0]):
        if row < 0:
            break
        r = rule_index.rule(int(row))
        print(f"  add {sorted(r.consequent)}  (conf={c:.2f}, "
              f"because of {sorted(r.antecedent)})")


if __name__ == "__main__":
    main()
