"""Quickstart: mine frequent itemsets from a synthetic market-basket database.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bitmap as bm, eclat
from repro.data.ibm_gen import IBMParams, generate_dense


def main():
    params = IBMParams(n_tx=2048, n_items=40, n_patterns=25,
                       avg_pattern_len=6, avg_tx_len=10, seed=0)
    dense = generate_dense(params)
    db = bm.BitmapDB.from_dense(jnp.asarray(dense))
    min_support = int(0.05 * params.n_tx)
    print(f"database {params.name}: {params.n_tx} transactions, "
          f"{params.n_items} items, min_support={min_support}")

    res = eclat.mine_all(
        db, min_support,
        config=eclat.EclatConfig(max_out=1 << 14, max_stack=4096),
    )
    n = int(res.n_out)
    print(f"|F| = {int(res.n_total)} frequent itemsets "
          f"({int(res.n_iters)} DFS node expansions, overflow={int(res.stack_overflow)})")

    supports = np.asarray(res.supports[:n])
    order = np.argsort(-supports)[:10]
    print("top itemsets by support:")
    for k in order:
        mask = np.asarray(bm.unpack_bool(res.items[k], params.n_items))
        items = np.nonzero(mask)[0].tolist()
        print(f"  {items}  supp={supports[k]} ({supports[k]/params.n_tx:.1%})")


if __name__ == "__main__":
    main()
