"""Streaming subsystem: sliding-window ingestion with drift-triggered
re-mining and hot-swapped serving indexes.

Closes the loop between the miner and the query-serving subsystem
(DESIGN.md, "Streaming subsystem"):

  * :mod:`repro.stream.window`  — device-resident ring buffer of packed
    transaction blocks, O(1) admit/expire;
  * :mod:`repro.stream.monitor` — Thm 6.1 sample-based staleness test plus
    exact border tracking, deciding *when to re-mine*;
  * :mod:`repro.stream.engine`  — :class:`StreamingMiner`: fused
    arrive/expire support deltas (``kernels/delta_support.py``), full
    re-mine on trigger, atomic index hot-swap in the
    :class:`~repro.serve.engine.QueryEngine`.

End-to-end driver: ``python -m repro.launch.stream_mine``.
"""
from repro.stream.engine import (  # noqa: F401
    AdmitEvent,
    StreamingMiner,
    StreamParams,
    fimi_mine_fn,
)
from repro.stream.monitor import DriftMonitor, DriftVerdict  # noqa: F401
from repro.stream.window import SlidingWindow, WindowSpill  # noqa: F401
