"""StreamingMiner — keep the serving layer fresh against a live stream.

Orchestrates the loop the subsystem exists for (DESIGN.md, "Streaming
subsystem")::

    admit block ─→ fused delta-support update ─→ drift check ─→ (on trigger)
        full re-mine of the window ─→ build standby indexes ─→ atomic
        hot-swap inside the QueryEngine (generation bump + cache clear)

Between re-mines the serving indexes are **immutable** — queries stay pure
vector work against frozen device arrays — while a host-side support vector
tracks the *exact* current window supports of every indexed itemset via the
``[2, F]`` arrive/expire kernel (``kernels/delta_support.py``).  That exact
vector feeds the monitor's border signal and the staleness report; the
sample-based Thm 6.1 signal needs no exact state at all.

Re-mining is pluggable: ``mine_fn(window, abs_minsup) -> {frozenset: supp}``
defaults to the full Parallel-FIMI pipeline over the window
(:func:`fimi_mine_fn`); tests inject the brute-force oracle.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmap as bm
from repro.kernels import ops
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve.cache import QueryCache
from repro.serve.engine import QueryEngine
from repro.serve.index import build_indexes
from repro.stream.monitor import DriftMonitor, DriftVerdict
from repro.stream.window import SlidingWindow, WindowSpill

MineFn = Callable[[SlidingWindow, int], Dict[frozenset, int]]


@dataclasses.dataclass(frozen=True)
class StreamParams:
    """Knobs of the streaming subsystem (window ∪ monitor ∪ serving)."""

    n_blocks: int = 8               # ring length B (window = B·block_tx tx)
    block_tx: int = 256             # transactions per stream block
    min_support_rel: float = 0.1
    min_confidence: float = 0.6
    eps: float = 0.1                # staleness tolerance ε (monitor)
    delta: float = 0.05             # confidence 1−δ (Thm 6.1)
    border_margin: float = 0.0      # exact border tracking width (0 = off)
    border_hysteresis: float = 0.0  # crossing must clear minsup by this much
    check_every: int = 1            # drift-check cadence in blocks
    cooldown_blocks: int = 0        # suppress triggers this long after a mine
    batch: int = 256                # QueryEngine dispatch width
    top_k: int = 5
    cache_capacity: int = 2048
    force: Optional[str] = None     # kernel backend pin (kernels.ops)
    spill_dir: Optional[str] = None  # persist expired blocks to a TxStore
    seed: int = 0


@dataclasses.dataclass
class AdmitEvent:
    """What one :meth:`StreamingMiner.admit` did (driver-observable)."""

    block_index: int
    expired: bool                   # an old block left the window
    delta_applied: bool             # supports updated in place
    verdict: Optional[DriftVerdict]
    remined: bool
    remine_reason: Optional[str]    # "initial" | "error" | "border" | "recovery"
    mine_ms: float = 0.0            # re-mine + standby index build
    swap_ms: float = 0.0            # the atomic publish itself
    generation: int = 0


@dataclasses.dataclass
class StreamStats:
    blocks_in: int = 0
    tx_in: int = 0
    remines: int = 0
    drift_checks: int = 0
    fired_error: int = 0
    fired_border: int = 0
    fired_recovery: int = 0   # re-mines forced by an empty mined table
    mine_ms: List[float] = dataclasses.field(default_factory=list)
    swap_ms: List[float] = dataclasses.field(default_factory=list)

    def as_dict(self) -> dict:
        return {
            "blocks_in": self.blocks_in,
            "tx_in": self.tx_in,
            "remines": self.remines,
            "drift_checks": self.drift_checks,
            "fired_error": self.fired_error,
            "fired_border": self.fired_border,
            "fired_recovery": self.fired_recovery,
            "mine_ms_mean": float(np.mean(self.mine_ms)) if self.mine_ms else 0.0,
            "swap_ms_max": float(np.max(self.swap_ms)) if self.swap_ms else 0.0,
        }


def fimi_mine_fn(
    P: int = 4, fimi_params=None, seed: int = 0
) -> MineFn:
    """Default re-miner: the full Parallel-FIMI pipeline over the window.

    Shards the materialized window row-wise over ``P`` (virtual) miners and
    runs the four-phase pipeline (``core.fimi.run``) with ``materialize=True``.
    ``fimi_params`` overrides everything except ``min_support_rel``, which is
    always derived from the trigger's absolute minsup.
    """
    from repro.core import eclat, fimi

    def mine(window: SlidingWindow, abs_minsup: int) -> Dict[frozenset, int]:
        n_tx = window.n_tx
        assert n_tx % P == 0, f"window size {n_tx} not divisible by P={P}"
        rows = window.rows()
        shards = rows.reshape(P, n_tx // P, window.n_words)
        base = fimi_params or fimi.FimiParams(
            n_db_sample=min(1024, n_tx),
            n_fi_sample=512,
            eclat=eclat.EclatConfig(
                max_out=1 << 14, max_stack=4096, frontier_size=16
            ),
        )
        # (abs−0.5)/n_tx survives the float round-trip: fimi.run's
        # ceil(rel·n_tx) lands exactly on abs_minsup, whereas abs/n_tx can
        # ceil to abs+1 and silently drop itemsets at exactly abs_minsup
        params = dataclasses.replace(
            base, min_support_rel=(abs_minsup - 0.5) / n_tx
        )
        res = fimi.run(
            shards, window.n_items, params, jax.random.PRNGKey(seed),
            materialize=True,
        )
        return res.fi_dict

    return mine


class StreamingMiner:
    """The streaming control loop: window + monitor + serving engine.

    Life cycle: admit blocks; once the window first fills, mine it and bring
    the :class:`~repro.serve.engine.QueryEngine` up (generation 0).  Every
    later admit evicts the oldest block, applies the fused arrive/expire
    support delta, and (on the configured cadence) runs the drift check;
    a trigger re-mines the *current* window into standby indexes and
    hot-swaps them in.  ``engine`` is None until the first mine completes.
    """

    def __init__(
        self,
        params: StreamParams,
        n_items: int,
        *,
        mine_fn: Optional[MineFn] = None,
    ):
        self.params = params
        self.n_items = n_items
        self.window = SlidingWindow.empty(
            params.n_blocks, params.block_tx, n_items
        )
        self.monitor = DriftMonitor(
            params.n_blocks,
            params.block_tx,
            eps=params.eps,
            delta=params.delta,
            border_margin=params.border_margin,
            border_hysteresis=params.border_hysteresis,
            seed=params.seed,
        )
        self.mine_fn = mine_fn or fimi_mine_fn(seed=params.seed)
        # store-backed spill: evicted blocks persist as the stream's history
        self.spill: Optional[WindowSpill] = (
            WindowSpill(params.spill_dir, params.block_tx, n_items)
            if params.spill_dir
            else None
        )
        self.cache = QueryCache(capacity=params.cache_capacity)
        self.engine: Optional[QueryEngine] = None
        self.current_supports: Optional[np.ndarray] = None  # int64[F], exact
        self.stats = StreamStats()
        self._since_check = 0
        self._since_remine = 0

    # -- views ----------------------------------------------------------------
    @property
    def abs_minsup(self) -> int:
        return int(np.ceil(self.params.min_support_rel * self.window.n_tx))

    def _index_masks(self) -> jnp.ndarray:
        """Valid rows of the serving FI mask slab (drops shape padding)."""
        idx = self.engine.index
        return idx.masks[: idx.n_fis]

    def served_rel_supports(self) -> np.ndarray:
        """float64[F] — what the serving index claims (mine-time snapshot)."""
        idx = self.engine.index
        return (
            np.asarray(idx.supports)[: idx.n_fis].astype(np.float64) / idx.n_tx
        )

    def current_rel_supports(self) -> np.ndarray:
        """float64[F] — exact delta-maintained window supports, relative."""
        return self.current_supports.astype(np.float64) / self.window.n_tx

    def exact_window_supports(self) -> np.ndarray:
        """int64[F] — offline oracle: full recompute over the whole window.

        O(window) work — this is the per-block cost the delta kernel avoids
        (benchmarks/stream.py); used for staleness reporting and invariants.
        """
        counts = ops.block_itemset_supports(
            self.window.stacked(), self._index_masks(), force=self.params.force
        )
        return np.asarray(counts).sum(axis=0).astype(np.int64)

    def staleness(self) -> float:
        """max |served_rel − true current rel support| over indexed FIs."""
        if self.engine is None or self.engine.index.n_fis == 0:
            return 0.0
        true_rel = (
            self.exact_window_supports().astype(np.float64) / self.window.n_tx
        )
        return float(np.abs(self.served_rel_supports() - true_rel).max())

    # -- the control loop ------------------------------------------------------
    def admit(self, block) -> AdmitEvent:
        """Ingest one stream block (dense bool [T, I] or packed uint32 [T, IW])."""
        block = np.asarray(block)
        if block.dtype != np.uint32:
            block = np.asarray(bm.pack_bool(jnp.asarray(block, jnp.bool_)))
        arrive = jnp.asarray(block, jnp.uint32)

        self.window, expired = self.window.admit(arrive)
        if expired is not None and self.spill is not None:
            self.spill.append(expired)
        self.monitor.admit(block)
        self.stats.blocks_in += 1
        self.stats.tx_in += self.window.block_tx
        reg = obs_metrics.registry()
        reg.counter("stream/blocks_in").inc()
        reg.counter("stream/tx_in").inc(self.window.block_tx)
        ev = AdmitEvent(
            block_index=self.stats.blocks_in - 1,
            expired=expired is not None,
            delta_applied=False,
            verdict=None,
            remined=False,
            remine_reason=None,
        )

        if self.engine is None:
            if self.window.full:
                self._remine("initial", ev)
            return self._stamp(ev)

        # steady state: engine exists ⇒ the window was full ⇒ every admit evicts
        assert expired is not None
        F = self.engine.index.n_fis
        if F:
            counts = ops.delta_supports(
                arrive, expired, self._index_masks(), force=self.params.force
            )
            counts = np.asarray(counts).astype(np.int64)
            self.current_supports += counts[0] - counts[1]
            ev.delta_applied = True
            reg.counter("stream/delta_updates").inc()

        # drift-triggered re-mining is rate-limited: during a drift washout
        # the window keeps changing for B blocks, and re-mining every one of
        # them buys little freshness for full mining cost.
        self._since_remine += 1
        if self._since_remine <= self.params.cooldown_blocks:
            return self._stamp(ev)

        self._since_check += 1
        if self._since_check >= self.params.check_every:
            self._since_check = 0
            if F == 0:
                # an empty mined table has nothing to monitor (no masks to
                # estimate, no border to track) but must not wedge the loop:
                # re-mine unconditionally until the stream yields FIs again
                self.stats.fired_recovery += 1
                self._remine("recovery", ev)
                return self._stamp(ev)
            self.stats.drift_checks += 1
            reg.counter("stream/drift_checks").inc()
            ev.verdict = self.monitor.check(
                self._index_masks(),
                current_rel=self.current_rel_supports(),
                force=self.params.force,
            )
            if ev.verdict.fired:
                if ev.verdict.reason == "border":
                    self.stats.fired_border += 1
                else:
                    self.stats.fired_error += 1
                reg.counter(f"stream/fired_{ev.verdict.reason}").inc()
                obs_trace.TRACER.instant(
                    "stream/drift",
                    reason=ev.verdict.reason,
                    block=ev.block_index,
                )
                self._remine(ev.verdict.reason, ev)
        return self._stamp(ev)

    def _stamp(self, ev: AdmitEvent) -> AdmitEvent:
        ev.generation = self.engine.generation if self.engine else -1
        return ev

    def _remine(self, reason: str, ev: AdmitEvent) -> None:
        """Mine the current window, build standby indexes, hot-swap."""
        t0 = time.perf_counter()
        with obs_trace.TRACER.span("stream/remine", reason=reason,
                                   block=ev.block_index):
            fis = self.mine_fn(self.window, self.abs_minsup)
            fi_idx, rule_idx = build_indexes(
                fis,
                self.n_items,
                self.window.n_tx,
                min_confidence=self.params.min_confidence,
            )
        ev.mine_ms = (time.perf_counter() - t0) * 1e3

        t0 = time.perf_counter()
        with obs_trace.TRACER.span("stream/swap", reason=reason):
            if self.engine is None:
                self.engine = QueryEngine(
                    fi_idx,
                    rule_idx,
                    batch=self.params.batch,
                    top_k=self.params.top_k,
                    force=self.params.force,
                    cache=self.cache,
                )
            else:
                self.engine.swap_indexes(fi_idx, rule_idx)
        ev.swap_ms = (time.perf_counter() - t0) * 1e3
        reg = obs_metrics.registry()
        reg.counter("stream/remines").inc()
        reg.histogram("stream/mine_ms").record(ev.mine_ms)
        reg.histogram("stream/swap_ms").record(ev.swap_ms)

        F = fi_idx.n_fis
        self.current_supports = (
            np.asarray(fi_idx.supports)[:F].astype(np.int64)
        )
        self.monitor.rearm(
            self.served_rel_supports(), self.params.min_support_rel
        )
        self.stats.remines += 1
        self.stats.mine_ms.append(ev.mine_ms)
        self.stats.swap_ms.append(ev.swap_ms)
        ev.remined = True
        ev.remine_reason = reason
        self._since_check = 0
        self._since_remine = 0
