"""Device-resident sliding window of packed transaction blocks.

The stream arrives in fixed-size blocks of ``block_tx`` transactions,
horizontally packed (``uint32[block_tx, IW]``, layout of
``core.bitmap.pack_bool``).  The window holds the most recent ``n_blocks``
blocks in a ring buffer slab ``uint32[B, T_blk, IW]`` that never moves:
admit writes one slot, expire is implicit (the overwritten slot), both O(1)
in device work — one ``at[slot].set`` — regardless of window length.

The buffer is a frozen functional structure in the repo's pytree style:
:meth:`admit` returns ``(new_window, expired_block | None)`` and the caller
threads the new value (the `StreamingMiner` owns exactly one).  Ring
position (``head``/``count``) is static host state, like every other static
shape parameter in this codebase — the device never scans for sentinels.

Support bookkeeping against the window is the delta identity the streaming
kernel (`kernels/delta_support.py`) exists for::

  supp_W'(f) = supp_W(f) + |{t ∈ arrive : f ⊆ t}| − |{t ∈ expire : f ⊆ t}|

:meth:`rows` / :meth:`to_bitmap_db` materialize the logical window (oldest →
newest) for full re-mining; the ring order is resolved by a host-side gather
of block slots, never by copying on admit.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmap as bm

_U32 = jnp.uint32


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SlidingWindow:
    """Ring buffer of the last ``n_blocks`` packed transaction blocks.

    Attributes:
      blocks:   ``uint32[B, T_blk, IW]`` slab; slot contents are valid for
                the ``count`` logical blocks, others are zero/stale.
      head:     slot index of the *oldest* resident block (static).
      count:    number of resident blocks, ≤ B (static).
      n_items:  |B| of the item universe (static).
    """

    blocks: jnp.ndarray
    head: int
    count: int
    n_items: int

    def tree_flatten(self):
        return (self.blocks,), (self.head, self.count, self.n_items)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)

    # -- constructors ---------------------------------------------------------
    @classmethod
    def empty(cls, n_blocks: int, block_tx: int, n_items: int) -> "SlidingWindow":
        slab = jnp.zeros((n_blocks, block_tx, bm.n_words(n_items)), _U32)
        return cls(blocks=slab, head=0, count=0, n_items=n_items)

    # -- ring geometry --------------------------------------------------------
    @property
    def n_blocks(self) -> int:
        return int(self.blocks.shape[0])

    @property
    def block_tx(self) -> int:
        return int(self.blocks.shape[1])

    @property
    def n_words(self) -> int:
        return int(self.blocks.shape[2])

    @property
    def full(self) -> bool:
        return self.count == self.n_blocks

    @property
    def n_tx(self) -> int:
        """Transactions currently resident (count · block size)."""
        return self.count * self.block_tx

    def slot_order(self) -> Tuple[int, ...]:
        """Resident slot indices in logical (oldest → newest) order."""
        return tuple(
            (self.head + i) % self.n_blocks for i in range(self.count)
        )

    # -- admit / expire -------------------------------------------------------
    def admit(
        self, block: jnp.ndarray
    ) -> Tuple["SlidingWindow", Optional[jnp.ndarray]]:
        """Admit one packed block; O(1) device work.

        Returns ``(window', expired)`` where ``expired`` is the evicted
        oldest block once the ring is full, else None (warm-up: the window
        only grows).
        """
        block = jnp.asarray(block, _U32)
        assert block.shape == (self.block_tx, self.n_words), (
            f"block shape {block.shape} != {(self.block_tx, self.n_words)}"
        )
        if not self.full:
            slot = (self.head + self.count) % self.n_blocks
            return (
                dataclasses.replace(
                    self,
                    blocks=self.blocks.at[slot].set(block),
                    count=self.count + 1,
                ),
                None,
            )
        expired = self.blocks[self.head]
        return (
            dataclasses.replace(
                self,
                blocks=self.blocks.at[self.head].set(block),
                head=(self.head + 1) % self.n_blocks,
            ),
            expired,
        )

    # -- materialized views (re-mine path only) -------------------------------
    def rows(self) -> jnp.ndarray:
        """``uint32[count·T_blk, IW]`` — resident rows, oldest → newest."""
        order = jnp.asarray(self.slot_order(), jnp.int32)
        picked = jnp.take(self.blocks, order, axis=0)
        return picked.reshape(-1, self.n_words)

    def stacked(self) -> jnp.ndarray:
        """``uint32[count, T_blk, IW]`` resident blocks — the shape of the
        fused per-block support sweep (``kernels.ops.block_itemset_supports``),
        used by the full-recompute oracle in tests and benchmarks."""
        order = jnp.asarray(self.slot_order(), jnp.int32)
        return jnp.take(self.blocks, order, axis=0)

    def to_bitmap_db(self) -> bm.BitmapDB:
        """Full BitmapDB of the current window (the re-mine input)."""
        return bm.rebuild_vertical(self.rows(), self.n_items, self.n_tx)


class WindowSpill:
    """Store-backed spill mode: expired blocks persist instead of vanishing.

    Wraps an append-only :class:`repro.store.StoreWriter` on ``directory``;
    every block the ring evicts is appended (oldest → newest, the stream's
    arrival order), so the on-disk store is the stream's **history** beyond
    the window — re-minable later with ``fimi.run(store, …)`` or auditable
    with the streamed support counters, at O(block) host cost at both ends.
    An existing store at ``directory`` is resumed (appended after its last
    block; geometry must match), never reset — a restarted stream extends
    its history.  The resume path runs the writer's shallow fsck pass
    first (``store/fsck.py``), which adopts any blocks a crashed stream
    saved but never indexed and clears torn residue, so a kill mid-spill
    never corrupts the history the restart appends to.

    The engine wires this up via ``StreamParams.spill_dir``; standalone use::

        spill = WindowSpill(directory, window.block_tx, window.n_items)
        window, expired = window.admit(block)
        if expired is not None:
            spill.append(expired)
    """

    def __init__(
        self, directory: str, block_tx: int, n_items: int, *,
        source: str = "stream-spill",
    ):
        from repro.store.store import StoreWriter

        self.directory = directory
        self._writer = StoreWriter(
            directory, n_items=n_items, block_tx=block_tx, source=source,
            resume=True,
        )

    def append(self, expired_packed) -> int:
        """Persist one evicted packed block ``uint32[T_blk, IW]``."""
        return self._writer.append_packed(np.asarray(expired_packed))

    @property
    def n_spilled(self) -> int:
        return len(self._writer.manifest.blocks)

    def store(self):
        """Open the spilled history as a readable :class:`TxStore`."""
        return self._writer.close()
