"""Drift detection for the streaming miner — thesis Ch. 6 turned online.

The thesis uses sampling to make mining *cheaper*; here the same machinery
decides *when a mined FI table has gone stale*.  The monitor maintains a
uniform sample of the **current window** and fires a re-mine trigger on
either of two signals:

* **Support-error signal** (Thm 6.1).  Estimate the relative support of
  every indexed itemset on the sample and compare against what the serving
  index claims.  The sample is sized by ``sampling.db_sample_size(ε/2, δ)``
  so the estimator itself errs ≤ ε/2 w.p. ≥ 1−δ; firing when the observed
  discrepancy exceeds ε/2 then gives the two-sided guarantee (per itemset,
  w.p. ≥ 1−δ): a fresh table (true error 0) does not fire, and a table whose
  true support error exceeds ε does.
* **Border signal** (exact).  Itemsets whose mine-time relative support was
  within ``border_margin`` of minsup are *tracked*; the streaming engine
  maintains their exact current window supports via the delta kernel, and
  the monitor fires as soon as a tracked itemset crosses minsup — the
  mined table's membership is then provably wrong, no estimation needed.
  ``border_hysteresis`` requires the crossing to clear minsup by that much
  before firing, so a support sitting exactly on the threshold doesn't
  flap a re-mine on every one-transaction fluctuation.

Window sampling is stratified by block: ``m = ⌈n/B⌉`` rows are drawn
uniformly without replacement from each admitted block and retired with it
(a deque aligned with the ring buffer).  Blocks have equal size, so the
union is a uniform (without-replacement) sample of the window — the
hypergeometric regime of Thm 6.3, for which the with-replacement Chernoff
bound of Thm 6.1 is conservative.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from collections import deque
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import sampling
from repro.kernels import ops


def chernoff_eps(n: int, delta: float) -> float:
    """Invert Thm 6.1: support error of an n-row sample, w.p. ≥ 1−δ."""
    return math.sqrt(math.log(2.0 / delta) / (2.0 * n))


@dataclasses.dataclass(frozen=True)
class DriftVerdict:
    """Outcome of one drift check (all fields observable by the driver)."""

    fired: bool
    reason: Optional[str]        # "error" | "border" | None
    max_err: float               # max |p̂_sample − p_served| over indexed FIs
    threshold: float             # the ε/2 firing threshold
    eps_sample: float            # Thm 6.1 error of the sample actually held
    n_sample: int
    n_border_crossed: int = 0


class DriftMonitor:
    """Window sampler + staleness test for a served FI table.

    Args:
      eps:    staleness tolerance ε on relative support (fire at true
              error > ε; never fire at 0, each w.p. ≥ 1−δ).
      delta:  confidence parameter δ of Thm 6.1.
      n_blocks / block_tx: ring geometry (sets the per-block sample quota).
      border_margin: track itemsets with |supp_rel − minsup| ≤ margin for
              the exact border signal (0 disables).
      seed:   host RNG seed (sampling is deterministic given the stream).
    """

    def __init__(
        self,
        n_blocks: int,
        block_tx: int,
        *,
        eps: float = 0.1,
        delta: float = 0.05,
        border_margin: float = 0.0,
        border_hysteresis: float = 0.0,
        seed: int = 0,
    ):
        self.eps = float(eps)
        self.delta = float(delta)
        self.border_margin = float(border_margin)
        self.border_hysteresis = float(border_hysteresis)
        n_target = sampling.db_sample_size(eps / 2.0, delta)
        self.rows_per_block = min(block_tx, -(-n_target // n_blocks))
        if n_blocks * block_tx < n_target:
            # the whole window is smaller than the Thm 6.1 sample: the ε/2
            # firing threshold no longer carries the two-sided guarantee
            # (check() still reports the achievable eps_sample per verdict)
            warnings.warn(
                f"window of {n_blocks * block_tx} tx cannot hold the "
                f"{n_target}-row Thm 6.1 sample for eps={eps}, delta={delta}; "
                f"drift detection degrades to "
                f"eps≈{2 * chernoff_eps(n_blocks * block_tx, delta):.3f}",
                stacklevel=2,
            )
        self._samples: deque = deque(maxlen=n_blocks)
        self._rng = np.random.default_rng(seed)
        # armed state (set by rearm() after each (re-)mine)
        self._served_rel: Optional[np.ndarray] = None
        self._tracked: Optional[np.ndarray] = None
        self._minsup_rel: float = 0.0

    # -- window sample maintenance -------------------------------------------
    def admit(self, block_packed: np.ndarray) -> None:
        """Subsample one admitted block; the deque retires the expired one."""
        block = np.asarray(block_packed, np.uint32)
        pick = self._rng.choice(
            block.shape[0], size=self.rows_per_block, replace=False
        )
        self._samples.append(block[pick])

    @property
    def n_sample(self) -> int:
        return sum(s.shape[0] for s in self._samples)

    def sample_rows(self) -> np.ndarray:
        """uint32[n_sample, IW] — the current window sample."""
        return np.concatenate(list(self._samples), axis=0)

    # -- arming ----------------------------------------------------------------
    def rearm(self, served_rel: np.ndarray, minsup_rel: float) -> None:
        """Snapshot what the freshly swapped index serves; reset tracking."""
        self._served_rel = np.asarray(served_rel, np.float64)
        self._minsup_rel = float(minsup_rel)
        if self.border_margin > 0.0:
            self._tracked = (
                np.abs(self._served_rel - minsup_rel) <= self.border_margin
            )
        else:
            self._tracked = np.zeros(self._served_rel.shape, bool)

    # -- the drift test --------------------------------------------------------
    def estimate_rel_supports(
        self, fi_masks: jnp.ndarray, *, force: Optional[str] = None
    ) -> np.ndarray:
        """float64[F] sample-estimated relative supports of the indexed FIs."""
        rows = jnp.asarray(self.sample_rows())
        counts = ops.block_itemset_supports(rows[None], fi_masks, force=force)
        return np.asarray(counts)[0].astype(np.float64) / rows.shape[0]

    def check(
        self,
        fi_masks: jnp.ndarray,
        *,
        current_rel: Optional[np.ndarray] = None,
        force: Optional[str] = None,
    ) -> DriftVerdict:
        """Run both staleness signals against the armed serving snapshot.

        ``current_rel`` (optional) is the engine's exact delta-maintained
        relative supports — enables the border signal; the support-error
        signal needs only the sample.
        """
        assert self._served_rel is not None, "monitor not armed (call rearm)"
        n = self.n_sample
        est = self.estimate_rel_supports(fi_masks, force=force)
        err = np.abs(est - self._served_rel)
        max_err = float(err.max()) if err.size else 0.0
        threshold = self.eps / 2.0
        eps_n = chernoff_eps(n, self.delta) if n else float("inf")

        n_crossed = 0
        if current_rel is not None and self._tracked is not None:
            cur = np.asarray(current_rel)
            h = self.border_hysteresis
            served_freq = self._served_rel >= self._minsup_rel
            # crossing must clear minsup by the hysteresis band to count
            crossed = np.where(
                served_freq,
                cur < self._minsup_rel - h,
                cur >= self._minsup_rel + h,
            )
            n_crossed = int((self._tracked & crossed).sum())

        if n_crossed:
            reason: Optional[str] = "border"
        elif max_err > threshold:
            reason = "error"
        else:
            reason = None
        return DriftVerdict(
            fired=reason is not None,
            reason=reason,
            max_err=max_err,
            threshold=threshold,
            eps_sample=eps_n,
            n_sample=n,
            n_border_crossed=n_crossed,
        )
