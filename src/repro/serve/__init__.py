"""Serving subsystem: mine once, serve many.

Turns a mined FI table into a queryable online service (DESIGN.md,
"Serving subsystem"):

  * :mod:`repro.serve.index`  — immutable device-resident FI/rule indexes
    (packed uint32 itemset masks + metric vectors + per-size offsets);
  * :mod:`repro.serve.engine` — batched query engine: Q queries per
    dispatch over the fused subset/superset Pallas sweep
    (``repro.kernels.subset_query``); indexes are hot-swappable under
    traffic (generation counter, used by ``repro.stream``);
  * :mod:`repro.serve.cache`  — LRU query cache keyed on packed query
    masks, with hit-rate counters and swap invalidation.

End-to-end drivers: ``python -m repro.launch.serve_mine`` (static) and
``python -m repro.launch.stream_mine`` (streaming).
"""
from repro.serve.cache import QueryCache  # noqa: F401
from repro.serve.engine import QueryEngine  # noqa: F401
from repro.serve.index import FIIndex, RuleIndex  # noqa: F401
