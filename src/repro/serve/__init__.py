"""Serving subsystem: mine once, serve many.

Turns a mined FI table into a queryable online service (DESIGN.md,
"Serving subsystem"):

  * :mod:`repro.serve.index`  — immutable device-resident FI/rule indexes
    (packed uint32 itemset masks + metric vectors + per-size offsets);
  * :mod:`repro.serve.engine` — batched query engine: Q queries per
    dispatch over the fused subset/superset Pallas sweep
    (``repro.kernels.subset_query``); indexes are hot-swappable under
    traffic (generation counter, used by ``repro.stream``);
  * :mod:`repro.serve.cache`  — LRU query cache keyed on packed query
    masks, with hit-rate counters and swap invalidation;
  * :mod:`repro.serve.service` — the production front end over N replica
    engines: arrival-stream micro-batching (flush on deadline or width),
    bounded-queue admission control with typed ``Shed`` results, and
    generation-consistent hot-swap across the replica fleet (DESIGN.md,
    "Serving service & SLOs").

End-to-end drivers: ``python -m repro.launch.serve_mine`` (static),
``python -m repro.launch.stream_mine`` (streaming), and
``python -m repro.launch.serve_load`` (arrival-process load harness with
live windowed SLO telemetry).
"""
from repro.serve.cache import QueryCache  # noqa: F401
from repro.serve.engine import EngineSnapshot, QueryEngine  # noqa: F401
from repro.serve.index import FIIndex, RuleIndex  # noqa: F401
from repro.serve.service import (  # noqa: F401
    Failed,
    MiningService,
    Shed,
    Ticket,
)
