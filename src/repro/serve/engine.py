"""Batched query engine over the FI/rule indexes.

Answers Q queries per dispatch — the serving analogue of frontier batching
(DESIGN.md): one fused ``[Q, F]`` subset/superset sweep
(``kernels.subset_query``) instead of Q per-query launches, so the index
slab streams from HBM once per batch and every lane stays busy.

Three query types, all over packed uint32 query masks ``[Q, IW]``:

  * :func:`support_lookup` — exact support of each queried itemset
    (-1 if not frequent): equality is ``miss == 0 & extra == 0`` on the
    set-difference counts, plus the size-band trick — only rows whose
    cardinality equals the query's can match, so candidate scoring masks by
    the index ``sizes`` vector (no host branching).
  * :func:`top_rules_for_baskets` — the store-owner query: top-K rules by
    confidence whose antecedent ⊆ basket; ``novel_only`` drops rules whose
    consequent is already fully in the basket (a recommendation, not a
    restatement).  One sweep over the stacked ``[2R, IW]`` antecedent ∥
    consequent slab answers both tests.
  * :func:`top_supersets` — completion query: top-K frequent supersets of a
    (partial) itemset, by support; ties prefer fewer extra items.

All three are jit-compiled with static K and static index row counts;
results are (indices, score) pairs with index -1 ⇔ "no more hits", so a
short result list never fabricates entries.

:class:`QueryEngine` wraps the functions with a fixed batch width Q: every
dispatch is padded to Q rows (one compiled program per query type, no
recompiles mid-serve) — exactly how a production server amortizes traffic.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rules as rules_mod
from repro.kernels import ops
from repro.serve.index import FIIndex, RuleIndex

NOT_FOUND = -1


# ---------------------------------------------------------------------------
# Batched query primitives (jit; index pytrees as traced args)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("force",))
def support_lookup(
    index: FIIndex,
    query_masks: jnp.ndarray,      # uint32[Q, IW]
    query_sizes: jnp.ndarray,      # int32[Q] — |q| (popcount of the mask)
    *,
    force: Optional[str] = None,
) -> jnp.ndarray:
    """int32[Q] absolute supports; ``NOT_FOUND`` for non-frequent queries."""
    miss, extra = ops.subset_superset_counts(query_masks, index.masks,
                                            force=force)
    # equality needs both difference counts zero; the size check is redundant
    # given both counts but keeps the match honest on the all-zero pad row.
    eq = (
        (miss == 0)
        & (extra == 0)
        & (index.sizes[None, :] == query_sizes[:, None])
        & index.valid()[None, :]
    )
    row = jnp.argmax(eq, axis=1)
    found = eq.any(axis=1)
    return jnp.where(found, index.supports[row], NOT_FOUND)


@functools.partial(jax.jit, static_argnames=("k", "novel_only", "force"))
def top_rules_for_baskets(
    rules: RuleIndex,
    basket_masks: jnp.ndarray,     # uint32[Q, IW]
    *,
    k: int = 5,
    novel_only: bool = True,
    force: Optional[str] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(rule_rows int32[Q, k], confidence f32[Q, k]); row -1 ⇔ no hit.

    A rule applies to basket q iff antecedent ⊆ q.  Ranking is by
    confidence with support as tie-break (the RuleIndex row order).
    """
    R = rules.r_pad
    # one sweep over the stacked antecedent ∥ consequent slab: [Q, 2R]
    miss, _ = ops.subset_superset_counts(basket_masks, rules.ant_con,
                                         force=force)
    applies = (miss[:, :R] == 0) & rules.valid()[None, :]
    if novel_only:
        applies &= miss[:, R:] > 0
    # rows are confidence-sorted, so rank by (applies, confidence): boosting
    # applicable rows by 2 (> max confidence 1) keeps relative order.
    score = rules.confidence[None, :] + 2.0 * applies
    top_score, top_row = _top_k_padded(score, k)
    hit = top_score >= 2.0
    return (
        jnp.where(hit, top_row, NOT_FOUND),
        jnp.where(hit, top_score - 2.0, jnp.float32(jnp.nan)),
    )


@functools.partial(jax.jit, static_argnames=("k", "proper", "force"))
def top_supersets(
    index: FIIndex,
    query_masks: jnp.ndarray,      # uint32[Q, IW]
    *,
    k: int = 5,
    proper: bool = False,
    force: Optional[str] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(fi_rows int32[Q, k], supports int32[Q, k]); row -1 ⇔ no hit.

    Frequent supersets of each query, by support descending; among equal
    supports, fewer missing items (|f ∖ q|) first — the closest completion
    wins.  ``proper`` excludes the queried itemset itself.
    """
    miss, extra = ops.subset_superset_counts(query_masks, index.masks,
                                             force=force)
    is_sup = (extra == 0) & index.valid()[None, :]
    if proper:
        is_sup &= miss > 0
    # lexicographic (support ↓, |f∖q| ↑): a stable two-key sort, exact for
    # any n_tx (folding both keys into one integer would overflow int32
    # once n_tx·(n_items+1) ≥ 2³¹).
    sentinel = jnp.iinfo(jnp.int32).max
    key_supp = jnp.where(is_sup, -index.supports[None, :], sentinel)
    key_miss = jnp.where(is_sup, miss, sentinel)
    top_key, top_row = _lex_smallest_k(key_supp, key_miss, k)
    hit = top_key != sentinel
    return (
        jnp.where(hit, top_row, NOT_FOUND),
        jnp.where(hit, -top_key, NOT_FOUND),
    )


def _top_k_padded(score: jnp.ndarray, k: int):
    """lax.top_k that tolerates k > score columns (pad with -inf rows)."""
    cols = score.shape[-1]
    if k <= cols:
        return jax.lax.top_k(score, k)
    lowest = (
        -jnp.inf if jnp.issubdtype(score.dtype, jnp.floating)
        else jnp.iinfo(score.dtype).min
    )
    pad = jnp.full(score.shape[:-1] + (k - cols,), lowest, score.dtype)
    return jax.lax.top_k(jnp.concatenate([score, pad], axis=-1), k)


def _lex_smallest_k(key1: jnp.ndarray, key2: jnp.ndarray, k: int):
    """Per row, the k columns with lexicographically smallest (key1, key2).

    Returns ``(key1 values, column indices)``, both ``[Q, k]``; the stable
    sort makes equal keys resolve by column index, so results are
    deterministic.  ``k`` beyond the column count pads with int32 max / -1.
    """
    Q, F = key1.shape
    idx = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32), (Q, F))
    s1, _, rows = jax.lax.sort((key1, key2, idx), num_keys=2, is_stable=True)
    if k > F:
        s1 = jnp.pad(s1, ((0, 0), (0, k - F)),
                     constant_values=jnp.iinfo(jnp.int32).max)
        rows = jnp.pad(rows, ((0, 0), (0, k - F)), constant_values=NOT_FOUND)
    return s1[:, :k], rows[:, :k]


# ---------------------------------------------------------------------------
# Fixed-batch engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class QueryEngine:
    """Serving facade with a fixed dispatch width.

    Every call pads its query rows to ``batch`` (shape-stable jit, one
    compiled program per query type for the whole serving session) and
    slices real rows back out.  ``force`` pins the kernel backend the same
    way ``kernels.ops`` does (None = auto: Pallas on TPU, jnp ref on CPU).
    """

    index: FIIndex
    rules: Optional[RuleIndex] = None
    batch: int = 256
    top_k: int = 5
    force: Optional[str] = None

    def _pad(self, masks: np.ndarray) -> Tuple[jnp.ndarray, int]:
        q = np.asarray(masks, np.uint32)
        assert q.ndim == 2 and q.shape[1] == self.index.n_words, q.shape
        n = q.shape[0]
        assert n <= self.batch, f"query batch {n} exceeds width {self.batch}"
        return jnp.asarray(_pad_to(q, self.batch)), n

    # -- typed entry points (packed masks in, numpy out) ---------------------
    def support(self, masks: np.ndarray) -> np.ndarray:
        """int32[n] supports (NOT_FOUND = not frequent / not indexed)."""
        qp, n = self._pad(masks)
        sizes = _popcount_rows(qp)
        out = support_lookup(self.index, qp, sizes, force=self.force)
        return np.asarray(out)[:n]

    def rules_for(
        self, masks: np.ndarray, *, novel_only: bool = True
    ) -> Tuple[np.ndarray, np.ndarray]:
        """(rule rows [n, k], confidences [n, k]) for basket masks."""
        assert self.rules is not None, "engine built without a RuleIndex"
        qp, n = self._pad(masks)
        rows, conf = top_rules_for_baskets(
            self.rules, qp, k=self.top_k, novel_only=novel_only,
            force=self.force,
        )
        return np.asarray(rows)[:n], np.asarray(conf)[:n]

    def supersets(
        self, masks: np.ndarray, *, proper: bool = False
    ) -> Tuple[np.ndarray, np.ndarray]:
        """(FI rows [n, k], supports [n, k]) for itemset masks."""
        qp, n = self._pad(masks)
        rows, supp = top_supersets(
            self.index, qp, k=self.top_k, proper=proper, force=self.force,
        )
        return np.asarray(rows)[:n], np.asarray(supp)[:n]

    # -- convenience: python itemsets in --------------------------------------
    def pack(self, itemsets) -> np.ndarray:
        return rules_mod.pack_itemsets(list(itemsets), self.index.n_items)


def _pad_to(a: np.ndarray, n: int) -> np.ndarray:
    if a.shape[0] == n:
        return a
    return np.pad(a, [(0, n - a.shape[0])] + [(0, 0)] * (a.ndim - 1))


def _popcount_rows(packed: jnp.ndarray) -> jnp.ndarray:
    from repro.core import bitmap as bm

    return bm.popcount_u32(packed).sum(axis=-1)
