"""Batched query engine over the FI/rule indexes.

Answers Q queries per dispatch — the serving analogue of frontier batching
(DESIGN.md): one fused ``[Q, F]`` subset/superset sweep
(``kernels.subset_query``) instead of Q per-query launches, so the index
slab streams from HBM once per batch and every lane stays busy.

Three query types, all over packed uint32 query masks ``[Q, IW]``:

  * :func:`support_lookup` — exact support of each queried itemset
    (-1 if not frequent): equality is ``miss == 0 & extra == 0`` on the
    set-difference counts, plus the size-band trick — only rows whose
    cardinality equals the query's can match, so candidate scoring masks by
    the index ``sizes`` vector (no host branching).
  * :func:`top_rules_for_baskets` — the store-owner query: top-K rules by
    confidence whose antecedent ⊆ basket; ``novel_only`` drops rules whose
    consequent is already fully in the basket (a recommendation, not a
    restatement).  One sweep over the stacked ``[2R, IW]`` antecedent ∥
    consequent slab answers both tests.
  * :func:`top_supersets` — completion query: top-K frequent supersets of a
    (partial) itemset, by support; ties prefer fewer extra items.

All three are jit-compiled with static K and static index row counts;
results are (indices, score) pairs with index -1 ⇔ "no more hits", so a
short result list never fabricates entries.

:class:`QueryEngine` wraps the functions with a fixed batch width Q: every
dispatch is padded to Q rows (one compiled program per query type, no
recompiles mid-serve) — exactly how a production server amortizes traffic.

The engine is also the streaming subsystem's swap point: the index pair
lives in ONE internal reference (``_state``) that :meth:`~QueryEngine.
swap_indexes` replaces atomically with a fully built standby pair, bumping a
``generation`` counter and invalidating the attached query cache — in-flight
queries read a single snapshot of the state, so they never see a torn
FI/rule index (DESIGN.md, "Streaming subsystem": hot-swap protocol).
"""
from __future__ import annotations

import functools
import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rules as rules_mod
from repro.kernels import ops
from repro.obs import metrics as obs_metrics
from repro.serve.index import FIIndex, RuleIndex

NOT_FOUND = -1


# ---------------------------------------------------------------------------
# Batched query primitives (jit; index pytrees as traced args)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("force",))
def support_lookup(
    index: FIIndex,
    query_masks: jnp.ndarray,      # uint32[Q, IW]
    query_sizes: jnp.ndarray,      # int32[Q] — |q| (popcount of the mask)
    *,
    force: Optional[str] = None,
) -> jnp.ndarray:
    """int32[Q] absolute supports; ``NOT_FOUND`` for non-frequent queries."""
    miss, extra = ops.subset_superset_counts(query_masks, index.masks,
                                            force=force)
    # equality needs both difference counts zero; the size check is redundant
    # given both counts but keeps the match honest on the all-zero pad row.
    eq = (
        (miss == 0)
        & (extra == 0)
        & (index.sizes[None, :] == query_sizes[:, None])
        & index.valid()[None, :]
    )
    row = jnp.argmax(eq, axis=1)
    found = eq.any(axis=1)
    return jnp.where(found, index.supports[row], NOT_FOUND)


@functools.partial(jax.jit, static_argnames=("k", "novel_only", "force"))
def top_rules_for_baskets(
    rules: RuleIndex,
    basket_masks: jnp.ndarray,     # uint32[Q, IW]
    *,
    k: int = 5,
    novel_only: bool = True,
    force: Optional[str] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(rule_rows int32[Q, k], confidence f32[Q, k]); row -1 ⇔ no hit.

    A rule applies to basket q iff antecedent ⊆ q.  Ranking is by
    confidence with support as tie-break (the RuleIndex row order).
    """
    R = rules.r_pad
    # one sweep over the stacked antecedent ∥ consequent slab: [Q, 2R]
    miss, _ = ops.subset_superset_counts(basket_masks, rules.ant_con,
                                         force=force)
    applies = (miss[:, :R] == 0) & rules.valid()[None, :]
    if novel_only:
        applies &= miss[:, R:] > 0
    # rows are confidence-sorted, so rank by (applies, confidence): boosting
    # applicable rows by 2 (> max confidence 1) keeps relative order.
    score = rules.confidence[None, :] + 2.0 * applies
    top_score, top_row = _top_k_padded(score, k)
    hit = top_score >= 2.0
    return (
        jnp.where(hit, top_row, NOT_FOUND),
        jnp.where(hit, top_score - 2.0, jnp.float32(jnp.nan)),
    )


@functools.partial(jax.jit, static_argnames=("k", "proper", "force"))
def top_supersets(
    index: FIIndex,
    query_masks: jnp.ndarray,      # uint32[Q, IW]
    *,
    k: int = 5,
    proper: bool = False,
    force: Optional[str] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(fi_rows int32[Q, k], supports int32[Q, k]); row -1 ⇔ no hit.

    Frequent supersets of each query, by support descending; among equal
    supports, fewer missing items (|f ∖ q|) first — the closest completion
    wins.  ``proper`` excludes the queried itemset itself.
    """
    miss, extra = ops.subset_superset_counts(query_masks, index.masks,
                                             force=force)
    is_sup = (extra == 0) & index.valid()[None, :]
    if proper:
        is_sup &= miss > 0
    # lexicographic (support ↓, |f∖q| ↑): a stable two-key sort, exact for
    # any n_tx (folding both keys into one integer would overflow int32
    # once n_tx·(n_items+1) ≥ 2³¹).
    sentinel = jnp.iinfo(jnp.int32).max
    key_supp = jnp.where(is_sup, -index.supports[None, :], sentinel)
    key_miss = jnp.where(is_sup, miss, sentinel)
    top_key, top_row = _lex_smallest_k(key_supp, key_miss, k)
    hit = top_key != sentinel
    return (
        jnp.where(hit, top_row, NOT_FOUND),
        jnp.where(hit, -top_key, NOT_FOUND),
    )


def _top_k_padded(score: jnp.ndarray, k: int):
    """lax.top_k that tolerates k > score columns (pad with -inf rows)."""
    cols = score.shape[-1]
    if k <= cols:
        return jax.lax.top_k(score, k)
    lowest = (
        -jnp.inf if jnp.issubdtype(score.dtype, jnp.floating)
        else jnp.iinfo(score.dtype).min
    )
    pad = jnp.full(score.shape[:-1] + (k - cols,), lowest, score.dtype)
    return jax.lax.top_k(jnp.concatenate([score, pad], axis=-1), k)


def _lex_smallest_k(key1: jnp.ndarray, key2: jnp.ndarray, k: int):
    """Per row, the k columns with lexicographically smallest (key1, key2).

    Returns ``(key1 values, column indices)``, both ``[Q, k]``; the stable
    sort makes equal keys resolve by column index, so results are
    deterministic.  ``k`` beyond the column count pads with int32 max / -1.
    """
    Q, F = key1.shape
    idx = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32), (Q, F))
    s1, _, rows = jax.lax.sort((key1, key2, idx), num_keys=2, is_stable=True)
    if k > F:
        s1 = jnp.pad(s1, ((0, 0), (0, k - F)),
                     constant_values=jnp.iinfo(jnp.int32).max)
        rows = jnp.pad(rows, ((0, 0), (0, k - F)), constant_values=NOT_FOUND)
    return s1[:, :k], rows[:, :k]


# ---------------------------------------------------------------------------
# Fixed-batch engine
# ---------------------------------------------------------------------------


class QueryEngine:
    """Serving facade with a fixed dispatch width and hot-swappable indexes.

    Every call pads its query rows to ``batch`` (shape-stable jit, one
    compiled program per query type for the whole serving session) and
    slices real rows back out.  ``force`` pins the kernel backend the same
    way ``kernels.ops`` does (None = auto: Pallas on TPU, jnp ref on CPU).

    The FI/rule index pair and the swap ``generation`` live in a single
    tuple reference; each query method snapshots it ONCE, so a concurrent
    :meth:`swap_indexes` can never pair an old FIIndex with a new RuleIndex
    mid-query (the torn-index hazard of a naive two-field update).  An
    optional ``cache`` (:class:`repro.serve.cache.QueryCache`) attached here
    is invalidated on every swap; cache keys should additionally include
    :attr:`generation` (see ``query_key``) so a stale hit is structurally
    impossible even for entries raced in around the swap.
    """

    def __init__(
        self,
        index: FIIndex,
        rules: Optional[RuleIndex] = None,
        batch: int = 256,
        top_k: int = 5,
        force: Optional[str] = None,
        cache=None,
    ):
        self._state: Tuple[FIIndex, Optional[RuleIndex], int] = (
            index, rules, 0,
        )
        self.batch = batch
        self.top_k = top_k
        self.force = force
        self.cache = cache

    # -- swappable state -------------------------------------------------------
    @property
    def index(self) -> FIIndex:
        return self._state[0]

    @property
    def rules(self) -> Optional[RuleIndex]:
        return self._state[1]

    @property
    def generation(self) -> int:
        """Number of completed index hot-swaps (0 = the launch indexes)."""
        return self._state[2]

    def swap_indexes(
        self, index: FIIndex, rules: Optional[RuleIndex] = None
    ) -> int:
        """Atomically publish a fully built standby index pair.

        Double-buffered hot-swap: the caller builds the new ``FIIndex`` /
        ``RuleIndex`` completely off to the side, then this single reference
        assignment makes them live together; queries already holding the old
        snapshot finish against consistent old state.  Bumps and returns the
        generation counter and invalidates the attached cache.
        """
        assert index.n_items == self.index.n_items, "item universe changed"
        t0 = time.perf_counter()
        self._state = (index, rules, self._state[2] + 1)
        if self.cache is not None:
            self.cache.clear()
        reg = obs_metrics.registry()
        reg.counter("serve/swaps").inc()
        reg.histogram("serve/swap_ms").record((time.perf_counter() - t0) * 1e3)
        return self._state[2]

    def snapshot(self) -> "EngineSnapshot":
        """One coherent (index, rules, generation) view, frozen at call time.

        The single-reference read extended to a *multi-call* consumer: the
        micro-batching service (:mod:`repro.serve.service`) dispatches one
        flush as several per-kind engine calls — each call alone is
        torn-free, but a hot-swap landing between them would mix
        generations inside one flush.  A snapshot pins every call of the
        flush to the same state (and names the generation for cache keys
        and trace args).
        """
        return EngineSnapshot(self, self._state)

    def stats(self) -> dict:
        index, rules, gen = self._state
        out = {
            "generation": gen,
            "n_fis": index.n_fis,
            "n_rules": rules.n_rules if rules is not None else 0,
        }
        if self.cache is not None:
            # hit_rate gauge is maintained on the access path (CacheStats);
            # this merely reports the same numbers
            out.update(self.cache.stats.as_dict())
        return out

    def _observe(self, kind: str, n: int, t0: float) -> None:
        """One dispatched query batch → latency histogram + query counter."""
        reg = obs_metrics.registry()
        reg.counter("serve/queries").inc(n)
        reg.histogram(f"serve/{kind}_ms").record(
            (time.perf_counter() - t0) * 1e3
        )

    def _pad(self, masks: np.ndarray, index: FIIndex) -> Tuple[jnp.ndarray, int]:
        q = np.asarray(masks, np.uint32)
        assert q.ndim == 2 and q.shape[1] == index.n_words, q.shape
        n = q.shape[0]
        assert n <= self.batch, f"query batch {n} exceeds width {self.batch}"
        return jnp.asarray(_pad_to(q, self.batch)), n

    # -- typed entry points (packed masks in, numpy out) ---------------------
    def support(self, masks: np.ndarray, *, _state=None) -> np.ndarray:
        """int32[n] supports (NOT_FOUND = not frequent / not indexed)."""
        index, _, _ = _state if _state is not None else self._state
        t0 = time.perf_counter()
        qp, n = self._pad(masks, index)
        sizes = _popcount_rows(qp)
        out = support_lookup(index, qp, sizes, force=self.force)
        res = np.asarray(out)[:n]     # np.asarray is the device sync
        self._observe("support", n, t0)
        return res

    def rules_for(
        self, masks: np.ndarray, *, novel_only: bool = True, _state=None
    ) -> Tuple[np.ndarray, np.ndarray]:
        """(rule rows [n, k], confidences [n, k]) for basket masks."""
        index, rules, _ = _state if _state is not None else self._state
        assert rules is not None, "engine built without a RuleIndex"
        t0 = time.perf_counter()
        qp, n = self._pad(masks, index)
        rows, conf = top_rules_for_baskets(
            rules, qp, k=self.top_k, novel_only=novel_only,
            force=self.force,
        )
        out = np.asarray(rows)[:n], np.asarray(conf)[:n]
        self._observe("rules", n, t0)
        return out

    def supersets(
        self, masks: np.ndarray, *, proper: bool = False, _state=None
    ) -> Tuple[np.ndarray, np.ndarray]:
        """(FI rows [n, k], supports [n, k]) for itemset masks."""
        index, _, _ = _state if _state is not None else self._state
        t0 = time.perf_counter()
        qp, n = self._pad(masks, index)
        rows, supp = top_supersets(
            index, qp, k=self.top_k, proper=proper, force=self.force,
        )
        out = np.asarray(rows)[:n], np.asarray(supp)[:n]
        self._observe("supersets", n, t0)
        return out

    # -- convenience: python itemsets in --------------------------------------
    def pack(self, itemsets) -> np.ndarray:
        return rules_mod.pack_itemsets(list(itemsets), self.index.n_items)


class EngineSnapshot:
    """A :class:`QueryEngine` view pinned to one (index, rules, generation).

    Same typed entry points as the engine; every call resolves against the
    state captured by :meth:`QueryEngine.snapshot`, no matter how many
    hot-swaps land meanwhile.  Cheap (one tuple reference) — take one per
    service flush.
    """

    __slots__ = ("_engine", "_st")

    def __init__(self, engine: QueryEngine, state):
        self._engine = engine
        self._st = state

    @property
    def index(self) -> FIIndex:
        return self._st[0]

    @property
    def rules(self) -> Optional[RuleIndex]:
        return self._st[1]

    @property
    def generation(self) -> int:
        return self._st[2]

    @property
    def top_k(self) -> int:
        return self._engine.top_k

    def support(self, masks: np.ndarray) -> np.ndarray:
        return self._engine.support(masks, _state=self._st)

    def rules_for(self, masks: np.ndarray, *, novel_only: bool = True):
        return self._engine.rules_for(
            masks, novel_only=novel_only, _state=self._st)

    def supersets(self, masks: np.ndarray, *, proper: bool = False):
        return self._engine.supersets(masks, proper=proper, _state=self._st)


def _pad_to(a: np.ndarray, n: int) -> np.ndarray:
    if a.shape[0] == n:
        return a
    return np.pad(a, [(0, n - a.shape[0])] + [(0, 0)] * (a.ndim - 1))


def _popcount_rows(packed: jnp.ndarray) -> jnp.ndarray:
    from repro.core import bitmap as bm

    return bm.popcount_u32(packed).sum(axis=-1)
