"""Production serving front end: micro-batching, admission control, replicas.

The :class:`QueryEngine` (PR 2) answers a *caller-assembled* batch; a real
service faces an **arrival process** — requests trickle in one at a time
and someone must decide when to cut a batch.  :class:`MiningService` is
that front end (ROADMAP item 1, the genre-recommendation scenario):

  * **dynamic micro-batching** — submissions enqueue; a dispatcher thread
    flushes when the batch is full (``max_batch``) or the oldest request
    has waited ``deadline_ms``, whichever first.  One fused
    ``subset_query`` sweep per kind per flush, so the deadline bounds the
    added latency at D while Poisson arrivals at rate λ fill batches to
    ≈ min(λ·D, K) rows (DESIGN.md, "Serving service & SLOs").
  * **admission control** — a bounded queue: at ``max_queue`` depth a
    submission is *shed*, returning a typed :class:`Shed` result
    immediately (never a silent drop, never an unbounded queue).  Sheds
    feed the SLO tracker's availability budget.
  * **replicas** — N :class:`QueryEngine`\\ s behind a round-robin router
    (one flush per replica turn).  Hot-swap is **generation-consistent**
    across all of them: :meth:`swap_indexes` swaps every replica's
    single-reference state under one lock and asserts they converge to
    the same generation; each flush pins itself to one replica
    :class:`~repro.serve.engine.EngineSnapshot`, so no flush ever mixes
    generations even mid-swap.
  * **per-request tracing** — every request id flows through the span
    chain ``service/enqueue`` (queue wait, a span per request) →
    ``service/assemble`` → ``service/sweep`` (device) →
    ``service/respond``, each batch span carrying the member ids in its
    args; a Perfetto timeline separates queueing from compute per flush.

Outcome types a ticket can resolve to: the query's value, :class:`Shed`
(admission control), or :class:`Failed` (a dispatch raised — the error is
named, counted, and never lost on the dispatcher thread).
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve.cache import QueryCache, query_key
from repro.serve.engine import QueryEngine

#: query kinds the service routes (mirrors the engine's entry points)
KINDS = ("support", "rules", "superset")


@dataclass(frozen=True)
class Shed:
    """Typed admission-control rejection (the request was NOT served)."""

    reason: str
    queue_depth: int


@dataclass(frozen=True)
class Failed:
    """A dispatch error, surfaced to the submitter instead of swallowed."""

    error: str


class Ticket:
    """The submitter's handle: blocks on :meth:`result` until resolved."""

    __slots__ = ("id", "_ev", "_val")

    def __init__(self, req_id: int):
        self.id = req_id
        self._ev = threading.Event()
        self._val = None

    def _resolve(self, val) -> None:
        self._val = val
        self._ev.set()

    def done(self) -> bool:
        return self._ev.is_set()

    def result(self, timeout: Optional[float] = None):
        """The outcome: a query value, :class:`Shed`, or :class:`Failed`."""
        if not self._ev.wait(timeout):
            raise TimeoutError(f"request {self.id} not resolved "
                               f"within {timeout}s")
        return self._val


class _Request:
    __slots__ = ("id", "kind", "mask", "t_submit", "ticket")

    def __init__(self, req_id: int, kind: str, mask: np.ndarray,
                 t_submit: float):
        self.id = req_id
        self.kind = kind
        self.mask = mask
        self.t_submit = t_submit
        self.ticket = Ticket(req_id)


class MiningService:
    """Arrival-stream front end over N replica engines.

    Args:
      engines: one or more :class:`QueryEngine` replicas (equal batch
        widths and top_k; typically built over the same index pair).
      max_batch: flush width (default: the replicas' batch width).
      deadline_ms: max time the OLDEST queued request waits before its
        batch is cut — the micro-batching latency bound.
      max_queue: admission-control bound; submissions beyond this depth
        shed with a typed :class:`Shed` result.
      slo: optional :class:`repro.obs.slo.SLOTracker` fed every outcome
        (served latency / shed / error) — the live windowed view.
      cache: optional :class:`QueryCache` consulted per flush; keys carry
        the flush snapshot's generation so hot-swaps can never serve
        stale hits.  Duplicate queries inside one flush dispatch once.
      auto_start: start the dispatcher thread immediately (tests pass
        False to stage deterministic queue states).
    """

    def __init__(
        self,
        engines: Sequence[QueryEngine],
        *,
        max_batch: Optional[int] = None,
        deadline_ms: float = 5.0,
        max_queue: int = 1024,
        slo=None,
        cache: Optional[QueryCache] = None,
        auto_start: bool = True,
    ):
        assert engines, "need at least one replica engine"
        self.engines: Tuple[QueryEngine, ...] = tuple(engines)
        widths = {e.batch for e in self.engines}
        assert len(widths) == 1, f"replica batch widths differ: {widths}"
        self.max_batch = max_batch or self.engines[0].batch
        assert self.max_batch <= self.engines[0].batch, (
            f"max_batch {self.max_batch} exceeds engine width "
            f"{self.engines[0].batch}")
        assert deadline_ms > 0 and max_queue > 0
        self.deadline_s = deadline_ms / 1e3
        self.max_queue = max_queue
        self.slo = slo
        self.cache = cache
        gens = {e.generation for e in self.engines}
        assert len(gens) == 1, f"replica generations diverged: {gens}"
        self._generation = gens.pop()
        self._q: "deque[_Request]" = deque()
        self._cond = threading.Condition()
        self._ids = itertools.count()
        self._rr = 0
        self._swap_lock = threading.Lock()
        self._stop = False
        self._thread: Optional[threading.Thread] = None
        self._reg = obs_metrics.registry()
        self._tracer = obs_trace.tracer()
        if auto_start:
            self.start()

    # -- lifecycle ------------------------------------------------------------
    def start(self) -> "MiningService":
        if self._thread is None or not self._thread.is_alive():
            self._stop = False
            self._thread = threading.Thread(
                target=self._run, name="service-dispatch", daemon=True)
            self._thread.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Stop the dispatcher; ``drain`` flushes queued requests first
        (False sheds them — still typed, never silent)."""
        with self._cond:
            self._stop = True
            if not drain:
                while self._q:
                    r = self._q.popleft()
                    self._shed_locked(r, "shutdown")
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=30)
            self._thread = None

    def __enter__(self) -> "MiningService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- submission (any thread) ----------------------------------------------
    def submit(self, kind: str, mask: np.ndarray) -> Ticket:
        """Enqueue one query; returns immediately with a :class:`Ticket`.

        A full queue resolves the ticket to :class:`Shed` on the spot —
        admission control pushes back instead of letting latency grow
        without bound.
        """
        assert kind in KINDS, f"unknown query kind {kind!r}"
        now = time.monotonic()
        req = _Request(next(self._ids), kind, np.asarray(mask, np.uint32),
                       now)
        with self._cond:
            if self._stop:
                raise RuntimeError("service is stopped")
            if len(self._q) >= self.max_queue:
                self._shed_locked(req, "queue_full")
                return req.ticket
            self._q.append(req)
            self._reg.gauge("service/queue_depth").update_max(len(self._q))
            self._tracer.counter("queue depth", depth=len(self._q))
            self._cond.notify()
        return req.ticket

    def _shed_locked(self, req: _Request, reason: str) -> None:
        depth = len(self._q)
        req.ticket._resolve(Shed(reason=reason, queue_depth=depth))
        self._reg.counter("service/shed").inc()
        if self.slo is not None:
            self.slo.record_shed()
        self._tracer.instant("service/shed", req=req.id, reason=reason,
                             queue_depth=depth)

    # -- hot swap (any thread) -------------------------------------------------
    @property
    def generation(self) -> int:
        """Service-wide swap generation (all replicas agree by invariant)."""
        return self._generation

    def swap_indexes(self, index, rules=None) -> int:
        """Publish a standby index pair on EVERY replica, consistently.

        Extends PR 3's single-reference swap across the fleet: each
        replica's swap is individually atomic, the service lock serializes
        swaps so replicas step through generations in lockstep, and the
        post-condition asserts one common generation.  Flushes pin a
        snapshot first, so a flush concurrent with the swap serves
        entirely old or entirely new — never a mix.
        """
        with self._swap_lock:
            gens = [e.swap_indexes(index, rules) for e in self.engines]
            assert len(set(gens)) == 1, f"replica swap diverged: {gens}"
            self._generation = gens[0]
            if self.cache is not None:
                self.cache.clear()
            self._reg.counter("service/swaps").inc()
            self._tracer.instant("service/swap", generation=self._generation)
            return self._generation

    # -- dispatcher ------------------------------------------------------------
    def _run(self) -> None:
        while True:
            with self._cond:
                while not self._q and not self._stop:
                    self._cond.wait(0.05)
                if not self._q:
                    if self._stop:
                        return
                    continue
                if not self._stop:
                    # cut the batch at width K or the oldest's deadline
                    deadline = self._q[0].t_submit + self.deadline_s
                    while (len(self._q) < self.max_batch
                           and not self._stop):
                        remain = deadline - time.monotonic()
                        if remain <= 0:
                            break
                        self._cond.wait(remain)
                n = min(len(self._q), self.max_batch)
                batch = [self._q.popleft() for _ in range(n)]
                self._tracer.counter("queue depth", depth=len(self._q))
            self._flush(batch)

    def _flush(self, batch: List[_Request]) -> None:
        replica = self._rr
        self._rr = (self._rr + 1) % len(self.engines)
        snap = self.engines[replica].snapshot()
        t_flush = time.monotonic()
        ids = [r.id for r in batch]
        tracing = self._tracer.enabled
        if tracing:
            # queue-wait span per request: enqueue -> batch cut, id in args
            for r in batch:
                self._tracer.add_span(
                    "service/enqueue", r.t_submit, t_flush - r.t_submit,
                    track=f"service/replica{replica}/queue",
                    cat="service", args={"req": r.id},
                )
        with self._tracer.span("service/flush", replica=replica,
                               generation=snap.generation, n=len(batch),
                               reqs=ids):
            values: Dict[int, object] = {}
            error: Optional[str] = None
            for kind in KINDS:
                rows = [r for r in batch if r.kind == kind]
                if not rows:
                    continue
                kind_ids = [r.id for r in rows]
                try:
                    with self._tracer.span("service/assemble", kind=kind,
                                           reqs=kind_ids):
                        masks = np.stack([r.mask for r in rows])
                        keys = None
                        if self.cache is not None:
                            keys = [query_key(kind, r.mask, snap.top_k,
                                              snap.generation)
                                    for r in rows]
                            results, miss = self.cache.split_batch(keys)
                        else:
                            results = [None] * len(rows)
                            miss = list(range(len(rows)))
                    if miss:
                        with self._tracer.span(
                            "service/sweep", kind=kind, replica=replica,
                            n=len(miss), reqs=[rows[j].id for j in miss],
                        ):
                            vals = self._dispatch(
                                snap, kind, masks[miss]
                                if len(miss) < len(rows) else masks)
                        if self.cache is not None:
                            results = self.cache.fill_batch(
                                keys, results, miss, vals)
                        else:
                            for j, v in zip(miss, vals):
                                results[j] = v
                    for r, v in zip(rows, results):
                        values[r.id] = v
                except Exception as e:   # dispatcher must never die silently
                    error = f"{type(e).__name__}: {e}"
                    self._reg.counter("service/errors").inc(len(rows))
                    for r in rows:
                        values[r.id] = Failed(error=error)
                        if self.slo is not None:
                            self.slo.record_error()
            with self._tracer.span("service/respond", reqs=ids):
                t_done = time.monotonic()
                lat_hist = self._reg.histogram("service/latency_ms")
                for r in batch:
                    v = values.get(r.id)
                    r.ticket._resolve(v)
                    if isinstance(v, Failed):
                        continue
                    ms = (t_done - r.t_submit) * 1e3
                    lat_hist.record(ms)
                    if self.slo is not None:
                        self.slo.record_ok(ms)
        self._reg.counter("service/flushes").inc()
        self._reg.counter(f"service/replica{replica}/flushes").inc()
        self._reg.counter(f"service/replica{replica}/requests").inc(
            len(batch))
        self._reg.histogram("service/batch_fill").record(len(batch))

    def _dispatch(self, snap, kind: str, masks: np.ndarray) -> List[object]:
        """One fused sweep for a per-kind group, rows back out as values."""
        if kind == "support":
            return list(snap.support(masks))
        if kind == "rules":
            rows, conf = snap.rules_for(masks)
            return [(rows[i], conf[i]) for i in range(rows.shape[0])]
        rows, supp = snap.supersets(masks)
        return [(rows[i], supp[i]) for i in range(rows.shape[0])]

    # -- introspection ---------------------------------------------------------
    def stats(self) -> dict:
        with self._cond:
            depth = len(self._q)
        reg = self._reg
        out = {
            "generation": self._generation,
            "replicas": len(self.engines),
            "queue_depth": depth,
            "max_queue": self.max_queue,
            "max_batch": self.max_batch,
            "deadline_ms": self.deadline_s * 1e3,
            "flushes": reg.counter("service/flushes").value,
            "shed": reg.counter("service/shed").value,
            "errors": reg.counter("service/errors").value,
            "per_replica_flushes": [
                reg.counter(f"service/replica{r}/flushes").value
                for r in range(len(self.engines))
            ],
            "per_replica_requests": [
                reg.counter(f"service/replica{r}/requests").value
                for r in range(len(self.engines))
            ],
        }
        if self.cache is not None:
            out["cache"] = self.cache.stats.as_dict()
        return out
