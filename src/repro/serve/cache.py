"""LRU query cache keyed on packed query masks.

Real basket traffic is heavily repetitive (popular carts, hot itemsets), so
the cheapest query is the one never dispatched.  The cache sits in front of
the batched engine on the host: keys are the raw bytes of a packed uint32
query mask plus the query kind and its static knobs — exact, collision-free
and already in wire format (no canonicalization step; two baskets hash
equal iff their bitmaps are equal).

Plain ``OrderedDict`` LRU with hit/miss/eviction counters; the driver
(`launch/serve_mine.py`) reports the hit rate next to QPS and latency.
``split_batch`` is the serving-loop helper: partition a query batch into
cached results and the de-duplicated miss set that still needs a dispatch
(duplicates inside one batch dispatch once).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs import metrics as obs_metrics


def query_key(kind: str, packed_row: np.ndarray, *knobs: Hashable) -> Tuple:
    """Cache key for one query: (kind, knobs..., mask bytes).

    When the engine's indexes can be hot-swapped (``repro.stream``), include
    ``engine.generation`` among the knobs: entries raced in around a swap
    then key to the dead generation and can never serve stale results, even
    before :meth:`QueryCache.clear` lands.
    """
    return (kind, *knobs, np.asarray(packed_row, np.uint32).tobytes())


class CacheStats:
    """Per-cache counters as thin views over a metrics registry.

    The counts live in a per-instance :class:`~repro.obs.metrics.
    MetricsRegistry` — every cache keeps its own numbers, exactly as the old
    plain-int dataclass did — and each event is mirrored into the
    process-global registry under the same ``serve/cache/...`` names, so run
    records and ``obs_report`` see cache behavior without any extra plumbing.
    ``hits`` / ``misses`` / ``evictions`` / ``invalidations`` read exactly as
    before; mutation goes through the ``hit()`` / ``miss()`` / … recorders.
    """

    def __init__(self, registry: Optional[obs_metrics.MetricsRegistry] = None):
        self._reg = (
            registry if registry is not None else obs_metrics.MetricsRegistry()
        )

    def _inc(self, field: str) -> None:
        self._reg.counter(f"serve/cache/{field}").inc()
        g = obs_metrics.registry()
        if g is not self._reg:   # mirror unless we ARE the global registry
            g.counter(f"serve/cache/{field}").inc()
        if field in ("hits", "misses"):
            # keep the hit-rate gauge current on the ACCESS path — a
            # windowed SLO snapshot taken between stats() calls must never
            # read a stale value
            hr = self.hit_rate
            self._reg.gauge("serve/cache/hit_rate").set(hr)
            if g is not self._reg:
                g.gauge("serve/cache/hit_rate").set(hr)

    def hit(self) -> None:
        self._inc("hits")

    def miss(self) -> None:
        self._inc("misses")

    def eviction(self) -> None:
        self._inc("evictions")

    def invalidation(self) -> None:   # whole-cache clears (index hot-swaps)
        self._inc("invalidations")

    @property
    def hits(self) -> int:
        return self._reg.counter("serve/cache/hits").value

    @property
    def misses(self) -> int:
        return self._reg.counter("serve/cache/misses").value

    @property
    def evictions(self) -> int:
        return self._reg.counter("serve/cache/evictions").value

    @property
    def invalidations(self) -> int:
        return self._reg.counter("serve/cache/invalidations").value

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def as_dict(self) -> Dict[str, float]:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "invalidations": self.invalidations,
            "hit_rate": self.hit_rate,
        }

    def snapshot(self) -> Dict[str, dict]:
        """This cache's counts in the canonical metrics-snapshot shape."""
        return self._reg.snapshot()


class QueryCache:
    """Bounded LRU over query results.

    ``capacity <= 0`` disables caching (every lookup is a miss, nothing is
    stored) so the serving loop needs no branches.
    """

    def __init__(self, capacity: int = 4096):
        self.capacity = capacity
        self.stats = CacheStats()
        self._data: "OrderedDict[Tuple, Any]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._data)

    def get(self, key: Tuple) -> Optional[Any]:
        if self.capacity <= 0 or key not in self._data:
            self.stats.miss()
            return None
        self.stats.hit()
        self._data.move_to_end(key)
        return self._data[key]

    def clear(self) -> int:
        """Drop every entry (index hot-swap invalidation); returns the count
        dropped.  Hit/miss/eviction counters survive — only the data goes."""
        n = len(self._data)
        self._data.clear()
        self.stats.invalidation()
        return n

    def put(self, key: Tuple, value: Any) -> None:
        if self.capacity <= 0:
            return
        if key in self._data:
            self._data.move_to_end(key)
        self._data[key] = value
        if len(self._data) > self.capacity:
            self._data.popitem(last=False)
            self.stats.eviction()

    # -- batch helper ---------------------------------------------------------
    def split_batch(
        self, keys: Sequence[Tuple]
    ) -> Tuple[List[Optional[Any]], List[int]]:
        """Partition a batch into cached results and the miss set.

        Returns ``(results, miss_positions)``: ``results[i]`` is the cached
        value or None; ``miss_positions`` lists the indices still needing a
        dispatch, **first occurrence only** (duplicate keys inside the batch
        resolve from the first's result via :meth:`fill_batch`).
        """
        results: List[Optional[Any]] = []
        miss: List[int] = []
        seen: Dict[Tuple, int] = {}
        for i, key in enumerate(keys):
            hit = self.get(key)
            if hit is not None:
                results.append(hit)
            else:
                results.append(None)
                if key not in seen:
                    seen[key] = i
                    miss.append(i)
        return results, miss

    def fill_batch(
        self,
        keys: Sequence[Tuple],
        results: List[Optional[Any]],
        miss_positions: Sequence[int],
        miss_values: Sequence[Any],
    ) -> List[Any]:
        """Insert dispatched values, then resolve every remaining None.

        Duplicates resolve from a per-batch map of the dispatched values, so
        the result is complete even with caching disabled or under eviction
        pressure.
        """
        batch_map: Dict[Tuple, Any] = {}
        for pos, val in zip(miss_positions, miss_values):
            self.put(keys[pos], val)
            batch_map[keys[pos]] = val
        for i, r in enumerate(results):
            if r is None:
                results[i] = batch_map[keys[i]]
        return results
