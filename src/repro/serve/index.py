"""Immutable, device-resident indexes over a mined FI table.

The distributed-mining literature treats the mined set as an *index to be
queried at scale* (arXiv:1903.03008); this module is that index in TPU
shape.  Both structures are frozen pytrees of dense device arrays — no
pointers, no hashing — so a query batch is pure vector work against them:

  * :class:`FIIndex` — the F frequent itemsets as packed uint32 masks
    ``[F, IW]`` (layout of ``core.bitmap.pack_bool``) plus a support vector,
    a per-itemset size vector, and **per-size offsets**: rows are sorted by
    (|itemset|, lexicographic), so all size-s itemsets form the contiguous
    band ``[size_offsets[s], size_offsets[s+1])`` — the engine uses the
    size band to skip impossible exact-match candidates and callers can
    slice a band for level-wise scans.
  * :class:`RuleIndex` — a :class:`repro.core.rules.RuleTable` on device,
    antecedent and consequent masks stacked into ONE ``[2R, IW]`` slab so a
    basket query answers "which antecedents apply" and "which consequents
    are already owned" from a single fused sweep.

Row counts F and R are static python ints; arrays are padded to at least
one row so zero-FI / zero-rule corner cases keep static shapes (padded rows
are excluded via the static count, never by a device-side sentinel scan).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmap as bm
from repro.core import rules as rules_mod

_U32 = jnp.uint32


def _pad_rows(a: np.ndarray, n: int) -> np.ndarray:
    """Pad axis 0 up to ``n`` rows with zeros (no-op if already there)."""
    if a.shape[0] >= n:
        return a
    pad = [(0, n - a.shape[0])] + [(0, 0)] * (a.ndim - 1)
    return np.pad(a, pad)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class FIIndex:
    """The mined FI table as a queryable device structure.

    Attributes:
      masks:    ``uint32[Fp, IW]`` packed itemset masks, sorted by
                (size, lexicographic); ``Fp = max(F, 1)``.
      supports: ``int32[Fp]`` absolute supports.
      sizes:    ``int32[Fp]`` itemset cardinalities (|f|).
      n_fis:    F — number of valid rows (static).
      n_items:  |B| (static).
      n_tx:     |D| (static) — denominator for relative support.
      size_offsets: static tuple; size-s rows live at
                ``[size_offsets[s], size_offsets[s+1])``, s ∈ [0, max_size].
    """

    masks: jnp.ndarray
    supports: jnp.ndarray
    sizes: jnp.ndarray
    n_fis: int
    n_items: int
    n_tx: int
    size_offsets: Tuple[int, ...]

    def tree_flatten(self):
        return (
            (self.masks, self.supports, self.sizes),
            (self.n_fis, self.n_items, self.n_tx, self.size_offsets),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    # -- constructors ---------------------------------------------------------
    @classmethod
    def from_fi_dict(
        cls, fis: Dict[frozenset, int], n_items: int, n_tx: int
    ) -> "FIIndex":
        """Build from a materialized ``{frozenset: support}`` table."""
        order = sorted(fis, key=lambda s: (len(s), tuple(sorted(s))))
        F = len(order)
        masks = rules_mod.pack_itemsets(order, n_items)
        supports = np.asarray([fis[s] for s in order], np.int32)
        sizes = np.asarray([len(s) for s in order], np.int32)
        max_size = int(sizes.max()) if F else 0
        offsets = tuple(
            int(np.searchsorted(sizes, s)) for s in range(max_size + 1)
        ) + (F,)
        return cls(
            masks=jnp.asarray(_pad_rows(masks, 1)),
            supports=jnp.asarray(_pad_rows(supports, 1)),
            sizes=jnp.asarray(_pad_rows(sizes, 1)),
            n_fis=F,
            n_items=n_items,
            n_tx=n_tx,
            size_offsets=offsets,
        )

    @classmethod
    def from_result(
        cls, result, n_items: int, n_tx: int, abs_minsup: int
    ) -> "FIIndex":
        """Build from a ``fimi.FimiResult`` (materializes if needed)."""
        from repro.core import fimi

        fis = result.fi_dict
        if fis is None:
            fis = fimi.materialize_fis(result, n_items, abs_minsup)
        return cls.from_fi_dict(fis, n_items, n_tx)

    # -- views ----------------------------------------------------------------
    @property
    def n_words(self) -> int:
        return int(self.masks.shape[-1])

    @property
    def max_size(self) -> int:
        return len(self.size_offsets) - 2

    def valid(self) -> jnp.ndarray:
        """bool[Fp] — True for real rows, False for shape padding."""
        return jnp.arange(self.masks.shape[0]) < self.n_fis

    def size_band(self, s: int) -> Tuple[int, int]:
        """Row range [lo, hi) of size-s itemsets (empty if s out of range)."""
        if s < 0 or s > self.max_size:
            return (0, 0)
        return (self.size_offsets[s], self.size_offsets[s + 1])

    def itemset(self, row: int) -> frozenset:
        """Unpack row back to a python itemset (debug/printing)."""
        mask = np.asarray(
            bm.unpack_bool(self.masks[row], self.n_items)
        )
        return frozenset(np.nonzero(mask)[0].tolist())


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class RuleIndex:
    """A :class:`~repro.core.rules.RuleTable` as device arrays.

    ``ant_con`` stacks antecedent masks (rows ``[0, R)``) and consequent
    masks (rows ``[R, 2R)``) so the engine's basket query computes
    applicability and novelty with one fused ``[Q, 2R]`` sweep.  Rows are
    sorted by (confidence, support) descending — ties aside, row order IS
    rule rank, which the top-K kernel exploits.
    """

    ant_con: jnp.ndarray      # uint32[2·Rp, IW]
    supports: jnp.ndarray     # int32[Rp]
    confidence: jnp.ndarray   # float32[Rp]
    lift: jnp.ndarray         # float32[Rp]
    leverage: jnp.ndarray     # float32[Rp]
    n_rules: int
    n_items: int
    n_tx: int

    def tree_flatten(self):
        return (
            (self.ant_con, self.supports, self.confidence, self.lift,
             self.leverage),
            (self.n_rules, self.n_items, self.n_tx),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @classmethod
    def from_table(cls, table: rules_mod.RuleTable) -> "RuleIndex":
        Rp = max(table.n_rules, 1)
        ant = _pad_rows(table.antecedents, Rp)
        con = _pad_rows(table.consequents, Rp)
        return cls(
            ant_con=jnp.asarray(np.concatenate([ant, con], axis=0)),
            supports=jnp.asarray(_pad_rows(table.supports, Rp)),
            confidence=jnp.asarray(_pad_rows(table.confidence, Rp)),
            lift=jnp.asarray(_pad_rows(table.lift, Rp)),
            leverage=jnp.asarray(_pad_rows(table.leverage, Rp)),
            n_rules=table.n_rules,
            n_items=table.n_items,
            n_tx=table.n_tx,
        )

    # -- views ----------------------------------------------------------------
    @property
    def r_pad(self) -> int:
        """Padded row count Rp (``ant_con`` holds 2·Rp rows)."""
        return int(self.ant_con.shape[0]) // 2

    def valid(self) -> jnp.ndarray:
        return jnp.arange(self.r_pad) < self.n_rules

    def antecedents(self) -> jnp.ndarray:
        return self.ant_con[: self.r_pad]

    def consequents(self) -> jnp.ndarray:
        return self.ant_con[self.r_pad:]

    def rule(self, row: int) -> rules_mod.Rule:
        """Unpack rule ``row`` for printing (host round-trip)."""
        ant = np.asarray(bm.unpack_bool(self.antecedents()[row], self.n_items))
        con = np.asarray(bm.unpack_bool(self.consequents()[row], self.n_items))
        return rules_mod.Rule(
            frozenset(np.nonzero(ant)[0].tolist()),
            frozenset(np.nonzero(con)[0].tolist()),
            int(self.supports[row]),
            float(self.confidence[row]),
            float(self.lift[row]),
            float(self.leverage[row]),
        )


def build_indexes(
    fis: Dict[frozenset, int],
    n_items: int,
    n_tx: int,
    min_confidence: float = 0.5,
) -> Tuple[FIIndex, RuleIndex]:
    """One-call build: FI index + rules (ap-genrules) + rule index."""
    fi_index = FIIndex.from_fi_dict(fis, n_items, n_tx)
    rl = rules_mod.generate_rules(fis, n_tx, min_confidence)
    table = rules_mod.RuleTable.from_rules(rl, n_items, n_tx)
    return fi_index, RuleIndex.from_table(table)
