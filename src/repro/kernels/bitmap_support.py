"""Pallas TPU kernel: batched extension-support counting.

The Eclat inner loop (thesis §B.3.1 "support counting") — for a node with
prefix tidlist t and candidate extensions, compute ``popcount(bits_i & t)``
for every item i.  On the original CPU implementation this is |Σ| independent
sorted-list merges; here it is one dense 2-D sweep over the packed bitmap
slab, tiled through VMEM:

  grid = (I/BI, W/BW);  per step AND a ``[BI, BW]`` uint32 tile of item
  bitmaps with a ``[1, BW]`` tile of the prefix tidlist, SWAR-popcount on the
  VPU, and accumulate a ``[BI, 1]`` partial into the output block.  The W grid
  axis is the minormost (sequential on TPU), so the f32/int32 accumulator
  lives in the output block across W steps.

Tile defaults (BI=256, BW=512 words = 16 Ki transactions) keep the working
set at 256·512·4 B = 512 KiB ≪ VMEM while giving 8·128-aligned lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_U32 = jnp.uint32


def _popcount_swar(x: jnp.ndarray) -> jnp.ndarray:
    x = x - ((x >> 1) & _U32(0x55555555))
    x = (x & _U32(0x33333333)) + ((x >> 2) & _U32(0x33333333))
    x = (x + (x >> 4)) & _U32(0x0F0F0F0F)
    return ((x * _U32(0x01010101)) >> 24).astype(jnp.int32)


def _kernel(items_ref, tid_ref, out_ref):
    w = pl.program_id(1)

    @pl.when(w == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    tile = items_ref[...] & tid_ref[...]            # [BI, BW] & [1, BW]
    partial = _popcount_swar(tile).sum(axis=1, keepdims=True)  # [BI, 1]
    out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("block_i", "block_w", "interpret"))
def extension_supports_pallas(
    item_bits: jnp.ndarray,   # uint32[I, W]
    prefix_tid: jnp.ndarray,  # uint32[W]
    *,
    block_i: int = 256,
    block_w: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """int32[I] supports of prefix ∪ {i}; pads I and W to tile multiples."""
    I, W = item_bits.shape
    bi = min(block_i, max(8, I))
    bw = min(block_w, max(128, W))
    pi = (-I) % bi
    pw = (-W) % bw
    items = jnp.pad(item_bits, ((0, pi), (0, pw)))
    tid = jnp.pad(prefix_tid, (0, pw))[None, :]      # [1, Wp]
    Ip, Wp = items.shape

    out = pl.pallas_call(
        _kernel,
        grid=(Ip // bi, Wp // bw),
        in_specs=[
            pl.BlockSpec((bi, bw), lambda i, w: (i, w)),
            pl.BlockSpec((1, bw), lambda i, w: (0, w)),
        ],
        out_specs=pl.BlockSpec((bi, 1), lambda i, w: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Ip, 1), jnp.int32),
        interpret=interpret,
    )(items, tid)
    return out[:I, 0]
