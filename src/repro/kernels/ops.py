"""Public jit'd entry points for the mining kernels with backend dispatch.

On TPU the Pallas kernels run compiled; on CPU (this container) the pure-jnp
reference path is used for speed, with ``interpret=True`` Pallas execution
available everywhere for validation (exercised by the kernel tests).

``extension_supports`` is the function the Eclat/MFI miners take as their
``support_fn`` plug-in.

Every dispatch is wrapped by the kernel profiler
(:mod:`repro.obs.profile`): when enabled, eager calls get device-synced
per-call timing bucketed by shape, and trace-time dispatches (kernels
compiled into ``while_loop`` bodies) are tallied for later loop
attribution.  When disabled — the default — the wrapper is one attribute
check and a plain tail call (gated <2 % overhead in
``tests/test_profile.py``).
"""
from __future__ import annotations

import functools
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import bitmap_support as _bs
from repro.kernels import delta_support as _ds
from repro.kernels import multi_support as _ms
from repro.kernels import pair_support as _ps
from repro.kernels import ref as _ref
from repro.kernels import subset_query as _sq
from repro.obs import profile as _prof


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _profiled(family, dims_fn):
    """Route a dispatch through the kernel profiler when it is enabled."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if not _prof.PROFILER.enabled:
                return fn(*args, **kwargs)
            return _prof.PROFILER.call(
                family, dims_fn(*args), lambda: fn(*args, **kwargs)
            )

        return wrapper

    return deco


@_profiled(
    "bitmap",
    lambda item_bits, prefix_tid: {
        "I": int(item_bits.shape[0]), "W": int(item_bits.shape[1]),
    },
)
def extension_supports(
    item_bits: jnp.ndarray,
    prefix_tid: jnp.ndarray,
    *,
    force: str | None = None,
) -> jnp.ndarray:
    """Supports of prefix ∪ {i} for all items.  force ∈ {None,'pallas','ref',
    'interpret'} selects the implementation."""
    mode = force or ("pallas" if _on_tpu() else "ref")
    if mode == "pallas":
        return _bs.extension_supports_pallas(item_bits, prefix_tid)
    if mode == "interpret":
        return _bs.extension_supports_pallas(item_bits, prefix_tid, interpret=True)
    return _ref.extension_supports_ref(item_bits, prefix_tid)


@_profiled(
    "multi",
    lambda item_bits, prefix_tids: {
        "K": int(prefix_tids.shape[0]),
        "I": int(item_bits.shape[0]), "W": int(item_bits.shape[1]),
    },
)
def multi_extension_supports(
    item_bits: jnp.ndarray,
    prefix_tids: jnp.ndarray,
    *,
    use_mxu: bool = False,
    force: str | None = None,
) -> jnp.ndarray:
    """Supports of prefix_k ∪ {i} for K prefixes: int32[K, I].

    The frontier-batched Eclat plug-in (``multi_support_fn``).  ``use_mxu``
    picks the unpack+dot kernel (wins once K fills MXU rows); force ∈
    {None, 'pallas', 'ref', 'interpret'} selects the implementation.
    """
    mode = force or ("pallas" if _on_tpu() else "ref")
    if mode in ("pallas", "interpret"):
        f = (
            _ms.multi_extension_supports_mxu_pallas
            if use_mxu
            else _ms.multi_extension_supports_pallas
        )
        return f(item_bits, prefix_tids, interpret=(mode == "interpret"))
    if use_mxu:
        return _ref.multi_extension_supports_mxu_ref(item_bits, prefix_tids)
    return _ref.multi_extension_supports_ref(item_bits, prefix_tids)


@_profiled(
    "subset",
    lambda query_masks, fi_masks: {
        "Q": int(query_masks.shape[0]),
        "F": int(fi_masks.shape[0]), "IW": int(fi_masks.shape[1]),
    },
)
def subset_superset_counts(
    query_masks: jnp.ndarray,
    fi_masks: jnp.ndarray,
    *,
    force: str | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """``(miss, extra)`` int32[Q, F] set-difference popcounts (|f∖q|, |q∖f|).

    The batched serving sweep (``repro.serve.engine``); force ∈ {None,
    'pallas', 'ref', 'interpret'} selects the implementation.
    """
    mode = force or ("pallas" if _on_tpu() else "ref")
    if mode in ("pallas", "interpret"):
        return _sq.subset_superset_counts_pallas(
            query_masks, fi_masks, interpret=(mode == "interpret")
        )
    return _ref.subset_superset_counts_ref(query_masks, fi_masks)


@_profiled(
    "delta",
    lambda tx_blocks, fi_masks: {
        "S": int(tx_blocks.shape[0]), "T": int(tx_blocks.shape[1]),
        "F": int(fi_masks.shape[0]), "IW": int(fi_masks.shape[1]),
    },
)
def block_itemset_supports(
    tx_blocks: jnp.ndarray,
    fi_masks: jnp.ndarray,
    *,
    force: str | None = None,
) -> jnp.ndarray:
    """int32[S, F] per-block containment counts of every itemset.

    The streaming update sweep (``repro.stream``): S stacked transaction
    blocks ``uint32[S, T, IW]`` against F packed itemset masks in one fused
    launch; force ∈ {None, 'pallas', 'ref', 'interpret'} selects the
    implementation.
    """
    mode = force or ("pallas" if _on_tpu() else "ref")
    if mode in ("pallas", "interpret"):
        return _ds.block_itemset_supports_pallas(
            tx_blocks, fi_masks, interpret=(mode == "interpret")
        )
    return _ref.block_itemset_supports_ref(tx_blocks, fi_masks)


def delta_supports(
    arrive: jnp.ndarray,   # uint32[T, IW] — admitted transaction block
    expire: jnp.ndarray,   # uint32[T, IW] — evicted transaction block
    fi_masks: jnp.ndarray,  # uint32[F, IW]
    *,
    force: str | None = None,
) -> jnp.ndarray:
    """int32[2, F] — (arrive counts, expire counts) from ONE fused sweep.

    The window support update is ``supports += counts[0] - counts[1]``;
    keeping the two contributions separate lets callers also track ingress
    rates.  Both blocks ride the S axis of :func:`block_itemset_supports`,
    so the itemset slab streams from HBM once for the pair.
    """
    return block_itemset_supports(
        jnp.stack([arrive, expire]), fi_masks, force=force
    )


@_profiled(
    "pair",
    lambda item_bits, valid_tid: {
        "I": int(item_bits.shape[0]), "W": int(item_bits.shape[1]),
    },
)
def pair_supports(
    item_bits: jnp.ndarray,
    valid_tid: jnp.ndarray,
    *,
    use_mxu: bool = True,
    force: str | None = None,
) -> jnp.ndarray:
    """All-pairs supports S[i,j].  ``use_mxu`` picks the unpack+dot kernel."""
    mode = force or ("pallas" if _on_tpu() else "ref")
    if mode == "pallas":
        f = _ps.pair_supports_mxu_pallas if use_mxu else _ps.pair_supports_pallas
        return f(item_bits, valid_tid)
    if mode == "interpret":
        f = _ps.pair_supports_mxu_pallas if use_mxu else _ps.pair_supports_pallas
        return f(item_bits, valid_tid, interpret=True)
    if use_mxu:
        return _ref.pair_supports_mxu_ref(item_bits, valid_tid)
    return _ref.pair_supports_ref(item_bits, valid_tid)
