"""Pure-jnp oracles for the Pallas kernels (the contract every kernel meets).

These are thin named wrappers over ``repro.core.bitmap`` reference forms so the
kernel tests have a single import point, plus the unpacked-MXU reference.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import bitmap as bm


def extension_supports_ref(item_bits: jnp.ndarray, prefix_tid: jnp.ndarray) -> jnp.ndarray:
    """int32[I] = popcount(item_bits[i] & prefix_tid) summed over words."""
    return bm.extension_supports(item_bits, prefix_tid)


def multi_extension_supports_ref(
    item_bits: jnp.ndarray, prefix_tids: jnp.ndarray
) -> jnp.ndarray:
    """int32[K, I] = popcount(item_bits[i] & prefix_tids[k]) summed over words."""
    return bm.multi_extension_supports(item_bits, prefix_tids)


def pair_supports_ref(item_bits: jnp.ndarray, valid_tid: jnp.ndarray) -> jnp.ndarray:
    """int32[I, I] all-pairs supports via VPU-style popcount(AND)."""
    return bm.pair_supports(item_bits, valid_tid)


def unpack_bits_f32(words: jnp.ndarray) -> jnp.ndarray:
    """uint32[..., W] -> float32[..., W*32] of 0/1 — the MXU-form operand."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(words.shape[:-1] + (words.shape[-1] * 32,)).astype(jnp.float32)


def pair_supports_mxu_ref(item_bits: jnp.ndarray, valid_tid: jnp.ndarray) -> jnp.ndarray:
    """All-pairs supports as a matmul over unpacked bits (exact in f32 for
    supports < 2^24).  Oracle of the fused unpack+dot Pallas kernel."""
    masked = unpack_bits_f32(item_bits & valid_tid[None, :])
    return jnp.dot(masked, masked.T).astype(jnp.int32)


def subset_superset_counts_ref(
    query_masks: jnp.ndarray, fi_masks: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """``(miss, extra)`` int32[Q, F]: |f ∖ q| and |q ∖ f| per (query, FI) pair.

    ``miss == 0`` ⇔ f ⊆ q;  ``extra == 0`` ⇔ q ⊆ f;  both ⇔ f = q.
    Oracle of the fused serving kernel ``kernels.subset_query``.
    """
    only_f = fi_masks[None, :, :] & ~query_masks[:, None, :]   # [Q, F, IW]
    only_q = query_masks[:, None, :] & ~fi_masks[None, :, :]
    return (
        bm.popcount_u32(only_f).sum(axis=-1),
        bm.popcount_u32(only_q).sum(axis=-1),
    )


def block_itemset_supports_ref(
    tx_blocks: jnp.ndarray, fi_masks: jnp.ndarray
) -> jnp.ndarray:
    """int32[S, F]: per transaction block, how many rows contain each itemset.

    ``counts[s, f] = Σ_t [fi_masks[f] ⊆ tx_blocks[s, t]]`` — containment is a
    zero test on the set-difference popcount (``subset_query`` semantics).
    Oracle of the fused streaming delta kernel ``kernels.delta_support``.
    """
    missing = fi_masks[None, None, :, :] & ~tx_blocks[:, :, None, :]
    contained = bm.popcount_u32(missing).sum(axis=-1) == 0      # [S, T, F]
    return contained.sum(axis=1).astype(jnp.int32)


def multi_extension_supports_mxu_ref(
    item_bits: jnp.ndarray, prefix_tids: jnp.ndarray
) -> jnp.ndarray:
    """Multi-prefix supports as a matmul over unpacked bits — oracle of the
    fused unpack+dot multi-prefix Pallas kernel."""
    t = unpack_bits_f32(prefix_tids)
    a = unpack_bits_f32(item_bits)
    return jnp.dot(t, a.T).astype(jnp.int32)
