"""Pallas TPU kernel: fused per-block itemset-containment supports.

The streaming hot spot (`repro.stream`): when a transaction block enters the
sliding window and another expires, every mined itemset's window support
changes by

  ``Δ[f] = |{t ∈ arrive : f ⊆ t}| − |{t ∈ expire : f ⊆ t}|``

so the serving table is *updated in place* instead of recomputed over the
whole window.  The kernel computes the general form — S stacked transaction
blocks against all F itemset masks in ONE launch,

  ``counts[s, f] = Σ_t [ fi[f] ⊆ tx[s, t] ]``

(S = 2 for the arrive/expire pair).  Containment over packed little-endian
uint32 masks (layout of ``core.bitmap.pack_bool``) is a zero test on the
set-difference popcount, the same SWAR sweep as ``multi_support.py`` /
``subset_query.py``:

  ``f ⊆ t  ⇔  Σ_w popcount(fi[f, w] & ~tx[t, w]) == 0``

Unlike those kernels the reduced word axis must be *fully resident* per grid
step (the zero test needs the complete count before thresholding), which is
free here: the item-word axis IW = n_words(n_items) is a few words.  The
grid is ``(S, F/BF, T/BT)`` with T minormost (sequential on TPU) so the
``[1, BF]`` int32 accumulator lives in its output block across T steps.

Row-padding trick: T and F pad to tile multiples, and a padded all-zero
transaction row would falsely "contain" the empty itemset.  The wrapper
appends one **sentinel word** set to 1 on every itemset row and every *real*
transaction row but left 0 on padding — padded rows therefore miss the
sentinel bit and can never count, making the kernel exact for every mask
(∅ included) without a separate validity operand.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_U32 = jnp.uint32


def _popcount_swar(x):
    x = x - ((x >> 1) & _U32(0x55555555))
    x = (x & _U32(0x33333333)) + ((x >> 2) & _U32(0x33333333))
    x = (x + (x >> 4)) & _U32(0x0F0F0F0F)
    return ((x * _U32(0x01010101)) >> 24).astype(jnp.int32)


def _kernel(tx_ref, fi_ref, out_ref):
    t_step = pl.program_id(2)

    @pl.when(t_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    tx = tx_ref[0]                                  # [BT, W]
    fi = fi_ref[...]                                # [BF, W]
    missing = fi[None, :, :] & ~tx[:, None, :]      # [BT, BF, W]
    miss_ct = _popcount_swar(missing).sum(axis=-1)  # [BT, BF]
    contained = (miss_ct == 0).astype(jnp.int32)
    out_ref[...] += contained.sum(axis=0)[None, :]


@functools.partial(
    jax.jit, static_argnames=("block_f", "block_t", "interpret")
)
def block_itemset_supports_pallas(
    tx_blocks: jnp.ndarray,  # uint32[S, T, IW] — horizontal packed rows
    fi_masks: jnp.ndarray,   # uint32[F, IW]    — packed itemset masks
    *,
    block_f: int = 128,
    block_t: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """int32[S, F] — per-block containment counts of every itemset.

    Pads T and F to tile multiples and the word axis to a multiple of 8
    (one extra sentinel word, see module docstring).  VMEM per step ≈
    BT·BF·Wp·4 B for the widened ANDN (512 KiB at defaults with Wp = 8).
    """
    S, T, IW = tx_blocks.shape
    F = fi_masks.shape[0]
    assert fi_masks.shape[1] == IW, "tx/itemset word width mismatch"
    bt = min(block_t, max(8, T))
    bf = min(block_f, max(8, F))
    Wp = -(-(IW + 1) // 8) * 8           # sentinel word, padded to 8
    pt, pf = (-T) % bt, (-F) % bf

    tx = jnp.zeros((S, T + pt, Wp), _U32)
    tx = tx.at[:, :T, :IW].set(tx_blocks)
    tx = tx.at[:, :T, IW].set(_U32(1))   # sentinel: real transaction rows
    fi = jnp.zeros((F + pf, Wp), _U32)
    fi = fi.at[:F, :IW].set(fi_masks)
    fi = fi.at[:F, IW].set(_U32(1))      # sentinel: every itemset row
    Tp, Fp = T + pt, F + pf

    out = pl.pallas_call(
        _kernel,
        grid=(S, Fp // bf, Tp // bt),
        in_specs=[
            pl.BlockSpec((1, bt, Wp), lambda s, f, t: (s, t, 0)),
            pl.BlockSpec((bf, Wp), lambda s, f, t: (f, 0)),
        ],
        out_specs=pl.BlockSpec((1, bf), lambda s, f, t: (s, f)),
        out_shape=jax.ShapeDtypeStruct((S, Fp), jnp.int32),
        interpret=interpret,
    )(tx, fi)
    return out[:, :F]
