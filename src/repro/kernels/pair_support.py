"""Pallas TPU kernels: all-pairs itemset supports (the C2 counting step).

``S[i, j] = |T({i}) ∩ T({j})| = Σ_w popcount(bits_i[w] & bits_j[w])`` — the
Parallel-Eclat initialization (thesis Alg. 5 line 3) and the profit matrix of
DB-Repl-Min (Alg. 23).

Two TPU formulations, both tiled through VMEM with a shared accumulator
pattern (W is the minormost sequential grid axis):

  * ``pair_supports_pallas``      — VPU SWAR popcount over an AND of tiles.
    Work per output element: W AND+popcount ops on 32-bit lanes.
  * ``pair_supports_mxu_pallas``  — **beyond-paper TPU adaptation**: unpack the
    packed words to 0/1 bf16 inside the kernel and feed the 128×128 MXU with
    ``dot(bits, bitsᵀ)``.  popcount(AND) ≡ dot-product of indicator vectors,
    exact in f32 accumulation for supports < 2²⁴.  This turns a VPU-bound
    bit-twiddle into an MXU matmul at 32 MACs per packed word — the itemset-
    mining analogue of quantized matmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_U32 = jnp.uint32


def _popcount_swar(x):
    x = x - ((x >> 1) & _U32(0x55555555))
    x = (x & _U32(0x33333333)) + ((x >> 2) & _U32(0x33333333))
    x = (x + (x >> 4)) & _U32(0x0F0F0F0F)
    return ((x * _U32(0x01010101)) >> 24).astype(jnp.int32)


def _vpu_kernel(a_ref, b_ref, out_ref):
    w = pl.program_id(2)

    @pl.when(w == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...]                                  # [BI, BW]
    b = b_ref[...]                                  # [BJ, BW]
    inter = a[:, None, :] & b[None, :, :]           # [BI, BJ, BW]
    out_ref[...] += _popcount_swar(inter).sum(axis=-1)


@functools.partial(
    jax.jit, static_argnames=("block_i", "block_j", "block_w", "interpret")
)
def pair_supports_pallas(
    item_bits: jnp.ndarray,  # uint32[I, W]
    valid_tid: jnp.ndarray,  # uint32[W]
    *,
    block_i: int = 64,
    block_j: int = 64,
    block_w: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """int32[I, I] via VPU popcount.  VMEM/step ≈ BI·BJ·BW·4 B (4 MiB def.)."""
    I, W = item_bits.shape
    bi, bj = min(block_i, max(8, I)), min(block_j, max(8, I))
    bw = min(block_w, max(128, W))
    pi, pw = (-I) % bi, (-W) % bw
    pj = (-I) % bj
    masked = item_bits & valid_tid[None, :]
    a = jnp.pad(masked, ((0, pi), (0, pw)))
    b = jnp.pad(masked, ((0, pj), (0, pw)))
    Ip, Wp = a.shape
    Jp = b.shape[0]

    out = pl.pallas_call(
        _vpu_kernel,
        grid=(Ip // bi, Jp // bj, Wp // bw),
        in_specs=[
            pl.BlockSpec((bi, bw), lambda i, j, w: (i, w)),
            pl.BlockSpec((bj, bw), lambda i, j, w: (j, w)),
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j, w: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Ip, Jp), jnp.int32),
        interpret=interpret,
    )(a, b)
    return out[:I, :I]


def _mxu_kernel(a_ref, b_ref, out_ref):
    w = pl.program_id(2)

    @pl.when(w == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    def unpack(words):  # uint32[B, BW] -> bf16[B, BW*32] of 0/1
        shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2)
        bits = (words[:, :, None] >> shifts) & _U32(1)
        return bits.reshape(words.shape[0], -1).astype(jnp.bfloat16)

    a = unpack(a_ref[...])
    b = unpack(b_ref[...])
    out_ref[...] += jax.lax.dot_general(
        a,
        b,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(
    jax.jit, static_argnames=("block_i", "block_j", "block_w", "interpret")
)
def pair_supports_mxu_pallas(
    item_bits: jnp.ndarray,
    valid_tid: jnp.ndarray,
    *,
    block_i: int = 128,
    block_j: int = 128,
    block_w: int = 64,   # 64 words = 2048 unpacked bf16 lanes per step
    interpret: bool = False,
) -> jnp.ndarray:
    """int32[I, I] via fused unpack+MXU-dot.  Exact for supports < 2^24."""
    I, W = item_bits.shape
    bi, bj = min(block_i, max(8, I)), min(block_j, max(8, I))
    bw = min(block_w, max(4, W))
    pi, pj, pw = (-I) % bi, (-I) % bj, (-W) % bw
    masked = item_bits & valid_tid[None, :]
    a = jnp.pad(masked, ((0, pi), (0, pw)))
    b = jnp.pad(masked, ((0, pj), (0, pw)))
    Ip, Wp = a.shape
    Jp = b.shape[0]

    out = pl.pallas_call(
        _mxu_kernel,
        grid=(Ip // bi, Jp // bj, Wp // bw),
        in_specs=[
            pl.BlockSpec((bi, bw), lambda i, j, w: (i, w)),
            pl.BlockSpec((bj, bw), lambda i, j, w: (j, w)),
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j, w: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Ip, Jp), jnp.float32),
        interpret=interpret,
    )(a, b)
    return out[:I, :I].astype(jnp.int32)
