"""Pallas TPU kernel: batched subset/superset queries over packed itemsets.

The serving hot spot (`repro.serve.engine`): Q query masks against the F
itemset masks of the FI/rule index, all pairs, one fused sweep.  For packed
little-endian uint32 masks (layout of ``core.bitmap.pack_bool``) the kernel
computes the two **set-difference popcount** matrices

  ``miss[q, f]  = Σ_w popcount(fi[f, w]    & ~query[q, w])``   (= |f ∖ q|)
  ``extra[q, f] = Σ_w popcount(query[q, w] & ~fi[f, w])``      (= |q ∖ f|)

from one pass over both operands.  Membership is a comparison on top:

  ``miss == 0``   ⇔  f ⊆ q   (rule antecedent applies to basket q)
  ``extra == 0``  ⇔  q ⊆ f   (f is a superset of the queried itemset)
  both zero      ⇔  f = q   (exact support lookup)

Returning counts instead of booleans costs nothing (the AND/ANDN + SWAR
popcount dominates) and buys ranking signals: |f ∖ q| is "items missing from
the basket", |q ∖ f| is "extra items beyond the query" — the tie-breakers
the top-K superset query uses.

Grid ``(Q/BQ, F/BF, W/BW)`` with W minormost (sequential on TPU) so both
int32 accumulators live in their output blocks across W steps — the pattern
of ``multi_support.py``/``pair_support.py``.  Unlike those kernels the
reduced axis here is the *item-word* axis (IW = n_words(n_items), a few
words), not the transaction-word axis, so W is typically a single step and
the default ``block_w`` is small; Q and F carry the parallelism.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_U32 = jnp.uint32


def _popcount_swar(x):
    x = x - ((x >> 1) & _U32(0x55555555))
    x = (x & _U32(0x33333333)) + ((x >> 2) & _U32(0x33333333))
    x = (x + (x >> 4)) & _U32(0x0F0F0F0F)
    return ((x * _U32(0x01010101)) >> 24).astype(jnp.int32)


def _kernel(query_ref, fi_ref, miss_ref, extra_ref):
    w = pl.program_id(2)

    @pl.when(w == 0)
    def _init():
        miss_ref[...] = jnp.zeros_like(miss_ref)
        extra_ref[...] = jnp.zeros_like(extra_ref)

    q = query_ref[...]                              # [BQ, BW]
    f = fi_ref[...]                                 # [BF, BW]
    only_f = f[None, :, :] & ~q[:, None, :]         # [BQ, BF, BW]
    only_q = q[:, None, :] & ~f[None, :, :]
    miss_ref[...] += _popcount_swar(only_f).sum(axis=-1)
    extra_ref[...] += _popcount_swar(only_q).sum(axis=-1)


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_f", "block_w", "interpret")
)
def subset_superset_counts_pallas(
    query_masks: jnp.ndarray,  # uint32[Q, IW]
    fi_masks: jnp.ndarray,     # uint32[F, IW]
    *,
    block_q: int = 128,
    block_f: int = 128,
    block_w: int = 8,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """``(miss, extra)`` int32[Q, F] set-difference popcount matrices.

    Pads Q, F, W to tile multiples (zero words change no counts; padded
    rows are sliced off).  VMEM per step ≈ 2·BQ·BF·BW·4 B for the widened
    ANDNs (1 MiB at defaults).
    """
    Q, W = query_masks.shape
    F = fi_masks.shape[0]
    assert fi_masks.shape[1] == W, "query/index word width mismatch"
    bq = min(block_q, max(8, Q))
    bf = min(block_f, max(8, F))
    bw = min(block_w, W)
    pq, pf, pw = (-Q) % bq, (-F) % bf, (-W) % bw
    q = jnp.pad(query_masks, ((0, pq), (0, pw)))
    f = jnp.pad(fi_masks, ((0, pf), (0, pw)))
    Qp, Wp = q.shape
    Fp = f.shape[0]

    miss, extra = pl.pallas_call(
        _kernel,
        grid=(Qp // bq, Fp // bf, Wp // bw),
        in_specs=[
            pl.BlockSpec((bq, bw), lambda i, j, w: (i, w)),
            pl.BlockSpec((bf, bw), lambda i, j, w: (j, w)),
        ],
        out_specs=[
            pl.BlockSpec((bq, bf), lambda i, j, w: (i, j)),
            pl.BlockSpec((bq, bf), lambda i, j, w: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Qp, Fp), jnp.int32),
            jax.ShapeDtypeStruct((Qp, Fp), jnp.int32),
        ],
        interpret=interpret,
    )(q, f)
    return miss[:Q, :F], extra[:Q, :F]
