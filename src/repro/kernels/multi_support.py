"""Pallas TPU kernels: fused multi-prefix extension-support counting.

The frontier-batched Eclat (DESIGN.md, "Frontier-batched DFS") pops K PBEC
nodes per ``while_loop`` trip and needs, in **one** launch,

  ``S[k, i] = Σ_w popcount(item_bits[i, w] & prefix_tids[k, w])``

— the supports of every extension of every frontier node.  Launching the
single-prefix kernel K times wastes the grid: each launch re-streams the whole
``[I, W]`` bitmap slab from HBM and computes a skinny ``[I, 1]`` output.  Here
the K prefixes ride along as a second output axis, so each ``[BI, BW]`` item
tile fetched into VMEM is reused against all BK prefix rows of the step.

Two formulations, same grid ``(K/BK, I/BI, W/BW)`` with W minormost
(sequential on TPU) so the accumulator lives in the output block across W
steps — the pattern of ``pair_support.py``:

  * ``multi_extension_supports_pallas``      — VPU SWAR popcount of the
    3-D AND ``[BK, BI, BW]``; work per output element is W AND+popcount ops
    on 32-bit lanes.
  * ``multi_extension_supports_mxu_pallas``  — unpack both operands to 0/1
    bf16 inside the kernel and feed the 128×128 MXU with
    ``dot(prefixes, itemsᵀ)``: popcount(AND) ≡ dot of indicator vectors,
    exact in f32 accumulation for supports < 2²⁴.  Preferable once K is large
    enough to fill MXU rows (K ≳ 64); for small frontiers the VPU form wins.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_U32 = jnp.uint32


def _popcount_swar(x):
    x = x - ((x >> 1) & _U32(0x55555555))
    x = (x & _U32(0x33333333)) + ((x >> 2) & _U32(0x33333333))
    x = (x + (x >> 4)) & _U32(0x0F0F0F0F)
    return ((x * _U32(0x01010101)) >> 24).astype(jnp.int32)


def _vpu_kernel(tids_ref, items_ref, out_ref):
    w = pl.program_id(2)

    @pl.when(w == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    t = tids_ref[...]                               # [BK, BW]
    a = items_ref[...]                              # [BI, BW]
    inter = t[:, None, :] & a[None, :, :]           # [BK, BI, BW]
    out_ref[...] += _popcount_swar(inter).sum(axis=-1)


@functools.partial(
    jax.jit, static_argnames=("block_k", "block_i", "block_w", "interpret")
)
def multi_extension_supports_pallas(
    item_bits: jnp.ndarray,    # uint32[I, W]
    prefix_tids: jnp.ndarray,  # uint32[K, W]
    *,
    block_k: int = 8,
    block_i: int = 128,
    block_w: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """int32[K, I] multi-prefix supports via VPU SWAR popcount.

    Pads K, I and W to tile multiples; VMEM per step ≈ BK·BI·BW·4 B for the
    widened AND (1 MiB at defaults).
    """
    I, W = item_bits.shape
    K = prefix_tids.shape[0]
    bk = min(block_k, max(8, K))
    bi = min(block_i, max(8, I))
    bw = min(block_w, max(128, W))
    pk, pi, pw = (-K) % bk, (-I) % bi, (-W) % bw
    tids = jnp.pad(prefix_tids, ((0, pk), (0, pw)))
    items = jnp.pad(item_bits, ((0, pi), (0, pw)))
    Kp, Wp = tids.shape
    Ip = items.shape[0]

    out = pl.pallas_call(
        _vpu_kernel,
        grid=(Kp // bk, Ip // bi, Wp // bw),
        in_specs=[
            pl.BlockSpec((bk, bw), lambda k, i, w: (k, w)),
            pl.BlockSpec((bi, bw), lambda k, i, w: (i, w)),
        ],
        out_specs=pl.BlockSpec((bk, bi), lambda k, i, w: (k, i)),
        out_shape=jax.ShapeDtypeStruct((Kp, Ip), jnp.int32),
        interpret=interpret,
    )(tids, items)
    return out[:K, :I]


def _mxu_kernel(tids_ref, items_ref, out_ref):
    w = pl.program_id(2)

    @pl.when(w == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    def unpack(words):  # uint32[B, BW] -> bf16[B, BW*32] of 0/1
        shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2)
        bits = (words[:, :, None] >> shifts) & _U32(1)
        return bits.reshape(words.shape[0], -1).astype(jnp.bfloat16)

    t = unpack(tids_ref[...])                       # [BK, BW*32]
    a = unpack(items_ref[...])                      # [BI, BW*32]
    out_ref[...] += jax.lax.dot_general(
        t,
        a,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(
    jax.jit, static_argnames=("block_k", "block_i", "block_w", "interpret")
)
def multi_extension_supports_mxu_pallas(
    item_bits: jnp.ndarray,    # uint32[I, W]
    prefix_tids: jnp.ndarray,  # uint32[K, W]
    *,
    block_k: int = 128,
    block_i: int = 128,
    block_w: int = 64,   # 64 words = 2048 unpacked bf16 lanes per step
    interpret: bool = False,
) -> jnp.ndarray:
    """int32[K, I] via fused unpack+MXU-dot.  Exact for supports < 2^24."""
    I, W = item_bits.shape
    K = prefix_tids.shape[0]
    bk = min(block_k, max(8, K))
    bi = min(block_i, max(8, I))
    bw = min(block_w, max(4, W))
    pk, pi, pw = (-K) % bk, (-I) % bi, (-W) % bw
    tids = jnp.pad(prefix_tids, ((0, pk), (0, pw)))
    items = jnp.pad(item_bits, ((0, pi), (0, pw)))
    Kp, Wp = tids.shape
    Ip = items.shape[0]

    out = pl.pallas_call(
        _mxu_kernel,
        grid=(Kp // bk, Ip // bi, Wp // bw),
        in_specs=[
            pl.BlockSpec((bk, bw), lambda k, i, w: (k, w)),
            pl.BlockSpec((bi, bw), lambda k, i, w: (i, w)),
        ],
        out_specs=pl.BlockSpec((bk, bi), lambda k, i, w: (k, i)),
        out_shape=jax.ShapeDtypeStruct((Kp, Ip), jnp.float32),
        interpret=interpret,
    )(tids, items)
    return out[:K, :I].astype(jnp.int32)
