"""AdamW with dtype-configurable state (no external deps).

State dtype is a memory lever recorded per-run in EXPERIMENTS.md: fp32 m/v for
≤30B models; bf16 m/v for the 398B Jamba so params+state fit 16 GB/chip at 256
chips (a distributed-optimization trick in the sense of the task brief —
quantized optimizer state; the update math still runs in fp32).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"      # "float32" | "bfloat16"
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def init(params, cfg: AdamWConfig) -> AdamWState:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def lr_at(step, cfg: AdamWConfig):
    """Linear warmup → cosine decay to min_lr_frac·lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def update(
    grads, state: AdamWState, params, cfg: AdamWConfig
) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = lr_at(step, cfg)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mh = m32 / bc1
        vh = v32 / bc2
        step_ = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        newp = (p.astype(jnp.float32) - lr * step_).astype(p.dtype)
        return newp, m32.astype(sdt), v32.astype(sdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics
