"""Logical-axis → mesh-axis sharding rules (MaxText-style).

Every parameter carries logical axis names from its ParamSpec; rules map them
to mesh axes.  A mapping is *dropped* (replicated) when the dim is not
divisible by the mesh-axis product or the mesh axis was already consumed by an
earlier dim of the same param — so one rule table serves every architecture
(24-head llama can't split 16-way TP on heads: heads drop, ffn still shards).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS


Rules = Dict[str, Optional[Tuple[str, ...]]]


def default_rules(multi_pod: bool, fsdp: bool = True) -> Rules:
    """Baseline rule table.  TP over "model"; FSDP of the d_model ("embed")
    param dim over the data axes (ZeRO-3-style, all-gathered per scan step)."""
    data_axes = ("pod", "data") if multi_pod else ("data",)
    return {
        "vocab": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "ffn": ("model",),
        "experts": ("model",),
        "embed": data_axes if fsdp else None,
        "layers": None,
        "head_dim": None,
        "q_lora": None,
        "kv_lora": None,
    }


def _axis_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes]))


def spec_for(
    shape: Sequence[int],
    logical: Sequence[Optional[str]],
    mesh: Mesh,
    rules: Rules,
) -> PS:
    """PartitionSpec for one param; drops non-divisible / conflicting axes."""
    used: set = set()
    entries = []
    for dim, name in zip(shape, logical):
        target = rules.get(name) if name else None
        if target:
            target = tuple(a for a in target if a not in used)
        if not target or dim % _axis_size(mesh, target) != 0:
            entries.append(None)
            continue
        used.update(target)
        entries.append(target if len(target) > 1 else target[0])
    # trim trailing Nones (canonical form)
    while entries and entries[-1] is None:
        entries.pop()
    return PS(*entries)


def tree_shardings(abstract_tree, axes_tree, mesh: Mesh, rules: Rules):
    """NamedSharding tree matching an abstract param tree."""

    def one(a, ax):
        return NamedSharding(mesh, spec_for(a.shape, ax, mesh, rules))

    return jax.tree.map(one, abstract_tree, axes_tree)


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def data_spec(mesh: Mesh, batch: int, ndim: int) -> PS:
    """Spec for a [B, ...] batch array: B over the data axes when divisible."""
    ax = batch_axes(mesh)
    if batch % _axis_size(mesh, ax) == 0:
        lead = ax if len(ax) > 1 else ax[0]
        return PS(lead, *([None] * (ndim - 1)))
    # fall back: try "data" alone
    if batch % mesh.shape["data"] == 0:
        return PS("data", *([None] * (ndim - 1)))
    return PS(*([None] * ndim))


def _divides(n: int, mesh: Mesh, axis: str) -> bool:
    return n % mesh.shape[axis] == 0


def cache_spec_for_leaf(shape: Tuple[int, ...], mesh: Mesh) -> PS:
    """Heuristic decode-cache sharding.

    Leaves look like [L, B, S, KV, hd] (attention K/V), [L, B, S, r] (MLA
    latent), [L, B, H, P, N] (SSM state), [L, B, w, conv] (conv state), or the
    same with an extra hybrid sub-layer dim.  Policy: shard the batch dim over
    "data" when divisible, else the longest remaining dim; shard a heads-like
    middle dim over "model" when divisible, else the sequence dim.
    """
    entries: list = [None] * len(shape)
    if len(shape) < 2:
        return PS()
    # batch dim = first dim of size != n_layers... by construction dim 1
    b_dim = 1 if len(shape) >= 2 else 0
    used_data = used_model = False
    has_pod = "pod" in mesh.shape
    d_axes = ("pod", "data") if has_pod else ("data",)
    d_size = 1
    for a_ in d_axes:
        d_size *= mesh.shape[a_]
    if shape[b_dim] % d_size == 0 and shape[b_dim] > 1:
        entries[b_dim] = d_axes if has_pod else "data"
        used_data = True
    elif _divides(shape[b_dim], mesh, "data") and shape[b_dim] > 1:
        entries[b_dim] = "data"
        used_data = True
    # model axis: prefer a later dim divisible by model size, largest first
    cand = sorted(
        range(b_dim + 1, len(shape)), key=lambda i: -shape[i]
    )
    for i in cand:
        if entries[i] is None and shape[i] > 1 and _divides(shape[i], mesh, "model"):
            entries[i] = "model"
            used_model = True
            break
    if not used_data:
        for i in cand:
            if entries[i] is None and shape[i] > 1 and _divides(shape[i], mesh, "data"):
                entries[i] = "data"
                break
    while entries and entries[-1] is None:
        entries.pop()
    return PS(*entries)


def cache_shardings(cache_abstract, mesh: Mesh):
    return jax.tree.map(
        lambda a: NamedSharding(mesh, cache_spec_for_leaf(a.shape, mesh)),
        cache_abstract,
    )


def replicated(mesh: Mesh):
    return NamedSharding(mesh, PS())
