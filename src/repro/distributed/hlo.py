"""Post-SPMD HLO inspection: collective inventory + wire-byte accounting.

``compiled.cost_analysis()`` has no collective term, so we parse the scheduled
HLO text.  For each collective we record the *result* shape bytes and the
replica-group size g, then convert to **per-device wire bytes** (ring
algorithms, the v5e ICI model):

  all-gather:          (g-1)/g · result_bytes
  all-reduce:        2·(g-1)/g · result_bytes
  reduce-scatter:      (g-1)/g · operand_bytes  = (g-1) · result_bytes
  all-to-all:          (g-1)/g · result_bytes
  collective-permute:            result_bytes

The roofline collective term is Σ wire_bytes_per_device / link_bw — already a
per-chip time, equivalent to the brief's "collective_bytes / (chips·link_bw)"
with collective_bytes summed over chips.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s+(?:\([^)]*\)|(?P<dtype>\w+)\[(?P<dims>[\d,]*)\][^ ]*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_TUPLE_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def normalize_cost_analysis(ca) -> Dict:
    """``Compiled.cost_analysis()`` across JAX versions.

    Older releases return a one-element list of dicts (one per program),
    newer ones the dict itself; either may be ``None`` for some backends.
    """
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else None
    return dict(ca) if ca else {}


@dataclasses.dataclass
class Collective:
    op: str
    bytes_result: int
    group_size: int
    line: str


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _line_result_bytes(line: str) -> Tuple[int, Optional[str]]:
    """Total result bytes (handles tuple results) and op name if collective."""
    m = _COLL_RE.search(line)
    if not m:
        return 0, None
    op = m.group("op")
    if m.group("dtype"):
        return _shape_bytes(m.group("dtype"), m.group("dims")), op
    # tuple result: sum the component shapes inside (...) right after '='
    lhs = line.split("=", 1)[1]
    paren = lhs[: lhs.find(op)]
    total = sum(_shape_bytes(t, d) for t, d in _TUPLE_SHAPE_RE.findall(paren))
    return total, op


def _group_size(line: str, world: int) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return world


def parse_collectives(hlo_text: str, world: int) -> List[Collective]:
    out: List[Collective] = []
    seen_done = set()
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # paired with the -start that carries the shape
        b, op = _line_result_bytes(line)
        if op is None or b == 0:
            continue
        out.append(Collective(op, b, _group_size(line, world), line.strip()[:160]))
    return out


def wire_bytes_per_device(c: Collective) -> float:
    g = max(c.group_size, 1)
    frac = (g - 1) / g
    if c.op == "all-reduce":
        return 2.0 * frac * c.bytes_result
    if c.op == "all-gather":
        return frac * c.bytes_result
    if c.op == "reduce-scatter":
        return (g - 1) * c.bytes_result
    if c.op == "all-to-all":
        return frac * c.bytes_result
    if c.op == "collective-permute":
        return float(c.bytes_result)
    return 0.0


def collective_summary(hlo_text: str, world: int) -> Dict[str, float]:
    colls = parse_collectives(hlo_text, world)
    by_op: Dict[str, float] = {}
    total = 0.0
    for c in colls:
        w = wire_bytes_per_device(c)
        by_op[c.op] = by_op.get(c.op, 0.0) + w
        total += w
    return {"total_wire_bytes_per_device": total, "count": len(colls), **by_op}
