"""Round-granular checkpoints of the distributed mining executor.

A multi-round distributed mine is a long-running job; the paper's contract
is an *exact* FITable, so a mid-run death must not force a silent partial
result or a full restart.  The executor's state between rounds is small
and entirely host-side — the FIs merged so far, the per-shard class queues
(post-donation), the load ledger's rates, the overflow counters, and the
round index — so after every ``all_to_all``/Phase-4 round it can be
persisted in one atomic step and a resumed run replays the remaining
rounds **bit-exactly**: round ``r``'s PRNG keys are derived from the round
index, the chunk width is a pure function of the plan, and donations are a
deterministic function of the ledger, all of which the checkpoint carries.

Disk layout (reusing the store's atomic-manifest pattern)::

    ckpt/
      CHECKPOINT.json        # tiny: round, payload name, CRC32C, plan hash
      round_000003.npz       # the arrays (published before the json points
                             # at it; older payloads deleted after publish)

The payload is guarded by the same CRC32C as store blocks, and the
``plan_hash`` — a SHA-256 fingerprint of the :class:`MiningPlan`'s
semantic content — refuses a resume against a different database, support
threshold, shard count, or schedule: a checkpoint is only ever replayed
into the exact run that wrote it.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import List, Optional

import numpy as np

from repro.cluster.rebalance import Donation
from repro.store.checksum import crc32c

META_NAME = "CHECKPOINT.json"
FORMAT = "cluster-ckpt-v1"


class CheckpointError(RuntimeError):
    """A checkpoint is unreadable, corrupt, or belongs to a different run."""


def plan_fingerprint(plan) -> str:
    """SHA-256 over the plan's semantic content (not its object identity).

    Two plans with the same fingerprint schedule the same classes of the
    same database at the same support onto the same shards — the
    precondition for a checkpoint to be replayable.
    """
    h = hashlib.sha256()
    h.update(
        f"{FORMAT}|{plan.n_items}|{plan.n_tx}|{plan.P}|{plan.abs_minsup}|"
        f"{plan.scheduler_used}|{len(plan.classes)}".encode()
    )
    for c in plan.classes:
        h.update(np.packbits(np.asarray(c.prefix, bool)).tobytes())
        h.update(np.packbits(np.asarray(c.ext, bool)).tobytes())
    h.update(np.asarray(plan.est_sizes, np.float64).tobytes())
    h.update(np.asarray(plan.assignment, np.int64).tobytes())
    h.update(np.packbits(np.asarray(plan.ancestor_masks, bool)).tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class RoundState:
    """Everything ``execute`` accumulates across rounds (host-side only)."""

    round_index: int                    # rounds completed so far
    queues: List[List[int]]             # per-shard pending class ids
    fi_masks: np.ndarray                # uint32 [F, IW] merged so far
    fi_supports: np.ndarray             # int64 [F]
    anc_supports: Optional[np.ndarray]  # int64 [A] (None before round 0)
    observed: np.ndarray                # ledger: float [P]
    est_mined: np.ndarray               # ledger: float [P]
    exchange_overflow: int
    mine_overflow: int
    rounds: List["object"]              # executor RoundStats telemetry
    donations: List[Donation]


def _rounds_to_json(rounds) -> list:
    return [
        dict(
            round_index=r.round_index,
            classes_mined=[int(x) for x in r.classes_mined],
            work_iters=np.asarray(r.work_iters).astype(np.int64).tolist(),
            est_mined=np.asarray(r.est_mined).astype(float).tolist(),
            replication=float(r.replication),
            donations=[list(d) for d in r.donations],
            mine_ms=float(getattr(r, "mine_ms", 0.0)),
        )
        for r in rounds
    ]


def _rounds_from_json(data: list) -> list:
    from repro.cluster.executor import RoundStats

    return [
        RoundStats(
            round_index=int(d["round_index"]),
            classes_mined=[int(x) for x in d["classes_mined"]],
            work_iters=np.asarray(d["work_iters"], np.int64),
            est_mined=np.asarray(d["est_mined"], np.float64),
            replication=float(d["replication"]),
            donations=[
                Donation(*map(int, t)) for t in d["donations"]
            ],
            mine_ms=float(d.get("mine_ms", 0.0)),
        )
        for d in data
    ]


def save(directory: str, state: RoundState, plan_hash: str) -> str:
    """Persist one round's state atomically; returns the payload path.

    Publish order is payload-then-pointer: the ``.npz`` lands fully (via
    temp + ``os.replace``) before ``CHECKPOINT.json`` names it, so a crash
    at any instant leaves either the previous checkpoint or the new one —
    never a pointer to a torn payload.  Older payloads are deleted after
    the pointer moves.
    """
    os.makedirs(directory, exist_ok=True)
    name = f"round_{state.round_index:06d}.npz"
    path = os.path.join(directory, name)
    tmp = path + ".tmp.npz"
    flat = [cid for q in state.queues for cid in q]
    qlens = [len(q) for q in state.queues]
    with open(tmp, "wb") as f:
        np.savez(
            f,
            fi_masks=np.asarray(state.fi_masks, np.uint32),
            fi_supports=np.asarray(state.fi_supports, np.int64),
            anc_supports=(
                np.zeros(0, np.int64) if state.anc_supports is None
                else np.asarray(state.anc_supports, np.int64)
            ),
            has_anc=np.asarray([state.anc_supports is not None]),
            queue_flat=np.asarray(flat, np.int64),
            queue_lens=np.asarray(qlens, np.int64),
            observed=np.asarray(state.observed, np.float64),
            est_mined=np.asarray(state.est_mined, np.float64),
        )
    os.replace(tmp, path)
    with open(path, "rb") as f:
        payload_crc = crc32c(np.frombuffer(f.read(), np.uint8))
    meta = dict(
        format=FORMAT,
        round=state.round_index,
        payload=name,
        payload_crc32c=payload_crc,
        plan_hash=plan_hash,
        exchange_overflow=int(state.exchange_overflow),
        mine_overflow=int(state.mine_overflow),
        rounds=_rounds_to_json(state.rounds),
        donations=[list(d) for d in state.donations],
    )
    meta_path = os.path.join(directory, META_NAME)
    meta_tmp = meta_path + ".tmp"
    with open(meta_tmp, "w") as f:
        json.dump(meta, f, indent=1)
        f.write("\n")
    os.replace(meta_tmp, meta_path)
    for other in os.listdir(directory):
        if other.startswith("round_") and other.endswith(".npz") \
                and other != name:
            os.remove(os.path.join(directory, other))
    return path


def load(directory: str, plan_hash: Optional[str] = None
         ) -> Optional[RoundState]:
    """Read the latest checkpoint, or None if the directory holds none.

    Verifies the payload CRC32C and (when given) the plan fingerprint;
    raises :class:`CheckpointError` on corruption or a cross-run mismatch
    rather than resuming into a wrong — and therefore inexact — state.
    """
    meta_path = os.path.join(directory, META_NAME)
    if not os.path.exists(meta_path):
        return None
    try:
        with open(meta_path) as f:
            meta = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointError(f"unreadable checkpoint meta {meta_path}: {e}")
    if meta.get("format") != FORMAT:
        raise CheckpointError(
            f"not a {FORMAT} checkpoint: {meta.get('format')!r}"
        )
    if plan_hash is not None and meta["plan_hash"] != plan_hash:
        raise CheckpointError(
            f"checkpoint {directory} belongs to a different run: plan hash "
            f"{meta['plan_hash'][:12]}… != current {plan_hash[:12]}… — "
            f"same DB/support/P/scheduler required for an exact resume"
        )
    path = os.path.join(directory, meta["payload"])
    if not os.path.exists(path):
        raise CheckpointError(f"checkpoint payload missing: {path}")
    with open(path, "rb") as f:
        raw = f.read()
    got = crc32c(np.frombuffer(raw, np.uint8))
    if got != int(meta["payload_crc32c"]):
        raise CheckpointError(
            f"checkpoint payload corrupt: CRC32C {got:#010x} != recorded "
            f"{int(meta['payload_crc32c']):#010x} at {path}"
        )
    with np.load(path) as z:
        flat = z["queue_flat"].tolist()
        qlens = z["queue_lens"].tolist()
        queues, off = [], 0
        for ln in qlens:
            queues.append([int(c) for c in flat[off:off + ln]])
            off += ln
        anc = z["anc_supports"] if bool(z["has_anc"][0]) else None
        state = RoundState(
            round_index=int(meta["round"]),
            queues=queues,
            fi_masks=z["fi_masks"],
            fi_supports=z["fi_supports"],
            anc_supports=anc,
            observed=z["observed"],
            est_mined=z["est_mined"],
            exchange_overflow=int(meta["exchange_overflow"]),
            mine_overflow=int(meta["mine_overflow"]),
            rounds=_rounds_from_json(meta["rounds"]),
            donations=[
                Donation(*map(int, t))
                for t in meta["donations"]
            ],
        )
    return state
