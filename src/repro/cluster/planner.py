"""Phase-1/2 control plane of the distributed mining executor.

The paper's headline mechanism: estimate the size of every candidate
equivalence class from a **database sample** (Thm 6.1 sizes the sample, a
reservoir over the sample's FI stream sizes the itemset sample), then assign
classes to mesh shards *before* any distributed work starts.  The planner is
pure host-side control plane — it runs once per job on replicated inputs and
its output (:class:`MiningPlan`) is broadcast, exactly how a production
launcher treats a scheduler.

Pipeline (reusing ``core.sampling`` / ``core.pbec`` / ``core.schedule``)::

    D ── i.i.d. sample (Thm 6.1) ──► D̃ ── Eclat + in-loop reservoir ──► F̃s
      ── Partition (Alg. 15/17) ──► PBECs ── est. sizes ──► LPT ⊕ DB-Repl-Min
      ── volume comparison ──► assignment + per-shard queues

Scheduler choice is data-driven: ``scheduler="auto"`` computes both the LPT
and the DB-Repl-Min assignment, prices each by its **exact replicated
transaction volume** on the sample (``schedule.replicated_volume`` — the new
DB-Repl-Min report), and keeps the replication-aware one only when it moves
strictly fewer transactions without blowing the makespan up.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmap as bm
from repro.core import eclat, pbec, phases, sampling, schedule


@dataclasses.dataclass(frozen=True)
class PlannerParams:
    """Knobs of the sample-based planning stage (thesis Ch. 6 + Ch. 8)."""

    min_support_rel: float = 0.1
    eps_db: float = 0.05                # ε of the Thm 6.1 database sample
    delta_db: float = 0.1
    eps_fs: float = 0.05                # ε of the Thm 6.3 reservoir sample
    delta_fs: float = 0.1
    rho: float = 0.01
    alpha: float = 0.5                  # Phase-2 split granularity
    n_db_sample: Optional[int] = None   # override |D̃|
    n_fi_sample: Optional[int] = None   # override |F̃s|
    scheduler: str = "auto"             # "lpt" | "repl_min" | "auto"
    makespan_slack: float = 1.5         # auto: repl_min may cost ≤ slack × LPT
    max_classes: int = 512
    sample_eclat: eclat.EclatConfig = eclat.EclatConfig(
        max_out=1, max_stack=4096, frontier_size=16, count_only=True
    )


@dataclasses.dataclass
class MiningPlan:
    """Everything the executor needs, plus what the planner learned.

    The plan is deterministic in (inputs, key): the sample, the reservoir,
    the partition, and both schedules derive from one host RNG seeded off the
    key — every host computes the same plan from the same broadcast sample.
    """

    n_items: int
    n_tx: int
    P: int
    abs_minsup: int
    classes: List[pbec.PBEC]
    est_sizes: np.ndarray           # float [C] — sample counts per class
    assignment: np.ndarray          # int [C] — class → shard
    est_loads: np.ndarray           # float [P]
    scheduler_used: str             # "lpt" | "repl_min"
    lpt_volume: float               # replicated tx volume of the LPT schedule
    repl_volume: float              # … and of the DB-Repl-Min schedule
    sample_masks: np.ndarray        # bool [N, I] — F̃s (|W| ≥ 2)
    ancestor_masks: np.ndarray      # bool [A, I] — prefix side channel
    n_ancestors: int                # valid rows of ancestor_masks
    n_db_sample: int                # |D̃| actually drawn
    n_fi_sample: int                # reservoir capacity
    sample_item_rel: np.ndarray     # float [I] — item supports on D̃ (relative)
    eps_db_effective: float         # Thm 6.1 ε implied by |D̃| at delta_db

    def shard_queues(self) -> List[List[int]]:
        """Per-shard class queues, heaviest estimated class first.

        The executor drains these front-to-front each round; the rebalancer
        moves tail entries between them.
        """
        queues: List[List[int]] = [[] for _ in range(self.P)]
        order = np.argsort(-self.est_sizes, kind="stable")
        for cid in order:
            queues[int(self.assignment[cid])].append(int(cid))
        return queues


def plan(
    tx_shards,                # uint32[P, T, IW] shards — or a store.TxStore
    n_items: int,
    params: PlannerParams,
    key: jax.Array,
    *,
    P: Optional[int] = None,
) -> MiningPlan:
    """Build the mining plan from a database sample (Phases 1–2).

    Accepts either the device shards or an on-disk :class:`repro.store.TxStore`
    (``P`` required then).  The store path draws the Thm 6.1 sample straight
    off disk (``store.reader.sample_rows`` — same PRNG indices, bit-exact
    rows) so planning runs in O(sample + block) host memory without the
    database ever being resident; everything downstream of the sample is
    identical, so the two paths produce the same plan bit for bit.
    """
    store = None
    if not hasattr(tx_shards, "shape"):   # a TxStore: plan off-disk
        store = tx_shards
        if P is None:
            raise ValueError("P (shard count) is required when planning a TxStore")
        if n_items is None:
            n_items = store.n_items
        T, IW = store.n_tx // P, store.n_words
    else:
        P, T, IW = tx_shards.shape
    n_tx = P * T
    abs_minsup = int(np.ceil(params.min_support_rel * n_tx))

    # ---- Phase 1a: database sample (Thm 6.1) -------------------------------
    n_db = params.n_db_sample or sampling.db_sample_size(
        params.eps_db, params.delta_db
    )
    n_db = min(n_db, n_tx)
    k_samp, k_mine = jax.random.split(key)
    if store is not None:
        from repro.store import reader as store_reader

        rows = store_reader.sample_rows(store, k_samp, n_db, n_tx=n_tx)
    else:
        all_tx = tx_shards.reshape(n_tx, IW)
        rows = bm.sample_transactions(all_tx, k_samp, n_db, n_tx)
    sample_bitdb = bm.rebuild_vertical(rows, n_items, n_db)
    sample_minsup = int(np.ceil(params.min_support_rel * n_db))
    eps_eff = math.sqrt(math.log(2.0 / params.delta_db) / (2.0 * n_db))

    # ---- Phase 1b: FI sample — Eclat over D̃ with the in-loop reservoir ----
    n_fs = params.n_fi_sample or sampling.reservoir_sample_size(
        params.eps_fs, params.delta_fs, params.rho
    )
    res = eclat.mine_all(
        sample_bitdb,
        sample_minsup,
        k_mine,
        config=dataclasses.replace(
            params.sample_eclat, reservoir_size=n_fs, count_only=True
        ),
    )
    n_stream = int(res.n_total)
    res_rows = np.asarray(res.reservoir_items)[: min(n_stream, n_fs)]
    sample_masks = np.asarray(
        bm.unpack_bool(jnp.asarray(res_rows), n_items)
    ).reshape(-1, n_items)
    # the partitioner's sample space is F̃_{≥2}: singletons are exactly the
    # 1-prefixes, handled by the prefix side channel (Prop. 2.23's {V} term)
    sample_masks = sample_masks[sample_masks.sum(axis=1) >= 2]

    # ---- Phase 2: Partition + schedule -------------------------------------
    def ext_supports(prefix: np.ndarray) -> np.ndarray:
        tid = bm.tidlist_of_itemset(sample_bitdb, jnp.asarray(prefix))
        return np.asarray(bm.extension_supports(sample_bitdb.item_bits, tid))

    classes = pbec.partition(
        sample_masks,
        P,
        params.alpha,
        ext_supports,
        n_items,
        max_classes=params.max_classes,
    )
    est_sizes = np.array([c.est_count for c in classes], dtype=np.float64)

    tids = np.asarray(
        phases.seed_tidlists(
            sample_bitdb.item_bits,
            jnp.asarray(np.stack([c.prefix for c in classes])),
            sample_bitdb.all_tids(),
        )
    )
    if params.scheduler not in ("lpt", "repl_min", "auto"):
        raise ValueError(f"unknown scheduler {params.scheduler!r}")
    lpt_assign = schedule.lpt_schedule(est_sizes, P)
    lpt_volume = schedule.replicated_volume(tids, lpt_assign, P)
    if params.scheduler == "lpt":
        # skip the O(C²) profit matrix + greedy QKP the choice would discard
        repl_volume = float("nan")
        assignment, used = lpt_assign, "lpt"
    else:
        profit = schedule.pairwise_shared_transactions(tids)
        repl = schedule.db_repl_min(est_sizes, profit, P, tidlists=tids)
        repl_volume = repl.volume
        if params.scheduler == "repl_min":
            assignment, used = repl.assignment, "repl_min"
        else:  # "auto": replication-aware only if it moves strictly less data
            mk_lpt = schedule.makespan_of(est_sizes, lpt_assign, P)
            mk_rep = schedule.makespan_of(est_sizes, repl.assignment, P)
            take_repl = repl.volume < lpt_volume and (
                mk_rep <= params.makespan_slack * max(mk_lpt, 1.0)
            )
            assignment, used = (
                (repl.assignment, "repl_min") if take_repl
                else (lpt_assign, "lpt")
            )
    est_loads = schedule.loads_of(est_sizes, assignment, P)

    ancestor_masks, anc_list = pbec.ancestor_closure(classes, n_items)
    item_rel = (
        np.asarray(
            bm.extension_supports(sample_bitdb.item_bits, sample_bitdb.all_tids())
        ).astype(np.float64)
        / n_db
    )

    return MiningPlan(
        n_items=n_items,
        n_tx=n_tx,
        P=P,
        abs_minsup=abs_minsup,
        classes=classes,
        est_sizes=est_sizes,
        assignment=np.asarray(assignment),
        est_loads=est_loads,
        scheduler_used=used,
        lpt_volume=lpt_volume,
        repl_volume=repl_volume,
        sample_masks=sample_masks,
        ancestor_masks=ancestor_masks,
        n_ancestors=len(anc_list),
        n_db_sample=n_db,
        n_fi_sample=n_fs,
        sample_item_rel=item_rel,
        eps_db_effective=eps_eff,
    )


def pack_seeds(
    classes: List[pbec.PBEC],
    ids_per_shard: List[List[int]],
    n_items: int,
    width: int,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad per-shard class lists into static ``[P, width, I]`` seed arrays.

    Returns ``(seed_prefix, seed_ext, seed_valid)`` — the Phase-4 inputs.
    Width is fixed across rounds so the executor compiles each phase once.
    """
    P = len(ids_per_shard)
    seed_prefix = np.zeros((P, width, n_items), dtype=bool)
    seed_ext = np.zeros((P, width, n_items), dtype=bool)
    seed_valid = np.zeros((P, width), dtype=bool)
    for p, ids in enumerate(ids_per_shard):
        assert len(ids) <= width, "round chunk exceeds seed width"
        for j, cid in enumerate(ids):
            seed_prefix[p, j] = classes[cid].prefix
            seed_ext[p, j] = classes[cid].ext
            seed_valid[p, j] = True
    return seed_prefix, seed_ext, seed_valid
