"""Distributed mining executor — the paper pipeline over a device mesh.

``planner``   Phase-1/2 control plane: Thm 6.1 database sample → reservoir FI
              sample → PBEC partition → LPT / DB-Repl-Min assignment priced
              by replicated-transaction volume.
``executor``  Phase-3/4 data plane: all_to_all transaction exchange +
              frontier-batched Eclat per shard under ``jax.shard_map`` (or
              vmap simulation), merged into one global :class:`FITable`.
``rebalance`` Dynamic correction: per-round load telemetry, bounded donation
              of unexplored PBEC subtrees from overloaded to idle shards.
``checkpoint`` Fault tolerance: atomic round-granular checkpoints (CRC32C-
              guarded payload, plan-hash binding) enabling bit-exact resume
              of an interrupted distributed mine.
"""
from repro.cluster.checkpoint import (  # noqa: F401
    CheckpointError,
    RoundState,
    plan_fingerprint,
)
from repro.cluster.executor import (  # noqa: F401
    ClusterParams,
    ClusterReport,
    ClusterResult,
    FITable,
    RoundStats,
    cluster_mine_fn,
    execute,
)
from repro.cluster.planner import (  # noqa: F401
    MiningPlan,
    PlannerParams,
    pack_seeds,
    plan,
)
from repro.cluster.rebalance import (  # noqa: F401
    Donation,
    LoadLedger,
    rebalance,
    remaining_loads,
)
