"""Distributed mining executor: Phase-3 exchange + Phase-4 shard_map mining.

Takes a packed transaction DB sharded over a 1-D miner mesh and runs the full
paper pipeline end to end::

    plan (host, sample-based)                                  planner.py
      └─► per-shard class queues
    round r = 0, 1, …                                          this module
      ├─ Phase 3: all_to_all exchange of the transactions the
      │           round's classes need (fixed-capacity slabs)  core/phases.py
      ├─ Phase 4: frontier-batched Eclat per shard under
      │           jax.shard_map / vmap, multi_support kernels  core/eclat.py
      └─ rebalance: telemetry-driven donation of queued PBEC
                    subtrees between shard queues              rebalance.py
    merge: all shards' FI buffers + frequent ancestors ──► one FITable

Every device buffer is **static-shape**: the per-round class table is padded
to ``P·chunk`` rows and the seed slabs to ``[P, chunk, I]``, so each phase
compiles exactly once and rounds replay the same executables (DESIGN.md,
"Distributed mining").  Donating a class re-runs the Phase-3 exchange for the
round that mines it, so ownership changes never mine a stale slab — results
stay bit-exact w.r.t. single-device ``fimi.run`` regardless of how many
donations the rebalancer makes.

The SPMD combinator is pluggable exactly as in ``core.fimi``: ``vmap`` for
P virtual miners on one device, ``shard_map`` over a real miner mesh when
enough devices exist (``launch/cluster_mine.py`` forks host devices).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmap as bm
from repro.core import eclat, fimi, phases
from repro.cluster import checkpoint as checkpoint_mod
from repro.cluster import planner as planner_mod
from repro.cluster import rebalance as rebalance_mod
from repro.obs import metrics as obs_metrics
from repro.obs import profile as obs_profile
from repro.obs import progress as obs_progress
from repro.obs import trace as obs_trace

AXIS = fimi.AXIS  # the miner mesh axis name ("miners")


@dataclasses.dataclass(frozen=True)
class ClusterParams:
    """Executor knobs on top of the planner's."""

    planner: planner_mod.PlannerParams = planner_mod.PlannerParams()
    eclat: eclat.EclatConfig = eclat.EclatConfig(
        max_out=1 << 14, max_stack=4096, frontier_size=16
    )
    exchange_capacity: Optional[int] = None  # Phase-3 per-(src,dst) row cap
    chunk: Optional[int] = None     # classes per shard per round (None: auto)
    rebalance: bool = True          # telemetry-driven queue donation
    skew_threshold: float = 1.25    # rebalance when max/mean exceeds this
    max_donations: int = 8          # bounded moves per inter-round pass
    max_rounds: int = 128           # hard bound on mining rounds
    target_rounds: int = 4          # auto-chunk aims for this many rounds
    use_mxu: bool = False           # MXU unpack-dot multi-support kernel
    force: Optional[str] = None     # kernel backend pin (kernels.ops)
    strict: bool = True             # raise on any overflow (exactness guard)


@dataclasses.dataclass(frozen=True)
class FITable:
    """The merged global mining result — one table, every shard's FIs.

    Supports are **bit-exact** full-database counts: Phase 4 mines each class
    on the slab of all transactions containing its prefix, which preserves
    the support of every itemset in the class (thesis Prop. 8.1).
    """

    masks: np.ndarray       # uint32 [F, IW] packed itemset masks
    supports: np.ndarray    # int64 [F]
    n_items: int
    n_tx: int

    @property
    def n_fis(self) -> int:
        return int(self.masks.shape[0])

    def to_dict(self) -> Dict[frozenset, int]:
        """Materialize as {frozenset(items): support} (tests / serving glue)."""
        out: Dict[frozenset, int] = {}
        if self.n_fis == 0:
            return out
        dense = np.asarray(
            bm.unpack_bool(jnp.asarray(self.masks), self.n_items)
        ).reshape(self.n_fis, self.n_items)
        for row, s in zip(dense, self.supports):
            out[frozenset(np.nonzero(row)[0].tolist())] = int(s)
        assert len(out) == self.n_fis, "duplicate itemsets in merged FITable"
        return out


@dataclasses.dataclass
class RoundStats:
    """Telemetry of one mining round (driver- and benchmark-observable)."""

    round_index: int
    classes_mined: List[int]        # per shard
    work_iters: np.ndarray          # int [P] — DFS trips (the load metric)
    est_mined: np.ndarray           # float [P] — planner units mined
    replication: float              # Phase-3 Σ|D'_i| / |D| for this round
    donations: List[rebalance_mod.Donation]
    mine_ms: float = 0.0            # this round's mine-phase wall (host)


@dataclasses.dataclass
class ClusterReport:
    """What the executor observed, for the driver/benchmark to print."""

    P: int
    backend: str                    # "shard_map" | "vmap"
    rounds: List[RoundStats]
    phase_ms: Dict[str, float]      # plan / exchange / mine / merge
    est_loads: np.ndarray           # float [P] — planner prediction
    observed_loads: np.ndarray      # float [P] — cumulative DFS trips
    donations: List[rebalance_mod.Donation]
    exchange_overflow: int
    mine_overflow: int

    @property
    def n_rounds(self) -> int:
        return len(self.rounds)

    @property
    def imbalance(self) -> float:
        """max/mean of observed per-shard load (1.0 = perfect)."""
        mean = float(self.observed_loads.mean())
        return float(self.observed_loads.max()) / mean if mean > 0 else 1.0

    @property
    def makespan_trips(self) -> float:
        """Modeled makespan: Σ_r max_p trips(r, p) — rounds are barriers."""
        return float(
            sum(float(np.max(r.work_iters)) for r in self.rounds)
        )

    def estimation_error(self) -> float:
        """Relative error between predicted and observed load *shares*.

        ``max_p |est_share_p − obs_share_p|`` — the planner is judged on the
        distribution it balanced, not on absolute trip counts (estimates are
        in sample-FI units, observations in DFS trips).
        """
        est, obs = self.est_loads.astype(float), self.observed_loads.astype(float)
        if est.sum() <= 0 or obs.sum() <= 0:
            return 0.0
        return float(np.abs(est / est.sum() - obs / obs.sum()).max())

    def snapshot(self) -> Dict[str, dict]:
        """This report in the canonical metrics-snapshot shape.

        The properties above (``imbalance``, ``makespan_trips``, …) stay the
        ergonomic views; this is the machine-readable form every subsystem
        shares (``repro.obs.metrics.snapshot()``), so run records and
        ``obs_report`` diff cluster telemetry like any other metric.
        """
        counters = {
            "cluster/donations": len(self.donations),
            "cluster/exchange_overflow": int(self.exchange_overflow),
            "cluster/mine_overflow": int(self.mine_overflow),
            "cluster/rounds": self.n_rounds,
        }
        gauges = {
            "cluster/imbalance": self.imbalance,
            "cluster/makespan_trips": self.makespan_trips,
            "cluster/load/estimation_error": self.estimation_error(),
        }
        for phase, ms in self.phase_ms.items():
            gauges[f"cluster/phase_ms/{phase}"] = float(ms)
        for p in range(self.P):
            gauges[f"cluster/shard{p}/est_load"] = float(self.est_loads[p])
            gauges[f"cluster/shard{p}/obs_load"] = float(self.observed_loads[p])
        for r in self.rounds:
            # per-round detail the speedup waterfall's compile term needs
            gauges[f"cluster/round{r.round_index}/mine_ms"] = float(r.mine_ms)
            gauges[f"cluster/round{r.round_index}/max_trips"] = (
                float(np.max(r.work_iters)) if len(r.work_iters) else 0.0
            )
        hist = obs_metrics.Histogram("cluster/round_makespan_trips")
        for r in self.rounds:
            hist.record(float(np.max(r.work_iters)) if len(r.work_iters) else 0.0)
        # the additive speedup-loss decomposition rides along: every run
        # record with cluster gauges also carries its own waterfall
        from repro.obs import speedup as speedup_mod

        wf = speedup_mod.from_snapshot(
            {"counters": counters, "gauges": gauges, "histograms": {}}
        )
        if wf is not None:
            gauges.update(wf.gauges())
        return {
            "counters": counters,
            "gauges": gauges,
            "histograms": {hist.name: hist.summary()},
        }

    def emit(self, reg: Optional[obs_metrics.MetricsRegistry] = None) -> None:
        """Publish this report into the (default: global) metrics registry."""
        reg = reg if reg is not None else obs_metrics.registry()
        snap = self.snapshot()
        for name, v in snap["counters"].items():
            reg.counter(name).inc(int(v))
        for name, v in snap["gauges"].items():
            reg.gauge(name).set(float(v))
        h = reg.histogram("cluster/round_makespan_trips")
        for r in self.rounds:
            h.record(float(np.max(r.work_iters)) if len(r.work_iters) else 0.0)

    def republish_gauges(
        self, reg: Optional[obs_metrics.MetricsRegistry] = None
    ) -> None:
        """Re-set the gauge family (gauges only — counters/histograms would
        double-count).  Drivers call this after back-patching ``phase_ms``
        with work that happened outside :func:`execute` (off-disk planning,
        block-streamed assembly), so the recorded waterfall charges it to
        ``host_tail`` instead of the unexplained driver residual."""
        reg = reg if reg is not None else obs_metrics.registry()
        for name, v in self.snapshot()["gauges"].items():
            reg.gauge(name).set(float(v))


@dataclasses.dataclass
class ClusterResult:
    table: FITable
    plan: planner_mod.MiningPlan
    report: ClusterReport


def _auto_spmd(P: int, spmd, mesh):
    """Resolve the SPMD combinator: real devices when available, else vmap."""
    if spmd is not None:
        return spmd, mesh, ("shard_map" if spmd is fimi.shard_map_spmd else "vmap")
    if len(jax.devices()) >= P:
        from repro.launch.mesh import make_miner_mesh

        return fimi.shard_map_spmd, make_miner_mesh(P), "shard_map"
    return fimi.vmap_spmd, None, "vmap"


def execute(
    tx_shards: jnp.ndarray,   # uint32[P, T, IW] — horizontal packed D_i shards
    n_items: int,
    params: ClusterParams,
    key: jax.Array,
    *,
    spmd=None,
    mesh=None,
    plan: Optional[planner_mod.MiningPlan] = None,
    checkpoint_dir: Optional[str] = None,
    resume: bool = False,
    round_hook: Optional[Callable[[int], None]] = None,
    progress_cb: Optional[
        Callable[[obs_progress.ProgressSnapshot], None]
    ] = None,
) -> ClusterResult:
    """Run the full distributed pipeline; returns table + plan + telemetry.

    Fault tolerance (DESIGN.md, "Failure model"): with ``checkpoint_dir``
    set, the complete inter-round state is persisted atomically after every
    round; ``resume=True`` restores the latest checkpoint (plan-hash
    guarded) and replays only the remaining rounds, **bit-exact** with the
    uninterrupted run — round keys are derived from the round index, the
    chunk width from the plan, and donations from the restored ledger.
    ``round_hook(r)`` is called after round ``r`` is checkpointed; the
    fault harness raises from it to simulate a mid-run death.

    ``progress_cb`` receives a live :class:`ProgressSnapshot` after every
    round — the drivers print its ``line()`` — fed from the planner's
    estimated loads and the observed per-round completions (ETA math in
    :mod:`repro.obs.progress`).
    """
    P, T, IW = tx_shards.shape
    spmd, mesh, backend = _auto_spmd(P, spmd, mesh)
    phase_ms = {"plan": 0.0, "exchange": 0.0, "mine": 0.0, "merge": 0.0}

    tr = obs_trace.TRACER
    t0 = time.perf_counter()
    with tr.span("cluster/plan", P=P, backend=backend):
        if plan is None:
            plan = planner_mod.plan(
                tx_shards,
                n_items,
                dataclasses.replace(params.planner),
                key,
            )
    phase_ms["plan"] = (time.perf_counter() - t0) * 1e3
    classes = plan.classes
    est_sizes = plan.est_sizes
    queues = plan.shard_queues()

    maxlen = max((len(q) for q in queues), default=0)
    if params.chunk is not None:
        chunk = max(1, params.chunk)
    elif params.rebalance and maxlen > 1:
        chunk = max(1, -(-maxlen // max(params.target_rounds, 1)))
    else:
        chunk = max(1, maxlen)
    assert chunk <= params.eclat.max_stack, "chunk exceeds miner stack capacity"

    # one-time device constants / mapped phase programs
    cap = params.exchange_capacity or T
    local_valid = jnp.ones((P, T), jnp.bool_)
    minsup_b = jnp.broadcast_to(jnp.asarray(plan.abs_minsup, jnp.int32), (P,))
    A = plan.ancestor_masks.shape[0]
    anc_b = jnp.broadcast_to(
        jnp.asarray(plan.ancestor_masks), (P, A, n_items)
    )
    # one partial per execute(): it is a static jit arg of mine_seeded, so a
    # stable identity keeps all rounds on the same compiled executable
    from repro.kernels import ops

    multi_support_fn = partial(
        ops.multi_extension_supports,
        use_mxu=params.use_mxu,
        force=params.force,
    )
    p3 = spmd(
        partial(phases.phase3_exchange, axis_name=AXIS, capacity=cap), P, mesh
    )
    p4 = spmd(
        partial(
            phases.phase4_mine,
            axis_name=AXIS,
            n_items=n_items,
            eclat_cfg=params.eclat,
            multi_support_fn=multi_support_fn,
        ),
        P,
        mesh,
    )

    C_round = P * chunk  # padded class-table width, static across rounds
    ledger = rebalance_mod.LoadLedger(P)
    rounds: List[RoundStats] = []
    donations: List[rebalance_mod.Donation] = []
    fi_masks: List[np.ndarray] = []
    fi_supports: List[np.ndarray] = []
    exchange_overflow = 0
    mine_overflow = 0
    anc_supports: Optional[np.ndarray] = None

    plan_hash = (
        checkpoint_mod.plan_fingerprint(plan) if checkpoint_dir else ""
    )
    r = 0
    if resume and checkpoint_dir:
        state = checkpoint_mod.load(checkpoint_dir, plan_hash=plan_hash)
        if state is not None:
            # chunk/C_round above are pure functions of the plan, so the
            # restored queues slot into the same static-shape executables
            r = state.round_index
            queues = state.queues
            if state.fi_masks.shape[0]:
                fi_masks = [np.asarray(state.fi_masks, np.uint32)]
                fi_supports = [np.asarray(state.fi_supports, np.int64)]
            anc_supports = state.anc_supports
            ledger.observed[:] = state.observed
            ledger.est_mined[:] = state.est_mined
            exchange_overflow = state.exchange_overflow
            mine_overflow = state.mine_overflow
            rounds = list(state.rounds)
            donations = list(state.donations)

    progress = obs_progress.ProgressEstimator(plan.est_loads)
    progress.start()
    if r > 0:
        # resumed mid-run: credit the restored rounds as one bulk update so
        # frac/straggler pick up where the dead run left off (the warm-up
        # discount then treats this replay credit like compile time)
        progress.update(ledger.est_mined, ledger.observed)

    while any(queues) and r < params.max_rounds:
        take = [q[:chunk] for q in queues]
        queues = [q[chunk:] for q in queues]

        # ---- padded static class table for this round's exchange ----------
        round_ids = [cid for ids in take for cid in ids]
        prefix_rows = np.zeros((C_round, n_items), dtype=bool)
        class_valid = np.zeros((C_round,), dtype=bool)
        class_assign = np.zeros((C_round,), dtype=np.int32)
        k = 0
        for p, ids in enumerate(take):
            for cid in ids:
                prefix_rows[k] = classes[cid].prefix
                class_valid[k] = True
                class_assign[k] = p
                k += 1
        prefix_packed = np.asarray(bm.pack_bool(jnp.asarray(prefix_rows)))

        t0 = time.perf_counter()
        with tr.span("cluster/exchange", round=r, classes=len(round_ids)):
            out3 = p3(
                tx_shards,
                local_valid,
                jnp.broadcast_to(
                    jnp.asarray(prefix_packed),
                    (P, C_round, prefix_packed.shape[-1]),
                ),
                jnp.broadcast_to(jnp.asarray(class_valid), (P, C_round)),
                jnp.broadcast_to(jnp.asarray(class_assign), (P, C_round)),
            )
            out3 = jax.block_until_ready(out3)
        phase_ms["exchange"] += (time.perf_counter() - t0) * 1e3

        # ---- Phase 4: mine this round's classes on the received slabs -----
        seed_prefix, seed_ext, seed_valid = planner_mod.pack_seeds(
            classes, take, n_items, chunk
        )
        keys4 = jax.vmap(lambda i: jax.random.fold_in(key, i))(
            r * P + jnp.arange(P)
        )
        mine_t0 = time.perf_counter()
        with tr.span("cluster/mine", round=r, chunk=chunk):
            out4 = p4(
                out3.slab.reshape(P, -1, IW),
                out3.slab_valid.reshape(P, -1),
                tx_shards,
                local_valid,
                jnp.asarray(seed_prefix),
                jnp.asarray(seed_ext),
                jnp.asarray(seed_valid),
                anc_b,
                minsup_b,
                keys4,
            )
            out4 = jax.device_get(out4)
        mine_s = time.perf_counter() - mine_t0
        phase_ms["mine"] += mine_s * 1e3

        exchange_overflow += int(np.asarray(out3.overflow).reshape(-1)[0])
        counts = np.asarray(out4.fi_count).reshape(P)
        totals = np.asarray(out4.fi_total).reshape(P)
        mine_overflow += int((totals - counts).sum()) + int(
            np.asarray(out4.overflow).sum()
        )
        items = np.asarray(out4.fi_items).reshape(P, -1, IW)
        supps = np.asarray(out4.fi_supports).reshape(P, -1)
        for p in range(P):
            n = int(counts[p])
            if n:
                fi_masks.append(items[p, :n])
                fi_supports.append(supps[p, :n])
        anc_supports = np.asarray(out4.prefix_supports).reshape(P, -1)[0]

        trips = np.asarray(out4.work_iters).reshape(P).astype(np.float64)
        est_mined = np.array(
            [sum(max(float(est_sizes[c]), 1.0) for c in ids) for ids in take]
        )
        ledger.record_round(trips, est_mined)
        snap = progress.update(est_mined, trips)
        if progress_cb is not None:
            progress_cb(snap)
        if obs_profile.PROFILER.enabled:
            # The multi-support kernel runs once per DFS trip inside the
            # compiled Phase-4 while_loop; attribute this round's mine wall
            # time to those executions (shapes from the per-shard slab).
            obs_profile.PROFILER.observe_loop(
                "multi",
                {
                    "K": max(1, int(params.eclat.frontier_size)),
                    "I": n_items,
                    "W": (int(out3.slab.reshape(P, -1, IW).shape[1]) + 31)
                    // 32,
                },
                n_exec=int(trips.sum()),
                wall_s=mine_s,
            )

        if tr.enabled:
            # Modeled per-shard lanes: shards run the round in lockstep, so
            # shard p's busy fraction is its DFS-trip share of the slowest
            # shard — the rendered lane gaps ARE the round's imbalance.
            t_max = max(float(trips.max()), 1.0)
            for p in range(P):
                tr.add_span(
                    "cluster/mine",
                    mine_t0,
                    mine_s * float(trips[p]) / t_max,
                    track=f"shard{p}",
                    args={
                        "round": r,
                        "trips": int(trips[p]),
                        "classes": len(take[p]),
                        "est_mined": float(est_mined[p]),
                    },
                )

        moved: List[rebalance_mod.Donation] = []
        if params.rebalance and any(queues):
            moved = rebalance_mod.rebalance(
                queues,
                est_sizes,
                ledger,
                round_index=r,
                skew_threshold=params.skew_threshold,
                max_donations=params.max_donations,
            )
            donations.extend(moved)
            for d in moved:
                tr.instant(
                    "cluster/donate",
                    round=d.round_index, class_id=d.class_id,
                    src=d.src, dst=d.dst,
                )
        rounds.append(
            RoundStats(
                round_index=r,
                classes_mined=[len(ids) for ids in take],
                work_iters=trips.astype(np.int64),
                est_mined=est_mined,
                replication=float(np.asarray(out3.replication).reshape(-1)[0]),
                donations=moved,
                mine_ms=mine_s * 1e3,
            )
        )
        r += 1
        if checkpoint_dir:
            checkpoint_mod.save(
                checkpoint_dir,
                checkpoint_mod.RoundState(
                    round_index=r,
                    queues=queues,
                    fi_masks=(
                        np.concatenate(fi_masks, axis=0)
                        if fi_masks else np.zeros((0, IW), np.uint32)
                    ),
                    fi_supports=(
                        np.concatenate(fi_supports, axis=0)
                        if fi_supports else np.zeros((0,), np.int64)
                    ),
                    anc_supports=anc_supports,
                    observed=ledger.observed,
                    est_mined=ledger.est_mined,
                    exchange_overflow=exchange_overflow,
                    mine_overflow=mine_overflow,
                    rounds=rounds,
                    donations=donations,
                ),
                plan_hash,
            )
        if round_hook is not None:
            round_hook(r - 1)
    assert not any(queues), "max_rounds exhausted with classes still queued"
    progress.finish()

    if params.strict and (exchange_overflow or mine_overflow):
        raise RuntimeError(
            f"cluster executor overflow (exchange={exchange_overflow}, "
            f"mine={mine_overflow}): raise exchange_capacity / eclat.max_out "
            f"/ eclat.max_stack — the result would not be exact"
        )

    # ---- merge: one global table = all shards' FIs + frequent ancestors ---
    t0 = time.perf_counter()
    if anc_supports is None:  # no classes at all ⇒ still need prefix supports
        anc_supports = np.zeros((A,), np.int64)
    n_anc = plan.n_ancestors
    anc_keep = np.zeros((A,), bool)
    anc_keep[:n_anc] = anc_supports[:n_anc] >= plan.abs_minsup
    if anc_keep.any():
        fi_masks.append(
            np.asarray(bm.pack_bool(jnp.asarray(plan.ancestor_masks[anc_keep])))
        )
        fi_supports.append(anc_supports[anc_keep])
    if fi_masks:
        masks = np.concatenate(fi_masks, axis=0).astype(np.uint32)
        supports = np.concatenate(fi_supports, axis=0).astype(np.int64)
    else:
        masks = np.zeros((0, bm.n_words(n_items)), np.uint32)
        supports = np.zeros((0,), np.int64)
    table = FITable(
        masks=masks, supports=supports, n_items=n_items, n_tx=plan.n_tx
    )
    phase_ms["merge"] = (time.perf_counter() - t0) * 1e3

    report = ClusterReport(
        P=P,
        backend=backend,
        rounds=rounds,
        phase_ms=phase_ms,
        est_loads=plan.est_loads,
        observed_loads=ledger.observed.copy(),
        donations=donations,
        exchange_overflow=exchange_overflow,
        mine_overflow=mine_overflow,
    )
    report.emit()
    return ClusterResult(table=table, plan=plan, report=report)


# ---------------------------------------------------------------------------
# StreamingMiner integration — the distributed re-miner
# ---------------------------------------------------------------------------


def cluster_mine_fn(
    P: int = 4,
    cluster_params: Optional[ClusterParams] = None,
    seed: int = 0,
) -> Callable:
    """A ``StreamingMiner.mine_fn`` that re-mines the window distributed.

    Shards the materialized window row-wise over the P miners and runs the
    full planner → exchange → shard-mine → rebalance pipeline; drift-triggered
    re-mines then scale with the mesh instead of a single device.
    ``cluster_params`` overrides everything except ``min_support_rel``, which
    is always derived from the trigger's absolute minsup.
    """

    def mine(window, abs_minsup: int) -> Dict[frozenset, int]:
        n_tx = window.n_tx
        assert n_tx % P == 0, f"window size {n_tx} not divisible by P={P}"
        shards = window.rows().reshape(P, n_tx // P, window.n_words)
        base = cluster_params or ClusterParams(
            planner=planner_mod.PlannerParams(
                n_db_sample=min(1024, n_tx), n_fi_sample=512
            )
        )
        # (abs−0.5)/n_tx survives the float round-trip: the planner's
        # ceil(rel·n_tx) lands exactly on abs_minsup, whereas abs/n_tx can
        # ceil to abs+1 and silently drop itemsets at exactly abs_minsup
        params = dataclasses.replace(
            base,
            planner=dataclasses.replace(
                base.planner, min_support_rel=(abs_minsup - 0.5) / n_tx
            ),
        )
        res = execute(
            shards, window.n_items, params, jax.random.PRNGKey(seed)
        )
        return res.table.to_dict()

    return mine
