"""Dynamic work rebalancing between mining rounds.

The paper's estimator is *static*: class sizes come from one sample taken
before any mining starts, so a shard whose classes were under-estimated
stays overloaded for the whole of Phase 4 — exactly the skew that the
distributed-Apriori literature (Aouad et al.; Koundinya et al.) identifies
as the speedup killer.  The executor therefore mines in **rounds** and this
module closes the loop between them:

  * :class:`LoadLedger` ingests per-shard telemetry (observed DFS trips per
    round — ``Phase4Out.work_iters``, the load metric the miner already
    reports) and maintains a per-shard *rate*: observed trips per unit of
    estimated size actually mined there.  A rate > 1 means the sample
    under-estimated that shard's classes.
  * :func:`rebalance` compares the rate-corrected **remaining** load of every
    shard queue; while the skew (max/mean) exceeds a threshold it donates
    unexplored PBEC subtrees — whole classes, from the *tail* of the most
    loaded queue (its cheapest pending work, so the expensive head the
    estimates placed deliberately stays put) — to the least loaded shard.
    Donations per call are bounded, so a pathological estimate cannot turn
    the control plane into a thrash loop.

Donating a class is *exact* by construction: the executor re-runs the
Phase-3 exchange for each round's classes, so the recipient shard receives
precisely the transactions containing the donated prefix before it mines it
(no stale slab is ever reused across an ownership change).
"""
from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Optional

import numpy as np


class Donation(NamedTuple):
    """One class moved between shard queues (telemetry record)."""

    round_index: int
    class_id: int
    src: int
    dst: int


@dataclasses.dataclass
class LoadLedger:
    """Per-shard telemetry accumulator: estimated-vs-observed load.

    ``rates[p]`` converts the planner's size estimates into observed DFS
    trips for shard p; shards with no history fall back to the global rate,
    and the global rate starts at 1.0 (trust the estimates until told
    otherwise).
    """

    P: int
    observed: np.ndarray = dataclasses.field(default=None)   # trips per shard
    est_mined: np.ndarray = dataclasses.field(default=None)  # est units mined

    def __post_init__(self):
        if self.observed is None:
            self.observed = np.zeros(self.P, dtype=np.float64)
        if self.est_mined is None:
            self.est_mined = np.zeros(self.P, dtype=np.float64)

    def record_round(self, trips: np.ndarray, est_mined: np.ndarray) -> None:
        """Add one round of telemetry (both arrays are per-shard, length P)."""
        self.observed += np.asarray(trips, dtype=np.float64)
        self.est_mined += np.asarray(est_mined, dtype=np.float64)

    @property
    def global_rate(self) -> float:
        tot_est = float(self.est_mined.sum())
        if tot_est <= 0.0:
            return 1.0
        return float(self.observed.sum()) / tot_est

    def rates(self) -> np.ndarray:
        """float [P] — observed trips per estimated size unit, per shard."""
        g = self.global_rate
        out = np.full(self.P, g, dtype=np.float64)
        has = self.est_mined > 0.0
        out[has] = self.observed[has] / self.est_mined[has]
        return out

    def imbalance(self) -> float:
        """max/mean of cumulative observed load (1.0 = perfect balance)."""
        mean = float(self.observed.mean())
        if mean <= 0.0:
            return 1.0
        return float(self.observed.max()) / mean


def remaining_loads(
    queues: List[List[int]],
    est_sizes: np.ndarray,
    rates: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Rate-corrected estimated load still queued on every shard."""
    P = len(queues)
    rates = np.ones(P) if rates is None else np.asarray(rates, dtype=np.float64)
    # every queued class costs at least ~1 trip (the pop that prunes it), so
    # an all-zero estimate still exposes queue-length skew to the balancer
    return np.array(
        [
            rates[p] * float(sum(max(est_sizes[c], 1.0) for c in queues[p]))
            for p in range(P)
        ]
    )


def rebalance(
    queues: List[List[int]],
    est_sizes: np.ndarray,
    ledger: LoadLedger,
    *,
    round_index: int,
    skew_threshold: float = 1.25,
    max_donations: int = 8,
) -> List[Donation]:
    """Donate queued classes from overloaded to underloaded shards, in place.

    Runs at most ``max_donations`` single-class moves; stops early once the
    rate-corrected remaining skew (max/mean) drops under ``skew_threshold``
    or a move would overshoot (never makes the donor lighter than the
    recipient was — the classic list-scheduling stability rule).
    """
    rates = ledger.rates()
    donations: List[Donation] = []
    for _ in range(max_donations):
        loads = remaining_loads(queues, est_sizes, rates)
        mean = float(loads.mean())
        if mean <= 0.0 or float(loads.max()) <= skew_threshold * mean:
            break
        src = int(loads.argmax())
        dst = int(loads.argmin())
        if src == dst or not queues[src]:
            break
        cid = queues[src][-1]  # tail = lightest pending class of the donor
        cost_dst = rates[dst] * max(float(est_sizes[cid]), 1.0)
        # stability: donating must not just swap who is overloaded
        if loads[dst] + cost_dst >= loads[src]:
            break
        queues[src].pop()
        queues[dst].append(cid)
        donations.append(Donation(round_index, int(cid), src, dst))
    return donations
