"""Arrival-process load harness for the serving front end, SLO-gated.

Drives :class:`repro.serve.service.MiningService` with the traffic a
production deployment actually faces (ROADMAP item 1, the
genre-recommendation scenario): **open-loop Poisson arrivals** at a target
QPS (optionally a **closed loop** of concurrent callers), **Zipf-hot**
query popularity, and a **drifting hot set** (the popular queries rotate
every ``--drift-every`` seconds).  While the service runs, a live
dashboard repaints the last-W-seconds view — windowed p50/p95/p99, QPS,
shed rate, error-budget burn rate, queue depth, per-replica lanes — from
the :class:`repro.obs.slo.SLOTracker` the service feeds.

Phases: **warm** (compile every query kind off the clock) → **ramp**
(arrival rate climbs linearly to the target) → **measure**.  The gate
(``--gate``) exits non-zero iff the measured phase violated the SLO: any
burn-rate or latency alert fired, or the final windowed p99 exceeds the
objective.  Alerts also land as trace instants and run-record events
(``--trace DIR`` makes the whole run a Perfetto timeline in which each
request id threads enqueue → assemble → sweep → respond).

SLO keys are merged into ``BENCH_serve.json`` (``slo_*`` — preserved by
``benchmarks/serve.py`` rewrites, summarized by ``benchmarks/report.py``).
``--compare-dispatch`` additionally measures micro-batched vs per-query
dispatch throughput over the same workload and records the speedup.

  python -m repro.launch.serve_load --qps 200 --duration 10 --replicas 2 \\
      [--closed 8] [--gate] [--trace DIR] [--no-dashboard]

The injected-overload self-test (CI): a target far past capacity with a
small queue must shed, burn the error budget, fire the alert, and exit
non-zero::

  python -m repro.launch.serve_load --qps 50000 --max-queue 64 --gate
"""
from __future__ import annotations

import argparse

from repro.launch.host_devices import preparse_devices

preparse_devices()  # must run before anything imports jax

import json  # noqa: E402
import os  # noqa: E402
import sys  # noqa: E402
import threading  # noqa: E402
import time  # noqa: E402
from typing import List, Optional  # noqa: E402

import numpy as np  # noqa: E402

KINDS = ("support", "rules", "superset")
KIND_MIX = (0.5, 0.3, 0.2)


# ---------------------------------------------------------------------------
# workload: Zipf-hot pools per kind, hot set drifting over time
# ---------------------------------------------------------------------------


class Workload:
    """Zipf-ranked query pools whose hot head rotates while serving runs.

    ``draw(now)`` picks a kind by the fixed mix and a pool rank by a Zipf
    law, then shifts the rank → pool-slot mapping by the drift offset
    ``(now - t0) // drift_every`` — the identity of the hot queries
    changes over time (cache churn, new compiled nothing: masks only),
    exactly the regime a windowed view exists for.
    """

    def __init__(self, rng, pools, zipf_a: float = 1.3,
                 drift_every: float = 10.0, drift_step: int = 7):
        self.rng = rng
        self.pools = pools                       # {kind: uint32[P, IW]}
        self.zipf_a = zipf_a
        self.drift_every = drift_every
        self.drift_step = drift_step
        self.t0 = time.monotonic()

    def draw(self, now: Optional[float] = None):
        now = time.monotonic() if now is None else now
        kind = KINDS[self.rng.choice(len(KINDS), p=KIND_MIX)]
        pool = self.pools[kind]
        n = pool.shape[0]
        rank = min(int(self.rng.zipf(self.zipf_a)) - 1, n - 1)
        shift = int((now - self.t0) / self.drift_every) * self.drift_step
        return kind, pool[(rank + shift) % n]


def build_pools(rng, fis, dense, n_items, pool: int = 64):
    """Per-kind query pools over the mined index (cf. serve_mine)."""
    from repro.core.rules import pack_itemsets

    fi_list = sorted(fis, key=lambda s: (len(s), tuple(sorted(s))))
    cand = [fi_list[i] for i in rng.choice(
        len(fi_list), size=min(pool, len(fi_list)), replace=False)]
    probes = [
        frozenset(rng.choice(n_items, size=min(6, n_items),
                             replace=False).tolist())
        for _ in range(max(pool // 8, 1))
    ]
    rows = rng.choice(dense.shape[0], size=min(pool, dense.shape[0]),
                      replace=False)
    baskets = [frozenset(np.nonzero(dense[t])[0].tolist()) for t in rows]
    small = [s for s in fi_list if len(s) <= 2] or fi_list[:1]
    prefixes = [small[i] for i in rng.choice(
        len(small), size=min(pool, len(small)), replace=False)]
    return {
        "support": pack_itemsets(cand + probes, n_items),
        "rules": pack_itemsets(baskets, n_items),
        "superset": pack_itemsets(prefixes, n_items),
    }


# ---------------------------------------------------------------------------
# dashboard
# ---------------------------------------------------------------------------


class Dashboard:
    """Live refreshing operator panel (ANSI repaint on a tty, plain lines
    otherwise)."""

    def __init__(self, enabled: bool, out=sys.stdout):
        self.enabled = enabled
        self.out = out
        self.repaint = enabled and out.isatty()
        self._last_lines = 0

    @staticmethod
    def _ms(v) -> str:
        return f"{v:6.1f}" if v is not None else "     -"

    def render(self, t: float, phase: str, status, svc, policy) -> None:
        if not self.enabled:
            return
        st = svc.stats()
        alert = "ALERT" if status.alert_active else "ok"
        lines = [
            f"serve_load  t={t:6.1f}s  phase={phase:<7}  "
            f"gen={st['generation']}  slo={alert}",
            f"  window {status.window_s:.0f}s: "
            f"qps={status.qps:8.1f} (offered {status.offered_qps:8.1f})  "
            f"p50={self._ms(status.p50_ms)} p95={self._ms(status.p95_ms)} "
            f"p99={self._ms(status.p99_ms)}ms (obj {policy.p99_ms:.0f}ms)",
            f"  shed={status.shed_rate:6.2%}  "
            f"burn={status.burn_rate:6.2f} "
            f"(fire>={policy.burn_hi:.1f} clear<{policy.burn_lo:.1f})  "
            f"queue={st['queue_depth']}/{st['max_queue']}  "
            f"flushes={st['flushes']}  shed_total={st['shed']}",
        ]
        per_flush = st["per_replica_flushes"]
        per_req = st["per_replica_requests"]
        peak = max(per_flush) or 1
        lanes = "  ".join(
            f"r{i} {'▇' * max(1, round(6 * f / peak))} "
            f"{f} flushes/{q} reqs"
            for i, (f, q) in enumerate(zip(per_flush, per_req))
        )
        lines.append(f"  replica lanes: {lanes}")
        if self.repaint and self._last_lines:
            self.out.write(f"\x1b[{self._last_lines}F\x1b[J")
        self.out.write("\n".join(lines) + "\n")
        self.out.flush()
        self._last_lines = len(lines) if self.repaint else 0


# ---------------------------------------------------------------------------
# traffic generators
# ---------------------------------------------------------------------------


def open_loop(svc, workload, rng, t_end: float, rate_fn, tickets: list,
              stop: threading.Event) -> None:
    """Poisson arrivals: exponential gaps at the (ramping) target rate."""
    next_t = time.monotonic()
    while not stop.is_set():
        now = time.monotonic()
        if now >= t_end:
            break
        if now < next_t:
            time.sleep(min(next_t - now, 0.25))
            continue
        kind, mask = workload.draw(now)
        tickets.append(svc.submit(kind, mask))
        # the NEXT arrival's gap — drawn only after an arrival fires
        rate = max(rate_fn(now), 1e-3)
        next_t += rng.exponential(1.0 / rate)
        if next_t < now - 1.0:      # fell behind (stall): don't burst-spiral
            next_t = now


def closed_loop(svc, workload, n_workers: int, t_end: float,
                tickets: list, stop: threading.Event) -> List[threading.Thread]:
    """N concurrent callers, each submit → wait → repeat (think-time 0)."""
    lock = threading.Lock()

    def worker(seed: int):
        rng = np.random.default_rng(seed)
        wl = Workload(rng, workload.pools, workload.zipf_a,
                      workload.drift_every, workload.drift_step)
        wl.t0 = workload.t0
        while not stop.is_set() and time.monotonic() < t_end:
            kind, mask = wl.draw()
            t = svc.submit(kind, mask)
            with lock:
                tickets.append(t)
            try:
                t.result(timeout=10.0)
            except TimeoutError:
                return
    threads = [threading.Thread(target=worker, args=(1000 + i,), daemon=True)
               for i in range(n_workers)]
    for t in threads:
        t.start()
    return threads


# ---------------------------------------------------------------------------
# micro-batch vs per-query dispatch comparison (same harness, same queries)
# ---------------------------------------------------------------------------


def compare_dispatch(engine, workload, n: int = 256) -> dict:
    """Throughput of fused flush-width sweeps vs per-query dispatch.

    Every engine call pads to the engine width, so both sides run the SAME
    compiled program — the difference measured is purely amortization.
    """
    draws = [workload.draw() for _ in range(n)]
    by_kind = {k: np.stack([m for kk, m in draws if kk == k])
               for k in KINDS if any(kk == k for kk, _ in draws)}
    call = {"support": engine.support, "rules": engine.rules_for,
            "superset": engine.supersets}
    B = engine.batch
    for k, masks in by_kind.items():        # warm every kind's program
        call[k](masks[:B])
    t0 = time.perf_counter()
    for k, masks in by_kind.items():
        for off in range(0, masks.shape[0], B):
            call[k](masks[off:off + B])
    batched_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for k, masks in by_kind.items():
        for i in range(masks.shape[0]):
            call[k](masks[i: i + 1])
    per_query_s = time.perf_counter() - t0
    return {
        "n": n,
        "batched_qps": n / batched_s,
        "per_query_qps": n / per_query_s,
        "speedup": per_query_s / batched_s,
    }


# ---------------------------------------------------------------------------
# BENCH_serve.json merge
# ---------------------------------------------------------------------------


def merge_bench(path: str, keys: dict) -> None:
    """Fold ``slo_*`` keys into the (possibly existing) serve BENCH file.

    The suite's provenance ``meta`` block (git SHA / backend / ts stamped
    by ``benchmarks.report.bench_meta``) is preserved when present and
    stamped fresh when the load harness writes the file first — either
    way the merged file stays attributable.
    """
    from repro.obs import perfdb

    data = {"bench": "serve"}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError):
            pass
    data.update(keys)
    if not isinstance(data.get("meta"), dict):
        data["meta"] = {"git_sha": perfdb.git_sha(), "backend": "",
                        "ts": perfdb.utc_stamp()}
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=2)
        f.write("\n")
    os.replace(tmp, path)


# ---------------------------------------------------------------------------


def main(argv: Optional[List[str]] = None) -> int:
    from repro.core import eclat
    from repro.data.ibm_gen import generate_dense, params_from_name
    from repro.obs import trace as obs_trace
    from repro.obs.session import add_obs_flags, start_session
    from repro.obs.slo import SLOPolicy, SLOTracker
    from repro.serve import MiningService, QueryCache, QueryEngine
    from repro.serve.index import build_indexes

    ap = argparse.ArgumentParser(
        description="SLO-gated load harness for the serving front end")
    ap.add_argument("--db", default="T0.5I0.024P8PL5TL8",
                    help="IBM synthetic DB name (mined by brute force — "
                         "small DBs; the harness exercises serving, not "
                         "mining)")
    ap.add_argument("--support", type=float, default=0.08)
    ap.add_argument("--minconf", type=float, default=0.3)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--batch", type=int, default=64,
                    help="engine dispatch width / max flush size")
    ap.add_argument("--deadline-ms", type=float, default=4.0,
                    dest="deadline_ms",
                    help="micro-batch deadline: max wait of the oldest "
                         "queued request")
    ap.add_argument("--max-queue", type=int, default=1024, dest="max_queue")
    ap.add_argument("--cache", type=int, default=2048,
                    help="service LRU capacity (0 disables)")
    ap.add_argument("--topk", type=int, default=5)
    ap.add_argument("--qps", type=float, default=200.0,
                    help="open-loop target arrival rate")
    ap.add_argument("--closed", type=int, default=0,
                    help="ALSO run a closed loop of N concurrent callers")
    ap.add_argument("--duration", type=float, default=10.0,
                    help="measured-phase seconds")
    ap.add_argument("--ramp", type=float, default=2.0,
                    help="seconds ramping arrival rate up to the target")
    ap.add_argument("--pool", type=int, default=64)
    ap.add_argument("--zipf", type=float, default=1.3)
    ap.add_argument("--drift-every", type=float, default=5.0,
                    dest="drift_every",
                    help="seconds between hot-set rotations")
    ap.add_argument("--window", type=float, default=5.0,
                    help="SLO sliding-window seconds")
    ap.add_argument("--slo-p99-ms", type=float, default=200.0,
                    dest="slo_p99_ms")
    ap.add_argument("--availability", type=float, default=0.99)
    ap.add_argument("--burn-hi", type=float, default=2.0, dest="burn_hi")
    ap.add_argument("--burn-lo", type=float, default=1.0, dest="burn_lo")
    ap.add_argument("--report-every", type=float, default=0.5,
                    dest="report_every")
    ap.add_argument("--no-dashboard", action="store_true",
                    dest="no_dashboard")
    ap.add_argument("--gate", action="store_true",
                    help="exit non-zero if the measured phase violated "
                         "the SLO (alert fired or final windowed p99 over "
                         "objective)")
    ap.add_argument("--compare-dispatch", action="store_true",
                    dest="compare_dispatch",
                    help="also measure micro-batched vs per-query dispatch "
                         "throughput")
    ap.add_argument("--bench-out", default="BENCH_serve.json",
                    dest="bench_out",
                    help="BENCH file to merge slo_* keys into ('' skips)")
    ap.add_argument("--seed", type=int, default=0)
    add_obs_flags(ap)
    args = ap.parse_args(argv)
    obs = start_session(args, "serve_load")

    # ---- index --------------------------------------------------------------
    rng = np.random.default_rng(args.seed)
    dense = generate_dense(params_from_name(args.db, seed=args.seed))
    n_tx, n_items = dense.shape
    minsup = int(np.ceil(args.support * n_tx))
    fis = eclat.brute_force_fis(dense, minsup)
    fi_index, rule_index = build_indexes(fis, n_items, n_tx,
                                         min_confidence=args.minconf)
    print(f"index: db={args.db} |D|={n_tx} |B|={n_items} "
          f"F={fi_index.n_fis} R={rule_index.n_rules}")

    # ---- service ------------------------------------------------------------
    policy = SLOPolicy(
        p99_ms=args.slo_p99_ms, availability=args.availability,
        window_s=args.window, burn_hi=args.burn_hi, burn_lo=args.burn_lo,
    )
    slo = SLOTracker(policy)
    tracer = obs_trace.tracer()

    def on_alert(ev):
        line = (f"[slo] {ev['kind']} ({ev['objective']})  "
                + "  ".join(f"{k}={v}" for k, v in ev.items()
                            if k not in ("kind", "objective", "slo", "t")))
        print(line, file=sys.stderr)
        tracer.instant(f"slo/{ev['kind']}", **{
            k: v for k, v in ev.items() if k != "t"})
        if obs:
            obs.event(ev["kind"], **{k: v for k, v in ev.items()
                                     if k != "kind"})

    slo.on_alert(on_alert)

    engines = [
        QueryEngine(fi_index, rule_index, batch=args.batch,
                    top_k=args.topk)
        for _ in range(args.replicas)
    ]
    cache = QueryCache(capacity=args.cache) if args.cache > 0 else None
    svc = MiningService(
        engines, max_batch=args.batch, deadline_ms=args.deadline_ms,
        max_queue=args.max_queue, slo=slo, cache=cache, auto_start=False,
    )

    pools = build_pools(rng, fis, dense, n_items, pool=args.pool)
    workload = Workload(rng, pools, zipf_a=args.zipf,
                        drift_every=args.drift_every)

    # ---- warm (compile off the clock) ---------------------------------------
    t0 = time.time()
    for kind in KINDS:
        m = pools[kind][:1]
        eng_call = {"support": engines[0].support,
                    "rules": engines[0].rules_for,
                    "superset": engines[0].supersets}[kind]
        eng_call(np.broadcast_to(m, (args.batch,) + m.shape[1:]))
        eng_call(m)
    print(f"warm: compiled {len(KINDS)} query kinds in {time.time()-t0:.2f}s")

    # ---- drive --------------------------------------------------------------
    svc.start()
    dash = Dashboard(enabled=not args.no_dashboard)
    stop = threading.Event()
    tickets: list = []
    t_start = time.monotonic()
    t_measure0 = t_start + args.ramp
    t_end = t_measure0 + args.duration

    def rate_fn(now: float) -> float:
        if args.ramp <= 0 or now >= t_measure0:
            return args.qps
        frac = (now - t_start) / args.ramp
        return args.qps * (0.25 + 0.75 * frac)

    arr = threading.Thread(
        target=open_loop,
        args=(svc, workload, np.random.default_rng(args.seed + 1), t_end,
              rate_fn, tickets, stop),
        daemon=True,
    )
    arr.start()
    closed_threads = []
    if args.closed > 0:
        closed_threads = closed_loop(svc, workload, args.closed, t_end,
                                     tickets, stop)

    last_status = slo.evaluate()
    while time.monotonic() < t_end:
        time.sleep(args.report_every)
        now = time.monotonic()
        phase = "ramp" if now < t_measure0 else "measure"
        last_status = slo.evaluate()   # alert callback handles transitions
        dash.render(now - t_start, phase, last_status, svc, policy)
    stop.set()
    arr.join(timeout=5)
    for t in closed_threads:
        t.join(timeout=5)
    svc.stop(drain=True)

    # resolve every ticket (sheds resolved at submit; the rest at flush)
    unresolved = sum(1 for t in tickets if not t.done())
    final = slo.evaluate()
    dash.render(time.monotonic() - t_start, "done", final, svc, policy)
    measure_alerts = slo.alerts_since(t_measure0)

    st = svc.stats()
    wall = time.monotonic() - t_start
    print(f"\nserve_load: {len(tickets)} offered in {wall:.1f}s "
          f"(target {args.qps:.0f} QPS, ramp {args.ramp:.0f}s + measure "
          f"{args.duration:.0f}s), {st['shed']} shed, {st['errors']} "
          f"errors, {unresolved} unresolved")
    p99 = final.p99_ms
    print(f"window[{policy.window_s:.0f}s]: qps={final.qps:.1f} "
          f"p50={final.p50_ms} p95={final.p95_ms} p99={p99} ms "
          f"(objective {policy.p99_ms}), shed_rate={final.shed_rate:.2%}, "
          f"burn={final.burn_rate:.2f}")
    print(f"alerts: {len(measure_alerts)} fired in measured phase "
          f"({len(slo.alerts)} transitions total)")

    cmp_stats = None
    if args.compare_dispatch:
        cmp_stats = compare_dispatch(engines[0], workload)
        print(f"dispatch: micro-batched {cmp_stats['batched_qps']:,.0f} QPS "
              f"vs per-query {cmp_stats['per_query_qps']:,.0f} QPS "
              f"-> {cmp_stats['speedup']:.1f}x")

    # ---- gate + artifacts ----------------------------------------------------
    p99_over = (p99 is not None and p99 > policy.p99_ms)
    violated = bool(measure_alerts) or p99_over or final.alert_active
    slo_keys = {
        "slo_target_qps": args.qps,
        "slo_window_s": policy.window_s,
        "slo_qps": final.qps,
        "slo_offered_qps": final.offered_qps,
        "slo_p50_ms": final.p50_ms,
        "slo_p95_ms": final.p95_ms,
        "slo_p99_ms": p99,
        "slo_p99_objective_ms": policy.p99_ms,
        "slo_shed_rate": final.shed_rate,
        "slo_shed_total": float(st["shed"]),
        "slo_burn_rate": final.burn_rate,
        "slo_alerts_fired": len(measure_alerts),
        "slo_gate_ok": not violated,
    }
    if cmp_stats is not None:
        slo_keys["slo_microbatch_speedup"] = cmp_stats["speedup"]
    if args.bench_out:
        merge_bench(args.bench_out, slo_keys)
        print(f"[merged {len(slo_keys)} slo_* keys into {args.bench_out}]")
    if args.gate:
        # gated launches feed the persistent perf trajectory too, so SLO
        # latencies/burn trend across PRs (obs_report history/regress)
        import jax

        from repro.obs import perfdb

        row = perfdb.append(perfdb.DEFAULT_PATH, "serve_load", slo_keys,
                            backend=jax.default_backend())
        print(f"[history += serve_load: {len(row['keys'])} keys @ "
              f"{row['sha'] or '?'}]")
    if obs:
        obs.event("load_done", offered=len(tickets), shed=st["shed"],
                  alerts=len(measure_alerts))
        obs.finish(**{k: v for k, v in slo_keys.items()})

    if args.gate and violated:
        why = []
        if measure_alerts:
            why.append(f"{len(measure_alerts)} SLO alert(s) fired")
        if p99_over:
            why.append(f"windowed p99 {p99:.1f}ms > {policy.p99_ms}ms")
        if final.alert_active:
            why.append("alert still active at end of run")
        print(f"SLO GATE FAILED: {'; '.join(why)}", file=sys.stderr)
        return 1
    if args.gate:
        print("SLO gate: ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
