"""Production training driver: config → mesh → data → train loop → checkpoints.

Scaled to the hardware it finds: on a pod this is the same `train_step` the
dry-run lowered (FSDP+TP shardings, accum, remat); on this CPU container run
it with a smoke config:

  python -m repro.launch.train --arch llama3.2-3b --smoke --steps 200

Fault tolerance exercised here: atomic checkpoints every ``--ckpt-every``
steps, automatic resume from the latest complete checkpoint (including the
data-pipeline cursor), deterministic batch addressing (a restart or an
elastic re-shard replays the identical stream).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_config
from repro.data.lm_pipeline import SyntheticLM
from repro.models import model as M
from repro.models import steps as steps_mod
from repro.optim import adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    opt_cfg = adamw.AdamWConfig(
        lr=args.lr, warmup_steps=20, total_steps=args.steps
    )
    key = jax.random.PRNGKey(0)
    params = M.init(cfg, key)
    opt = adamw.init(params, opt_cfg)
    n = M.n_params(cfg)
    print(f"arch={cfg.name} params={n/1e6:.1f}M devices={len(jax.devices())}")

    pipe = SyntheticLM(cfg.vocab, args.seq, args.batch, seed=1)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    start = 0
    if args.resume and mgr.latest_step() is not None:
        abstract = {
            "params": jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params
            ),
            "opt": jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), opt
            ),
        }
        state, extra = mgr.restore(abstract)
        params, opt = state["params"], state["opt"]
        pipe.load_state_dict(extra["pipeline"])
        start = extra["step"] + 1
        print(f"resumed from step {extra['step']}")

    step_fn = jax.jit(steps_mod.make_train_step(cfg, opt_cfg, accum=args.accum))
    t0 = time.time()
    tokens_done = 0
    for step in range(start, args.steps):
        b = pipe.next_batch()
        batch = {
            "tokens": jnp.asarray(b["tokens"]),
            "loss_mask": jnp.asarray(b["loss_mask"]),
        }
        if cfg.family == "vlm":
            batch["vision_embeds"] = jnp.zeros(
                (args.batch, cfg.vision_tokens, cfg.d_model), jnp.float32
            )
        if cfg.family == "encdec":
            batch["frames"] = jax.random.normal(
                jax.random.fold_in(key, step),
                (args.batch, cfg.enc_context, cfg.d_model),
                jnp.float32,
            )
        params, opt, metrics = step_fn(params, opt, batch)
        tokens_done += b["tokens"].size
        if step % 10 == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            dt = time.time() - t0
            print(
                f"step {step:5d} loss {loss:7.4f} gnorm "
                f"{float(metrics['grad_norm']):8.3f} lr {float(metrics['lr']):.2e} "
                f"tok/s {tokens_done/max(dt,1e-9):,.0f}",
                flush=True,
            )
        if step % args.ckpt_every == 0 and step > start:
            mgr.save(
                step,
                {"params": params, "opt": opt},
                extra={"step": step, "pipeline": pipe.state_dict()},
            )
    print("done")


if __name__ == "__main__":
    main()
