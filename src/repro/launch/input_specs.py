"""ShapeDtypeStruct stand-ins for every (arch × shape) dry-run cell.

No device allocation; shardable; weak-type-correct.  Modality frontends are
stubs per the assignment: ``vision_embeds`` / ``frames`` arrive as precomputed
embeddings with the model's d_model width.

Shape semantics (recorded per DESIGN.md):
  * train/prefill: ``seq_len`` is the token positions budget.  VLM: 256 of the
    positions are patch embeddings, the rest text.  Enc-dec: seq_len applies to
    the *encoder frames* (audio length — the compute-dominant side) with a
    448-token decoder, Whisper's native split.
  * decode: one new token against a cache of ``seq_len``.  Enc-dec: cross
    context of seq_len encoder states, 448-deep decoder self-cache.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as M

SDS = jax.ShapeDtypeStruct

WHISPER_DEC_LEN = 448


def shape_adjusted_config(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """Per-cell config tweaks (enc-dec cross-context follows the cell)."""
    if cfg.family == "encdec":
        return dataclasses.replace(cfg, enc_context=shape.seq_len)
    return cfg


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """The forward-pass batch for train/prefill cells."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.compute_dtype)
    if cfg.family == "vlm":
        nv = cfg.vision_tokens
        return {
            "tokens": SDS((B, S - nv), jnp.int32),
            "vision_embeds": SDS((B, nv, cfg.d_model), dt),
        }
    if cfg.family == "encdec":
        return {
            "tokens": SDS((B, WHISPER_DEC_LEN), jnp.int32),
            "frames": SDS((B, S, cfg.d_model), dt),
        }
    return {"tokens": SDS((B, S), jnp.int32)}


def decode_specs(
    cfg: ModelConfig, shape: ShapeConfig
) -> Tuple[Any, Any, Any]:
    """(cache, tokens, pos) abstract args for serve_step."""
    B, S = shape.global_batch, shape.seq_len
    cfg = shape_adjusted_config(cfg, shape)
    max_len = WHISPER_DEC_LEN if cfg.family == "encdec" else S
    cache = jax.eval_shape(
        lambda: M.init_cache(cfg, B, max_len, jnp.dtype(cfg.compute_dtype))
    )
    tokens = SDS((B, 1), jnp.int32)
    pos = SDS((), jnp.int32)
    return cache, tokens, pos
