"""Production mesh construction.

A FUNCTION, not a module constant — importing this module never touches jax
device state (device count is locked at first backend init, which the
dry-run controls via XLA_FLAGS before any import).
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    """``jax.make_mesh`` across JAX versions: older releases have neither
    ``axis_types`` nor ``jax.sharding.AxisType``; Auto is their default."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips with a leading "pod" axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_miner_mesh(n: int):
    """1-D mesh for the Parallel-FIMI miner axis (launch/mine.py)."""
    return _make_mesh((n,), ("miners",))


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU multi-device tests (device count set by the test)."""
    return _make_mesh((data, model), ("data", "model"))
