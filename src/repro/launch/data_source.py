"""Shared --dataset / --store / --db data-source resolution for launchers.

``launch/mine.py`` and ``launch/cluster_mine.py`` take the same three data
sources; this resolves them in one place:

  * ``--dataset f.dat``  — ingest a FIMI file into a store (at ``--store``
    or a temp dir) and mine it out of core;
  * ``--store dir/``     — open an existing :class:`~repro.store.TxStore`,
    or spill the ``--db`` IBM database into it block-by-block first;
  * neither              — generate the ``--db`` database dense in RAM
    (the seed behavior).

Returns ``(store, dense, label)`` where exactly one of ``store`` /
``dense`` is set.
"""
from __future__ import annotations

import tempfile
from typing import Optional, Tuple


def resolve_source(
    dataset: str,
    store_dir: str,
    db: str,
    *,
    block_tx: int,
    seed: int,
) -> Tuple[Optional[object], Optional[object], str]:
    """Resolve the launcher's data source; see module docstring."""
    if dataset:
        from repro.store import ingest_dat

        directory = store_dir or tempfile.mkdtemp(prefix="txstore_")
        store = ingest_dat(dataset, directory, block_tx=block_tx)
        return store, None, f"dataset={dataset}"
    if store_dir:
        from repro.data.ibm_gen import params_from_name
        from repro.store import TxStore, write_ibm_store

        if TxStore.exists(store_dir):
            return TxStore.open(store_dir), None, f"store={store_dir}"
        store = write_ibm_store(
            params_from_name(db, seed=seed), store_dir, block_tx=block_tx
        )
        return store, None, f"store={store_dir} (spilled from {db})"
    from repro.data.ibm_gen import generate_dense, params_from_name

    dense = generate_dense(params_from_name(db, seed=seed))
    return None, dense, f"db={db}"
