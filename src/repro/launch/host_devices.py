"""``--devices N`` preamble shared by the CLI launchers.

XLA locks the host device count at first backend initialization, so the
flag must be applied to ``XLA_FLAGS`` *before anything imports jax* — the
launchers call :func:`preparse_devices` at module import, ahead of their
jax imports, and this module must therefore never import jax itself.
"""
from __future__ import annotations

import os
import sys
from typing import Optional, Sequence


def preparse_devices(argv: Optional[Sequence[str]] = None) -> Optional[int]:
    """Scan argv for ``--devices N`` / ``--devices=N`` and set XLA_FLAGS.

    Appends to any pre-existing ``XLA_FLAGS`` rather than clobbering it
    (unless a host-device-count flag is already present, which wins).
    Returns the parsed count, or None if the flag is absent.
    """
    argv = list(sys.argv if argv is None else argv)
    n: Optional[str] = None
    for i, arg in enumerate(argv):
        if arg == "--devices" and i + 1 < len(argv):
            n = argv[i + 1]
        elif arg.startswith("--devices="):
            n = arg.split("=", 1)[1]
    if n is None or int(n) <= 0:
        return None
    prev = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in prev:
        flag = f"--xla_force_host_platform_device_count={int(n)}"
        os.environ["XLA_FLAGS"] = f"{prev} {flag}".strip()
    return int(n)
