"""Profiled demo mine: every kernel family, measured vs modeled.

One short run that drives all five ``repro.kernels.ops`` dispatch
families through the kernel profiler:

  * **bitmap / multi / pair** — the mining support counters, called
    eagerly (per-call device-synced timing) on an IBM-generator database;
  * **subset** — the serving sweep, queries against itemset masks;
  * **delta**  — the streaming sweep, stacked transaction blocks against
    itemset masks;
  * plus a real Parallel-FIMI mine, so the ``while_loop`` frontier work
    is loop-attributed and the sample-grounded live progress line shows.

With ``--trace DIR`` the attribution rides the run record as
``kernels/*`` gauges; ``tools/check.sh --profile`` renders and gates it::

    python -m repro.launch.profile_demo --trace RUN
    python -m repro.launch.obs_report kernels RUN \
        --require bitmap,multi,pair,subset,delta --check-model
"""
from __future__ import annotations

import argparse
import time


def main():
    import jax
    import jax.numpy as jnp

    from repro.core import bitmap as bm
    from repro.core import eclat, fimi
    from repro.data.ibm_gen import generate_dense, params_from_name
    from repro.kernels import ops
    from repro.obs import profile as obs_profile
    from repro.obs.session import add_obs_flags, start_session

    ap = argparse.ArgumentParser()
    ap.add_argument("--db", default="T0.5I0.024P8PL5TL8")
    ap.add_argument("--support", type=float, default=0.08)
    ap.add_argument("-P", type=int, default=2)
    ap.add_argument("--reps", type=int, default=3,
                    help="eager dispatches per family")
    ap.add_argument("--frontier", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    add_obs_flags(ap)
    args = ap.parse_args()
    args.profile = True      # this driver exists to profile
    obs = start_session(args, "profile_demo")
    prof = obs_profile.profiler()
    if obs is None:          # no run record asked for: still profile + print
        prof.clear()
        prof.enable()

    dense = generate_dense(params_from_name(args.db, seed=args.seed))
    db = bm.BitmapDB.from_dense(jnp.asarray(dense))
    n_tx, n_items = dense.shape
    print(f"ibm:{args.db} |D|={n_tx} |B|={n_items} sup={args.support} "
          f"P={args.P} reps={args.reps}")

    # ---- eager family sweep (per-call device-synced timing) ----------------
    all_t = db.all_tids()
    prefix_tids = jnp.tile(all_t[None, :], (8, 1))
    q_masks = db.tx_bits[: min(32, n_tx)]
    fi_masks = db.tx_bits[: min(64, n_tx)]
    half = max(1, n_tx // 2)
    blocks = db.tx_bits[: 2 * half].reshape(2, half, -1)
    t0 = time.perf_counter()
    for _ in range(max(1, args.reps)):
        ops.extension_supports(db.item_bits, all_t)          # bitmap
        ops.multi_extension_supports(db.item_bits, prefix_tids)  # multi
        ops.pair_supports(db.item_bits, all_t)               # pair
        ops.subset_superset_counts(q_masks, fi_masks)        # subset
        ops.block_itemset_supports(blocks, fi_masks)         # delta
    print(f"eager sweep: {args.reps} reps x 5 families in "
          f"{time.perf_counter() - t0:.2f}s")

    # ---- a real mine: loop attribution + live progress ---------------------
    params = fimi.FimiParams(
        min_support_rel=args.support,
        n_db_sample=min(2048, n_tx), n_fi_sample=1024,
        eclat=eclat.EclatConfig(
            max_out=1 << 15, max_stack=8192, frontier_size=args.frontier
        ),
    )
    res = fimi.run(
        fimi.shard_db(jnp.asarray(dense), args.P), n_items, params,
        jax.random.PRNGKey(args.seed),
    )
    print(f"|F| = {res.n_fis}  work_iters={res.work_iters.tolist()}")
    if res.progress is not None:
        print(res.progress.line())

    # ---- attribution table --------------------------------------------------
    rep = prof.report()
    m = rep["machine"]
    print(f"machine={m['name']} word_ops_peak={m['word_ops_peak']:.3g} "
          f"hbm_bw={m['hbm_bw']:.3g}")
    for family in obs_profile.FAMILIES:
        fam = rep["families"].get(family)
        if fam is None:
            print(f"  {family:<7} (no dispatches)")
            continue
        frac = fam["achieved_frac"]
        print(f"  {family:<7} calls={fam['calls']:<4d} "
              f"loop_execs={fam['loop_execs']:<6d} "
              f"measured={fam['measured_ms']:.3f}ms "
              f"modeled={fam['modeled_ms']:.3f}ms "
              f"frac={frac if frac is None else round(frac, 4)} "
              f"{'memory' if fam['mem_bound'] else 'compute'}-bound")
    missing = [f for f in obs_profile.FAMILIES
               if rep["families"].get(f, {}).get("measured_ms", 0.0) <= 0.0]
    if obs:
        obs.finish(n_fis=res.n_fis, families=len(rep["families"]))
    else:
        prof.disable()
    if missing:
        print(f"profile_demo: families without measured time: {missing}")
        return 1
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
