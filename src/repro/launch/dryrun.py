import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this lowers the real train/prefill/serve step with production
shardings on the 16×16 (single-pod, 256 chips) or 2×16×16 (multi-pod, 512
chips) mesh, compiles it, and records

  * ``memory_analysis()``  — per-device argument/temp/output bytes (the proof
    the cell fits 16 GB HBM),
  * ``cost_analysis()``    — per-device HLO FLOPs / bytes accessed,
  * the collective inventory parsed from the scheduled HLO (wire bytes),

into ``results/dryrun/<arch>__<shape>__<mesh>.json`` for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch granite-20b --shape train_4k --mesh single
  python -m repro.launch.dryrun --sweep [--mesh both] [--jobs 1]
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def run_cell(arch: str, shape_name: str, mesh_kind: str, overrides=None) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs.base import SHAPES, shapes_for
    from repro.configs.registry import get_config
    from repro.distributed import hlo as hlo_mod
    from repro.distributed import sharding as shd
    from repro.launch import input_specs as ispec
    from repro.launch.mesh import make_production_mesh
    from repro.models import model as M
    from repro.models import steps
    from repro.optim import adamw

    t0 = time.time()
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    if shape_name not in shapes_for(cfg):
        return {"skipped": f"{arch} is full-attention; long_500k not lowered"}
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    world = int(len(jax.devices()) if multi else 256)
    cfg_cell = ispec.shape_adjusted_config(cfg, shape)

    n_par = M.n_params(cfg_cell)
    big = n_par > 50e9
    small = n_par < 1e9  # pure-DP: TP gains nothing, batch spans both axes
    if not small and cfg_cell.moe and cfg_cell.moe.n_experts:
        # bound the dispatch buffers: ~8k tokens per chunk per data shard
        tc = 8192 * (world // 16)
        cfg_cell = dataclasses.replace(
            cfg_cell, moe=dataclasses.replace(cfg_cell.moe, token_chunk=tc)
        )
    rules = shd.default_rules(multi_pod=multi)
    zero1 = bool(os.environ.get("REPRO_ZERO1")) and not small and not big
    if zero1:
        # ZeRO-1: params TP-only (replicated across data) so the per-microbatch
        # FSDP all-gather disappears; optimizer state stays data-sharded.
        rules = shd.default_rules(multi_pod=multi, fsdp=False)
    if small:
        # pure-DP: replicate params; batch spans both mesh axes
        rules = {k: None for k in rules}
    abs_params = M.abstract(cfg_cell)
    ax = M.axes(cfg_cell)
    p_shard = shd.tree_shardings(abs_params, ax, mesh, rules)

    opt_cfg = adamw.AdamWConfig(state_dtype="bfloat16" if big else "float32")
    attn_chunk = None if shape.seq_len < 4096 else (
        1024 if shape.seq_len == 4096 else 2048
    )

    from jax.sharding import PartitionSpec as PS

    if small:
        b_axes = ("pod", "data", "model") if multi else ("data", "model")
    else:
        b_axes = ("pod", "data") if multi else ("data",)
    act_spec = PS(b_axes, None, None)

    def bspec(a):
        nshards = 1
        for ax_ in b_axes:
            nshards *= mesh.shape[ax_]
        if a.shape[0] % nshards == 0:
            return jax.NamedSharding(
                mesh, PS(b_axes, *([None] * (len(a.shape) - 1)))
            )
        return jax.NamedSharding(
            mesh, shd.data_spec(mesh, a.shape[0], len(a.shape))
        )

    accum = 1
    with jax.set_mesh(mesh):
        if shape.kind == "train":
            # MoE dispatch buffers and d6144 dense activations scale 1/accum;
            # policy tuned per family from the baseline sweep (§Perf).
            # microbatch must stay divisible by the data shards: B=256 over
            # 16 data shards caps accum at 16 (accum 32 ⇒ mb 8 unshardable —
            # measured: batch silently replicated, +20 GB on Jamba train).
            if small:
                accum = 1
            elif big or (cfg_cell.moe and cfg_cell.moe.n_experts):
                accum = 16
            elif n_par > 10e9:
                accum = 16
            else:
                accum = 8
            step_fn = steps.make_train_step(
                cfg_cell,
                opt_cfg,
                accum=accum,
                attn_chunk=attn_chunk,
                batch_spec=b_axes,
                act_spec=act_spec,
                accum_dtype=jnp.bfloat16 if big else jnp.float32,
            )
            abs_opt = jax.eval_shape(lambda p: adamw.init(p, opt_cfg), abs_params)
            if zero1:
                opt_rules = shd.default_rules(multi_pod=multi, fsdp=True)
                ov_shard = shd.tree_shardings(abs_params, ax, mesh, opt_rules)
            else:
                ov_shard = p_shard
            o_shard = adamw.AdamWState(
                step=shd.replicated(mesh),
                m=jax.tree.map(lambda a, s: s, abs_opt.m, ov_shard),
                v=jax.tree.map(lambda a, s: s, abs_opt.v, ov_shard),
            )
            batch = ispec.batch_specs(cfg_cell, shape)
            b_shard = jax.tree.map(bspec, batch)
            jf = jax.jit(
                step_fn,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None),
            )
            lowered = jf.lower(abs_params, abs_opt, batch)
        elif shape.kind == "prefill":
            step_fn = steps.make_prefill_step(
                cfg_cell, attn_chunk=attn_chunk, act_spec=act_spec
            )
            batch = ispec.batch_specs(cfg_cell, shape)
            b_shard = jax.tree.map(bspec, batch)
            jf = jax.jit(step_fn, in_shardings=(p_shard, b_shard))
            lowered = jf.lower(abs_params, batch)
        else:  # decode
            step_fn = steps.make_serve_step(cfg_cell)
            cache, tokens, pos = ispec.decode_specs(cfg, shape)
            c_shard = shd.cache_shardings(cache, mesh)
            t_shard = jax.NamedSharding(
                mesh, shd.data_spec(mesh, tokens.shape[0], 2)
            )
            jf = jax.jit(
                step_fn,
                in_shardings=(p_shard, c_shard, t_shard, shd.replicated(mesh)),
                out_shardings=(None, c_shard),
            )
            lowered = jf.lower(abs_params, cache, tokens, pos)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    ca = hlo_mod.normalize_cost_analysis(compiled.cost_analysis())
    txt = compiled.as_text()
    colls = hlo_mod.collective_summary(txt, world)

    per_dev_bytes = (
        ma.argument_size_in_bytes + ma.temp_size_in_bytes + ma.output_size_in_bytes
        - ma.alias_size_in_bytes
    )
    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "world": world,
        "n_params": n_par,
        "kind": shape.kind,
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "per_device_total_bytes": per_dev_bytes,
            "fits_16GB": bool(per_dev_bytes < 16e9),
        },
        "cost": {
            "flops_per_device": float(ca.get("flops", -1.0)),
            "bytes_accessed_per_device": float(ca.get("bytes accessed", -1.0)),
        },
        "collectives": colls,
        "timing": {"lower_s": t_lower, "compile_s": t_compile},
        "overrides": overrides or {},
        # Loop multipliers for cost reconstruction: XLA cost_analysis counts
        # while-loop bodies ONCE (verified), so the analytic roofline model in
        # benchmarks/roofline.py carries the trip counts explicitly.
        "loops": {
            "accum": accum if shape.kind == "train" else 1,
            "layer_scan_trips": (
                cfg_cell.n_layers // max(cfg_cell.attn_every, 1)
                if cfg_cell.family == "hybrid"
                else cfg_cell.n_layers
            ),
            "attn_chunk": attn_chunk,
        },
    }


def cell_filename(arch, shape, mesh_kind, tag=""):
    t = f"__{tag}" if tag else ""
    return RESULTS / f"{arch}__{shape}__{mesh_kind}{t}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--tag", default="", help="suffix for experiment variants")
    ap.add_argument("--overrides", default="", help="JSON dict of ModelConfig overrides")
    ap.add_argument("--timeout", type=int, default=3000)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    RESULTS.mkdir(parents=True, exist_ok=True)

    if args.sweep:
        from repro.configs.base import SHAPES, shapes_for
        from repro.configs.registry import all_archs, get_config

        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        cells = []
        for arch in all_archs():
            for shape in shapes_for(get_config(arch)):
                for mk in meshes:
                    cells.append((arch, shape, mk))
        print(f"sweeping {len(cells)} cells", flush=True)
        for arch, shape, mk in cells:
            out = cell_filename(arch, shape, mk, args.tag)
            if out.exists() and not args.force:
                print(f"SKIP {out.name} (exists)", flush=True)
                continue
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", arch, "--shape", shape, "--mesh", mk,
            ]
            if args.tag:
                cmd += ["--tag", args.tag]
            if args.overrides:
                cmd += ["--overrides", args.overrides]
            t0 = time.time()
            try:
                r = subprocess.run(cmd, timeout=args.timeout, capture_output=True, text=True)
                ok = r.returncode == 0 and out.exists()
                print(
                    f"{'OK  ' if ok else 'FAIL'} {arch} {shape} {mk} "
                    f"({time.time()-t0:.0f}s)",
                    flush=True,
                )
                if not ok:
                    (RESULTS / f"{arch}__{shape}__{mk}{'__'+args.tag if args.tag else ''}.err").write_text(
                        (r.stdout or "")[-4000:] + "\n---\n" + (r.stderr or "")[-8000:]
                    )
            except subprocess.TimeoutExpired:
                print(f"TIMEOUT {arch} {shape} {mk}", flush=True)
        return

    overrides = json.loads(args.overrides) if args.overrides else None
    try:
        rec = run_cell(args.arch, args.shape, args.mesh, overrides)
    except Exception:
        traceback.print_exc()
        sys.exit(1)
    out = cell_filename(args.arch, args.shape, args.mesh, args.tag)
    out.write_text(json.dumps(rec, indent=2))
    if "skipped" in rec:
        print(f"SKIPPED: {rec['skipped']}")
        return
    print(json.dumps({k: rec[k] for k in ("arch", "shape", "mesh", "memory", "cost", "timing")}, indent=2))
    print("collectives:", json.dumps(rec["collectives"]))


if __name__ == "__main__":
    main()
