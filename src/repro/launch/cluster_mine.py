"""Distributed mining launcher — the cluster executor end to end.

Runs planner → exchange → shard-mine → rebalance on N simulated host devices
(``--devices N`` forks CPU devices before jax imports, launch/host_devices.py)
or real mesh devices when present, and reports what a cluster operator needs:

  * per-phase time (plan / exchange / mine / merge),
  * load imbalance (observed DFS trips, max/mean) and the planner's
    estimation error (predicted vs observed load shares),
  * a speedup-vs-devices curve (``--curve 1,2,4``) in modeled makespan
    (Σ_r max_p trips — the barrier-aware metric) and wall time,
  * exact parity against single-device ``fimi.run`` (``--parity``; exits
    non-zero on any itemset/support mismatch — the CI gate uses this),
  * fault tolerance: ``--checkpoint DIR`` persists the inter-round state
    atomically after every round; ``--resume`` restarts from the latest
    checkpoint and the finished run is bit-exact with an uninterrupted
    one; ``--kill-after-round R`` dies (exit 0) right after round R's
    checkpoint — the fault-injection gate pairs it with ``--resume
    --parity``.

  python -m repro.launch.cluster_mine --db T2I0.048P50PL10TL16 --support 0.1 \
      -P 4 --devices 4 --parity [--curve 1,2,4] [--no-rebalance] \
      [--checkpoint DIR [--resume | --kill-after-round R]]
"""
from __future__ import annotations

import argparse
import sys

from repro.launch.host_devices import preparse_devices

preparse_devices()  # must run before anything imports jax

import dataclasses  # noqa: E402
import time  # noqa: E402

import numpy as np  # noqa: E402


def _skew_plan(plan):
    """Fault injection (``--force-skew``): pile every class onto shard 0.

    The estimated loads move with the assignment, so the skew is *planned*
    and predicted — pure imbalance, zero estimation error — which is
    exactly the shape the doctor's "imbalance dominates / rebalance not
    engaging" self-test needs to see.
    """
    assignment = np.zeros_like(plan.assignment)
    est_loads = np.zeros_like(plan.est_loads)
    if est_loads.shape[0]:
        est_loads[0] = float(np.sum(plan.est_loads))
    return dataclasses.replace(
        plan, assignment=assignment, est_loads=est_loads
    )


def run_once(dense, n_items, P, args, eclat_mod, fimi_mod, cluster,
             store=None):
    """One executor run at P miners; returns (result, wall seconds).

    With ``store`` set, the plan is computed **off disk** (Thm 6.1 sample
    via ``store.reader.sample_rows`` — bit-exact vs the in-RAM sample) and
    the data-plane shards are assembled block-by-block through the
    double-buffered reader; ``dense`` is only used otherwise.
    """
    import jax

    params = cluster.ClusterParams(
        planner=cluster.PlannerParams(
            min_support_rel=args.support,
            alpha=args.alpha,
            scheduler=args.scheduler,
            n_db_sample=min(2048, store.n_tx if store else dense.shape[0]),
            n_fi_sample=1024,
        ),
        eclat=eclat_mod.EclatConfig(
            max_out=1 << 15, max_stack=8192, frontier_size=args.frontier
        ),
        chunk=args.chunk or None,
        # --force-skew also pins rebalancing off: the injected skew must
        # survive to the report for the self-test to observe it
        rebalance=not (args.no_rebalance
                       or getattr(args, "force_skew", False)),
        skew_threshold=args.skew,
    )
    force_skew = getattr(args, "force_skew", False)
    key = jax.random.PRNGKey(args.seed)
    ck = dict(
        checkpoint_dir=getattr(args, "checkpoint", "") or None,
        resume=getattr(args, "resume", False),
        round_hook=_kill_hook(args),
        # the live line: sample-estimated completion + barrier-aware ETA +
        # worst straggler, refreshed at every round boundary
        progress_cb=lambda s: print("  " + s.line(), flush=True),
    )
    t0 = time.perf_counter()
    if store is not None:
        from repro.store.reader import to_device_shards

        plan = cluster.plan(store, None, params.planner, key, P=P)
        if force_skew:
            plan = _skew_plan(plan)
        t1 = time.perf_counter()
        shards = jax.block_until_ready(to_device_shards(store, P))
        t2 = time.perf_counter()
        res = cluster.execute(shards, n_items, params, key, plan=plan, **ck)
        # execute() saw a precomputed plan (plan≈0): charge the off-disk
        # planning + block-streamed assembly where they actually happened
        res.report.phase_ms["plan"] = (t1 - t0) * 1e3
        res.report.phase_ms["assemble"] = (t2 - t1) * 1e3
        res.report.republish_gauges()
    else:
        shards = fimi_mod.shard_db(dense, P)
        if force_skew:
            plan = _skew_plan(cluster.plan(shards, n_items,
                                           params.planner, key))
            t1 = time.perf_counter()
            res = cluster.execute(shards, n_items, params, key, plan=plan,
                                  **ck)
            res.report.phase_ms["plan"] = (t1 - t0) * 1e3
            res.report.republish_gauges()
        else:
            res = cluster.execute(shards, n_items, params, key, **ck)
    return res, time.perf_counter() - t0


def _kill_hook(args):
    """Round hook that simulates a mid-run death for the fault gate."""
    kill_at = getattr(args, "kill_after_round", -1)
    if kill_at < 0:
        return None

    def hook(r: int) -> None:
        if r >= kill_at:
            print(f"KILLED after round {r} (checkpoint saved) — "
                  f"rerun with --resume to finish")
            sys.exit(0)

    return hook


def main():
    import jax

    from repro import cluster
    from repro.core import eclat, fimi
    from repro.launch.data_source import resolve_source
    from repro.obs.session import add_obs_flags, start_session

    ap = argparse.ArgumentParser()
    ap.add_argument("--db", default="T2I0.048P50PL10TL16")
    ap.add_argument("--dataset", default="",
                    help="mine a FIMI .dat file (ingested into a store)")
    ap.add_argument("--store", default="",
                    help="mine out-of-core from this TxStore dir "
                         "(spilled from --db when empty)")
    ap.add_argument("--blocktx", type=int, default=256,
                    help="store block size (rows) when spilling/ingesting")
    ap.add_argument("--support", type=float, default=0.1)
    ap.add_argument("-P", type=int, default=4)
    ap.add_argument("--devices", type=int, default=0,
                    help="fork N simulated host devices (before jax init)")
    ap.add_argument("--scheduler", default="auto",
                    choices=["auto", "lpt", "repl_min"])
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--frontier", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=0,
                    help="classes per shard per round (0 = auto)")
    ap.add_argument("--skew", type=float, default=1.25,
                    help="rebalance when remaining max/mean exceeds this")
    ap.add_argument("--no-rebalance", action="store_true")
    ap.add_argument("--force-skew", action="store_true", dest="force_skew",
                    help="fault injection: assign every equivalence class "
                         "to shard 0 and disable rebalancing — the doctor's "
                         "'imbalance dominates' self-test")
    ap.add_argument("--curve", default="",
                    help="comma-separated device counts for a speedup curve")
    ap.add_argument("--parity", action="store_true",
                    help="verify exact FI parity vs single-device fimi.run")
    ap.add_argument("--checkpoint", default="",
                    help="persist inter-round state to this dir after "
                         "every round (atomic, CRC32C-guarded)")
    ap.add_argument("--resume", action="store_true",
                    help="restart from the latest checkpoint in "
                         "--checkpoint (bit-exact with an unbroken run)")
    ap.add_argument("--kill-after-round", type=int, default=-1,
                    dest="kill_after_round", metavar="R",
                    help="simulate a crash: exit 0 right after round R's "
                         "checkpoint is saved (fault-injection gate)")
    ap.add_argument("--seed", type=int, default=0)
    add_obs_flags(ap)
    args = ap.parse_args()
    obs = start_session(args, "cluster_mine")

    store, dense, src = resolve_source(
        args.dataset, args.store, args.db,
        block_tx=args.blocktx, seed=args.seed,
    )
    n_tx = store.n_tx if store is not None else dense.shape[0]
    n_items = store.n_items if store is not None else dense.shape[1]
    print(
        f"{src} |D|={n_tx} |B|={n_items} sup={args.support} "
        f"P={args.P} devices={len(jax.devices())} "
        f"rebalance={not args.no_rebalance} scheduler={args.scheduler}"
    )
    if store is not None:
        print(f"store: {store.n_blocks} blocks x <= {store.block_tx} tx "
              f"({store.total_bytes} packed bytes; plan sampled off-disk)")

    res, wall = run_once(dense, n_items, args.P, args, eclat, fimi, cluster,
                         store=store)
    rep, plan = res.report, res.plan
    print(f"|F| = {res.table.n_fis}  in {wall:.2f}s  backend={rep.backend}  "
          f"rounds={rep.n_rounds}  scheduler={plan.scheduler_used}")
    print("per-phase ms: "
          + "  ".join(f"{k}={v:.0f}" for k, v in rep.phase_ms.items()))
    print(f"classes={len(plan.classes)}  "
          f"volume lpt={plan.lpt_volume:.0f} repl_min={plan.repl_volume:.0f}  "
          f"replication/round="
          f"{np.mean([r.replication for r in rep.rounds]):.2f}")
    print(f"load: observed trips={rep.observed_loads.astype(int).tolist()}  "
          f"imbalance={rep.imbalance:.2f}  "
          f"estimation_error={rep.estimation_error():.3f}  "
          f"donations={len(rep.donations)}")
    if obs:
        for r in rep.rounds:
            obs.event(
                "round", index=r.round_index,
                classes_mined=r.classes_mined,
                work_iters=r.work_iters.tolist(),
                replication=r.replication,
                donations=len(r.donations),
            )
        obs.finish(
            n_fis=res.table.n_fis, mine_wall_s=wall, rounds=rep.n_rounds,
            backend=rep.backend, imbalance=rep.imbalance,
            makespan_trips=rep.makespan_trips,
            estimation_error=rep.estimation_error(),
        )

    if args.curve:
        counts = [int(c) for c in args.curve.split(",") if c]
        base_makespan = None
        print("speedup curve (modeled makespan = sum of per-round max trips):")
        for Pc in counts:
            r, w = run_once(dense, n_items, Pc, args, eclat, fimi, cluster,
                            store=store)
            mk = r.report.makespan_trips
            if base_makespan is None:
                base_makespan = mk
            print(f"  P={Pc:<3d} makespan={mk:>8.0f} trips  "
                  f"speedup={base_makespan / max(mk, 1):.2f}x  wall={w:.2f}s  "
                  f"imbalance={r.report.imbalance:.2f}")

    if args.parity:
        if dense is None:
            dense = store.to_dense()  # O(n_tx) host — parity reference only
        fp = fimi.FimiParams(
            min_support_rel=args.support,
            n_db_sample=min(2048, dense.shape[0]), n_fi_sample=1024,
            eclat=eclat.EclatConfig(
                max_out=1 << 15, max_stack=8192, frontier_size=args.frontier
            ),
        )
        ref = fimi.run(
            fimi.shard_db(dense, 1), n_items, fp, jax.random.PRNGKey(args.seed),
            materialize=True,
        )
        got = res.table.to_dict()
        if got != ref.fi_dict:
            only_got = set(got) - set(ref.fi_dict)
            only_ref = set(ref.fi_dict) - set(got)
            diff_supp = {
                k for k in set(got) & set(ref.fi_dict)
                if got[k] != ref.fi_dict[k]
            }
            print(f"PARITY FAIL: +{len(only_got)} -{len(only_ref)} "
                  f"support-mismatch={len(diff_supp)}")
            sys.exit(1)
        print(f"parity vs single-device fimi.run: OK "
              f"({len(got)} itemsets, bit-exact supports)")


if __name__ == "__main__":
    main()
