"""Batched serving driver: prefill + incremental decode with a KV cache.

  python -m repro.launch.serve --arch mamba2-1.3b --smoke --batch 4 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(0)
    params = M.init(cfg, key)
    B, P, G = args.batch, args.prompt_len, args.gen
    max_len = P + G

    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab)
    cache = M.init_cache(cfg, B, max_len, jnp.float32)
    if cfg.family == "encdec":
        frames = jax.random.normal(key, (B, cfg.enc_context, cfg.d_model), jnp.float32)
        cache = M.encode(cfg, params, frames, cache)

    step = jax.jit(lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos))

    # prefill by stepping (simple serving path; batched prefill kernel exists
    # as make_prefill_step for the bulk case)
    t0 = time.time()
    logits = None
    for t in range(P):
        logits, cache = step(params, cache, prompts[:, t : t + 1], jnp.asarray(t, jnp.int32))
    out = []
    tok = jnp.argmax(logits[:, -1:, : cfg.vocab], axis=-1).astype(jnp.int32)
    for t in range(P, P + G):
        out.append(np.asarray(tok)[:, 0])
        logits, cache = step(params, cache, tok, jnp.asarray(t, jnp.int32))
        if args.temperature > 0:
            key2 = jax.random.fold_in(key, t)
            tok = jax.random.categorical(
                key2, logits[:, -1, : cfg.vocab] / args.temperature
            )[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1:, : cfg.vocab], axis=-1).astype(jnp.int32)
    dt = time.time() - t0
    gen = np.stack(out, axis=1)
    print(f"arch={cfg.name} generated {gen.shape} tokens in {dt:.2f}s "
          f"({B*(P+G)/dt:.1f} tok/s incl. prefill)")
    print("first sequence:", gen[0][:16], "...")


if __name__ == "__main__":
    main()
