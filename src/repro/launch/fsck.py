"""Store integrity checker CLI — scan, repair, or quarantine a TxStore.

Read-only by default: classifies every damage class the failure model
names (missing / truncated / bit-flip / stale-manifest / orphan) and exits
non-zero if anything is wrong, so it slots into cron jobs and CI the way a
filesystem fsck does.  ``--repair`` adopts the contiguous valid blocks a
crashed writer left unindexed and deletes torn ones; ``--quarantine`` also
moves damaged indexed blocks into ``quarantine/`` and recounts the
manifest exactly from the survivors.  ``--shallow`` skips payload reads
(stat-level checks only — what ``StoreWriter(resume=True)`` runs).

This is a pure host tool: it never imports jax, so it runs on storage
hosts that have no accelerator stack at all.

  python -m repro.launch.fsck /data/txstore            # scan, exit 1 if bad
  python -m repro.launch.fsck /data/txstore --repair   # + adopt crash residue
  python -m repro.launch.fsck /data/txstore --quarantine  # + salvage
"""
from __future__ import annotations

import argparse
import sys


def main():
    from repro.store.fsck import fsck

    ap = argparse.ArgumentParser(
        description="check / repair an on-disk transaction store"
    )
    ap.add_argument("store", help="TxStore directory (holds manifest.json)")
    ap.add_argument("--repair", action="store_true",
                    help="adopt a crashed writer's unindexed blocks, delete "
                         "torn ones")
    ap.add_argument("--quarantine", action="store_true",
                    help="also move damaged indexed blocks to quarantine/ "
                         "and recount the manifest (implies --repair)")
    ap.add_argument("--shallow", action="store_true",
                    help="stat-level checks only (no payload reads/CRC)")
    args = ap.parse_args()

    try:
        rep = fsck(
            args.store,
            repair=args.repair,
            quarantine=args.quarantine,
            deep=not args.shallow,
        )
    except FileNotFoundError as e:
        print(f"fsck: no store at {args.store}: {e}", file=sys.stderr)
        sys.exit(2)
    except ValueError as e:
        print(f"fsck: unreadable manifest: {e}", file=sys.stderr)
        sys.exit(2)

    print(rep.summary())
    if rep.damages and not rep.clean:
        print("fsck: damage remains (re-run with --repair / --quarantine "
              "to act on it)", file=sys.stderr)
        sys.exit(1)
    if rep.damages:
        print(f"fsck: {len(rep.damages)} finding(s) handled; store is "
              f"consistent ({rep.n_blocks} blocks, {rep.n_tx} tx)")
    sys.exit(0)


if __name__ == "__main__":
    main()
