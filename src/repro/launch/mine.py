"""Distributed Parallel-FIMI launcher (the paper's production entry point).

Runs the full four-phase method over real devices when available (shard_map
over a 1-D miner mesh) or P virtual miners on one device (vmap).  On a TPU
pod the miner axis maps onto the 256 chips of `make_production_mesh` row- or
column-major; on this container use --devices to fork virtual CPU devices
(set before jax import, hence the flag is handled in __main__ preamble).

  python -m repro.launch.mine --db T2I0.048P50PL10TL16 --support 0.1 \
      --variant reservoir -P 8 [--devices 8]
"""
from __future__ import annotations

import argparse

from repro.launch.host_devices import preparse_devices

preparse_devices()  # must run before anything imports jax

import time  # noqa: E402

import numpy as np  # noqa: E402


def main():
    import jax

    from repro.core import eclat, fimi
    from repro.data.ibm_gen import generate_dense, params_from_name
    from repro.launch.mesh import make_miner_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument("--db", default="T2I0.048P50PL10TL16")
    ap.add_argument("--support", type=float, default=0.1)
    ap.add_argument("--variant", default="reservoir",
                    choices=["seq", "par", "reservoir"])
    ap.add_argument("-P", type=int, default=4)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--scheduler", default="lpt", choices=["lpt", "repl_min"])
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--frontier", type=int, default=16,
                    help="DFS nodes mined per while_loop trip (K)")
    args = ap.parse_args()

    dense = generate_dense(params_from_name(args.db, seed=args.seed))
    n_items = dense.shape[1]
    shards = fimi.shard_db(dense, args.P)
    params = fimi.FimiParams(
        variant=args.variant, min_support_rel=args.support,
        alpha=args.alpha, scheduler=args.scheduler,
        n_db_sample=min(2048, dense.shape[0]), n_fi_sample=1024,
        eclat=eclat.EclatConfig(
            max_out=1 << 15, max_stack=8192, frontier_size=args.frontier
        ),
    )
    use_shard_map = len(jax.devices()) >= args.P
    spmd = fimi.shard_map_spmd if use_shard_map else fimi.vmap_spmd
    mesh = make_miner_mesh(args.P) if use_shard_map else None
    print(
        f"db={args.db} |D|={dense.shape[0]} |B|={n_items} sup={args.support} "
        f"variant={args.variant} P={args.P} frontier={args.frontier} "
        f"backend={'shard_map' if use_shard_map else 'vmap'}"
    )
    t0 = time.time()
    res = fimi.run(
        shards, n_items, params, jax.random.PRNGKey(args.seed),
        spmd=spmd, mesh=mesh,
    )
    dt = time.time() - t0
    w = res.work_iters.astype(float)
    print(f"|F| = {res.n_fis}  in {dt:.2f}s")
    print(f"classes={len(res.classes)}  replication={res.replication:.2f}  "
          f"exchange_overflow={res.exchange_overflow}")
    print(f"per-miner work (DFS trips): {res.work_iters.tolist()}  "
          f"balance={w.max()/max(w.mean(),1):.2f}")


if __name__ == "__main__":
    main()
