"""Distributed Parallel-FIMI launcher (the paper's production entry point).

Runs the full four-phase method over real devices when available (shard_map
over a 1-D miner mesh) or P virtual miners on one device (vmap).  On a TPU
pod the miner axis maps onto the 256 chips of `make_production_mesh` row- or
column-major; on this container use --devices to fork virtual CPU devices
(set before jax import, hence the flag is handled in __main__ preamble).

Three data sources:

  * default          — generate the --db IBM database in RAM (seed behavior);
  * --store DIR      — mine **out of core** from an on-disk TxStore (spilled
                       there block-by-block from --db first if DIR is empty);
  * --dataset F.dat  — ingest a standard FIMI file into a store, then mine it
                       out of core (--store names the store dir, else a temp).

--parity is the exactness gate: mine the same database through the dense
in-RAM path and require the two FITables to match bit for bit; exits
non-zero on any difference (CI runs this on a store larger than the host
block budget).

  python -m repro.launch.mine --db T2I0.048P50PL10TL16 --support 0.1 \
      --variant reservoir -P 8 [--devices 8]
  python -m repro.launch.mine --db T2I0.048P50PL10TL16 --support 0.1 \
      --store /tmp/txstore --blocktx 256 --parity
  python -m repro.launch.mine --dataset examples/retail_tiny.dat \
      --support 0.2 -P 2 --parity
"""
from __future__ import annotations

import argparse
import sys

from repro.launch.host_devices import preparse_devices

preparse_devices()  # must run before anything imports jax

import time  # noqa: E402


def main():
    import jax

    from repro.core import eclat, fimi
    from repro.launch.data_source import resolve_source
    from repro.launch.mesh import make_miner_mesh
    from repro.obs.session import add_obs_flags, start_session
    from repro.store.reader import BlockReader

    ap = argparse.ArgumentParser()
    ap.add_argument("--db", default="T2I0.048P50PL10TL16")
    ap.add_argument("--dataset", default="",
                    help="mine a FIMI .dat file (ingested into a store)")
    ap.add_argument("--store", default="",
                    help="mine out-of-core from this TxStore dir "
                         "(spilled from --db when empty)")
    ap.add_argument("--blocktx", type=int, default=256,
                    help="store block size (rows) when spilling/ingesting")
    ap.add_argument("--budget-blocks", type=int, default=2,
                    help="host block budget of the streamed reader")
    ap.add_argument("--parity", action="store_true",
                    help="verify bit-exact FITable vs the dense in-RAM path")
    ap.add_argument("--support", type=float, default=0.1)
    ap.add_argument("--variant", default="reservoir",
                    choices=["seq", "par", "reservoir"])
    ap.add_argument("-P", type=int, default=4)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--scheduler", default="lpt", choices=["lpt", "repl_min"])
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--frontier", type=int, default=16,
                    help="DFS nodes mined per while_loop trip (K)")
    add_obs_flags(ap)
    args = ap.parse_args()
    obs = start_session(args, "mine")

    # ---- resolve the data source -------------------------------------------
    store, dense, src = resolve_source(
        args.dataset, args.store, args.db,
        block_tx=args.blocktx, seed=args.seed,
    )
    if store is not None:
        n_tx, n_items = store.n_tx, store.n_items
    else:
        n_tx, n_items = dense.shape

    params = fimi.FimiParams(
        variant=args.variant, min_support_rel=args.support,
        alpha=args.alpha, scheduler=args.scheduler,
        n_db_sample=min(2048, n_tx), n_fi_sample=1024,
        eclat=eclat.EclatConfig(
            max_out=1 << 15, max_stack=8192, frontier_size=args.frontier
        ),
    )
    use_shard_map = len(jax.devices()) >= args.P
    spmd = fimi.shard_map_spmd if use_shard_map else fimi.vmap_spmd
    mesh = make_miner_mesh(args.P) if use_shard_map else None
    print(
        f"{src} |D|={n_tx} |B|={n_items} sup={args.support} "
        f"variant={args.variant} P={args.P} frontier={args.frontier} "
        f"backend={'shard_map' if use_shard_map else 'vmap'}"
    )
    if store is not None:
        budget = args.budget_blocks * max(store.max_block_bytes, 1)
        print(
            f"store: {store.n_blocks} blocks x <= {store.block_tx} tx "
            f"({store.total_bytes} packed bytes on disk)  "
            f"host budget = {args.budget_blocks} blocks ({budget} bytes)"
        )

    t0 = time.time()
    key = jax.random.PRNGKey(args.seed)
    if store is not None:
        # the mine's own block stream is the residency measurement: fimi.run
        # assembles the shards through this reader (one pass, no extra I/O)
        reader = BlockReader(store, args.budget_blocks)
        res = fimi.run(
            store, None, params, key, spmd=spmd, mesh=mesh,
            materialize=args.parity, P=args.P, reader=reader,
        )
    else:
        res = fimi.run(
            fimi.shard_db(dense, args.P), n_items, params, key,
            spmd=spmd, mesh=mesh, materialize=args.parity,
        )
    dt = time.time() - t0
    w = res.work_iters.astype(float)
    print(f"|F| = {res.n_fis}  in {dt:.2f}s")
    print(f"classes={len(res.classes)}  replication={res.replication:.2f}  "
          f"exchange_overflow={res.exchange_overflow}")
    print(f"per-miner work (DFS trips): {res.work_iters.tolist()}  "
          f"balance={w.max()/max(w.mean(),1):.2f}")
    if res.progress is not None:
        print(res.progress.line() + "  stragglers="
              + ",".join(f"{s:.2f}" for s in res.progress.stragglers))
    if store is not None:
        print(f"streamed host high-water: {reader.peak_host_bytes} bytes "
              f"(budget {reader.budget_bytes})")
    if obs:
        obs.event("mined", n_fis=res.n_fis, wall_s=dt,
                  work_iters=res.work_iters.tolist())
        obs.finish(n_fis=res.n_fis, n_tx=n_tx, n_items=n_items,
                   mine_wall_s=dt, replication=res.replication)

    # ---- parity gate: out-of-core result == dense in-RAM result ------------
    if args.parity:
        if store is None:
            print("--parity needs --store or --dataset (nothing to compare)")
            sys.exit(2)
        if store.total_bytes <= reader.budget_bytes:
            print(f"note: store ({store.total_bytes}B) fits the host budget "
                  f"({reader.budget_bytes}B); gate still exact but not "
                  f"out-of-core — use a bigger --db or smaller --blocktx")
        dense_ref = store.to_dense()  # O(n_tx) host — the gate's reference
        ref = fimi.run(
            fimi.shard_db(dense_ref, args.P), n_items, params, key,
            spmd=spmd, mesh=mesh, materialize=True,
        )
        got, want = res.fi_dict, ref.fi_dict
        if got != want:
            only_got = set(got) - set(want)
            only_ref = set(want) - set(got)
            diff = {k for k in set(got) & set(want) if got[k] != want[k]}
            print(f"PARITY FAIL: +{len(only_got)} -{len(only_ref)} "
                  f"support-mismatch={len(diff)}")
            sys.exit(1)
        print(f"parity vs dense in-RAM fimi.run: OK ({len(got)} itemsets, "
              f"bit-exact supports; store {store.total_bytes}B > "
              f"host budget {reader.budget_bytes}B)"
              if store.total_bytes > reader.budget_bytes else
              f"parity vs dense in-RAM fimi.run: OK ({len(got)} itemsets)")


if __name__ == "__main__":
    main()
