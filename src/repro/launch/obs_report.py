"""Run-record report CLI: summarize, diff, and gate on observability output.

Reads the run records :mod:`repro.obs.runlog` writes (``--trace`` /
``--metrics`` on any launcher) and the ``BENCH_*.json`` files the benchmark
suite writes.  **jax-free by construction** (the fsck layering rule): this
tool must load anywhere the JSON does — CI report steps, a laptop without
the accelerator stack, a post-mortem container.

Subcommands::

  summary RUN_DIR [--format text|json|markdown]
      Digest one run: manifest identity, driver event timeline, the metric
      families, span time by name.  ``--format json`` emits the full digest
      as machine-readable JSON; ``--format markdown`` renders tables for CI
      step summaries.

  diff OLD_RUN NEW_RUN [--threshold 0.2]
      Compare two runs' time-like metrics (wall_s, */phase_ms/*, *_ms/*_s
      gauges, latency-histogram p95s).  Prints old → new with the ratio and
      **exits 1** when any time-like metric regressed by more than the
      threshold (0.2 = +20%).  Counters/gauges that are not time-like are
      shown for context but never gate.

  baseline --bench BENCH.json [...] [--threshold 0.05] [RUN_DIR]
      Gate on benchmark baselines: every ratio-type key in each BENCH file
      (``*_overhead*``, ``*_slowdown*`` — measured-vs-baseline ratios where
      1.0 = parity) must stay <= 1 + threshold; ``--match SUBSTR`` narrows
      the gated keys (e.g. ``--match overhead`` for the parity-type gates
      only).  With a RUN_DIR, metrics sharing a flattened name with a bench
      key are also compared under the same threshold.  Exits 1 on any
      regression.

  inject-slowdown SRC_RUN DST_RUN --factor 1.3 [--match SUBSTR]
      Copy a run record with every time-like quantity scaled by ``factor``
      (wall_s, *_ms/*_s gauges and histograms, trace durations); ``--match``
      narrows the scaling to names containing a substring.  The
      deterministic partner for testing the diff gate: ``diff SRC DST``
      must fail and ``diff SRC SRC`` must pass, with no timing flakiness.
      (``--match compute_ms`` is the kernels --check-model failing partner.)

  kernels RUN_DIR [--require bitmap,multi,...] [--check-model]
      Render the kernel profiler's attribution (``--profile`` runs):
      measured vs modeled time, achieved roofline fraction, and the
      memory-/compute-bound verdict per family.  ``--require`` exits 1
      unless every named family has attribution; ``--check-model``
      recomputes each roofline term from the published flop/byte/machine
      gauges and exits 1 on mismatch.

  history [--history BENCH_HISTORY.jsonl] [--suite S] [--key SUBSTR]
          [--format text|markdown]
      Render per-key trends from the perf ledger (newest last, with the
      git SHA each row was stamped with).

  regress [--history BENCH_HISTORY.jsonl] [--threshold 0.25] [--window 8]
          [--direction KEY=up|down ...] [--format text|markdown]
      Gate the newest ledger row: exit 1 when any directional key degraded
      past the threshold vs its trailing median (``repro.obs.perfdb``).
      ``--degrade F`` synthetically worsens the newest values first — the
      deterministic proof in tools/check.sh that the gate can fire.
      ``--direction`` overrides the name-inferred better-direction per key.

  critpath RUN_DIR [--top N] [--path] [--format text|json]
      Reconstruct the span DAG of a traced run and print the critical-path
      table: on-path exclusive self-time by span name, straggler lanes and
      all (``repro.obs.critpath``).

  doctor RUN_DIR [--history LEDGER] [--format text|json|markdown] [--gate]
      The performance doctor: critical path + speedup-loss waterfall +
      ranked findings with evidence keys and remediation hints
      (``repro.obs.doctor``).  ``--gate`` exits 1 when any severity>=error
      finding fires — the CI hook.

Exit codes: 0 ok, 1 regression detected, 2 usage / unreadable record.
"""
from __future__ import annotations

import argparse
import copy
import json
import os
import sys
from typing import Dict, Iterable, List, Optional, Tuple

from repro.obs import critpath, doctor, perfdb, runlog

#: gauge/summary names treated as durations (the regression-gated set)
_TIME_SUFFIXES = ("_ms", "_s", "wall_s")


def _is_time_like(name: str) -> bool:
    short = name.rsplit("/", 1)[-1]
    return (
        short.endswith(_TIME_SUFFIXES)
        or "/phase_ms/" in name
        or "stall" in short
        or "latency" in short
    )


def _time_metrics(run: dict) -> Dict[str, float]:
    """Flatten one run's time-like scalars: summary + gauges + hist p95s."""
    out: Dict[str, float] = {}
    man = run.get("manifest") or {}
    for k, v in man.items():
        if isinstance(v, (int, float)) and _is_time_like(str(k)):
            out[str(k)] = float(v)
    m = run.get("metrics") or {}
    for name, v in (m.get("gauges") or {}).items():
        if isinstance(v, (int, float)) and _is_time_like(name):
            out[name] = float(v)
    for name, summ in (m.get("histograms") or {}).items():
        if _is_time_like(name) and isinstance(summ, dict):
            p95 = summ.get("p95")
            if isinstance(p95, (int, float)):
                out[f"{name}:p95"] = float(p95)
    return out


def _load(run_dir: str) -> dict:
    try:
        return runlog.load_run(run_dir)
    except (FileNotFoundError, json.JSONDecodeError) as e:
        print(f"obs_report: cannot read run record at {run_dir}: {e}",
              file=sys.stderr)
        sys.exit(2)


# ---------------------------------------------------------------------------
# summary
# ---------------------------------------------------------------------------


def _span_totals(trace: Optional[dict]) -> List[dict]:
    """Per-name span rows — inclusive total AND exclusive self-time.

    One implementation: :func:`repro.obs.critpath.exclusive_totals` over
    the reconstructed span DAG, so long parents (``fimi/phase4_mine``)
    stop masking the children nested inside them.  Longest-self first.
    """
    dag = critpath.build(trace)
    if dag is None:
        return []
    rows = [
        {"name": name, "total_ms": r["total_ms"], "self_ms": r["self_ms"],
         "count": int(r["count"])}
        for name, r in critpath.exclusive_totals(dag).items()
    ]
    rows.sort(key=lambda r: (-r["self_ms"], -r["total_ms"]))
    return rows


def _summary_digest(run: dict) -> dict:
    """The summary's content as one plain dict (every format renders it)."""
    man = run["manifest"]
    extras = {
        k: v for k, v in man.items()
        if k not in ("name", "config", "argv", "git_sha", "started_unix",
                     "backend", "device_kind", "n_devices", "wall_s")
    }
    m = run["metrics"] or {}
    return {
        "name": man.get("name"),
        "run_dir": run["run_dir"],
        "git_sha": man.get("git_sha"),
        "backend": man.get("backend"),
        "n_devices": man.get("n_devices"),
        "device_kind": man.get("device_kind"),
        "wall_s": man.get("wall_s"),
        "summary": extras,
        "events": run["events"],
        "counters": m.get("counters") or {},
        "gauges": m.get("gauges") or {},
        "histograms": m.get("histograms") or {},
        "spans": _span_totals(run["trace"]),
    }


def _num(v) -> str:
    return f"{v:.4g}" if isinstance(v, (int, float)) else str(v)


def _render_markdown(d: dict, max_events: int, max_gauges: int) -> str:
    """A CI-step-summary-friendly digest (GitHub-flavored markdown)."""
    out = [f"### run `{d['name']}`",
           "",
           f"- dir: `{d['run_dir']}`  git: `{str(d['git_sha'])[:12]}`  "
           f"backend: {d['backend']} ×{d['n_devices']} ({d['device_kind']})",
           f"- wall_s: {_num(d['wall_s']) if d['wall_s'] is not None else '<unfinished>'}"]
    if d["summary"]:
        out.append("- " + "  ".join(f"{k}={_num(v)}"
                                    for k, v in d["summary"].items()))
    if d["events"]:
        out += ["", f"#### events ({len(d['events'])})", "",
                "| t (s) | kind | fields |", "|---|---|---|"]
        for ev in d["events"][:max_events]:
            rest = {k: v for k, v in ev.items() if k not in ("t", "kind")}
            out.append(f"| {ev['t']:.3f} | {ev['kind']} | "
                       + "  ".join(f"{k}={_num(v)}"
                                   for k, v in rest.items()) + " |")
    if d["counters"]:
        out += ["", "#### counters", "", "| name | value |", "|---|---|"]
        out += [f"| {k} | {v} |" for k, v in sorted(d["counters"].items())]
    if d["gauges"]:
        out += ["", "#### gauges", "", "| name | value |", "|---|---|"]
        out += [f"| {k} | {_num(v)} |"
                for k, v in sorted(d["gauges"].items())[:max_gauges]]
    if d["histograms"]:
        out += ["", "#### histograms", "",
                "| name | n | mean | p50 | p95 | p99 | max |",
                "|---|---|---|---|---|---|---|"]
        out += [
            f"| {k} | {s['count']} | {_num(s['mean'])} | {_num(s['p50'])} "
            f"| {_num(s['p95'])} | {_num(s['p99'])} | {_num(s['max'])} |"
            for k, s in sorted(d["histograms"].items())
        ]
    if d["spans"]:
        out += ["", "#### trace spans", "",
                "| span | total ms | self ms | count |", "|---|---|---|---|"]
        out += [f"| {s['name']} | {s['total_ms']:.2f} | {s['self_ms']:.2f} "
                f"| {s['count']} |"
                for s in d["spans"][:12]]
        out.append(f"\n(trace: `{d['run_dir']}/trace.json` — loads in "
                   f"[ui.perfetto.dev](https://ui.perfetto.dev))")
    return "\n".join(out)


def cmd_summary(args) -> int:
    run = _load(args.run)
    d = _summary_digest(run)
    if args.format == "json":
        print(json.dumps(d, indent=2))
        return 0
    if args.format == "markdown":
        print(_render_markdown(d, args.events, args.gauges))
        return 0
    print(f"run: {d['name']}  dir={d['run_dir']}")
    print(f"  git={str(d['git_sha'])[:12]}  "
          f"backend={d['backend']} x{d['n_devices']} "
          f"({d['device_kind']})")
    wall = d["wall_s"]
    print(f"  wall_s={wall:.3f}" if isinstance(wall, (int, float))
          else "  wall_s=<unfinished>")
    if d["summary"]:
        print("  summary: "
              + "  ".join(f"{k}={v}" for k, v in d["summary"].items()))
    if d["events"]:
        print(f"events ({len(d['events'])}):")
        for ev in d["events"][: args.events]:
            rest = {k: v for k, v in ev.items() if k not in ("t", "kind")}
            print(f"  t={ev['t']:>8.3f}s  {ev['kind']:<12} "
                  + " ".join(f"{k}={v}" for k, v in rest.items()))
        if len(d["events"]) > args.events:
            print(f"  ... {len(d['events']) - args.events} more")
    if d["counters"]:
        print("counters:")
        for k, v in sorted(d["counters"].items()):
            print(f"  {k} = {v}")
    if d["gauges"]:
        print(f"gauges: {len(d['gauges'])} "
              f"(use diff/baseline for comparisons)")
        for k, v in sorted(d["gauges"].items())[: args.gauges]:
            print(f"  {k} = {v:.6g}")
        if len(d["gauges"]) > args.gauges:
            print(f"  ... {len(d['gauges']) - args.gauges} more")
    if d["histograms"]:
        print("histograms:")
        for k, s in sorted(d["histograms"].items()):
            print(f"  {k}: n={s['count']} mean={s['mean']:.4g} "
                  f"p50={s['p50']:.4g} p95={s['p95']:.4g} max={s['max']:.4g}")
    if d["spans"]:
        print("trace spans (inclusive total / exclusive self ms):")
        for s in d["spans"][:12]:
            print(f"  {s['name']:<28} {s['total_ms']:>10.2f}ms  "
                  f"self {s['self_ms']:>10.2f}ms  x{s['count']}")
        print(f"  -> load {d['run_dir']}/trace.json in "
              f"https://ui.perfetto.dev or chrome://tracing")
    return 0


# ---------------------------------------------------------------------------
# diff
# ---------------------------------------------------------------------------


def cmd_diff(args) -> int:
    old, new = _load(args.old), _load(args.new)
    t_old, t_new = _time_metrics(old), _time_metrics(new)
    shared = sorted(set(t_old) & set(t_new))
    only_old = sorted(set(t_old) - set(t_new))
    only_new = sorted(set(t_new) - set(t_old))
    if not shared:
        print("obs_report diff: no shared time-like metrics "
              "(were both runs recorded with --metrics or --trace?)",
              file=sys.stderr)
        for name in only_old:
            print(f"  only in {args.old}: {name}", file=sys.stderr)
        for name in only_new:
            print(f"  only in {args.new}: {name}", file=sys.stderr)
        return 2
    regressions: List[str] = []
    print(f"diff {args.old} -> {args.new}  (threshold +{args.threshold:.0%})")
    for name in shared:
        a, b = t_old[name], t_new[name]
        if a <= args.min_seconds_ignore and b <= args.min_seconds_ignore:
            continue  # sub-noise-floor timings cannot gate
        ratio = b / a if a > 0 else float("inf")
        worse = b > a * (1.0 + args.threshold)
        flag = "  << REGRESSION" if worse else ""
        print(f"  {name:<36} {a:>12.4f} -> {b:>12.4f}  "
              f"x{ratio:.2f}{flag}")
        if worse:
            regressions.append(name)
    # non-time context: counter deltas worth a glance (never gate)
    c_old = (old.get("metrics") or {}).get("counters") or {}
    c_new = (new.get("metrics") or {}).get("counters") or {}
    changed = {
        k: (c_old[k], c_new[k])
        for k in set(c_old) & set(c_new) if c_old[k] != c_new[k]
    }
    if changed:
        print("counter deltas (context only):")
        for k, (a, b) in sorted(changed.items()):
            print(f"  {k:<36} {a} -> {b}")
    # metrics present on one side only: a run that silently stopped (or
    # started) recording a phase is itself a finding — never hide it
    if only_old or only_new:
        print(f"metrics in one run only ({len(only_old) + len(only_new)}, "
              f"not gated):")
        for name in only_old:
            print(f"  {name:<36} {t_old[name]:>12.4f} -> (missing in new)")
        for name in only_new:
            print(f"  {name:<36} (missing in old) -> {t_new[name]:>12.4f}")
    if regressions:
        print(f"REGRESSION: {len(regressions)} time-like metric(s) slowed "
              f"beyond +{args.threshold:.0%}: {', '.join(regressions)}")
        return 1
    print("ok: no time-like metric regressed beyond the threshold")
    return 0


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------


def _flatten(obj, prefix: str = "") -> Dict[str, float]:
    out: Dict[str, float] = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(_flatten(v, f"{prefix}{k}."))
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            name = v.get("name") if isinstance(v, dict) else None
            out.update(_flatten(v, f"{prefix}{name or i}."))
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        out[prefix[:-1]] = float(obj)
    return out


def _ratio_gates(flat: Dict[str, float],
                 match: Optional[List[str]] = None) -> Dict[str, float]:
    """Keys whose value is a measured/baseline ratio (1.0 = parity).

    ``match`` narrows the gated set to keys containing any substring — e.g.
    ``--match overhead`` gates the parity-type overheads at a tight
    threshold without dragging in looser-by-design slowdown factors.
    """
    gates = {
        k: v for k, v in flat.items()
        if "overhead" in k.rsplit(".", 1)[-1]
        or "slowdown" in k.rsplit(".", 1)[-1]
    }
    if match:
        gates = {k: v for k, v in gates.items()
                 if any(m in k for m in match)}
    return gates


def cmd_baseline(args) -> int:
    if not args.bench:
        print("obs_report baseline: need at least one --bench BENCH.json",
              file=sys.stderr)
        return 2
    failures: List[str] = []
    run_flat: Dict[str, float] = {}
    if args.run:
        run = _load(args.run)
        run_flat = _flatten(
            {"gauges": (run["metrics"] or {}).get("gauges") or {}}
        )
        run_flat = {k.split("gauges.", 1)[-1]: v for k, v in run_flat.items()}
    for path in args.bench:
        try:
            with open(path) as f:
                bench = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"obs_report baseline: cannot read {path}: {e}",
                  file=sys.stderr)
            return 2
        flat = _flatten(bench)
        gates = _ratio_gates(flat, args.match or None)
        label = os.path.basename(path)
        print(f"{label}: {len(gates)} ratio gate(s), "
              f"threshold <= {1 + args.threshold:.2f}x")
        for k, v in sorted(gates.items()):
            bad = v > 1.0 + args.threshold
            print(f"  {k:<44} {v:.4f}x"
                  + ("  << REGRESSION" if bad else ""))
            if bad:
                failures.append(f"{label}:{k}")
        # run metrics that share a flattened name with a bench scalar
        for k in sorted(set(flat) & set(run_flat)):
            a, b = flat[k], run_flat[k]
            if a <= 0:
                continue
            bad = b > a * (1.0 + args.threshold)
            print(f"  {k:<44} bench={a:.4g} run={b:.4g}"
                  + ("  << REGRESSION" if bad else ""))
            if bad:
                failures.append(f"{label}:{k}(run)")
    if failures:
        print(f"REGRESSION vs baseline: {', '.join(failures)}")
        return 1
    print("ok: all baseline gates hold")
    return 0


# ---------------------------------------------------------------------------
# inject-slowdown (deterministic diff-gate test partner)
# ---------------------------------------------------------------------------


def _scale_time(obj, factor: float, hit, name: str = ""):
    if isinstance(obj, dict):
        return {
            k: _scale_time(v, factor, hit, f"{name}/{k}" if name else str(k))
            for k, v in obj.items()
        }
    if isinstance(obj, (int, float)) and not isinstance(obj, bool):
        return obj * factor if hit(name) else obj
    return obj


def cmd_inject(args) -> int:
    src = _load(args.src)
    match = args.match or []

    def hit(name: str) -> bool:
        if not _is_time_like(name):
            return False
        return not match or any(m in name for m in match)

    os.makedirs(args.dst, exist_ok=True)
    man = _scale_time(copy.deepcopy(src["manifest"]), args.factor, hit)
    with open(os.path.join(args.dst, runlog.MANIFEST), "w") as f:
        json.dump(man, f, indent=2)
    if src["metrics"] is not None:
        m = copy.deepcopy(src["metrics"])
        m["gauges"] = {
            k: (v * args.factor if hit(k) else v)
            for k, v in (m.get("gauges") or {}).items()
        }
        m["histograms"] = {
            k: (
                {
                    f: (v * args.factor
                        if hit(k) and f != "count" else v)
                    for f, v in summ.items()
                }
                if isinstance(summ, dict) else summ
            )
            for k, summ in (m.get("histograms") or {}).items()
        }
        with open(os.path.join(args.dst, runlog.METRICS), "w") as f:
            json.dump(m, f, indent=2)
    if src["trace"] is not None:
        tr = copy.deepcopy(src["trace"])
        scale_trace = not match  # named scaling targets metrics only
        for ev in tr.get("traceEvents", []):
            if scale_trace and "dur" in ev:
                ev["dur"] = ev["dur"] * args.factor
        with open(os.path.join(args.dst, runlog.TRACE), "w") as f:
            json.dump(tr, f)
    epath = os.path.join(args.src, runlog.EVENTS)
    if os.path.exists(epath):
        with open(epath) as fin, \
                open(os.path.join(args.dst, runlog.EVENTS), "w") as fout:
            fout.write(fin.read())
    print(f"wrote {args.dst}: {args.src} with time-like metrics "
          + (f"matching {match} " if match else "")
          + f"scaled x{args.factor}")
    return 0


# ---------------------------------------------------------------------------
# kernels (profiler attribution report)
# ---------------------------------------------------------------------------

_KERNEL_FIELDS = ("measured_ms", "modeled_ms", "compute_ms", "memory_ms",
                  "flops", "bytes", "achieved_frac", "mem_bound")


def _kernel_report(run: dict) -> Tuple[Dict[str, dict], Dict[str, float]]:
    """(families, machine) parsed back out of the published gauge scheme."""
    m = run.get("metrics") or {}
    gauges = m.get("gauges") or {}
    counters = m.get("counters") or {}
    machine = {
        k.rsplit("/", 1)[-1]: float(v)
        for k, v in gauges.items() if k.startswith("kernels/machine/")
    }
    fams: Dict[str, dict] = {}
    for name, v in gauges.items():
        parts = name.split("/")
        if len(parts) == 3 and parts[0] == "kernels" \
                and parts[1] != "machine" and parts[2] in _KERNEL_FIELDS:
            fams.setdefault(parts[1], {})[parts[2]] = float(v)
    for name, v in counters.items():
        parts = name.split("/")
        if len(parts) == 3 and parts[0] == "kernels" \
                and parts[2] in ("calls", "loop_execs"):
            fams.setdefault(parts[1], {})[parts[2]] = int(v)
    return fams, machine


def cmd_kernels(args) -> int:
    run = _load(args.run)
    fams, machine = _kernel_report(run)
    if not fams:
        print(f"obs_report kernels: no kernel-profiler gauges in {args.run} "
              f"(was the run launched with --profile?)", file=sys.stderr)
        return 2
    if machine:
        print("machine model: "
              + "  ".join(f"{k}={v:.3g}" for k, v in sorted(machine.items())))
    print(f"{'family':<8} {'calls':>6} {'loop':>8} {'measured':>11} "
          f"{'modeled':>10} {'achieved':>9}  verdict")
    for fam in sorted(fams):
        d = fams[fam]
        measured = d.get("measured_ms", 0.0)
        modeled = d.get("modeled_ms", 0.0)
        ach = d.get("achieved_frac")
        verdict = ("memory-bound" if d.get("mem_bound", 0.0) > 0.5
                   else "compute-bound")
        print(f"{fam:<8} {d.get('calls', 0):>6} {d.get('loop_execs', 0):>8} "
              f"{measured:>9.3f}ms {modeled:>8.4f}ms "
              + (f"{ach:>9.2g}" if ach is not None else f"{'—':>9}")
              + f"  {verdict}")

    failures: List[str] = []
    if args.require:
        for fam in [f for f in args.require.split(",") if f]:
            d = fams.get(fam)
            if d is None:
                failures.append(f"{fam}: no attribution recorded")
            elif d.get("measured_ms", 0.0) <= 0.0 \
                    or d.get("modeled_ms", 0.0) <= 0.0:
                failures.append(f"{fam}: present but unattributed "
                                f"(measured={d.get('measured_ms', 0.0):.4g}ms"
                                f" modeled={d.get('modeled_ms', 0.0):.4g}ms)")
    if args.check_model:
        peak = machine.get("word_ops_peak", 0.0)
        bw = machine.get("hbm_bw", 0.0)
        if peak <= 0 or bw <= 0:
            failures.append("machine constants missing from the record")
        else:
            tol = args.tolerance
            for fam in sorted(fams):
                d = fams[fam]
                if d.get("modeled_ms", 0.0) <= 0.0:
                    continue
                want_c = d.get("flops", 0.0) / peak * 1e3
                want_m = d.get("bytes", 0.0) / bw * 1e3
                got_c, got_m = d.get("compute_ms", 0.0), d.get("memory_ms", 0.0)
                if abs(got_c - want_c) > tol * max(want_c, 1e-12):
                    failures.append(
                        f"{fam}: compute_ms {got_c:.4g} != flops/peak "
                        f"{want_c:.4g}")
                if abs(got_m - want_m) > tol * max(want_m, 1e-12):
                    failures.append(
                        f"{fam}: memory_ms {got_m:.4g} != bytes/bw "
                        f"{want_m:.4g}")
                lo = max(got_c, got_m)
                hi = got_c + got_m
                mod = d.get("modeled_ms", 0.0)
                if not (lo * (1 - tol) <= mod <= hi * (1 + tol)):
                    failures.append(
                        f"{fam}: modeled_ms {mod:.4g} outside "
                        f"[max,sum]=[{lo:.4g},{hi:.4g}] of its terms")
                if abs(got_m - got_c) > tol * max(got_m, got_c, 1e-12) and \
                        (d.get("mem_bound", 0.0) > 0.5) != (got_m > got_c):
                    failures.append(f"{fam}: mem_bound verdict inconsistent "
                                    f"with its terms")
    if failures:
        print("KERNEL ATTRIBUTION FAIL: " + "; ".join(failures))
        return 1
    if args.require or args.check_model:
        print("ok: kernel attribution "
              + ("complete" if args.require else "")
              + (" and " if args.require and args.check_model else "")
              + ("model-consistent" if args.check_model else ""))
    return 0


# ---------------------------------------------------------------------------
# history / regress (the perf ledger)
# ---------------------------------------------------------------------------


def _load_history(path: str) -> Tuple[List[dict], int]:
    try:
        rows, corrupt = perfdb.load(path)
    except OSError as e:
        print(f"obs_report: cannot read perf history {path}: {e}",
              file=sys.stderr)
        sys.exit(2)
    if not rows:
        print(f"obs_report: no usable rows in {path}", file=sys.stderr)
        sys.exit(2)
    if corrupt:
        print(f"note: skipped {corrupt} corrupt line(s) in {path}")
    return rows, corrupt


def cmd_history(args) -> int:
    rows, _ = _load_history(args.history)
    series = perfdb.trends(
        rows, suite=args.suite or None, key_match=args.key or None
    )
    if not series:
        print("obs_report history: no matching keys", file=sys.stderr)
        return 2
    if args.format == "markdown":
        print(f"### perf history `{args.history}` "
              f"({len(rows)} rows, {len(series)} series)")
        print()
        print("| suite/key | dir | min | max | trailing values "
              "(newest last) |")
        print("|---|---|---|---|---|")
        for (suite, key), pts in sorted(series.items()):
            d = perfdb.direction(key)
            tail = pts[-args.last:]
            vals = " ".join(f"{p['value']:.4g}" for p in tail)
            lo = min(p["value"] for p in pts)
            hi = max(p["value"] for p in pts)
            print(f"| `{suite}/{key}` | {d or '—'} | {lo:.4g} | {hi:.4g} "
                  f"| {vals} |")
        return 0
    print(f"{args.history}: {len(rows)} rows, {len(series)} series")
    for (suite, key), pts in sorted(series.items()):
        d = perfdb.direction(key)
        tail = pts[-args.last:]
        vals = "  ".join(f"{p['value']:.4g}" for p in tail)
        lo = min(p["value"] for p in pts)
        hi = max(p["value"] for p in pts)
        print(f"  {suite}/{key} [{d or 'untracked'}] "
              f"min={lo:.4g} max={hi:.4g}")
        print(f"    {vals}   (newest last, "
              f"sha {tail[-1]['sha'] or '?'} @ {tail[-1]['ts']})")
    return 0


def _parse_directions(specs: List[str]) -> Dict[str, str]:
    """``KEY=up|down`` (CLI speak) → {key: "higher"|"lower"} (perfdb's)."""
    out: Dict[str, str] = {}
    for spec in specs:
        key, _, word = spec.partition("=")
        if word not in ("up", "down") or not key:
            print(f"obs_report regress: bad --direction {spec!r} "
                  f"(want KEY=up or KEY=down)", file=sys.stderr)
            sys.exit(2)
        out[key] = "higher" if word == "up" else "lower"
    return out


def cmd_regress(args) -> int:
    rows, _ = _load_history(args.history)
    found, checked = perfdb.check_regressions(
        rows,
        threshold=args.threshold,
        window=args.window,
        min_history=args.min_history,
        degrade=args.degrade,
        direction_overrides=_parse_directions(args.direction),
    )
    label = f" (values degraded x{args.degrade} first)" \
        if args.degrade != 1.0 else ""
    if args.format == "markdown":
        print(f"### perf regressions `{args.history}`")
        print()
        print(f"{len(rows)} rows, {checked} gated key(s), threshold "
              f"+{args.threshold:.0%}{label}")
        print()
        if found:
            print("| suite/key | latest | trailing median | worse by |")
            print("|---|---|---|---|")
            for reg in found:
                print(f"| `{reg.suite}/{reg.key}` | {reg.latest:.4g} "
                      f"| {reg.median:.4g} | {reg.ratio:.2f}× |")
            print()
            print(f"**REGRESSION:** {len(found)} key(s) degraded")
            return 1
        print("ok: no key degraded past the threshold")
        return 0
    print(f"{args.history}: {len(rows)} rows, {checked} gated key(s), "
          f"threshold +{args.threshold:.0%}{label}")
    if found:
        for reg in found:
            print(f"  REGRESSION {reg.line()}")
        print(f"REGRESSION: {len(found)} key(s) degraded vs trailing median")
        return 1
    print("ok: no key degraded past the threshold")
    return 0


# ---------------------------------------------------------------------------
# critpath / doctor (the diagnosis layer)
# ---------------------------------------------------------------------------


def cmd_critpath(args) -> int:
    run = _load(args.run)
    cp = critpath.analyze(run.get("trace"), top_n=args.top)
    if cp is None:
        print(f"obs_report critpath: no trace spans in {args.run} "
              f"(was the run launched with --trace?)", file=sys.stderr)
        return 2
    if args.format == "json":
        print(json.dumps(cp, indent=2))
        return 0
    print(f"critical path of {args.run} (wall {cp['wall_ms']:.1f} ms)")
    print(f"  {'self ms':>10}  {'share':>6}  {'n':>3}  name")
    for r in cp["table"]:
        print(f"  {r['self_ms']:>10.2f}  {r['share']:>6.1%}  "
              f"{r['count']:>3d}  {r['name']}"
              + (f"  [{r['tracks']}]" if r["tracks"] else ""))
    if args.path:
        print("path (pre-order, on-path self time):")
        for seg in cp["path"]:
            pad = "  " * seg["depth"]
            print(f"  {pad}{seg['name']}  dur={seg['dur_ms']:.2f}ms "
                  f"self={seg['self_ms']:.2f}ms"
                  + (f"  [{seg['track']}]" if seg["track"] else ""))
    return 0


def cmd_doctor(args) -> int:
    run = _load(args.run)
    history_rows = None
    if args.history and os.path.exists(args.history):
        try:
            history_rows, _ = perfdb.load(args.history)
        except OSError:
            history_rows = None
    report = doctor.diagnose(run, history_rows=history_rows, top_n=args.top)
    if args.format == "json":
        print(json.dumps(report, indent=2))
    elif args.format == "markdown":
        print(doctor.render_markdown(report))
    else:
        print(doctor.render_text(report))
    if args.gate and doctor._RANK[report["worst"]] >= doctor._RANK["error"]:
        print(f"DOCTOR GATE: severity {report['worst']} finding(s) present",
              file=sys.stderr)
        return 1
    return 0


# ---------------------------------------------------------------------------


def main(argv: Optional[Iterable[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="obs_report", description=__doc__.split("\n\n")[0]
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    s = sub.add_parser("summary", help="digest one run record")
    s.add_argument("run")
    s.add_argument("--events", type=int, default=20,
                   help="max driver events to print")
    s.add_argument("--gauges", type=int, default=24,
                   help="max gauges to print")
    s.add_argument("--format", choices=("text", "json", "markdown"),
                   default="text",
                   help="output format (json: full machine-readable digest; "
                        "markdown: CI step-summary tables)")
    s.set_defaults(fn=cmd_summary)

    d = sub.add_parser("diff", help="compare two runs; exit 1 on regression")
    d.add_argument("old")
    d.add_argument("new")
    d.add_argument("--threshold", type=float, default=0.2,
                   help="allowed slowdown fraction (0.2 = +20%%)")
    d.add_argument("--min-seconds-ignore", type=float, default=0.0,
                   dest="min_seconds_ignore",
                   help="ignore time metrics where both sides are <= this "
                        "(noise floor)")
    d.set_defaults(fn=cmd_diff)

    b = sub.add_parser("baseline",
                       help="gate BENCH_*.json ratio keys; exit 1 on "
                            "regression")
    b.add_argument("run", nargs="?", default="",
                   help="optional run record to compare by shared key names")
    b.add_argument("--bench", action="append", default=[],
                   help="BENCH_*.json baseline file (repeatable)")
    b.add_argument("--threshold", type=float, default=0.05,
                   help="allowed overhead/slowdown above 1.0 (0.05 = 5%%)")
    b.add_argument("--match", action="append", default=[],
                   help="only gate ratio keys containing this substring "
                        "(repeatable; default: every overhead/slowdown key)")
    b.set_defaults(fn=cmd_baseline)

    i = sub.add_parser("inject-slowdown",
                       help="copy a run record with time metrics scaled "
                            "(deterministic diff-gate test input)")
    i.add_argument("src")
    i.add_argument("dst")
    i.add_argument("--factor", type=float, default=1.3)
    i.add_argument("--match", action="append", default=[],
                   help="only scale time-like metrics containing this "
                        "substring (repeatable; trace durations untouched "
                        "when given)")
    i.set_defaults(fn=cmd_inject)

    k = sub.add_parser("kernels",
                       help="render kernel-profiler attribution; gate on "
                            "coverage/model consistency")
    k.add_argument("run")
    k.add_argument("--require", default="",
                   help="comma-separated families that must carry "
                        "attribution (exit 1 otherwise), e.g. "
                        "bitmap,multi,pair,subset,delta")
    k.add_argument("--check-model", action="store_true", dest="check_model",
                   help="recompute roofline terms from the flop/byte/machine "
                        "gauges; exit 1 on mismatch")
    k.add_argument("--tolerance", type=float, default=0.01,
                   help="relative tolerance of --check-model (default 1%%)")
    k.set_defaults(fn=cmd_kernels)

    h = sub.add_parser("history", help="render perf-ledger trends")
    h.add_argument("--history", default=perfdb.DEFAULT_PATH)
    h.add_argument("--suite", default="", help="only this suite")
    h.add_argument("--key", default="", help="only keys containing this")
    h.add_argument("--last", type=int, default=12,
                   help="values shown per series (newest last)")
    h.add_argument("--format", choices=("text", "markdown"), default="text",
                   help="markdown renders a CI step-summary table")
    h.set_defaults(fn=cmd_history)

    r = sub.add_parser("regress",
                       help="gate the newest perf-ledger row vs trailing "
                            "median; exit 1 on degradation")
    r.add_argument("--history", default=perfdb.DEFAULT_PATH)
    r.add_argument("--threshold", type=float, default=0.25,
                   help="allowed relative degradation (0.25 = 25%%)")
    r.add_argument("--window", type=int, default=8,
                   help="trailing values the median is taken over")
    r.add_argument("--min-history", type=int, default=2,
                   dest="min_history",
                   help="prior values a key needs before it gates")
    r.add_argument("--degrade", type=float, default=1.0,
                   help="synthetically worsen newest values by this factor "
                        "(failing-partner self-test)")
    r.add_argument("--direction", action="append", default=[],
                   metavar="KEY=up|down",
                   help="override the name-inferred better-direction of a "
                        "key (up = higher is better); repeatable")
    r.add_argument("--format", choices=("text", "markdown"), default="text",
                   help="markdown renders a CI step-summary table")
    r.set_defaults(fn=cmd_regress)

    c = sub.add_parser("critpath",
                       help="critical path + exclusive self-time of one "
                            "traced run")
    c.add_argument("run")
    c.add_argument("--top", type=int, default=10,
                   help="rows in the by-name critical-path table")
    c.add_argument("--path", action="store_true",
                   help="also print the full pre-order path")
    c.add_argument("--format", choices=("text", "json"), default="text")
    c.set_defaults(fn=cmd_critpath)

    o = sub.add_parser("doctor",
                       help="diagnose one run record: critical path, "
                            "speedup waterfall, ranked findings")
    o.add_argument("run")
    o.add_argument("--history", default=perfdb.DEFAULT_PATH,
                   help="perf ledger for trend rules (missing file: rules "
                        "needing history are skipped)")
    o.add_argument("--top", type=int, default=10,
                   help="rows in the critical-path table")
    o.add_argument("--format", choices=("text", "json", "markdown"),
                   default="text")
    o.add_argument("--gate", action="store_true",
                   help="exit 1 when any severity>=error finding fires")
    o.set_defaults(fn=cmd_doctor)

    args = ap.parse_args(list(argv) if argv is not None else None)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
