"""Run-record report CLI: summarize, diff, and gate on observability output.

Reads the run records :mod:`repro.obs.runlog` writes (``--trace`` /
``--metrics`` on any launcher) and the ``BENCH_*.json`` files the benchmark
suite writes.  **jax-free by construction** (the fsck layering rule): this
tool must load anywhere the JSON does — CI report steps, a laptop without
the accelerator stack, a post-mortem container.

Subcommands::

  summary RUN_DIR
      Human-readable digest: manifest identity, driver event timeline,
      the metric families, span time by name.

  diff OLD_RUN NEW_RUN [--threshold 0.2]
      Compare two runs' time-like metrics (wall_s, */phase_ms/*, *_ms/*_s
      gauges, latency-histogram p95s).  Prints old → new with the ratio and
      **exits 1** when any time-like metric regressed by more than the
      threshold (0.2 = +20%).  Counters/gauges that are not time-like are
      shown for context but never gate.

  baseline --bench BENCH.json [...] [--threshold 0.05] [RUN_DIR]
      Gate on benchmark baselines: every ratio-type key in each BENCH file
      (``*_overhead*``, ``*_slowdown*`` — measured-vs-baseline ratios where
      1.0 = parity) must stay <= 1 + threshold; ``--match SUBSTR`` narrows
      the gated keys (e.g. ``--match overhead`` for the parity-type gates
      only).  With a RUN_DIR, metrics sharing a flattened name with a bench
      key are also compared under the same threshold.  Exits 1 on any
      regression.

  inject-slowdown SRC_RUN DST_RUN --factor 1.3
      Copy a run record with every time-like quantity scaled by ``factor``
      (wall_s, *_ms/*_s gauges and histograms, trace durations).  The
      deterministic partner for testing the diff gate: ``diff SRC DST``
      must fail and ``diff SRC SRC`` must pass, with no timing flakiness.

Exit codes: 0 ok, 1 regression detected, 2 usage / unreadable record.
"""
from __future__ import annotations

import argparse
import copy
import json
import os
import sys
from typing import Dict, Iterable, List, Optional, Tuple

from repro.obs import runlog

#: gauge/summary names treated as durations (the regression-gated set)
_TIME_SUFFIXES = ("_ms", "_s", "wall_s")


def _is_time_like(name: str) -> bool:
    short = name.rsplit("/", 1)[-1]
    return (
        short.endswith(_TIME_SUFFIXES)
        or "/phase_ms/" in name
        or "stall" in short
        or "latency" in short
    )


def _time_metrics(run: dict) -> Dict[str, float]:
    """Flatten one run's time-like scalars: summary + gauges + hist p95s."""
    out: Dict[str, float] = {}
    man = run.get("manifest") or {}
    for k, v in man.items():
        if isinstance(v, (int, float)) and _is_time_like(str(k)):
            out[str(k)] = float(v)
    m = run.get("metrics") or {}
    for name, v in (m.get("gauges") or {}).items():
        if isinstance(v, (int, float)) and _is_time_like(name):
            out[name] = float(v)
    for name, summ in (m.get("histograms") or {}).items():
        if _is_time_like(name) and isinstance(summ, dict):
            p95 = summ.get("p95")
            if isinstance(p95, (int, float)):
                out[f"{name}:p95"] = float(p95)
    return out


def _load(run_dir: str) -> dict:
    try:
        return runlog.load_run(run_dir)
    except (FileNotFoundError, json.JSONDecodeError) as e:
        print(f"obs_report: cannot read run record at {run_dir}: {e}",
              file=sys.stderr)
        sys.exit(2)


# ---------------------------------------------------------------------------
# summary
# ---------------------------------------------------------------------------


def _span_totals(trace: Optional[dict]) -> List[Tuple[str, float, int]]:
    """(name, total_ms, count) per complete-event span, longest first."""
    if not trace:
        return []
    acc: Dict[str, List[float]] = {}
    for ev in trace.get("traceEvents", []):
        if ev.get("ph") == "X":
            acc.setdefault(ev["name"], []).append(ev.get("dur", 0) / 1e3)
    return sorted(
        ((n, sum(d), len(d)) for n, d in acc.items()),
        key=lambda t: -t[1],
    )


def cmd_summary(args) -> int:
    run = _load(args.run)
    man = run["manifest"]
    print(f"run: {man.get('name')}  dir={run['run_dir']}")
    print(f"  git={str(man.get('git_sha'))[:12]}  "
          f"backend={man.get('backend')} x{man.get('n_devices')} "
          f"({man.get('device_kind')})")
    wall = man.get("wall_s")
    print(f"  wall_s={wall:.3f}" if isinstance(wall, (int, float))
          else "  wall_s=<unfinished>")
    extras = {
        k: v for k, v in man.items()
        if k not in ("name", "config", "argv", "git_sha", "started_unix",
                     "backend", "device_kind", "n_devices", "wall_s")
    }
    if extras:
        print("  summary: " + "  ".join(f"{k}={v}" for k, v in extras.items()))
    if run["events"]:
        print(f"events ({len(run['events'])}):")
        for ev in run["events"][: args.events]:
            rest = {k: v for k, v in ev.items() if k not in ("t", "kind")}
            print(f"  t={ev['t']:>8.3f}s  {ev['kind']:<12} "
                  + " ".join(f"{k}={v}" for k, v in rest.items()))
        if len(run["events"]) > args.events:
            print(f"  ... {len(run['events']) - args.events} more")
    m = run["metrics"] or {}
    if m.get("counters"):
        print("counters:")
        for k, v in sorted(m["counters"].items()):
            print(f"  {k} = {v}")
    if m.get("gauges"):
        print(f"gauges: {len(m['gauges'])} "
              f"(use diff/baseline for comparisons)")
        for k, v in sorted(m["gauges"].items())[: args.gauges]:
            print(f"  {k} = {v:.6g}")
        if len(m["gauges"]) > args.gauges:
            print(f"  ... {len(m['gauges']) - args.gauges} more")
    if m.get("histograms"):
        print("histograms:")
        for k, s in sorted(m["histograms"].items()):
            print(f"  {k}: n={s['count']} mean={s['mean']:.4g} "
                  f"p50={s['p50']:.4g} p95={s['p95']:.4g} max={s['max']:.4g}")
    spans = _span_totals(run["trace"])
    if spans:
        print("trace spans (total ms):")
        for name, tot, cnt in spans[:12]:
            print(f"  {name:<28} {tot:>10.2f}ms  x{cnt}")
        print(f"  -> load {run['run_dir']}/trace.json in "
              f"https://ui.perfetto.dev or chrome://tracing")
    return 0


# ---------------------------------------------------------------------------
# diff
# ---------------------------------------------------------------------------


def cmd_diff(args) -> int:
    old, new = _load(args.old), _load(args.new)
    t_old, t_new = _time_metrics(old), _time_metrics(new)
    shared = sorted(set(t_old) & set(t_new))
    if not shared:
        print("obs_report diff: no shared time-like metrics "
              "(were both runs recorded with --metrics or --trace?)",
              file=sys.stderr)
        return 2
    regressions: List[str] = []
    print(f"diff {args.old} -> {args.new}  (threshold +{args.threshold:.0%})")
    for name in shared:
        a, b = t_old[name], t_new[name]
        if a <= args.min_seconds_ignore and b <= args.min_seconds_ignore:
            continue  # sub-noise-floor timings cannot gate
        ratio = b / a if a > 0 else float("inf")
        worse = b > a * (1.0 + args.threshold)
        flag = "  << REGRESSION" if worse else ""
        print(f"  {name:<36} {a:>12.4f} -> {b:>12.4f}  "
              f"x{ratio:.2f}{flag}")
        if worse:
            regressions.append(name)
    # non-time context: counter deltas worth a glance (never gate)
    c_old = (old.get("metrics") or {}).get("counters") or {}
    c_new = (new.get("metrics") or {}).get("counters") or {}
    changed = {
        k: (c_old[k], c_new[k])
        for k in set(c_old) & set(c_new) if c_old[k] != c_new[k]
    }
    if changed:
        print("counter deltas (context only):")
        for k, (a, b) in sorted(changed.items()):
            print(f"  {k:<36} {a} -> {b}")
    if regressions:
        print(f"REGRESSION: {len(regressions)} time-like metric(s) slowed "
              f"beyond +{args.threshold:.0%}: {', '.join(regressions)}")
        return 1
    print("ok: no time-like metric regressed beyond the threshold")
    return 0


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------


def _flatten(obj, prefix: str = "") -> Dict[str, float]:
    out: Dict[str, float] = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(_flatten(v, f"{prefix}{k}."))
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            name = v.get("name") if isinstance(v, dict) else None
            out.update(_flatten(v, f"{prefix}{name or i}."))
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        out[prefix[:-1]] = float(obj)
    return out


def _ratio_gates(flat: Dict[str, float],
                 match: Optional[List[str]] = None) -> Dict[str, float]:
    """Keys whose value is a measured/baseline ratio (1.0 = parity).

    ``match`` narrows the gated set to keys containing any substring — e.g.
    ``--match overhead`` gates the parity-type overheads at a tight
    threshold without dragging in looser-by-design slowdown factors.
    """
    gates = {
        k: v for k, v in flat.items()
        if "overhead" in k.rsplit(".", 1)[-1]
        or "slowdown" in k.rsplit(".", 1)[-1]
    }
    if match:
        gates = {k: v for k, v in gates.items()
                 if any(m in k for m in match)}
    return gates


def cmd_baseline(args) -> int:
    if not args.bench:
        print("obs_report baseline: need at least one --bench BENCH.json",
              file=sys.stderr)
        return 2
    failures: List[str] = []
    run_flat: Dict[str, float] = {}
    if args.run:
        run = _load(args.run)
        run_flat = _flatten(
            {"gauges": (run["metrics"] or {}).get("gauges") or {}}
        )
        run_flat = {k.split("gauges.", 1)[-1]: v for k, v in run_flat.items()}
    for path in args.bench:
        try:
            with open(path) as f:
                bench = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"obs_report baseline: cannot read {path}: {e}",
                  file=sys.stderr)
            return 2
        flat = _flatten(bench)
        gates = _ratio_gates(flat, args.match or None)
        label = os.path.basename(path)
        print(f"{label}: {len(gates)} ratio gate(s), "
              f"threshold <= {1 + args.threshold:.2f}x")
        for k, v in sorted(gates.items()):
            bad = v > 1.0 + args.threshold
            print(f"  {k:<44} {v:.4f}x"
                  + ("  << REGRESSION" if bad else ""))
            if bad:
                failures.append(f"{label}:{k}")
        # run metrics that share a flattened name with a bench scalar
        for k in sorted(set(flat) & set(run_flat)):
            a, b = flat[k], run_flat[k]
            if a <= 0:
                continue
            bad = b > a * (1.0 + args.threshold)
            print(f"  {k:<44} bench={a:.4g} run={b:.4g}"
                  + ("  << REGRESSION" if bad else ""))
            if bad:
                failures.append(f"{label}:{k}(run)")
    if failures:
        print(f"REGRESSION vs baseline: {', '.join(failures)}")
        return 1
    print("ok: all baseline gates hold")
    return 0


# ---------------------------------------------------------------------------
# inject-slowdown (deterministic diff-gate test partner)
# ---------------------------------------------------------------------------


def _scale_time(obj, factor: float, name: str = ""):
    if isinstance(obj, dict):
        return {
            k: _scale_time(v, factor, f"{name}/{k}" if name else str(k))
            for k, v in obj.items()
        }
    if isinstance(obj, (int, float)) and not isinstance(obj, bool):
        return obj * factor if _is_time_like(name) else obj
    return obj


def cmd_inject(args) -> int:
    src = _load(args.src)
    os.makedirs(args.dst, exist_ok=True)
    man = _scale_time(copy.deepcopy(src["manifest"]), args.factor)
    with open(os.path.join(args.dst, runlog.MANIFEST), "w") as f:
        json.dump(man, f, indent=2)
    if src["metrics"] is not None:
        m = copy.deepcopy(src["metrics"])
        m["gauges"] = {
            k: (v * args.factor if _is_time_like(k) else v)
            for k, v in (m.get("gauges") or {}).items()
        }
        m["histograms"] = {
            k: (
                {
                    f: (v * args.factor
                        if _is_time_like(k) and f != "count" else v)
                    for f, v in summ.items()
                }
                if isinstance(summ, dict) else summ
            )
            for k, summ in (m.get("histograms") or {}).items()
        }
        with open(os.path.join(args.dst, runlog.METRICS), "w") as f:
            json.dump(m, f, indent=2)
    if src["trace"] is not None:
        tr = copy.deepcopy(src["trace"])
        for ev in tr.get("traceEvents", []):
            if "dur" in ev:
                ev["dur"] = ev["dur"] * args.factor
        with open(os.path.join(args.dst, runlog.TRACE), "w") as f:
            json.dump(tr, f)
    epath = os.path.join(args.src, runlog.EVENTS)
    if os.path.exists(epath):
        with open(epath) as fin, \
                open(os.path.join(args.dst, runlog.EVENTS), "w") as fout:
            fout.write(fin.read())
    print(f"wrote {args.dst}: {args.src} with time-like metrics "
          f"scaled x{args.factor}")
    return 0


# ---------------------------------------------------------------------------


def main(argv: Optional[Iterable[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="obs_report", description=__doc__.split("\n\n")[0]
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    s = sub.add_parser("summary", help="digest one run record")
    s.add_argument("run")
    s.add_argument("--events", type=int, default=20,
                   help="max driver events to print")
    s.add_argument("--gauges", type=int, default=24,
                   help="max gauges to print")
    s.set_defaults(fn=cmd_summary)

    d = sub.add_parser("diff", help="compare two runs; exit 1 on regression")
    d.add_argument("old")
    d.add_argument("new")
    d.add_argument("--threshold", type=float, default=0.2,
                   help="allowed slowdown fraction (0.2 = +20%%)")
    d.add_argument("--min-seconds-ignore", type=float, default=0.0,
                   dest="min_seconds_ignore",
                   help="ignore time metrics where both sides are <= this "
                        "(noise floor)")
    d.set_defaults(fn=cmd_diff)

    b = sub.add_parser("baseline",
                       help="gate BENCH_*.json ratio keys; exit 1 on "
                            "regression")
    b.add_argument("run", nargs="?", default="",
                   help="optional run record to compare by shared key names")
    b.add_argument("--bench", action="append", default=[],
                   help="BENCH_*.json baseline file (repeatable)")
    b.add_argument("--threshold", type=float, default=0.05,
                   help="allowed overhead/slowdown above 1.0 (0.05 = 5%%)")
    b.add_argument("--match", action="append", default=[],
                   help="only gate ratio keys containing this substring "
                        "(repeatable; default: every overhead/slowdown key)")
    b.set_defaults(fn=cmd_baseline)

    i = sub.add_parser("inject-slowdown",
                       help="copy a run record with time metrics scaled "
                            "(deterministic diff-gate test input)")
    i.add_argument("src")
    i.add_argument("dst")
    i.add_argument("--factor", type=float, default=1.3)
    i.set_defaults(fn=cmd_inject)

    args = ap.parse_args(list(argv) if argv is not None else None)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
