"""Streaming driver: ingest a drifting stream while serving queries.

The live half of the store-owner scenario: transactions arrive in blocks,
the sliding window advances, mined FI supports are delta-updated in place
(one fused arrive/expire kernel sweep per block), and the drift monitor
decides when the serving table is stale enough to re-mine — at which point
the window is re-mined with the full Parallel-FIMI pipeline and the serving
indexes are hot-swapped under live traffic.  Between admits, a Zipf-hot
query workload is served through the engine + LRU cache (cache keys carry
the swap generation, so a hot-swap can never serve a stale hit).

Reports ingest throughput, re-mine count by trigger reason, swap latency,
staleness (max support error of the served table vs. the offline window
oracle), serving QPS / cache hit rate, and the torn-index parity check
(engine vs. host oracle before and after every swap — must be 0 failures).

  python -m repro.launch.stream_mine --db T2I0.048P50PL10TL16 --support 0.1 \\
      --blocks 8 --blocktx 256 --stream 32 --breaks 16 [-P 4] [--eps 0.1]
"""
from __future__ import annotations

import argparse

from repro.launch.host_devices import preparse_devices

preparse_devices()  # must run before anything imports jax

import time  # noqa: E402

import numpy as np  # noqa: E402


def parity_failures(sm, rng, n_probe=32) -> int:
    """Torn-index check: engine answers vs the host-read index itself.

    Every indexed itemset must look up at exactly its indexed support; a
    torn swap (old FI masks against new supports, or half-published state)
    breaks this immediately.
    """
    idx = sm.engine.index
    if idx.n_fis == 0:
        return 0
    pick = rng.choice(idx.n_fis, size=min(n_probe, idx.n_fis), replace=False)
    masks = np.asarray(idx.masks)[pick]
    want = np.asarray(idx.supports)[pick]
    got = sm.engine.support(masks)
    return int((got != want).sum())


def serve_block(sm, rng, n_queries, zipf_a=1.3):
    """Serve a Zipf-hot batch of support lookups through cache + engine."""
    from repro.serve.cache import query_key

    idx = sm.engine.index
    if idx.n_fis == 0 or n_queries == 0:
        return 0.0, 0
    rows = np.minimum(
        rng.zipf(zipf_a, size=n_queries) - 1, idx.n_fis - 1
    ).astype(np.int64)
    masks = np.asarray(idx.masks)[rows]
    gen = sm.engine.generation
    keys = [
        query_key("support", m, sm.engine.top_k, gen) for m in masks
    ]
    t0 = time.perf_counter()
    results, miss = sm.cache.split_batch(keys)
    # dispatch misses in batch-width chunks, then resolve the whole batch in
    # ONE fill (fill_batch resolves every pending None from the values it is
    # given, so partial fills would KeyError on keys of later chunks)
    vals = []
    for lo in range(0, len(miss), sm.engine.batch):
        part = miss[lo: lo + sm.engine.batch]
        vals.extend(sm.engine.support(masks[part]))
    sm.cache.fill_batch(keys, results, miss, vals)
    return time.perf_counter() - t0, len(miss)


def main():
    from repro.core import eclat, fimi
    from repro.data.ibm_gen import drifting_stream, params_from_name
    from repro.obs.session import add_obs_flags, start_session
    from repro.stream import StreamingMiner, StreamParams, fimi_mine_fn

    ap = argparse.ArgumentParser()
    ap.add_argument("--db", default="T2I0.048P50PL10TL16",
                    help="IBM generator family (n_tx field sets nothing; "
                         "the stream length does)")
    ap.add_argument("--support", type=float, default=0.12)
    ap.add_argument("--blocks", type=int, default=8,
                    help="sliding-window length B in blocks")
    ap.add_argument("--blocktx", type=int, default=256,
                    help="transactions per stream block")
    ap.add_argument("--stream", type=int, default=32,
                    help="total blocks to replay")
    ap.add_argument("--breaks", default="16",
                    help="comma-separated block indices of concept drift")
    ap.add_argument("--eps", type=float, default=0.1,
                    help="staleness tolerance ε (Thm 6.1 monitor)")
    ap.add_argument("--delta", type=float, default=0.05)
    ap.add_argument("--margin", type=float, default=0.02,
                    help="border tracking width around minsup (0 disables)")
    ap.add_argument("--hysteresis", type=float, default=0.02,
                    help="border crossing must clear minsup by this much")
    ap.add_argument("--check-every", type=int, default=1)
    ap.add_argument("--cooldown", type=int, default=2,
                    help="blocks after a re-mine before triggers re-arm")
    ap.add_argument("-P", type=int, default=4, help="miners for re-mining")
    ap.add_argument("--cluster", type=int, default=0, metavar="N",
                    help="re-mine with the distributed cluster executor over "
                         "N miners (planner + exchange + shard-mine + "
                         "rebalance) instead of the in-process fimi.run")
    ap.add_argument("--frontier", type=int, default=16)
    ap.add_argument("--queries", type=int, default=512,
                    help="queries served per ingested block")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--topk", type=int, default=5)
    ap.add_argument("--minconf", type=float, default=0.6)
    ap.add_argument("--cache", type=int, default=2048)
    ap.add_argument("--spill", default="", metavar="DIR",
                    help="persist expired window blocks to a TxStore at DIR")
    ap.add_argument("--force", default=None,
                    choices=[None, "pallas", "ref", "interpret"])
    ap.add_argument("--seed", type=int, default=0)
    add_obs_flags(ap)
    args = ap.parse_args()
    obs = start_session(args, "stream_mine")

    gen_params = params_from_name(args.db, seed=args.seed)
    breaks = tuple(int(b) for b in args.breaks.split(",") if b != "")
    n_items = gen_params.n_items
    window_tx = args.blocks * args.blocktx
    if args.cluster and window_tx % args.cluster:
        ap.error(f"--cluster {args.cluster} must divide the window size "
                 f"({args.blocks} blocks x {args.blocktx} tx = {window_tx})")

    if args.cluster:
        from repro import cluster as cluster_mod

        mine_fn = cluster_mod.cluster_mine_fn(
            P=args.cluster,
            cluster_params=cluster_mod.ClusterParams(
                planner=cluster_mod.PlannerParams(
                    n_db_sample=min(2048, window_tx), n_fi_sample=1024
                ),
                eclat=eclat.EclatConfig(
                    max_out=1 << 15, max_stack=8192,
                    frontier_size=args.frontier,
                ),
            ),
            seed=args.seed,
        )
    else:
        mine_fn = fimi_mine_fn(
            P=args.P,
            fimi_params=fimi.FimiParams(
                n_db_sample=min(2048, window_tx),
                n_fi_sample=1024,
                eclat=eclat.EclatConfig(
                    max_out=1 << 15, max_stack=8192,
                    frontier_size=args.frontier,
                ),
            ),
            seed=args.seed,
        )
    sp = StreamParams(
        n_blocks=args.blocks, block_tx=args.blocktx,
        min_support_rel=args.support, min_confidence=args.minconf,
        eps=args.eps, delta=args.delta, border_margin=args.margin,
        border_hysteresis=args.hysteresis, check_every=args.check_every,
        cooldown_blocks=args.cooldown,
        batch=args.batch, top_k=args.topk, cache_capacity=args.cache,
        force=args.force, spill_dir=args.spill or None, seed=args.seed,
    )
    sm = StreamingMiner(sp, n_items, mine_fn=mine_fn)
    print(f"stream: db-family={args.db} |B|={n_items} window={args.blocks}"
          f"x{args.blocktx}tx sup={args.support} eps={args.eps} "
          f"breaks={breaks} stream={args.stream} blocks")

    rng = np.random.default_rng(args.seed + 1)
    ingest_s = 0.0
    serve_s = 0.0
    n_served = 0
    n_dispatched = 0
    torn = 0
    max_stale = 0.0
    remine_log = []
    prev_gen = -1
    for dense_block, segment in drifting_stream(
        gen_params, n_blocks=args.stream, block_tx=args.blocktx,
        breaks=breaks,
    ):
        if sm.engine is not None:
            torn += parity_failures(sm, rng)     # before a potential swap
        t0 = time.perf_counter()
        ev = sm.admit(dense_block)
        ingest_s += time.perf_counter() - t0
        if ev.remined:
            post = parity_failures(sm, rng)      # after the swap
            torn += post
            if args.cluster:
                # a distributed re-mine must preserve the serving invariants:
                # the swap is atomic (no torn index) and bumps the generation
                assert post == 0, (
                    f"cluster re-mine broke index parity ({post} failures)"
                )
                assert ev.generation == prev_gen + 1, (
                    f"cluster re-mine generation {ev.generation} != "
                    f"{prev_gen + 1}"
                )
            remine_log.append(
                (ev.block_index, segment, ev.remine_reason, ev.mine_ms,
                 ev.swap_ms, sm.engine.index.n_fis)
            )
            if obs:
                obs.event(
                    "remine", block=ev.block_index, segment=segment,
                    reason=ev.remine_reason, mine_ms=ev.mine_ms,
                    swap_ms=ev.swap_ms, generation=ev.generation,
                    n_fis=sm.engine.index.n_fis,
                )
            print(f"  block {ev.block_index:>3} (segment {segment}): "
                  f"re-mine [{ev.remine_reason}] -> F={sm.engine.index.n_fis} "
                  f"R={sm.engine.rules.n_rules} gen={ev.generation} "
                  f"mine={ev.mine_ms:.0f}ms swap={ev.swap_ms:.2f}ms")
        prev_gen = sm.engine.generation if sm.engine else -1
        if sm.engine is not None:
            max_stale = max(max_stale, sm.staleness())   # off the clock
            dt, nd = serve_block(sm, rng, args.queries)
            serve_s += dt
            n_served += args.queries
            n_dispatched += nd

    s = sm.stats
    print(f"ingest: {s.tx_in} tx in {ingest_s:.3f}s -> "
          f"{s.tx_in / ingest_s:,.0f} tx/s "
          f"({s.blocks_in} blocks, delta-updated supports)")
    if sm.engine is None:
        print(f"no mine: stream ended after {s.blocks_in} blocks, window "
              f"needs {args.blocks} to fill (raise --stream)")
        if obs:
            obs.finish(**s.as_dict())
        return
    reasons = {
        "initial": s.remines - s.fired_error - s.fired_border
        - s.fired_recovery,
        "error": s.fired_error, "border": s.fired_border,
        "recovery": s.fired_recovery,
    }
    print(f"re-mine: {s.remines} total ({reasons}), "
          f"mine mean={np.mean(s.mine_ms):.0f}ms, "
          f"swap p100={np.max(s.swap_ms):.2f}ms")
    print(f"staleness: max |served - true| = {max_stale:.4f} "
          f"(tolerance eps={args.eps})")
    if n_served:
        print(f"serve: {n_served} queries in {serve_s:.3f}s -> "
              f"{n_served / serve_s:,.0f} QPS "
              f"({n_dispatched} engine dispatches after cache)")
    es = sm.engine.stats()
    print(f"engine: generation={es['generation']} F={es['n_fis']} "
          f"R={es['n_rules']} cache hit_rate={es['hit_rate']:.1%} "
          f"invalidations={es['invalidations']}")
    print(f"torn-index parity failures: {torn}"
          + ("  <-- BUG" if torn else "  (zero = atomic swaps)"))
    if obs:
        obs.finish(
            **s.as_dict(), max_staleness=max_stale, torn=torn,
            ingest_wall_s=ingest_s, serve_wall_s=serve_s,
            n_served=n_served, generation=sm.engine.generation,
        )
    if sm.spill is not None:
        hist = sm.spill.store()
        print(f"spill: {hist.n_blocks} expired blocks persisted to "
              f"{args.spill} ({hist.n_tx} tx, {hist.total_bytes} packed "
              f"bytes) — re-minable via `launch.mine --store`")


if __name__ == "__main__":
    main()
