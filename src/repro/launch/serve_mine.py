"""Mine-then-serve driver: the paper's store-owner scenario end to end.

Mines a named IBM database with the frontier-batched Parallel-FIMI pipeline,
builds the serving indexes (FI table → packed FI index, ap-genrules → rule
index), then replays a synthetic query workload through the batched engine
with an LRU cache in front and reports QPS, latency percentiles, and the
cache hit rate.

The workload models serving traffic, not mining: a fixed population of
distinct queries per kind (support lookups, basket→rules, itemset→supersets)
drawn with a Zipf-tilted popularity so hot queries repeat — the regime the
cache exists for.  Every dispatch is a fixed-width batch (one compiled
program per query kind for the whole session).

  python -m repro.launch.serve_mine --db T2I0.048P50PL10TL16 --support 0.1 \\
      --queries 1024 [--frontier 16] [-P 4] [--devices 4] [--batch 256]
"""
from __future__ import annotations

import argparse

from repro.launch.host_devices import preparse_devices

preparse_devices()  # must run before anything imports jax

import time  # noqa: E402

import numpy as np  # noqa: E402

KINDS = ("support", "rules", "superset")


def build_workload(rng, fis, dense, n_items, n_queries, pool=64, zipf_a=1.3):
    """A query stream [(kind, packed_mask_row)] with Zipf-hot repetition."""
    from repro.core.rules import pack_itemsets

    fi_list = sorted(fis, key=lambda s: (len(s), tuple(sorted(s))))
    pools = {}
    # support: indexed FIs plus a sprinkle of (likely) non-frequent probes
    cand = [fi_list[i] for i in rng.choice(len(fi_list),
                                           size=min(pool, len(fi_list)),
                                           replace=False)]
    probes = [
        frozenset(rng.choice(n_items, size=min(6, n_items), replace=False)
                  .tolist())
        for _ in range(max(pool // 8, 1))
    ]
    pools["support"] = cand + probes
    # rules: real baskets — transaction rows of the database
    rows = rng.choice(dense.shape[0], size=min(pool, dense.shape[0]),
                      replace=False)
    pools["rules"] = [frozenset(np.nonzero(dense[t])[0].tolist())
                      for t in rows]
    # superset: small frequent prefixes (completion queries)
    small = [s for s in fi_list if len(s) <= 2] or fi_list[:1]
    pools["superset"] = [small[i] for i in
                         rng.choice(len(small),
                                    size=min(pool, len(small)),
                                    replace=False)]

    packed = {k: pack_itemsets(v, n_items) for k, v in pools.items()}
    mix = rng.choice(len(KINDS), size=n_queries, p=[0.5, 0.3, 0.2])
    stream = []
    for kind_id in mix:
        kind = KINDS[kind_id]
        n = packed[kind].shape[0]
        # Zipf-tilted popularity over the pool (hot queries repeat)
        i = min(int(rng.zipf(zipf_a)) - 1, n - 1)
        stream.append((kind, packed[kind][i]))
    return stream


def _dispatchers(engine):
    """Per-kind batched dispatch: packed masks [n, IW] -> n result values."""
    return {
        "support": lambda m: list(engine.support(m)),
        "rules": lambda m: list(zip(*map(list, engine.rules_for(m)))),
        "superset": lambda m: list(zip(*map(list, engine.supersets(m)))),
    }


def warm(stream, engine):
    """Compile each query kind's program off the clock (deploy-time warm)."""
    dispatch = _dispatchers(engine)
    for kind in KINDS:
        mask = next((m for k, m in stream if k == kind), None)
        if mask is not None:
            dispatch[kind](mask[None])


def replay(stream, engine, cache, batch):
    """Serve the stream in fixed-width batches; return latency samples [s]."""
    from repro.serve.cache import query_key

    dispatch = _dispatchers(engine)
    latencies = []
    n_dispatched = 0
    for lo in range(0, len(stream), batch):
        chunk = stream[lo: lo + batch]
        t0 = time.perf_counter()
        for kind in KINDS:
            rows = [(i, m) for i, (k, m) in enumerate(chunk) if k == kind]
            if not rows:
                continue
            # keys carry the swap generation: a hot-swapped index (the
            # streaming subsystem) can never serve a stale cached hit
            keys = [query_key(kind, m, engine.top_k, engine.generation)
                    for _, m in rows]
            results, miss = cache.split_batch(keys)
            if miss:
                masks = np.stack([rows[j][1] for j in miss])
                vals = dispatch[kind](masks)
                n_dispatched += len(miss)
                cache.fill_batch(keys, results, miss, vals)
        latencies.append(time.perf_counter() - t0)
    return latencies, n_dispatched


def main():
    import jax

    from repro.core import eclat, fimi
    from repro.data.ibm_gen import generate_dense, params_from_name
    from repro.launch.mesh import make_miner_mesh
    from repro.obs.session import add_obs_flags, start_session
    from repro.serve import QueryCache, QueryEngine
    from repro.serve.index import build_indexes

    ap = argparse.ArgumentParser()
    ap.add_argument("--db", default="T2I0.048P50PL10TL16")
    ap.add_argument("--support", type=float, default=0.1)
    ap.add_argument("--variant", default="reservoir",
                    choices=["seq", "par", "reservoir"])
    ap.add_argument("-P", type=int, default=4)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--frontier", type=int, default=16,
                    help="DFS nodes mined per while_loop trip (K)")
    ap.add_argument("--queries", type=int, default=1024)
    ap.add_argument("--batch", type=int, default=256,
                    help="queries per engine dispatch")
    ap.add_argument("--topk", type=int, default=5)
    ap.add_argument("--minconf", type=float, default=0.5)
    ap.add_argument("--cache", type=int, default=2048,
                    help="LRU capacity (0 disables)")
    ap.add_argument("--pool", type=int, default=64,
                    help="distinct queries per kind in the workload")
    ap.add_argument("--seed", type=int, default=0)
    add_obs_flags(ap)
    args = ap.parse_args()
    obs = start_session(args, "serve_mine")

    # ---- mine ---------------------------------------------------------------
    dense = generate_dense(params_from_name(args.db, seed=args.seed))
    n_tx, n_items = dense.shape
    abs_minsup = int(np.ceil(args.support * n_tx))
    shards = fimi.shard_db(dense, args.P)
    params = fimi.FimiParams(
        variant=args.variant, min_support_rel=args.support,
        n_db_sample=min(2048, n_tx), n_fi_sample=1024,
        eclat=eclat.EclatConfig(
            max_out=1 << 15, max_stack=8192, frontier_size=args.frontier
        ),
    )
    use_shard_map = len(jax.devices()) >= args.P
    spmd = fimi.shard_map_spmd if use_shard_map else fimi.vmap_spmd
    mesh = make_miner_mesh(args.P) if use_shard_map else None
    print(f"mine: db={args.db} |D|={n_tx} |B|={n_items} sup={args.support} "
          f"P={args.P} frontier={args.frontier} "
          f"backend={'shard_map' if use_shard_map else 'vmap'}")
    t0 = time.time()
    res = fimi.run(shards, n_items, params, jax.random.PRNGKey(args.seed),
                   spmd=spmd, mesh=mesh, materialize=True)
    fis = res.fi_dict
    print(f"mine: |F| = {len(fis)} in {time.time() - t0:.2f}s")

    # ---- index + rules ------------------------------------------------------
    t0 = time.time()
    fi_index, rule_index = build_indexes(
        fis, n_items, n_tx, min_confidence=args.minconf
    )
    print(f"index: F={fi_index.n_fis} itemsets "
          f"(max size {fi_index.max_size}, {fi_index.n_words} words/mask), "
          f"R={rule_index.n_rules} rules @ conf>={args.minconf} "
          f"in {time.time() - t0:.2f}s")

    # ---- serve --------------------------------------------------------------
    cache = QueryCache(capacity=args.cache)
    engine = QueryEngine(fi_index, rule_index, batch=args.batch,
                         top_k=args.topk, cache=cache)
    rng = np.random.default_rng(args.seed + 1)
    stream = build_workload(rng, fis, dense, n_items, args.queries,
                            pool=args.pool)

    # warm every query kind's compiled program off the clock (a real server
    # warms at deploy time), then replay the measured session
    warm(stream, engine)

    t0 = time.time()
    latencies, n_dispatched = replay(stream, engine, cache, args.batch)
    wall = time.time() - t0
    lat = np.asarray(latencies) * 1e3
    qps = len(stream) / wall
    print(f"serve: {len(stream)} queries in {wall:.3f}s -> {qps:,.0f} QPS "
          f"(batch={args.batch}, {len(latencies)} dispatch rounds, "
          f"{n_dispatched} engine queries after cache)")
    print(f"serve: batch latency ms p50={np.percentile(lat, 50):.2f} "
          f"p95={np.percentile(lat, 95):.2f} "
          f"p99={np.percentile(lat, 99):.2f} max={lat.max():.2f}")
    s = cache.stats
    print(f"cache: {s.hits}/{s.lookups} hits ({s.hit_rate:.1%}), "
          f"{s.evictions} evictions, {s.invalidations} invalidations, "
          f"{len(cache)} resident")
    es = engine.stats()
    print(f"engine: generation={es['generation']} (index hot-swaps; see "
          f"repro.launch.stream_mine) F={es['n_fis']} R={es['n_rules']}")
    if obs:
        obs.event("served", queries=len(stream), dispatched=n_dispatched,
                  qps=qps)
        obs.finish(
            n_fis=fi_index.n_fis, n_rules=rule_index.n_rules, qps=qps,
            serve_wall_s=wall,
            batch_p50_ms=float(np.percentile(lat, 50)),
            batch_p95_ms=float(np.percentile(lat, 95)),
            batch_p99_ms=float(np.percentile(lat, 99)),
            cache_hit_rate=s.hit_rate,
        )

    # a taste of the product: the most confident rules overall
    print(f"top-{min(5, rule_index.n_rules)} rules by confidence:")
    from repro.core.rules import format_rule
    for r in range(min(5, rule_index.n_rules)):
        print("  " + format_rule(rule_index.rule(r), n_tx))


if __name__ == "__main__":
    main()
