"""The performance doctor: ranked, actionable diagnoses over a run record.

PRs 7–9 taught every driver to *record* — spans, the canonical metrics
snapshot, kernel attribution, the perf ledger.  This module *interprets*:
a rules engine over three inputs

  * the canonical snapshot (``metrics.json``),
  * the critical path (:mod:`repro.obs.critpath` over ``trace.json``),
  * the speedup-loss waterfall (:mod:`repro.obs.speedup`, cluster runs),

emitting :class:`Finding` rows — each with a stable rule id, a severity,
the **evidence keys** (the exact gauge/counter/histogram names and values
that triggered it), and a remediation hint naming the knob to turn.

Rule catalog (ids are stable; golden tests diff the exact finding set):

  ``cluster-imbalance``      always on cluster runs: how much speedup the
                             shard load skew costs (info → warn when it
                             dominates the gap).  Evidence: the waterfall
                             imbalance term, ``cluster/imbalance``.
  ``rebalance-not-engaging`` imbalance dominates *and* ``cluster/donations``
                             is 0 — the rebalancer exists but did nothing.
  ``thm61-estimation-error`` always on cluster runs: the paper's own
                             metric — Thm 6.1 sample-estimated vs observed
                             load shares (``cluster/load/estimation_error``,
                             ``cluster/shard{p}/est_load|obs_load``); warn
                             when the unpredicted skew is material.
  ``exchange-dominates``     Phase-3 all_to_all is the largest loss term.
  ``compile-warmup``         round-0 jit warm-up costs a material slice.
  ``prefetch-stall``         ``store/prefetch_stall_s`` p95 above threshold;
                             escalates when store spans sit on the critical
                             path — raise ``host_budget_blocks``.
  ``roofline-regression``    a ``kernels/*/achieved_frac`` gauge dropped vs
                             its trailing median in ``BENCH_HISTORY.jsonl``.
  ``capacity-overflow``      exchange/mine overflow counters nonzero —
                             exactness is at risk; raise capacity factors.
  ``retry-exhausted``        ``store/retry/exhausted`` nonzero (error) /
                             ``store/retry/retried_errors`` nonzero (warn).
  ``service-errors``         serving: ``service/errors`` nonzero.
  ``service-shed``           serving: ``service/shed`` nonzero — queue
                             capacity or offered load needs adjusting.
  ``trace-truncated``        the tracer dropped events (``max_events``);
                             the critical path may be partial.
  ``healthy``                emitted when nothing at warn+ fired.

Severities: ``info`` < ``warn`` < ``error``; ``--gate`` fails the process
when anything ≥ ``error`` fires.  Stdlib-only and jax-free.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.obs import critpath as critpath_mod
from repro.obs import perfdb
from repro.obs import speedup as speedup_mod

SEVERITIES = ("info", "warn", "error")
_RANK = {s: i for i, s in enumerate(SEVERITIES)}


@dataclasses.dataclass
class Finding:
    """One diagnosis: what fired, on what evidence, and what to turn."""

    rule: str                 # stable id from the catalog above
    severity: str             # "info" | "warn" | "error"
    title: str                # one line, rendered in every format
    detail: str               # the why, with numbers
    evidence: Dict[str, float]  # metric/gauge names -> values that triggered
    remediation: str          # the knob to turn

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Thresholds:
    """Every rule's tunable trigger point, in one reviewable place."""

    dominant_frac: float = 0.5       # share of the parallel-phase losses
    min_gap_x: float = 0.25          # ignore dominance below this gap
    imbalance_dominant_x: float = 0.5   # absolute floor for "dominates"
    imbalance_warn: float = 1.5      # max/mean observed load
    est_err_warn: float = 0.15       # Thm 6.1 max |est - obs| load share
    est_loss_warn_x: float = 0.25    # or: speedup lost to unpredicted skew
    exchange_frac: float = 0.3       # exchange share of the gap
    compile_frac: float = 0.3        # compile share of the gap
    stall_p95_warn_s: float = 0.02   # prefetch stall p95
    stall_share_warn: float = 0.10   # stall seconds / wall seconds
    roofline_drop: float = 0.15      # relative achieved_frac drop vs median
    roofline_min_history: int = 3    # rows before the roofline rule gates
    dropped_events_warn: int = 10_000


def _sev_max(a: str, b: str) -> str:
    return a if _RANK[a] >= _RANK[b] else b


def worst_severity(findings: List[Finding]) -> str:
    sev = "info"
    for f in findings:
        sev = _sev_max(sev, f.severity)
    return sev


# ---------------------------------------------------------------------------
# The rules
# ---------------------------------------------------------------------------


def _counters(snap: dict) -> Dict[str, float]:
    return {k: float(v) for k, v in (snap.get("counters") or {}).items()
            if isinstance(v, (int, float))}


def _gauges(snap: dict) -> Dict[str, float]:
    return {k: float(v) for k, v in (snap.get("gauges") or {}).items()
            if isinstance(v, (int, float))}


def _hist(snap: dict, name: str) -> Optional[dict]:
    h = (snap.get("histograms") or {}).get(name)
    return h if isinstance(h, dict) else None


def _term(wf, name: str):
    for t in wf.terms:
        if t.name == name:
            return t
    return None


def _cluster_rules(
    snap: dict, wf, th: Thresholds, out: List[Finding]
) -> None:
    g = _gauges(snap)
    if wf is None and "cluster/imbalance" not in g:
        return
    gap = wf.gap_x if wf else 0.0
    imb = g.get("cluster/imbalance", 1.0)
    donations = _counters(snap).get("cluster/donations", 0.0)

    # -- cluster-imbalance: always emitted, severity scales ------------------
    t_imb = _term(wf, "imbalance") if wf else None
    loss_x = t_imb.loss_x if t_imb else 0.0
    # "dominates" is judged among the PARALLEL-phase losses (imbalance /
    # estimation / exchange / compile): host_tail and driver are the serial
    # fraction — real, but not what the rebalancer or the Thm 6.1 sample
    # can fix, and on small demo runs they swamp everything.  An absolute
    # floor keeps a well-balanced run (tiny parallel losses, big serial
    # overhead) from ever "dominating".
    par = {t.name: t.loss_x for t in wf.terms} if wf else {}
    par_losses = [par.get(k, 0.0)
                  for k in ("imbalance", "estimation", "exchange", "compile")]
    dominates = (
        wf is not None and gap >= th.min_gap_x
        and loss_x >= th.imbalance_dominant_x
        and loss_x >= max(par_losses)
        and loss_x >= th.dominant_frac * sum(par_losses)
    )
    sev = "warn" if (dominates or imb >= th.imbalance_warn) else "info"
    ev = {"cluster/imbalance": imb, "speedup/loss/imbalance_x": loss_x}
    if "cluster/makespan_trips" in g:
        ev["cluster/makespan_trips"] = g["cluster/makespan_trips"]
    out.append(Finding(
        rule="cluster-imbalance", severity=sev,
        title=(f"shard load imbalance (max/mean {imb:.2f}) costs "
               f"{loss_x:.2f}x of speedup"
               + (" — the dominant loss term" if dominates else "")),
        detail=(f"observed per-shard DFS work is uneven; the imbalance "
                f"waterfall term is {loss_x:.2f}x of the "
                f"{gap:.2f}x gap to ideal" if wf else
                f"observed per-shard DFS work max/mean = {imb:.2f}"),
        evidence=ev,
        remediation=("smaller --chunk (finer rounds), rebalancing on, or "
                     "more equivalence classes per shard"),
    ))

    # -- rebalance-not-engaging ---------------------------------------------
    if dominates and donations == 0:
        out.append(Finding(
            rule="rebalance-not-engaging", severity="error",
            title="imbalance dominates but the rebalancer made 0 donations",
            detail=("the imbalance term dominates the speedup gap yet "
                    "cluster/donations is 0: inter-round queue donation "
                    "never engaged"),
            evidence={"cluster/donations": donations,
                      "speedup/loss/imbalance_x": loss_x,
                      "cluster/imbalance": imb},
            remediation=("check rebalance=True / --no-rebalance, raise "
                         "max_donations, or lower the donation threshold"),
        ))

    # -- thm61-estimation-error: the paper's metric, always emitted ----------
    est_err = g.get("cluster/load/estimation_error", 0.0)
    t_est = _term(wf, "estimation") if wf else None
    est_loss = t_est.loss_x if t_est else 0.0
    ev = {"cluster/load/estimation_error": est_err,
          "speedup/loss/estimation_x": est_loss}
    # attach the worst shard's est/obs pair as direct Thm 6.1 evidence
    shards = speedup_mod._shard_loads(g)
    if shards is not None:
        est, obs = shards
        W, E = sum(obs) or 1.0, sum(est) or 1.0
        p_worst = max(range(len(obs)),
                      key=lambda p: abs(obs[p] / W - est[p] / E))
        ev[f"cluster/shard{p_worst}/est_load"] = est[p_worst]
        ev[f"cluster/shard{p_worst}/obs_load"] = obs[p_worst]
    sev = ("warn" if est_err >= th.est_err_warn
           or est_loss >= th.est_loss_warn_x else "info")
    out.append(Finding(
        rule="thm61-estimation-error", severity=sev,
        title=(f"Thm 6.1 load estimation error {est_err:.3f} "
               f"(unpredicted skew costs {est_loss:.2f}x)"),
        detail=("max |estimated - observed| per-shard load share; the "
                "estimation waterfall term prices only the skew the "
                "sample-based plan failed to predict"),
        evidence=ev,
        remediation=("raise the Thm 6.1 sample sizes (n_db_sample / "
                     "n_fi_sample) or loosen eps_db"),
    ))

    if wf is None or gap < th.min_gap_x:
        return

    # -- exchange-dominates --------------------------------------------------
    t_ex = _term(wf, "exchange")
    if t_ex and t_ex.loss_x >= th.exchange_frac * gap and t_ex.loss_x > 0:
        out.append(Finding(
            rule="exchange-dominates", severity="warn",
            title=(f"Phase-3 exchange costs {t_ex.loss_x:.2f}x of the "
                   f"{gap:.2f}x gap"),
            detail="all_to_all transaction exchange wall is a major term",
            evidence={"cluster/phase_ms/exchange": t_ex.ms,
                      "speedup/loss/exchange_x": t_ex.loss_x},
            remediation=("larger --chunk (fewer exchange rounds) or overlap "
                         "exchange with mining"),
        ))

    # -- compile-warmup ------------------------------------------------------
    t_c = _term(wf, "compile")
    if t_c and t_c.loss_x >= th.compile_frac * gap and t_c.loss_x > 0:
        out.append(Finding(
            rule="compile-warmup", severity="info",
            title=(f"round-0 jit warm-up costs {t_c.loss_x:.2f}x "
                   f"({t_c.ms:.0f} ms)"),
            detail=("round 0's mine wall sits above its steady per-trip "
                    "rate: one-time compilation, not algorithmic loss"),
            evidence={"cluster/round0/mine_ms":
                      t_c.evidence.get("cluster/round0/mine_ms", t_c.ms),
                      "speedup/loss/compile_x": t_c.loss_x},
            remediation=("persistent compilation cache, or amortize over "
                         "longer runs before reading speedups"),
        ))


def _store_rules(
    snap: dict, cp: Optional[dict], th: Thresholds, out: List[Finding]
) -> None:
    h = _hist(snap, "store/prefetch_stall_s")
    c = _counters(snap)
    if h and h.get("count", 0) > 0:
        p95 = float(h.get("p95") or 0.0)
        stall_sum = float(h.get("sum") or 0.0)
        wall_s = (cp or {}).get("wall_ms", 0.0) / 1e3
        share = stall_sum / wall_s if wall_s > 0 else 0.0
        on_path = any(
            "store" in r["name"] or "prefetch" in r["name"]
            for r in (cp or {}).get("table", [])
        )
        if p95 > th.stall_p95_warn_s:
            sev = "error" if (on_path or share > th.stall_share_warn) \
                else "warn"
            out.append(Finding(
                rule="prefetch-stall", severity=sev,
                title=(f"prefetch stalls: p95 {p95 * 1e3:.1f} ms, "
                       f"{stall_sum:.2f} s total"
                       + (" — store work on the critical path"
                          if on_path else "")),
                detail=(f"the consumer blocked on disk reads the double "
                        f"buffer failed to hide ({share:.0%} of wall)"
                        if wall_s > 0 else
                        "the consumer blocked on disk reads the double "
                        "buffer failed to hide"),
                evidence={"store/prefetch_stall_s.p95": p95,
                          "store/prefetch_stall_s.sum": stall_sum,
                          "store/blocks_read":
                          c.get("store/blocks_read", 0.0)},
                remediation=("raise host_budget_blocks (--budget-blocks) "
                             "or use larger blocks"),
            ))
    if c.get("store/retry/exhausted", 0) > 0:
        out.append(Finding(
            rule="retry-exhausted", severity="error",
            title=f"{c['store/retry/exhausted']:.0f} I/O retries exhausted",
            detail="a block read/transfer failed past the retry budget",
            evidence={"store/retry/exhausted": c["store/retry/exhausted"],
                      "store/retry/attempts":
                      c.get("store/retry/attempts", 0.0)},
            remediation="check the disk/path; raise RetryPolicy.max_attempts",
        ))
    elif c.get("store/retry/retried_errors", 0) > 0:
        out.append(Finding(
            rule="retry-exhausted", severity="warn",
            title=(f"{c['store/retry/retried_errors']:.0f} transient I/O "
                   "errors were retried"),
            detail="reads succeeded only after retry: flaky storage",
            evidence={"store/retry/retried_errors":
                      c["store/retry/retried_errors"]},
            remediation="inspect the storage path before trusting timings",
        ))


def _overflow_rules(snap: dict, out: List[Finding]) -> None:
    g = _gauges(snap)
    c = _counters(snap)
    total = (g.get("cluster/exchange_overflow", 0)
             + g.get("cluster/mine_overflow", 0)
             + c.get("fimi/exchange_overflow", 0))
    if total > 0:
        out.append(Finding(
            rule="capacity-overflow", severity="error",
            title=f"{total:.0f} buffer overflows: exactness at risk",
            detail=("exchange/mine capacity buffers overflowed; results "
                    "may be truncated unless strict mode raised"),
            evidence={k: v for k, v in
                      {"cluster/exchange_overflow":
                       g.get("cluster/exchange_overflow", 0),
                       "cluster/mine_overflow":
                       g.get("cluster/mine_overflow", 0),
                       "fimi/exchange_overflow":
                       c.get("fimi/exchange_overflow", 0)}.items() if v},
            remediation="raise the capacity factor / frontier cap",
        ))


def _serve_rules(snap: dict, out: List[Finding]) -> None:
    c = _counters(snap)
    errors = c.get("service/errors", 0)
    shed = c.get("service/shed", 0)
    if errors > 0:
        out.append(Finding(
            rule="service-errors", severity="error",
            title=f"{errors:.0f} serving requests errored",
            detail="the mining service returned typed errors",
            evidence={"service/errors": errors},
            remediation="inspect service logs; errors burn the SLO budget",
        ))
    if shed > 0:
        h = _hist(snap, "service/latency_ms") or {}
        out.append(Finding(
            rule="service-shed", severity="warn",
            title=f"{shed:.0f} serving requests shed",
            detail="the admission queue filled; offered load beat capacity",
            evidence={"service/shed": shed,
                      "service/latency_ms.p95":
                      float(h.get("p95") or 0.0)},
            remediation=("raise queue capacity / batch window, or lower "
                         "offered QPS"),
        ))


def _trace_rules(snap: dict, th: Thresholds, out: List[Finding]) -> None:
    c = _counters(snap)
    dropped = c.get("trace/dropped_events", 0)
    if dropped > 0:
        sev = "warn" if dropped >= th.dropped_events_warn else "info"
        out.append(Finding(
            rule="trace-truncated", severity=sev,
            title=f"trace dropped {dropped:.0f} oldest events at its cap",
            detail=("the exported trace is a suffix of the run; critical-"
                    "path and self-time numbers cover only what remains"),
            evidence={"trace/dropped_events": dropped},
            remediation="raise Tracer max_events for full-fidelity traces",
        ))


def _roofline_rules(
    snap: dict, history_rows: Optional[List[dict]], th: Thresholds,
    out: List[Finding],
) -> None:
    if not history_rows:
        return
    fams = {
        k: v for k, v in _gauges(snap).items()
        if k.startswith("kernels/") and k.endswith("/achieved_frac")
    }
    if not fams:
        return
    series = perfdb.trends(history_rows)
    for gauge_name, val in sorted(fams.items()):
        fam = gauge_name.split("/")[1]
        hist = None
        for (_suite, key), pts in series.items():
            if key == gauge_name or key == f"{fam}_achieved_frac":
                hist = [p["value"] for p in pts]
                break
        if not hist or len(hist) < th.roofline_min_history:
            continue
        med = perfdb._median(hist[-8:])
        if med > 0 and val < med * (1.0 - th.roofline_drop):
            out.append(Finding(
                rule="roofline-regression", severity="warn",
                title=(f"kernel family '{fam}' at {val:.2f} of roofline, "
                       f"down from trailing median {med:.2f}"),
                detail=(f"achieved fraction dropped "
                        f"{(1 - val / med):.0%} vs BENCH_HISTORY.jsonl"),
                evidence={gauge_name: val, f"{gauge_name}.median": med},
                remediation=("re-run autotune; check tile shapes against "
                             "the current input sizes"),
            ))


# ---------------------------------------------------------------------------
# diagnose: the engine
# ---------------------------------------------------------------------------


def _wf_dict(wf) -> dict:
    return {
        "P": wf.P, "ideal_x": wf.ideal_x, "measured_x": wf.measured_x,
        "gap_x": wf.gap_x, "wall_ms": wf.wall_ms, "ideal_ms": wf.ideal_ms,
        "additivity_err": wf.additivity_error(), "source": wf.source,
        "terms": [dataclasses.asdict(t) for t in wf.terms],
    }


def diagnose(
    run: dict,
    *,
    history_rows: Optional[List[dict]] = None,
    thresholds: Optional[Thresholds] = None,
    top_n: int = 10,
) -> dict:
    """Run every rule over one loaded run record (``runlog.load_run`` shape).

    Returns ``{"findings": [...], "worst": sev, "critpath": ...,
    "waterfall": ...}`` — findings sorted severity-first, both analysis
    digests included (None when the record lacks the needed input) so the
    renderers and golden tests see one self-contained dict.
    """
    th = thresholds or Thresholds()
    snap = run.get("metrics") or {}
    cp = critpath_mod.analyze(run.get("trace"), top_n=top_n)
    wf = speedup_mod.from_run(run)

    findings: List[Finding] = []
    _cluster_rules(snap, wf, th, findings)
    _store_rules(snap, cp, th, findings)
    _overflow_rules(snap, findings)
    _serve_rules(snap, findings)
    _trace_rules(snap, th, findings)
    _roofline_rules(snap, history_rows, th, findings)

    if worst_severity(findings) == "info":
        detail = "no rule fired above info"
        if wf is not None:
            detail = (f"modeled speedup {wf.measured_x:.2f}x of "
                      f"{wf.ideal_x:.0f}x ideal; no rule fired above info")
        findings.append(Finding(
            rule="healthy", severity="info",
            title="no actionable performance problems found",
            detail=detail, evidence={}, remediation="",
        ))

    findings.sort(key=lambda f: (-_RANK[f.severity], f.rule))
    return {
        "findings": [f.to_dict() for f in findings],
        "worst": worst_severity(findings),
        "critpath": cp,
        "waterfall": _wf_dict(wf) if wf is not None else None,
    }


# ---------------------------------------------------------------------------
# Renderers (shared by obs_report doctor and the drivers' --doctor exit hook)
# ---------------------------------------------------------------------------

_MARK = {"info": "·", "warn": "!", "error": "✗"}


def render_text(report: dict, *, verbose: bool = True) -> str:
    lines: List[str] = []
    cp = report.get("critpath")
    if cp and verbose:
        lines.append(f"critical path (wall {cp['wall_ms']:.1f} ms):")
        lines.append(f"  {'self ms':>9}  {'share':>6}  {'n':>3}  name")
        for r in cp["table"]:
            lines.append(
                f"  {r['self_ms']:>9.2f}  {r['share']:>6.1%}  "
                f"{r['count']:>3d}  {r['name']}"
                + (f"  [{r['tracks']}]" if r["tracks"] else "")
            )
        lines.append("")
    wfd = report.get("waterfall")
    if wfd and verbose:
        wf = _wf_from_dict(wfd)
        lines.append(wf.render_text())
        lines.append("")
    lines.append(f"doctor: {len(report['findings'])} finding(s), "
                 f"worst = {report['worst']}")
    for f in report["findings"]:
        lines.append(f"  {_MARK.get(f['severity'], '?')} "
                     f"[{f['severity']}] {f['rule']}: {f['title']}")
        if verbose and f["detail"]:
            lines.append(f"      {f['detail']}")
        if verbose and f["evidence"]:
            ev = ", ".join(f"{k}={v:.4g}" for k, v in f["evidence"].items())
            lines.append(f"      evidence: {ev}")
        if f["remediation"]:
            lines.append(f"      fix: {f['remediation']}")
    return "\n".join(lines)


def render_markdown(report: dict) -> str:
    lines: List[str] = ["## Performance doctor", ""]
    lines.append(f"**{len(report['findings'])} finding(s)** — worst "
                 f"severity: **{report['worst']}**")
    lines.append("")
    lines.append("| sev | rule | finding | remediation |")
    lines.append("|---|---|---|---|")
    for f in report["findings"]:
        lines.append(f"| {f['severity']} | `{f['rule']}` | {f['title']} | "
                     f"{f['remediation']} |")
    cp = report.get("critpath")
    if cp:
        lines += ["", "### Critical path", "",
                  f"wall: {cp['wall_ms']:.1f} ms", "",
                  "| self ms | share | n | span |", "|---|---|---|---|"]
        for r in cp["table"]:
            lines.append(f"| {r['self_ms']:.2f} | {r['share']:.1%} | "
                         f"{r['count']} | `{r['name']}` |")
    wfd = report.get("waterfall")
    if wfd:
        lines += ["", "### Speedup waterfall", "",
                  _wf_from_dict(wfd).render_markdown()]
    return "\n".join(lines)


def _wf_from_dict(d: dict):
    terms = [speedup_mod.LossTerm(**t) for t in d["terms"]]
    return speedup_mod.Waterfall(
        P=d["P"], ideal_x=d["ideal_x"], measured_x=d["measured_x"],
        wall_ms=d["wall_ms"], ideal_ms=d["ideal_ms"], terms=terms,
        source=d["source"],
    )
