"""Speedup accounting: decompose (ideal P× − measured×) into loss terms.

The paper's headline is a speedup of ~6 on 10 processors, and its method
is an argument about the *gap to ideal*: Thm 6.1 sample estimates bound
how uneven the partition can be, the exchange phases are the price of
independence, and everything else is overhead.  This module turns one
cluster run's telemetry — gauges and spans the executor already records —
into an **additive waterfall** over that gap.

Accounting scheme (exact by construction).  Let ``TP`` be the run's wall
time and ``T_ideal`` the perfectly-parallel time: total observed DFS work
``W = Σ_p obs_load_p`` split ``P`` ways, at the steady per-trip rate ``ρ``
measured on this very run.  Write

    TP = T_ideal + Δ_compile + Δ_estimation + Δ_imbalance
       + Δ_exchange + Δ_host_tail + Δ_driver

with every ``Δ`` ≥ 0 derived below and the last one the residual.  Then
with measured (modeled) speedup ``S = P · T_ideal / TP``,

    P − S  =  Σ_k  P · Δ_k / TP

— each term *is* the speedup lost to that cause, and the terms sum to the
gap exactly (floating point aside), which is what the acceptance gate
checks.  Terms:

  * ``compile``     — round 0's mine wall above its steady-rate cost:
                      jit warm-up (needs per-round ``mine_ms`` gauges).
  * ``estimation``  — skew the planner *failed to predict*: observed vs
                      estimated max load share (the paper's own Thm 6.1
                      metric), priced at ``ρ``.
  * ``imbalance``   — the rest of ``Σ_r max_p − W/P``: planned skew plus
                      round-granularity, the rebalancer's target.
  * ``exchange``    — Phase-3 all_to_all wall (``phase_ms/exchange``).
  * ``host_tail``   — plan + merge + store assembly: serial host work.
  * ``driver``      — wall not inside any phase (only when the manifest
                      carries ``mine_wall_s``).

``S`` is *modeled* — relative to this run's own work at its own rate, the
same convention as ``BENCH_cluster.json``'s trips-based speedups — so one
run decomposes without needing a P=1 partner.  For BENCH curve entries
(which do have the P=1 baseline but no phase detail),
:func:`from_bench_entries` gives the coarser exact split

    P − S  =  [P − S·imbalance]  +  [S·(imbalance − 1)]
               (work inflation)      (load imbalance)

where S = base_makespan/makespan and imbalance = max/mean observed load.

Stdlib-only and jax-free, like the rest of :mod:`repro.obs`.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

#: phase_ms keys that are serial host work at the run's tail/head
_HOST_PHASES = ("plan", "merge", "assemble")


@dataclasses.dataclass
class LossTerm:
    """One cause's share of the speedup gap."""

    name: str                # "imbalance" | "estimation" | ...
    loss_x: float            # speedup units; sums to ideal − measured
    ms: float                # the wall time behind it
    detail: str              # one-line human explanation
    evidence: Dict[str, float] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Waterfall:
    """The additive decomposition of one run's speedup gap."""

    P: int
    ideal_x: float           # = P
    measured_x: float        # modeled: P * T_ideal / TP
    wall_ms: float           # TP
    ideal_ms: float          # T_ideal
    terms: List[LossTerm]
    source: str              # "run" | "bench"

    @property
    def gap_x(self) -> float:
        return self.ideal_x - self.measured_x

    def additivity_error(self) -> float:
        """|Σ terms − gap| / ideal — the acceptance gate checks < 5%."""
        s = sum(t.loss_x for t in self.terms)
        return abs(s - self.gap_x) / max(self.ideal_x, 1e-12)

    def gauges(self) -> Dict[str, float]:
        """The ``speedup/*`` gauge family this waterfall publishes."""
        out = {
            "speedup/ideal_x": self.ideal_x,
            "speedup/measured_x": self.measured_x,
            "speedup/gap_x": self.gap_x,
            "speedup/additivity_err": self.additivity_error(),
        }
        for t in self.terms:
            out[f"speedup/loss/{t.name}_x"] = t.loss_x
        return out

    def publish(self, reg) -> None:
        for name, v in self.gauges().items():
            reg.gauge(name).set(float(v))

    # -- rendering -----------------------------------------------------------
    def rows(self) -> List[Dict[str, object]]:
        rows: List[Dict[str, object]] = [
            {"label": f"ideal ({self.P} shards)", "x": self.ideal_x,
             "kind": "ideal", "detail": ""}
        ]
        for t in sorted(self.terms, key=lambda t: -t.loss_x):
            rows.append({"label": f"− {t.name}", "x": -t.loss_x,
                         "kind": "loss", "detail": t.detail})
        rows.append({"label": "= measured (modeled)", "x": self.measured_x,
                     "kind": "measured", "detail": ""})
        return rows

    def render_text(self, width: int = 34) -> str:
        scale = width / max(self.ideal_x, 1e-12)
        lines = [f"speedup waterfall ({self.source}): ideal {self.ideal_x:.2f}x "
                 f"-> measured {self.measured_x:.2f}x "
                 f"(gap {self.gap_x:.2f}x, additivity err "
                 f"{self.additivity_error():.1%})"]
        running = self.ideal_x
        for r in self.rows():
            x = float(r["x"])  # signed
            if r["kind"] == "loss":
                running += x
            bar_len = max(0, int(round(abs(x) * scale)))
            bar = ("█" if r["kind"] != "loss" else "▒") * bar_len
            detail = f"  {r['detail']}" if r["detail"] else ""
            lines.append(f"  {r['label']:<22} {x:>+7.3f}x "
                         f"|{bar:<{width}}|{detail}")
        return "\n".join(lines)

    def render_markdown(self) -> str:
        lines = [
            f"**speedup waterfall** ({self.source}): ideal "
            f"{self.ideal_x:.2f}× → measured {self.measured_x:.2f}× "
            f"(gap {self.gap_x:.2f}×, additivity err "
            f"{self.additivity_error():.1%})",
            "",
            "| term | Δ speedup | why |",
            "|---|---|---|",
        ]
        for r in self.rows():
            lines.append(f"| {r['label']} | {float(r['x']):+.3f}× | "
                         f"{r['detail']} |")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# From a run record's canonical snapshot
# ---------------------------------------------------------------------------

_SHARD_RE = re.compile(r"^cluster/shard(\d+)/(est_load|obs_load)$")
_ROUND_RE = re.compile(r"^cluster/round(\d+)/(mine_ms|max_trips)$")


def _shard_loads(gauges: Dict[str, float]):
    est: Dict[int, float] = {}
    obs: Dict[int, float] = {}
    for k, v in gauges.items():
        m = _SHARD_RE.match(k)
        if m:
            (est if m.group(2) == "est_load" else obs)[int(m.group(1))] = \
                float(v)
    P = len(obs)
    if P == 0 or len(est) != P:
        return None
    return ([est[p] for p in range(P)], [obs[p] for p in range(P)])


def from_snapshot(
    snapshot: dict, *, wall_ms: Optional[float] = None
) -> Optional[Waterfall]:
    """Build the waterfall from a cluster run's canonical metrics snapshot.

    Needs the ``cluster/shard{p}/{est,obs}_load`` gauges, the
    ``cluster/phase_ms/*`` gauges and ``cluster/makespan_trips``; uses the
    per-round ``cluster/round{r}/{mine_ms,max_trips}`` gauges for the
    compile term when present.  Returns None when the snapshot is not a
    cluster run's.
    """
    gauges = {k: float(v) for k, v in (snapshot.get("gauges") or {}).items()
              if isinstance(v, (int, float))}
    loads = _shard_loads(gauges)
    makespan = gauges.get("cluster/makespan_trips", 0.0)
    mine_ms = gauges.get("cluster/phase_ms/mine", 0.0)
    if loads is None or makespan <= 0 or mine_ms <= 0:
        return None
    est, obs = loads
    P = len(obs)
    W = sum(obs)
    if W <= 0:
        return None

    # per-round detail (for the compile term); tolerate absence
    rounds: Dict[int, Dict[str, float]] = {}
    for k, v in gauges.items():
        m = _ROUND_RE.match(k)
        if m:
            rounds.setdefault(int(m.group(1)), {})[m.group(2)] = float(v)
    mine0 = rounds.get(0, {})
    r0_ms, r0_trips = mine0.get("mine_ms", 0.0), mine0.get("max_trips", 0.0)
    later_ms = mine_ms - r0_ms
    later_trips = makespan - r0_trips
    if len(rounds) >= 2 and r0_ms > 0 and later_trips > 0 and later_ms > 0:
        rho = later_ms / later_trips           # steady ms per critical trip
        d_compile = max(0.0, r0_ms - r0_trips * rho)
    else:
        rho = mine_ms / makespan
        d_compile = 0.0

    t_ideal = (W / P) * rho
    # the skew the planner did not predict: observed vs estimated max share
    est_total = sum(est)
    est_max_share = (max(est) / est_total) if est_total > 0 else 1.0 / P
    obs_max_share = max(obs) / W
    d_imb_total = max(0.0, mine_ms - d_compile - t_ideal)
    d_est = min(
        d_imb_total,
        max(0.0, (obs_max_share - est_max_share) * W * rho),
    )
    d_imb = d_imb_total - d_est

    phase = {
        k.rsplit("/", 1)[-1]: v
        for k, v in gauges.items() if k.startswith("cluster/phase_ms/")
    }
    d_exchange = max(0.0, phase.get("exchange", 0.0))
    d_host = sum(max(0.0, phase.get(p, 0.0)) for p in _HOST_PHASES)
    d_host += sum(
        max(0.0, v) for k, v in phase.items()
        if k not in _HOST_PHASES + ("exchange", "mine")
    )
    tp_phases = t_ideal + d_compile + d_est + d_imb + d_exchange + d_host
    d_driver = max(0.0, (wall_ms or 0.0) - tp_phases)
    TP = tp_phases + d_driver

    def loss(ms: float) -> float:
        return P * ms / TP

    imb_gauge = gauges.get("cluster/imbalance", max(obs) / (W / P))
    est_err = gauges.get("cluster/load/estimation_error", 0.0)
    terms = [
        LossTerm("imbalance", loss(d_imb), d_imb,
                 "shard load skew + round granularity "
                 f"(max/mean = {imb_gauge:.2f})",
                 {"cluster/imbalance": imb_gauge,
                  "cluster/makespan_trips": makespan}),
        LossTerm("estimation", loss(d_est), d_est,
                 "skew the Thm 6.1 sample did not predict "
                 f"(est max share {est_max_share:.3f} vs obs "
                 f"{obs_max_share:.3f})",
                 {"cluster/load/estimation_error": est_err}),
        LossTerm("exchange", loss(d_exchange), d_exchange,
                 "Phase-3 all_to_all transaction exchange",
                 {"cluster/phase_ms/exchange": d_exchange}),
        LossTerm("compile", loss(d_compile), d_compile,
                 "round-0 jit warm-up above the steady per-trip rate",
                 {"cluster/round0/mine_ms": r0_ms}),
        LossTerm("host_tail", loss(d_host), d_host,
                 "serial host work: plan + merge + store assembly",
                 {f"cluster/phase_ms/{p}": phase.get(p, 0.0)
                  for p in _HOST_PHASES if p in phase}),
    ]
    if d_driver > 0:
        terms.append(LossTerm(
            "driver", loss(d_driver), d_driver,
            "wall time outside every recorded phase", {}))
    return Waterfall(
        P=P, ideal_x=float(P), measured_x=P * t_ideal / TP,
        wall_ms=TP, ideal_ms=t_ideal, terms=terms, source="run",
    )


def from_run(run: dict) -> Optional[Waterfall]:
    """Waterfall from a loaded run record (``runlog.load_run`` shape)."""
    metrics = run.get("metrics") or {}
    man = run.get("manifest") or {}
    wall = man.get("mine_wall_s")
    wall_ms = float(wall) * 1e3 if isinstance(wall, (int, float)) else None
    return from_snapshot(metrics, wall_ms=wall_ms)


# ---------------------------------------------------------------------------
# From BENCH_cluster.json curve entries
# ---------------------------------------------------------------------------


def from_bench_entries(entries: List[dict]) -> Dict[int, Waterfall]:
    """The coarse two-term decomposition per curve point (see module doc).

    Uses the P=1 entry's makespan as the serial baseline; each P>1 entry
    splits its gap exactly into work inflation (replication + round
    granularity growing ``Σ obs`` with P) and load imbalance
    (``max/mean``).  Keyed by P.
    """
    curve = [e for e in entries
             if e.get("name") == "cluster_speedup"
             and isinstance(e.get("makespan_trips"), (int, float))]
    base = next((e for e in curve if e.get("P") == 1), None)
    if base is None:
        return {}
    base_mk = float(base["makespan_trips"])
    out: Dict[int, Waterfall] = {}
    for e in curve:
        P = int(e.get("P", 0))
        mk = float(e["makespan_trips"])
        if P <= 1 or mk <= 0:
            continue
        S = base_mk / mk
        imb = float(e.get("imbalance", 1.0))
        s_balanced = S * imb            # speedup if max == mean at same work
        terms = [
            LossTerm("inflation", P - s_balanced, 0.0,
                     "work growth with P: replication + round granularity",
                     {"makespan_trips": mk, "base_makespan_trips": base_mk}),
            LossTerm("imbalance", s_balanced - S, 0.0,
                     f"shard load skew (max/mean = {imb:.2f})",
                     {"imbalance": imb}),
        ]
        out[P] = Waterfall(
            P=P, ideal_x=float(P), measured_x=S,
            wall_ms=float(e.get("wall_s", 0.0)) * 1e3, ideal_ms=0.0,
            terms=terms, source="bench",
        )
    return out


def bench_loss_keys(entries: List[dict]) -> Dict[str, float]:
    """Flat ``loss_*`` keys for BENCH_cluster.json / the perf ledger.

    ``loss_imbalance_x_p4 = 0.7`` reads "0.7× of speedup lost to imbalance
    at P=4" — lower is better, which :mod:`repro.obs.perfdb` infers from
    the ``loss`` prefix, so the trajectory ledger tracks *why* speedup
    moves, not just that it moved.
    """
    out: Dict[str, float] = {}
    for P, wf in sorted(from_bench_entries(entries).items()):
        for t in wf.terms:
            out[f"loss_{t.name}_x_p{P}"] = round(t.loss_x, 6)
        # "loss_total", not "speedup_gap": the "speedup" substring would
        # flip the perfdb direction inference to higher-is-better
        out[f"loss_total_x_p{P}"] = round(wf.gap_x, 6)
    return out
