"""Unified observability layer: metrics registry, span tracing, run records.

Zero-dependency (stdlib-only at import; jax strictly lazy) so it is usable
from every layer — kernels' host glue, the store's prefetch thread, the
jax-free report CLI.  See DESIGN.md, "Observability".

  * :mod:`repro.obs.metrics` — process-global counters / gauges /
    log-bucketed latency histograms, one canonical snapshot shape;
  * :mod:`repro.obs.trace`   — nested host spans, Chrome trace-event
    export (Perfetto), device ``sync`` helper, ``jax_profiler`` hook;
  * :mod:`repro.obs.runlog`  — per-run manifest + JSONL events + metrics
    snapshot, read back by ``launch/obs_report.py``;
  * :mod:`repro.obs.session` — the shared ``--trace`` / ``--metrics``
    driver glue (crash-safe: atexit/SIGTERM partial flush);
  * :mod:`repro.obs.slo`     — sliding-window histograms/counters and the
    SLO policy engine (windowed p50/p95/p99/QPS/shed-rate, error-budget
    burn-rate alerts with hysteresis) behind the serving front end;
  * :mod:`repro.obs.machine` — the shared roofline machine constants
    (factored out of ``benchmarks/roofline.py``);
  * :mod:`repro.obs.profile` — the kernel profiler: per-dispatch-family
    measured-vs-modeled time attribution and bound-ness verdicts;
  * :mod:`repro.obs.progress` — the sample-grounded live progress/ETA
    estimator fed by planner loads and observed DFS trips;
  * :mod:`repro.obs.perfdb`  — the persistent perf trajectory
    (``BENCH_HISTORY.jsonl`` append / trend / regression check);
  * :mod:`repro.obs.critpath` — span-DAG reconstruction over a run
    record's ``trace.json``: critical path + exclusive self-time;
  * :mod:`repro.obs.speedup` — the additive speedup-loss waterfall
    (imbalance / Thm 6.1 estimation error / exchange / compile / host);
  * :mod:`repro.obs.doctor`  — the rules engine turning snapshot +
    critical path + waterfall into ranked findings with evidence keys.
"""
from repro.obs.critpath import SpanDag, critical_path  # noqa: F401
from repro.obs.doctor import Finding, Thresholds, diagnose  # noqa: F401
from repro.obs.machine import MachineModel, machine_for_backend  # noqa: F401
from repro.obs.metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    registry,
    snapshot,
)
from repro.obs.profile import KernelProfiler, cost_model, profiler  # noqa: F401
from repro.obs.perfdb import check_regressions, trends  # noqa: F401
from repro.obs.progress import ProgressEstimator, ProgressSnapshot  # noqa: F401
from repro.obs.runlog import RunLog, load_run  # noqa: F401
from repro.obs.speedup import LossTerm, Waterfall  # noqa: F401
from repro.obs.slo import (  # noqa: F401
    SLOPolicy,
    SLOStatus,
    SLOTracker,
    WindowedCounter,
    WindowedHistogram,
)
from repro.obs.trace import TRACER, Tracer, tracer  # noqa: F401
