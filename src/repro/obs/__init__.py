"""Unified observability layer: metrics registry, span tracing, run records.

Zero-dependency (stdlib-only at import; jax strictly lazy) so it is usable
from every layer — kernels' host glue, the store's prefetch thread, the
jax-free report CLI.  See DESIGN.md, "Observability".

  * :mod:`repro.obs.metrics` — process-global counters / gauges /
    log-bucketed latency histograms, one canonical snapshot shape;
  * :mod:`repro.obs.trace`   — nested host spans, Chrome trace-event
    export (Perfetto), device ``sync`` helper, ``jax_profiler`` hook;
  * :mod:`repro.obs.runlog`  — per-run manifest + JSONL events + metrics
    snapshot, read back by ``launch/obs_report.py``;
  * :mod:`repro.obs.session` — the shared ``--trace`` / ``--metrics``
    driver glue.
"""
from repro.obs.metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    registry,
    snapshot,
)
from repro.obs.runlog import RunLog, load_run  # noqa: F401
from repro.obs.trace import TRACER, Tracer, tracer  # noqa: F401
