"""Sliding-window telemetry + SLO policy engine for the serving tier.

PR 7's :mod:`repro.obs.metrics` answers "what happened over the whole
process lifetime" — the right shape for a mining run that starts, works,
and exits.  A serving front end (:mod:`repro.serve.service`) needs the
*live* half: what are p99 latency, QPS and the shed rate **right now**,
where "now" is the last W seconds, not since boot?  This module is that
view, plus the policy that acts on it:

  * :class:`WindowedHistogram` — a ring of ``slots`` log-bucketed
    :class:`~repro.obs.metrics.Histogram`\\ s, one per rotation interval of
    ``window_s / slots`` wall-clock seconds.  Recording lands in the
    current slot; reading merges the ring into one histogram, so
    p50/p95/p99 reflect exactly the samples of the trailing window (slot
    granularity: a sample expires between ``window_s - rotate_s`` and
    ``window_s`` seconds after it arrived).  Merging is exact — the
    per-slot buckets share boundaries, so the merged percentile walk is
    the percentile walk over the union stream, same ``sqrt(growth)``
    error bound as the base histogram (numpy-verified over rotating
    windows in ``tests/test_slo.py``).
  * :class:`WindowedCounter` — the same ring over plain counts;
    ``rate()`` is events per second over the trailing window (QPS, shed
    rate, error rate).
  * :class:`SLOPolicy` / :class:`SLOTracker` — the objectives (windowed
    p99 latency bound + availability target) and the alerting state
    machine.  Availability alerts are **error-budget burn-rate** alerts in
    the SRE sense: with budget ``1 - availability``, the burn rate is
    ``bad_fraction / budget`` — burn 1.0 spends the budget exactly at the
    allowed pace, burn 2.0 spends it twice as fast.  Both alert kinds
    have hysteresis (fire at/above ``burn_hi`` / the latency objective,
    clear only below ``burn_lo`` / ``latency_clear`` × objective) so a
    workload hovering at the threshold cannot flap the pager.

Everything here is stdlib-only (the obs layering rule), thread-safe (the
service's dispatcher thread records while the dashboard thread reads),
and takes an injectable ``clock`` so the window/alert math is unit-tested
against a fake clock with zero wall-time dependence.

Alert *transitions* come back from :meth:`SLOTracker.evaluate` as event
dicts and are also pushed to ``on_alert`` callbacks — the load harness
(``launch/serve_load.py``) wires those to trace instants, run-record
events, and its non-zero gate exit.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional

from repro.obs.metrics import Histogram


class _Ring:
    """Shared rotation bookkeeping: absolute slot index from the clock.

    Slot ``k = floor((now - epoch) / rotate_s)``; the ring cell is
    ``k % slots``.  Advancing from the last seen ``k`` clears every cell
    that a skipped interval invalidated, so an idle stretch longer than
    the window leaves the ring empty — time moves the window forward even
    when no samples arrive.
    """

    def __init__(self, window_s: float, slots: int, clock):
        assert window_s > 0 and slots >= 2, (window_s, slots)
        self.window_s = float(window_s)
        self.slots = slots
        self.rotate_s = self.window_s / slots
        self.clock = clock
        self.epoch = clock()
        self.cur_k = 0

    def advance(self, clear_cell) -> int:
        """Rotate to the clock's slot, clearing expired cells; returns the
        current ring cell index."""
        k = int((self.clock() - self.epoch) / self.rotate_s)
        if k > self.cur_k:
            step = min(k - self.cur_k, self.slots)
            for j in range(1, step + 1):
                clear_cell((self.cur_k + j) % self.slots)
            self.cur_k = k
        return self.cur_k % self.slots

    def coverage_s(self) -> float:
        """Seconds of traffic the ring currently represents (ramps from 0
        to ``window_s`` after start/idle — keeps early rates honest)."""
        return min(self.window_s, max(self.clock() - self.epoch, 1e-6))


class WindowedHistogram:
    """Trailing-window latency distribution: a ring of log-bucket slots."""

    def __init__(self, name: str, window_s: float = 30.0, slots: int = 6,
                 growth: float = 1.08, clock=time.monotonic):
        self.name = name
        self.growth = growth
        self._ring = _Ring(window_s, slots, clock)
        self._slots = [Histogram(f"{name}[{i}]", growth)
                       for i in range(slots)]
        self._lock = threading.Lock()

    @property
    def window_s(self) -> float:
        return self._ring.window_s

    def record(self, v: float) -> None:
        with self._lock:
            cell = self._ring.advance(lambda i: self._slots[i].clear())
            self._slots[cell].record(v)

    def merged(self) -> Histogram:
        """One histogram holding exactly the live window's samples."""
        acc = Histogram(self.name, self.growth)
        with self._lock:
            self._ring.advance(lambda i: self._slots[i].clear())
            for h in self._slots:
                acc.merge_from(h)
        return acc

    def percentile(self, q: float) -> Optional[float]:
        return self.merged().percentile(q)

    @property
    def count(self) -> int:
        return self.merged().count

    def summary(self) -> Dict[str, Optional[float]]:
        return self.merged().summary()


class WindowedCounter:
    """Trailing-window event count; ``rate()`` = events/s over the window."""

    def __init__(self, name: str, window_s: float = 30.0, slots: int = 6,
                 clock=time.monotonic):
        self.name = name
        self._ring = _Ring(window_s, slots, clock)
        self._cells = [0] * slots
        self._lock = threading.Lock()

    def _clear(self, i: int) -> None:
        self._cells[i] = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            cell = self._ring.advance(self._clear)
            self._cells[cell] += n

    @property
    def value(self) -> int:
        """Events inside the trailing window."""
        with self._lock:
            self._ring.advance(self._clear)
            return sum(self._cells)

    def rate(self) -> float:
        """Events per second over the (possibly still ramping) window."""
        with self._lock:
            self._ring.advance(self._clear)
            return sum(self._cells) / self._ring.coverage_s()


# ---------------------------------------------------------------------------
# SLO policy + alerting state machine
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SLOPolicy:
    """The objectives a serving window is held to.

    ``availability`` is the fraction of requests that must be *served*
    (not shed, not errored); its complement is the error budget the burn
    rate is measured against.  ``p99_ms`` bounds the windowed p99 latency
    of served requests.  ``min_requests`` keeps a near-empty window from
    alerting on noise (one shed request out of three is not an outage).
    """

    p99_ms: float = 50.0
    availability: float = 0.999
    window_s: float = 30.0
    slots: int = 6
    burn_hi: float = 2.0          # fire availability alert at/above this burn
    burn_lo: float = 1.0          # clear only below this burn (hysteresis)
    latency_clear: float = 0.8    # clear latency alert below this × p99_ms
    min_requests: int = 20

    @property
    def budget(self) -> float:
        """Allowed bad fraction per window (the error budget)."""
        return max(1.0 - self.availability, 1e-9)


@dataclasses.dataclass
class SLOStatus:
    """One evaluation of the live window against the policy."""

    t: float
    window_s: float
    total: int                    # requests that entered the window
    served: int
    shed: int
    errors: int
    qps: float                    # served per second (trailing window)
    offered_qps: float            # served + shed + errors per second
    shed_rate: float              # (shed + errors) / total
    burn_rate: float              # shed_rate / error budget
    p50_ms: Optional[float]
    p95_ms: Optional[float]
    p99_ms: Optional[float]
    latency_ok: bool
    availability_ok: bool
    alert_active: bool
    events: List[dict]            # alert transitions THIS evaluation


class SLOTracker:
    """Records request outcomes; evaluates the window against the policy.

    The recording side (:meth:`record_ok` / :meth:`record_shed` /
    :meth:`record_error`) is called by the service on its dispatcher
    thread; :meth:`evaluate` is called by whoever acts on the state — the
    harness dashboard tick, a router.  Alert state transitions are edge
    events: each fire/clear is reported exactly once, both in the returned
    :class:`SLOStatus` and to every ``on_alert`` callback.
    """

    def __init__(self, policy: SLOPolicy, clock=time.monotonic,
                 name: str = "service"):
        self.policy = policy
        self.name = name
        self._clock = clock
        p = policy
        self.latency = WindowedHistogram(
            f"{name}/window/latency_ms", p.window_s, p.slots, clock=clock)
        self._served = WindowedCounter(
            f"{name}/window/served", p.window_s, p.slots, clock=clock)
        self._shed = WindowedCounter(
            f"{name}/window/shed", p.window_s, p.slots, clock=clock)
        self._errors = WindowedCounter(
            f"{name}/window/errors", p.window_s, p.slots, clock=clock)
        self._lock = threading.Lock()
        self._burn_active = False
        self._latency_active = False
        self.alerts: List[dict] = []      # every transition, timestamped
        self._callbacks: List[Callable[[dict], None]] = []

    # -- recording (dispatcher thread) ---------------------------------------
    def record_ok(self, latency_ms: float) -> None:
        self.latency.record(latency_ms)
        self._served.inc()

    def record_shed(self) -> None:
        self._shed.inc()

    def record_error(self) -> None:
        self._errors.inc()

    def on_alert(self, cb: Callable[[dict], None]) -> None:
        self._callbacks.append(cb)

    # -- evaluation -----------------------------------------------------------
    def _transition(self, events: List[dict], kind: str, objective: str,
                    **fields) -> None:
        ev = {"kind": kind, "objective": objective, "slo": self.name,
              "t": self._clock(), **fields}
        events.append(ev)
        self.alerts.append(ev)
        for cb in self._callbacks:
            cb(ev)

    def evaluate(self) -> SLOStatus:
        p = self.policy
        with self._lock:
            served = self._served.value
            shed = self._shed.value
            errors = self._errors.value
            total = served + shed + errors
            summ = self.latency.summary()
            bad = shed + errors
            shed_rate = bad / total if total else 0.0
            burn = shed_rate / p.budget
            p99 = summ["p99"]
            enough = total >= p.min_requests
            latency_breached = (
                enough and p99 is not None and p99 > p.p99_ms
            )
            availability_ok = not (enough and burn >= p.burn_hi)
            events: List[dict] = []
            # burn-rate alert: fire >= burn_hi, clear < burn_lo
            if not self._burn_active and enough and burn >= p.burn_hi:
                self._burn_active = True
                self._transition(events, "slo_alert", "availability",
                                 burn_rate=burn, shed_rate=shed_rate,
                                 budget=p.budget)
            elif self._burn_active and burn < p.burn_lo:
                self._burn_active = False
                self._transition(events, "slo_clear", "availability",
                                 burn_rate=burn)
            # latency alert: fire > p99_ms, clear < latency_clear * p99_ms
            if not self._latency_active and latency_breached:
                self._latency_active = True
                self._transition(events, "slo_alert", "latency",
                                 p99_ms=p99, objective_ms=p.p99_ms)
            elif self._latency_active and (
                p99 is None or p99 < p.latency_clear * p.p99_ms
            ):
                self._latency_active = False
                self._transition(events, "slo_clear", "latency", p99_ms=p99)
            return SLOStatus(
                t=self._clock(),
                window_s=p.window_s,
                total=total,
                served=served,
                shed=shed,
                errors=errors,
                qps=self._served.rate(),
                offered_qps=(self._served.rate() + self._shed.rate()
                             + self._errors.rate()),
                shed_rate=shed_rate,
                burn_rate=burn,
                p50_ms=summ["p50"],
                p95_ms=summ["p95"],
                p99_ms=p99,
                latency_ok=not latency_breached,
                availability_ok=availability_ok,
                alert_active=self._burn_active or self._latency_active,
                events=events,
            )

    def alerts_since(self, t: float) -> List[dict]:
        """Alert *fire* transitions at or after ``t`` (the harness gates on
        alerts inside the measured phase, ignoring the ramp)."""
        return [ev for ev in self.alerts
                if ev["kind"] == "slo_alert" and ev["t"] >= t]
