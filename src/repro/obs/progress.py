"""Live mining progress/ETA from the paper's sample-based load estimates.

Thm 6.1 of the source paper bounds how well a database sample predicts each
processor's mining load; PR 4 used that only *post hoc* (the
``fimi/load/estimation_error`` metric).  This module promotes it to a
runtime signal: a :class:`ProgressEstimator` is seeded with the planner's
per-shard estimated loads (the same units ``schedule.loads_of`` /
``cluster.planner`` assign with) and fed observed completions as mining
proceeds; it answers, at any moment, *how far along is the run, when will
it finish, and which shard is dragging the barrier*.

ETA math (barrier-aware)
------------------------
Mining rounds are barriers — a round ends when its **slowest** shard does —
so a fleet-average rate systematically underestimates the finish time.
Per shard ``p`` with estimated total ``E_p``, completed ``D_p`` and
observed per-shard rate ``r_p`` (units/s),

    eta = max_p (E_p − D_p) / r_p

i.e. the projected finish of the slowest remaining shard.  Rates use a
**warm-up discount**: once a second update exists, the first inter-update
interval (which swallows jit compilation) is dropped from every shard's
rate window — ``r_p = (D_p − D_p¹) / (t − t¹)`` — so early ETAs are not
inflated by compile time that will never recur.

Straggler score
---------------
``s_p`` = shard ``p``'s observed cost per estimated unit, normalized by the
fleet mean (trips per unit when trip telemetry is supplied, seconds per
unit otherwise).  ``s_p ≈ 1`` means the sample predicted shard ``p``'s
load well; ``s_p > 1`` flags the shard as slower than modeled — the live
version of the paper's estimation-error bound, and the signal the
executor's rebalancer acts on.

Outputs: gauges (``progress/{frac, eta_s, elapsed_s, round}``,
``progress/shard<p>/straggler``), a Perfetto counter track
(``Tracer.counter``), a one-line live string for the drivers, and a
post-run midpoint ETA error (``progress/eta_rel_err_mid``) that
``tools/check.sh --profile`` gates against the acceptance threshold.

Deliberately jax-free and clock-injectable (the ETA tests run on a fake
clock against an offline oracle).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


@dataclasses.dataclass
class ProgressSnapshot:
    """One observation of run progress."""

    frac: float                     # completed fraction of estimated work
    elapsed_s: float                # since start()
    eta_s: Optional[float]          # None until a rate exists
    rate: float                     # fleet units/s over the rate window
    round: int                      # updates observed so far
    stragglers: List[float]         # per-shard score (1.0 = as modeled)

    def line(self) -> str:
        """The drivers' live status line."""
        eta = f"{self.eta_s:6.1f}s" if self.eta_s is not None else "   ?  "
        worst = max(self.stragglers) if self.stragglers else 1.0
        return (
            f"progress {100.0 * self.frac:5.1f}%  eta {eta}  "
            f"elapsed {self.elapsed_s:6.1f}s  round {self.round}  "
            f"worst-straggler {worst:.2f}x"
        )


class ProgressEstimator:
    """Turn per-shard load estimates + observed completions into ETA."""

    def __init__(
        self,
        est_loads: Sequence[float],
        *,
        clock: Callable[[], float] = time.monotonic,
        registry: Optional[obs_metrics.MetricsRegistry] = None,
        tracer: Optional[obs_trace.Tracer] = None,
        publish: bool = True,
    ):
        self._est = [max(float(e), 1.0) for e in est_loads]
        self._P = len(self._est)
        self._done = [0.0] * self._P
        self._trips = [0.0] * self._P
        self._clock = clock
        self._reg = registry
        self._tracer = tracer
        self._publish = publish
        self._t0: Optional[float] = None
        # rate window anchor: state as of the FIRST update (warm-up discount)
        self._t1: Optional[float] = None
        self._done1: Optional[List[float]] = None
        self._round = 0
        self._history: List[ProgressSnapshot] = []

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> None:
        self._t0 = self._clock()

    @property
    def total_est(self) -> float:
        return sum(self._est)

    # -- feeding -------------------------------------------------------------
    def update(
        self,
        done_delta: Sequence[float],
        trips_delta: Optional[Sequence[float]] = None,
    ) -> ProgressSnapshot:
        """Account per-shard work completed since the previous update.

        ``done_delta`` is in the planner's estimated-load units (the
        executor feeds each round's ``est_mined``); ``trips_delta`` is the
        matching observed DFS trip counts when available — it sharpens the
        straggler score from time-based to work-based.
        """
        if self._t0 is None:
            self.start()
        now = self._clock()
        for p in range(self._P):
            self._done[p] += float(done_delta[p])
            if trips_delta is not None:
                self._trips[p] += float(trips_delta[p])
        self._round += 1
        if self._round == 1:
            self._t1 = now
            self._done1 = list(self._done)
        snap = self._snapshot(now)
        self._history.append(snap)
        if self._publish:
            self._export(snap)
        return snap

    # -- math ----------------------------------------------------------------
    def _rates(self, now: float) -> List[float]:
        """Per-shard units/s over the warm-up-discounted window."""
        rates = []
        for p in range(self._P):
            if (
                self._round >= 2
                and self._t1 is not None
                and now > self._t1 + 1e-9
            ):
                r = (self._done[p] - self._done1[p]) / (now - self._t1)
            elif self._t0 is not None and now > self._t0 + 1e-9:
                r = self._done[p] / (now - self._t0)
            else:
                r = 0.0
            rates.append(r)
        return rates

    def _snapshot(self, now: float) -> ProgressSnapshot:
        elapsed = now - (self._t0 if self._t0 is not None else now)
        total = self.total_est
        frac = min(sum(self._done) / total, 1.0) if total > 0 else 0.0
        rates = self._rates(now)
        etas = []
        for p in range(self._P):
            remaining = max(self._est[p] - self._done[p], 0.0)
            if remaining <= 0.0:
                etas.append(0.0)
            elif rates[p] > 0.0:
                etas.append(remaining / rates[p])
        eta = max(etas) if etas else None

        # straggler: observed cost per estimated unit vs fleet mean
        if any(t > 0 for t in self._trips):
            cost = [
                self._trips[p] / max(self._done[p], 1.0)
                for p in range(self._P)
            ]
        else:
            mean_rate = sum(rates) / self._P if self._P else 0.0
            cost = [
                (mean_rate / rates[p]) if rates[p] > 0 else 1.0
                for p in range(self._P)
            ]
        mean_cost = sum(cost) / len(cost) if cost else 1.0
        stragglers = [
            c / mean_cost if mean_cost > 0 else 1.0 for c in cost
        ]
        return ProgressSnapshot(
            frac=frac,
            elapsed_s=elapsed,
            eta_s=eta,
            rate=sum(rates),
            round=self._round,
            stragglers=stragglers,
        )

    def snapshot(self) -> ProgressSnapshot:
        return self._snapshot(self._clock())

    # -- export --------------------------------------------------------------
    def _export(self, snap: ProgressSnapshot) -> None:
        reg = self._reg or obs_metrics.registry()
        reg.gauge("progress/frac").set(snap.frac)
        reg.gauge("progress/elapsed_s").set(snap.elapsed_s)
        reg.gauge("progress/round").set(float(snap.round))
        if snap.eta_s is not None:
            reg.gauge("progress/eta_s").set(snap.eta_s)
        for p, s in enumerate(snap.stragglers):
            reg.gauge(f"progress/shard{p}/straggler").set(s)
        tr = self._tracer or obs_trace.tracer()
        tr.counter(
            "mining progress",
            percent=100.0 * snap.frac,
            eta_s=snap.eta_s if snap.eta_s is not None else 0.0,
        )

    def finish(self) -> Optional[float]:
        """Seal the run: midpoint-ETA relative error vs what really remained.

        Finds the first update at ≥ 50 % completed work, compares the ETA
        it printed against the actual time from that update to now, and
        publishes ``progress/eta_rel_err_mid`` — the acceptance number
        (\"ETA at the mining midpoint within 25 % of actual remaining\").
        Returns the error, or None when the run never crossed the midpoint
        with a usable ETA (single-round runs).
        """
        now = self._clock()
        mid = next(
            (
                s for s in self._history
                if s.frac >= 0.5 and s.eta_s is not None and s.frac < 1.0
            ),
            None,
        )
        err: Optional[float] = None
        if mid is not None and self._t0 is not None:
            actual_remaining = (now - self._t0) - mid.elapsed_s
            if actual_remaining > 1e-9:
                err = abs(mid.eta_s - actual_remaining) / actual_remaining
        if self._publish and err is not None:
            reg = self._reg or obs_metrics.registry()
            reg.gauge("progress/eta_rel_err_mid").set(err)
        return err
