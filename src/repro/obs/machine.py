"""Machine models: the hardware constants every roofline consumer shares.

One frozen :class:`MachineModel` per target — peak arithmetic throughput,
HBM/DRAM bandwidth, interconnect link bandwidth — factored out of
``benchmarks/roofline.py`` so the LLM roofline tables and the mining-kernel
profiler (:mod:`repro.obs.profile`) price work against the SAME constants
instead of each hard-coding its own copy.  Stdlib-only and jax-free (the
layering rule of :mod:`repro.obs`): the report CLI recomputes roofline terms
from these numbers in contexts where jax never loads.

Two units of "flops" coexist deliberately:

  * the LLM roofline prices bf16 MXU FLOPs (``peak_flops`` of ``TPU_V5E``
    is the 197 TFLOP/s bf16 figure from the brief);
  * the mining kernels are integer word machines — one "op" is one 32-bit
    word operation (AND / popcount / add).  ``word_ops_peak`` is the
    sustained word-op throughput the kernels can reach on that target
    (VPU lanes on TPU, vectorized scalar units on CPU).

The **machine balance** ``word_ops_peak / hbm_bw`` (ops per byte) is what
classifies a kernel family as compute- or memory-bound: a family whose
arithmetic intensity (modeled word-ops per modeled byte) falls below the
balance is bandwidth-limited — exactly the single-prefix vs batched-frontier
distinction PR 1 exploited (DESIGN.md, "Performance attribution").
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MachineModel:
    """Roofline constants of one execution target."""

    name: str
    peak_flops: float       # bf16 FLOP/s (dense-matmul peak; LLM roofline)
    hbm_bw: float           # bytes/s main-memory bandwidth
    link_bw: float          # bytes/s per interconnect link
    word_ops_peak: float    # 32-bit word ops/s (mining-kernel peak)

    @property
    def balance_word_ops_per_byte(self) -> float:
        """Machine balance for the word-op kernels: ops/byte at the ridge."""
        return self.word_ops_peak / self.hbm_bw


#: TPU v5e, from the brief: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s ICI.
#: Word-op peak: 8 VPU lanes × 128 sublanes × ~3 ops/cycle @ ~0.9 GHz is
#: O(1e12); we use a conservative 1e12 sustained.
TPU_V5E = MachineModel(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    link_bw=50e9,
    word_ops_peak=1e12,
)

#: A container-class x86 host (the CI target): XLA:CPU multithreaded.
#: ~50 G sustained 32-bit vector word-ops/s and ~20 GB/s effective stream
#: bandwidth are deliberately round numbers — the profiler's verdicts
#: compare *terms against each other*, so only their ratio (the balance,
#: 2.5 ops/byte) needs to be in the right regime.
CPU_HOST = MachineModel(
    name="cpu-host",
    peak_flops=2e11,
    hbm_bw=20e9,
    link_bw=10e9,
    word_ops_peak=5e10,
)


def machine_for_backend(backend: str | None) -> MachineModel:
    """The model to price kernels against on a given jax backend name."""
    if backend and backend.lower() in ("tpu",):
        return TPU_V5E
    return CPU_HOST
