"""Run records: a manifest + JSONL event log + metrics/trace files per run.

Every observed run lands in one directory — the unit ``obs_report``
summarizes, diffs and gates on::

    run_dir/
      manifest.json   # what ran: name, config, git SHA, backend, devices,
                      # start time; finish() adds wall_s and any summary
      events.jsonl    # append-only timeline of driver events (one JSON
                      # object per line: {"t": rel_seconds, "kind": ..., ...})
      metrics.json    # the registry's canonical snapshot at finish()
      trace.json      # Chrome trace-event JSON (only when tracing was on)

Everything here is stdlib-only and jax-free (backend detection is a
guarded lazy import), so the report CLI can read run records in contexts
where jax never loads — the same layering rule as ``launch/fsck.py``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import threading
import time
from typing import Optional

MANIFEST = "manifest.json"
EVENTS = "events.jsonl"
METRICS = "metrics.json"
TRACE = "trace.json"


def git_sha(cwd: Optional[str] = None) -> Optional[str]:
    """Best-effort HEAD SHA of the surrounding checkout (None outside git)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, timeout=5,
            cwd=cwd or os.path.dirname(os.path.abspath(__file__)),
        )
        return out.stdout.strip() if out.returncode == 0 else None
    except Exception:
        return None


def _backend_info() -> dict:
    """Backend/device identity — only if jax is already importable/initialized
    cheaply; a missing or broken jax must never break run recording."""
    try:
        import jax

        devs = jax.devices()
        return {
            "backend": jax.default_backend(),
            "device_kind": devs[0].device_kind if devs else None,
            "n_devices": len(devs),
        }
    except Exception:
        return {"backend": None, "device_kind": None, "n_devices": 0}


class RunLog:
    """One run's record: manifest at start, events during, metrics at end."""

    def __init__(self, run_dir: str, name: str, config: Optional[dict] = None):
        self.run_dir = run_dir
        os.makedirs(run_dir, exist_ok=True)
        self._t0 = time.monotonic()
        self._lock = threading.Lock()
        self.manifest = {
            "name": name,
            "config": _jsonable(config or {}),
            "argv": sys.argv,
            "git_sha": git_sha(),
            "started_unix": time.time(),
            **_backend_info(),
        }
        self._write_manifest()
        self._events = open(os.path.join(run_dir, EVENTS), "a")

    def _write_manifest(self) -> None:
        path = os.path.join(self.run_dir, MANIFEST)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.manifest, f, indent=2)
        os.replace(tmp, path)

    def event(self, kind: str, **fields) -> None:
        """Append one timeline event (relative seconds since run start)."""
        rec = {"t": round(time.monotonic() - self._t0, 6), "kind": kind,
               **_jsonable(fields)}
        with self._lock:
            self._events.write(json.dumps(rec) + "\n")
            self._events.flush()

    def flush_partial(
        self,
        metrics_snapshot: Optional[dict] = None,
        tracer=None,
        reason: str = "partial",
    ) -> None:
        """Write everything recorded SO FAR without sealing the record.

        The crash path (:class:`~repro.obs.session.ObsSession`'s atexit /
        SIGTERM hooks): a killed run still leaves a loadable
        ``manifest.json`` (flagged ``partial`` with the reason),
        ``metrics.json`` and ``trace.json`` next to the already-durable
        ``events.jsonl``.  Idempotent; a later :meth:`finish` overwrites
        the partial flag with the sealed summary.
        """
        self.manifest["wall_s"] = time.monotonic() - self._t0
        self.manifest["partial"] = True
        self.manifest["partial_reason"] = reason
        self._write_manifest()
        if metrics_snapshot is not None:
            with open(os.path.join(self.run_dir, METRICS), "w") as f:
                json.dump(metrics_snapshot, f, indent=2)
        if tracer is not None and tracer.enabled:
            tracer.write(os.path.join(self.run_dir, TRACE))
        with self._lock:
            if not self._events.closed:
                self._events.flush()

    def finish(
        self,
        metrics_snapshot: Optional[dict] = None,
        tracer=None,
        **summary,
    ) -> None:
        """Seal the record: wall time + summary into the manifest, the
        metrics snapshot to ``metrics.json``, the trace (if any) to
        ``trace.json``."""
        self.manifest["wall_s"] = time.monotonic() - self._t0
        self.manifest.pop("partial", None)
        self.manifest.pop("partial_reason", None)
        self.manifest.update(_jsonable(summary))
        self._write_manifest()
        if metrics_snapshot is not None:
            with open(os.path.join(self.run_dir, METRICS), "w") as f:
                json.dump(metrics_snapshot, f, indent=2)
        if tracer is not None and tracer.enabled:
            tracer.write(os.path.join(self.run_dir, TRACE))
        with self._lock:
            self._events.close()


def _jsonable(obj):
    """Best-effort plain-JSON projection (numpy scalars/arrays, tuples…)."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    if hasattr(obj, "item") and not hasattr(obj, "__len__"):  # numpy scalar
        return obj.item()
    if hasattr(obj, "tolist"):                                # numpy array
        return obj.tolist()
    return repr(obj)


def load_run(run_dir: str) -> dict:
    """Read a run record back: manifest, metrics, events, trace (if present).

    Raises ``FileNotFoundError`` when ``manifest.json`` is missing — the
    defining file of a run record.
    """
    with open(os.path.join(run_dir, MANIFEST)) as f:
        out = {"run_dir": run_dir, "manifest": json.load(f)}
    mpath = os.path.join(run_dir, METRICS)
    out["metrics"] = None
    if os.path.exists(mpath):
        with open(mpath) as f:
            out["metrics"] = json.load(f)
    out["events"] = []
    epath = os.path.join(run_dir, EVENTS)
    if os.path.exists(epath):
        with open(epath) as f:
            out["events"] = [json.loads(ln) for ln in f if ln.strip()]
    tpath = os.path.join(run_dir, TRACE)
    out["trace"] = None
    if os.path.exists(tpath):
        with open(tpath) as f:
            out["trace"] = json.load(f)
    return out
