"""Host-side span tracing with Chrome trace-event export (Perfetto-loadable).

A :class:`Tracer` records nested wall-clock spans on any thread — the main
mining loop, the :class:`~repro.store.reader.BlockReader` prefetch worker,
the serving path — against one shared monotonic clock, and exports the
Chrome trace-event JSON that ``ui.perfetto.dev`` / ``chrome://tracing``
render as a per-thread timeline.  Three event flavors:

  * ``span(name, **args)`` — a context manager recording one complete
    ("ph": "X") event; nesting is by time containment per thread, exactly
    how the trace viewers stack them;
  * ``add_span(...)`` — a raw event on a *virtual* track (e.g. the
    executor's modeled per-shard mining lanes, one track per shard);
  * ``instant(name, **args)`` — a zero-duration marker ("ph": "i") for
    point events like drift triggers;
  * ``counter(name, **values)`` — a counter-track sample ("ph": "C") for
    live gauges (mining progress %, serve queue depth, host-bytes
    high-water) rendered as area/line tracks alongside the spans.

Device timing: JAX dispatch is asynchronous, so a host span around a
dispatch measures enqueue, not execution.  ``sync(value, name)`` closes the
gap — **only when tracing is enabled** it blocks on the value inside a
span, so the enclosing phase span covers real device time; when disabled it
returns the value untouched and the pipeline stays fully async (the
disabled path must not change execution).  ``jax_profiler(log_dir)`` is the
opt-in escape hatch to the real profiler (TensorBoard/XProf) when
op-level device detail is needed.

The disabled fast path is a single attribute check returning a shared
no-op context manager — no allocation, no clock read, no lock
(benchmarked in ``benchmarks/io.py``: streamed-mine overhead with
everything enabled is gated < 5 %; disabled is in the noise).
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Deque, Dict, Optional

#: Default event-buffer cap.  Long soak runs (``serve_load``) otherwise grow
#: the buffer — and the exported trace.json — without bound; at the cap the
#: oldest events are dropped (the *recent* timeline is the diagnostic one)
#: and the drop is accounted: a ``trace/dropped_events`` counter plus a
#: ``truncated_events`` note in the exported JSON, which the doctor's
#: ``trace-truncated`` rule surfaces.
DEFAULT_MAX_EVENTS = 500_000


class _NullSpan:
    """Shared do-nothing context manager — the disabled fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_tracer", "_name", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args: Optional[dict]):
        self._tracer = tracer
        self._name = name
        self._args = args
        self._t0 = 0.0

    def __enter__(self):
        self._t0 = time.monotonic()
        return self

    def __exit__(self, *exc):
        self._tracer._record(
            self._name, self._t0, time.monotonic() - self._t0, self._args
        )
        return False


class Tracer:
    """Thread-safe span recorder with Chrome trace-event JSON export."""

    def __init__(
        self, enabled: bool = False, max_events: int = DEFAULT_MAX_EVENTS
    ):
        self._enabled = enabled
        self._max_events = max(1, int(max_events))
        self._events: Deque[dict] = deque(maxlen=self._max_events)
        self._dropped = 0
        self._t_base = time.monotonic()
        self._track_names: Dict[int, str] = {}
        self._lock = threading.Lock()

    # -- lifecycle -----------------------------------------------------------
    @property
    def enabled(self) -> bool:
        return self._enabled

    def enable(self) -> None:
        self._enabled = True

    def disable(self) -> None:
        self._enabled = False

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._track_names.clear()
            self._dropped = 0
        self._t_base = time.monotonic()

    def set_max_events(self, max_events: int) -> None:
        """Re-cap the buffer (keeping the newest events that still fit)."""
        with self._lock:
            self._max_events = max(1, int(max_events))
            old = self._events
            self._dropped += max(0, len(old) - self._max_events)
            self._events = deque(old, maxlen=self._max_events)

    @property
    def max_events(self) -> int:
        return self._max_events

    @property
    def dropped_events(self) -> int:
        with self._lock:
            return self._dropped

    def _append_locked(self, ev: dict) -> None:
        # deque(maxlen) silently evicts the oldest; account for it first
        if len(self._events) == self._max_events:
            self._dropped += 1
            from repro.obs import metrics as _metrics  # lazy: cold path only

            _metrics.registry().counter("trace/dropped_events").inc()
        self._events.append(ev)

    # -- recording -----------------------------------------------------------
    def span(self, name: str, **args):
        """Context manager timing one nested span on the calling thread."""
        if not self._enabled:
            return _NULL_SPAN
        return _Span(self, name, args or None)

    def _tid(self) -> int:
        t = threading.current_thread()
        tid = t.ident or 0
        if tid not in self._track_names:       # benign race: same value
            self._track_names[tid] = t.name
        return tid

    def _record(self, name, t0, dur_s, args, tid=None, cat="host"):
        ev = {
            "ph": "X",
            "name": name,
            "cat": cat,
            "pid": 0,
            "tid": self._tid() if tid is None else tid,
            "ts": (t0 - self._t_base) * 1e6,
            "dur": dur_s * 1e6,
        }
        if args:
            ev["args"] = args
        with self._lock:
            self._append_locked(ev)

    def add_span(
        self,
        name: str,
        t0: float,
        dur_s: float,
        *,
        track: str,
        cat: str = "modeled",
        args: Optional[dict] = None,
    ) -> None:
        """Record a span on a named virtual track (``t0`` from
        ``time.monotonic()``).  Used for modeled lanes — e.g. per-shard
        mining spans whose duration is apportioned from trip telemetry."""
        if not self._enabled:
            return
        tid = 1_000_000 + (hash(track) & 0xFFFF)
        if tid not in self._track_names:
            self._track_names[tid] = track
        self._record(name, t0, dur_s, args, tid=tid, cat=cat)

    def counter(self, name: str, **values) -> None:
        """A Chrome counter sample ("ph": "C") — renders as a counter track.

        Each call appends one sample of the named counter series; Perfetto
        draws the series as a stacked area/line track (one lane per key in
        ``values``).  Used for the live gauges worth seeing against the
        span timeline: mining progress %, serve queue depth, host-bytes
        high-water.  Values must be numeric."""
        if not self._enabled:
            return
        ev = {
            "ph": "C",
            "name": name,
            "cat": "counter",
            "pid": 0,
            "tid": self._tid(),
            "ts": (time.monotonic() - self._t_base) * 1e6,
            "args": {k: float(v) for k, v in values.items()},
        }
        with self._lock:
            self._append_locked(ev)

    def instant(self, name: str, **args) -> None:
        """A zero-duration marker event (drift fired, checkpoint saved…)."""
        if not self._enabled:
            return
        ev = {
            "ph": "i",
            "s": "t",
            "name": name,
            "cat": "event",
            "pid": 0,
            "tid": self._tid(),
            "ts": (time.monotonic() - self._t_base) * 1e6,
        }
        if args:
            ev["args"] = args
        with self._lock:
            self._append_locked(ev)

    # -- device helper -------------------------------------------------------
    def sync(self, value, name: str = "device_sync"):
        """Block on a JAX value inside a span — ONLY when tracing.

        The disabled path returns ``value`` untouched (no import, no sync):
        tracing must never change how the async pipeline executes when off.
        """
        if not self._enabled:
            return value
        import jax

        with self.span(name, cat="device"):
            return jax.block_until_ready(value)

    # -- export --------------------------------------------------------------
    @property
    def n_events(self) -> int:
        with self._lock:
            return len(self._events)

    def export(self) -> dict:
        """The Chrome trace-event object (Perfetto/chrome://tracing)."""
        with self._lock:
            events = list(self._events)
            tracks = dict(self._track_names)
            dropped = self._dropped
        meta = [
            {
                "ph": "M",
                "name": "thread_name",
                "pid": 0,
                "tid": tid,
                "args": {"name": tname},
            }
            for tid, tname in sorted(tracks.items())
        ]
        out = {"traceEvents": meta + events, "displayTimeUnit": "ms"}
        if dropped:
            out["truncated_events"] = dropped   # oldest `dropped` evicted
        return out

    def write(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.export(), f)
        return path


#: The process-global tracer every subsystem records into by default.
TRACER = Tracer()


def tracer() -> Tracer:
    return TRACER


class jax_profiler:
    """Opt-in ``jax.profiler.trace`` hook (TensorBoard/XProf log dir).

    Complements the host tracer with op-level device timing; a context
    manager so drivers can hold it across the whole run::

        with obs_trace.jax_profiler(log_dir):
            ... mine ...
    """

    def __init__(self, log_dir: str):
        self.log_dir = log_dir

    def __enter__(self):
        import jax

        jax.profiler.start_trace(self.log_dir)
        return self

    def __exit__(self, *exc):
        import jax

        jax.profiler.stop_trace()
        return False
