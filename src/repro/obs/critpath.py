"""Span-DAG reconstruction and critical-path analysis over ``trace.json``.

"Where did the wall clock go" gets one canonical answer here.  The tracer
(:mod:`repro.obs.trace`) exports flat Chrome trace events; this module
rebuilds the structure those events imply and walks it:

  * **Nesting** — per track (tid), a span is the child of the innermost
    span whose interval contains it: exactly how Perfetto stacks them.
  * **Cross-track containment** — spans recorded on *other* tracks
    (the executor's modeled per-shard mining lanes, the store's prefetch
    worker thread) attach to the innermost main-track span that temporally
    contains them, so a ``cluster/mine`` round owns its shard lanes and a
    ``fimi/assemble_store`` span owns the prefetch reads that served it.
    Instants (``cluster/donate`` donations, ``stream/drift`` triggers,
    swap markers) attach to their enclosing span as annotations — the
    cross-track evidence the doctor's rules cite.
  * **Exclusive self-time** — ``span.dur − union(child intervals)``:
    long parents (``phase4``) stop masking their children.  Children on
    parallel tracks overlap each other, so the subtraction uses the merged
    interval union, never a naive sum.
  * **Critical path** — from a virtual root covering the whole trace,
    repeatedly descend into the chain of children that were *last active*
    walking backwards in time.  Parallel siblings (shard lanes) resolve to
    the straggler; the time a parent spent with no selected child active
    is its own on-path self-time; gaps between top-level spans surface as
    the virtual root's self-time (``(untraced)``).

Everything is stdlib-only and jax-free (the ``obs_report`` layering rule):
input is the already-loaded trace dict of a run record.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

#: slack (us) when testing containment: modeled lanes are stamped with a
#: ``t0`` taken just before the enclosing span entered, and clocks are
#: microsecond-rounded — a strict test would orphan them.
_EPS_US = 2_000.0

#: the aggregate row name for time inside no span at all (driver glue,
#: argument parsing, everything the tracer never saw).
UNTRACED = "(untraced)"


@dataclasses.dataclass
class SpanNode:
    """One complete ("ph": "X") event, placed in the reconstructed DAG."""

    name: str
    track: str               # thread/virtual-track name ("" when unnamed)
    tid: int
    t0: float                # us, trace timebase
    dur: float               # us
    args: dict
    order: int = 0           # position in the event stream (tie-breaks)
    children: List["SpanNode"] = dataclasses.field(default_factory=list)
    parent: Optional["SpanNode"] = None
    instants: List[dict] = dataclasses.field(default_factory=list)

    @property
    def end(self) -> float:
        return self.t0 + self.dur

    def exclusive_us(self) -> float:
        """dur minus the merged union of child intervals (clipped to self)."""
        covered = _union_len(
            [(max(c.t0, self.t0), min(c.end, self.end)) for c in self.children]
        )
        return max(0.0, self.dur - covered)


@dataclasses.dataclass
class SpanDag:
    """The reconstructed forest plus the virtual root spanning the trace."""

    nodes: List[SpanNode]
    root: SpanNode           # virtual: name == UNTRACED, covers [min, max]
    tracks: Dict[int, str]

    @property
    def wall_us(self) -> float:
        return self.root.dur


@dataclasses.dataclass
class PathSeg:
    """One span on the critical path, with its on-path self contribution."""

    name: str
    track: str
    depth: int
    t0_us: float
    dur_us: float
    self_us: float           # dur minus the selected (on-path) children
    args: dict


def _union_len(intervals: Sequence[Tuple[float, float]]) -> float:
    """Total length of the union of (start, end) intervals."""
    ivs = sorted((a, b) for a, b in intervals if b > a)
    total = 0.0
    cur_a = cur_b = None
    for a, b in ivs:
        if cur_b is None or a > cur_b:
            if cur_b is not None:
                total += cur_b - cur_a
            cur_a, cur_b = a, b
        elif b > cur_b:
            cur_b = b
    if cur_b is not None:
        total += cur_b - cur_a
    return total


def _contains(outer: SpanNode, inner: SpanNode, eps: float = _EPS_US) -> bool:
    return (
        outer.t0 - eps <= inner.t0
        and inner.end <= outer.end + eps
        and outer.dur >= inner.dur - eps
    )


def build(trace: Optional[dict]) -> Optional[SpanDag]:
    """Reconstruct the span DAG of one exported Chrome trace (None if empty).

    Accepts the dict shape :meth:`repro.obs.trace.Tracer.export` writes;
    tolerates missing metadata and unordered events.
    """
    if not trace:
        return None
    events = trace.get("traceEvents") or []
    tracks: Dict[int, str] = {}
    spans: List[SpanNode] = []
    instants: List[dict] = []
    for ev in events:
        ph = ev.get("ph")
        if ph == "M" and ev.get("name") == "thread_name":
            tracks[ev.get("tid", 0)] = (ev.get("args") or {}).get("name", "")
        elif ph == "X":
            spans.append(SpanNode(
                name=str(ev.get("name", "?")),
                track="",
                tid=int(ev.get("tid", 0)),
                t0=float(ev.get("ts", 0.0)),
                dur=max(0.0, float(ev.get("dur", 0.0))),
                args=dict(ev.get("args") or {}),
                order=len(spans),
            ))
        elif ph == "i":
            instants.append(ev)
    if not spans:
        return None
    for s in spans:
        s.track = tracks.get(s.tid, f"tid{s.tid}")

    # --- per-track nesting (innermost containing span on the same tid) -----
    by_tid: Dict[int, List[SpanNode]] = {}
    for s in spans:
        by_tid.setdefault(s.tid, []).append(s)
    for tid_spans in by_tid.values():
        # enter-order with ties broken outermost-first; a stack of open
        # spans gives each its innermost container
        tid_spans.sort(key=lambda s: (s.t0, -s.dur))
        stack: List[SpanNode] = []
        for s in tid_spans:
            while stack and not _contains(stack[-1], s, eps=0.5):
                stack.pop()
            if stack:
                s.parent = stack[-1]
                stack[-1].children.append(s)
            stack.append(s)

    # --- cross-track containment: attach orphan roots of other tracks ------
    roots = [s for s in spans if s.parent is None]
    # candidates a foreign root may attach to, innermost (shortest) first.
    # The eps slack makes near-equal intervals contain each other BOTH
    # ways (the executor's straggler lane vs the main-track mine span it
    # mirrors exactly) — resolve mutual containment asymmetrically: the
    # longer span is the parent; on equal durations the earlier-recorded
    # one wins, never the reverse (a lane must not adopt its host).
    def _may_adopt(cand: SpanNode, r: SpanNode) -> bool:
        if not _contains(cand, r):
            return False
        if not _contains(r, cand):
            return True
        if cand.dur != r.dur:
            return cand.dur > r.dur
        return cand.order < r.order

    for r in roots:
        best: Optional[SpanNode] = None
        for cand in spans:
            if cand.tid == r.tid or _in_subtree(cand, r):
                continue
            if _may_adopt(cand, r) and (best is None or cand.dur < best.dur):
                best = cand
        if best is not None:
            r.parent = best
            best.children.append(r)

    # --- instants annotate the innermost enclosing span --------------------
    for ev in instants:
        ts = float(ev.get("ts", 0.0))
        tid = int(ev.get("tid", 0))
        host: Optional[SpanNode] = None
        for s in spans:
            if s.tid == tid and s.t0 <= ts <= s.end \
                    and (host is None or s.dur < host.dur):
                host = s
        if host is not None:
            host.instants.append(ev)

    # --- the virtual root: whole-trace interval over the real roots --------
    roots = [s for s in spans if s.parent is None]
    t_lo = min(s.t0 for s in spans)
    t_hi = max(s.end for s in spans)
    root = SpanNode(
        name=UNTRACED, track="", tid=-1,
        t0=t_lo, dur=max(0.0, t_hi - t_lo), args={},
    )
    root.children = sorted(roots, key=lambda s: s.t0)
    for r in roots:
        r.parent = root
    return SpanDag(nodes=spans, root=root, tracks=tracks)


def _in_subtree(node: SpanNode, ancestor: SpanNode) -> bool:
    cur: Optional[SpanNode] = node
    while cur is not None:
        if cur is ancestor:
            return True
        cur = cur.parent
    return False


# ---------------------------------------------------------------------------
# Exclusive self-time (the summary's new column)
# ---------------------------------------------------------------------------


def exclusive_totals(dag: SpanDag) -> Dict[str, Dict[str, float]]:
    """Per-name inclusive/exclusive totals over the whole DAG.

    ``{name: {"total_ms", "self_ms", "count"}}`` — the single
    implementation both ``obs_report summary`` and the doctor use, so the
    two never disagree about what a span's own time is.
    """
    out: Dict[str, Dict[str, float]] = {}
    for s in dag.nodes:
        row = out.setdefault(
            s.name, {"total_ms": 0.0, "self_ms": 0.0, "count": 0}
        )
        row["total_ms"] += s.dur / 1e3
        row["self_ms"] += s.exclusive_us() / 1e3
        row["count"] += 1
    return out


# ---------------------------------------------------------------------------
# Critical path
# ---------------------------------------------------------------------------


def _select_chain(node: SpanNode) -> List[SpanNode]:
    """The children that were last-active, walking backwards through node.

    Starting at ``node.end``, repeatedly take the child that ends latest at
    or before the cursor, then jump the cursor to that child's start.
    Parallel siblings fully shadowed by a later-ending sibling (the faster
    shard lanes under the straggler) never get selected — they are slack,
    not critical.  Returns the selected children in time order.
    """
    sel: List[SpanNode] = []
    cursor = node.end + 1.0          # tolerate child.end == node.end
    for c in sorted(node.children, key=lambda c: -c.end):
        if c.end <= cursor:
            sel.append(c)
            cursor = c.t0
    return list(reversed(sel))


def critical_path(dag: SpanDag) -> List[PathSeg]:
    """The critical path as a depth-annotated pre-order list of segments.

    Each segment's ``self_us`` is its duration minus the selected on-path
    children — so ``sum(self_us)`` accounts the full wall clock with
    nothing double-counted (up to the microsecond attach slack of
    cross-track children).
    """
    segs: List[PathSeg] = []

    def walk(node: SpanNode, depth: int) -> None:
        chain = _select_chain(node)
        covered = sum(
            max(0.0, min(c.end, node.end) - max(c.t0, node.t0))
            for c in chain
        )
        self_us = max(0.0, node.dur - covered)
        segs.append(PathSeg(
            name=node.name, track=node.track, depth=depth,
            t0_us=node.t0, dur_us=node.dur, self_us=self_us,
            args=node.args,
        ))
        for c in chain:
            walk(c, depth + 1)

    walk(dag.root, 0)
    return segs


def path_table(
    segs: List[PathSeg], top_n: int = 10
) -> List[Dict[str, object]]:
    """Aggregate on-path self-time by span name, largest first.

    The top-N answer to "where did the wall clock go": every row carries
    the share of the total wall it was critical for.
    """
    total = sum(s.self_us for s in segs) or 1.0
    acc: Dict[str, Dict[str, float]] = {}
    for s in segs:
        row = acc.setdefault(
            s.name, {"self_ms": 0.0, "count": 0, "tracks": set()}
        )
        row["self_ms"] += s.self_us / 1e3
        row["count"] += 1
        if s.track:
            row["tracks"].add(s.track)
    rows = [
        {
            "name": name,
            "self_ms": r["self_ms"],
            "count": int(r["count"]),
            "share": r["self_ms"] * 1e3 / total,
            "tracks": ",".join(sorted(r["tracks"])),
        }
        for name, r in acc.items()
    ]
    rows.sort(key=lambda r: (-r["self_ms"], r["name"]))
    return rows[:top_n]


def analyze(trace: Optional[dict], top_n: int = 10) -> Optional[dict]:
    """One-call digest: DAG + critical path + tables, plain-dict shaped.

    ``{"wall_ms", "path": [seg dicts], "table": [...], "exclusive": {...}}``
    — what ``obs_report critpath``/``doctor`` render and tests assert on.
    Returns None when the trace has no complete spans.
    """
    dag = build(trace)
    if dag is None:
        return None
    segs = critical_path(dag)
    return {
        "wall_ms": dag.wall_us / 1e3,
        "path": [
            {
                "name": s.name, "track": s.track, "depth": s.depth,
                "t0_ms": s.t0_us / 1e3, "dur_ms": s.dur_us / 1e3,
                "self_ms": s.self_us / 1e3,
            }
            for s in segs
        ],
        "table": path_table(segs, top_n),
        "exclusive": exclusive_totals(dag),
    }
