"""Kernel performance attribution: measured vs modeled time per family.

A :class:`KernelProfiler` sits around every ``repro.kernels.ops`` dispatch
and answers the question the post-hoc benchmarks cannot: *where did this
run's wall time actually go, and was that time well spent?*  Per kernel
family it accumulates

  * **measured** time — device-synced wall clock per eager call, bucketed
    by power-of-two-rounded shape, plus loop-attributed time for kernels
    that execute inside ``lax.while_loop`` (see below);
  * **modeled** time — an analytic word-op/byte cost model priced against
    the shared :mod:`repro.obs.machine` roofline constants (factored out
    of ``benchmarks/roofline.py``), giving per-family compute and memory
    terms, ``modeled = max(compute, memory)``, an achieved fraction
    ``modeled / measured``, and a memory- vs compute-bound verdict.

Two measurement paths
---------------------
Eager dispatches (the serving subset sweep, streaming delta sweep, pair
counts, planner PBEC) pass through :meth:`KernelProfiler.call`, which times
``thunk`` → ``jax.block_until_ready`` on the host clock.  The frontier
mining kernels are different: ``core/eclat.mine_seeded`` is jit'd with the
support fn as a static argument, so the ops dispatch executes **once per
compilation** under tracing, then the compiled loop body runs thousands of
trips with no Python in sight.  ``call`` detects the traced case (the
output is a :class:`jax.core.Tracer`) and only notes the shape; the actual
work is attributed afterwards by the drivers — ``core/fimi.run`` and
``cluster/executor`` call :meth:`observe_loop` with the loop's trip count
and the phase-4 wall time they already measure.  Attribution, not a second
timer: the loop cost model says how much arithmetic those trips performed,
and the phase wall clock says how long they took.

Cost models (word-ops; one op = one 32-bit AND / popcount / add)
----------------------------------------------------------------
``W``/``IW`` = uint32 words per bitmap row.

  bitmap  (I, W)        flops 3·I·W            bytes 4·(I·W + W + I)
  multi   (K, I, W)     flops 3·K·I·W          bytes 4·(I·W + K·W + K·I)
  pair    (I, W)        flops 3·I²·W           bytes 4·(I·W + W + I²)
  subset  (Q, F, IW)    flops 8·Q·F·IW         bytes 4·((Q+F)·IW + 2·Q·F)
  delta   (S, T, F, IW) flops 4·S·T·F·IW       bytes 4·(S·T·IW + F·IW + S·F)

The constants are per-word operation counts of the reference algorithm
(AND + popcount + accumulate ≈ 3 ops; the subset sweep does both set
differences per pair; the delta sweep adds the containment compare), not
microarchitectural truth — what matters is that the *same* model prices
every family, so the bound-ness verdicts and the cross-family attribution
ranking are consistent, and that ``obs_report kernels --check-model`` can
recompute every term from the published flop/byte/constant gauges.

Disabled path: one attribute check in the ops wrapper, no allocation, no
clock read — same contract as the null tracer (gated <2 % in
``tests/test_profile.py``).
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, Optional, Tuple

from repro.obs import metrics as obs_metrics
from repro.obs.machine import CPU_HOST, MachineModel, machine_for_backend

#: The five dispatch families of ``repro.kernels.ops``.
FAMILIES = ("bitmap", "multi", "pair", "subset", "delta")

#: Canonical dimension order per family (bucket labels, report rows).
DIM_ORDER: Dict[str, Tuple[str, ...]] = {
    "bitmap": ("I", "W"),
    "multi": ("K", "I", "W"),
    "pair": ("I", "W"),
    "subset": ("Q", "F", "IW"),
    "delta": ("S", "T", "F", "IW"),
}


def cost_model(family: str, dims: Dict[str, int]) -> Tuple[float, float]:
    """(word_ops, bytes) one execution of ``family`` at ``dims`` performs."""
    d = dims
    if family == "bitmap":
        flops = 3.0 * d["I"] * d["W"]
        nbytes = 4.0 * (d["I"] * d["W"] + d["W"] + d["I"])
    elif family == "multi":
        flops = 3.0 * d["K"] * d["I"] * d["W"]
        nbytes = 4.0 * (d["I"] * d["W"] + d["K"] * d["W"] + d["K"] * d["I"])
    elif family == "pair":
        flops = 3.0 * d["I"] * d["I"] * d["W"]
        nbytes = 4.0 * (d["I"] * d["W"] + d["W"] + d["I"] * d["I"])
    elif family == "subset":
        flops = 8.0 * d["Q"] * d["F"] * d["IW"]
        nbytes = 4.0 * ((d["Q"] + d["F"]) * d["IW"] + 2.0 * d["Q"] * d["F"])
    elif family == "delta":
        flops = 4.0 * d["S"] * d["T"] * d["F"] * d["IW"]
        nbytes = 4.0 * (
            d["S"] * d["T"] * d["IW"] + d["F"] * d["IW"] + d["S"] * d["F"]
        )
    else:
        raise ValueError(f"unknown kernel family: {family!r}")
    return flops, nbytes


def _pow2(n: int) -> int:
    """Round up to a power of two (≥ 1) — the shape-bucket resolution."""
    n = int(n)
    if n <= 1:
        return 1
    return 1 << (n - 1).bit_length()


def _bucket_label(family: str, dims: Dict[str, int]) -> str:
    parts = ",".join(f"{k}={_pow2(dims[k])}" for k in DIM_ORDER[family])
    return f"{family}[{parts}]"


class _Bucket:
    """Accumulator for one (family, pow2-shape) bucket."""

    __slots__ = (
        "calls", "loop_execs", "wall_s", "loop_wall_s",
        "flops", "bytes", "min_s", "max_s",
    )

    def __init__(self):
        self.calls = 0          # eager, individually timed dispatches
        self.loop_execs = 0     # while_loop-attributed executions
        self.wall_s = 0.0       # summed device-synced eager wall time
        self.loop_wall_s = 0.0  # wall time attributed by observe_loop
        self.flops = 0.0        # modeled word-ops across all executions
        self.bytes = 0.0        # modeled bytes across all executions
        self.min_s = float("inf")
        self.max_s = 0.0


class KernelProfiler:
    """Per-(family, shape-bucket) timing + roofline cost attribution.

    Thread-safe (the store prefetch thread and serve replicas dispatch
    kernels concurrently with the main loop).  All recording methods are
    no-ops while disabled; the ops-layer fast path additionally skips the
    method call entirely behind the :attr:`enabled` attribute check.
    """

    def __init__(self, machine: Optional[MachineModel] = None):
        self.enabled = False          # read directly by the ops wrapper
        self._machine = machine       # None → resolve from backend lazily
        self._buckets: Dict[Tuple[str, str], _Bucket] = {}
        self._traced: Dict[str, int] = {}   # family -> trace-time dispatches
        self._lock = threading.Lock()

    # -- lifecycle -----------------------------------------------------------
    def enable(self, machine: Optional[MachineModel] = None) -> None:
        if machine is not None:
            self._machine = machine
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        with self._lock:
            self._buckets.clear()
            self._traced.clear()

    @property
    def machine(self) -> MachineModel:
        if self._machine is None:
            try:
                import jax

                self._machine = machine_for_backend(jax.default_backend())
            except Exception:
                self._machine = CPU_HOST
        return self._machine

    # -- recording -----------------------------------------------------------
    def call(self, family: str, dims: Dict[str, int], thunk: Callable):
        """Run ``thunk`` with device-synced timing (the eager path).

        Under jit tracing the output is abstract and cannot be waited on;
        the dispatch is tallied as trace-time only and the real executions
        must be attributed via :meth:`observe_loop` by whoever runs the
        compiled loop.
        """
        if not self.enabled:
            return thunk()
        import jax

        t0 = time.monotonic()
        out = thunk()
        leaf = out[0] if isinstance(out, tuple) else out
        if isinstance(leaf, jax.core.Tracer):
            with self._lock:
                self._traced[family] = self._traced.get(family, 0) + 1
            return out
        jax.block_until_ready(out)
        self.record_call(family, dims, time.monotonic() - t0)
        return out

    def record_call(self, family: str, dims: Dict[str, int], wall_s: float) -> None:
        """Account one timed eager execution of ``family`` at ``dims``."""
        if not self.enabled:
            return
        flops, nbytes = cost_model(family, dims)
        label = _bucket_label(family, dims)
        with self._lock:
            b = self._buckets.setdefault((family, label), _Bucket())
            b.calls += 1
            b.wall_s += wall_s
            b.flops += flops
            b.bytes += nbytes
            b.min_s = min(b.min_s, wall_s)
            b.max_s = max(b.max_s, wall_s)
        obs_metrics.registry().histogram(
            f"kernels/{family}/call_us/{label}"
        ).record(wall_s * 1e6)

    def observe_loop(
        self, family: str, dims: Dict[str, int], n_exec: int, wall_s: float
    ) -> None:
        """Attribute ``n_exec`` in-loop executions covered by ``wall_s``.

        For kernels compiled into ``lax.while_loop`` bodies: the driver
        knows the trip count (``work_iters``) and the phase wall clock; the
        cost model per trip comes from ``dims`` exactly as for eager calls.
        """
        if not self.enabled or n_exec <= 0:
            return
        flops, nbytes = cost_model(family, dims)
        label = _bucket_label(family, dims)
        with self._lock:
            b = self._buckets.setdefault((family, label), _Bucket())
            b.loop_execs += int(n_exec)
            b.loop_wall_s += float(wall_s)
            b.flops += flops * n_exec
            b.bytes += nbytes * n_exec

    # -- reporting -----------------------------------------------------------
    def report(self) -> dict:
        """Measured-vs-modeled attribution, per family and per bucket."""
        m = self.machine
        with self._lock:
            items = [(k, b) for k, b in self._buckets.items()]
            traced = dict(self._traced)
        families: Dict[str, dict] = {}
        for (family, label), b in sorted(items):
            compute_s = b.flops / m.word_ops_peak
            memory_s = b.bytes / m.hbm_bw
            modeled_s = max(compute_s, memory_s)
            measured_s = b.wall_s + b.loop_wall_s
            fam = families.setdefault(
                family,
                {
                    "calls": 0, "loop_execs": 0, "measured_ms": 0.0,
                    "flops": 0.0, "bytes": 0.0,
                    "compute_ms": 0.0, "memory_ms": 0.0, "modeled_ms": 0.0,
                    "trace_dispatches": traced.get(family, 0),
                    "buckets": [],
                },
            )
            fam["calls"] += b.calls
            fam["loop_execs"] += b.loop_execs
            fam["measured_ms"] += measured_s * 1e3
            fam["flops"] += b.flops
            fam["bytes"] += b.bytes
            fam["compute_ms"] += compute_s * 1e3
            fam["memory_ms"] += memory_s * 1e3
            fam["modeled_ms"] += modeled_s * 1e3
            fam["buckets"].append(
                {
                    "bucket": label,
                    "calls": b.calls,
                    "loop_execs": b.loop_execs,
                    "measured_ms": measured_s * 1e3,
                    "modeled_ms": modeled_s * 1e3,
                    "compute_ms": compute_s * 1e3,
                    "memory_ms": memory_s * 1e3,
                    "min_us": (b.min_s * 1e6) if b.calls else None,
                    "max_us": (b.max_s * 1e6) if b.calls else None,
                }
            )
        for family in traced:
            families.setdefault(
                family,
                {
                    "calls": 0, "loop_execs": 0, "measured_ms": 0.0,
                    "flops": 0.0, "bytes": 0.0,
                    "compute_ms": 0.0, "memory_ms": 0.0, "modeled_ms": 0.0,
                    "trace_dispatches": traced[family],
                    "buckets": [],
                },
            )
        for fam in families.values():
            measured = fam["measured_ms"]
            fam["achieved_frac"] = (
                fam["modeled_ms"] / measured if measured > 0 else None
            )
            fam["mem_bound"] = fam["memory_ms"] > fam["compute_ms"]
        return {
            "machine": {
                "name": m.name,
                "peak_flops": m.peak_flops,
                "hbm_bw": m.hbm_bw,
                "link_bw": m.link_bw,
                "word_ops_peak": m.word_ops_peak,
            },
            "families": families,
        }

    def publish(self, reg: Optional[obs_metrics.MetricsRegistry] = None) -> dict:
        """Export the report as counters/gauges so it rides the run record.

        Gauge scheme (all consumed jax-free by ``obs_report kernels``)::

            kernels/machine/{word_ops_peak, hbm_bw, peak_flops}
            kernels/<family>/{measured_ms, modeled_ms, compute_ms,
                              memory_ms, flops, bytes, achieved_frac,
                              mem_bound}
            kernels/<family>/{calls, loop_execs}          (counters)
        """
        reg = reg or obs_metrics.registry()
        rep = self.report()
        for k, v in rep["machine"].items():
            if k != "name":
                reg.gauge(f"kernels/machine/{k}").set(float(v))
        for family, fam in rep["families"].items():
            reg.counter(f"kernels/{family}/calls").inc(fam["calls"])
            reg.counter(f"kernels/{family}/loop_execs").inc(fam["loop_execs"])
            for k in (
                "measured_ms", "modeled_ms", "compute_ms", "memory_ms",
                "flops", "bytes",
            ):
                reg.gauge(f"kernels/{family}/{k}").set(float(fam[k]))
            if fam["achieved_frac"] is not None:
                reg.gauge(f"kernels/{family}/achieved_frac").set(
                    float(fam["achieved_frac"])
                )
            reg.gauge(f"kernels/{family}/mem_bound").set(
                1.0 if fam["mem_bound"] else 0.0
            )
        return rep


#: The process-global profiler the ops layer checks on every dispatch.
PROFILER = KernelProfiler()


def profiler() -> KernelProfiler:
    return PROFILER
