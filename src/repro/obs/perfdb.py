"""Persistent perf trajectory: stamped benchmark snapshots in a JSONL file.

Eight perf-focused PRs in, the repo had no memory of its own numbers: every
``BENCH_*.json`` is overwritten in place, so a regression that slips past
the per-run gates is invisible.  This module is the missing ledger —
``BENCH_HISTORY.jsonl``, one JSON object per line::

    {"ts": "2026-08-08T12:00:00Z", "sha": "a03672c", "backend": "cpu",
     "suite": "cluster", "keys": {"speedup_1_to_4": 3.1, ...}}

Writers: every ``benchmarks/run.py`` invocation (one row per suite it ran)
and the gated ``launch/serve_load.py`` run.  Readers: ``obs_report
history`` (per-key trend rendering) and ``obs_report regress`` (exit
non-zero when the newest value degrades past a threshold vs the trailing
median — the ``tools/check.sh`` / CI gate).

Properties the gates rely on:

  * **Atomic append** — each row is a single ``os.write`` to an
    ``O_APPEND`` fd, so concurrent writers interleave whole lines and a
    crash can at worst truncate the final line;
  * **Corrupt-line tolerance** — :func:`load` skips unparsable lines (and
    reports how many), so one torn write never wedges the trend gates;
  * **Directionality by key name** — the same conventions the BENCH
    summary table already prints with: ``*_ms``/``*_s``/``*_us``/
    ``overhead``/``slowdown``/``stall``/``latency`` are lower-better,
    ``*_speedup``/``*_qps``/``*_improvement`` higher-better, anything
    else (counts, config echoes) is recorded but not gated.

Stdlib-only and jax-free, like the rest of :mod:`repro.obs`.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, Iterable, List, Optional, Tuple

from repro.obs.runlog import git_sha as _full_git_sha

#: Default ledger file, at the repo root next to the BENCH_*.json it tracks.
DEFAULT_PATH = "BENCH_HISTORY.jsonl"

_LOWER_SUBSTR = ("overhead", "slowdown", "stall", "latency", "burn_rate",
                 "loss")
_LOWER_SUFFIX = ("_ms", "_s", "_us", "_bytes")
_HIGHER_SUBSTR = ("speedup", "improvement")
_HIGHER_SUFFIX = ("_qps", "_frac")


def direction(key: str) -> Optional[str]:
    """'lower' / 'higher' when the key has a better direction, else None."""
    k = key.lower()
    if any(s in k for s in _HIGHER_SUBSTR) or k.endswith(_HIGHER_SUFFIX):
        return "higher"
    if any(s in k for s in _LOWER_SUBSTR) or k.endswith(_LOWER_SUFFIX):
        return "lower"
    return None


def git_sha(cwd: Optional[str] = None) -> str:
    """Short git SHA of the surrounding checkout ('' outside git)."""
    return (_full_git_sha(cwd) or "")[:9]


def utc_stamp(t: Optional[float] = None) -> str:
    return time.strftime(
        "%Y-%m-%dT%H:%M:%SZ", time.gmtime(time.time() if t is None else t)
    )


def append(
    path: str,
    suite: str,
    keys: Dict[str, float],
    *,
    sha: Optional[str] = None,
    backend: str = "",
    ts: Optional[str] = None,
) -> dict:
    """Atomically append one stamped snapshot row; returns the row."""
    row = {
        "ts": ts if ts is not None else utc_stamp(),
        "sha": sha if sha is not None else git_sha(),
        "backend": backend,
        "suite": suite,
        "keys": {
            k: float(v) for k, v in keys.items()
            if isinstance(v, (int, float)) and not isinstance(v, bool)
        },
    }
    data = (json.dumps(row, sort_keys=True) + "\n").encode()
    fd = os.open(path, os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644)
    try:
        os.write(fd, data)
    finally:
        os.close(fd)
    return row


def load(path: str) -> Tuple[List[dict], int]:
    """All well-formed rows in file order, plus the corrupt-line count."""
    rows: List[dict] = []
    corrupt = 0
    with open(path, "r") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                row = json.loads(line)
            except json.JSONDecodeError:
                corrupt += 1
                continue
            if not isinstance(row, dict) or "suite" not in row \
                    or not isinstance(row.get("keys"), dict):
                corrupt += 1
                continue
            rows.append(row)
    return rows, corrupt


def trends(
    rows: Iterable[dict],
    *,
    suite: Optional[str] = None,
    key_match: Optional[str] = None,
) -> Dict[Tuple[str, str], List[dict]]:
    """{(suite, key): [{ts, sha, value}, ...]} in file (=time) order."""
    out: Dict[Tuple[str, str], List[dict]] = {}
    for row in rows:
        s = str(row.get("suite", ""))
        if suite and s != suite:
            continue
        for k, v in row.get("keys", {}).items():
            if key_match and key_match not in k:
                continue
            if not isinstance(v, (int, float)) or isinstance(v, bool):
                continue
            out.setdefault((s, k), []).append(
                {"ts": row.get("ts", ""), "sha": row.get("sha", ""),
                 "value": float(v)}
            )
    return out


def _median(vals: List[float]) -> float:
    s = sorted(vals)
    n = len(s)
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


@dataclasses.dataclass
class Regression:
    """One gated key whose newest value degraded past the threshold."""

    suite: str
    key: str
    direction: str          # "lower" | "higher" (better)
    latest: float
    median: float           # trailing median the latest is judged against
    ratio: float            # latest/median (lower-better) or inverse
    n_history: int

    def line(self) -> str:
        arrow = "↑" if self.direction == "lower" else "↓"
        return (
            f"{self.suite}/{self.key}: {self.latest:.4g} vs trailing "
            f"median {self.median:.4g} ({self.ratio:.2f}x {arrow} worse, "
            f"n={self.n_history})"
        )


def check_regressions(
    rows: List[dict],
    *,
    threshold: float = 0.25,
    window: int = 8,
    min_history: int = 2,
    degrade: float = 1.0,
    direction_overrides: Optional[Dict[str, str]] = None,
) -> Tuple[List[Regression], int]:
    """Judge each directional key's newest value against its own history.

    The newest value regresses when it is worse than the trailing median
    of the previous ``min(window, available)`` values by more than
    ``threshold`` (relative).  Keys need ``min_history`` prior values
    before they gate — a brand-new metric can't regress.  ``degrade``
    synthetically worsens every newest value by that factor first: the
    deterministic failing partner ``tools/check.sh`` uses to prove the
    gate can fire.  ``direction_overrides`` ({key: "lower"|"higher"})
    wins over the name-inferred direction — the escape hatch for keys the
    naming convention misreads (and a way to gate an otherwise-untracked
    key).  Returns (regressions, n_keys_gated).
    """
    checked = 0
    found: List[Regression] = []
    overrides = direction_overrides or {}
    for (suite, key), series in sorted(trends(rows).items()):
        d = overrides.get(key) or direction(key)
        if d is None or len(series) < min_history + 1:
            continue
        prior = [p["value"] for p in series[:-1]][-window:]
        med = _median(prior)
        latest = series[-1]["value"]
        if degrade != 1.0:
            latest = latest * degrade if d == "lower" else latest / degrade
        if med <= 0:
            continue
        checked += 1
        ratio = latest / med if d == "lower" else med / max(latest, 1e-12)
        if ratio > 1.0 + threshold:
            found.append(Regression(
                suite=suite, key=key, direction=d, latest=latest,
                median=med, ratio=ratio, n_history=len(prior),
            ))
    return found, checked


def bench_result_keys(bench: dict) -> Dict[str, float]:
    """The numeric result scalars of one ``BENCH_*.json`` payload.

    Mirrors the summary table's config/result split: config echoes and
    structured fields are dropped; per-entry kernel timings are folded in
    as ``<entry-name>_us`` so the kernel suite contributes gateable
    series too.
    """
    config_keys = {"bench", "backend", "db", "fast", "reps", "block_tx",
                   "n_blocks", "P", "window_blocks", "support", "meta"}
    out: Dict[str, float] = {}
    for k, v in bench.items():
        if k in config_keys or isinstance(v, bool):
            continue
        if isinstance(v, (int, float)):
            out[k] = float(v)
    for e in bench.get("entries") or []:
        name, us = e.get("name"), e.get("us")
        if isinstance(name, str) and isinstance(us, (int, float)):
            out[f"{name}_us"] = float(us)
    return out
