"""Process-global metrics registry: counters, gauges, latency histograms.

One named home for every number the mining runtime wants to report — the
per-phase times, load-balance gauges, cache hit counters and query-latency
percentiles that used to live in five disjoint ad-hoc report shapes
(``ClusterReport`` fields, ``CacheStats`` ints, driver ``print``\\ s).  All
of them now flow through one :class:`MetricsRegistry` and come back out in
ONE canonical snapshot dict shape (DESIGN.md, "Observability")::

    {"counters":   {name: int},
     "gauges":     {name: float},
     "histograms": {name: {count, sum, mean, min, max, p50, p95, p99}}}

Design constraints, in order:

  * **zero dependencies** — stdlib only, importable from the jax-free CLI
    (``launch/obs_report.py``) and from ``store/retry.py`` alike;
  * **thread-safe** — the :class:`~repro.store.reader.BlockReader` prefetch
    worker and the serving loop record concurrently with the main thread;
  * **no sample retention** — :class:`Histogram` is log-bucketed: geometric
    buckets of width ``growth`` (default 8 %) give p50/p95/p99 within
    ``sqrt(growth)`` relative error of the exact nearest-rank percentile at
    O(buckets) memory, any stream length (numpy-verified in
    ``tests/test_obs.py`` on adversarial distributions);
  * **near-zero when idle** — recording is one lock + int add; nothing is
    formatted, allocated per-event, or written until :func:`snapshot`.

Naming scheme: ``subsystem/metric`` with per-shard families spelled
``subsystem/shard{p}/metric`` — flat strings, no label cardinality to
manage, trivially diffable across runs by ``obs_report``.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, List, Optional


class Counter:
    """Monotonic event count (thread-safe)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        return self._value


class Gauge:
    """Last-written (or high-water) value of a quantity (thread-safe)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def update_max(self, v: float) -> None:
        """High-water semantics: keep the largest value ever seen."""
        with self._lock:
            if float(v) > self._value:
                self._value = float(v)

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Log-bucketed distribution of a non-negative quantity.

    Bucket ``i`` covers ``[growth**i, growth**(i+1))`` — relative, not
    absolute, resolution, so one histogram spans nanoseconds to hours in a
    few hundred ints.  ``percentile(q)`` walks the cumulative counts to the
    nearest-rank sample's bucket and returns its geometric midpoint, clamped
    to the exact observed ``[min, max]``: the estimate is within a
    ``sqrt(growth)`` factor (≈ 4 % at the default) of
    ``numpy.percentile(samples, q, method="nearest")``.  Exact ``count``,
    ``sum``, ``min`` and ``max`` are kept on the side; values below
    ``floor`` (and zeros) land in a dedicated underflow bucket.
    """

    __slots__ = ("name", "growth", "floor", "_log_g", "_buckets", "_zero",
                 "_count", "_sum", "_min", "_max", "_lock")

    def __init__(self, name: str, growth: float = 1.08, floor: float = 1e-9):
        assert growth > 1.0, "bucket growth must be > 1"
        self.name = name
        self.growth = growth
        self.floor = floor
        self._log_g = math.log(growth)
        self._buckets: Dict[int, int] = {}
        self._zero = 0
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._lock = threading.Lock()

    def record(self, v: float) -> None:
        v = float(v)
        if v < 0.0 or v != v:          # negative or NaN: not a latency/size
            raise ValueError(f"histogram {self.name}: bad sample {v!r}")
        with self._lock:
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v
            if v < self.floor:
                self._zero += 1
            else:
                i = int(math.floor(math.log(v) / self._log_g))
                self._buckets[i] = self._buckets.get(i, 0) + 1

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def clear(self) -> None:
        """Forget every sample (the sliding-window ring rotates on this)."""
        with self._lock:
            self._buckets.clear()
            self._zero = 0
            self._count = 0
            self._sum = 0.0
            self._min = math.inf
            self._max = -math.inf

    def merge_from(self, other: "Histogram") -> None:
        """Fold ``other``'s samples into this histogram.

        Exact for every summary field: bucket counts add, min/max take the
        extremes, and the percentile walk over the summed buckets is the
        walk over the union stream.  Requires equal ``growth`` (bucket
        boundaries must line up).  Used by the sliding-window view
        (:mod:`repro.obs.slo`) to merge its ring of rotation slots into one
        last-W-seconds distribution.
        """
        assert other.growth == self.growth, "bucket geometries differ"
        with other._lock:
            buckets = dict(other._buckets)
            zero, count = other._zero, other._count
            total, lo, hi = other._sum, other._min, other._max
        if count == 0:
            return
        with self._lock:
            for i, n in buckets.items():
                self._buckets[i] = self._buckets.get(i, 0) + n
            self._zero += zero
            self._count += count
            self._sum += total
            if lo < self._min:
                self._min = lo
            if hi > self._max:
                self._max = hi

    def percentile(self, q: float) -> Optional[float]:
        """Nearest-rank percentile estimate; None on an empty histogram."""
        with self._lock:
            if self._count == 0:
                return None
            if self._count == 1:
                return self._min
            # nearest-rank index over the (conceptually sorted) samples
            k = int(round((q / 100.0) * (self._count - 1)))
            seen = self._zero
            if k < seen:
                return self._min
            for i in sorted(self._buckets):
                seen += self._buckets[i]
                if k < seen:
                    mid = math.exp((i + 0.5) * self._log_g)
                    return min(max(mid, self._min), self._max)
            return self._max

    def summary(self) -> Dict[str, Optional[float]]:
        empty = self._count == 0
        return {
            "count": self._count,
            "sum": self._sum,
            "mean": (self._sum / self._count) if not empty else None,
            "min": None if empty else self._min,
            "max": None if empty else self._max,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
        }


class MetricsRegistry:
    """Named, typed, get-or-create metric store with one snapshot shape."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls, *args):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, *args)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, requested {cls.__name__}"
                )
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, growth: float = 1.08) -> Histogram:
        return self._get(name, Histogram, growth)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def snapshot(self) -> dict:
        """The canonical dict shape every subsystem's stats reduce to."""
        with self._lock:
            items = list(self._metrics.items())
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for name, m in sorted(items):
            if isinstance(m, Counter):
                out["counters"][name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][name] = m.value
            else:
                out["histograms"][name] = m.summary()
        return out

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


#: The process-global registry every subsystem records into by default.
REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    return REGISTRY


def snapshot() -> dict:
    return REGISTRY.snapshot()


def reset() -> None:
    """Drop every process-global metric (drivers call this at run start so
    a run record contains exactly that run; tests call it for isolation)."""
    REGISTRY.reset()
