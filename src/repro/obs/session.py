"""Shared ``--trace`` / ``--metrics`` observability flags for launchers.

All four drivers (``mine``, ``cluster_mine``, ``stream_mine``,
``serve_mine``) opt into the same run-record contract through two calls::

    add_obs_flags(ap)                         # argparse: --trace/--metrics/
                                              #           --jax-profile
    obs = start_session(args, "cluster_mine") # None unless a flag was given
    ...
    if obs: obs.event("round", ...)           # driver timeline events
    ...
    if obs: obs.finish(n_fis=...)             # seal the run record

``--metrics DIR`` records the run (manifest + events + metrics snapshot);
``--trace DIR`` additionally enables the span tracer and writes the
Perfetto-loadable ``trace.json``.  Both may name the same directory; the
record layout is :mod:`repro.obs.runlog`'s.  ``--jax-profile DIR`` is the
opt-in pass-through to ``jax.profiler`` for op-level device timing.
"""
from __future__ import annotations

import argparse
import atexit
import os
import signal
import tempfile
import threading
from typing import Optional

from repro.obs import metrics as obs_metrics
from repro.obs import profile as obs_profile
from repro.obs import trace as obs_trace
from repro.obs.runlog import RunLog


def add_obs_flags(ap: argparse.ArgumentParser) -> None:
    g = ap.add_argument_group("observability")
    g.add_argument("--trace", default="", metavar="DIR",
                   help="record this run (manifest/events/metrics) to DIR "
                        "with span tracing on; DIR/trace.json loads in "
                        "Perfetto / chrome://tracing")
    g.add_argument("--metrics", default="", metavar="DIR",
                   help="record this run's manifest/events/metrics snapshot "
                        "to DIR (no tracer overhead)")
    g.add_argument("--jax-profile", default="", metavar="DIR",
                   dest="jax_profile",
                   help="also capture a jax.profiler device trace to DIR "
                        "(TensorBoard/XProf)")
    g.add_argument("--profile", action="store_true",
                   help="enable the kernel profiler: per-family measured-vs-"
                        "modeled attribution published into the run record "
                        "(render with obs_report kernels; needs --trace or "
                        "--metrics)")
    g.add_argument("--doctor", action="store_true",
                   help="diagnose this run at exit: critical path, speedup "
                        "waterfall, and the doctor's ranked findings "
                        "(records to a temp dir unless --trace/--metrics "
                        "names one; implies span tracing)")


class ObsSession:
    """A run record plus the tracer/profiler lifetime bound to it.

    Crash-safe: construction registers an ``atexit`` hook and (when on the
    main thread) a chaining ``SIGTERM`` handler, both of which flush the
    partial record — manifest (flagged ``partial``), metrics snapshot,
    trace — so a killed run still leaves a loadable, Perfetto-openable
    record next to the already-durable ``events.jsonl``.  A normal
    :meth:`finish` unregisters both and seals the record.
    """

    def __init__(self, run_dir: str, name: str, config: dict,
                 trace_on: bool, jax_profile: str = "",
                 profile_on: bool = False, doctor_on: bool = False):
        self._doctor_on = doctor_on
        # a fresh registry state so the record contains exactly this run
        obs_metrics.reset()
        self.tracer = obs_trace.tracer()
        if trace_on:
            self.tracer.clear()
            self.tracer.enable()
        self.profiler = obs_profile.profiler()
        self._profile_on = profile_on
        if profile_on:
            self.profiler.clear()
            self.profiler.enable()
        self.log = RunLog(run_dir, name, config)
        self._profiler = (
            obs_trace.jax_profiler(jax_profile) if jax_profile else None
        )
        if self._profiler is not None:
            self._profiler.__enter__()
        self._finished = False
        atexit.register(self._atexit_flush)
        self._prev_sigterm = None
        self._sigterm_installed = False
        if threading.current_thread() is threading.main_thread():
            try:
                self._prev_sigterm = signal.signal(
                    signal.SIGTERM, self._on_sigterm)
                self._sigterm_installed = True
            except (ValueError, OSError):   # no signals on this platform
                pass

    @property
    def run_dir(self) -> str:
        return self.log.run_dir

    def event(self, kind: str, **fields) -> None:
        self.log.event(kind, **fields)

    # -- crash path -----------------------------------------------------------
    def _publish_profile(self) -> None:
        if self._profile_on:
            self.profiler.publish(obs_metrics.registry())
            self._profile_on = False          # publish is cumulative: once

    def _flush_partial(self, reason: str) -> None:
        if self._finished:
            return
        self._publish_profile()
        self.log.flush_partial(
            metrics_snapshot=obs_metrics.snapshot(),
            tracer=self.tracer,
            reason=reason,
        )

    def _atexit_flush(self) -> None:
        self._flush_partial("atexit")

    def _on_sigterm(self, signum, frame) -> None:
        self._flush_partial("sigterm")
        self._finished = True
        prev = self._prev_sigterm
        if callable(prev):
            prev(signum, frame)
        # die with the conventional 128+SIGTERM status via the default
        # disposition (atexit hooks have nothing left to do)
        signal.signal(signal.SIGTERM, signal.SIG_DFL)
        signal.raise_signal(signal.SIGTERM)

    def _uninstall(self) -> None:
        atexit.unregister(self._atexit_flush)
        if self._sigterm_installed:
            try:
                signal.signal(
                    signal.SIGTERM, self._prev_sigterm or signal.SIG_DFL)
            except (ValueError, OSError):
                pass
            self._sigterm_installed = False

    def finish(self, **summary) -> str:
        if self._profiler is not None:
            self._profiler.__exit__(None, None, None)
            self._profiler = None
        self._publish_profile()
        if self.profiler.enabled:
            self.profiler.disable()
        self.log.finish(
            metrics_snapshot=obs_metrics.snapshot(),
            tracer=self.tracer,
            **summary,
        )
        self._finished = True
        self._uninstall()
        if self.tracer.enabled:
            self.tracer.disable()
        print(f"obs: run record written to {self.run_dir}"
              + (" (trace.json loads in Perfetto)" if "trace.json" in
                 os.listdir(self.run_dir) else ""))
        if self._doctor_on:
            self._print_diagnosis()
        return self.run_dir

    def _print_diagnosis(self) -> None:
        """The ``--doctor`` exit hook: diagnose the sealed record, print."""
        from repro.obs import doctor as obs_doctor
        from repro.obs import perfdb
        from repro.obs.runlog import load_run

        rows = None
        if os.path.exists(perfdb.DEFAULT_PATH):
            rows, _ = perfdb.load(perfdb.DEFAULT_PATH)
        report = obs_doctor.diagnose(
            load_run(self.run_dir), history_rows=rows)
        print(obs_doctor.render_text(report))


def start_session(args, name: str,
                  config: Optional[dict] = None) -> Optional[ObsSession]:
    """Build the session the driver's flags ask for (None when neither)."""
    run_dir = getattr(args, "trace", "") or getattr(args, "metrics", "")
    doctor_on = bool(getattr(args, "doctor", False))
    if not run_dir:
        if not doctor_on:
            return None
        # --doctor alone still needs a record to diagnose: a temp one
        run_dir = tempfile.mkdtemp(prefix=f"doctor-{name}-")
    return ObsSession(
        run_dir,
        name,
        config if config is not None else dict(vars(args)),
        # the doctor's critical path needs spans, so --doctor implies tracing
        trace_on=bool(getattr(args, "trace", "")) or doctor_on,
        jax_profile=getattr(args, "jax_profile", ""),
        profile_on=bool(getattr(args, "profile", False)),
        doctor_on=doctor_on,
    )
