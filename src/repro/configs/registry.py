"""--arch id → config module registry (all 10 assigned architectures)."""
from __future__ import annotations

import importlib
from typing import Dict

ARCHS: Dict[str, str] = {
    "granite-20b": "repro.configs.granite_20b",
    "starcoder2-15b": "repro.configs.starcoder2_15b",
    "minicpm3-4b": "repro.configs.minicpm3_4b",
    "llama3.2-3b": "repro.configs.llama3_2_3b",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large",
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "internvl2-26b": "repro.configs.internvl2_26b",
    "whisper-small": "repro.configs.whisper_small",
}


def get_config(arch: str, smoke: bool = False):
    mod = importlib.import_module(ARCHS[arch])
    return mod.smoke() if smoke else mod.config()


def all_archs():
    return list(ARCHS)
