"""Model/shape/run configuration for the LM substrate.

One :class:`ModelConfig` instance per assigned architecture lives in
``repro.configs.<id>``; each also exposes ``smoke()`` — a reduced same-family
config for CPU smoke tests.  ``repro.configs.registry`` maps ``--arch`` ids to
modules.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0            # routed experts
    n_shared: int = 0             # always-on shared experts
    top_k: int = 0
    expert_d_ff: int = 0          # per-expert FFN hidden
    every: int = 1                # MoE applied on layers where l % every == 0
    capacity_factor: float = 1.25
    lpt_placement: bool = True    # paper-bridge: LPT expert→EP-rank assignment
    ep_axis: object = None        # mesh axis for expert parallelism (set by the
                                  # launcher when n_experts divides the axis)
    token_chunk: int = 0          # >0: dispatch in token chunks (bounds the
                                  # [E·C, d] buffers regardless of sharding)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_dim: int = 64
    qk_rope_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"         # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab: int = 1024
    mlp_type: str = "swiglu"   # swiglu (3 mats) | gelu (2 mats)
    head_dim: Optional[int] = None   # default d_model // n_heads
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_every: int = 1           # hybrid: attention on layers l % attn_every == 0
    n_enc_layers: int = 0         # encdec: encoder depth (frontend stub feeds it)
    enc_context: int = 1500       # encdec: #frames the encoder sees in decode
    vision_tokens: int = 256      # vlm: #patch-embedding tokens from the stub
    sub_quadratic: bool = False   # may lower long_500k
    # numerics / memory policy
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: str = "block"          # none | block  (checkpoint each scan block)
    pad_vocab_to: int = 128       # TPU lane alignment + mesh divisibility; the
                                  # padded tail is masked out of loss/decoding

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        m = self.pad_vocab_to
        return ((self.vocab + m - 1) // m) * m

    def n_params(self) -> int:
        """Analytic parameter count (matches the spec trees; used for 6ND)."""
        d, V = self.d_model, self.vocab
        emb = V * d * (1 if self.tie_embeddings else 2)
        total = emb
        for l in range(self.n_layers):
            total += self._layer_params(l)
        if self.family == "encdec":
            for l in range(self.n_enc_layers):
                total += self._enc_layer_params()
        total += d  # final norm
        return total

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.hd
        if self.mla is not None:
            m = self.mla
            qk = m.qk_nope_dim + m.qk_rope_dim
            return (
                d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk
                + d * (m.kv_lora_rank + m.qk_rope_dim)
                + m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
                + self.n_heads * m.v_head_dim * d
                + m.q_lora_rank + m.kv_lora_rank
            )
        return (
            d * self.n_heads * hd
            + 2 * d * self.n_kv_heads * hd
            + self.n_heads * hd * d
        )

    def _mlp_params(self, layer: int) -> int:
        d = self.d_model
        k = 2 if self.mlp_type == "gelu" else 3
        if self.moe and self.moe.n_experts and (layer % self.moe.every == 0):
            m = self.moe
            per = 3 * d * m.expert_d_ff
            return (m.n_experts + m.n_shared) * per + d * m.n_experts
        return k * d * self.d_ff

    def _ssm_params(self) -> int:
        s = self.ssm
        d = self.d_model
        di = s.expand * d
        H = di // s.head_dim
        conv_dim = di + 2 * s.n_groups * s.d_state
        return (
            d * (2 * di + 2 * s.n_groups * s.d_state + H)
            + conv_dim * s.conv_width
            + 2 * H
            + di * d
            + d
        )

    def _layer_params(self, l: int) -> int:
        d = self.d_model
        if self.family == "ssm":
            return self._ssm_params() + d
        if self.family == "hybrid":
            is_attn = (l % self.attn_every) == 0
            core = self._attn_params() if is_attn else self._ssm_params()
            return core + self._mlp_params(l) + 2 * d
        mlp = self._mlp_params(l)
        extra = 0
        if self.family == "encdec":
            extra = self._attn_params() + d  # cross attention + its norm
        return self._attn_params() + mlp + 2 * d + extra

    def _enc_layer_params(self) -> int:
        k = 2 if self.mlp_type == "gelu" else 3
        return self._attn_params() + k * self.d_model * self.d_ff + 2 * self.d_model


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shapes_for(cfg: ModelConfig) -> Tuple[str, ...]:
    """The dry-run cells this architecture runs (long_500k: sub-quadratic only)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        out.append("long_500k")
    return tuple(out)
