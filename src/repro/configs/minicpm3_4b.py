"""minicpm3-4b — dense with Multi-head Latent Attention.
[hf:openbmb/MiniCPM3-4B; hf]  MLA ranks follow the HF config family
(q_lora 768, kv_lora 256, qk 64+32 rope, v 64); the assignment's "GQA kv=40"
denotes 40 full KV heads pre-compression — MLA stores the 288-wide latent.
"""
from repro.configs.base import MLAConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b",
        family="dense",
        n_layers=62,
        d_model=2560,
        n_heads=40,
        n_kv_heads=40,
        d_ff=6400,
        vocab=73448,
        mla=MLAConfig(
            q_lora_rank=768,
            kv_lora_rank=256,
            qk_nope_dim=64,
            qk_rope_dim=32,
            v_head_dim=64,
        ),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=8,
        n_kv_heads=8,
        d_ff=256,
        vocab=512,
        mla=MLAConfig(
            q_lora_rank=48, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
            v_head_dim=16,
        ),
        param_dtype="float32",
    )
