"""llama3.2-3b — small llama3, GQA(kv=8). [hf:meta-llama/Llama-3.2; unverified]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b",
        family="dense",
        n_layers=28,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_ff=8192,
        vocab=128256,
        rope_theta=500_000.0,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=8,
        n_kv_heads=4,
        d_ff=256,
        vocab=512,
        param_dtype="float32",
    )
