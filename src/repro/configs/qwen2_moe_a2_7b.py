"""qwen2-moe-a2.7b — 60 routed top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab=151936,
        moe=MoEConfig(n_experts=60, n_shared=4, top_k=4, expert_d_ff=1408),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-smoke",
        family="moe",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=64,
        vocab=512,
        moe=MoEConfig(n_experts=8, n_shared=2, top_k=4, expert_d_ff=64),
        param_dtype="float32",
    )
