"""granite-20b — dense llama-arch code model, MQA (kv=1). [arXiv:2405.04324; hf]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b",
        family="dense",
        n_layers=52,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,
        d_ff=24576,
        vocab=49152,
        mlp_type="gelu",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-20b-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=8,
        n_kv_heads=1,
        d_ff=256,
        vocab=512,
        mlp_type="gelu",
        param_dtype="float32",
    )
