"""internvl2-26b — InternLM2 backbone of the VLM; InternViT frontend is a
STUB (input_specs supplies precomputed patch embeddings). [arXiv:2404.16821; hf]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b",
        family="vlm",
        n_layers=48,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=16384,
        vocab=92553,
        vision_tokens=256,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke",
        family="vlm",
        n_layers=2,
        d_model=128,
        n_heads=8,
        n_kv_heads=4,
        d_ff=256,
        vocab=512,
        vision_tokens=8,
        param_dtype="float32",
    )
