"""starcoder2-15b — dense GQA(kv=4), RoPE. [arXiv:2402.19173; hf]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b",
        family="dense",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=4,
        d_ff=24576,
        vocab=49152,
        mlp_type="gelu",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=8,
        n_kv_heads=4,
        d_ff=256,
        vocab=512,
        mlp_type="gelu",
        param_dtype="float32",
    )
