"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7, MoE 16e top-2.
[arXiv:2403.19887; hf]  72 layers = 9 super-blocks of 8 (1 attention + 7
mamba); MoE replaces the MLP on every 2nd sublayer.  Spec-tree total is
~398B params (verified in tests).
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        n_layers=72,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=24576,
        vocab=65536,
        attn_every=8,
        moe=MoEConfig(n_experts=16, top_k=2, expert_d_ff=24576, every=2),
        ssm=SSMConfig(d_state=128, expand=2, head_dim=128, n_groups=8, chunk=256),
        sub_quadratic=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke",
        family="hybrid",
        n_layers=8,
        d_model=128,
        n_heads=8,
        n_kv_heads=2,
        d_ff=256,
        vocab=512,
        attn_every=4,
        moe=MoEConfig(n_experts=4, top_k=2, expert_d_ff=128, every=2),
        ssm=SSMConfig(d_state=16, expand=2, head_dim=32, n_groups=2, chunk=16),
        sub_quadratic=True,
        param_dtype="float32",
    )
