"""whisper-small — enc-dec; conv audio frontend is a STUB (input_specs
supplies precomputed frame embeddings).  12 encoder + 12 decoder layers.
[arXiv:2212.04356; unverified]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small",
        family="encdec",
        n_layers=12,
        n_enc_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=3072,
        vocab=51865,
        mlp_type="gelu",
        tie_embeddings=True,
        enc_context=1500,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke",
        family="encdec",
        n_layers=2,
        n_enc_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab=512,
        mlp_type="gelu",
        tie_embeddings=True,
        enc_context=16,
        param_dtype="float32",
    )
