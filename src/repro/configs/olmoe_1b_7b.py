"""olmoe-1b-7b — 64 experts top-8, no shared. [arXiv:2409.02060; hf]"""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1024,
        vocab=50304,
        moe=MoEConfig(n_experts=64, top_k=8, expert_d_ff=1024),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="olmoe-smoke",
        family="moe",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=64,
        vocab=512,
        moe=MoEConfig(n_experts=8, top_k=4, expert_d_ff=64),
        param_dtype="float32",
    )
