"""mamba2-1.3b — attention-free SSD. [arXiv:2405.21060; unverified]"""
from repro.configs.base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        family="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab=50280,
        tie_embeddings=True,
        ssm=SSMConfig(d_state=128, expand=2, head_dim=64, n_groups=1, chunk=256),
        sub_quadratic=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke",
        family="ssm",
        n_layers=2,
        d_model=128,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab=512,
        tie_embeddings=True,
        ssm=SSMConfig(d_state=16, expand=2, head_dim=32, n_groups=1, chunk=16),
        sub_quadratic=True,
        param_dtype="float32",
    )
