"""Fault-tolerant checkpointing: atomic npz shards + manifest, elastic restore.

Design for 1000+ nodes (scaled down to one host here):
  * **atomic**: write to ``step_N.tmp/`` then ``os.rename`` — a crash mid-save
    never corrupts the latest checkpoint; restart resumes from the newest
    complete manifest.
  * **elastic**: arrays are saved unsharded-logical (gathered); ``restore``
    re-``device_put``s onto *whatever mesh/shardings the new job provides*, so
    a 256-chip checkpoint restarts on 512 chips (or 8) unchanged — elastic
    scaling across restarts.
  * **data-pipeline state** (rng + step counters) rides in the manifest, so a
    restore replays the exact token stream (deterministic recovery).
  * retention: keep the newest ``keep`` checkpoints, delete older ones.

On a real multi-host pod each host writes its own address-space shard and the
manifest is written by host 0 — the single-host layout keeps the same
structure (one shard dir per "host").
"""
from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

_EXOTIC = {"bfloat16", "float8_e4m3fn", "float8_e5m2"}


def _flatten(tree) -> Dict[str, Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out[key] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # -- save -----------------------------------------------------------------
    def save(self, step: int, state: Dict[str, Any], extra: Optional[Dict] = None):
        """state: pytree dict (params/opt/...); extra: JSON-serializable."""
        tmp = self.dir / f"step_{step:010d}.tmp"
        final = self.dir / f"step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = _flatten(state)
        arrays = {}
        dtypes = {}
        for k, v in flat.items():
            a = np.asarray(jax.device_get(v))
            dtypes[k] = str(a.dtype)
            if a.dtype.name in _EXOTIC:  # numpy npz can't serialize these
                a = a.view(np.uint16 if a.dtype.itemsize == 2 else np.uint8)
            arrays[k] = a
        np.savez(tmp / "host0.npz", **arrays)
        manifest = {
            "step": step,
            "time": time.time(),
            "keys": sorted(arrays.keys()),
            "dtypes": dtypes,
            "extra": extra or {},
            "format": 1,
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        os.replace(tmp, final)  # atomic on POSIX
        self._gc()
        return final

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)
        # remove stale tmp dirs from crashed saves
        for t in self.dir.glob("*.tmp"):
            shutil.rmtree(t, ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        abstract_state: Dict[str, Any],
        step: Optional[int] = None,
        shardings: Optional[Dict[str, Any]] = None,
    ) -> Tuple[Dict[str, Any], Dict]:
        """Restore onto the template tree; optionally re-shard onto a new mesh.

        ``shardings``: a pytree congruent with state giving target shardings
        (or None → single-device).  Values are validated against the abstract
        template (shape+dtype) — a mismatched restore fails loudly.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self.dir / f"step_{step:010d}"
        manifest = json.loads((path / "manifest.json").read_text())
        data = np.load(path / "host0.npz")
        dtypes = manifest.get("dtypes", {})

        flat_template, treedef = jax.tree_util.tree_flatten_with_path(abstract_state)
        flat_shard = (
            jax.tree_util.tree_leaves(shardings) if shardings is not None else None
        )
        leaves = []
        for i, (kpath, tmpl) in enumerate(flat_template):
            key = "/".join(str(p) for p in kpath)
            arr = data[key]
            saved_dt = dtypes.get(key, str(arr.dtype))
            if saved_dt in _EXOTIC:
                arr = arr.view(getattr(ml_dtypes, saved_dt))
            if tuple(arr.shape) != tuple(tmpl.shape):
                raise ValueError(f"{key}: shape {arr.shape} != {tmpl.shape}")
            if str(arr.dtype) != str(tmpl.dtype):
                arr = arr.astype(tmpl.dtype)
            if flat_shard is not None:
                leaves.append(jax.device_put(arr, flat_shard[i]))
            else:
                leaves.append(jax.device_put(arr))
        state = jax.tree_util.tree_unflatten(treedef, leaves)
        return state, manifest["extra"]
