"""Out-of-core transaction store — mine databases bigger than memory.

``store``    Disk format: JSON manifest + packed-bitmap block files
             (``uint32[T_blk, IW]``), append-only :class:`StoreWriter`,
             host-side :class:`TxStore` handle, IBM-generator spill.
``reader``   Streamed read side: double-buffered host→device
             :class:`BlockReader` (O(block) host residency, enforced),
             block-wise shard assembly, off-disk Thm 6.1 sampling,
             streamed exact support counting.
``fimi_io``  Standard FIMI ``.dat`` parse / write / streamed ingest with
             dense-id remapping and inverse label map.
``checksum`` CRC32C (Castagnoli) in vectorized numpy — per-block payload
             integrity, verified on every read.
``fsck``     Scan / repair / quarantine: classifies every damage class of
             the failure model, adopts a crashed writer's residue.
``retry``    Bounded exponential-backoff :class:`RetryPolicy` for disk
             reads and host→device transfers (injectable clock/sleep).
"""
from repro.store.checksum import crc32c  # noqa: F401
from repro.store.fimi_io import (  # noqa: F401
    export_dat,
    ingest_dat,
    parse_dat,
    write_dat,
)
from repro.store.fsck import Damage, FsckReport, fsck  # noqa: F401
from repro.store.retry import (  # noqa: F401
    NO_RETRY,
    RetriesExhausted,
    RetryPolicy,
)
from repro.store.store import (  # noqa: F401
    ChecksumMismatchError,
    Manifest,
    MissingBlockError,
    StaleManifestError,
    StoreIntegrityError,
    StoreWriter,
    TruncatedBlockError,
    TxStore,
    pack_bool_np,
    unpack_bool_np,
    write_ibm_store,
)

# The read side imports jax; the write path above is numpy-only and must
# stay importable on hosts that never touch a device (PEP 562 lazy load).
_READER_EXPORTS = (
    "BlockReadError",
    "BlockReader",
    "HostBudgetExceeded",
    "gather_rows",
    "sample_rows",
    "streamed_itemset_supports",
    "to_device_rows",
    "to_device_shards",
)


def __getattr__(name):
    if name in _READER_EXPORTS:
        from repro.store import reader

        return getattr(reader, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_READER_EXPORTS))
