"""CRC32C (Castagnoli) in vectorized numpy — the store's integrity hash.

Every block payload in a ``txstore-v2`` manifest carries a CRC32C; the
reader verifies it on every disk read, so a single flipped bit anywhere in
a block is detected before it can corrupt a support count (DESIGN.md,
"Failure model").  The container has no C crc32c extension and a per-byte
Python loop would cost far more than the <5% checksum budget the IO
benchmark gates, so this module computes the CRC with O(COL_W + 32·log n)
**vectorized** numpy passes instead of O(n) interpreted ones:

  1. *Column scan*: reshape the message into ``[k, COL_W]`` chunks and run
     the byte-at-a-time table recurrence down the columns — one numpy op
     per byte *position*, parallel across all ``k`` chunks.
  2. *Combine tree*: CRC is linear over GF(2), so
     ``crc(A‖B) = shift_{8·|B|}(crc(A)) ^ crc(B)`` where ``shift_m`` (the
     operator that appends ``m`` zero bytes) is a fixed 32×32 bit matrix.
     Adjacent chunk CRCs are folded pairwise, squaring the shift matrix per
     level — log₂(k) vectorized folds.

Init/xorout handling uses the same linearity: seeding the register with
``0xFFFFFFFF`` equals XORing ``shift_{8n}(0xFFFFFFFF)`` into the raw
(zero-seeded) CRC.  Zero-seeded CRCs ignore leading zero bytes
(``TABLE[0] == 0``), which is what makes the front-padding in step 1 safe.

Verified against the RFC 3720 check value (``crc32c(b"123456789") ==
0xE3069283``) and a per-byte reference in ``tests/test_faults.py``.
"""
from __future__ import annotations

import functools
from typing import Union

import numpy as np

_POLY = np.uint32(0x82F63B78)   # Castagnoli, reflected
_INIT = 0xFFFFFFFF
_COL_W = 64                     # bytes per chunk in the column scan


def _make_table() -> np.ndarray:
    """Byte-at-a-time table: TABLE[b] = zero-seeded CRC of the byte b."""
    idx = np.arange(256, dtype=np.uint32)
    c = idx
    for _ in range(8):
        c = (c >> np.uint32(1)) ^ np.where(c & np.uint32(1), _POLY, np.uint32(0))
    return c


_TABLE = _make_table()


def _apply_op(mat: np.ndarray, values: np.ndarray) -> np.ndarray:
    """Apply a 32×32 GF(2) operator to uint32 values, vectorized over them.

    ``mat[i]`` is the operator's image of basis vector ``1 << i``; the image
    of ``v`` is the XOR of rows selected by v's set bits.
    """
    out = np.zeros_like(values)
    for i in range(32):
        bit = (values >> np.uint32(i)) & np.uint32(1)
        out ^= bit * mat[i]
    return out


def _compose(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Operator composition a∘b (apply b, then a), as basis images."""
    return _apply_op(a, b)


@functools.lru_cache(maxsize=64)
def _zero_op(n_bytes: int) -> np.ndarray:
    """32×32 GF(2) matrix of "extend the CRC register by n zero bytes"."""
    assert n_bytes >= 1
    basis = np.uint32(1) << np.arange(32, dtype=np.uint32)
    one = (basis >> np.uint32(8)) ^ _TABLE[basis & np.uint32(0xFF)]
    if n_bytes == 1:
        return one
    half = _zero_op(n_bytes // 2)
    op = _compose(half, half)
    if n_bytes % 2:
        op = _compose(one, op)
    return op


def _crc_raw(data: np.ndarray) -> int:
    """Zero-seeded, zero-xorout CRC32C of a uint8 array (vectorized)."""
    n = int(data.size)
    if n == 0:
        return 0
    k = -(-n // _COL_W)
    k = 1 << max(k - 1, 0).bit_length()       # power of two for the fold tree
    buf = np.zeros(k * _COL_W, np.uint8)
    buf[-n:] = data                            # front zero-pad: crc-neutral
    cols = buf.reshape(k, _COL_W)
    state = np.zeros(k, np.uint32)
    for j in range(_COL_W):                    # parallel across all k chunks
        state = (state >> np.uint32(8)) ^ _TABLE[
            (state ^ cols[:, j]) & np.uint32(0xFF)
        ]
    op = _zero_op(_COL_W)
    while state.size > 1:                      # crc(A‖B) = op(crc A) ^ crc B
        state = _apply_op(op, state[0::2]) ^ state[1::2]
        op = _compose(op, op)
    return int(state[0])


def crc32c(data: Union[bytes, bytearray, memoryview, np.ndarray]) -> int:
    """CRC32C (Castagnoli; init and xorout ``0xFFFFFFFF``) of ``data``."""
    arr = np.frombuffer(memoryview(data), np.uint8) if not isinstance(
        data, np.ndarray
    ) else np.ascontiguousarray(data).view(np.uint8).reshape(-1)
    n = int(arr.size)
    if n == 0:
        return 0
    seed = _apply_op(_zero_op(n), np.array([_INIT], np.uint32))[0]
    return int(_crc_raw(arr) ^ seed ^ np.uint32(_INIT))


def crc32c_ref(data) -> int:
    """Per-byte reference implementation (tests only — O(n) Python)."""
    if isinstance(data, np.ndarray):
        data = np.ascontiguousarray(data).view(np.uint8).reshape(-1)
    c = _INIT
    for b in data:
        c = (c >> 8) ^ int(_TABLE[(c ^ int(b)) & 0xFF])
    return c ^ _INIT
