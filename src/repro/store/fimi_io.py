"""FIMI ``.dat`` reader/writer — the standard frequent-itemset exchange format.

One transaction per line, items as whitespace-separated tokens (the public
FIMI repository datasets — retail, kosarak, T10I4D100K … — all use it).
Item tokens are remapped to **dense ids** in first-occurrence order; the
inverse map (dense id → source label) is kept alongside so a store round-
trips back to the original labels.

Everything streams line-by-line / block-by-block: ingesting a multi-GB
``.dat`` into a :class:`~repro.store.store.TxStore` holds one block of
transactions at a time (two passes: label scan, then packed spill).
"""
from __future__ import annotations

from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.store.store import StoreWriter, TxStore


def iter_dat(path: str) -> Iterator[List[str]]:
    """Yield one transaction per line as raw item tokens (blank lines skipped)."""
    with open(path) as f:
        for line in f:
            toks = line.split()
            if toks:
                yield toks


def scan_labels(path: str) -> List[str]:
    """First pass: distinct item tokens in first-occurrence order."""
    seen: Dict[str, int] = {}
    for toks in iter_dat(path):
        for t in toks:
            if t not in seen:
                seen[t] = len(seen)
    return list(seen)


def parse_dat(path: str) -> Tuple[List[List[int]], List[str]]:
    """Parse a ``.dat`` file into dense-id transactions + the label map.

    Returns ``(transactions, labels)`` where ``transactions[t]`` is the
    sorted list of dense item ids of line ``t`` (duplicates within a line
    collapse — a transaction is a set) and ``labels[i]`` is the source token
    of dense id ``i``.  In-RAM convenience for small files; use
    :func:`ingest_dat` for anything large.
    """
    labels: List[str] = []
    ids: Dict[str, int] = {}
    txs: List[List[int]] = []
    for toks in iter_dat(path):
        row = set()
        for t in toks:
            if t not in ids:
                ids[t] = len(labels)
                labels.append(t)
            row.add(ids[t])
        txs.append(sorted(row))
    return txs, labels


def write_dat(
    path: str,
    transactions: Iterable[Sequence[int]],
    labels: Optional[Sequence[str]] = None,
) -> None:
    """Write transactions to ``.dat``: one line per transaction, items in
    ascending dense-id order, rendered through ``labels`` when given (else
    the dense ids themselves) — the canonical form :func:`parse_dat` reads
    back bit-exactly."""
    with open(path, "w") as f:
        for tx in transactions:
            items = sorted(set(int(i) for i in tx))
            toks = [labels[i] if labels is not None else str(i) for i in items]
            f.write(" ".join(toks) + "\n")


def ingest_dat(path: str, directory: str, block_tx: int = 1024) -> TxStore:
    """Stream a ``.dat`` file into an on-disk store, O(block) host memory.

    Two passes: (1) scan the distinct item tokens to fix the dense universe,
    (2) re-read, densify ``block_tx`` transactions at a time, pack, append.
    The label map lands in the manifest (``item_labels``), so
    :func:`export_dat` restores the original tokens.
    """
    labels = scan_labels(path)
    ids = {t: i for i, t in enumerate(labels)}
    n_items = max(len(labels), 1)
    w = StoreWriter(
        directory,
        n_items=n_items,
        block_tx=block_tx,
        item_labels=labels,
        source=f"fimi:{path}",
        flush_every=16,  # bulk ingest: amortize the O(n_blocks) manifest dump
    )
    block = np.zeros((block_tx, n_items), dtype=bool)
    fill = 0
    for toks in iter_dat(path):
        for t in toks:
            block[fill, ids[t]] = True
        fill += 1
        if fill == block_tx:
            w.append_dense(block)
            block[:] = False
            fill = 0
    if fill:
        w.append_dense(block[:fill])
    return w.close()


def export_dat(store: TxStore, path: str) -> None:
    """Stream a store back to ``.dat`` (original labels when ingested from
    one, dense ids otherwise) — the inverse of :func:`ingest_dat`."""
    labels = store.item_labels
    from repro.store.store import unpack_bool_np

    with open(path, "w") as f:
        for blk in store.iter_blocks():
            dense = unpack_bool_np(blk, store.n_items)
            for row in dense:
                items = np.nonzero(row)[0]
                toks = [
                    labels[i] if labels is not None else str(i) for i in items
                ]
                f.write(" ".join(toks) + "\n")
