"""On-disk columnar transaction store — the out-of-core tier.

The paper's opening premise is that "the data do not fit into main memory";
this module is the repo's answer (DESIGN.md, "Storage subsystem").  A store
is a directory::

    store/
      manifest.json          # n_tx, n_items, block sizes, per-block sketches
      blocks/
        block_000000.npy     # uint32[T_blk, IW] packed transaction rows
        block_000001.npy
        ...

Each block holds ``pack_bool``-layout horizontal bitmap rows (bit ``k`` of
word ``w`` = item ``32·w + k``, exactly ``core.bitmap.pack_bool``), so a
block read from disk is device-ready without any host transform — the
double-buffered :class:`~repro.store.reader.BlockReader` just
``jax.device_put``s it.  Blocks may be ragged (a partial final block, or
even empty blocks from an idle stream spill); the manifest records every
block's row count so readers never guess.

This module is deliberately **numpy-only** (no jax import): the write path
(`ibm_gen` spill, FIMI ``.dat`` ingest, sliding-window spill) must run
O(block) on hosts that never touch a device.  The device-facing read path
lives in :mod:`repro.store.reader`.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Iterator, List, Optional, Sequence

import numpy as np

from repro.store.checksum import crc32c

MANIFEST_NAME = "manifest.json"
BLOCK_DIR = "blocks"
FORMAT = "txstore-v2"           # written by this code: per-block crc32c
LEGACY_FORMATS = ("txstore-v1",)  # still readable (no checksums to verify)
WORD_BITS = 32
SKETCH_K = 16  # per-block item-frequency sketch width


# ---------------------------------------------------------------------------
# Integrity errors (DESIGN.md, "Failure model")
# ---------------------------------------------------------------------------


class StoreIntegrityError(RuntimeError):
    """The store's on-disk state contradicts its manifest.

    Every subclass names a *distinct, actionable* damage class — the reader
    raises these instead of ever returning silently wrong rows, and
    :mod:`repro.store.fsck` classifies a whole store with them.
    """


class MissingBlockError(StoreIntegrityError):
    """A manifest-indexed block file does not exist on disk."""


class TruncatedBlockError(StoreIntegrityError):
    """A block file is shorter than its payload (torn/partial write)."""


class ChecksumMismatchError(StoreIntegrityError):
    """A block payload fails its CRC32C (bit rot / silent corruption)."""


class StaleManifestError(StoreIntegrityError):
    """Manifest metadata and block payload disagree structurally
    (hand-edited or out-of-date manifest: wrong shape, dtype, or byte
    count for a payload that otherwise reads cleanly)."""


def n_words(n: int) -> int:
    return (n + WORD_BITS - 1) // WORD_BITS


def block_file_index(rel_or_name: str) -> Optional[int]:
    """The NNNNNN of a ``block_NNNNNN.npy`` file name (None if not one)."""
    name = os.path.basename(rel_or_name)
    if name.startswith("block_") and name.endswith(".npy"):
        digits = name[len("block_"):-len(".npy")]
        if digits.isdigit():
            return int(digits)
    return None


# ---------------------------------------------------------------------------
# Host-side packing (bit-exact mirror of core.bitmap.{pack,unpack}_bool)
# ---------------------------------------------------------------------------


def pack_bool_np(dense: np.ndarray) -> np.ndarray:
    """Pack bool ``[..., n]`` into uint32 ``[..., n_words(n)]`` on host.

    Same layout as ``core.bitmap.pack_bool`` (little-endian within words):
    ``np.packbits(bitorder="little")`` puts column ``8b + k`` at bit ``k`` of
    byte ``b``, and viewing 4 bytes as a little-endian uint32 puts byte ``b``
    at bits ``8b..8b+7`` — composing to column ``32w + k`` ↔ bit ``k`` of
    word ``w``.
    """
    dense = np.asarray(dense, dtype=bool)
    n = dense.shape[-1]
    W = n_words(n)
    pad = W * WORD_BITS - n
    if pad:
        dense = np.concatenate(
            [dense, np.zeros(dense.shape[:-1] + (pad,), bool)], axis=-1
        )
    packed8 = np.packbits(dense, axis=-1, bitorder="little")
    return np.ascontiguousarray(packed8).view(np.uint32).reshape(
        dense.shape[:-1] + (W,)
    )


def unpack_bool_np(packed: np.ndarray, n: int) -> np.ndarray:
    """Inverse of :func:`pack_bool_np`: bool ``[..., n]``."""
    packed = np.ascontiguousarray(np.asarray(packed, np.uint32))
    bits8 = packed.view(np.uint8)
    bits = np.unpackbits(bits8, axis=-1, bitorder="little")
    return bits[..., :n].astype(bool)


# ---------------------------------------------------------------------------
# Manifest
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BlockMeta:
    """One block's manifest entry.

    ``n_bytes``/``crc32c`` are the v2 integrity fields (payload byte size
    and CRC32C of the packed rows); ``None`` on blocks indexed by a legacy
    v1 manifest, which read without verification.
    """

    file: str               # relative path under the store dir
    n_tx: int               # rows in this block (0 = empty block)
    sketch_items: List[int]     # top-K item ids by in-block frequency
    sketch_counts: List[int]    # their in-block supports
    n_bytes: Optional[int] = None   # packed payload bytes (v2)
    crc32c: Optional[int] = None    # CRC32C of the payload bytes (v2)

    def as_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "BlockMeta":
        return cls(
            file=d["file"],
            n_tx=int(d["n_tx"]),
            sketch_items=[int(x) for x in d["sketch_items"]],
            sketch_counts=[int(x) for x in d["sketch_counts"]],
            n_bytes=None if d.get("n_bytes") is None else int(d["n_bytes"]),
            crc32c=None if d.get("crc32c") is None else int(d["crc32c"]),
        )


@dataclasses.dataclass
class Manifest:
    """The store's JSON metadata (everything a reader plans with)."""

    n_tx: int
    n_items: int
    n_words: int
    block_tx: int           # nominal rows per block (blocks may be ragged)
    blocks: List[BlockMeta]
    item_counts: List[int]  # exact global per-item supports, length n_items
    item_labels: Optional[List[str]]  # dense id -> source label (.dat ingest)
    source: str

    def as_json(self) -> dict:
        return {
            "format": FORMAT,
            "n_tx": self.n_tx,
            "n_items": self.n_items,
            "n_words": self.n_words,
            "block_tx": self.block_tx,
            "blocks": [b.as_json() for b in self.blocks],
            "item_counts": self.item_counts,
            "item_labels": self.item_labels,
            "source": self.source,
        }

    @classmethod
    def from_json(cls, d: dict) -> "Manifest":
        if d.get("format") not in (FORMAT,) + LEGACY_FORMATS:
            raise ValueError(f"not a {FORMAT} manifest: {d.get('format')!r}")
        return cls(
            n_tx=int(d["n_tx"]),
            n_items=int(d["n_items"]),
            n_words=int(d["n_words"]),
            block_tx=int(d["block_tx"]),
            blocks=[BlockMeta.from_json(b) for b in d["blocks"]],
            item_counts=[int(x) for x in d["item_counts"]],
            item_labels=d.get("item_labels"),
            source=d.get("source", ""),
        )


def write_manifest(directory: str, manifest: Manifest) -> None:
    """Atomically publish a manifest (write-temp + ``os.replace``).

    Shared by the writer, fsck's repairs, and the cluster checkpoint —
    readers never observe a torn metadata file.
    """
    path = os.path.join(directory, MANIFEST_NAME)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(manifest.as_json(), f, indent=1)
        f.write("\n")
    os.replace(tmp, path)


# ---------------------------------------------------------------------------
# Writer
# ---------------------------------------------------------------------------


class StoreWriter:
    """Append packed transaction blocks to a store directory, O(block) host.

    The manifest is rewritten every ``flush_every`` appends (default: every
    append) and on :meth:`close`, so a store is readable at any point of a
    long spill; after a crash at most ``flush_every`` trailing blocks are
    unindexed.  Serializing the manifest costs O(n_blocks), so bulk writers
    (``write_ibm_store``, ``ingest_dat``) raise the cadence to keep a long
    spill O(n_blocks) total instead of O(n_blocks²).
    ``append_dense`` / ``append_packed`` both return the block index.

    ``resume=True`` re-opens an existing store and keeps appending after its
    last block (geometry must match) instead of resetting it — the window
    spill uses this so a restarted stream extends its history rather than
    silently destroying it.  Resume first runs :func:`repro.store.fsck.fsck`
    in repair mode to clean up after a crashed writer: block files appended
    after the last manifest flush are deterministically **adopted** (their
    counts and checksums recomputed into the manifest) and a torn trailing
    payload is deleted, so the crash window between ``np.save`` and the
    manifest publish can neither lose indexed data nor miscount it.
    """

    def __init__(
        self,
        directory: str,
        n_items: int,
        block_tx: int,
        *,
        item_labels: Optional[Sequence[str]] = None,
        source: str = "",
        resume: bool = False,
        flush_every: int = 1,
    ):
        self.directory = directory
        self.flush_every = max(1, int(flush_every))
        os.makedirs(os.path.join(directory, BLOCK_DIR), exist_ok=True)
        manifest_path = os.path.join(directory, MANIFEST_NAME)
        if resume and os.path.exists(manifest_path):
            from repro.store.fsck import fsck as run_fsck

            # adopt blocks a crashed writer saved but never indexed, delete
            # torn partial payloads — then the manifest below is trustworthy.
            # Shallow scan: one stat per indexed block, payload reads only
            # for orphans, so restarting a long stream spill stays cheap.
            rep = run_fsck(directory, repair=True, deep=False)
            if not rep.clean:
                raise StoreIntegrityError(
                    f"cannot resume {directory}: unrepaired damage —\n"
                    f"{rep.summary()}\n"
                    f"run repro.launch.fsck --quarantine to salvage it"
                )
            with open(manifest_path) as f:
                self.manifest = Manifest.from_json(json.load(f))
            if (self.manifest.n_items != int(n_items)
                    or self.manifest.block_tx != int(block_tx)):
                raise ValueError(
                    f"cannot resume {directory}: existing geometry "
                    f"(n_items={self.manifest.n_items}, "
                    f"block_tx={self.manifest.block_tx}) != requested "
                    f"({n_items}, {block_tx})"
                )
            self._counts = np.asarray(self.manifest.item_counts, np.int64)
            self._next_idx = 1 + max(
                (i for i in (block_file_index(b.file)
                             for b in self.manifest.blocks) if i is not None),
                default=-1,
            )
            return
        self.manifest = Manifest(
            n_tx=0,
            n_items=int(n_items),
            n_words=n_words(n_items),
            block_tx=int(block_tx),
            blocks=[],
            item_counts=[0] * int(n_items),
            item_labels=list(item_labels) if item_labels is not None else None,
            source=source,
        )
        self._counts = np.zeros(int(n_items), np.int64)
        self._next_idx = 0
        self._flush()

    # -- append ---------------------------------------------------------------
    def append_dense(self, dense: np.ndarray) -> int:
        """Append a dense bool block ``[T, n_items]`` (packed here, O(block))."""
        dense = np.asarray(dense, dtype=bool)
        assert dense.ndim == 2 and dense.shape[1] == self.manifest.n_items
        return self._append(pack_bool_np(dense), dense.sum(axis=0))

    def append_packed(self, packed: np.ndarray) -> int:
        """Append an already-packed block ``uint32[T, IW]``."""
        packed = np.asarray(packed, np.uint32)
        assert packed.ndim == 2 and packed.shape[1] == self.manifest.n_words, (
            f"block shape {packed.shape} != (*, {self.manifest.n_words})"
        )
        if packed.shape[0]:
            counts = unpack_bool_np(packed, self.manifest.n_items).sum(axis=0)
        else:
            counts = np.zeros(self.manifest.n_items, np.int64)
        return self._append(packed, counts)

    def _append(self, packed: np.ndarray, item_counts: np.ndarray) -> int:
        bidx = len(self.manifest.blocks)
        # file names use a monotone counter, not len(blocks): after fsck
        # quarantines a mid-store block the two diverge, and reusing a name
        # would overwrite a payload the manifest still indexes
        rel = os.path.join(BLOCK_DIR, f"block_{self._next_idx:06d}.npy")
        self._next_idx += 1
        packed = np.ascontiguousarray(packed)
        np.save(os.path.join(self.directory, rel), packed, allow_pickle=False)
        counts = np.asarray(item_counts, np.int64)
        k = min(SKETCH_K, self.manifest.n_items)
        top = np.argsort(-counts, kind="stable")[:k]
        top = top[counts[top] > 0]
        self.manifest.blocks.append(
            BlockMeta(
                file=rel,
                n_tx=int(packed.shape[0]),
                sketch_items=[int(i) for i in top],
                sketch_counts=[int(counts[i]) for i in top],
                n_bytes=int(packed.nbytes),
                crc32c=crc32c(packed),
            )
        )
        self.manifest.n_tx += int(packed.shape[0])
        self._counts += counts
        if len(self.manifest.blocks) % self.flush_every == 0:
            self._flush()
        return bidx

    def _flush(self) -> None:
        self.manifest.item_counts = [int(c) for c in self._counts]
        write_manifest(self.directory, self.manifest)

    def close(self) -> "TxStore":
        self._flush()
        return TxStore.open(self.directory)


# ---------------------------------------------------------------------------
# Store handle (read side, host)
# ---------------------------------------------------------------------------


class TxStore:
    """Handle on an on-disk store: manifest + lazy block reads.

    Pure host metadata object — opening a store reads only the manifest.
    Block payloads are read on demand (:meth:`read_block`) by the streamed
    consumers in :mod:`repro.store.reader`; nothing here ever materializes
    more than one block.

    Every block read is verified against the manifest's integrity fields
    (payload byte size + CRC32C) and raises a typed
    :class:`StoreIntegrityError` on any disagreement — never a silently
    wrong count.  ``verify=False`` skips the CRC pass (the IO benchmark's
    overhead baseline); legacy v1 manifests carry no checksums and read
    unverified either way.
    """

    def __init__(self, directory: str, manifest: Manifest, verify: bool = True):
        self.directory = directory
        self.manifest = manifest
        self.verify = verify

    @classmethod
    def open(cls, directory: str, verify: bool = True) -> "TxStore":
        path = os.path.join(directory, MANIFEST_NAME)
        with open(path) as f:
            return cls(directory, Manifest.from_json(json.load(f)), verify)

    @staticmethod
    def exists(directory: str) -> bool:
        return os.path.exists(os.path.join(directory, MANIFEST_NAME))

    # -- metadata views -------------------------------------------------------
    @property
    def n_tx(self) -> int:
        return self.manifest.n_tx

    @property
    def n_items(self) -> int:
        return self.manifest.n_items

    @property
    def n_words(self) -> int:
        return self.manifest.n_words

    @property
    def n_blocks(self) -> int:
        return len(self.manifest.blocks)

    @property
    def block_tx(self) -> int:
        return self.manifest.block_tx

    @property
    def block_sizes(self) -> List[int]:
        return [b.n_tx for b in self.manifest.blocks]

    @property
    def total_bytes(self) -> int:
        """Packed payload bytes across all blocks (the out-of-core size)."""
        return sum(b.n_tx * self.n_words * 4 for b in self.manifest.blocks)

    @property
    def max_block_bytes(self) -> int:
        return max(
            (b.n_tx * self.n_words * 4 for b in self.manifest.blocks),
            default=0,
        )

    @property
    def item_labels(self) -> Optional[List[str]]:
        return self.manifest.item_labels

    def item_counts(self) -> np.ndarray:
        """Exact global per-item supports (maintained by the writer)."""
        return np.asarray(self.manifest.item_counts, np.int64)

    # -- block reads ----------------------------------------------------------
    def read_block(self, i: int) -> np.ndarray:
        """One packed block ``uint32[T_i, IW]`` from disk, verified.

        Raises :class:`MissingBlockError` / :class:`TruncatedBlockError` /
        :class:`StaleManifestError` / :class:`ChecksumMismatchError` — each
        damage class is distinct so callers (and the fsck CLI) can act on
        it.  OS-level read failures propagate as ``OSError`` for the
        reader's retry policy.
        """
        meta = self.manifest.blocks[i]
        path = os.path.join(self.directory, meta.file)
        if not os.path.exists(path):
            raise MissingBlockError(
                f"block {i}: {path} does not exist (manifest expects "
                f"{meta.n_tx} rows) — restore the file or fsck --quarantine"
            )
        try:
            arr = np.load(path, allow_pickle=False)
        except (ValueError, EOFError) as e:
            # np.save is not atomic: a crash mid-write leaves a payload
            # shorter than its own header claims, which np.load rejects
            raise TruncatedBlockError(
                f"block {i}: {path} is truncated or torn "
                f"(manifest expects {meta.n_tx}x{self.n_words} uint32): {e}"
            ) from e
        if arr.dtype != np.uint32 or arr.shape != (meta.n_tx, self.n_words):
            raise StaleManifestError(
                f"block {i}: payload {arr.dtype}{list(arr.shape)} != "
                f"manifest uint32[{meta.n_tx}, {self.n_words}] at {path} — "
                f"manifest is stale or hand-edited"
            )
        if meta.n_bytes is not None and int(arr.nbytes) != meta.n_bytes:
            raise StaleManifestError(
                f"block {i}: payload is {arr.nbytes}B but manifest records "
                f"{meta.n_bytes}B at {path}"
            )
        if self.verify and meta.crc32c is not None:
            got = crc32c(np.ascontiguousarray(arr))
            if got != meta.crc32c:
                raise ChecksumMismatchError(
                    f"block {i}: CRC32C {got:#010x} != manifest "
                    f"{meta.crc32c:#010x} at {path} — payload bits flipped "
                    f"since the writer sealed it"
                )
        return np.asarray(arr, np.uint32)

    def iter_blocks(self) -> Iterator[np.ndarray]:
        """Host-side block iterator (one block resident at a time)."""
        for i in range(self.n_blocks):
            yield self.read_block(i)

    # -- materialized views (parity gates / tests only — O(n_tx) host) --------
    def read_all_packed(self) -> np.ndarray:
        """All rows ``uint32[n_tx, IW]`` — parity/tests only, O(n_tx) host."""
        if self.n_blocks == 0:
            return np.zeros((0, self.n_words), np.uint32)
        return np.concatenate(list(self.iter_blocks()), axis=0)

    def to_dense(self) -> np.ndarray:
        """Dense bool ``[n_tx, n_items]`` — parity/tests only, O(n_tx·I) host."""
        return unpack_bool_np(self.read_all_packed(), self.n_items)


# ---------------------------------------------------------------------------
# IBM-generator spill: synthesize straight to disk, O(block) host memory
# ---------------------------------------------------------------------------


def write_ibm_store(
    params, directory: str, block_tx: int = 4096
) -> TxStore:
    """Spill an IBM-generator database to a store, one block at a time.

    Uses :func:`repro.data.ibm_gen.generate_blocks`, so peak host residency
    is one dense block + one packed block — never the full ``[N, I]`` matrix
    the old generate-then-pack pipeline materialized.
    """
    from repro.data.ibm_gen import generate_blocks

    w = StoreWriter(
        directory,
        n_items=params.n_items,
        block_tx=block_tx,
        source=f"ibm:{params.name}:seed={params.seed}",
        flush_every=16,  # bulk spill: amortize the O(n_blocks) manifest dump
    )
    for dense_block in generate_blocks(params, block_tx):
        w.append_dense(dense_block)
    return w.close()
