"""Store fsck: scan a TxStore, classify damage, repair or quarantine.

The store's failure model (DESIGN.md, "Failure model") names four damage
classes plus one recoverable crash artifact:

  ``missing``         an indexed block file is gone;
  ``truncated``       an indexed payload is shorter than the manifest
                      records (torn ``np.save``, partial copy);
  ``bit-flip``        payload bytes fail their CRC32C;
  ``stale-manifest``  payload reads cleanly but disagrees structurally
                      with its manifest entry (shape/dtype/bytes), or the
                      manifest's totals disagree with its own blocks;
  ``orphan``          a ``block_NNNNNN.npy`` on disk that no manifest entry
                      indexes — the normal residue of a writer that crashed
                      between ``np.save`` and the manifest flush.

:func:`fsck` only ever *adds* safety: without flags it is a read-only scan;
``repair=True`` adopts the contiguous run of valid orphans left by a
crashed writer (recomputing their counts, sketches, and checksums into the
manifest — deterministic, so two resumes of the same crash agree) and
deletes torn or non-contiguous orphans; ``quarantine=True`` additionally
moves damaged *indexed* blocks into ``quarantine/`` and rebuilds the
manifest's exact totals from the surviving payloads, so what remains is a
smaller but internally consistent store.  Damage repair never guesses at
payload bits: a block that fails its checksum is quarantined, not patched.

``StoreWriter(resume=True)`` runs the ``deep=False`` mode before touching
an existing store: orphan adoption plus cheap size/existence checks (one
``stat`` per block, no payload reads), which keeps stream-spill restarts
O(orphans) while still closing the writer's crash window.  The CLI
(``launch/fsck.py``) defaults to ``deep=True``, which reads and checksums
every payload.
"""
from __future__ import annotations

import dataclasses
import os
from typing import List, Optional

import numpy as np

from repro.store.checksum import crc32c
from repro.store.store import (
    BLOCK_DIR,
    BlockMeta,
    ChecksumMismatchError,
    MissingBlockError,
    SKETCH_K,
    StaleManifestError,
    StoreIntegrityError,
    TruncatedBlockError,
    TxStore,
    block_file_index,
    unpack_bool_np,
    write_manifest,
)

QUARANTINE_DIR = "quarantine"


@dataclasses.dataclass
class Damage:
    """One classified finding (and what, if anything, was done about it)."""

    kind: str                   # missing|truncated|bit-flip|stale-manifest|orphan
    path: str
    detail: str
    block_index: Optional[int] = None   # manifest position, None for orphans
    action: str = "none"        # none|adopted|deleted|quarantined|repaired


@dataclasses.dataclass
class FsckReport:
    directory: str
    n_blocks: int               # manifest-indexed blocks after fsck
    n_tx: int
    damages: List[Damage]
    deep: bool

    @property
    def clean(self) -> bool:
        """True when no damage remains unhandled after the requested mode."""
        return all(d.action != "none" for d in self.damages)

    def summary(self) -> str:
        if not self.damages:
            return (f"{self.directory}: clean "
                    f"({self.n_blocks} blocks, {self.n_tx} tx, "
                    f"{'deep' if self.deep else 'shallow'} scan)")
        lines = [
            f"{self.directory}: {len(self.damages)} finding(s) "
            f"({'deep' if self.deep else 'shallow'} scan)"
        ]
        for d in self.damages:
            where = f"block {d.block_index}" if d.block_index is not None \
                else "orphan"
            lines.append(f"  [{d.kind}] {where} {d.path}: {d.detail}"
                         f" -> {d.action}")
        return "\n".join(lines)


def _classify_indexed(store: TxStore, i: int, deep: bool) -> Optional[Damage]:
    """Damage of manifest block ``i``, or None if it checks out."""
    meta = store.manifest.blocks[i]
    path = os.path.join(store.directory, meta.file)
    if not deep:
        # shallow: one stat per block — existence plus a payload-size floor
        if not os.path.exists(path):
            return Damage("missing", path, "file does not exist", i)
        if meta.n_bytes is not None and os.path.getsize(path) < meta.n_bytes:
            return Damage(
                "truncated", path,
                f"{os.path.getsize(path)}B on disk < {meta.n_bytes}B payload",
                i,
            )
        return None
    try:
        store.read_block(i)
    except MissingBlockError as e:
        return Damage("missing", path, str(e), i)
    except TruncatedBlockError as e:
        return Damage("truncated", path, str(e), i)
    except ChecksumMismatchError as e:
        return Damage("bit-flip", path, str(e), i)
    except StaleManifestError as e:
        return Damage("stale-manifest", path, str(e), i)
    return None


def _adoptable(path: str, n_words: int) -> Optional[np.ndarray]:
    """The orphan's payload if it is a well-formed packed block, else None."""
    try:
        arr = np.load(path, allow_pickle=False)
    except (ValueError, EOFError, OSError):
        return None
    if arr.dtype != np.uint32 or arr.ndim != 2 or arr.shape[1] != n_words:
        return None
    return np.ascontiguousarray(arr)


def _adopt(store: TxStore, rel: str, arr: np.ndarray) -> None:
    """Index an orphan payload: recompute counts, sketch, and checksum."""
    m = store.manifest
    counts = (
        unpack_bool_np(arr, m.n_items).sum(axis=0).astype(np.int64)
        if arr.shape[0] else np.zeros(m.n_items, np.int64)
    )
    k = min(SKETCH_K, m.n_items)
    top = np.argsort(-counts, kind="stable")[:k]
    top = top[counts[top] > 0]
    m.blocks.append(BlockMeta(
        file=rel,
        n_tx=int(arr.shape[0]),
        sketch_items=[int(i) for i in top],
        sketch_counts=[int(counts[i]) for i in top],
        n_bytes=int(arr.nbytes),
        crc32c=crc32c(arr),
    ))
    m.n_tx += int(arr.shape[0])
    m.item_counts = [
        int(a + b) for a, b in zip(m.item_counts, counts)
    ]


def _recount(store: TxStore) -> None:
    """Rebuild manifest totals (n_tx, item_counts) from surviving payloads."""
    m = store.manifest
    counts = np.zeros(m.n_items, np.int64)
    n_tx = 0
    for i in range(len(m.blocks)):
        arr = store.read_block(i)
        if arr.shape[0]:
            counts += unpack_bool_np(arr, m.n_items).sum(axis=0)
        n_tx += int(arr.shape[0])
    m.n_tx = n_tx
    m.item_counts = [int(c) for c in counts]


def fsck(
    directory: str,
    *,
    repair: bool = False,
    quarantine: bool = False,
    deep: bool = True,
) -> FsckReport:
    """Scan (and optionally heal) the store at ``directory``.

    ``repair`` adopts a crashed writer's contiguous valid orphans and
    deletes torn ones; ``quarantine`` (implies ``repair``) also moves
    damaged indexed blocks to ``quarantine/`` and recounts the manifest
    exactly from what survives.  Returns a :class:`FsckReport`; raises
    ``FileNotFoundError`` if there is no manifest to check against.
    """
    repair = repair or quarantine
    store = TxStore.open(directory)
    m = store.manifest
    damages: List[Damage] = []

    # ---- orphan scan: block files no manifest entry indexes ---------------
    indexed = {os.path.normpath(b.file) for b in m.blocks}
    block_dir = os.path.join(directory, BLOCK_DIR)
    orphans = sorted(
        (idx, name) for name in os.listdir(block_dir)
        if os.path.normpath(os.path.join(BLOCK_DIR, name)) not in indexed
        and (idx := block_file_index(name)) is not None
    ) if os.path.isdir(block_dir) else []
    # a crashed writer leaves orphans at consecutive indices right after the
    # last indexed block; that contiguous valid run is adoptable, in order
    next_idx = 1 + max(
        (i for i in (block_file_index(b.file) for b in m.blocks)
         if i is not None),
        default=-1,
    )
    manifest_dirty = False
    adopt_run = True
    for idx, name in orphans:
        rel = os.path.join(BLOCK_DIR, name)
        path = os.path.join(block_dir, name)
        arr = (
            _adoptable(path, m.n_words)
            if adopt_run and idx == next_idx else None
        )
        if arr is not None:
            next_idx += 1
            d = Damage("orphan", path,
                       f"{arr.shape[0]} rows written after the last manifest "
                       f"flush", block_index=None)
            if repair:
                _adopt(store, rel, arr)
                manifest_dirty = True
                d.action = "adopted"
            damages.append(d)
            continue
        adopt_run = False  # gap or torn payload: nothing later is trustworthy
        d = Damage("orphan", path,
                   "not adoptable (torn payload, wrong geometry, or "
                   "non-contiguous index)", block_index=None)
        if repair:
            os.remove(path)
            d.action = "deleted"
        damages.append(d)

    # ---- indexed blocks ----------------------------------------------------
    bad: List[int] = []
    for i in range(len(m.blocks)):
        d = _classify_indexed(store, i, deep)
        if d is not None:
            damages.append(d)
            bad.append(i)

    # ---- manifest self-consistency ----------------------------------------
    blocks_n_tx = sum(b.n_tx for b in m.blocks)
    if m.n_tx != blocks_n_tx or len(m.item_counts) != m.n_items:
        d = Damage(
            "stale-manifest", os.path.join(directory, "manifest.json"),
            f"totals disagree: n_tx={m.n_tx} vs blocks sum {blocks_n_tx}, "
            f"|item_counts|={len(m.item_counts)} vs n_items={m.n_items}",
        )
        if repair and not bad:
            _recount(store)
            manifest_dirty = True
            d.action = "repaired"
        damages.append(d)

    # ---- quarantine damaged indexed blocks + exact recount -----------------
    if quarantine and bad:
        qdir = os.path.join(directory, QUARANTINE_DIR)
        os.makedirs(qdir, exist_ok=True)
        for d in damages:
            if d.block_index is None or d.action != "none":
                continue
            if os.path.exists(d.path):
                os.replace(d.path, os.path.join(qdir, os.path.basename(d.path)))
            d.action = "quarantined"
        m.blocks = [b for i, b in enumerate(m.blocks) if i not in set(bad)]
        _recount(store)
        manifest_dirty = True
        # the totals finding (if any) is subsumed by the recount
        for d in damages:
            if d.kind == "stale-manifest" and d.block_index is None:
                d.action = "repaired"

    if manifest_dirty:
        write_manifest(directory, m)

    return FsckReport(
        directory=directory,
        n_blocks=len(m.blocks),
        n_tx=m.n_tx,
        damages=damages,
        deep=deep,
    )


def check(directory: str, *, deep: bool = True) -> FsckReport:
    """Read-only scan; raises :class:`StoreIntegrityError` on any damage."""
    rep = fsck(directory, deep=deep)
    if rep.damages:
        raise StoreIntegrityError(rep.summary())
    return rep
