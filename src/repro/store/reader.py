"""Streamed read side of the transaction store: disk → host → device.

:class:`BlockReader` is the double-buffer protocol (DESIGN.md, "Storage
subsystem"): while the consumer sweeps block *i* on device, a single reader
thread is already pulling block *i+1* off disk, and the ``jax.device_put``
dispatch for it is asynchronous — so at most **two** blocks are ever
resident on host, regardless of database size.  The reader accounts its
live host bytes and raises if they would exceed the configured budget, so
"O(block) host residency" is an enforced invariant, not a hope.

On top of it:

  * :func:`to_device_shards` — assemble the ``uint32[P, T, IW]`` device
    shards ``core.fimi.run`` / ``cluster.execute`` mine, block by block,
    bit-exact with ``fimi.shard_db(store.to_dense(), P)`` (same row order,
    same ``n_tx − n_tx mod P`` truncation).
  * :func:`sample_rows` — the Thm 6.1 i.i.d. database sample drawn off
    disk: identical indices (same key, same PRNG call) and therefore
    identical rows to ``bitmap.sample_transactions`` over the in-RAM DB.
  * :func:`streamed_itemset_supports` — exact containment supports of
    arbitrary packed itemset masks over the whole store, one block sweep
    at a time (the ``block_itemset_supports`` kernel per block).
"""
from __future__ import annotations

import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.store.retry import RetriesExhausted, RetryPolicy
from repro.store.store import StoreIntegrityError, TxStore

_U32 = jnp.uint32


class HostBudgetExceeded(RuntimeError):
    """The reader would hold more host bytes than the configured budget."""


class BlockReadError(RuntimeError):
    """A block failed to read/transfer; the message names block and path."""


#: Errors that already carry their own block context (or are the budget
#: invariant itself) — re-raised as-is at the consumer, never wrapped.
_PASSTHROUGH = (StoreIntegrityError, RetriesExhausted, HostBudgetExceeded)


class BlockReader:
    """Double-buffered host→device block iterator with residency accounting.

    ``host_budget_blocks`` is the store's host block budget in units of the
    largest block; double buffering needs 2 (read-ahead + in-flight).  The
    observed high-water mark is exposed as :attr:`peak_host_bytes` — the
    IO benchmark asserts it stays O(block) while the database grows.

    Fault behavior (DESIGN.md, "Failure model"): disk reads and the
    ``device_put`` dispatch run under ``retry`` (bounded exponential
    backoff, ``OSError`` only by default).  A failure on the prefetch
    thread is raised to the consumer at its next ``__next__`` — typed
    integrity errors pass through unchanged, anything else is wrapped in
    :class:`BlockReadError` naming the failing block index and path — and
    the worker thread is joined before the error propagates, so an
    aborted stream never leaks a thread or an unretrieved future.
    """

    def __init__(
        self,
        store: TxStore,
        host_budget_blocks: int = 2,
        *,
        retry: RetryPolicy = RetryPolicy(),
    ):
        if host_budget_blocks < 2:
            raise ValueError(
                "double buffering needs a host budget of >= 2 blocks "
                f"(got {host_budget_blocks})"
            )
        self.store = store
        self.host_budget_blocks = host_budget_blocks
        self.budget_bytes = host_budget_blocks * max(store.max_block_bytes, 1)
        self.peak_host_bytes = 0
        self.retry = retry
        self.read_attempts = 0      # telemetry: total read attempts made
        self._live: dict = {}
        self._lock = threading.Lock()

    # -- residency accounting -------------------------------------------------
    def _block_path(self, i: int) -> str:
        return os.path.join(
            self.store.directory, self.store.manifest.blocks[i].file
        )

    def _read_host(self, i: int) -> np.ndarray:
        # fault injection for the doctor's prefetch-stall self-test: a
        # per-block read delay the double buffer cannot hide on small DBs
        delay = float(os.environ.get("REPRO_STORE_READ_DELAY_S", "0") or 0)
        if delay > 0:
            time.sleep(delay)

        def attempt() -> np.ndarray:
            with self._lock:
                self.read_attempts += 1
            return self.store.read_block(i)

        arr = self.retry.call(
            attempt, describe=f"read block {i} ({self._block_path(i)})"
        )
        with self._lock:
            self._live[i] = arr.nbytes
            live = sum(self._live.values())
            self.peak_host_bytes = max(self.peak_host_bytes, live)
            obs_metrics.registry().gauge("store/host_bytes_peak").update_max(
                float(self.peak_host_bytes)
            )
            obs_trace.TRACER.counter(
                "host bytes", live=float(live),
                peak=float(self.peak_host_bytes))
            if live > self.budget_bytes:
                raise HostBudgetExceeded(
                    f"host residency {live}B exceeds budget "
                    f"{self.budget_bytes}B ({self.host_budget_blocks} blocks)"
                )
        return arr

    def _release(self, i: int) -> None:
        with self._lock:
            self._live.pop(i, None)
            obs_trace.TRACER.counter(
                "host bytes", live=float(sum(self._live.values())),
                peak=float(self.peak_host_bytes))

    # -- the double-buffered stream -------------------------------------------
    def device_blocks(
        self,
    ) -> Iterator[Tuple[int, int, jnp.ndarray, int]]:
        """Yield ``(block_index, row_offset, device_block, n_rows)``.

        The next block's disk read runs on a worker thread and its
        ``device_put`` is dispatched before the consumer finishes the
        current one — the overlap that hides I/O behind device sweeps.
        A prefetch failure raises here, at the iteration that needed the
        block, with the block's index/path in the message.
        """
        n = self.store.n_blocks
        if n == 0:
            return
        reg = obs_metrics.registry()
        stall_h = reg.histogram("store/prefetch_stall_s")
        blocks_c = reg.counter("store/blocks_read")
        off = 0
        ex = ThreadPoolExecutor(max_workers=1)
        fut = ex.submit(self._read_host, 0)
        try:
            for i in range(n):
                t_wait = time.perf_counter()
                try:
                    arr = fut.result()
                except _PASSTHROUGH:
                    raise
                except Exception as e:
                    raise BlockReadError(
                        f"prefetch of block {i} ({self._block_path(i)}) "
                        f"failed: {e!r}"
                    ) from e
                # stall = how long the consumer blocked on the prefetch: ~0
                # when the read hid behind the previous device sweep
                stall_h.record(time.perf_counter() - t_wait)
                blocks_c.inc()
                if i + 1 < n:
                    fut = ex.submit(self._read_host, i + 1)
                dev = self.retry.call(
                    lambda: jax.device_put(arr),   # async dispatch
                    describe=f"device_put block {i}",
                )
                n_rows = int(arr.shape[0])
                del arr  # drop the host reference; the transfer owns a copy
                yield i, off, dev, n_rows
                self._release(i)
                off += n_rows
        finally:
            # join the worker before any exception propagates: no leaked
            # thread, and the in-flight future's error (if any) is
            # retrieved so it cannot surface later as a bare warning
            ex.shutdown(wait=True)
            if not fut.cancelled():
                fut.exception()
            with self._lock:
                self._live.clear()


# ---------------------------------------------------------------------------
# Device assembly — the mining input, built one block at a time
# ---------------------------------------------------------------------------


def _place_impl(
    buf: jnp.ndarray, blk: jnp.ndarray, off: jnp.ndarray
) -> jnp.ndarray:
    return jax.lax.dynamic_update_slice(buf, blk, (off, jnp.int32(0)))


# Donating buf lets XLA write the block into the accumulating device buffer
# in place — without it every per-block update copies the whole O(n_tx) slab
# (O(n_blocks · n_tx) traffic + 2x transient memory).  CPU does not
# implement donation (jax warns and copies anyway), so only donate off-CPU.
if jax.default_backend() == "cpu":
    _place = jax.jit(_place_impl)
else:
    _place = jax.jit(_place_impl, donate_argnums=(0,))


def to_device_rows(
    store: TxStore,
    n_rows: Optional[int] = None,
    *,
    host_budget_blocks: int = 2,
    reader: Optional[BlockReader] = None,
) -> jnp.ndarray:
    """All (or the first ``n_rows``) packed rows as one device array.

    Host residency stays within the reader's budget; the device buffer is
    the packed working set (32× smaller than the dense bool matrix).
    Pass ``reader`` to account residency on a caller-owned
    :class:`BlockReader` (drivers report its ``peak_host_bytes``).
    """
    total = store.n_tx if n_rows is None else min(n_rows, store.n_tx)
    buf = jnp.zeros((total, store.n_words), _U32)
    reader = reader or BlockReader(store, host_budget_blocks)
    for _, off, dev, n_blk in reader.device_blocks():
        if off >= total:
            break
        take = min(n_blk, total - off)
        if take <= 0:      # empty block mid-stream: nothing to place
            continue
        blk = dev if take == n_blk else dev[:take]
        buf = _place(buf, blk, jnp.int32(off))
    return buf


def to_device_shards(
    store: TxStore,
    P: int,
    *,
    host_budget_blocks: int = 2,
    reader: Optional[BlockReader] = None,
) -> jnp.ndarray:
    """``uint32[P, T, IW]`` horizontal shards, bit-exact with
    ``fimi.shard_db(store.to_dense(), P)`` (row order preserved, the last
    ``n_tx mod P`` rows dropped) — but assembled block-by-block so the host
    never holds more than the reader's budget."""
    T = store.n_tx // P
    rows = to_device_rows(
        store, T * P, host_budget_blocks=host_budget_blocks, reader=reader
    )
    return rows.reshape(P, T, store.n_words)


# ---------------------------------------------------------------------------
# Off-disk sampling + streamed support counting (Phase-1/2, O(block))
# ---------------------------------------------------------------------------


def gather_rows(store: TxStore, indices: np.ndarray) -> np.ndarray:
    """Gather arbitrary row indices (duplicates allowed) in one block pass."""
    idx = np.asarray(indices, np.int64)
    assert idx.size == 0 or (idx.min() >= 0 and idx.max() < store.n_tx), (
        f"row index out of range [0, {store.n_tx})"
    )
    out = np.zeros((idx.shape[0], store.n_words), np.uint32)
    off = 0
    for blk in store.iter_blocks():
        nb = blk.shape[0]
        if nb:
            sel = np.nonzero((idx >= off) & (idx < off + nb))[0]
            if sel.size:
                out[sel] = blk[idx[sel] - off]
        off += nb
    return out


def sample_rows(
    store: TxStore,
    key: jax.Array,
    n_sample: int,
    n_tx: Optional[int] = None,
) -> jnp.ndarray:
    """Thm 6.1 i.i.d. (with replacement) transaction sample drawn off disk.

    Draws the **same indices** as ``bitmap.sample_transactions(rows, key,
    n_sample, n_tx)`` over the in-RAM row slab (same key, same
    ``jax.random.randint`` call — JAX PRNG results are jit-invariant), then
    gathers them in one block pass: the sample, and hence every plan built
    from it, is bit-exact with the in-memory path at O(block) host cost.
    """
    n_tx = store.n_tx if n_tx is None else n_tx
    idx = np.asarray(jax.random.randint(key, (n_sample,), 0, n_tx))
    return jnp.asarray(gather_rows(store, idx))


def streamed_itemset_supports(
    store: TxStore, masks: jnp.ndarray, *, force: Optional[str] = None
) -> np.ndarray:
    """Exact supports ``int64[F]`` of packed itemset masks over the store.

    One ``block_itemset_supports`` sweep per resident block, accumulated on
    host — O(block) memory at every tier, any database size.  Empty blocks
    are skipped (they support nothing).
    """
    from repro.kernels import ops

    masks = jnp.asarray(masks, _U32)
    total = np.zeros((masks.shape[0],), np.int64)
    for _, _, dev, n_rows in BlockReader(store).device_blocks():
        if n_rows == 0:
            continue
        counts = ops.block_itemset_supports(dev[None], masks, force=force)
        total += np.asarray(counts)[0].astype(np.int64)
    return total
