"""Bounded exponential-backoff retry — the store's transient-fault policy.

Disk reads and host→device transfers fail transiently in production (NFS
hiccups, EINTR, a device briefly wedged); the mining contract is exactness,
so the right response is a bounded retry followed by a *typed* failure —
never a silent skip.  :class:`RetryPolicy` is a frozen value object so it
can sit in params dataclasses; the clock and sleep functions are injectable
so tests exercise the full backoff schedule in microseconds.

What is retryable is deliberately narrow by default (``OSError`` — the
environment failing) and never includes
:class:`~repro.store.store.StoreIntegrityError`: a failed checksum is a
*persistent* fact about bytes on disk, and retrying it would just delay
the typed report the caller needs (fsck decides what happens next).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Tuple, Type, TypeVar

from repro.obs import metrics as obs_metrics

T = TypeVar("T")


class RetriesExhausted(RuntimeError):
    """All attempts failed; ``__cause__`` is the last underlying error."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff with full-jitter-free determinism.

    ``delay(k)`` for attempt k (0-based) is ``base_delay_s · backoff^k``
    capped at ``max_delay_s`` — deterministic, so tests can assert the
    exact schedule.  ``attempts=1`` means no retry at all.
    """

    attempts: int = 3
    base_delay_s: float = 0.05
    backoff: float = 2.0
    max_delay_s: float = 2.0
    retry_on: Tuple[Type[BaseException], ...] = (OSError,)
    sleep: Callable[[float], None] = time.sleep
    clock: Callable[[], float] = time.monotonic

    def delay(self, attempt: int) -> float:
        return min(self.base_delay_s * self.backoff ** attempt,
                   self.max_delay_s)

    def call(self, fn: Callable[[], T], *, describe: str = "") -> T:
        """Run ``fn`` under the policy.

        Non-retryable exceptions propagate untouched on the first throw
        (typed integrity errors keep their type and context).  When the
        attempt budget runs out, the last retryable error is re-raised
        wrapped in :class:`RetriesExhausted` naming the operation, the
        attempt count, and the elapsed time.
        """
        assert self.attempts >= 1
        reg = obs_metrics.registry()
        t0 = self.clock()
        last: BaseException = None  # type: ignore[assignment]
        for k in range(self.attempts):
            reg.counter("store/retry/attempts").inc()
            try:
                return fn()
            except self.retry_on as e:
                reg.counter("store/retry/retried_errors").inc()
                last = e
                if k + 1 < self.attempts:
                    self.sleep(self.delay(k))
        reg.counter("store/retry/exhausted").inc()
        raise RetriesExhausted(
            f"{describe or 'operation'} failed after {self.attempts} "
            f"attempts over {self.clock() - t0:.3f}s: {last!r}"
        ) from last


#: No retries at all — for tests and for callers that do their own policy.
NO_RETRY = RetryPolicy(attempts=1)
