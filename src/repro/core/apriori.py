"""Apriori (§B.1) + the Count-Distribution parallel baseline (Alg. 2, §5.2.1).

The thesis compares its method against Apriori-family parallel algorithms; we
implement them as the baseline the instructions require.  Level-wise BFS:
candidate generation/pruning is host control plane (inherently bulk-
synchronous — each level is a barrier even in the original), support counting
is a device kernel over packed bitmaps, chunked to bound memory.

Count distribution (Alg. 2): every processor counts all candidates on its own
DB shard and the counts are all-reduced — in JAX that is literally a ``psum``
over the miner axis, executed by :func:`count_distribution_supports` under
``shard_map``/``vmap``.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, FrozenSet, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmap as bm

_U32 = jnp.uint32


@partial(jax.jit, static_argnames=("chunk",))
def count_supports(
    item_bits: jnp.ndarray,   # uint32[I, W]
    cand_masks: jnp.ndarray,  # bool [N, I]
    valid_tid: jnp.ndarray,   # uint32[W]
    chunk: int = 256,
) -> jnp.ndarray:
    """Supports of N candidate itemsets (int32[N]), chunked over candidates.

    tid(U) = ~ OR_{i∈U} ~bits_i  (De Morgan form of the AND-reduce) — one
    masked OR-einsum per chunk keeps peak memory at [chunk, W].
    """
    N = cand_masks.shape[0]
    pad = (-N) % chunk
    cands = jnp.concatenate(
        [cand_masks, jnp.zeros((pad, cand_masks.shape[1]), cand_masks.dtype)]
    )
    neg = ~item_bits  # [I, W]

    def one_chunk(c):  # bool [chunk, I]
        sel = c.astype(_U32)  # [chunk, I]
        # OR over items of (mask ? ~bits : 0): multiply-as-select then OR-reduce
        picked = sel[:, :, None] * neg[None, :, :]
        ored = jax.lax.reduce(
            picked, _U32(0), lambda a, b: jnp.bitwise_or(a, b), (1,)
        )
        tid = (~ored) & valid_tid[None, :]
        return bm.popcount_u32(tid).sum(axis=-1)

    chunks = cands.reshape(-1, chunk, cand_masks.shape[1])
    supports = jax.lax.map(one_chunk, chunks).reshape(-1)
    return supports[:N]


def generate_candidates(frequent: List[FrozenSet[int]]) -> List[FrozenSet[int]]:
    """Generate-Candidates (Alg. 24): join F_{k-1} pairs sharing a (k-2)-prefix,
    prune candidates with an infrequent (k-1)-subset."""
    fset = set(frequent)
    if not frequent:
        return []
    k = len(next(iter(frequent)))
    by_prefix: Dict[Tuple[int, ...], List[Tuple[int, ...]]] = {}
    for f in frequent:
        t = tuple(sorted(f))
        by_prefix.setdefault(t[:-1], []).append(t)
    cands = set()
    for pre, group in by_prefix.items():
        group = sorted(group)
        for a in range(len(group)):
            for b in range(a + 1, len(group)):
                u = frozenset(group[a]) | frozenset(group[b])
                if len(u) != k + 1:
                    continue
                if all(u - {x} in fset for x in u):
                    cands.add(u)
    return sorted(cands, key=lambda s: tuple(sorted(s)))


def apriori(db: bm.BitmapDB, min_support: int) -> Dict[FrozenSet[int], int]:
    """Sequential Apriori (Alg. 25) over a BitmapDB.  Host loop over levels."""
    I = db.n_items
    valid = db.all_tids()
    out: Dict[FrozenSet[int], int] = {}
    # level 1
    supp1 = np.asarray(
        bm.extension_supports(db.item_bits, valid)
    )
    frequent = [frozenset([i]) for i in range(I) if supp1[i] >= min_support]
    for f in frequent:
        out[f] = int(supp1[next(iter(f))])
    while frequent:
        cands = generate_candidates(frequent)
        if not cands:
            break
        masks = np.zeros((len(cands), I), dtype=bool)
        for r, c in enumerate(cands):
            masks[r, list(c)] = True
        supports = np.asarray(
            count_supports(db.item_bits, jnp.asarray(masks), valid)
        )
        frequent = []
        for c, s in zip(cands, supports):
            if s >= min_support:
                out[c] = int(s)
                frequent.append(c)
    return out


def count_distribution_supports(
    local_item_bits: jnp.ndarray,
    cand_masks: jnp.ndarray,
    local_valid_tid: jnp.ndarray,
    axis_name: str,
) -> jnp.ndarray:
    """One Count-Distribution level: local count + all-reduce (Alg. 2 line 10).

    Runs under shard_map/vmap with ``axis_name`` bound; each shard holds its
    database partition D_i as vertical bitmaps over *local* transactions.
    """
    local = count_supports(local_item_bits, cand_masks, local_valid_tid)
    return jax.lax.psum(local, axis_name)
