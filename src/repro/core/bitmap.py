"""Packed bitmap representation of a transaction database.

The thesis (Ch. 2, B.3) stores the *vertical representation* of the database as
per-item tidlists (sorted integer arrays) and computes support by tidlist
merge-intersection.  On TPU we replace tidlists with **packed bitmaps**:

  * vertical:   ``item_bits[i, w]``  — bit ``t`` of word ``w`` set iff transaction
                ``32*w + t`` contains item ``i``;  shape ``[n_items, n_words]``.
  * horizontal: ``tx_bits[t, w]``    — bit ``i`` of word ``w`` set iff transaction
                ``t`` contains item ``32*w + i``;  shape ``[n_tx, n_item_words]``.

Support of an itemset U is ``popcount(AND_{i in U} item_bits[i])`` (Lemma 2.28).
AND + popcount is branch-free, lane-parallel, and batches over candidate
extensions into a dense 2-D sweep — the natural TPU shape (see DESIGN.md,
"Hardware adaptation").

Everything here is pure jnp and jit-friendly; the Pallas kernels in
``repro.kernels`` accelerate the two hot spots (extension supports and all-pairs
supports) with the functions here as oracles.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

WORD_BITS = 32
_U32 = jnp.uint32


def n_words(n: int) -> int:
    """Number of 32-bit words needed for ``n`` bits."""
    return (n + WORD_BITS - 1) // WORD_BITS


def popcount_u32(x: jnp.ndarray) -> jnp.ndarray:
    """SWAR population count of a uint32 array (elementwise, returns int32).

    Classic bit-twiddling reduction; identical code runs inside Pallas kernels.
    """
    x = x.astype(_U32)
    x = x - ((x >> 1) & _U32(0x55555555))
    x = (x & _U32(0x33333333)) + ((x >> 2) & _U32(0x33333333))
    x = (x + (x >> 4)) & _U32(0x0F0F0F0F)
    return ((x * _U32(0x01010101)) >> 24).astype(jnp.int32)


def pack_bool(dense: jnp.ndarray) -> jnp.ndarray:
    """Pack a boolean array ``[..., n]`` into uint32 words ``[..., n_words(n)]``.

    Bit ``k`` of word ``w`` corresponds to column ``32*w + k`` (little-endian
    within the word).
    """
    n = dense.shape[-1]
    W = n_words(n)
    pad = W * WORD_BITS - n
    if pad:
        dense = jnp.concatenate(
            [dense, jnp.zeros(dense.shape[:-1] + (pad,), dense.dtype)], axis=-1
        )
    bits = dense.reshape(dense.shape[:-1] + (W, WORD_BITS)).astype(_U32)
    shifts = jnp.arange(WORD_BITS, dtype=_U32)
    return (bits << shifts).sum(axis=-1, dtype=_U32)


def unpack_bool(packed: jnp.ndarray, n: int) -> jnp.ndarray:
    """Inverse of :func:`pack_bool`; returns bool array ``[..., n]``."""
    shifts = jnp.arange(WORD_BITS, dtype=_U32)
    bits = (packed[..., None] >> shifts) & _U32(1)
    flat = bits.reshape(packed.shape[:-1] + (packed.shape[-1] * WORD_BITS,))
    return flat[..., :n].astype(jnp.bool_)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BitmapDB:
    """A transaction database in packed vertical + horizontal bitmap form.

    Attributes:
      item_bits: ``uint32[n_items, n_tx_words]`` vertical representation.
      tx_bits:   ``uint32[n_tx, n_item_words]`` horizontal representation.
      n_tx:      number of (valid) transactions.  Static python int.
      n_items:   size of the base set B.  Static python int.
    """

    item_bits: jnp.ndarray
    tx_bits: jnp.ndarray
    n_tx: int
    n_items: int

    # -- pytree plumbing (n_tx / n_items are static aux data) ----------------
    def tree_flatten(self):
        return (self.item_bits, self.tx_bits), (self.n_tx, self.n_items)

    @classmethod
    def tree_unflatten(cls, aux, children):
        item_bits, tx_bits = children
        return cls(item_bits, tx_bits, aux[0], aux[1])

    # -- constructors ---------------------------------------------------------
    @classmethod
    def from_dense(cls, dense: jnp.ndarray) -> "BitmapDB":
        """Build from a dense bool matrix ``[n_tx, n_items]``."""
        dense = jnp.asarray(dense, jnp.bool_)
        n_tx, n_items = dense.shape
        return cls(
            item_bits=pack_bool(dense.T),
            tx_bits=pack_bool(dense),
            n_tx=n_tx,
            n_items=n_items,
        )

    @classmethod
    def from_transactions(cls, transactions, n_items: int) -> "BitmapDB":
        """Build from a python list of iterables of item ids."""
        dense = np.zeros((len(transactions), n_items), dtype=bool)
        for t, items in enumerate(transactions):
            for i in items:
                dense[t, int(i)] = True
        return cls.from_dense(jnp.asarray(dense))

    # -- views ----------------------------------------------------------------
    def dense(self) -> jnp.ndarray:
        """Dense bool ``[n_tx, n_items]``."""
        return unpack_bool(self.tx_bits, self.n_items)

    @property
    def n_tx_words(self) -> int:
        return self.item_bits.shape[-1]

    @property
    def n_item_words(self) -> int:
        return self.tx_bits.shape[-1]

    def all_tids(self) -> jnp.ndarray:
        """Bitmap of all valid transaction ids: tidlist of the empty itemset."""
        full = jnp.full((self.n_tx_words,), jnp.iinfo(np.uint32).max, _U32)
        # mask the tail bits beyond n_tx
        tail_bits = self.n_tx_words * WORD_BITS - self.n_tx
        if tail_bits:
            last = _U32(0xFFFFFFFF) >> np.uint32(tail_bits)
            full = full.at[-1].set(last)
        return full


# ---------------------------------------------------------------------------
# Support counting (Lemma 2.28 / Corollary 2.29), pure-jnp reference forms.
# ---------------------------------------------------------------------------


def tidlist_of_itemset(db: BitmapDB, itemset_mask: jnp.ndarray) -> jnp.ndarray:
    """Tidlist bitmap ``uint32[W]`` of an itemset given as a bool mask ``[n_items]``.

    T(U) = AND over item bitmaps of members (all-ones for the empty set).
    """
    member = itemset_mask[:, None]  # [I, 1]
    # For non-members substitute all-ones so they don't constrain the AND.
    rows = jnp.where(member, db.item_bits, _U32(0xFFFFFFFF))
    # AND-reduce over items via ufunc reduce on the item axis.
    tid = jax.lax.reduce(
        rows, _U32(0xFFFFFFFF), lambda a, b: jnp.bitwise_and(a, b), (0,)
    )
    return jnp.bitwise_and(tid, db.all_tids())


def support_of_tidlist(tid: jnp.ndarray) -> jnp.ndarray:
    """Support (int32 scalar) = popcount of a tidlist bitmap."""
    return popcount_u32(tid).sum()


def support_of_itemset(db: BitmapDB, itemset_mask: jnp.ndarray) -> jnp.ndarray:
    return support_of_tidlist(tidlist_of_itemset(db, itemset_mask))


def extension_supports(
    item_bits: jnp.ndarray, prefix_tid: jnp.ndarray
) -> jnp.ndarray:
    """Supports of ``prefix ∪ {i}`` for every item i.

    Args:
      item_bits: ``uint32[I, W]`` vertical bitmaps.
      prefix_tid: ``uint32[W]`` tidlist of the prefix.
    Returns:
      ``int32[I]`` supports.  This is the Eclat inner loop — the Pallas kernel
      ``repro.kernels.bitmap_support`` computes exactly this.
    """
    return popcount_u32(item_bits & prefix_tid[None, :]).sum(axis=-1)


def multi_extension_supports(
    item_bits: jnp.ndarray, prefix_tids: jnp.ndarray
) -> jnp.ndarray:
    """Supports of ``prefix_k ∪ {i}`` for K prefixes at once.

    The frontier-batched Eclat inner loop (DESIGN.md, "Frontier-batched DFS"):
    one fused AND+popcount sweep over K prefix tidlists instead of K separate
    ``extension_supports`` launches.

    Args:
      item_bits: ``uint32[I, W]`` vertical bitmaps.
      prefix_tids: ``uint32[K, W]`` tidlists of the K frontier prefixes.
    Returns:
      ``int32[K, I]`` supports.  Oracle of the Pallas kernels in
      ``repro.kernels.multi_support``.
    """
    inter = prefix_tids[:, None, :] & item_bits[None, :, :]   # [K, I, W]
    return popcount_u32(inter).sum(axis=-1)


def pair_supports(item_bits: jnp.ndarray, valid_tid: jnp.ndarray) -> jnp.ndarray:
    """All-pairs supports ``int32[I, I]``: support({i, j}).

    The C2 counting step of Parallel-Eclat (Alg. 5 line 3).  AND/popcount
    "semiring matmul"; Pallas kernel ``repro.kernels.pair_support`` mirrors it.
    """
    masked = item_bits & valid_tid[None, :]
    return popcount_u32(masked[:, None, :] & masked[None, :, :]).sum(axis=-1)


def itemset_mask_to_packed(mask: jnp.ndarray) -> jnp.ndarray:
    """Pack a bool itemset mask ``[..., I]`` into uint32 ``[..., n_words(I)]``."""
    return pack_bool(mask)


def is_subset_packed(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Elementwise test a ⊆ b for packed itemset masks (last axis = words)."""
    return jnp.all((a & ~b) == _U32(0), axis=-1)


@partial(jax.jit, static_argnames=("n_sample", "n_tx"))
def sample_transactions(
    tx_bits: jnp.ndarray, key: jax.Array, n_sample: int, n_tx: int
) -> jnp.ndarray:
    """i.i.d. (with replacement) sample of transaction rows — Phase-1 DB sample.

    Thesis §6.1: the database sample is drawn **with replacement**, so the
    Chernoff analysis (Thm 6.1) applies without finite-population corrections.
    """
    idx = jax.random.randint(key, (n_sample,), 0, n_tx)
    return jnp.take(tx_bits, idx, axis=0)


def rebuild_vertical(tx_bits: jnp.ndarray, n_items: int, n_tx: int) -> BitmapDB:
    """Re-pack a horizontal bitmap slab into a full BitmapDB (host+device ok)."""
    dense = unpack_bool(tx_bits, n_items)[:n_tx]
    return BitmapDB.from_dense(dense)
