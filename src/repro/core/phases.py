"""Phases 1–4 of Parallel-FIMI as axis-name-parameterized SPMD programs.

Every device function here takes ``axis_name`` and runs identically under

  * ``jax.vmap(f, axis_name=AX)``   — single-device P-way simulation (tests,
    CPU container), and
  * ``jax.shard_map(f, mesh, ...)`` — real multi-device execution (the
    ``launch/mine.py`` path and the dry-run),

because the only cross-processor communication is ``psum / all_gather /
all_to_all / axis_index`` — the JAX-native image of the thesis' MPI collectives
(DESIGN.md, "Hardware adaptation").  Host-side control plane (Phase 2
partition + LPT, reservoir merge) lives in ``pbec.py`` / ``schedule.py`` /
``sampling.py`` and is orchestrated by ``fimi.py``.

Layout conventions
  * Global DB: horizontal packed ``tx_bits  uint32[P, T, IW_tx]`` — shard i is
    D_i, exactly |D|/P transactions (thesis §2.1); ``IW_tx = n_words(I)``.
  * A "slab" is a horizontal sub-database a processor holds after exchange.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import bitmap as bm
from repro.core import eclat, mfi

_U32 = jnp.uint32


# ---------------------------------------------------------------------------
# Shared device helpers
# ---------------------------------------------------------------------------


def axis_size(axis_name) -> int:
    """Static size of a named axis — ``jax.lax.axis_size`` compat shim.

    ``jax.lax.axis_size`` only exists on newer JAX; on older versions
    ``psum`` of an unmapped Python constant folds to ``1 * P`` at trace time
    under both ``vmap`` and ``shard_map``, so the result stays a Python int
    and remains usable for static shapes.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def vertical_from_slab(
    slab: jnp.ndarray, valid: jnp.ndarray, n_items: int
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Horizontal packed slab ``uint32[T, IW]`` (+ row-valid mask) → vertical
    ``item_bits uint32[I, n_words(T)]`` and the valid-tid bitmap.

    The transpose lives on device: unpack → mask → transpose → pack.
    """
    dense = bm.unpack_bool(slab, n_items) & valid[:, None]   # [T, I]
    item_bits = bm.pack_bool(dense.T)                        # [I, W]
    valid_tid = bm.pack_bool(valid)                          # [W]
    return item_bits, valid_tid


def seed_tidlists(
    item_bits: jnp.ndarray, seed_prefix: jnp.ndarray, valid_tid: jnp.ndarray
) -> jnp.ndarray:
    """T(U_k) for K packed seed prefixes — batched AND-reduce (`Prepare-
    Tidlists`, Alg. 20, as one vectorized op)."""

    def one(prefix_bool):
        rows = jnp.where(prefix_bool[:, None], item_bits, _U32(0xFFFFFFFF))
        tid = jax.lax.reduce(
            rows, _U32(0xFFFFFFFF), lambda a, b: jnp.bitwise_and(a, b), (0,)
        )
        return tid & valid_tid

    return jax.vmap(one)(seed_prefix)


# ---------------------------------------------------------------------------
# Phase 1 — sampling
# ---------------------------------------------------------------------------


class Phase1DeviceOut(NamedTuple):
    sample_db: jnp.ndarray       # uint32[n_sample, IW] — D̃, replicated
    reservoir: jnp.ndarray       # uint32[R, IW_items] — local reservoir (Res.)
    reservoir_supports: jnp.ndarray
    fi_count: jnp.ndarray        # int32 — f_i, #FIs streamed locally
    mfi_items: jnp.ndarray       # uint32[Mmax, IW_items] — M_i (Par variant)
    mfi_supports: jnp.ndarray
    mfi_count: jnp.ndarray       # int32
    overflow: jnp.ndarray        # int32 — any stack/output overflow


def _assigned_item_seeds(order: jnp.ndarray, n_items: int, p_idx, P: int):
    """Static 1-prefix block assignment (Alg. 11 line 3): processor i takes
    the items at positions j of the support-ascending ``order`` with
    ``j % P == i`` (round-robin balances heavy early classes better than
    contiguous blocks; any fixed rule is valid).

    Returns bool [K, I] prefix masks, [K, I] ext masks, valid [K] with
    K = ceil(I/P).
    """
    I = n_items
    K = (I + P - 1) // P
    slots = p_idx + P * jnp.arange(K)                       # positions in order
    valid = slots < I
    slots_c = jnp.minimum(slots, I - 1)
    items = order[slots_c]                                  # item ids
    prefix = jax.nn.one_hot(items, I, dtype=jnp.bool_) & valid[:, None]
    pos_of = jnp.argsort(order)                             # item -> position
    later = pos_of[None, None, :] > pos_of[None, :, None]   # unused broad form
    # ext_k = items with position > slots[k]
    positions = jnp.arange(I)
    ext = (positions[None, :] > slots_c[:, None])           # positions in order
    # map position-mask back to item-id mask
    ext_items = jnp.zeros((K, I), jnp.bool_)
    ext_items = ext_items.at[:, order].set(ext)
    ext_items = ext_items & valid[:, None]
    return prefix, ext_items, valid


def phase1_device(
    local_tx: jnp.ndarray,        # uint32[T, IW] — this processor's D_i
    key: jax.Array,
    min_support_rel: jnp.ndarray,  # float scalar — min_support*
    *,
    axis_name: str,
    n_items: int,
    n_tx_local: int,
    n_sample_per_proc: int,
    reservoir_size: int,
    eclat_cfg: eclat.EclatConfig,
    mfi_cfg: mfi.MFIConfig,
    variant: str,                 # "reservoir" | "par"
) -> Phase1DeviceOut:
    """Device part of Phase 1 (Algs. 12/13/14 lines 1–9).

    1. sample T' = n_sample_per_proc transactions of D_i i.i.d.;
    2. all-gather → D̃ replicated on every processor;
    3. mine D̃ restricted to this processor's 1-prefix PBECs, streaming FIs
       through a local reservoir (reservoir variant) or collecting MFI
       candidates M_i (par variant).
    """
    P = axis_size(axis_name)
    k_samp, k_res = jax.random.split(jax.random.fold_in(key, jax.lax.axis_index(axis_name)))

    rows = bm.sample_transactions(local_tx, k_samp, n_sample_per_proc, n_tx_local)
    sample_db = jax.lax.all_gather(rows, axis_name).reshape(
        P * n_sample_per_proc, -1
    )
    n_samp = P * n_sample_per_proc
    min_support = jnp.ceil(min_support_rel * n_samp).astype(jnp.int32)

    IW_items = bm.n_words(n_items)
    if variant == "sample":  # Seq variant: p_1 mines D̃ on the host afterwards
        return Phase1DeviceOut(
            sample_db=sample_db,
            reservoir=jnp.zeros((max(reservoir_size, 1), IW_items), _U32),
            reservoir_supports=jnp.zeros((max(reservoir_size, 1),), jnp.int32),
            fi_count=jnp.zeros((), jnp.int32),
            mfi_items=jnp.zeros((mfi_cfg.max_out, IW_items), _U32),
            mfi_supports=jnp.zeros((mfi_cfg.max_out,), jnp.int32),
            mfi_count=jnp.zeros((), jnp.int32),
            overflow=jnp.zeros((), jnp.int32),
        )

    # vertical form of D̃ (identical on every processor)
    item_bits, valid_tid = vertical_from_slab(
        sample_db, jnp.ones((n_samp,), jnp.bool_), n_items
    )

    # support-ascending global item order for the 1-prefix classes
    root_supp = bm.extension_supports(item_bits, valid_tid)
    frequent_item = root_supp >= min_support
    order = jnp.argsort(jnp.where(frequent_item, root_supp, jnp.iinfo(jnp.int32).max))

    p_idx = jax.lax.axis_index(axis_name)
    seed_prefix, seed_ext, seed_valid = _assigned_item_seeds(
        order, n_items, p_idx, P
    )
    # drop seeds whose item is not frequent
    seed_item_freq = (seed_prefix & frequent_item[None, :]).any(axis=-1)
    seed_valid = seed_valid & seed_item_freq
    seed_tid = seed_tidlists(item_bits, seed_prefix, valid_tid)
    seed_supp = (
        jnp.where(seed_prefix, root_supp[None, :], 0).sum(axis=-1).astype(jnp.int32)
    )

    if variant == "reservoir":
        res = eclat.mine_seeded(
            item_bits,
            seed_prefix,
            seed_ext,
            seed_tid,
            seed_valid,
            min_support,
            k_res,
            config=dataclasses.replace(
                eclat_cfg, reservoir_size=reservoir_size, count_only=True
            ),
            n_items=n_items,
        )
        # The stream contains every FI of D̃ with |W| ≥ 2; singleton FIs are
        # exactly the class prefixes, which the partitioner handles through
        # the prefix side channel (the thesis' "{V}" term of Prop. 2.23), so
        # the sample space is consistently F̃_{≥2}.
        fi_count = res.n_total
        return Phase1DeviceOut(
            sample_db=sample_db,
            reservoir=res.reservoir_items,
            reservoir_supports=res.reservoir_supports,
            fi_count=fi_count,
            mfi_items=jnp.zeros((mfi_cfg.max_out, IW_items), _U32),
            mfi_supports=jnp.zeros((mfi_cfg.max_out,), jnp.int32),
            mfi_count=jnp.zeros((), jnp.int32),
            overflow=res.stack_overflow,
        )
    elif variant == "par":
        res = mfi.mine_candidates_seeded(
            item_bits,
            seed_prefix,
            seed_ext,
            seed_tid,
            seed_supp,
            seed_valid,
            min_support,
            config=mfi_cfg,
            n_items=n_items,
        )
        return Phase1DeviceOut(
            sample_db=sample_db,
            reservoir=jnp.zeros((max(reservoir_size, 1), IW_items), _U32),
            reservoir_supports=jnp.zeros((max(reservoir_size, 1),), jnp.int32),
            fi_count=jnp.zeros((), jnp.int32),
            mfi_items=res.items,
            mfi_supports=res.supports,
            mfi_count=res.n_out,
            overflow=res.overflow,
        )
    else:
        raise ValueError(f"unknown phase-1 variant {variant!r}")


# ---------------------------------------------------------------------------
# Phase 3 — database partition exchange (Alg. 18 → all_to_all)
# ---------------------------------------------------------------------------


class Phase3Out(NamedTuple):
    slab: jnp.ndarray          # uint32[P*cap, IW] — D'_i rows (incl. padding)
    slab_valid: jnp.ndarray    # bool [P*cap]
    recv_counts: jnp.ndarray   # int32[P]
    overflow: jnp.ndarray      # int32 — rows that did not fit cap (global err)
    replication: jnp.ndarray   # float — Σ|D'_i| / |D| (thesis Ch. 10)


def phase3_exchange(
    local_tx: jnp.ndarray,       # uint32[T, IW] — D_i
    local_valid: jnp.ndarray,    # bool [T]
    class_prefix_packed: jnp.ndarray,  # uint32[C, IW] — U_k (padded classes)
    class_valid: jnp.ndarray,    # bool [C]
    class_assign: jnp.ndarray,   # int32[C] — processor per class
    *,
    axis_name: str,
    capacity: int,
) -> Phase3Out:
    """Each processor sends to p_j the transactions containing any U_k with
    assign(k)=j, via fixed-capacity ``all_to_all`` (replaces the round-robin
    tournament of Alg. 18 — see DESIGN.md).  Overflow is *counted*, never
    silently dropped.
    """
    P = axis_size(axis_name)
    T = local_tx.shape[0]

    # contains[t, k]: U_k ⊆ t
    contains = bm.is_subset_packed(
        class_prefix_packed[None, :, :], local_tx[:, None, :]
    )  # [T, C]
    contains = contains & class_valid[None, :] & local_valid[:, None]
    dest_onehot = jax.nn.one_hot(class_assign, P, dtype=jnp.bool_)  # [C, P]
    need = jnp.einsum("tc,cp->tp", contains, dest_onehot) > 0       # [T, P]

    # pack up to `capacity` rows per destination
    rank = jnp.cumsum(need, axis=0) - 1                             # [T, P]
    sent = need & (rank < capacity)
    overflow_local = (need & ~sent).sum()
    send = jnp.zeros((P, capacity, local_tx.shape[1]), _U32)
    send_valid = jnp.zeros((P, capacity), jnp.bool_)
    # scatter rows: for each dest p, positions rank[t,p]
    t_idx = jnp.arange(T)
    for_axis = jnp.where(sent, rank, capacity)                      # cap ⇒ drop

    def scatter_dest(p, carry):
        send, send_valid = carry
        pos = for_axis[:, p]
        send = send.at[p, pos].set(local_tx, mode="drop")
        send_valid = send_valid.at[p, pos].set(sent[:, p], mode="drop")
        return send, send_valid

    send, send_valid = jax.lax.fori_loop(0, P, scatter_dest, (send, send_valid))

    recv = jax.lax.all_to_all(send, axis_name, split_axis=0, concat_axis=0)
    recv_valid = jax.lax.all_to_all(
        send_valid, axis_name, split_axis=0, concat_axis=0
    )
    recv_counts = recv_valid.sum(axis=1).astype(jnp.int32)
    n_local = local_valid.sum()
    total_tx = jax.lax.psum(n_local, axis_name)
    my_rows = recv_valid.sum()
    replication = jax.lax.psum(my_rows, axis_name) / jnp.maximum(total_tx, 1)
    overflow = jax.lax.psum(overflow_local, axis_name)
    return Phase3Out(
        slab=recv.reshape(P * capacity, -1),
        slab_valid=recv_valid.reshape(P * capacity),
        recv_counts=recv_counts,
        overflow=overflow.astype(jnp.int32),
        replication=replication.astype(jnp.float32),
    )


# ---------------------------------------------------------------------------
# Phase 4 — parallel FI computation (Alg. 19 / 22)
# ---------------------------------------------------------------------------


class Phase4Out(NamedTuple):
    fi_items: jnp.ndarray      # uint32[max_out, IW_items]
    fi_supports: jnp.ndarray   # int32[max_out]
    fi_count: jnp.ndarray      # int32 — local |F_q| (excl. prefix side channel)
    fi_total: jnp.ndarray      # int32 — found (≥ fi_count if buffer overflowed)
    prefix_supports: jnp.ndarray  # int32[A] — global Supp(W) for ancestor set
    overflow: jnp.ndarray
    work_iters: jnp.ndarray    # int32 — DFS trips (the load-balance metric)
    nodes_popped: jnp.ndarray  # int32 — DFS nodes mined; /(trips·K) is the
    #                            frontier occupancy (obs histogram)


def phase4_mine(
    slab: jnp.ndarray,            # uint32[Tcap, IW] — D'_q from Phase 3
    slab_valid: jnp.ndarray,      # bool [Tcap]
    local_tx: jnp.ndarray,        # uint32[T, IW] — original D_q (side channel)
    local_valid: jnp.ndarray,     # bool [T]
    my_seed_prefix: jnp.ndarray,  # bool [K, I] — assigned classes (padded)
    my_seed_ext: jnp.ndarray,     # bool [K, I]
    my_seed_valid: jnp.ndarray,   # bool [K]
    ancestor_masks: jnp.ndarray,  # bool [A, I] — prefix side-channel itemsets
    min_support: jnp.ndarray,     # absolute, int32
    key: jax.Array,
    *,
    axis_name: str,
    n_items: int,
    eclat_cfg: eclat.EclatConfig,
    support_fn=None,
    multi_support_fn=None,
) -> Phase4Out:
    """Alg. 19 (Phase-4-Compute-FI) with Eclat (Alg. 22):

    * line 2–5: local supports of ancestor prefixes on D_q, ``psum`` → global;
    * line 6: Exec-Eclat over the assigned PBECs on the received slab D'_q,
      mining ``eclat_cfg.frontier_size`` nodes per loop trip.
    """
    from repro.core.apriori import count_supports

    # --- prefix side channel on the ORIGINAL partition D_q ------------------
    item_bits_orig, valid_tid_orig = vertical_from_slab(
        local_tx, local_valid, n_items
    )
    local_anc = count_supports(item_bits_orig, ancestor_masks, valid_tid_orig)
    prefix_supports = jax.lax.psum(local_anc, axis_name)

    # --- Exec-Eclat on the exchanged slab D'_q ------------------------------
    item_bits, valid_tid = vertical_from_slab(slab, slab_valid, n_items)
    seed_tid = seed_tidlists(item_bits, my_seed_prefix, valid_tid)
    res = eclat.mine_seeded(
        item_bits,
        my_seed_prefix,
        my_seed_ext,
        seed_tid,
        my_seed_valid,
        min_support,
        key,
        config=eclat_cfg,
        n_items=n_items,
        support_fn=support_fn,
        multi_support_fn=multi_support_fn,
    )
    return Phase4Out(
        fi_items=res.items,
        fi_supports=res.supports,
        fi_count=res.n_out,
        fi_total=res.n_total,
        prefix_supports=prefix_supports,
        overflow=res.stack_overflow,
        work_iters=res.n_iters,
        nodes_popped=res.n_popped,
    )
