"""Schedulers: LPT (Alg. 16) and the replication-aware greedy QKP (Alg. 23).

Host-side control plane.  ``lpt_schedule`` is Graham's Longest-Processing-Time
best-fit (4/3-approximation, Lemma 8.2) — used both for PBEC→processor
assignment (Phase 2) and, beyond the paper, for MoE expert→EP-rank placement
(see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional, Sequence, Tuple

import numpy as np


def lpt_schedule(sizes: Sequence[float], n_processors: int) -> np.ndarray:
    """Assign each task to the least-loaded processor, largest tasks first.

    Returns ``assignment int[n_tasks]``; ties broken by processor index for
    determinism (important for multi-host agreement: every host computes the
    same schedule from the same broadcast sample).
    """
    sizes = np.asarray(sizes, dtype=np.float64)
    order = np.argsort(-sizes, kind="stable")
    loads = np.zeros(n_processors, dtype=np.float64)
    assignment = np.zeros(len(sizes), dtype=np.int64)
    for t in order:
        p = int(np.argmin(loads))  # first minimum ⇒ deterministic
        assignment[t] = p
        loads[p] += sizes[t]
    return assignment


def loads_of(sizes: Sequence[float], assignment: np.ndarray, P: int) -> np.ndarray:
    loads = np.zeros(P, dtype=np.float64)
    np.add.at(loads, assignment, np.asarray(sizes, dtype=np.float64))
    return loads


def lpt_makespan_bound_ok(sizes: Sequence[float], assignment: np.ndarray, P: int) -> bool:
    """Soundly checkable Graham guarantee.

    The classic 4/3·OPT bound needs the true OPT; against the computable
    lower bound max(mean, max) the sound list-scheduling guarantee is
    ``makespan ≤ Σ/P + (1 − 1/P)·max`` — we check that (it implies ≤ 2·OPT,
    and LPT is in fact 4/3-optimal per Lemma 8.2)."""
    sizes = np.asarray(sizes, dtype=np.float64)
    if len(sizes) == 0:
        return True
    loads = loads_of(sizes, assignment, P)
    bound = sizes.sum() / P + (1.0 - 1.0 / P) * sizes.max()
    return loads.max() <= bound + 1e-9


def makespan_of(sizes: Sequence[float], assignment: np.ndarray, P: int) -> float:
    """Max processor load under an assignment (the schedule's makespan)."""
    return float(loads_of(sizes, assignment, P).max())


def replicated_volume(
    tidlists: np.ndarray,     # uint32[C, W] packed class tidlists T(U_i)
    assignment: np.ndarray,   # int[C]
    n_processors: int,
) -> float:
    """Exact replicated-transaction volume of an assignment: Σ_p |D'_p|.

    ``|D'_p| = popcount(OR of T(U_i) over classes i on p)`` — every
    transaction is counted once per processor whose classes it must reach
    (thesis Ch. 10's replication metric, in transactions rather than the
    |D|-normalized factor Phase 3 reports at runtime).
    """
    tidlists = np.asarray(tidlists, dtype=np.uint32)
    total = 0
    for p in range(n_processors):
        rows = tidlists[np.asarray(assignment) == p]
        if len(rows) == 0:
            continue
        union = np.bitwise_or.reduce(rows, axis=0)
        total += int(np.unpackbits(union.view(np.uint8)).sum())
    return float(total)


class ReplAssignment(NamedTuple):
    """DB-Repl-Min output: the assignment plus its replication cost.

    ``volume`` is the total replicated-transaction volume Σ_p |D'_p| — what
    Phase 3 will actually move — exact when tidlists are given, NaN without
    them (``sizes`` are sample-FI counts, not transactions, so no honest
    volume exists in that case).  The planner compares it with LPT's volume
    to pick the scheduler.
    """

    assignment: np.ndarray
    volume: float


def db_repl_min(
    sizes: np.ndarray,        # est. class sizes w_i
    profit: np.ndarray,       # S_ij = |T(U_i ∪ U_j)| shared-transaction counts
    n_processors: int,
    tidlists: Optional[np.ndarray] = None,  # packed uint32[C, W] → exact volume
) -> ReplAssignment:
    """Alg. 23 (DB-Repl-Min): replication-aware assignment via greedy QKP.

    For each processor in turn, greedily add the unassigned class with the
    largest marginal shared-transaction profit w.r.t. the classes already in
    this processor's knapsack, subject to the capacity c = Σw/P.  Greedy is our
    QKP oracle (the thesis leaves the QKP solver open; exact QKP is NP-hard).

    Returns :class:`ReplAssignment` ``(assignment int[n_tasks], volume)``.
    """
    n = len(sizes)
    sizes = np.asarray(sizes, dtype=np.float64)
    cap = sizes.sum() / n_processors
    assignment = np.full(n, -1, dtype=np.int64)
    for p in range(n_processors - 1):
        free = np.nonzero(assignment < 0)[0]
        if free.size == 0:
            break
        load = 0.0
        # seed with the largest free class (ensures progress even if > cap)
        seed = free[np.argmax(sizes[free])]
        chosen = [seed]
        assignment[seed] = p
        load += sizes[seed]
        while True:
            free = np.nonzero(assignment < 0)[0]
            if free.size == 0:
                break
            gains = profit[np.ix_(free, chosen)].sum(axis=1)
            ordergain = np.argsort(-gains, kind="stable")
            placed = False
            for gi in ordergain:
                c = free[gi]
                if load + sizes[c] <= cap * 1.05:  # small slack like LPT ties
                    assignment[c] = p
                    chosen.append(c)
                    load += sizes[c]
                    placed = True
                    break
            if not placed:
                break
    # last processor takes the remainder
    assignment[assignment < 0] = n_processors - 1

    volume = (
        replicated_volume(tidlists, assignment, n_processors)
        if tidlists is not None
        else float("nan")
    )
    return ReplAssignment(assignment=assignment, volume=volume)


def pairwise_shared_transactions(tidlists: np.ndarray) -> np.ndarray:
    """S_ij = popcount(tid_i & tid_j) for packed uint32 tidlists [C, W]."""
    from repro.core import bitmap as bm
    import jax.numpy as jnp

    t = jnp.asarray(tidlists)
    inter = bm.popcount_u32(t[:, None, :] & t[None, :, :]).sum(axis=-1)
    out = np.array(inter)  # writable copy
    np.fill_diagonal(out, 0)
    return out
