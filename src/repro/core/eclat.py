"""Eclat in JAX: depth-first FI mining over packed-bitmap tidlists.

This is the TPU-native re-expression of the thesis' Eclat (§B.3, Alg. 34/35)
used as the Phase-4 sequential miner and (on the database sample) as the
Phase-1 FI enumerator feeding the reservoir sampler.

Adaptation (see DESIGN.md):
  * recursion → ``lax.while_loop`` over a fixed-capacity explicit stack;
  * **frontier batching**: each loop trip pops up to ``frontier_size`` (K)
    nodes — the top of the stack — and computes all their extension supports
    in ONE fused ``[K, I]`` AND+popcount sweep (``multi_extension_supports``,
    replaceable by the Pallas kernels in ``repro.kernels.multi_support``);
    surviving children of the whole frontier are pushed back with a single
    vectorized scatter.  K=1 reproduces the classic one-node-per-trip DFS
    exactly and serves as the parity oracle;
  * dynamic item re-ordering by support (§B.4.2) is kept: each node sorts its
    frequent extensions ascending by support before splitting into child
    PBECs (Prop. 2.23 keeps the classes disjoint for *any* per-node order);
  * the (optional) reservoir sampler runs *inside* the mining loop: the FI
    stream never leaves the device (Alg. 9 / Vitter, §6.2.2).

All shapes are static; overflow of the stack or output buffer is counted and
reported, never silently dropped.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import bitmap as bm

_U32 = jnp.uint32


@dataclasses.dataclass(frozen=True)
class EclatConfig:
    """Static configuration of the DFS miner."""

    max_out: int = 4096          # capacity of the FI output buffer
    max_stack: int = 1024        # DFS stack capacity
    max_iters: int = 1 << 20     # hard bound on loop trips (≥ |F|+1 at K=1)
    reservoir_size: int = 0      # >0 enables the in-loop reservoir sampler
    count_only: bool = False     # skip writing the FI buffer (Phase-1 f_i count)
    frontier_size: int = 1       # K — DFS nodes mined per while_loop trip


class EclatResult(NamedTuple):
    """Mining result; buffers are only valid up to their counts."""

    items: jnp.ndarray       # uint32[max_out, IW] packed itemset masks
    supports: jnp.ndarray    # int32[max_out]
    n_out: jnp.ndarray       # int32 — number of FIs written (≤ max_out)
    n_total: jnp.ndarray     # int32 — number of FIs *found* (may exceed max_out)
    stack_overflow: jnp.ndarray  # int32 — dropped pushes (0 ⇒ complete result)
    reservoir_items: jnp.ndarray     # uint32[R, IW]
    reservoir_supports: jnp.ndarray  # int32[R]
    n_iters: jnp.ndarray     # int32 — loop trips executed
    n_popped: jnp.ndarray    # int32 — DFS nodes mined; /(n_iters·K) =
    #                          frontier occupancy (the batching efficiency)


class _State(NamedTuple):
    sp: jnp.ndarray
    stk_items: jnp.ndarray   # uint32[S, IW]
    stk_ext: jnp.ndarray     # uint32[S, IW]
    stk_tid: jnp.ndarray     # uint32[S, W]
    out_items: jnp.ndarray
    out_supp: jnp.ndarray
    n_out: jnp.ndarray
    n_total: jnp.ndarray
    overflow: jnp.ndarray
    res_items: jnp.ndarray
    res_supp: jnp.ndarray
    res_seen: jnp.ndarray    # t in Algorithm R
    key: jax.Array
    it: jnp.ndarray
    popped: jnp.ndarray      # DFS nodes popped over all trips


#: single-prefix support plug-in: (item_bits[I, W], tid[W]) -> int32[I]
SupportFn = Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]
#: multi-prefix support plug-in: (item_bits[I, W], tids[K, W]) -> int32[K, I]
MultiSupportFn = Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]


def _lift_support_fn(support_fn: SupportFn) -> MultiSupportFn:
    """vmap a single-prefix support fn over the frontier axis."""

    def multi(item_bits, prefix_tids):
        return jax.vmap(lambda t: support_fn(item_bits, t))(prefix_tids)

    return multi


def _reservoir_update(state, itemsets_packed, supports, emit_mask, R):
    """Algorithm R over the ≤I itemsets emitted this node (sequential fori)."""

    def body(i, carry):
        res_items, res_supp, seen, key = carry

        def do(carry):
            res_items, res_supp, seen, key = carry
            seen = seen + 1
            key, sub = jax.random.split(key)
            j = jax.random.randint(sub, (), 0, seen)
            slot = jnp.where(seen <= R, seen - 1, j)
            take = (seen <= R) | (j < R)
            slot = jnp.where(take, slot, R)  # R = out-of-bounds ⇒ drop
            res_items = res_items.at[slot].set(itemsets_packed[i], mode="drop")
            res_supp = res_supp.at[slot].set(supports[i], mode="drop")
            return res_items, res_supp, seen, key

        return jax.lax.cond(emit_mask[i], do, lambda c: c, carry)

    return jax.lax.fori_loop(0, emit_mask.shape[0], body, state)


@partial(
    jax.jit,
    static_argnames=("config", "n_items", "support_fn", "multi_support_fn"),
)
def mine_seeded(
    item_bits: jnp.ndarray,
    seed_prefix: jnp.ndarray,   # bool [K, I]
    seed_ext: jnp.ndarray,      # bool [K, I]
    seed_tid: jnp.ndarray,      # uint32 [K, W]
    seed_valid: jnp.ndarray,    # bool [K]
    min_support: jnp.ndarray,
    key: jax.Array,
    *,
    config: EclatConfig,
    n_items: int,
    support_fn: Optional[SupportFn] = None,
    multi_support_fn: Optional[MultiSupportFn] = None,
) -> EclatResult:
    """Mine all FIs in the union of K PBECs ``[prefix_k | ext_k]``.

    This is `Exec-Eclat` (thesis Alg. 21): a processor's assigned classes are
    the DFS seeds; the `Prepare-Tidlists` branch simulation of Ch. 9 becomes
    "caller passes T(U_k)" (computed in one batched AND-reduce).  The prefixes
    U_k themselves are *not* emitted (Phase 4 handles prefix supports via the
    side channel, Alg. 19 line 2).

    Each loop trip mines a **frontier** of up to ``config.frontier_size``
    nodes: one fused multi-prefix support sweep, one vectorized child scatter.
    ``multi_support_fn`` (if given) computes the fused ``[F, I]`` supports;
    otherwise a provided single-prefix ``support_fn`` is vmapped over the
    frontier, falling back to the pure-jnp oracle.
    """
    if multi_support_fn is None:
        if support_fn is not None:
            multi_support_fn = _lift_support_fn(support_fn)
        else:
            multi_support_fn = bm.multi_extension_supports
    I = n_items
    IW = bm.n_words(I)
    W = item_bits.shape[-1]
    S, O, R = config.max_stack, config.max_out, max(config.reservoir_size, 1)
    K = seed_prefix.shape[0]
    assert K <= S, "seed count exceeds stack capacity"
    F = max(1, min(config.frontier_size, S))   # frontier width per trip

    # Compact valid seeds to the bottom of the stack.
    seed_valid = seed_valid.astype(jnp.bool_)
    rank = jnp.cumsum(seed_valid) - 1
    pos = jnp.where(seed_valid, rank, S)
    n_seeds = seed_valid.sum().astype(jnp.int32)

    init = _State(
        sp=n_seeds,
        stk_items=jnp.zeros((S, IW), _U32)
        .at[pos]
        .set(bm.pack_bool(seed_prefix.astype(jnp.bool_)), mode="drop"),
        stk_ext=jnp.zeros((S, IW), _U32)
        .at[pos]
        .set(bm.pack_bool(seed_ext.astype(jnp.bool_)), mode="drop"),
        stk_tid=jnp.zeros((S, W), _U32).at[pos].set(seed_tid, mode="drop"),
        out_items=jnp.zeros((O, IW), _U32),
        out_supp=jnp.zeros((O,), jnp.int32),
        n_out=jnp.asarray(0, jnp.int32),
        n_total=jnp.asarray(0, jnp.int32),
        overflow=jnp.asarray(0, jnp.int32),
        res_items=jnp.zeros((R, IW), _U32),
        res_supp=jnp.zeros((R,), jnp.int32),
        res_seen=jnp.asarray(0, jnp.int32),
        key=key,
        it=jnp.asarray(0, jnp.int32),
        popped=jnp.asarray(0, jnp.int32),
    )

    # Constant across iterations: packed one-hot masks of every item
    # (hoisted out of the loop body — built fresh every trip in the seed).
    e_packed = bm.pack_bool(jax.nn.one_hot(jnp.arange(I), I, dtype=jnp.bool_))

    def cond(s: _State):
        return (s.sp > 0) & (s.it < config.max_iters)

    def body(s: _State) -> _State:
        # --- pop a frontier: the top min(sp, F) stack nodes -----------------
        idx = s.sp - 1 - jnp.arange(F)        # [F] — top of stack first
        active = idx >= 0                      # [F]
        idx_c = jnp.maximum(idx, 0)
        node_items = s.stk_items[idx_c]        # uint32[F, IW]
        node_ext = s.stk_ext[idx_c]            # uint32[F, IW]
        node_tid = s.stk_tid[idx_c]            # uint32[F, W]
        # Inactive lanes alias stack slot 0; masking their extension sets to ∅
        # makes them emit and push nothing.
        ext_bool = bm.unpack_bool(node_ext, I) & active[:, None]   # [F, I]

        # --- fused multi-prefix support counting (the Pallas hot spot) ------
        supports = multi_support_fn(item_bits, node_tid)     # int32[F, I]
        freq = ext_bool & (supports >= min_support)
        nf = freq.sum(axis=-1).astype(jnp.int32)             # [F]
        nf_total = nf.sum()

        # --- dynamic re-ordering: rank frequent extensions by support ------
        sort_key = jnp.where(freq, supports, jnp.iinfo(jnp.int32).max)
        order = jnp.argsort(sort_key, axis=-1)               # frequent first, asc
        rank = jnp.argsort(order, axis=-1)                   # rank per item
        # rank[f] < nf[f]  ⇔  item is a frequent extension of node f.

        # --- emit FIs: prefix_f ∪ {e} for each frequent e -------------------
        child_items = node_items[:, None, :] | e_packed[None, :, :]  # [F, I, IW]
        node_off = s.n_out + jnp.cumsum(nf) - nf             # exclusive prefix sum
        out_pos = jnp.where(freq, node_off[:, None] + rank, O)   # ≥O ⇒ dropped
        flat_pos = out_pos.reshape(F * I)
        flat_items = child_items.reshape(F * I, IW)
        flat_supp = supports.reshape(F * I)
        if not config.count_only:
            out_items = s.out_items.at[flat_pos].set(flat_items, mode="drop")
            out_supp = s.out_supp.at[flat_pos].set(flat_supp, mode="drop")
        else:
            out_items, out_supp = s.out_items, s.out_supp
        n_out = jnp.minimum(s.n_out + nf_total, O)
        n_total = s.n_total + nf_total

        # --- reservoir over the emitted stream ------------------------------
        if config.reservoir_size > 0:
            res_items, res_supp, res_seen, key = _reservoir_update(
                (s.res_items, s.res_supp, s.res_seen, s.key),
                flat_items,
                flat_supp,
                freq.reshape(F * I),
                config.reservoir_size,
            )
        else:
            res_items, res_supp, res_seen, key = (
                s.res_items,
                s.res_supp,
                s.res_seen,
                s.key,
            )

        # --- push child PBECs (one scatter for the whole frontier) ----------
        # Child of extension e keeps extensions with larger rank (Prop. 2.23).
        later = rank[:, None, :] > rank[:, :, None]          # [F, I(child e), I]
        child_ext_bool = later & freq[:, None, :]
        child_ext = bm.pack_bool(child_ext_bool)             # [F, I, IW]
        child_tid = item_bits[None, :, :] & node_tid[:, None, :]   # [F, I, W]
        # Children with no extensions are leaves: their FI was already emitted
        # above, so pushing them would only burn a trip — skip them.
        has_ext = child_ext_bool.any(axis=-1)
        push = freq & has_ext                                # [F, I]
        push_flat = push.reshape(F * I)
        n_push = push_flat.sum().astype(jnp.int32)
        sp_pop = s.sp - active.sum().astype(jnp.int32)
        push_rank = jnp.cumsum(push_flat) - 1                # 0..n_push-1
        stack_pos = jnp.where(push_flat, sp_pop + push_rank, S)  # ≥S ⇒ dropped
        dropped = jnp.maximum(sp_pop + n_push - S, 0)
        stk_items = s.stk_items.at[stack_pos].set(flat_items, mode="drop")
        stk_ext = s.stk_ext.at[stack_pos].set(
            child_ext.reshape(F * I, IW), mode="drop"
        )
        stk_tid = s.stk_tid.at[stack_pos].set(
            child_tid.reshape(F * I, W), mode="drop"
        )
        sp_new = jnp.minimum(sp_pop + n_push, S)

        return _State(
            sp=sp_new,
            stk_items=stk_items,
            stk_ext=stk_ext,
            stk_tid=stk_tid,
            out_items=out_items,
            out_supp=out_supp,
            n_out=n_out,
            n_total=n_total,
            overflow=s.overflow + dropped,
            res_items=res_items,
            res_supp=res_supp,
            res_seen=res_seen,
            key=key,
            it=s.it + 1,
            popped=s.popped + active.sum().astype(jnp.int32),
        )

    final = jax.lax.while_loop(cond, body, init)
    return EclatResult(
        items=final.out_items,
        supports=final.out_supp,
        n_out=final.n_out,
        n_total=final.n_total,
        stack_overflow=final.overflow,
        reservoir_items=final.res_items,
        reservoir_supports=final.res_supp,
        n_iters=final.it,
        n_popped=final.popped,
    )


def mine(
    item_bits: jnp.ndarray,
    prefix_mask: jnp.ndarray,
    ext_mask: jnp.ndarray,
    prefix_tid: jnp.ndarray,
    min_support: jnp.ndarray,
    key: jax.Array,
    *,
    config: EclatConfig,
    n_items: int,
    support_fn: Optional[SupportFn] = None,
    multi_support_fn: Optional[MultiSupportFn] = None,
) -> EclatResult:
    """Single-PBEC convenience wrapper over :func:`mine_seeded`."""
    return mine_seeded(
        item_bits,
        prefix_mask[None, :],
        ext_mask[None, :],
        prefix_tid[None, :],
        jnp.ones((1,), jnp.bool_),
        min_support,
        key,
        config=config,
        n_items=n_items,
        support_fn=support_fn,
        multi_support_fn=multi_support_fn,
    )


def mine_all(
    db: bm.BitmapDB,
    min_support,
    key: Optional[jax.Array] = None,
    *,
    config: EclatConfig = EclatConfig(),
    support_fn: Optional[SupportFn] = None,
    multi_support_fn: Optional[MultiSupportFn] = None,
) -> EclatResult:
    """Mine *all* FIs of a database (root PBEC [∅ | B])."""
    if key is None:
        key = jax.random.PRNGKey(0)
    I = db.n_items
    return mine(
        db.item_bits,
        jnp.zeros((I,), jnp.bool_),
        jnp.ones((I,), jnp.bool_),
        db.all_tids(),
        jnp.asarray(min_support, jnp.int32),
        key,
        config=config,
        n_items=I,
        support_fn=support_fn,
        multi_support_fn=multi_support_fn,
    )


# ---------------------------------------------------------------------------
# Host-side oracle: brute-force FI mining for tests (exponential, tiny DBs).
# ---------------------------------------------------------------------------


def brute_force_fis(dense, min_support: int):
    """All frequent itemsets of a dense bool matrix, as {frozenset: support}."""
    import itertools

    import numpy as np

    dense = np.asarray(dense)
    n_tx, n_items = dense.shape
    out = {}
    frontier = []
    for i in range(n_items):
        s = int(dense[:, i].sum())
        if s >= min_support:
            out[frozenset([i])] = s
            frontier.append((frozenset([i]), dense[:, i]))
    while frontier:
        nxt = []
        for items, cover in frontier:
            last = max(items)
            for j in range(last + 1, n_items):
                cov = cover & dense[:, j]
                s = int(cov.sum())
                if s >= min_support:
                    ns = items | {j}
                    out[frozenset(ns)] = s
                    nxt.append((frozenset(ns), cov))
        frontier = nxt
    return out
