"""Sampling theory + samplers — thesis Ch. 6.

Sample-size formulas implemented exactly:
  * Thm 6.1 (Toivonen/Chernoff):   |D̃| ≥ 1/(2ε²)·ln(2/δ)
  * Thm 6.2 (coverage, i.i.d.):    |F̃s| ≥ 4/(ε²ρ)·ln(2/δ)
  * Thm 6.3 (reservoir, hypergeom.): |F̃s| ≥ −log(δ/2)/D(ρ+ε‖ρ)

Samplers:
  * :func:`modified_coverage_sample` — Alg. 8, device-vectorized (the method's
    fast non-uniform heuristic; no i.i.d. guarantee, as the thesis states).
  * :func:`coverage_sample_uniform` — Alg. 7, host-side (uniform; used to
    validate the heuristic in tests/benchmarks).
  * the reservoir sampler lives *inside* the Eclat loop (repro.core.eclat);
    :func:`reservoir_sample_np` is the host oracle for uniformity tests.
  * :func:`merge_reservoirs` — Phase-1-Reservoir lines 10–14: hypergeometric
    re-weighting of P per-processor reservoirs into one global uniform sample.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmap as bm


# ---------------------------------------------------------------------------
# Sample sizes
# ---------------------------------------------------------------------------


def db_sample_size(eps: float, delta: float) -> int:
    """Thm 6.1 — database sample size for support error ≤ ε w.p. ≥ 1−δ."""
    return int(math.ceil(math.log(2.0 / delta) / (2.0 * eps * eps)))


def coverage_sample_size(eps: float, delta: float, rho: float) -> int:
    """Thm 6.2 — i.i.d. FI-sample size for relative-size error ≤ ε·ρ."""
    return int(math.ceil(4.0 / (eps * eps * rho) * math.log(2.0 / delta)))


def kl_bernoulli(p: float, q: float) -> float:
    """Kullback–Leibler divergence D(p‖q) of Bernoulli distributions."""
    p = min(max(p, 1e-12), 1 - 1e-12)
    q = min(max(q, 1e-12), 1 - 1e-12)
    return p * math.log(p / q) + (1 - p) * math.log((1 - p) / (1 - q))


def reservoir_sample_size(eps: float, delta: float, rho: float) -> int:
    """Thm 6.3 — hypergeometric (reservoir) FI-sample size."""
    return int(math.ceil(-math.log(delta / 2.0) / kl_bernoulli(rho + eps, rho)))


# ---------------------------------------------------------------------------
# Modified coverage algorithm (Alg. 8) — device, vectorized over N samples.
# ---------------------------------------------------------------------------


def modified_coverage_sample(
    key: jax.Array,
    mfi_items: jnp.ndarray,
    mfi_valid: jnp.ndarray,
    n_samples: int,
    n_items: int,
) -> jnp.ndarray:
    """Draw N itemsets: pick m ∝ |P(m)| = 2^|m|, then a uniform subset of m.

    Because the dedup loop of Alg. 7 is dropped, draws are independent but not
    uniform over F̃ (samples in many P(m_i) are over-represented) — the thesis
    calls this estimate a *heuristic* and so do we.

    Returns packed masks ``uint32[N, IW]``.
    """
    sizes = bm.popcount_u32(mfi_items).sum(axis=-1).astype(jnp.float32)
    logits = sizes * jnp.log(2.0)
    logits = jnp.where(mfi_valid, logits, -jnp.inf)
    k_pick, k_bits = jax.random.split(key)
    picks = jax.random.categorical(k_pick, logits, shape=(n_samples,))
    chosen = jnp.take(mfi_items, picks, axis=0)  # [N, IW]
    rand_words = jax.random.bits(
        k_bits, (n_samples, mfi_items.shape[-1]), dtype=jnp.uint32
    )
    return chosen & rand_words  # uniform subset of each chosen MFI


# ---------------------------------------------------------------------------
# Full coverage algorithm (Alg. 7) — host, uniform over F̃ = ∪P(m).
# ---------------------------------------------------------------------------


def coverage_sample_uniform(
    rng: np.random.Generator,
    mfi_masks: np.ndarray,  # bool [M, I]
    n_samples: int,
) -> np.ndarray:
    """Uniform i.i.d. sample of ∪ P(m_i) via the coverage rejection rule.

    A draw (W, i) is kept iff i is the *smallest* index with W ⊆ m_i — this
    samples the set S' of §6.2.1 whose elements biject with F̃.
    """
    M, I = mfi_masks.shape
    sizes = mfi_masks.sum(axis=1)
    w = np.exp2(sizes - sizes.max())
    w = w / w.sum()
    out = np.zeros((n_samples, I), dtype=bool)
    k = 0
    while k < n_samples:
        i = rng.choice(M, p=w)
        subset = mfi_masks[i] & (rng.random(I) < 0.5)
        # line 6: reject if contained in an earlier MFI
        earlier = mfi_masks[:i]
        if earlier.size and (~(subset & ~earlier).any(axis=1)).any():
            continue
        out[k] = subset
        k += 1
    return out


# ---------------------------------------------------------------------------
# Reservoir (host oracle) + hypergeometric merge of P reservoirs.
# ---------------------------------------------------------------------------


def reservoir_sample_np(
    rng: np.random.Generator, stream: np.ndarray, n: int
) -> np.ndarray:
    """Algorithm R over a host stream — oracle for the in-loop sampler."""
    R = stream[:n].copy()
    for t in range(n, len(stream)):
        j = rng.integers(0, t + 1)
        if j < n:
            R[j] = stream[t]
    return R


def merge_reservoirs(
    rng: np.random.Generator,
    counts: np.ndarray,  # f_i: total FIs seen by each processor [P]
    n_take: int,
) -> np.ndarray:
    """Phase-1-Reservoir lines 10–12: X ~ multivariate hypergeometric(f_i).

    Processor i contributes X_i of its reservoir elements; since each local
    reservoir is uniform over its local stream, the merged sample is uniform
    over the union.  Returns X ``int[P]`` with ΣX = min(n_take, Σf).
    """
    counts = np.asarray(counts, dtype=np.int64)
    remaining = counts.copy()
    total = int(counts.sum())
    n_take = min(n_take, total)
    X = np.zeros(len(counts), dtype=np.int64)
    # sequential marginals of the multivariate hypergeometric
    left = n_take
    pool = total
    for i in range(len(counts)):
        if left == 0 or pool == 0:
            break
        x = rng.hypergeometric(remaining[i], pool - remaining[i], left)
        X[i] = x
        left -= x
        pool -= remaining[i]
    return X


# ---------------------------------------------------------------------------
# Phase-1 database sampling helper
# ---------------------------------------------------------------------------


def sample_db(
    db: bm.BitmapDB, key: jax.Array, n_sample: int
) -> bm.BitmapDB:
    """i.i.d. with-replacement transaction sample as a new BitmapDB."""
    rows = bm.sample_transactions(db.tx_bits, key, n_sample, db.n_tx)
    return bm.rebuild_vertical(rows, db.n_items, n_sample)
