"""Association-rule generation over a mined FI table (ap-genrules).

The thesis' motivating scenario is a store owner asking "which goods are
bought together in ≥ p% of baskets" — a *query* against the mined result.
Association rules X → Y (X, Y disjoint, X ∪ Y frequent) are the canonical
consumer of a frequent-itemset table (Agrawal & Srikant '94, and the survey
framing of arXiv:1402.1814): mining runs once, rule generation and serving
run many times.

This module is the host-side half of the serving subsystem (`repro.serve`):

  * :func:`generate_rules` — the ap-genrules recursion.  For each frequent Z
    it grows *consequents* level-wise with an apriori join, pruning on
    confidence: conf(Z∖h → h) is antitone in h (shrinking the antecedent can
    only lower confidence), so a consequent that fails min-confidence never
    has a superset that passes.  Exact — verified against the brute-force
    enumeration below.
  * metrics per rule: confidence, lift, leverage (support is that of X ∪ Y).
  * :class:`RuleTable` — the rules packed into uint32 itemset masks + metric
    vectors, sorted by (confidence, support) descending: the array form the
    device-resident query engine (`repro.serve.engine`) consumes.
  * :func:`brute_force_rules` — exponential all-splits oracle for tests.

Supports are absolute transaction counts throughout (as in `core/eclat.py`);
relative forms divide by ``n_tx`` at the metric boundary only.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, FrozenSet, Iterable, List, Sequence, Tuple

import numpy as np

Itemset = FrozenSet[int]


@dataclasses.dataclass(frozen=True)
class Rule:
    """An association rule X → Y with its interestingness metrics.

    Attributes:
      antecedent: X (non-empty, disjoint from Y).
      consequent: Y (non-empty).
      support:    absolute support of X ∪ Y.
      confidence: supp(X∪Y) / supp(X)           — P(Y | X).
      lift:       conf / (supp(Y)/n)            — independence ratio.
      leverage:   supp(X∪Y)/n − supp(X)·supp(Y)/n²   — additive form.
    """

    antecedent: Itemset
    consequent: Itemset
    support: int
    confidence: float
    lift: float
    leverage: float

    def key(self) -> Tuple[Itemset, Itemset]:
        return (self.antecedent, self.consequent)


def _metrics(
    supp_z: int, supp_x: int, supp_y: int, n_tx: int
) -> Tuple[float, float, float]:
    conf = supp_z / supp_x
    lift = conf * n_tx / supp_y
    leverage = supp_z / n_tx - (supp_x / n_tx) * (supp_y / n_tx)
    return conf, lift, leverage


def _apriori_gen(consequents: List[Itemset]) -> List[Itemset]:
    """Level-wise candidate join over consequents (Apriori-gen, Alg. 1).

    Join pairs sharing all but their largest item, then prune candidates
    with an m-subset not in the previous level.
    """
    prev = set(consequents)
    seqs = sorted(tuple(sorted(h)) for h in consequents)
    out: List[Itemset] = []
    for a, b in itertools.combinations(seqs, 2):
        if a[:-1] != b[:-1]:
            continue
        cand = frozenset(a + b[-1:])
        if all(cand - {i} in prev for i in cand):
            out.append(cand)
    return out


def generate_rules(
    fis: Dict[Itemset, int],
    n_tx: int,
    min_confidence: float = 0.5,
) -> List[Rule]:
    """All rules X → Y with conf ≥ ``min_confidence`` from an FI table.

    ``fis`` must be downward closed (every subset of a frequent itemset
    present) — true of any complete mining result, e.g. ``fimi.run(...,
    materialize=True).fi_dict`` or ``eclat.brute_force_fis``.
    """
    rules: List[Rule] = []

    def emit(z: Itemset, supp_z: int, h: Itemset) -> bool:
        x = z - h
        supp_x = fis[x]
        conf = supp_z / supp_x
        if conf < min_confidence:
            return False
        _, lift, lev = _metrics(supp_z, supp_x, fis[h], n_tx)
        rules.append(Rule(x, h, supp_z, conf, lift, lev))
        return True

    for z, supp_z in fis.items():
        if len(z) < 2:
            continue
        # level 1: single-item consequents
        level = [h for i in z if emit(z, supp_z, h := frozenset([i]))]
        # ap-genrules: join surviving consequents level-wise
        while level and len(level[0]) + 1 < len(z):
            level = [h for h in _apriori_gen(level) if emit(z, supp_z, h)]
    return rules


def brute_force_rules(
    fis: Dict[Itemset, int], n_tx: int, min_confidence: float = 0.5
) -> Dict[Tuple[Itemset, Itemset], Rule]:
    """Oracle: every (X, Z∖X) split of every frequent Z, filtered on conf."""
    out: Dict[Tuple[Itemset, Itemset], Rule] = {}
    for z, supp_z in fis.items():
        if len(z) < 2:
            continue
        items = sorted(z)
        for r in range(1, len(items)):
            for ysel in itertools.combinations(items, r):
                y = frozenset(ysel)
                x = z - y
                conf, lift, lev = _metrics(supp_z, fis[x], fis[y], n_tx)
                if conf >= min_confidence:
                    out[(x, y)] = Rule(x, y, supp_z, conf, lift, lev)
    return out


# ---------------------------------------------------------------------------
# Packed array form for the serving engine
# ---------------------------------------------------------------------------


def pack_itemsets(sets: Sequence[Iterable[int]], n_items: int) -> np.ndarray:
    """Pack itemsets into little-endian uint32 masks ``[N, n_words]`` (host).

    Same layout as ``core.bitmap.pack_bool`` — bit ``i % 32`` of word
    ``i // 32`` — without touching jax.
    """
    W = (n_items + 31) // 32
    out = np.zeros((len(sets), W), np.uint32)
    for r, s in enumerate(sets):
        for i in s:
            out[r, i // 32] |= np.uint32(1) << np.uint32(i % 32)
    return out


@dataclasses.dataclass(frozen=True)
class RuleTable:
    """Rules as parallel arrays, sorted by (confidence, support) descending.

    The immutable host-side artifact `repro.serve.index.RuleIndex` puts on
    device.  ``antecedents``/``consequents`` are packed uint32 masks
    ``[R, n_words(n_items)]``; metric vectors are ``[R]``.
    """

    antecedents: np.ndarray
    consequents: np.ndarray
    supports: np.ndarray
    confidence: np.ndarray
    lift: np.ndarray
    leverage: np.ndarray
    n_items: int
    n_tx: int

    @property
    def n_rules(self) -> int:
        return int(self.antecedents.shape[0])

    @classmethod
    def from_rules(cls, rules: List[Rule], n_items: int, n_tx: int) -> "RuleTable":
        order = sorted(
            range(len(rules)),
            key=lambda r: (
                -rules[r].confidence,
                -rules[r].support,
                tuple(sorted(rules[r].antecedent)),
                tuple(sorted(rules[r].consequent)),
            ),
        )
        rs = [rules[r] for r in order]
        return cls(
            antecedents=pack_itemsets([r.antecedent for r in rs], n_items),
            consequents=pack_itemsets([r.consequent for r in rs], n_items),
            supports=np.asarray([r.support for r in rs], np.int32),
            confidence=np.asarray([r.confidence for r in rs], np.float32),
            lift=np.asarray([r.lift for r in rs], np.float32),
            leverage=np.asarray([r.leverage for r in rs], np.float32),
            n_items=n_items,
            n_tx=n_tx,
        )

    def rule(self, r: int) -> Rule:
        """Unpack row ``r`` back into a :class:`Rule` (debug/printing)."""
        ant = _unpack_row(self.antecedents[r], self.n_items)
        con = _unpack_row(self.consequents[r], self.n_items)
        return Rule(
            ant, con, int(self.supports[r]), float(self.confidence[r]),
            float(self.lift[r]), float(self.leverage[r]),
        )


def _unpack_row(words: np.ndarray, n_items: int) -> Itemset:
    items = [
        i for i in range(n_items)
        if (int(words[i // 32]) >> (i % 32)) & 1
    ]
    return frozenset(items)


def format_rule(r: Rule, n_tx: int) -> str:
    ant = ",".join(map(str, sorted(r.antecedent)))
    con = ",".join(map(str, sorted(r.consequent)))
    return (
        f"{{{ant}}} -> {{{con}}}  supp={r.support} ({r.support / n_tx:.1%})"
        f"  conf={r.confidence:.2f}  lift={r.lift:.2f}  lev={r.leverage:+.4f}"
    )


def top_rules(rules: List[Rule], k: int = 5) -> List[Rule]:
    """The k most confident rules (support breaks ties) — printing helper."""
    return sorted(
        rules, key=lambda r: (-r.confidence, -r.support,
                              tuple(sorted(r.antecedent)))
    )[:k]
