"""DFS mining of (candidates on) maximal frequent itemsets — thesis Ch. 7.

Implements the DFS-MFI-Schema (Alg. 10) on packed bitmaps: a frequent itemset
is a *candidate on an MFI* (Def. 7.1) iff none of its extensions is frequent.
Run over a subset of the 1-prefix PBECs this yields per-processor sets ``M_i``
whose union M satisfies ``M̃ ⊆ M ⊆ F̃`` with ``|M| ≤ min(P,|W|)·|M̃|``
(Thm. 7.5) — exactly the Parallel-FIMI-Par Phase-1 object.  A post-pass
(:func:`filter_maximal`) recovers the exact MFI set M̃ when run globally
(Parallel-FIMI-Seq Phase 1).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import bitmap as bm

_U32 = jnp.uint32


@dataclasses.dataclass(frozen=True)
class MFIConfig:
    max_out: int = 2048
    max_stack: int = 1024
    max_iters: int = 1 << 20


class MFIResult(NamedTuple):
    items: jnp.ndarray      # uint32[max_out, IW] candidate itemset masks
    supports: jnp.ndarray   # int32[max_out]
    n_out: jnp.ndarray      # int32
    overflow: jnp.ndarray   # int32 (stack + output drops; 0 ⇒ complete)
    n_iters: jnp.ndarray


class _State(NamedTuple):
    sp: jnp.ndarray
    stk_items: jnp.ndarray
    stk_ext: jnp.ndarray
    stk_tid: jnp.ndarray
    stk_supp: jnp.ndarray
    out_items: jnp.ndarray
    out_supp: jnp.ndarray
    n_out: jnp.ndarray
    overflow: jnp.ndarray
    it: jnp.ndarray


@partial(jax.jit, static_argnames=("config", "n_items", "support_fn"))
def mine_candidates_seeded(
    item_bits: jnp.ndarray,
    seed_prefix: jnp.ndarray,    # bool [K, I]
    seed_ext: jnp.ndarray,       # bool [K, I]
    seed_tid: jnp.ndarray,       # uint32 [K, W]
    seed_support: jnp.ndarray,   # int32 [K]
    seed_valid: jnp.ndarray,     # bool [K]
    min_support: jnp.ndarray,
    *,
    config: MFIConfig,
    n_items: int,
    support_fn=None,
) -> MFIResult:
    """All candidates-on-MFIs inside the union of K PBECs ``[prefix_k|ext_k]``.

    ``seed_support`` is Supp(prefix_k) (used when the prefix itself turns out
    to be a leaf).  A non-frequent / empty prefix with support 0 never emits.
    """
    if support_fn is None:
        support_fn = bm.extension_supports
    I = n_items
    IW = bm.n_words(I)
    W = item_bits.shape[-1]
    S, O = config.max_stack, config.max_out
    K = seed_prefix.shape[0]
    assert K <= S

    seed_valid = seed_valid.astype(jnp.bool_)
    rank = jnp.cumsum(seed_valid) - 1
    pos = jnp.where(seed_valid, rank, S)
    n_seeds = seed_valid.sum().astype(jnp.int32)

    init = _State(
        sp=n_seeds,
        stk_items=jnp.zeros((S, IW), _U32)
        .at[pos]
        .set(bm.pack_bool(seed_prefix.astype(jnp.bool_)), mode="drop"),
        stk_ext=jnp.zeros((S, IW), _U32)
        .at[pos]
        .set(bm.pack_bool(seed_ext.astype(jnp.bool_)), mode="drop"),
        stk_tid=jnp.zeros((S, W), _U32).at[pos].set(seed_tid, mode="drop"),
        stk_supp=jnp.zeros((S,), jnp.int32).at[pos].set(seed_support, mode="drop"),
        out_items=jnp.zeros((O, IW), _U32),
        out_supp=jnp.zeros((O,), jnp.int32),
        n_out=jnp.asarray(0, jnp.int32),
        overflow=jnp.asarray(0, jnp.int32),
        it=jnp.asarray(0, jnp.int32),
    )

    def cond(s):
        return (s.sp > 0) & (s.it < config.max_iters)

    def body(s: _State) -> _State:
        sp = s.sp - 1
        node_items = s.stk_items[sp]
        node_ext = s.stk_ext[sp]
        node_tid = s.stk_tid[sp]
        node_supp = s.stk_supp[sp]
        ext_bool = bm.unpack_bool(node_ext, I)

        supports = support_fn(item_bits, node_tid)
        freq = ext_bool & (supports >= min_support)
        nf = freq.sum().astype(jnp.int32)

        # The node is a candidate on an MFI iff frequent and no frequent ext.
        node_nonempty = (node_items != 0).any()
        is_cand = (nf == 0) & node_nonempty & (node_supp >= min_support)
        pos = jnp.where(is_cand, s.n_out, O)
        out_items = s.out_items.at[pos].set(node_items, mode="drop")
        out_supp = s.out_supp.at[pos].set(node_supp, mode="drop")
        n_out = s.n_out + is_cand.astype(jnp.int32)
        out_drop = jnp.maximum(n_out - O, 0)
        n_out = jnp.minimum(n_out, O)

        # Children (ascending-support order, Prop. 2.23 keeps classes disjoint).
        sort_key = jnp.where(freq, supports, jnp.iinfo(jnp.int32).max)
        order = jnp.argsort(sort_key)
        rank = jnp.argsort(order)
        e_packed = bm.pack_bool(jax.nn.one_hot(jnp.arange(I), I, dtype=jnp.bool_))
        child_items = node_items[None, :] | e_packed
        later = rank[None, :] > rank[:, None]
        child_ext = bm.pack_bool(later & freq[None, :])
        child_tid = item_bits & node_tid[None, :]

        push = freq  # every frequent child must be visited (leaves emit there)
        n_push = push.sum().astype(jnp.int32)
        push_rank = jnp.cumsum(push) - 1
        stack_pos = jnp.where(push, sp + push_rank, S)
        dropped = jnp.maximum(sp + n_push - S, 0)
        return _State(
            sp=jnp.minimum(sp + n_push, S),
            stk_items=s.stk_items.at[stack_pos].set(child_items, mode="drop"),
            stk_ext=s.stk_ext.at[stack_pos].set(child_ext, mode="drop"),
            stk_tid=s.stk_tid.at[stack_pos].set(child_tid, mode="drop"),
            stk_supp=s.stk_supp.at[stack_pos].set(supports, mode="drop"),
            out_items=out_items,
            out_supp=out_supp,
            n_out=n_out,
            overflow=s.overflow + dropped + out_drop,
            it=s.it + 1,
        )

    f = jax.lax.while_loop(cond, body, init)
    return MFIResult(f.out_items, f.out_supp, f.n_out, f.overflow, f.it)


def mine_candidates(
    item_bits,
    prefix_mask,
    ext_mask,
    prefix_tid,
    prefix_support,
    min_support,
    *,
    config: MFIConfig,
    n_items: int,
    support_fn=None,
) -> MFIResult:
    """Single-PBEC convenience wrapper over :func:`mine_candidates_seeded`."""
    return mine_candidates_seeded(
        item_bits,
        prefix_mask[None, :],
        ext_mask[None, :],
        prefix_tid[None, :],
        jnp.asarray(prefix_support, jnp.int32)[None],
        jnp.ones((1,), jnp.bool_),
        min_support,
        config=config,
        n_items=n_items,
        support_fn=support_fn,
    )


def mine_all_candidates(
    db: bm.BitmapDB, min_support, *, config: MFIConfig = MFIConfig(), support_fn=None
) -> MFIResult:
    """Candidates-on-MFIs over the whole lattice (root PBEC [∅ | B])."""
    I = db.n_items
    return mine_candidates(
        db.item_bits,
        jnp.zeros((I,), jnp.bool_),
        jnp.ones((I,), jnp.bool_),
        db.all_tids(),
        jnp.asarray(0, jnp.int32),
        jnp.asarray(min_support, jnp.int32),
        config=config,
        n_items=I,
        support_fn=support_fn,
    )


@partial(jax.jit, static_argnames=())
def filter_maximal(items: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Keep only itemsets not strictly contained in another valid itemset.

    Args:
      items: ``uint32[N, IW]`` packed masks.
      valid: bool ``[N]``.
    Returns: bool ``[N]`` — valid AND maximal.  Applied to the global candidate
    set this yields exactly M̃ (DFS-MFI-Schema line 5 as a post-pass; order-free
    and SPMD-friendly, unlike the thesis' sequential check).
    """
    sub = bm.is_subset_packed(items[:, None, :], items[None, :, :])  # [N, N]
    proper = sub & ~bm.is_subset_packed(items[None, :, :], items[:, None, :])
    dominated = (proper & valid[None, :]).any(axis=1)
    return valid & ~dominated


def powerset_log2_sizes(items: jnp.ndarray, n_items: int) -> jnp.ndarray:
    """|m| per packed mask — log2 |P(m)|, the coverage-algorithm weights."""
    return bm.popcount_u32(items).sum(axis=-1)
