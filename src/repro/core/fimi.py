"""Parallel-FIMI drivers — Methods 1–3 of the thesis (§8.5).

``run`` executes the full four-phase pipeline over P miners.  The device
phases are SPMD programs from :mod:`repro.core.phases`, mapped over the miner
axis by a pluggable ``spmd`` combinator:

  * ``vmap_spmd``       — P virtual miners on one device (tests, CPU),
  * ``shard_map_spmd``  — real devices along a mesh axis (launch/mine.py).

Host control plane between the phases (sampling merge, Partition+LPT,
seed construction) is identical for both — exactly what a production launcher
does between collectives.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmap as bm
from repro.core import eclat, mfi, pbec, phases, sampling, schedule
from repro.obs import metrics as obs_metrics
from repro.obs import profile as obs_profile
from repro.obs import progress as obs_progress
from repro.obs import trace as obs_trace

_U32 = jnp.uint32


@dataclasses.dataclass(frozen=True)
class FimiParams:
    """User-facing knobs (thesis Ch. 8 inputs)."""

    variant: str = "reservoir"          # "seq" | "par" | "reservoir"
    min_support_rel: float = 0.1        # min_support*
    eps_db: float = 0.05                # ε_D̃   (Thm 6.1)
    delta_db: float = 0.1               # δ_D̃
    eps_fs: float = 0.05                # ε_F̃s  (Thm 6.2/6.3)
    delta_fs: float = 0.1               # δ_F̃s
    rho: float = 0.01                   # smallest-PBEC relative size
    alpha: float = 0.5                  # Phase-2 granularity
    n_db_sample: Optional[int] = None   # override |D̃| (else from ε,δ)
    n_fi_sample: Optional[int] = None   # override |F̃s|
    scheduler: str = "lpt"              # "lpt" | "repl_min"
    exchange_capacity: Optional[int] = None  # Phase-3 per-(src,dst) row cap
    max_classes: int = 512
    eclat: eclat.EclatConfig = eclat.EclatConfig(max_out=8192, max_stack=2048)
    mfi: mfi.MFIConfig = mfi.MFIConfig(max_out=2048, max_stack=2048)
    support_fn: Optional[Callable] = None   # Phase-4 single-prefix kernel plug-in
    multi_support_fn: Optional[Callable] = None  # Phase-4 fused [K,I] kernel plug-in


@dataclasses.dataclass
class FimiResult:
    sample_masks: np.ndarray            # bool [N, I] — F̃s
    classes: List[pbec.PBEC]
    assignment: np.ndarray              # int [C]
    est_loads: np.ndarray               # float [P] — estimated work shares
    replication: float                  # Phase-3 replication factor
    exchange_overflow: int
    phase4: phases.Phase4Out            # stacked over P
    ancestor_masks: np.ndarray          # bool [A, I]
    ancestor_supports: np.ndarray       # int [A] — global supports
    n_fis: int                          # |F| (classes ∪ frequent ancestors)
    work_iters: np.ndarray              # int [P] — DFS trips per miner
    fi_dict: Optional[Dict] = None      # materialized {frozenset: supp}
    nodes_popped: Optional[np.ndarray] = None  # int [P] — DFS nodes mined
    progress: Optional[obs_progress.ProgressSnapshot] = None  # final snapshot


# ---------------------------------------------------------------------------
# SPMD combinators
# ---------------------------------------------------------------------------

AXIS = "miners"


def vmap_spmd(fn, P: int, mesh=None):
    """Map an SPMD fn over stacked [P, ...] arrays on a single device."""
    return jax.vmap(fn, axis_name=AXIS)


def shard_map_spmd(fn, P: int, mesh):
    """Map over real devices along mesh axis ``AXIS`` (1-D miner mesh).

    shard_map keeps the mapped dim (local size 1) where vmap removes it; the
    squeeze/unsqueeze wrapper gives both combinators identical semantics so
    the phase functions are written once.
    """
    from jax.sharding import PartitionSpec as PS

    def body(*args):
        args = jax.tree.map(lambda a: a.reshape(a.shape[1:]), args)
        out = fn(*args)
        return jax.tree.map(lambda a: jnp.asarray(a)[None], out)

    if hasattr(jax, "shard_map"):  # newer JAX: top-level API, check_vma kwarg
        return jax.shard_map(
            body,
            mesh=mesh,
            in_specs=PS(AXIS),
            out_specs=PS(AXIS),
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        body,
        mesh=mesh,
        in_specs=PS(AXIS),
        out_specs=PS(AXIS),
        check_rep=False,
    )


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def run(
    tx_shards,                # uint32[P, T, IW] shards — or a store.TxStore
    n_items: int,
    params: FimiParams,
    key: jax.Array,
    *,
    spmd=vmap_spmd,
    mesh=None,
    materialize: bool = False,
    P: Optional[int] = None,
    host_budget_blocks: int = 2,
    reader=None,
) -> FimiResult:
    tr = obs_trace.TRACER
    if not hasattr(tx_shards, "shape"):   # a TxStore: mine out-of-core
        from repro.store import reader as store_reader

        if P is None:
            raise ValueError("P (miner count) is required when mining a TxStore")
        if n_items is None:
            n_items = tx_shards.n_items
        # Assemble the device shards block-by-block through the double-
        # buffered reader: host residency stays within the block budget, the
        # device holds only the packed working set, and the result is
        # bit-exact with shard_db(dense, P) — so everything below (sampling
        # included) matches the in-memory path bit for bit.  Drivers pass
        # ``reader`` (a BlockReader on this store) to observe the streamed
        # host high-water mark of this very pass.
        with tr.span("fimi/assemble_store", P=P):
            tx_shards = tr.sync(store_reader.to_device_shards(
                tx_shards, P, host_budget_blocks=host_budget_blocks,
                reader=reader,
            ))
    P, T, IW = tx_shards.shape
    n_tx = P * T
    abs_minsup = int(np.ceil(params.min_support_rel * n_tx))

    n_db = params.n_db_sample or sampling.db_sample_size(
        params.eps_db, params.delta_db
    )
    n_db = min(n_db, n_tx)  # sampling more than |D| adds nothing but cost
    per_proc = max(1, n_db // P)
    n_db = per_proc * P
    n_fs = params.n_fi_sample or sampling.reservoir_sample_size(
        params.eps_fs, params.delta_fs, params.rho
    )

    # ---------------- Phase 1 ------------------------------------------------
    variant_dev = {"seq": "sample", "par": "par", "reservoir": "reservoir"}[
        params.variant
    ]
    p1 = partial(
        phases.phase1_device,
        axis_name=AXIS,
        n_items=n_items,
        n_tx_local=T,
        n_sample_per_proc=per_proc,
        reservoir_size=n_fs if params.variant == "reservoir" else 1,
        eclat_cfg=params.eclat,
        mfi_cfg=params.mfi,
        variant=variant_dev,
    )
    keys = jnp.broadcast_to(key, (P, *key.shape))
    minsup_rel = jnp.broadcast_to(
        jnp.asarray(params.min_support_rel, jnp.float32), (P,)
    )
    with tr.span("fimi/phase1_sample", P=P, variant=params.variant):
        out1 = tr.sync(spmd(p1, P, mesh)(tx_shards, keys, minsup_rel))

    sample_db_rows = np.asarray(jax.device_get(out1.sample_db))[0]  # replicated
    n_samp = sample_db_rows.shape[0]
    sample_minsup = int(np.ceil(params.min_support_rel * n_samp))
    sample_bitdb = bm.rebuild_vertical(
        jnp.asarray(sample_db_rows), n_items, n_samp
    )

    rng = np.random.default_rng(int(jax.random.key_data(key).sum()) & 0x7FFFFFFF)

    if params.variant == "reservoir":
        f_counts = np.asarray(out1.fi_count)
        X = sampling.merge_reservoirs(rng, f_counts, n_fs)
        picked = []
        res_items = np.asarray(out1.reservoir)
        for i in range(P):
            avail = int(min(f_counts[i], n_fs))
            if X[i] == 0 or avail == 0:
                continue
            sel = rng.choice(avail, size=int(min(X[i], avail)), replace=False)
            picked.append(res_items[i][sel])
        fs_packed = (
            np.concatenate(picked, axis=0)
            if picked
            else np.zeros((0, bm.n_words(n_items)), np.uint32)
        )
    elif params.variant == "par":
        m_items = np.asarray(out1.mfi_items)     # [P, Mmax, IW]
        m_counts = np.asarray(out1.mfi_count)
        all_m = [m_items[i, : int(m_counts[i])] for i in range(P)]
        M = (
            np.concatenate(all_m, axis=0)
            if any(len(a) for a in all_m)
            else np.zeros((0, bm.n_words(n_items)), np.uint32)
        )
        # global pick m ∝ 2^|m| ≡ thesis' per-processor s_i/s split (Alg. 13)
        fs_packed = _coverage_sample_host(M, n_fs, n_items, key)
    else:  # "seq": p_1 mines the MFIs of D̃ sequentially (Alg. 12)
        r = mfi.mine_all_candidates(
            sample_bitdb, sample_minsup, config=params.mfi
        )
        n = int(r.n_out)
        valid = np.zeros(r.items.shape[0], bool)
        valid[:n] = True
        keep = np.asarray(mfi.filter_maximal(r.items, jnp.asarray(valid)))
        M = np.asarray(r.items)[keep]
        fs_packed = _coverage_sample_host(M, n_fs, n_items, key)

    sample_masks = np.asarray(
        bm.unpack_bool(jnp.asarray(fs_packed), n_items)
    ).reshape(-1, n_items)
    # coverage samplers can emit ∅/singletons — the partitioner needs |W| ≥ 2
    # consistently with the reservoir stream (see phases.phase1_device).
    sample_masks = sample_masks[sample_masks.sum(axis=1) >= 2]

    # ---------------- Phase 2 ------------------------------------------------
    def ext_supports(prefix: np.ndarray) -> np.ndarray:
        tid = bm.tidlist_of_itemset(sample_bitdb, jnp.asarray(prefix))
        return np.asarray(bm.extension_supports(sample_bitdb.item_bits, tid))

    with tr.span("fimi/phase2_partition", scheduler=params.scheduler):
        classes = pbec.partition(
            sample_masks,
            P,
            params.alpha,
            ext_supports,
            n_items,
            max_classes=params.max_classes,
        )
        # Drop classes whose prefix is infrequent even in the sample: their
        # whole subtree is infrequent w.h.p.; their FIs (if any) are still
        # covered by the ancestor side channel check below only if prefix
        # frequent — so keep all classes to stay exact (the miner prunes cheap
        # infrequent seeds itself).
        sizes = np.array([c.est_count for c in classes], dtype=np.float64)
        if params.scheduler == "repl_min":
            pref_packed, _ = pbec.classes_to_packed(classes)
            tids = np.asarray(
                phases.seed_tidlists(
                    sample_bitdb.item_bits,
                    jnp.asarray(np.stack([c.prefix for c in classes])),
                    sample_bitdb.all_tids(),
                )
            )
            profit = schedule.pairwise_shared_transactions(tids)
            # no tidlists: the volume report (NaN then) is unused here
            assignment = schedule.db_repl_min(sizes, profit, P).assignment
        else:
            assignment = schedule.lpt_schedule(sizes, P)
        est_loads = schedule.loads_of(sizes, assignment, P)

    # ---------------- Phase 3 ------------------------------------------------
    C = len(classes)
    pref_packed, _ = pbec.classes_to_packed(classes)
    cap = params.exchange_capacity or T
    p3 = partial(phases.phase3_exchange, axis_name=AXIS, capacity=cap)
    local_valid = jnp.ones((P, T), jnp.bool_)
    class_prefix_b = jnp.broadcast_to(
        jnp.asarray(pref_packed), (P, C, pref_packed.shape[-1])
    )
    class_valid_b = jnp.ones((P, C), jnp.bool_)
    class_assign_b = jnp.broadcast_to(jnp.asarray(assignment, jnp.int32), (P, C))
    with tr.span("fimi/phase3_exchange", C=C):
        out3 = tr.sync(spmd(p3, P, mesh)(
            tx_shards, local_valid, class_prefix_b, class_valid_b,
            class_assign_b,
        ))

    # ---------------- Phase 4 ------------------------------------------------
    Cmax = max(int((assignment == p).sum()) for p in range(P))
    Cmax = max(Cmax, 1)
    seed_prefix = np.zeros((P, Cmax, n_items), dtype=bool)
    seed_ext = np.zeros((P, Cmax, n_items), dtype=bool)
    seed_valid = np.zeros((P, Cmax), dtype=bool)
    for p in range(P):
        mine_ids = np.nonzero(assignment == p)[0]
        for j, cid in enumerate(mine_ids):
            seed_prefix[p, j] = classes[cid].prefix
            seed_ext[p, j] = classes[cid].ext
            seed_valid[p, j] = True

    # ancestor side channel: every DFS-path prefix of every class, dedup'd
    ancestor_masks, anc_list = pbec.ancestor_closure(classes, n_items)
    A = ancestor_masks.shape[0]

    p4 = partial(
        phases.phase4_mine,
        axis_name=AXIS,
        n_items=n_items,
        eclat_cfg=params.eclat,
        support_fn=params.support_fn,
        multi_support_fn=params.multi_support_fn,
    )
    keys4 = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(P))
    slab = out3.slab.reshape(P, -1, IW) if out3.slab.ndim == 2 else out3.slab
    progress = obs_progress.ProgressEstimator(est_loads)
    progress.start()
    mine_t0 = time.perf_counter()
    with tr.span("fimi/phase4_mine", Cmax=Cmax, A=A):
        out4 = spmd(p4, P, mesh)(
            slab,
            out3.slab_valid.reshape(P, -1),
            tx_shards,
            local_valid,
            jnp.asarray(seed_prefix),
            jnp.asarray(seed_ext),
            jnp.asarray(seed_valid),
            jnp.broadcast_to(jnp.asarray(ancestor_masks), (P, A, n_items)),
            jnp.broadcast_to(jnp.asarray(abs_minsup, jnp.int32), (P,)),
            keys4,
        )
        out4 = jax.block_until_ready(out4)
    mine_s = time.perf_counter() - mine_t0
    trips_arr = np.asarray(out4.work_iters).astype(np.float64).reshape(-1)
    # Loop-attributed kernel work: the multi-support sweep executes inside
    # the compiled Eclat while_loop once per DFS trip (the ops wrapper only
    # sees the trace-time dispatch); shapes come from the mined slab.
    if obs_profile.PROFILER.enabled:
        obs_profile.PROFILER.observe_loop(
            "multi",
            {
                "K": max(1, int(params.eclat.frontier_size)),
                "I": n_items,
                "W": (int(slab.shape[1]) + 31) // 32,
            },
            n_exec=int(trips_arr.sum()),
            wall_s=mine_s,
        )
    # One-shot pipeline: the single update closes the progress record with
    # the trip-grounded straggler scores (Thm 6.1 estimate vs observation).
    final_progress = progress.update(est_loads, trips_arr)
    progress.finish()

    anc_supports = np.asarray(out4.prefix_supports)[0]  # identical on all p
    anc_frequent = int((anc_supports >= abs_minsup).sum()) if anc_list else 0
    n_fis = int(np.asarray(out4.fi_total).sum()) + anc_frequent

    result = FimiResult(
        sample_masks=sample_masks,
        classes=classes,
        assignment=assignment,
        est_loads=est_loads,
        replication=float(np.asarray(out3.replication).reshape(-1)[0]),
        exchange_overflow=int(np.asarray(out3.overflow).reshape(-1)[0]),
        phase4=out4,
        ancestor_masks=ancestor_masks[: len(anc_list)],
        ancestor_supports=anc_supports[: len(anc_list)],
        n_fis=n_fis,
        work_iters=np.asarray(out4.work_iters),
        nodes_popped=np.asarray(out4.nodes_popped).reshape(-1),
        progress=final_progress,
    )
    _emit_run_metrics(result, params, P)
    if materialize:
        result.fi_dict = materialize_fis(result, n_items, abs_minsup)
    return result


def _emit_run_metrics(result: FimiResult, params: FimiParams, P: int) -> None:
    """Publish one pipeline pass into the process-global metrics registry.

    Emits the estimated-vs-observed load story the thesis' Phase 2 is judged
    by: per-shard estimated share (PBEC sizes via the scheduler) next to the
    observed DFS-trip share, their max absolute gap as a gauge, and the
    frontier occupancy (nodes actually popped per trip slot) as a histogram.
    """
    reg = obs_metrics.registry()
    trips = result.work_iters.astype(np.float64).reshape(-1)
    est = result.est_loads.astype(np.float64).reshape(-1)
    reg.counter("fimi/runs").inc()
    reg.counter("fimi/trips").inc(int(trips.sum()))
    reg.counter("fimi/exchange_overflow").inc(result.exchange_overflow)
    reg.gauge("fimi/n_fis").set(float(result.n_fis))
    reg.gauge("fimi/n_classes").set(float(len(result.classes)))
    reg.gauge("fimi/replication").set(float(result.replication))
    est_share = est / est.sum() if est.sum() > 0 else np.full(P, 1.0 / P)
    obs_share = trips / trips.sum() if trips.sum() > 0 else np.full(P, 1.0 / P)
    reg.gauge("fimi/load/estimation_error").set(
        float(np.abs(est_share - obs_share).max())
    )
    occ = reg.histogram("fimi/frontier_occupancy")
    K = max(1, int(params.eclat.frontier_size))
    popped = (
        result.nodes_popped.astype(np.float64).reshape(-1)
        if result.nodes_popped is not None
        else None
    )
    for p in range(P):
        reg.gauge(f"fimi/shard{p}/est_load").set(float(est[p]))
        reg.gauge(f"fimi/shard{p}/obs_trips").set(float(trips[p]))
        if popped is not None and trips[p] > 0:
            occ.record(float(popped[p]) / (trips[p] * K))


def _coverage_sample_host(M: np.ndarray, n_fs: int, n_items: int, key) -> np.ndarray:
    if len(M) == 0:
        return np.zeros((0, M.shape[-1] if M.ndim == 2 else bm.n_words(n_items)), np.uint32)
    valid = jnp.ones((len(M),), jnp.bool_)
    # oversample: ∅/singletons get filtered downstream
    samp = sampling.modified_coverage_sample(
        key, jnp.asarray(M), valid, int(n_fs * 1.3) + 8, n_items
    )
    return np.asarray(samp)


def materialize_fis(result: FimiResult, n_items: int, abs_minsup: int) -> Dict:
    """Collect the distributed result into {frozenset: support} (tests only)."""
    out: Dict = {}
    items = np.asarray(result.phase4.fi_items)
    supps = np.asarray(result.phase4.fi_supports)
    counts = np.asarray(result.phase4.fi_count)
    P = items.shape[0]
    for p in range(P):
        for k in range(int(counts[p])):
            mask = np.asarray(bm.unpack_bool(jnp.asarray(items[p, k]), n_items))
            out[frozenset(np.nonzero(mask)[0].tolist())] = int(supps[p, k])
    for mask, s in zip(result.ancestor_masks, result.ancestor_supports):
        if s >= abs_minsup:
            out[frozenset(np.nonzero(mask)[0].tolist())] = int(s)
    return out


def shard_db(db_dense: np.ndarray, P: int) -> jnp.ndarray:
    """Split a dense bool DB row-wise into P packed shards [P, T, IW]."""
    n_tx, n_items = db_dense.shape
    T = n_tx // P
    rows = db_dense[: T * P].reshape(P, T, n_items)
    return bm.pack_bool(jnp.asarray(rows))
