"""Prefix-based equivalence classes: membership, size estimation, Partition.

Thesis §2.4 (Defs 2.20/2.21, Props 2.22/2.23) and Phase 2 (Alg. 15/17).

A PBEC is stored as a pair of bool masks ``(prefix, ext)`` over the base set.
With the recursive construction of Prop. 2.23, ``[U|Σ] = {U ∪ Y : ∅ ≠ Y ⊆ Σ}``
— membership is three bitwise tests, independent of item order (each node may
re-order its extensions; the classes stay disjoint).

Phase-2 partitioning/scheduling is host-side control-plane code (numpy): it
sees only the *sample* F̃s (thousands of packed masks), runs once per job, and
its output (the PBEC table + assignment) is broadcast — exactly how a real
launcher treats a scheduler.  Device code (estimation counts) stays in jnp.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Sequence, Tuple

import numpy as np

import jax.numpy as jnp

from repro.core import bitmap as bm


@dataclasses.dataclass
class PBEC:
    prefix: np.ndarray       # bool [I]
    ext: np.ndarray          # bool [I]
    est_count: float         # |[U|Σ] ∩ F̃s| (absolute sample count)
    seq: Tuple[int, ...] = ()  # order in which prefix items were added (DFS path)

    @property
    def depth(self) -> int:
        return int(self.prefix.sum())


def member_mask(
    sample_masks: np.ndarray,  # bool [N, I]
    prefix: np.ndarray,
    ext: np.ndarray,
) -> np.ndarray:
    """bool [N]: which sample itemsets lie in [prefix | ext]."""
    has_prefix = ~(prefix[None, :] & ~sample_masks).any(axis=1)
    inside = ~(sample_masks & ~(prefix | ext)[None, :]).any(axis=1)
    proper = (sample_masks & ~prefix[None, :]).any(axis=1)  # exclude W == U
    return has_prefix & inside & proper


def estimate_size(sample_masks: np.ndarray, prefix, ext) -> int:
    return int(member_mask(sample_masks, prefix, ext).sum())


SupportFn = Callable[[np.ndarray], np.ndarray]
# maps a prefix bool[I] -> supports of prefix ∪ {b} for all b, int[I]


def partition(
    sample_masks: np.ndarray,      # bool [N, I] — the F̃s sample
    n_processors: int,
    alpha: float,
    ext_supports: SupportFn,
    n_items: int,
    max_classes: int = 4096,
) -> List[PBEC]:
    """Alg. 17 (Phase-2-FI-Partitioning) + Alg. 15 (Partition).

    Starts from the 1-prefix classes [{b}|{b'>b}], recursively splits any class
    whose estimated relative size exceeds ``α/P``, ordering each split's
    extensions by support in D̃ ascending (§B.4.2 dynamic re-ordering — the
    order the Phase-4 sequential miner will use).
    """
    N = max(len(sample_masks), 1)
    threshold = alpha * N / n_processors
    I = n_items

    # Initial split of the root: order items by support ascending (the same
    # rule Partition applies recursively), then Σ_k = items after b_k.
    root_supp = ext_supports(np.zeros(I, dtype=bool))
    order = np.argsort(root_supp, kind="stable")
    classes: List[PBEC] = []
    work: List[PBEC] = []
    for pos, b in enumerate(order):
        prefix = np.zeros(I, dtype=bool)
        prefix[b] = True
        ext = np.zeros(I, dtype=bool)
        ext[order[pos + 1:]] = True
        s = estimate_size(sample_masks, prefix, ext)
        # the singleton {b} itself belongs to this processor's share
        s_with_self = s + int(
            member_self(sample_masks, prefix)
        )
        work.append(PBEC(prefix, ext, s_with_self, seq=(int(b),)))

    while work:
        c = work.pop()
        if c.est_count <= threshold or not c.ext.any() or (
            len(classes) + len(work) >= max_classes
        ):
            classes.append(c)
            continue
        # Alg. 15: split [U|Σ] on U∪{b}, b ∈ Σ in ascending-support order.
        supp = ext_supports(c.prefix)
        ext_items = np.nonzero(c.ext)[0]
        ext_sorted = ext_items[np.argsort(supp[ext_items], kind="stable")]
        for pos, b in enumerate(ext_sorted):
            prefix = c.prefix.copy()
            prefix[b] = True
            ext = np.zeros(I, dtype=bool)
            ext[ext_sorted[pos + 1:]] = True
            s = estimate_size(sample_masks, prefix, ext)
            s += int(member_self(sample_masks, prefix))
            work.append(PBEC(prefix, ext, s, seq=c.seq + (int(b),)))
        # Note: the parent prefix U itself ({V} in Prop. 2.23) stays with the
        # processor that gets the first child; its weight is 1 sample at most
        # and Phase 4 computes prefix supports separately (Alg. 19 line 2).
    return classes


def member_self(sample_masks: np.ndarray, prefix: np.ndarray) -> int:
    """# sample itemsets exactly equal to the prefix."""
    return int((sample_masks == prefix[None, :]).all(axis=1).sum())


def verify_disjoint_cover(
    classes: Sequence[PBEC], n_items: int, universe_masks: np.ndarray
) -> Tuple[bool, bool]:
    """Property check: classes are pairwise disjoint and cover every non-empty
    itemset except bare prefixes' strict subsets... precisely: every itemset in
    ``universe_masks`` (bool [N, I], non-empty) is in exactly one class OR is
    equal to some class prefix's proper prefix chain.

    Returns (disjoint, covered) summary booleans; used by hypothesis tests.
    """
    N = len(universe_masks)
    counts = np.zeros(N, dtype=np.int64)
    for c in classes:
        counts += member_mask(universe_masks, c.prefix, c.ext).astype(np.int64)
    # itemsets equal to a prefix of one of the classes (or an ancestor on its
    # DFS path) are scheduled with the prefix-support side channel (Phase 4
    # line 2), not via a class.
    closure = _prefix_closure([c.seq for c in classes])
    is_prefix = np.array(
        [frozenset(np.nonzero(m)[0].tolist()) in closure for m in universe_masks]
    )
    disjoint = bool((counts <= 1).all())
    covered = bool(((counts == 1) | is_prefix).all())
    return disjoint, covered


def _prefix_closure(seqs):
    """All ancestors along each class' DFS path, as frozensets of items."""
    out = set()
    for seq in seqs:
        for k in range(1, len(seq) + 1):
            out.add(frozenset(seq[:k]))
    return out


def ancestor_closure(
    classes: Sequence[PBEC], n_items: int
) -> Tuple[np.ndarray, List[frozenset]]:
    """The prefix side-channel itemsets of a class table (Alg. 19 line 2).

    Every DFS-path prefix of every class, dedup'd and ordered by
    (size, lexicographic) for determinism.  Returns ``(masks bool [A, I],
    list of frozensets)`` with A ≥ 1 (a zero row pads the empty case so
    device shapes stay static).
    """
    anc_list = sorted(
        _prefix_closure([c.seq for c in classes]),
        key=lambda s: (len(s), tuple(sorted(s))),
    )
    A = max(len(anc_list), 1)
    masks = np.zeros((A, n_items), dtype=bool)
    for i, s in enumerate(anc_list):
        masks[i, sorted(s)] = True
    return masks, anc_list


def classes_to_packed(classes: Sequence[PBEC]) -> Tuple[np.ndarray, np.ndarray]:
    """Stack class masks into packed uint32 arrays [C, IW] for device use."""
    prefixes = np.stack([c.prefix for c in classes])
    exts = np.stack([c.ext for c in classes])
    return (
        np.asarray(bm.pack_bool(jnp.asarray(prefixes))),
        np.asarray(bm.pack_bool(jnp.asarray(exts))),
    )
