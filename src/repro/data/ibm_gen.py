"""IBM-Quest-style synthetic transaction database generator.

The thesis evaluates on databases produced by the IBM generator, named
``T<tx/1000>I<items/1000>P<patterns>PL<pattern_len>TL<tx_len>`` (§11.2), e.g.
``T500I0.1P50PL10TL40`` = 500k transactions, 100 items, 50 patterns of average
length 10, average transaction length 40.

This is a faithful, deterministic numpy re-implementation of the generator's
core mechanism (Agrawal & Srikant '94): draw a pool of "potentially frequent"
patterns with Poisson lengths and exponentially-decaying weights, then build
each transaction as a union of weighted-sampled patterns (with per-item
corruption) until the target transaction length is reached.
"""
from __future__ import annotations

import dataclasses
import re

import numpy as np


@dataclasses.dataclass(frozen=True)
class IBMParams:
    n_tx: int = 2000
    n_items: int = 100
    n_patterns: int = 50
    avg_pattern_len: float = 10.0
    avg_tx_len: float = 40.0
    corruption: float = 0.5
    seed: int = 0

    @property
    def name(self) -> str:
        def fmt(x: float) -> str:
            s = f"{x:g}"
            return s

        return (
            f"T{fmt(self.n_tx / 1000)}I{fmt(self.n_items / 1000)}"
            f"P{self.n_patterns}PL{fmt(self.avg_pattern_len)}TL{fmt(self.avg_tx_len)}"
        )


_NAME_RE = re.compile(
    r"T(?P<t>[\d.]+)I(?P<i>[\d.]+)P(?P<p>\d+)PL(?P<pl>[\d.]+)TL(?P<tl>[\d.]+)"
)


def params_from_name(name: str, seed: int = 0) -> IBMParams:
    """Parse a thesis-style database name into generator params."""
    m = _NAME_RE.fullmatch(name)
    if not m:
        raise ValueError(f"not a T..I..P..PL..TL.. database name: {name!r}")
    return IBMParams(
        n_tx=int(float(m["t"]) * 1000),
        n_items=max(int(float(m["i"]) * 1000), 1),
        n_patterns=int(m["p"]),
        avg_pattern_len=float(m["pl"]),
        avg_tx_len=float(m["tl"]),
        seed=seed,
    )


@dataclasses.dataclass(frozen=True)
class PatternPool:
    """The generator's latent state: what counts as a "frequent pattern".

    Re-drawing the pool while keeping the item universe IS concept drift —
    the mechanism :func:`drifting_stream` uses to script drift scenarios.
    """

    patterns: list          # list[np.int64 array] — the potential FIs
    weights: np.ndarray     # float [P] — normalized pattern popularity
    corruption: np.ndarray  # float [P] — per-pattern item-drop rate


def _draw_pattern_pool(rng: np.random.Generator, params: IBMParams) -> PatternPool:
    """Draw a fresh pool of potentially-frequent patterns."""
    I, P = params.n_items, params.n_patterns
    # Pattern lengths ~ Poisson(avg_pattern_len), at least 1, at most n_items.
    plens = np.clip(rng.poisson(params.avg_pattern_len, P), 1, I)
    # Item popularity is skewed (Zipf-ish) as in the original generator.
    item_w = 1.0 / np.arange(1, I + 1)
    item_w /= item_w.sum()
    patterns = []
    prev: np.ndarray | None = None
    for k in range(P):
        L = int(plens[k])
        # Successive patterns share items (generator's "correlation"): take half
        # from the previous pattern when possible.
        take_prev = 0
        base: list[int] = []
        if prev is not None and len(prev) > 1:
            take_prev = min(L // 2, len(prev))
            base = list(rng.choice(prev, size=take_prev, replace=False))
        rest = rng.choice(I, size=I, replace=False, p=None)
        for it in rest:
            if len(base) >= L:
                break
            if it not in base:
                base.append(int(it))
        patterns.append(np.array(sorted(base[:L]), dtype=np.int64))
        prev = patterns[-1]

    # Pattern weights: exponential decay, normalized (original: exp. distributed).
    pw = rng.exponential(1.0, P)
    pw /= pw.sum()
    # Per-pattern corruption level.
    corr = np.clip(rng.normal(params.corruption, 0.1, P), 0.0, 0.95)
    return PatternPool(patterns=patterns, weights=pw, corruption=corr)


def _emit_transactions(
    rng: np.random.Generator, params: IBMParams, pool: PatternPool, n_tx: int
) -> np.ndarray:
    """Emit ``n_tx`` transactions from a pattern pool: dense bool [n_tx, I]."""
    I, P = params.n_items, params.n_patterns
    tlens = np.clip(rng.poisson(params.avg_tx_len, n_tx), 1, I)
    dense = np.zeros((n_tx, I), dtype=bool)
    pat_choices = rng.choice(P, size=(n_tx, 8), p=pool.weights)
    for t in range(n_tx):
        target = int(tlens[t])
        got = 0
        for k in pat_choices[t]:
            if got >= target:
                break
            pat = pool.patterns[k]
            keep = rng.random(len(pat)) >= pool.corruption[k]
            kept = pat[keep]
            dense[t, kept] = True
            got = int(dense[t].sum())
        if got == 0:  # guarantee non-empty transactions
            dense[t, rng.integers(0, I)] = True
    return dense


def generate_blocks(params: IBMParams, block_tx: int):
    """Yield the database as dense bool blocks ``[≤block_tx, n_items]``.

    The O(block) generation path: each block's RNG draws (lengths, pattern
    picks, corruption) happen when the block is emitted, so peak host
    residency is one block — never the full ``[N, I]`` matrix.  The
    store spill (``repro.store.write_ibm_store``) packs each block as it
    lands, keeping generate→pack→disk O(block) end to end.

    Deterministic under ``params.seed``.  With ``block_tx >= n_tx`` the
    single emitted block is bit-identical to :func:`generate_dense`; for
    smaller blocks the draw *order* differs (per-block instead of whole-DB
    batching), so a blocked database is its own deterministic dataset, not
    a re-chunking of the unblocked one.
    """
    if block_tx <= 0:
        raise ValueError(f"block_tx must be positive (got {block_tx})")
    rng = np.random.default_rng(params.seed)
    pool = _draw_pattern_pool(rng, params)
    done = 0
    while done < params.n_tx:
        b = min(block_tx, params.n_tx - done)
        yield _emit_transactions(rng, params, pool, b)
        done += b


def generate_dense(params: IBMParams) -> np.ndarray:
    """Generate a dense bool transaction matrix ``[n_tx, n_items]``.

    One-shot emission (a single :func:`generate_blocks` block), bit-exact
    with every previous release.  For databases that should never be
    resident at once, spill blocks to disk instead:
    ``repro.store.write_ibm_store(params, dir, block_tx)``.
    """
    if params.n_tx == 0:
        return np.zeros((0, params.n_items), dtype=bool)
    return next(generate_blocks(params, params.n_tx))


def drifting_stream(
    params: IBMParams,
    *,
    n_blocks: int,
    block_tx: int,
    breaks: tuple = (),
):
    """Yield a concept-drifting transaction stream, block by block.

    Yields ``(dense_block [block_tx, n_items], segment_id)`` for
    ``n_blocks`` blocks.  At every block index listed in ``breaks`` the
    pattern pool is **re-drawn** (fresh patterns, weights, and corruption
    over the same item universe) — an abrupt concept drift: itemsets
    frequent under the old pool lose their generating patterns while new
    co-occurrences appear.  ``segment_id`` counts the pool in force (0 =
    initial), so drivers and tests can align observed re-mines with
    scripted drift.

    Deterministic under ``params.seed``: one host RNG drives pool draws and
    emission in sequence, so the same (params, n_blocks, block_tx, breaks)
    always replays the same stream.
    """
    rng = np.random.default_rng(params.seed)
    pool = _draw_pattern_pool(rng, params)
    bset = {int(b) for b in breaks}
    segment = 0
    for b in range(n_blocks):
        if b in bset:
            # a break at 0 re-draws over the initial pool, as documented —
            # segment ids then start at 1
            pool = _draw_pattern_pool(rng, params)
            segment += 1
        yield _emit_transactions(rng, params, pool, block_tx), segment


def generate(params: IBMParams):
    """Generate and return a ``BitmapDB`` (imported lazily to avoid jax at import)."""
    from repro.core.bitmap import BitmapDB

    return BitmapDB.from_dense(generate_dense(params))
