"""Deterministic, shardable, checkpointable LM token pipeline.

Synthetic corpus (offline container), but with the production contracts that
matter for fault tolerance and scale:

  * **stateless addressing** — batch ``i`` of host ``h`` is a pure function of
    (seed, step, host); any worker can reproduce any batch, so restarts and
    elastic re-sharding replay the exact stream (no data loss/duplication).
  * **checkpointable state** — the pipeline state is just ``step`` (+seed),
    stored in the checkpoint manifest.
  * **LPT length-bucketing** (paper bridge, DESIGN.md §Arch-applicability):
    documents are packed into fixed-length rows by assigning sampled document
    lengths to rows with the same Graham LPT rule Phase 2 uses for PBECs —
    balancing padding waste across the batch.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.core.schedule import lpt_schedule


@dataclasses.dataclass
class PipelineState:
    seed: int
    step: int


class SyntheticLM:
    """Markov-ish synthetic token stream with document structure."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, n_hosts: int = 1, host_id: int = 0,
                 mean_doc_len: int = 256):
        assert global_batch % n_hosts == 0
        self.vocab = vocab
        self.seq_len = seq_len
        self.local_batch = global_batch // n_hosts
        self.host_id = host_id
        self.mean_doc_len = mean_doc_len
        self.state = PipelineState(seed=seed, step=0)

    # -- stateless batch addressing -------------------------------------------
    def _rng_for(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.state.seed * 1_000_003 + step) * 631 + self.host_id
        )

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = self._rng_for(step)
        B, S = self.local_batch, self.seq_len
        # documents: lengths ~ clipped exponential; LPT-pack into B rows
        n_docs = max(B * S // self.mean_doc_len, B)
        lens = np.clip(
            rng.exponential(self.mean_doc_len, n_docs).astype(int), 16, S
        )
        rows = lpt_schedule(lens, B)
        tokens = np.zeros((B, S), dtype=np.int32)
        mask = np.zeros((B, S), dtype=bool)
        fill = np.zeros(B, dtype=int)
        for d in np.argsort(-lens, kind="stable"):
            r = rows[d]
            L = int(min(lens[d], S - fill[r]))
            if L <= 0:
                continue
            # order-2 markov-ish: mixture of a doc-level bias + noise
            base = rng.integers(0, self.vocab)
            seq = (base + np.cumsum(rng.integers(0, 17, L))) % self.vocab
            tokens[r, fill[r] : fill[r] + L] = seq
            mask[r, fill[r] : fill[r] + L] = True
            fill[r] += L
        return {"tokens": tokens, "loss_mask": mask[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()

    def next_batch(self) -> Dict[str, np.ndarray]:
        b = self.batch_at(self.state.step)
        self.state.step += 1
        return b

    # -- checkpoint plumbing ----------------------------------------------------
    def state_dict(self) -> Dict:
        return dataclasses.asdict(self.state)

    def load_state_dict(self, d: Dict):
        self.state = PipelineState(**d)
