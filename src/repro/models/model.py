"""Unified LM: five families behind one functional interface.

  * ``dense`` / ``moe`` / ``vlm`` / ``audio-decoder`` — DecoderLM (GQA or MLA
    attention, dense-MLP or MoE FFN), scan-over-layers.
  * ``ssm``     — Mamba-2 (SSD) stack.
  * ``hybrid``  — Jamba-style period-``attn_every`` super-blocks (1 attention +
    N-1 mamba sublayers, MoE on alternate sublayers), scan over super-blocks.
  * ``encdec``  — Whisper-style encoder–decoder (frontend stubbed: the caller
    provides frame embeddings).

Interface (all pure functions of (config, params, ...)):
  ``specs(cfg)`` → ParamSpec tree;  ``init/abstract/axes`` derive from it.
  ``forward(cfg, params, batch)`` → logits           (train / prefill)
  ``init_cache(cfg, batch, max_len, dtype)``         (decode state)
  ``decode_step(cfg, params, cache, tokens, pos)`` → (logits, cache)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba2 as m2
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models.layers import (
    ParamSpec,
    abstract_params,
    axes_tree,
    cross_entropy,
    init_params,
    mlp_forward,
    mlp_specs,
    param_count,
    rms_norm,
    stacked,
    swiglu,
)


# ---------------------------------------------------------------------------
# Spec trees
# ---------------------------------------------------------------------------


def _mixer_specs(cfg: ModelConfig) -> Dict[str, Any]:
    if cfg.mla is not None:
        return mla_mod.mla_specs(cfg.d_model, cfg.n_heads, cfg.mla)
    return attn.attn_specs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd)


def _ffn_specs(cfg: ModelConfig) -> Dict[str, Any]:
    if cfg.moe and cfg.moe.n_experts:
        return moe_mod.moe_specs(cfg.d_model, cfg.moe)
    return mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp_type)


def _decoder_block_specs(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "ln1": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "attn": _mixer_specs(cfg),
        "ln2": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "mlp": _ffn_specs(cfg),
    }


def _ssm_block_specs(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "ln": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "mixer": m2.mamba2_specs(cfg.d_model, cfg.ssm),
    }


def _hybrid_superblock_specs(cfg: ModelConfig) -> Dict[str, Any]:
    period = cfg.attn_every
    n_moe = period // 2
    n_dense = period - n_moe
    return {
        "attn_ln": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "attn": attn.attn_specs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd),
        "mamba_ln": stacked(
            {"g": ParamSpec((cfg.d_model,), ("embed",), init="ones")}, period - 1
        )["g"],
        "mamba": stacked(m2.mamba2_specs(cfg.d_model, cfg.ssm), period - 1),
        "mlp_ln": stacked(
            {"g": ParamSpec((cfg.d_model,), ("embed",), init="ones")}, n_dense
        )["g"],
        "mlp": stacked(mlp_specs(cfg.d_model, cfg.d_ff), n_dense),
        "moe_ln": stacked(
            {"g": ParamSpec((cfg.d_model,), ("embed",), init="ones")}, n_moe
        )["g"],
        "moe": stacked(moe_mod.moe_specs(cfg.d_model, cfg.moe), n_moe),
    }


def _encdec_block_specs(cfg: ModelConfig, cross: bool) -> Dict[str, Any]:
    s = {
        "ln1": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "attn": attn.attn_specs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd),
        "ln2": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "mlp": mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp_type),
    }
    if cross:
        s["ln_x"] = ParamSpec((cfg.d_model,), ("embed",), init="ones")
        s["xattn"] = attn.attn_specs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd)
    return s


def specs(cfg: ModelConfig) -> Dict[str, Any]:
    V, d = cfg.vocab_padded, cfg.d_model
    tree: Dict[str, Any] = {
        "embed": ParamSpec((V, d), ("vocab", "embed"), scale=0.02),
        "final_norm": ParamSpec((d,), ("embed",), init="ones"),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = ParamSpec((d, V), ("embed", "vocab"))
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        tree["blocks"] = stacked(_decoder_block_specs(cfg), cfg.n_layers)
    elif cfg.family == "ssm":
        tree["blocks"] = stacked(_ssm_block_specs(cfg), cfg.n_layers)
    elif cfg.family == "hybrid":
        assert cfg.n_layers % cfg.attn_every == 0
        tree["blocks"] = stacked(
            _hybrid_superblock_specs(cfg), cfg.n_layers // cfg.attn_every
        )
    elif cfg.family == "encdec":
        tree["enc_blocks"] = stacked(
            _encdec_block_specs(cfg, cross=False), cfg.n_enc_layers
        )
        tree["enc_norm"] = ParamSpec((d,), ("embed",), init="ones")
        tree["blocks"] = stacked(_encdec_block_specs(cfg, cross=True), cfg.n_layers)
    else:
        raise ValueError(cfg.family)
    return tree


def init(cfg: ModelConfig, key: jax.Array):
    return init_params(specs(cfg), key, dtype=jnp.dtype(cfg.param_dtype))


def abstract(cfg: ModelConfig):
    return abstract_params(specs(cfg), dtype=jnp.dtype(cfg.param_dtype))


def axes(cfg: ModelConfig):
    return axes_tree(specs(cfg))


def n_params(cfg: ModelConfig) -> int:
    return param_count(specs(cfg))


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "block":
        return jax.checkpoint(fn, prevent_cse=False)
    return fn


def _decoder_block(cfg: ModelConfig, p, x, chunk):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        x = x + mla_mod.mla_forward(p["attn"], h, cfg.mla, chunk=chunk)
    else:
        x = x + attn.gqa_forward(p["attn"], h, cfg.rope_theta, chunk=chunk)
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.moe and cfg.moe.n_experts:
        y, _aux = moe_mod.moe_forward(p["mlp"], h, cfg.moe)
        x = x + y
    else:
        x = x + mlp_forward(h, p["mlp"])
    return x


def _ssm_block(cfg: ModelConfig, p, x):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    return x + m2.mamba2_forward(p["mixer"], h, cfg.ssm)


def _hybrid_superblock(cfg: ModelConfig, p, x, chunk):
    # Each SUBLAYER is checkpointed individually: with only superblock-level
    # remat, the backward pass keeps all 7 mamba sublayers' SSD residuals
    # alive simultaneously (~5 GB each on Jamba-398B) — sublayer remat keeps
    # one alive at a time.
    period = cfg.attn_every

    def _attn_sub(p_, h_):
        a = rms_norm(h_, p_["attn_ln"], cfg.norm_eps)
        return attn.gqa_forward(p_["attn"], a, cfg.rope_theta, chunk=chunk)

    def _mamba_sub(sub, ln, h_):
        a = rms_norm(h_, ln, cfg.norm_eps)
        return m2.mamba2_forward(sub, a, cfg.ssm)

    def _moe_sub(sub, ln, h_):
        a = rms_norm(h_, ln, cfg.norm_eps)
        y, _aux = moe_mod.moe_forward(sub, a, cfg.moe)
        return y

    def _mlp_sub(sub, ln, h_):
        a = rms_norm(h_, ln, cfg.norm_eps)
        return mlp_forward(a, sub)

    ck = (lambda f: jax.checkpoint(f, prevent_cse=False)) if cfg.remat == "block" else (lambda f: f)
    _attn_sub, _mamba_sub = ck(_attn_sub), ck(_mamba_sub)
    _moe_sub, _mlp_sub = ck(_moe_sub), ck(_mlp_sub)

    mi, di, oi = 0, 0, 0
    for i in range(period):
        if i == 0:
            x = x + _attn_sub(p, x)
        else:
            sub = jax.tree.map(lambda a: a[mi], p["mamba"])
            x = x + _mamba_sub(sub, p["mamba_ln"][mi], x)
            mi += 1
        if i % 2 == 1:
            sub = jax.tree.map(lambda a: a[oi], p["moe"])
            x = x + _moe_sub(sub, p["moe_ln"][oi], x)
            oi += 1
        else:
            sub = jax.tree.map(lambda a: a[di], p["mlp"])
            x = x + _mlp_sub(sub, p["mlp_ln"][di], x)
            di += 1
    return x


def _constrain(x, act_spec):
    if act_spec is not None:
        return jax.lax.with_sharding_constraint(x, act_spec)
    return x


def _run_stack(cfg: ModelConfig, blocks, x, body, act_spec=None,
               body_has_remat=False):
    if not body_has_remat:  # hybrid super-blocks checkpoint per SUBLAYER
        body = _maybe_remat(body, cfg)

    def step(h, p):
        return _constrain(body(p, h), act_spec), None

    x, _ = jax.lax.scan(step, x, blocks)
    return x


def _encoder(cfg: ModelConfig, params, frames, chunk=None, act_spec=None):
    def body(p, h):
        a = rms_norm(h, p["ln1"], cfg.norm_eps)
        h = h + attn.bidir_attention(p["attn"], a, cfg.rope_theta, chunk=chunk)
        a = rms_norm(h, p["ln2"], cfg.norm_eps)
        return h + mlp_forward(a, p["mlp"])

    x = _run_stack(cfg, params["enc_blocks"], frames, body, act_spec)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def hidden_states(
    cfg: ModelConfig,
    params,
    batch: Dict[str, jnp.ndarray],
    chunk: Optional[int] = None,
    act_spec=None,
) -> jnp.ndarray:
    """Embed inputs and run the stack; returns final hidden [B, T, d]."""
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.family == "vlm":
        x = jnp.concatenate([batch["vision_embeds"].astype(x.dtype), x], axis=1)
    x = _constrain(x, act_spec)
    if cfg.family == "encdec":
        enc = _encoder(cfg, params, batch["frames"].astype(x.dtype), chunk,
                       act_spec)

        def body(p, h):
            a = rms_norm(h, p["ln1"], cfg.norm_eps)
            h = h + attn.gqa_forward(p["attn"], a, cfg.rope_theta, chunk=chunk)
            a = rms_norm(h, p["ln_x"], cfg.norm_eps)
            h = h + attn.cross_attention(p["xattn"], a, enc)
            a = rms_norm(h, p["ln2"], cfg.norm_eps)
            return h + mlp_forward(a, p["mlp"])

        x = _run_stack(cfg, params["blocks"], x, body, act_spec)
    elif cfg.family == "ssm":
        x = _run_stack(
            cfg, params["blocks"], x, lambda p, h: _ssm_block(cfg, p, h), act_spec
        )
    elif cfg.family == "hybrid":
        # sublayer-level checkpoints live inside the superblock; wrapping the
        # whole superblock again would nest remat (measured: 57.9 → 121 GB on
        # Jamba train — recompute-of-recompute)
        x = _run_stack(
            cfg,
            params["blocks"],
            x,
            lambda p, h: _hybrid_superblock(cfg, p, h, chunk),
            act_spec,
            body_has_remat=True,
        )
    else:
        x = _run_stack(
            cfg,
            params["blocks"],
            x,
            lambda p, h: _decoder_block(cfg, p, h, chunk),
            act_spec,
        )
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def logits_of(cfg: ModelConfig, params, hidden: jnp.ndarray) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return jnp.einsum("btd,vd->btv", hidden, params["embed"])
    return jnp.einsum("btd,dv->btv", hidden, params["lm_head"])


def forward(
    cfg: ModelConfig, params, batch: Dict[str, jnp.ndarray], chunk=None,
    act_spec=None,
) -> jnp.ndarray:
    return logits_of(
        cfg, params, hidden_states(cfg, params, batch, chunk, act_spec)
    )


def mask_vocab_pad(cfg: ModelConfig, logits: jnp.ndarray) -> jnp.ndarray:
    """Kill the padded vocab tail (see ModelConfig.pad_vocab_to)."""
    if cfg.vocab_padded == cfg.vocab:
        return logits
    v = jnp.arange(cfg.vocab_padded) < cfg.vocab
    return jnp.where(v, logits, -1e30)


def loss_fn(cfg: ModelConfig, params, batch, chunk=None, act_spec=None) -> jnp.ndarray:
    """Next-token NLL.  VLM: loss on text positions only."""
    logits = mask_vocab_pad(cfg, forward(cfg, params, batch, chunk, act_spec))
    tokens = batch["tokens"]
    if cfg.family == "vlm":
        logits = logits[:, batch["vision_embeds"].shape[1] :]
    labels = tokens[:, 1:]
    return cross_entropy(logits[:, :-1], labels, batch.get("loss_mask"))


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.compute_dtype)

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        if cfg.mla is not None:
            one = mla_mod.mla_init_cache(batch, max_len, cfg.mla, dtype)
        else:
            one = attn.gqa_init_cache(batch, max_len, cfg.n_kv_heads, cfg.hd, dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one
        )
    if cfg.family == "ssm":
        one = m2.mamba2_init_cache(batch, cfg.d_model, cfg.ssm, dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one
        )
    if cfg.family == "hybrid":
        nb = cfg.n_layers // cfg.attn_every
        a_c = attn.gqa_init_cache(batch, max_len, cfg.n_kv_heads, cfg.hd, dtype)
        m_c = m2.mamba2_init_cache(batch, cfg.d_model, cfg.ssm, dtype)
        return {
            "attn": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (nb,) + a.shape), a_c
            ),
            "mamba": jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a, (nb, cfg.attn_every - 1) + a.shape
                ),
                m_c,
            ),
        }
    if cfg.family == "encdec":
        self_c = attn.gqa_init_cache(batch, max_len, cfg.n_kv_heads, cfg.hd, dtype)
        cache = {
            "self": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), self_c
            ),
            # cross K/V per layer, filled by `encode`:
            "cross_k": jnp.zeros(
                (cfg.n_layers, batch, cfg.enc_context, cfg.n_kv_heads, cfg.hd),
                dtype,
            ),
            "cross_v": jnp.zeros(
                (cfg.n_layers, batch, cfg.enc_context, cfg.n_kv_heads, cfg.hd),
                dtype,
            ),
        }
        return cache
    raise ValueError(cfg.family)


def encode(cfg: ModelConfig, params, frames: jnp.ndarray, cache):
    """encdec: run the encoder, precompute per-layer cross K/V into the cache."""
    enc = _encoder(cfg, params, frames)

    def kv(p):
        k = jnp.einsum("btd,dhk->bthk", enc, p["xattn"]["wk"])
        v = jnp.einsum("btd,dhk->bthk", enc, p["xattn"]["wv"])
        return k, v

    ks, vs = jax.vmap(kv)(params["blocks"])
    return {**cache, "cross_k": ks.astype(cache["cross_k"].dtype),
            "cross_v": vs.astype(cache["cross_v"].dtype)}


def decode_step(
    cfg: ModelConfig,
    params,
    cache,
    tokens: jnp.ndarray,   # [B, 1] int32
    pos: jnp.ndarray,      # scalar int32
) -> Tuple[jnp.ndarray, Any]:
    x = jnp.take(params["embed"], tokens, axis=0)

    if cfg.family in ("dense", "moe", "vlm", "audio"):

        def step(h, inp):
            p, c = inp
            a = rms_norm(h, p["ln1"], cfg.norm_eps)
            if cfg.mla is not None:
                y, c = mla_mod.mla_decode_step(p["attn"], c, a, pos, cfg.mla)
            else:
                y, c = attn.gqa_decode_step(p["attn"], c, a, pos, cfg.rope_theta)
            h = h + y
            a = rms_norm(h, p["ln2"], cfg.norm_eps)
            if cfg.moe and cfg.moe.n_experts:
                y, _ = moe_mod.moe_forward(p["mlp"], a, cfg.moe)
                h = h + y
            else:
                h = h + mlp_forward(a, p["mlp"])
            return h, c

        x, cache = jax.lax.scan(step, x, (params["blocks"], cache))
    elif cfg.family == "ssm":

        def step(h, inp):
            p, c = inp
            a = rms_norm(h, p["ln"], cfg.norm_eps)
            y, c = m2.mamba2_decode_step(p["mixer"], c, a, cfg.ssm)
            return h + y, c

        x, cache = jax.lax.scan(step, x, (params["blocks"], cache))
    elif cfg.family == "hybrid":
        period = cfg.attn_every

        def step(h, inp):
            p, c = inp
            mi, di, oi = 0, 0, 0
            for i in range(period):
                if i == 0:
                    a = rms_norm(h, p["attn_ln"], cfg.norm_eps)
                    y, c_a = attn.gqa_decode_step(
                        p["attn"], c["attn"], a, pos, cfg.rope_theta
                    )
                    c = {**c, "attn": c_a}
                    h = h + y
                else:
                    sub = jax.tree.map(lambda z: z[mi], p["mamba"])
                    subc = jax.tree.map(lambda z: z[mi], c["mamba"])
                    a = rms_norm(h, p["mamba_ln"][mi], cfg.norm_eps)
                    y, subc = m2.mamba2_decode_step(sub, subc, a, cfg.ssm)
                    c = {
                        **c,
                        "mamba": jax.tree.map(
                            lambda full, new: full.at[mi].set(new),
                            c["mamba"],
                            subc,
                        ),
                    }
                    h = h + y
                    mi += 1
                if i % 2 == 1:
                    sub = jax.tree.map(lambda z: z[oi], p["moe"])
                    a = rms_norm(h, p["moe_ln"][oi], cfg.norm_eps)
                    y, _ = moe_mod.moe_forward(sub, a, cfg.moe)
                    h = h + y
                    oi += 1
                else:
                    sub = jax.tree.map(lambda z: z[di], p["mlp"])
                    a = rms_norm(h, p["mlp_ln"][di], cfg.norm_eps)
                    h = h + mlp_forward(a, sub)
                    di += 1
            return h, c

        x, cache = jax.lax.scan(step, x, (params["blocks"], cache))
    elif cfg.family == "encdec":

        def step(h, inp):
            p, c, xk, xv = inp
            a = rms_norm(h, p["ln1"], cfg.norm_eps)
            y, c = attn.gqa_decode_step(p["attn"], c, a, pos, cfg.rope_theta)
            h = h + y
            a = rms_norm(h, p["ln_x"], cfg.norm_eps)
            q = jnp.einsum("btd,dhk->bthk", a, p["xattn"]["wq"])
            KV = xk.shape[2]
            qg = q.reshape(*q.shape[:2], KV, q.shape[2] // KV, q.shape[3])
            s = jnp.einsum("btkgh,bskh->bkgts", qg, xk).astype(jnp.float32)
            s = s * (q.shape[-1] ** -0.5)
            pr = jax.nn.softmax(s, axis=-1).astype(h.dtype)
            ctx = jnp.einsum("bkgts,bskh->btkgh", pr, xv)
            ctx = ctx.reshape(*q.shape)
            h = h + jnp.einsum("bthk,hkd->btd", ctx, p["xattn"]["wo"])
            a = rms_norm(h, p["ln2"], cfg.norm_eps)
            h = h + mlp_forward(a, p["mlp"])
            return h, c

        x, self_c = jax.lax.scan(
            step,
            x,
            (params["blocks"], cache["self"], cache["cross_k"], cache["cross_v"]),
        )
        cache = {**cache, "self": self_c}
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return mask_vocab_pad(cfg, logits_of(cfg, params, x)), cache
