"""Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style).

KV state is compressed to a per-token latent ``c_kv ∈ R^{kv_lora}`` plus one
shared RoPE key ``k_rope ∈ R^{rope}`` — the decode cache holds only
``kv_lora + rope`` floats/token (vs ``2·KV·hd`` for GQA).  Decode uses the
**absorbed** form: scores are taken directly against the latent via
``qᵀW_uk``-absorbed queries, and the attention output stays in latent space
until the final up-projection — so decode reads O(kv_lora) bytes/token.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig
from repro.models.layers import ParamSpec, apply_rope

NEG_INF = -1e30


def mla_specs(d: int, n_heads: int, m: MLAConfig) -> Dict[str, ParamSpec]:
    qk = m.qk_nope_dim + m.qk_rope_dim
    return {
        "q_down": ParamSpec((d, m.q_lora_rank), ("embed", "q_lora")),
        "q_norm": ParamSpec((m.q_lora_rank,), ("q_lora",), init="ones"),
        "q_up": ParamSpec((m.q_lora_rank, n_heads, qk), ("q_lora", "heads", None)),
        "kv_down": ParamSpec(
            (d, m.kv_lora_rank + m.qk_rope_dim), ("embed", "kv_lora")
        ),
        "kv_norm": ParamSpec((m.kv_lora_rank,), ("kv_lora",), init="ones"),
        "k_up": ParamSpec(
            (m.kv_lora_rank, n_heads, m.qk_nope_dim), ("kv_lora", "heads", None)
        ),
        "v_up": ParamSpec(
            (m.kv_lora_rank, n_heads, m.v_head_dim), ("kv_lora", "heads", None)
        ),
        "wo": ParamSpec((n_heads, m.v_head_dim, d), ("heads", None, "embed")),
    }


def _project(p, x, m: MLAConfig, positions):
    """Shared q/kv projections.  Returns (q_nope, q_rope, c_kv, k_rope)."""
    from repro.models.layers import rms_norm

    cq = rms_norm(jnp.einsum("btd,dr->btr", x, p["q_down"]), p["q_norm"])
    q = jnp.einsum("btr,rhk->bthk", cq, p["q_up"])
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim :]
    q_rope = apply_rope(q_rope, positions, 10_000.0)

    ckv_full = jnp.einsum("btd,dr->btr", x, p["kv_down"])
    c_kv = rms_norm(ckv_full[..., : m.kv_lora_rank], p["kv_norm"])
    k_rope = ckv_full[..., m.kv_lora_rank :][:, :, None, :]      # [B,T,1,rope]
    k_rope = apply_rope(k_rope, positions, 10_000.0)[:, :, 0]     # [B,T,rope]
    return q_nope, q_rope, c_kv, k_rope


def mla_forward(
    p: Dict[str, jnp.ndarray], x: jnp.ndarray, m: MLAConfig,
    chunk=None,
) -> jnp.ndarray:
    """Training/prefill path (materializes per-head K/V; causal).  With
    ``chunk`` the flash-style online-softmax path bounds memory at O(T·chunk)
    — required for the 32k prefill cells (dense MLA scores are O(H·T²))."""
    from repro.models.attention import chunked_causal_attention

    B, T, _ = x.shape
    pos = jnp.arange(T)
    q_nope, q_rope, c_kv, k_rope = _project(p, x, m, pos)
    k_nope = jnp.einsum("btr,rhk->bthk", c_kv, p["k_up"])
    v = jnp.einsum("btr,rhk->bthk", c_kv, p["v_up"])
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    if chunk is not None and T > chunk and T % chunk == 0:
        H = q_nope.shape[2]
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, T, H, m.qk_rope_dim))],
            axis=-1,
        )
        ctx = chunked_causal_attention(q, k, v, chunk)
        return jnp.einsum("bthk,hkd->btd", ctx, p["wo"])
    s = (
        jnp.einsum("bthk,bshk->bhts", q_nope, k_nope)
        + jnp.einsum("bthk,bsk->bhts", q_rope, k_rope)
    ).astype(jnp.float32) * scale
    mask = pos[None, :] <= pos[:, None]
    s = jnp.where(mask[None, None], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    ctx = jnp.einsum("bhts,bshk->bthk", pr, v)
    return jnp.einsum("bthk,hkd->btd", ctx, p["wo"])


def mla_init_cache(batch: int, max_len: int, m: MLAConfig, dtype):
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_dim), dtype),
    }


def mla_decode_step(
    p: Dict[str, jnp.ndarray],
    cache: Dict[str, jnp.ndarray],
    x: jnp.ndarray,            # [B, 1, d]
    pos: jnp.ndarray,          # scalar
    m: MLAConfig,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Absorbed-form decode: attention runs entirely in latent space."""
    q_nope, q_rope, c_kv_new, k_rope_new = _project(p, x, m, pos[None])
    cache = {
        "c_kv": jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv_new, pos, axis=1
        ),
        "k_rope": jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope_new, pos, axis=1
        ),
    }
    # absorb W_uk into the query:  q̃ = q_nope · W_uk  ∈ latent space
    q_lat = jnp.einsum("bthk,rhk->bthr", q_nope, p["k_up"])       # [B,1,H,r]
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    s = (
        jnp.einsum("bthr,bsr->bhts", q_lat, cache["c_kv"])
        + jnp.einsum("bthk,bsk->bhts", q_rope, cache["k_rope"])
    ).astype(jnp.float32) * scale
    valid = jnp.arange(cache["c_kv"].shape[1])[None, :] <= pos
    s = jnp.where(valid[None, None], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    ctx_lat = jnp.einsum("bhts,bsr->bthr", pr, cache["c_kv"])     # latent ctx
    ctx = jnp.einsum("bthr,rhk->bthk", ctx_lat, p["v_up"])
    return jnp.einsum("bthk,hkd->btd", ctx, p["wo"]), cache
