"""Shared building blocks: ParamSpec trees, norms, RoPE, MLP.

Single-source-of-truth parameter system: every model module builds a nested
dict of :class:`ParamSpec` (shape + **logical axes** + init law).  From that
one tree we derive
  * real initialized params           (``init_params``),
  * abstract ShapeDtypeStructs        (``abstract_params`` — dry-run, no alloc),
  * logical-axis tree                 (``axes_tree`` — mapped to mesh axes by
                                       ``repro.distributed.sharding``).
The three can never drift apart.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis name per dim (None = replicated)
    init: str = "normal"              # normal | zeros | ones
    scale: Optional[float] = None     # default: 1/sqrt(fan_in = shape[-2] or [-1])

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _leaf_init(spec: ParamSpec, key: jax.Array, dtype) -> jnp.ndarray:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    scale = spec.scale if spec.scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(dtype)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(specs, key: jax.Array, dtype=jnp.bfloat16):
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_leaf_init(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(specs, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs, is_leaf=_is_spec
    )


def axes_tree(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=_is_spec)


def param_count(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=_is_spec)
    return int(sum(np.prod(s.shape) for s in leaves))


# ---------------------------------------------------------------------------
# Ops
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * gamma


def rope_freqs(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., T, H, hd]; positions: broadcastable to [..., T]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                              # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs     # [..., T, hd/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2 :]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def swiglu(x: jnp.ndarray, w_gate, w_up, w_down) -> jnp.ndarray:
    """SwiGLU MLP: down( silu(x·gate) ⊙ (x·up) )."""
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def mlp_specs(d: int, d_ff: int, kind: str = "swiglu") -> Dict[str, ParamSpec]:
    if kind == "gelu":
        return {
            "up": ParamSpec((d, d_ff), ("embed", "ffn")),
            "down": ParamSpec((d_ff, d), ("ffn", "embed")),
        }
    return {
        "gate": ParamSpec((d, d_ff), ("embed", "ffn")),
        "up": ParamSpec((d, d_ff), ("embed", "ffn")),
        "down": ParamSpec((d_ff, d), ("ffn", "embed")),
    }


def mlp_forward(x, p) -> "jnp.ndarray":
    """Dispatch on the param dict: SwiGLU if a gate matrix is present."""
    if "gate" in p:
        return swiglu(x, p["gate"], p["up"], p["down"])
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, p["up"]))
    return jnp.einsum("...f,fd->...d", h, p["down"])


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray, mask=None) -> jnp.ndarray:
    """Mean token NLL; logits [..., V] (softmax in f32), labels int [...]."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def stacked(spec_dict: Dict[str, Any], n: int, axis_name: str = "layers"):
    """Prepend a stacking dim (for scan-over-layers) to every spec in a tree."""

    def add(s: ParamSpec) -> ParamSpec:
        return ParamSpec(
            (n,) + s.shape, (axis_name,) + s.axes, s.init, s.scale
        )

    return jax.tree.map(add, spec_dict, is_leaf=_is_spec)
