"""Mamba-2 block via SSD (state-space duality), chunked — arXiv:2405.21060.

The SSD form computes ``y = SSM(A, B, C)(x)`` as block-diagonal (intra-chunk,
quadratic in chunk length, MXU-friendly) plus low-rank inter-chunk terms
carried by a sequential scan over chunk states — sub-quadratic in T overall,
O(T·Q) FLOPs with chunk Q.  Decode is the classic O(1)/token recurrence on the
``[B, H, P, N]`` state.

Layout: d_inner = expand·d, H heads of dim P = head_dim, G state groups of
size N = d_state.  In-projection produces (z, x, B, C, dt); depthwise causal
conv of width w over (x, B, C); gated RMSNorm before out-projection.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import ParamSpec, rms_norm


def ssm_dims(d: int, s: SSMConfig):
    d_inner = s.expand * d
    H = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, H, conv_dim


def mamba2_specs(d: int, s: SSMConfig) -> Dict[str, ParamSpec]:
    """Projections are SPLIT (z, x, BC, dt) rather than one packed in_proj so
    each output shards cleanly: z/x on the head ("ffn"→TP) dim, B/C/dt
    replicated (they are small and feed group-broadcast einsums).  A packed
    projection sharded on the fused dim forces GSPMD to rematerialize at every
    slice — measured 10s-of-GB on the 398B Jamba before the split."""
    d_inner, H, conv_dim = ssm_dims(d, s)
    gN = s.n_groups * s.d_state
    return {
        "z_proj": ParamSpec((d, d_inner), ("embed", "ffn")),
        "x_proj": ParamSpec((d, d_inner), ("embed", "ffn")),
        "bc_proj": ParamSpec((d, 2 * gN), ("embed", None)),
        "dt_proj": ParamSpec((d, H), ("embed", None)),
        "conv_x_w": ParamSpec((s.conv_width, d_inner), (None, "ffn"), scale=0.5),
        "conv_x_b": ParamSpec((d_inner,), ("ffn",), init="zeros"),
        "conv_bc_w": ParamSpec((s.conv_width, 2 * gN), (None, None), scale=0.5),
        "conv_bc_b": ParamSpec((2 * gN,), (None,), init="zeros"),
        "A_log": ParamSpec((H,), (None,), init="zeros"),
        "D": ParamSpec((H,), (None,), init="ones"),
        "dt_bias": ParamSpec((H,), (None,), init="zeros"),
        "norm": ParamSpec((d_inner,), ("ffn",), init="ones"),
        "out_proj": ParamSpec((d_inner, d), ("ffn", "embed")),
    }


def _project(p, xin, s: SSMConfig):
    gN = s.n_groups * s.d_state
    z = jnp.einsum("btd,dk->btk", xin, p["z_proj"])
    x = jnp.einsum("btd,dk->btk", xin, p["x_proj"])
    bc = jnp.einsum("btd,dk->btk", xin, p["bc_proj"])
    dt = jnp.einsum("btd,dk->btk", xin, p["dt_proj"])
    return z, x, bc[..., :gN], bc[..., gN:], dt


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over time: xbc [B, T, D], w [width, D]."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :] for i in range(width)
    )
    return jax.nn.silu(out + b)


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """Lower-triangular segment sums: out[..., i, j] = Σ_{j<k≤i} x[..., k]."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(
    x: jnp.ndarray,    # [B, T, H, P]
    dt: jnp.ndarray,   # [B, T, H]   (post-softplus)
    A: jnp.ndarray,    # [H]         (negative)
    B_: jnp.ndarray,   # [B, T, G, N]
    C_: jnp.ndarray,   # [B, T, G, N]
    chunk: int,
    h0: jnp.ndarray = None,  # [B, H, P, N] initial state
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y [B,T,H,P], final state [B,H,P,N])."""
    Bb, T, H, P = x.shape
    G, N = B_.shape[-2:]
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    rep = H // G

    xc = x.reshape(Bb, nc, chunk, H, P)
    dtc = dt.reshape(Bb, nc, chunk, H)
    Bc = B_.reshape(Bb, nc, chunk, G, N)
    Cc = C_.reshape(Bb, nc, chunk, G, N)
    dA = dtc * A[None, None, None, :]                       # [B,nc,Q,H]
    dA_cs = jnp.cumsum(dA, axis=2)

    # intra-chunk (quadratic in Q — the MXU part).  All [*,H,Q,Q]-sized
    # intermediates are built with GROUPED einsums (H = G×rep as two indices)
    # instead of jnp.repeat: the repeat materializes a replicated head-major
    # tensor and breaks the TP head-sharding inherited from x — measured
    # tens of GB on Jamba-398B prefill.
    Lh = jnp.exp(
        _segsum(dA.reshape(Bb, nc, chunk, G, rep).transpose(0, 1, 3, 4, 2))
    )                                                        # [B,nc,G,r,Q,Q]
    CB = jnp.einsum("bnqgs,bnkgs->bngqk", Cc, Bc)            # [B,nc,G,Q,K]
    xdt = xc * dtc[..., None]                                # [B,nc,Q,H,P]
    xdt_g = xdt.reshape(Bb, nc, chunk, G, rep, P)
    y_diag = jnp.einsum(
        "bngqk,bngrqk,bnkgrp->bnqgrp",
        CB.astype(jnp.float32),
        Lh,
        xdt_g.astype(jnp.float32),
    ).reshape(Bb, nc, chunk, H, P)

    # chunk states (B broadcast from G groups to H heads via grouped einsum)
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)     # [B,nc,Q,H]
    xdtd_g = (xdt * decay_states[..., None]).reshape(
        Bb, nc, chunk, G, rep, P
    )
    states = jnp.einsum(
        "bnqgs,bnqgrp->bngrps",
        Bc.astype(jnp.float32),
        xdtd_g.astype(jnp.float32),
    ).reshape(Bb, nc, H, P, N)

    # inter-chunk recurrence over nc
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])               # [B,nc,H]

    def scan_fn(h, inp):
        st, dec = inp                                        # [B,H,P,N], [B,H]
        h_new = h * dec[:, :, None, None] + st
        return h_new, h

    if h0 is None:
        h0 = jnp.zeros((Bb, H, P, N), jnp.float32)
    h_final, h_prev = jax.lax.scan(
        scan_fn,
        h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)                # [B,nc,H,P,N]

    # off-diagonal contribution (grouped: no head-repeat materialization)
    state_decay = jnp.exp(dA_cs)                            # [B,nc,Q,H]
    h_prev_g = h_prev.reshape(Bb, nc, G, rep, P, N)
    y_off = jnp.einsum(
        "bnqgs,bngrps->bnqgrp", Cc.astype(jnp.float32), h_prev_g
    ).reshape(Bb, nc, chunk, H, P) * state_decay[..., None]

    y = (y_diag.astype(jnp.float32) + y_off).reshape(Bb, T, H, P)
    return y.astype(x.dtype), h_final


def mamba2_forward(
    p: Dict[str, jnp.ndarray], xin: jnp.ndarray, s: SSMConfig
) -> jnp.ndarray:
    """Full-sequence forward (training / prefill)."""
    d = xin.shape[-1]
    d_inner, H, conv_dim = ssm_dims(d, s)
    gN = s.n_groups * s.d_state
    z, x, B_, C_, dt = _project(p, xin, s)
    x = _causal_conv(x, p["conv_x_w"], p["conv_x_b"])
    bc = _causal_conv(
        jnp.concatenate([B_, C_], axis=-1), p["conv_bc_w"], p["conv_bc_b"]
    )
    B_, C_ = bc[..., :gN], bc[..., gN:]
    Bb, T = x.shape[:2]
    dt = jax.nn.softplus(dt + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, _ = ssd_chunked(
        x.reshape(Bb, T, H, s.head_dim),
        dt,
        A,
        B_.reshape(Bb, T, s.n_groups, s.d_state),
        C_.reshape(Bb, T, s.n_groups, s.d_state),
        min(s.chunk, T),
    )
    y = y + x.reshape(Bb, T, H, s.head_dim) * p["D"][None, None, :, None]
    y = y.reshape(Bb, T, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return jnp.einsum("btk,kd->btd", y, p["out_proj"])


def mamba2_init_cache(batch: int, d: int, s: SSMConfig, dtype):
    d_inner, H, conv_dim = ssm_dims(d, s)
    gN = s.n_groups * s.d_state
    return {
        "conv_x": jnp.zeros((batch, s.conv_width - 1, d_inner), dtype),
        "conv_bc": jnp.zeros((batch, s.conv_width - 1, 2 * gN), dtype),
        "ssm": jnp.zeros((batch, H, s.head_dim, s.d_state), jnp.float32),
    }


def mamba2_decode_step(
    p: Dict[str, jnp.ndarray],
    cache: Dict[str, jnp.ndarray],
    xin: jnp.ndarray,   # [B, 1, d]
    s: SSMConfig,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    d = xin.shape[-1]
    d_inner, H, conv_dim = ssm_dims(d, s)
    gN = s.n_groups * s.d_state
    z, x, B_, C_, dt = _project(p, xin, s)
    win_x = jnp.concatenate([cache["conv_x"], x], axis=1)
    x = jax.nn.silu(
        (win_x * p["conv_x_w"][None]).sum(axis=1, keepdims=True) + p["conv_x_b"]
    )
    bc_new = jnp.concatenate([B_, C_], axis=-1)
    win_bc = jnp.concatenate([cache["conv_bc"], bc_new], axis=1)
    bc = jax.nn.silu(
        (win_bc * p["conv_bc_w"][None]).sum(axis=1, keepdims=True)
        + p["conv_bc_b"]
    )
    B_, C_ = bc[..., :gN], bc[..., gN:]
    cache_conv_x, cache_conv_bc = win_x[:, 1:], win_bc[:, 1:]
    Bb = x.shape[0]
    dt = jax.nn.softplus(dt + p["dt_bias"])[:, 0]            # [B,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = x.reshape(Bb, H, s.head_dim).astype(jnp.float32)
    rep = H // s.n_groups
    Bh = jnp.repeat(B_.reshape(Bb, s.n_groups, s.d_state), rep, axis=1)
    Ch = jnp.repeat(C_.reshape(Bb, s.n_groups, s.d_state), rep, axis=1)
    decay = jnp.exp(dt * A[None, :]).astype(jnp.float32)     # [B,H]
    h = cache["ssm"] * decay[:, :, None, None] + jnp.einsum(
        "bhp,bhs->bhps", xh * dt[..., None], Bh.astype(jnp.float32)
    )
    y = jnp.einsum("bhps,bhs->bhp", h, Ch.astype(jnp.float32))
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(Bb, 1, d_inner).astype(xin.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    out = jnp.einsum("btk,kd->btd", y, p["out_proj"])
    return out, {"conv_x": cache_conv_x, "conv_bc": cache_conv_bc, "ssm": h}
