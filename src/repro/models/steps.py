"""train_step / serve_step builders — the functions the dry-run lowers.

``make_train_step`` supports microbatch gradient accumulation (a ``lax.scan``
over microbatches — overlapping each microbatch's backward with the next's
forward is left to XLA; the accumulation keeps activation memory at
1/accum).  ``make_serve_step`` is one decode step against a pre-sized cache;
``make_prefill_step`` is the full-sequence forward.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.optim import adamw


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: adamw.AdamWConfig,
    accum: int = 1,
    attn_chunk: Optional[int] = None,
    batch_spec=None,      # PartitionSpec of one microbatch's leading (B) dim
    act_spec=None,        # PartitionSpec for [B, T, d] activations
    accum_dtype=jnp.float32,  # bf16 halves the persistent grad buffer (≥100B)
):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``batch`` arrays have leading dim ``global_batch``; with accum > 1 the
    leading dim is reshaped to [accum, B/accum, ...] and scanned (the reshape
    gets an explicit sharding constraint so GSPMD keeps B on the data axes).
    """

    def loss_of(params, mb):
        return M.loss_fn(cfg, params, mb, chunk=attn_chunk, act_spec=act_spec)

    def train_step(params, opt_state, batch):
        if accum > 1:
            from jax.sharding import PartitionSpec as PS

            def resh(a):
                out = a.reshape((accum, a.shape[0] // accum) + a.shape[1:])
                if batch_spec is not None:
                    spec = PS(None, batch_spec, *([None] * (a.ndim - 1)))
                    out = jax.lax.with_sharding_constraint(out, spec)
                return out

            mb_batch = jax.tree.map(resh, batch)

            def micro(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_of)(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: (a.astype(jnp.float32) + b).astype(accum_dtype),
                    gsum,
                    g,
                )
                return (gsum, lsum + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params
            )
            (gsum, lsum), _ = jax.lax.scan(
                micro, (zeros, jnp.zeros((), jnp.float32)), mb_batch
            )
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = lsum / accum
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        params, opt_state, metrics = adamw.update(grads, opt_state, params, opt_cfg)
        metrics = {"loss": loss, **metrics}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(
    cfg: ModelConfig, attn_chunk: Optional[int] = None, act_spec=None
):
    """Full-sequence forward returning last-position logits (prefill cells)."""

    def prefill(params, batch):
        logits = M.forward(cfg, params, batch, chunk=attn_chunk, act_spec=act_spec)
        return logits[:, -1]

    return prefill


def make_serve_step(cfg: ModelConfig):
    """One incremental decode step: (params, cache, tokens[B,1], pos)."""

    def serve_step(params, cache, tokens, pos):
        return M.decode_step(cfg, params, cache, tokens, pos)

    return serve_step
