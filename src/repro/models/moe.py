"""Mixture-of-Experts with sort-free static dispatch + LPT expert placement.

Dispatch is scatter-based with static shapes (TPU-friendly, no one-hot
[N,E,C] blow-up): top-k routing → per-expert capacity slots via masked
cumsum → scatter tokens into an ``[E·C, d]`` buffer → batched expert matmul
``[E, C, d] × [E, d, f]`` → gather-combine.  Tokens over capacity are dropped
(counted in aux), the standard capacity-factor contract.

**Paper bridge** (DESIGN.md §Arch-applicability): routed-expert load is
irregular, data-dependent work — the MoE analogue of PBEC sizes.
``lpt_expert_permutation`` estimates per-expert load from a *sampled* router
histogram and LPT-packs experts onto EP ranks so each rank serves ≈1/R of the
tokens — the thesis' double-sampling static balance, re-targeted.  The
permutation is applied by re-indexing the stacked expert weights (a gather at
placement time, free at runtime).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.models.layers import ParamSpec, mlp_specs, swiglu


def moe_specs(d: int, m: MoEConfig) -> Dict[str, ParamSpec]:
    specs: Dict[str, ParamSpec] = {
        "router": ParamSpec((d, m.n_experts), ("embed", None), scale=0.02),
        "w_gate": ParamSpec((m.n_experts, d, m.expert_d_ff), ("experts", "embed", "ffn")),
        "w_up": ParamSpec((m.n_experts, d, m.expert_d_ff), ("experts", "embed", "ffn")),
        "w_down": ParamSpec((m.n_experts, m.expert_d_ff, d), ("experts", "ffn", "embed")),
    }
    if m.n_shared:
        specs["shared"] = mlp_specs(d, m.n_shared * m.expert_d_ff)
    return specs


def moe_forward(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,                 # [B, T, d]
    m: MoEConfig,
    expert_perm: Optional[jnp.ndarray] = None,  # int32[E] logical→physical
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    B, T, d = x.shape
    N = B * T
    if m.token_chunk and N > m.token_chunk and N % m.token_chunk == 0:
        # Token-chunked dispatch: bounds the [E·C, d] buffers at chunk
        # granularity regardless of how GSPMD treats the global scatter
        # (measured: the un-chunked buffer replicates to 10s of GB on
        # Jamba-398B prefill).  Expert weights are re-read per chunk — a
        # collective/HBM cost the §Roofline model charges explicitly.
        nch = N // m.token_chunk
        xc = x.reshape(nch, 1, m.token_chunk, d)
        m_inner = __import__("dataclasses").replace(m, token_chunk=0)

        def one(xi):
            y, aux = moe_forward(p, xi, m_inner, expert_perm)
            return y, (aux["lb_loss"], aux["dropped"], aux["expert_load"])

        ys, (lb, drop, load) = jax.lax.map(one, xc)
        aux = {
            "lb_loss": lb.mean(),
            "dropped": drop.sum(),
            "expert_load": load.sum(axis=0),
        }
        return ys.reshape(B, T, d), aux
    E, K = m.n_experts, m.top_k
    # decode / small batches: exact no-drop dispatch (C = N·K guarantees a
    # slot for every routed pair — serving must not drop tokens, and the
    # capacity heuristic is meaningless at N ≈ B)
    if N * K <= 4096 and m.capacity_factor >= 1.0:
        C = N * K
    else:
        C = int(np.ceil(m.capacity_factor * N * K / E))
    xt = x.reshape(N, d)

    logits = jnp.einsum("nd,de->ne", xt, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)                    # [N, K]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    if expert_perm is not None:
        top_e = expert_perm[top_e]

    # capacity slots: for the k-th choice of token n, its slot within expert e
    # is the running count of earlier (token, choice) pairs routed to e.
    flat_e = top_e.reshape(-1)                                 # [N*K]
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)        # [N*K, E]
    slots = ((jnp.cumsum(onehot, axis=0) - onehot) * onehot).sum(axis=-1)
    keep = slots < C
    dropped = (~keep).sum()

    buf_pos = jnp.where(keep, flat_e * C + slots, E * C)       # E*C ⇒ dropped
    tok_idx = jnp.repeat(jnp.arange(N), K)
    buf = jnp.zeros((E * C, d), x.dtype).at[buf_pos].set(
        xt[tok_idx], mode="drop"
    )
    eb = buf.reshape(E, C, d)
    if m.ep_axis is not None:
        # EP: pin the expert buffer and intermediates to the expert axis —
        # without this GSPMD replicates the scatter-built [E·C, d] buffer and
        # the [E, C, d_ff] expert activations (measured 16+ GB/dev on
        # Jamba-398B prefill).
        from jax.sharding import PartitionSpec as PS

        eb = jax.lax.with_sharding_constraint(eb, PS(m.ep_axis, None, None))

    g = jnp.einsum("ecd,edf->ecf", eb, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", eb, p["w_up"])
    h = jax.nn.silu(g) * u
    if m.ep_axis is not None:
        from jax.sharding import PartitionSpec as PS

        h = jax.lax.with_sharding_constraint(h, PS(m.ep_axis, None, None))
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    if m.ep_axis is not None:
        from jax.sharding import PartitionSpec as PS

        y = jax.lax.with_sharding_constraint(y, PS(m.ep_axis, None, None))
    y = y.reshape(E * C, d)

    gathered = y.at[jnp.minimum(buf_pos, E * C - 1)].get(mode="clip")
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    weighted = gathered * top_w.reshape(-1)[:, None].astype(x.dtype)
    out = jnp.zeros((N, d), x.dtype).at[tok_idx].add(weighted)

    if m.n_shared:
        sp = p["shared"]
        out = out + swiglu(xt, sp["gate"], sp["up"], sp["down"])

    # Switch-style load-balance aux loss + stats for the LPT placement.
    frac_tokens = jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32).mean(0)
    frac_probs = probs.mean(0)
    aux = {
        "lb_loss": E * jnp.sum(frac_tokens * frac_probs),
        "dropped": dropped,
        "expert_load": onehot.sum(axis=0),
    }
    return out.reshape(B, T, d), aux


# ---------------------------------------------------------------------------
# Paper bridge: sampled-histogram LPT expert placement
# ---------------------------------------------------------------------------


def lpt_expert_permutation(
    sampled_load: np.ndarray,   # float[E] — expert-load histogram from a sample
    n_ranks: int,
) -> np.ndarray:
    """LPT-pack experts onto EP ranks; return the expert permutation.

    The returned ``perm`` maps logical expert e to physical slot ``perm[e]``
    such that physical slots are grouped by rank (slot // (E/R) = rank) and
    per-rank estimated load is ≈ balanced (Graham 4/3 bound, as in Phase 2).
    """
    from repro.core.schedule import lpt_schedule

    E = len(sampled_load)
    assert E % n_ranks == 0, "experts must divide EP ranks"
    per = E // n_ranks
    rank_of = lpt_schedule(sampled_load, n_ranks)
    # LPT can overfill a rank count-wise; rebalance counts while keeping the
    # heaviest experts where LPT put them.
    order = np.argsort(-np.asarray(sampled_load), kind="stable")
    counts = np.zeros(n_ranks, dtype=np.int64)
    final_rank = np.zeros(E, dtype=np.int64)
    loads = np.zeros(n_ranks)
    for e in order:
        r = rank_of[e]
        if counts[r] >= per:  # fall back to least-loaded rank with room
            avail = np.nonzero(counts < per)[0]
            r = avail[np.argmin(loads[avail])]
        final_rank[e] = r
        counts[r] += 1
        loads[r] += sampled_load[e]
    # slot assignment within rank: stable order
    perm = np.zeros(E, dtype=np.int64)
    next_slot = {r: 0 for r in range(n_ranks)}
    for e in range(E):
        r = int(final_rank[e])
        perm[e] = r * per + next_slot[r]
        next_slot[r] += 1
    return perm


def apply_expert_permutation(p: Dict[str, jnp.ndarray], perm: np.ndarray):
    """Re-index stacked expert weights so physical slot layout matches perm."""
    inv = np.argsort(perm)
    out = dict(p)
    for k in ("w_gate", "w_up", "w_down"):
        out[k] = p[k][jnp.asarray(inv)]
    return out
