"""GQA attention: training/prefill (chunked, flash-style) + cached decode."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec, apply_rope

NEG_INF = -1e30


def attn_specs(d: int, n_heads: int, n_kv: int, hd: int) -> Dict[str, ParamSpec]:
    return {
        "wq": ParamSpec((d, n_heads, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, n_kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, n_kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((n_heads, hd, d), ("heads", "head_dim", "embed")),
    }


def _group(q: jnp.ndarray, n_kv: int) -> jnp.ndarray:
    """[B,T,H,hd] -> [B,T,KV,G,hd] with H = KV*G."""
    B, T, H, hd = q.shape
    return q.reshape(B, T, n_kv, H // n_kv, hd)


def sdpa_causal(
    q: jnp.ndarray,        # [B, Tq, H, hd]
    k: jnp.ndarray,        # [B, Tk, KV, hd]
    v: jnp.ndarray,        # [B, Tk, KV, hd]
    q_positions: jnp.ndarray,   # [Tq] absolute positions of queries
    k_valid_len: Optional[jnp.ndarray] = None,  # scalar: #valid kv (decode)
) -> jnp.ndarray:
    """Dense causal GQA attention (reference / decode / small-T path)."""
    B, Tq, H, hd = q.shape
    KV = k.shape[2]
    qg = _group(q, KV)                                  # [B,Tq,KV,G,hd]
    scale = hd ** -0.5
    scores = jnp.einsum("btkgh,bskh->bkgts", qg, k) * scale
    kpos = jnp.arange(k.shape[1])
    mask = kpos[None, :] <= q_positions[:, None]        # [Tq, Tk]
    if k_valid_len is not None:
        mask = mask & (kpos[None, :] < k_valid_len)
    scores = jnp.where(mask[None, None, None], scores.astype(jnp.float32), NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    ctx = jnp.einsum("bkgts,bskh->btkgh", p, v)
    return ctx.reshape(B, Tq, H, hd)


def chunked_causal_attention(
    q: jnp.ndarray,       # [B, T, H, hd_qk]
    k: jnp.ndarray,       # [B, T, KV, hd_qk]
    v: jnp.ndarray,       # [B, T, KV, hd_v]  (hd_v may differ — MLA)
    chunk: int = 1024,
) -> jnp.ndarray:
    """Flash-style online-softmax attention, O(T·chunk) memory.

    Queries are processed in blocks; for each query block a ``lax.scan`` walks
    the ≤ causal KV blocks carrying (m, l, acc) running statistics.  Pure jnp:
    on TPU, XLA maps the inner einsums onto the MXU; this is the memory-term
    workhorse for the 32k prefill cells.
    """
    B, T, H, hd = q.shape
    hdv = v.shape[-1]
    KV = k.shape[2]
    G = H // KV
    assert T % chunk == 0, (T, chunk)
    n = T // chunk
    scale = hd ** -0.5

    qb = q.reshape(B, n, chunk, H, hd)
    kb = k.reshape(B, n, chunk, KV, hd)
    vb = v.reshape(B, n, chunk, KV, hdv)

    def per_qblock(qi, q_blk):
        # q_blk: [B, chunk, H, hd]
        qg = q_blk.reshape(B, chunk, KV, G, hd)
        q_pos = qi * chunk + jnp.arange(chunk)

        @jax.checkpoint
        def step(carry, inp):
            m, l, acc = carry
            kj, (k_blk, v_blk) = inp
            s = jnp.einsum("btkgh,bskh->bkgts", qg, k_blk).astype(jnp.float32)
            s = s * scale
            k_pos = kj * chunk + jnp.arange(chunk)
            mask = (k_pos[None, :] <= q_pos[:, None]) & (kj <= qi)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgts,bskh->bkgth", p.astype(v_blk.dtype), v_blk
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, G, chunk, hdv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            step,
            (m0, l0, a0),
            (jnp.arange(n), (kb.swapaxes(0, 1), vb.swapaxes(0, 1))),
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 3, 1, 2, 4).reshape(B, chunk, H, hdv)

    per_qblock = jax.checkpoint(per_qblock, static_argnums=())
    outs = jax.lax.map(
        lambda i: per_qblock(i, qb[:, i]), jnp.arange(n)
    )  # [n, B, chunk, H, hdv]
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, T, H, hdv).astype(q.dtype)


def gqa_forward(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,             # [B, T, d]
    rope_theta: float,
    chunk: Optional[int] = None,
) -> jnp.ndarray:
    B, T, _ = x.shape
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"])
    pos = jnp.arange(T)
    q = apply_rope(q, pos, rope_theta)
    k = apply_rope(k, pos, rope_theta)
    if chunk is not None and T > chunk and T % chunk == 0:
        ctx = chunked_causal_attention(q, k, v, chunk)
    else:
        ctx = sdpa_causal(q, k, v, pos)
    return jnp.einsum("bthk,hkd->btd", ctx, p["wo"])


def gqa_init_cache(
    batch: int, max_len: int, n_kv: int, hd: int, dtype
) -> Dict[str, jnp.ndarray]:
    return {
        "k": jnp.zeros((batch, max_len, n_kv, hd), dtype),
        "v": jnp.zeros((batch, max_len, n_kv, hd), dtype),
    }


def gqa_decode_step(
    p: Dict[str, jnp.ndarray],
    cache: Dict[str, jnp.ndarray],
    x: jnp.ndarray,             # [B, 1, d]
    pos: jnp.ndarray,           # scalar int32 — index of the new token
    rope_theta: float,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"])
    q = apply_rope(q, pos[None], rope_theta)
    k = apply_rope(k, pos[None], rope_theta)
    cache = {
        "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos, axis=1),
        "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos, axis=1),
    }
    ctx = sdpa_causal(q, cache["k"], cache["v"], pos[None], k_valid_len=pos + 1)
    return jnp.einsum("bthk,hkd->btd", ctx, p["wo"]), cache


def chunked_bidir_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, chunk: int
) -> jnp.ndarray:
    """Online-softmax non-causal attention, O(T·chunk) memory (enc side)."""
    B, T, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    assert T % chunk == 0
    n = T // chunk
    scale = hd ** -0.5
    qb = q.reshape(B, n, chunk, H, hd)
    kb = k.reshape(B, n, chunk, KV, hd).swapaxes(0, 1)
    vb = v.reshape(B, n, chunk, KV, hd).swapaxes(0, 1)

    def per_qblock(q_blk):
        qg = q_blk.reshape(B, chunk, KV, G, hd)

        @jax.checkpoint
        def step(carry, kv):
            m, l, acc = carry
            k_blk, v_blk = kv
            s = jnp.einsum("btkgh,bskh->bkgts", qg, k_blk).astype(jnp.float32)
            s = s * scale
            m_new = jnp.maximum(m, s.max(axis=-1))
            p_ = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p_.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgts,bskh->bkgth", p_.astype(v_blk.dtype), v_blk
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, G, chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 3, 1, 2, 4).reshape(B, chunk, H, hd)

    per_qblock = jax.checkpoint(per_qblock)
    outs = jax.lax.map(lambda i: per_qblock(qb[:, i]), jnp.arange(n))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, T, H, hd).astype(q.dtype)


def bidir_attention(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,
    rope_theta: float,
    chunk: Optional[int] = None,
) -> jnp.ndarray:
    """Non-causal self-attention (encoder side of enc-dec)."""
    B, T, _ = x.shape
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"])
    pos = jnp.arange(T)
    q = apply_rope(q, pos, rope_theta)
    k = apply_rope(k, pos, rope_theta)
    if chunk is not None and T > chunk and T % chunk == 0:
        ctx = chunked_bidir_attention(q, k, v, chunk)
        return jnp.einsum("bthk,hkd->btd", ctx, p["wo"])
    KV = k.shape[2]
    qg = _group(q, KV)
    s = jnp.einsum("btkgh,bskh->bkgts", qg, k).astype(jnp.float32)
    s = s * (q.shape[-1] ** -0.5)
    pr = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    ctx = jnp.einsum("bkgts,bskh->btkgh", pr, v)
    return jnp.einsum(
        "bthk,hkd->btd", ctx.reshape(B, T, q.shape[2], q.shape[3]), p["wo"]
    )


def cross_attention(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,             # [B, Tq, d] decoder states
    enc: jnp.ndarray,           # [B, Te, d] encoder states
) -> jnp.ndarray:
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", enc, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", enc, p["wv"])
    KV = k.shape[2]
    qg = _group(q, KV)
    s = jnp.einsum("btkgh,bskh->bkgts", qg, k).astype(jnp.float32)
    s = s * (q.shape[-1] ** -0.5)
    pr = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    ctx = jnp.einsum("bkgts,bskh->btkgh", pr, v)
    B, Tq = x.shape[:2]
    return jnp.einsum(
        "bthk,hkd->btd", ctx.reshape(B, Tq, q.shape[2], q.shape[3]), p["wo"]
    )
