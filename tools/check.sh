#!/usr/bin/env bash
# CI gate (also the local pre-push check): tier-1 tests + smoke benchmarks.
#
#   tools/check.sh            # everything
#   tools/check.sh --tests    # tier-1 pytest only
#   tools/check.sh --bench    # smoke benchmarks only
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

run_tests=1
run_bench=1
case "${1:-}" in
  --tests) run_bench=0 ;;
  --bench) run_tests=0 ;;
  "") ;;
  *) echo "usage: tools/check.sh [--tests|--bench]" >&2; exit 2 ;;
esac

if [[ $run_tests -eq 1 ]]; then
  echo "== tier-1 tests =="
  python -m pytest -x -q
fi

if [[ $run_bench -eq 1 ]]; then
  echo "== smoke benchmarks (kernels + serve + stream) =="
  python -m benchmarks.run --smoke
fi

echo "check.sh: OK"
