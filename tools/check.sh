#!/usr/bin/env bash
# CI gate (also the local pre-push check): tier-1 tests + smoke benchmarks
# + the 4-host-device distributed-mining parity gate.
#
#   tools/check.sh            # everything
#   tools/check.sh --tests    # tier-1 pytest only
#   tools/check.sh --bench    # smoke benchmarks only
#   tools/check.sh --cluster  # 4-device cluster parity only
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

run_tests=1
run_bench=1
run_cluster=1
case "${1:-}" in
  --tests) run_bench=0; run_cluster=0 ;;
  --bench) run_tests=0; run_cluster=0 ;;
  --cluster) run_tests=0; run_bench=0 ;;
  "") ;;
  *) echo "usage: tools/check.sh [--tests|--bench|--cluster]" >&2; exit 2 ;;
esac

if [[ $run_tests -eq 1 ]]; then
  echo "== tier-1 tests =="
  python -m pytest -x -q
fi

if [[ $run_bench -eq 1 ]]; then
  echo "== smoke benchmarks (kernels + serve + stream + cluster) =="
  python -m benchmarks.run --smoke
fi

if [[ $run_cluster -eq 1 ]]; then
  echo "== cluster parity on 4 simulated host devices =="
  # --devices sets the XLA host-device-count flag before jax imports
  # (launch/host_devices.py); --parity exits non-zero on any FI mismatch
  python -m repro.launch.cluster_mine --devices 4 -P 4 \
    --db T0.5I0.024P8PL5TL8 --support 0.08 --parity
fi

echo "check.sh: OK"
