#!/usr/bin/env bash
# CI gate (also the local pre-push check): tier-1 tests + smoke benchmarks
# + the 4-host-device distributed-mining parity gate + the out-of-core
# store parity gate + the fault-injection gate (kill-and-resume parity).
#
#   tools/check.sh            # everything
#   tools/check.sh --tests    # tier-1 pytest only
#   tools/check.sh --bench    # smoke benchmarks only
#   tools/check.sh --cluster  # 4-device cluster parity only
#   tools/check.sh --store    # out-of-core store parity only
#   tools/check.sh --faults   # fault-injection suite + kill/resume parity
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

run_tests=1
run_bench=1
run_cluster=1
run_store=1
run_faults=1
case "${1:-}" in
  --tests) run_bench=0; run_cluster=0; run_store=0; run_faults=0 ;;
  --bench) run_tests=0; run_cluster=0; run_store=0; run_faults=0 ;;
  --cluster) run_tests=0; run_bench=0; run_store=0; run_faults=0 ;;
  --store) run_tests=0; run_bench=0; run_cluster=0; run_faults=0 ;;
  --faults) run_tests=0; run_bench=0; run_cluster=0; run_store=0 ;;
  "") ;;
  *) echo "usage: tools/check.sh [--tests|--bench|--cluster|--store|--faults]" >&2; exit 2 ;;
esac

if [[ $run_tests -eq 1 ]]; then
  echo "== tier-1 tests =="
  python -m pytest -x -q
fi

if [[ $run_bench -eq 1 ]]; then
  echo "== smoke benchmarks (kernels + serve + stream + cluster + io) =="
  python -m benchmarks.run --smoke
fi

if [[ $run_cluster -eq 1 ]]; then
  echo "== cluster parity on 4 simulated host devices =="
  # --devices sets the XLA host-device-count flag before jax imports
  # (launch/host_devices.py); --parity exits non-zero on any FI mismatch
  python -m repro.launch.cluster_mine --devices 4 -P 4 \
    --db T0.5I0.024P8PL5TL8 --support 0.08 --parity
fi

if [[ $run_store -eq 1 ]]; then
  echo "== out-of-core store parity (block-streamed mine vs dense in-RAM) =="
  # spills the IBM DB to a store of 8x64tx blocks — bigger than the 2-block
  # host budget — mines it through the double-buffered reader, and requires
  # a bit-exact FITable vs the dense path (exits non-zero on any mismatch)
  python -m repro.launch.mine --db T0.5I0.024P8PL5TL8 --support 0.08 \
    --store "$(mktemp -d)" --blocktx 64 --parity
fi

if [[ $run_faults -eq 1 ]]; then
  echo "== fault injection: integrity / retry / fsck / checkpoint suite =="
  python -m pytest -x -q tests/test_faults.py
  echo "== fault injection: kill-after-round + resume, bit-exact parity =="
  # a distributed mine is killed (exit 0) right after round 0's checkpoint,
  # then resumed from disk; --parity requires the finished FITable to be
  # bit-exact vs an uninterrupted single-device fimi.run
  CKPT="$(mktemp -d)/ck"
  python -m repro.launch.cluster_mine --db T0.5I0.024P8PL5TL8 \
    --support 0.08 -P 4 --chunk 1 --checkpoint "$CKPT" --kill-after-round 0
  python -m repro.launch.cluster_mine --db T0.5I0.024P8PL5TL8 \
    --support 0.08 -P 4 --chunk 1 --checkpoint "$CKPT" --resume --parity
fi

echo "check.sh: OK"
