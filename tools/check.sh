#!/usr/bin/env bash
# CI gate (also the local pre-push check): tier-1 tests + smoke benchmarks
# + the 4-host-device distributed-mining parity gate + the out-of-core
# store parity gate + the fault-injection gate (kill-and-resume parity)
# + the observability gate (traced run record + regression-gated report)
# + the serving SLO gate (load harness within SLO + overload self-test)
# + the kernel-profile gate (all five families attributed, model-consistent)
# + the perf-trajectory gate (BENCH_HISTORY.jsonl trend regression)
# + the doctor gate (critical path + speedup waterfall + injected-fault
#   self-tests: forced skew and a starved store prefetcher must both be
#   diagnosed, loudly).
#
#   tools/check.sh            # everything
#   tools/check.sh --tests    # tier-1 pytest only
#   tools/check.sh --bench    # smoke benchmarks + perf-trajectory gate only
#   tools/check.sh --cluster  # 4-device cluster parity only
#   tools/check.sh --store    # out-of-core store parity only
#   tools/check.sh --faults   # fault-injection suite + kill/resume parity
#   tools/check.sh --obs      # observability suite + trace/report gates
#   tools/check.sh --serve    # serving SLO gate + overload self-test
#   tools/check.sh --profile  # kernel-profiled mine + attribution gates
#   tools/check.sh --doctor   # performance-doctor diagnosis + self-tests
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

run_tests=1
run_bench=1
run_cluster=1
run_store=1
run_faults=1
run_obs=1
run_serve=1
run_profile=1
run_doctor=1
case "${1:-}" in
  --tests) run_bench=0; run_cluster=0; run_store=0; run_faults=0; run_obs=0; run_serve=0; run_profile=0; run_doctor=0 ;;
  --bench) run_tests=0; run_cluster=0; run_store=0; run_faults=0; run_obs=0; run_serve=0; run_profile=0; run_doctor=0 ;;
  --cluster) run_tests=0; run_bench=0; run_store=0; run_faults=0; run_obs=0; run_serve=0; run_profile=0; run_doctor=0 ;;
  --store) run_tests=0; run_bench=0; run_cluster=0; run_faults=0; run_obs=0; run_serve=0; run_profile=0; run_doctor=0 ;;
  --faults) run_tests=0; run_bench=0; run_cluster=0; run_store=0; run_obs=0; run_serve=0; run_profile=0; run_doctor=0 ;;
  --obs) run_tests=0; run_bench=0; run_cluster=0; run_store=0; run_faults=0; run_serve=0; run_profile=0; run_doctor=0 ;;
  --serve) run_tests=0; run_bench=0; run_cluster=0; run_store=0; run_faults=0; run_obs=0; run_profile=0; run_doctor=0 ;;
  --profile) run_tests=0; run_bench=0; run_cluster=0; run_store=0; run_faults=0; run_obs=0; run_serve=0; run_doctor=0 ;;
  --doctor) run_tests=0; run_bench=0; run_cluster=0; run_store=0; run_faults=0; run_obs=0; run_serve=0; run_profile=0 ;;
  "") ;;
  *) echo "usage: tools/check.sh [--tests|--bench|--cluster|--store|--faults|--obs|--serve|--profile|--doctor]" >&2; exit 2 ;;
esac

if [[ $run_tests -eq 1 ]]; then
  echo "== tier-1 tests =="
  python -m pytest -x -q
fi

if [[ $run_bench -eq 1 ]]; then
  echo "== smoke benchmarks (kernels + serve + stream + cluster + io) =="
  # every invocation appends one stamped BENCH_HISTORY.jsonl row per suite
  python -m benchmarks.run --smoke
  echo "== perf trajectory: trend regression vs trailing median =="
  # the committed ledger rows come from other machines, so the absolute-
  # timing keys carry cross-host variance; the gate flags catastrophic
  # drift (> 2.5x the trailing median), not noise.  Tighten locally with
  # a longer same-host history: obs_report regress --threshold 0.25
  python -m repro.launch.obs_report regress --history BENCH_HISTORY.jsonl \
    --threshold 1.5
  # the gate must be able to fire: a synthetic 4x degradation of every
  # newest value has to trip it (exit 1) — a pass here means it is broken
  if python -m repro.launch.obs_report regress --history BENCH_HISTORY.jsonl \
      --threshold 1.5 --degrade 4.0 >/dev/null 2>&1; then
    echo "perf-trajectory gate FAILED: synthetic 4x degradation not detected" >&2
    exit 1
  fi
fi

if [[ $run_cluster -eq 1 ]]; then
  echo "== cluster parity on 4 simulated host devices =="
  # --devices sets the XLA host-device-count flag before jax imports
  # (launch/host_devices.py); --parity exits non-zero on any FI mismatch
  python -m repro.launch.cluster_mine --devices 4 -P 4 \
    --db T0.5I0.024P8PL5TL8 --support 0.08 --parity
fi

if [[ $run_store -eq 1 ]]; then
  echo "== out-of-core store parity (block-streamed mine vs dense in-RAM) =="
  # spills the IBM DB to a store of 8x64tx blocks — bigger than the 2-block
  # host budget — mines it through the double-buffered reader, and requires
  # a bit-exact FITable vs the dense path (exits non-zero on any mismatch)
  python -m repro.launch.mine --db T0.5I0.024P8PL5TL8 --support 0.08 \
    --store "$(mktemp -d)" --blocktx 64 --parity
fi

if [[ $run_faults -eq 1 ]]; then
  echo "== fault injection: integrity / retry / fsck / checkpoint suite =="
  python -m pytest -x -q tests/test_faults.py
  echo "== fault injection: kill-after-round + resume, bit-exact parity =="
  # a distributed mine is killed (exit 0) right after round 0's checkpoint,
  # then resumed from disk; --parity requires the finished FITable to be
  # bit-exact vs an uninterrupted single-device fimi.run
  CKPT="$(mktemp -d)/ck"
  python -m repro.launch.cluster_mine --db T0.5I0.024P8PL5TL8 \
    --support 0.08 -P 4 --chunk 1 --checkpoint "$CKPT" --kill-after-round 0
  python -m repro.launch.cluster_mine --db T0.5I0.024P8PL5TL8 \
    --support 0.08 -P 4 --chunk 1 --checkpoint "$CKPT" --resume --parity
fi

if [[ $run_obs -eq 1 ]]; then
  echo "== observability: metrics/tracer/runlog/report suite =="
  python -m pytest -x -q tests/test_obs.py
  echo "== observability: traced cluster mine -> Perfetto-loadable record =="
  # a traced distributed mine must produce a complete run record: manifest,
  # events, metrics snapshot (per-shard est/obs load), Chrome trace JSON
  OBS_RUN="${OBS_RUN_DIR:-$(mktemp -d)/obs-run}"
  python -m repro.launch.cluster_mine --db T0.5I0.024P8PL5TL8 \
    --support 0.08 -P 4 --chunk 1 --trace "$OBS_RUN"
  python -m repro.launch.obs_report summary "$OBS_RUN"
  echo "== observability: diff gate (self-pass + injected-slowdown fail) =="
  python -m repro.launch.obs_report diff "$OBS_RUN" "$OBS_RUN"
  SLOW="$(mktemp -d)/obs-slow"
  python -m repro.launch.obs_report inject-slowdown "$OBS_RUN" "$SLOW" \
    --factor 1.5
  # the injected regression MUST trip the gate (exit 1) — a silent pass
  # here means the regression detector is broken
  if python -m repro.launch.obs_report diff "$OBS_RUN" "$SLOW" \
      --threshold 0.2; then
    echo "obs gate FAILED: injected 1.5x slowdown was not detected" >&2
    exit 1
  fi
  echo "== observability: benchmark overhead baselines =="
  # parity-type overhead ratios (checksum, obs instrumentation) must stay
  # within 5% of their no-op baselines in the recorded BENCH files
  if ls BENCH_*.json >/dev/null 2>&1; then
    python -m repro.launch.obs_report baseline --match overhead \
      --threshold 0.05 $(ls BENCH_*.json | sed 's/^/--bench /')
  else
    echo "(no BENCH_*.json yet — run tools/check.sh --bench first)"
  fi
fi

if [[ $run_serve -eq 1 ]]; then
  echo "== serving: SLO/service suites =="
  python -m pytest -x -q tests/test_slo.py tests/test_service.py \
    tests/test_serve_load.py
  echo "== serving: SLO-gated load harness at modest QPS =="
  # a traced, gated load run must sustain the target within the windowed
  # p99 objective (exit 0), record slo_* keys into BENCH_serve.json, and
  # leave a Perfetto-loadable per-request timeline in the run record
  SERVE_RUN="${SERVE_RUN_DIR:-$(mktemp -d)/serve-run}"
  python -m repro.launch.serve_load --qps 200 --duration 5 --ramp 2 \
    --window 3 --gate --no-dashboard --compare-dispatch \
    --trace "$SERVE_RUN"
  python -m repro.launch.obs_report summary "$SERVE_RUN"
  # the timeline must contain the device-sweep spans of the request chain
  if ! grep -q 'service/sweep' "$SERVE_RUN/trace.json"; then
    echo "serve gate FAILED: no service/sweep spans in trace" >&2
    exit 1
  fi
  echo "== serving: injected overload must trip the burn-rate alert =="
  # a target far past capacity with a tiny queue must shed, burn the error
  # budget, fire the alert, and exit non-zero — a pass here means the SLO
  # alerting is broken
  if python -m repro.launch.serve_load --qps 50000 --max-queue 64 \
      --duration 4 --ramp 1 --window 2 --gate --no-dashboard \
      --bench-out ""; then
    echo "serve gate FAILED: injected overload did not trip the SLO" >&2
    exit 1
  fi
fi

if [[ $run_profile -eq 1 ]]; then
  echo "== kernel profile: profiled demo mine (all five families) =="
  # a profiled run must attribute every dispatch family: eager sweeps give
  # per-call device-synced timing, the mine's while_loop work is loop-
  # attributed; the record carries it all as kernels/* gauges
  PROF_RUN="${PROF_RUN_DIR:-$(mktemp -d)/prof-run}"
  python -m repro.launch.profile_demo --db T0.5I0.024P8PL5TL8 \
    --support 0.08 -P 2 --trace "$PROF_RUN"
  python -m repro.launch.obs_report kernels "$PROF_RUN" \
    --require bitmap,multi,pair,subset,delta --check-model
  echo "== kernel profile: injected model mismatch must fail the check =="
  # scaling only the compute_ms gauges breaks modeled = max(compute, memory)
  # against the published flop/byte/constant gauges — the consistency check
  # must catch it (exit 1); a silent pass means --check-model is broken
  PROF_BAD="$(mktemp -d)/prof-bad"
  python -m repro.launch.obs_report inject-slowdown "$PROF_RUN" "$PROF_BAD" \
    --factor 1.5 --match compute_ms
  if python -m repro.launch.obs_report kernels "$PROF_BAD" --check-model \
      >/dev/null 2>&1; then
    echo "profile gate FAILED: injected model mismatch was not detected" >&2
    exit 1
  fi
fi

if [[ $run_doctor -eq 1 ]]; then
  echo "== doctor: critpath / speedup / doctor suites =="
  python -m pytest -x -q tests/test_critpath.py tests/test_speedup.py \
    tests/test_doctor.py
  echo "== doctor: diagnosis of a healthy traced cluster mine =="
  # the acceptance contract: a traced cluster mine must yield a critical-
  # path table, a speedup waterfall whose terms sum to (ideal - measured)
  # within 5%, and the imbalance + Thm 6.1 estimation findings keyed to
  # the paper's own gauges
  DOC_RUN="${DOC_RUN_DIR:-$(mktemp -d)/doc-run}"
  python -m repro.launch.cluster_mine --db T0.5I0.024P8PL5TL8 \
    --support 0.08 -P 4 --trace "$DOC_RUN"
  python -m repro.launch.obs_report doctor "$DOC_RUN"
  python -m repro.launch.obs_report doctor "$DOC_RUN" --format json \
    > "$DOC_RUN/doctor.json"
  python - "$DOC_RUN/doctor.json" <<'PY'
import json, sys
r = json.load(open(sys.argv[1]))
assert r["critpath"]["table"], "no critical-path table"
err = r["waterfall"]["additivity_err"]
assert err < 0.05, f"waterfall terms do not sum to the gap: err={err:.3f}"
rules = {f["rule"] for f in r["findings"]}
need = {"cluster-imbalance", "thm61-estimation-error"}
assert need <= rules, f"missing findings: {sorted(need - rules)}"
print(f"doctor OK: {len(r['findings'])} finding(s), "
      f"waterfall additivity err {err:.4f}")
PY
  echo "== doctor: forced skew must be diagnosed (gate exits non-zero) =="
  # every class piled onto shard 0 with rebalancing pinned off: the doctor
  # must blame the imbalance term and raise rebalance-not-engaging at
  # error severity — a passing --gate here means the diagnosis is broken
  SKEW_RUN="$(mktemp -d)/skew-run"
  python -m repro.launch.cluster_mine --db T0.5I0.024P8PL5TL8 \
    --support 0.08 -P 4 --force-skew --trace "$SKEW_RUN" >/dev/null
  if python -m repro.launch.obs_report doctor "$SKEW_RUN" --gate \
      >/dev/null 2>&1; then
    echo "doctor gate FAILED: forced skew did not trip --gate" >&2
    exit 1
  fi
  python -m repro.launch.obs_report doctor "$SKEW_RUN" --format json \
    > "$SKEW_RUN/doctor.json"
  grep -q '"rebalance-not-engaging"' "$SKEW_RUN/doctor.json" || {
    echo "doctor gate FAILED: forced skew run has no" \
      "rebalance-not-engaging finding" >&2
    exit 1
  }
  echo "== doctor: starved store prefetcher must be diagnosed =="
  # a 50 ms injected read delay against a 2-block host budget puts store
  # reads on the critical path: the prefetch-stall finding must appear
  STALL_RUN="$(mktemp -d)/stall-run"
  REPRO_STORE_READ_DELAY_S=0.05 python -m repro.launch.mine \
    --db T0.5I0.024P8PL5TL8 --support 0.08 --store "$(mktemp -d)" \
    --blocktx 64 --budget-blocks 2 --trace "$STALL_RUN" >/dev/null
  python -m repro.launch.obs_report doctor "$STALL_RUN" --format json \
    > "$STALL_RUN/doctor.json"
  grep -q '"prefetch-stall"' "$STALL_RUN/doctor.json" || {
    echo "doctor gate FAILED: starved prefetcher run has no" \
      "prefetch-stall finding" >&2
    exit 1
  }
fi

echo "check.sh: OK"
