"""core/rules.py: ap-genrules vs brute-force enumeration + metric values."""
import numpy as np
import pytest

from repro.core import eclat
from repro.core import rules as R


# ---------------------------------------------------------------------------
# Hand-checked toy database (the classic market-basket example)
# ---------------------------------------------------------------------------

# items: 0=bread 1=milk 2=diaper 3=beer 4=cola 5=eggs
TOY = np.zeros((5, 6), bool)
for t, items in enumerate([
    {0, 1}, {0, 2, 3, 5}, {1, 2, 3, 4}, {0, 1, 2, 3}, {0, 1, 2, 4},
]):
    TOY[t, list(items)] = True


def toy_fis():
    return eclat.brute_force_fis(TOY, 1)  # minsup 1: every occurring itemset


def test_toy_metrics_hand_checked():
    fis = toy_fis()
    rules = {r.key(): r for r in R.generate_rules(fis, 5, 0.5)}

    # {beer} -> {diaper}: supp({2,3})=3, supp({3})=3, supp({2})=4
    r = rules[(frozenset({3}), frozenset({2}))]
    assert r.support == 3
    assert r.confidence == pytest.approx(1.0)
    assert r.lift == pytest.approx(1.0 / (4 / 5))          # 1.25
    assert r.leverage == pytest.approx(3 / 5 - (3 / 5) * (4 / 5))  # 0.12

    # {diaper} -> {beer}: conf 3/4, lift (3/4)/(3/5), leverage symmetric
    r = rules[(frozenset({2}), frozenset({3}))]
    assert r.confidence == pytest.approx(3 / 4)
    assert r.lift == pytest.approx((3 / 4) / (3 / 5))
    assert r.leverage == pytest.approx(0.12)

    # {milk} -> {bread}: supp({0,1})=3, supp({1})=4 -> conf 0.75, lift
    # 0.75/0.8 < 1 (negatively correlated), leverage negative
    r = rules[(frozenset({1}), frozenset({0}))]
    assert r.confidence == pytest.approx(3 / 4)
    assert r.lift == pytest.approx((3 / 4) / (4 / 5))
    assert r.lift < 1 and r.leverage < 0

    # conf below threshold is absent: {bread} -> {cola} has conf 1/4
    assert (frozenset({0}), frozenset({4})) not in rules
    # conf exactly at threshold is kept: {bread} -> {beer} has conf 2/4
    assert (frozenset({0}), frozenset({3})) in rules


def test_toy_multi_item_consequent():
    """ap-genrules reaches |consequent| >= 2 (the apriori-join recursion)."""
    fis = toy_fis()
    rules = {r.key(): r for r in R.generate_rules(fis, 5, 0.5)}
    # {beer} -> {milk? no} ... take Z={1,2,4}: supp=2, X={4}: supp({4})=2
    r = rules[(frozenset({4}), frozenset({1, 2}))]
    assert r.support == 2 and r.confidence == pytest.approx(1.0)
    assert any(len(k[1]) >= 2 for k in rules)


@pytest.mark.parametrize("seed,min_conf", [
    (0, 0.3), (0, 0.7), (1, 0.5), (2, 0.9), (3, 0.5),
])
def test_ap_genrules_matches_brute_force(seed, min_conf):
    from repro.data.ibm_gen import IBMParams, generate_dense

    dense = generate_dense(
        IBMParams(n_tx=256, n_items=18, n_patterns=6, avg_pattern_len=5,
                  avg_tx_len=7, seed=seed)
    )
    n_tx = dense.shape[0]
    fis = eclat.brute_force_fis(dense, int(np.ceil(0.08 * n_tx)))
    got = {r.key(): r for r in R.generate_rules(fis, n_tx, min_conf)}
    want = R.brute_force_rules(fis, n_tx, min_conf)
    assert set(got) == set(want)
    for k, r in got.items():
        assert r.support == want[k].support
        assert r.confidence == pytest.approx(want[k].confidence)
        assert r.lift == pytest.approx(want[k].lift)
        assert r.leverage == pytest.approx(want[k].leverage)


def test_generate_rules_empty_and_singletons():
    assert R.generate_rules({}, 10, 0.5) == []
    assert R.generate_rules({frozenset({1}): 5}, 10, 0.5) == []


def test_rule_table_sorted_and_roundtrips():
    fis = toy_fis()
    rules = R.generate_rules(fis, 5, 0.5)
    table = R.RuleTable.from_rules(rules, 6, 5)
    assert table.n_rules == len(rules)
    conf = table.confidence
    assert (conf[:-1] >= conf[1:]).all()  # sorted descending
    # support breaks confidence ties
    for i in range(table.n_rules - 1):
        if conf[i] == conf[i + 1]:
            assert table.supports[i] >= table.supports[i + 1]
    # pack/unpack roundtrip preserves the rule set
    got = {table.rule(i).key() for i in range(table.n_rules)}
    assert got == {r.key() for r in rules}


def test_pack_itemsets_layout():
    """pack_itemsets (host) matches core.bitmap.pack_bool (device layout)."""
    import jax.numpy as jnp

    from repro.core import bitmap as bm

    sets = [frozenset({0, 31, 32, 63, 64}), frozenset(), frozenset({65})]
    n_items = 70
    packed = R.pack_itemsets(sets, n_items)
    dense = np.zeros((3, n_items), bool)
    for r, s in enumerate(sets):
        dense[r, list(s)] = True
    want = np.asarray(bm.pack_bool(jnp.asarray(dense)))
    np.testing.assert_array_equal(packed, want)


def test_top_rules_and_format():
    fis = toy_fis()
    rules = R.generate_rules(fis, 5, 0.5)
    top = R.top_rules(rules, 3)
    assert len(top) == 3
    assert top[0].confidence == max(r.confidence for r in rules)
    line = R.format_rule(top[0], 5)
    assert "->" in line and "conf=" in line and "lift=" in line
