"""Per-architecture smoke tests (reduced configs): one forward + one train
step + one decode step on CPU, asserting shapes and finiteness — the
assignment's required smoke coverage for all 10 architectures."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, shapes_for
from repro.configs.registry import all_archs, get_config
from repro.models import model as M
from repro.models import steps
from repro.optim import adamw

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, B=2, T=16):
    batch = {"tokens": jax.random.randint(KEY, (B, T), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            KEY, (B, cfg.vision_tokens, cfg.d_model), jnp.float32
        )
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            KEY, (B, cfg.enc_context, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", all_archs())
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = M.init(cfg, KEY)
    batch = _batch_for(cfg)
    logits = M.forward(cfg, params, batch)
    T_out = batch["tokens"].shape[1] + (
        cfg.vision_tokens if cfg.family == "vlm" else 0
    )
    assert logits.shape == (2, T_out, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    opt_cfg = adamw.AdamWConfig(lr=1e-3)
    step = steps.make_train_step(cfg, opt_cfg, accum=1)
    opt = adamw.init(params, opt_cfg)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(
            lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).sum()),
            params, params2,
        ),
    )
    assert delta > 0


@pytest.mark.parametrize("arch", all_archs())
def test_smoke_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    params = M.init(cfg, KEY)
    B = 2
    cache = M.init_cache(cfg, B, 32, jnp.float32)
    if cfg.family == "encdec":
        frames = jax.random.normal(KEY, (B, cfg.enc_context, cfg.d_model), jnp.float32)
        cache = M.encode(cfg, params, frames, cache)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache = M.decode_step(cfg, params, cache, tok, jnp.asarray(0, jnp.int32))
    assert logits.shape == (B, 1, cfg.vocab_padded)
    lg = np.asarray(logits, np.float32)
    assert np.isfinite(lg[..., : cfg.vocab]).all()
    # padded vocab tail is masked out of decoding
    if cfg.vocab_padded > cfg.vocab:
        assert (lg[..., cfg.vocab :] < -1e29).all()


@pytest.mark.parametrize("arch", all_archs())
def test_decode_matches_forward(arch):
    """Greedy parity: step-by-step decode logits == full forward logits."""
    cfg = get_config(arch, smoke=True)
    params = M.init(cfg, KEY)
    B, T = 2, 8
    batch = _batch_for(cfg, B, T)
    full = M.forward(cfg, params, batch)
    if cfg.family == "vlm":
        pytest.skip("vlm decode starts from a prefilled cache (covered above)")
    cache = M.init_cache(cfg, B, 16, jnp.float32)
    if cfg.family == "encdec":
        cache = M.encode(cfg, params, batch["frames"], cache)
    outs = []
    for t in range(T):
        lg, cache = M.decode_step(
            cfg, params, cache, batch["tokens"][:, t : t + 1],
            jnp.asarray(t, jnp.int32),
        )
        outs.append(lg)
    stepwise = jnp.concatenate(outs, axis=1)
    err = np.abs(
        np.asarray(full, np.float32)[..., : cfg.vocab]
        - np.asarray(stepwise, np.float32)[..., : cfg.vocab]
    ).max()
    assert err < 2e-2, f"{arch}: decode/forward divergence {err}"


def test_full_configs_match_nominal_size():
    expected = {
        "granite-20b": 20.3, "starcoder2-15b": 16.0, "minicpm3-4b": 4.3,
        "llama3.2-3b": 3.2, "jamba-1.5-large-398b": 398.6, "mamba2-1.3b": 1.3,
        "qwen2-moe-a2.7b": 14.3, "olmoe-1b-7b": 6.9, "internvl2-26b": 19.9,
        "whisper-small": 0.24,
    }
    for arch, want in expected.items():
        n = M.n_params(get_config(arch)) / 1e9
        assert abs(n - want) / want < 0.05, (arch, n, want)


def test_shapes_for_skips_long_on_full_attention():
    for arch in all_archs():
        cfg = get_config(arch)
        sh = shapes_for(cfg)
        if arch in ("jamba-1.5-large-398b", "mamba2-1.3b"):
            assert "long_500k" in sh
        else:
            assert "long_500k" not in sh
