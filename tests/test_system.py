"""End-to-end behaviour: generator naming, registry, phase-3 exchange unit."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import bitmap as bm, pbec, phases
from repro.data.ibm_gen import IBMParams, generate_dense, params_from_name


def test_ibm_name_roundtrip():
    p = params_from_name("T500I0.1P50PL10TL40")
    assert (p.n_tx, p.n_items, p.n_patterns) == (500_000, 100, 50)
    assert p.avg_pattern_len == 10 and p.avg_tx_len == 40
    q = IBMParams(n_tx=500_000, n_items=100, n_patterns=50,
                  avg_pattern_len=10, avg_tx_len=40)
    assert q.name == "T500I0.1P50PL10TL40"


def test_ibm_generator_statistics():
    p = IBMParams(n_tx=2000, n_items=100, n_patterns=20,
                  avg_pattern_len=8, avg_tx_len=20, seed=1)
    dense = generate_dense(p)
    lens = dense.sum(axis=1)
    assert lens.min() >= 1
    assert 5 < lens.mean() < 40  # corruption keeps it below TL but nonzero
    # deterministic
    np.testing.assert_array_equal(dense, generate_dense(p))


def test_registry_complete():
    from repro.configs.registry import all_archs, get_config

    assert len(all_archs()) == 10
    for a in all_archs():
        cfg = get_config(a)
        smoke = get_config(a, smoke=True)
        assert cfg.family == smoke.family


def test_phase3_exchange_unit(small_db):
    """Every processor receives exactly the transactions containing its
    assigned prefixes (Alg. 18 contract), via all_to_all under vmap."""
    dense, db, minsup, oracle = small_db
    P = 4
    T = dense.shape[0] // P
    from repro.core import fimi

    shards = fimi.shard_db(dense, P)
    I = db.n_items
    # 4 singleton classes, one per processor
    items = [0, 3, 5, 7]
    prefixes = np.zeros((4, I), bool)
    for c, it in enumerate(items):
        prefixes[c, it] = True
    pref_packed = np.asarray(bm.pack_bool(jnp.asarray(prefixes)))
    import functools

    p3 = functools.partial(phases.phase3_exchange, axis_name="p", capacity=T)
    out = jax.vmap(p3, axis_name="p")(
        shards,
        jnp.ones((P, T), jnp.bool_),
        jnp.broadcast_to(jnp.asarray(pref_packed), (P, 4, pref_packed.shape[-1])),
        jnp.ones((P, 4), jnp.bool_),
        jnp.broadcast_to(jnp.arange(4, dtype=jnp.int32), (P, 4)),
    )
    assert int(np.asarray(out.overflow).reshape(-1)[0]) == 0
    for proc in range(P):
        rows = np.asarray(out.slab[proc])
        valid = np.asarray(out.slab_valid[proc])
        got = rows[valid]
        want = dense[: P * T][dense[: P * T][:, items[proc]]]
        # every received row contains the item; count matches global count
        dmask = np.asarray(bm.unpack_bool(jnp.asarray(got), I))
        assert dmask[:, items[proc]].all()
        assert len(got) == len(want)
    # replication factor = sum of per-item covers / |D|
    covers = sum(dense[: P * T][:, it].sum() for it in items)
    np.testing.assert_allclose(
        float(np.asarray(out.replication).reshape(-1)[0]),
        covers / (P * T), rtol=1e-5,
    )
