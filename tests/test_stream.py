"""Streaming subsystem: delta kernel parity, ring-buffer invariant, drift
monitor (Thm 6.1), hot-swap/cache generation, StreamingMiner end to end."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import bitmap as bm
from repro.core import eclat, sampling
from repro.data.ibm_gen import IBMParams, drifting_stream
from repro.kernels import delta_support as ds
from repro.kernels import ops, ref
from repro.serve import FIIndex, QueryCache, QueryEngine
from repro.serve.cache import query_key
from repro.stream import (
    DriftMonitor,
    SlidingWindow,
    StreamingMiner,
    StreamParams,
)
from repro.stream.monitor import chernoff_eps


def _pack(dense) -> np.ndarray:
    return np.asarray(bm.pack_bool(jnp.asarray(dense)))


def _random_blocks(s, t, n_items, seed, density=0.3):
    rng = np.random.default_rng(seed)
    dense = rng.random((s, t, n_items)) < density
    return dense, jnp.asarray(_pack(dense))


# ---------------------------------------------------------------------------
# delta_support kernel: interpret-mode parity vs the jnp oracle
# ---------------------------------------------------------------------------

# ragged (S, T, F, n_items): sub-tile, tile-aligned, prime, multi-word masks
BLOCK_SHAPES = [
    (1, 1, 1, 5),
    (2, 7, 33, 17),
    (2, 64, 128, 32),
    (3, 13, 57, 40),
    (2, 130, 257, 96),
]


@pytest.mark.parametrize("s,t,f,n_items", BLOCK_SHAPES)
def test_delta_kernel_parity(s, t, f, n_items):
    txd, txp = _random_blocks(s, t, n_items, seed=s * t + f, density=0.4)
    fid, fip = _random_blocks(1, f, n_items, seed=f + 1, density=0.15)
    fid, fip = fid[0], fip[0]
    # edge cases: the empty itemset and an empty transaction row
    if f > 2:
        fid[1] = False
        fip = jnp.asarray(_pack(fid))
    if t > 2:
        txd[0, 1] = False
        txp = jnp.asarray(_pack(txd))
    want = ref.block_itemset_supports_ref(txp, fip)
    got = ds.block_itemset_supports_pallas(txp, fip, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # dense-bool containment semantics
    contained = ~(fid[None, None, :, :] & ~txd[:, :, None, :]).any(-1)
    np.testing.assert_array_equal(np.asarray(want), contained.sum(axis=1))


@pytest.mark.parametrize("block_f,block_t", [(8, 8), (16, 64), (128, 128)])
def test_delta_kernel_block_shapes(block_f, block_t):
    _, txp = _random_blocks(2, 27, 53, seed=1)
    _, fip = _random_blocks(1, 91, 53, seed=2)
    want = ref.block_itemset_supports_ref(txp, fip[0])
    got = ds.block_itemset_supports_pallas(
        txp, fip[0], block_f=block_f, block_t=block_t, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_delta_ops_dispatch_and_sign():
    txd, txp = _random_blocks(2, 16, 24, seed=3)
    _, fip = _random_blocks(1, 9, 24, seed=4, density=0.2)
    fip = fip[0]
    a = ops.block_itemset_supports(txp, fip)
    b = ops.block_itemset_supports(txp, fip, force="interpret")
    c = ops.block_itemset_supports(txp, fip, force="ref")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
    # delta_supports stacks (arrive, expire) on the S axis, in that order
    d = ops.delta_supports(txp[0], txp[1], fip)
    np.testing.assert_array_equal(np.asarray(d), np.asarray(a))


# ---------------------------------------------------------------------------
# Sliding window ring buffer
# ---------------------------------------------------------------------------


def test_window_admit_expire_ring_order():
    n_items, T, B = 16, 8, 3
    dense, packed = _random_blocks(7, T, n_items, seed=5)
    w = SlidingWindow.empty(B, T, n_items)
    assert w.count == 0 and not w.full and w.n_tx == 0
    logical = []   # python model of the window
    for i in range(7):
        w, expired = w.admit(packed[i])
        if len(logical) == B:
            oldest = logical.pop(0)
            np.testing.assert_array_equal(np.asarray(expired), oldest)
        else:
            assert expired is None
        logical.append(np.asarray(packed[i]))
        np.testing.assert_array_equal(
            np.asarray(w.stacked()), np.stack(logical)
        )
        np.testing.assert_array_equal(
            np.asarray(w.rows()), np.concatenate(logical)
        )
    assert w.full and w.n_tx == B * T


def test_window_delta_invariant():
    """Any admit sequence: delta-accumulated supports == full recompute."""
    n_items, T, B = 20, 32, 4
    rng = np.random.default_rng(9)
    _, fi_masks = _random_blocks(1, 11, n_items, seed=6, density=0.2)
    fi_masks = fi_masks[0]
    w = SlidingWindow.empty(B, T, n_items)
    acc = None
    for i in range(B + 6):
        dense = rng.random((T, n_items)) < rng.uniform(0.1, 0.5)
        block = jnp.asarray(_pack(dense))
        w, expired = w.admit(block)
        if expired is None:
            if w.full:   # window just filled: anchor the accumulator once
                acc = np.asarray(
                    ops.block_itemset_supports(w.stacked(), fi_masks)
                ).sum(axis=0)
            continue
        assert acc is not None
        counts = np.asarray(ops.delta_supports(block, expired, fi_masks))
        acc = acc + counts[0] - counts[1]
        full = np.asarray(
            ops.block_itemset_supports(w.stacked(), fi_masks)
        ).sum(axis=0)
        np.testing.assert_array_equal(acc, full)
    assert acc is not None


def test_window_to_bitmap_db_roundtrip():
    n_items, T, B = 12, 16, 2
    dense, packed = _random_blocks(B, T, n_items, seed=7)
    w = SlidingWindow.empty(B, T, n_items)
    for i in range(B):
        w, _ = w.admit(packed[i])
    db = w.to_bitmap_db()
    assert db.n_tx == B * T and db.n_items == n_items
    np.testing.assert_array_equal(
        np.asarray(db.dense()), dense.reshape(B * T, n_items)
    )


# ---------------------------------------------------------------------------
# Drift monitor: Thm 6.1 on a synthetic support step
# ---------------------------------------------------------------------------


def _bernoulli_block(t, n_items, item, p, rng):
    """Block where `item` appears in exactly round(p·t) rows (plus noise
    items so masks are non-trivial)."""
    dense = rng.random((t, n_items)) < 0.05
    dense[:, item] = False
    k = int(round(p * t))
    rows = rng.choice(t, size=k, replace=False)
    dense[rows, item] = True
    return _pack(dense)


def test_monitor_fires_on_support_step_within_thm61_bound():
    n_items, T, B = 8, 200, 4
    eps, delta = 0.2, 0.05
    mon = DriftMonitor(B, T, eps=eps, delta=delta, seed=0)
    # the monitor sizes its sample by Thm 6.1 at eps/2
    assert mon.rows_per_block * B >= sampling.db_sample_size(eps / 2, delta)
    rng = np.random.default_rng(1)
    mask = _pack(np.eye(n_items, dtype=bool)[:1])           # itemset {0}
    p0, p1 = 0.5, 0.9                                       # step > eps

    for _ in range(B):
        mon.admit(_bernoulli_block(T, n_items, 0, p0, rng))
    mon.rearm(np.asarray([p0]), minsup_rel=0.1)
    v = mon.check(jnp.asarray(mask))
    # fresh table: estimator error ≤ ε/2 w.p. ≥ 1−δ ⇒ no trigger
    assert not v.fired and v.max_err <= v.threshold
    assert v.eps_sample <= eps / 2

    for _ in range(B):                                      # window refreshes
        mon.admit(_bernoulli_block(T, n_items, 0, p1, rng))
    v = mon.check(jnp.asarray(mask))
    # true error |p1−p0| = 0.4 > ε ⇒ must fire, and the estimate itself is
    # within the Thm 6.1 bound of the true stepped support
    assert v.fired and v.reason == "error"
    est = mon.estimate_rel_supports(jnp.asarray(mask))[0]
    assert abs(est - p1) <= v.eps_sample


def test_monitor_border_crossing_and_hysteresis():
    n_items, T, B = 8, 64, 2
    # eps huge so the sampled error signal cannot fire; border is isolated
    mon = DriftMonitor(B, T, eps=2.0, delta=0.05, border_margin=0.05,
                       border_hysteresis=0.02, seed=0)
    rng = np.random.default_rng(2)
    for _ in range(B):
        mon.admit(_bernoulli_block(T, n_items, 0, 0.5, rng))
    masks = _pack(np.eye(n_items, dtype=bool)[:2])          # {0}, {1}
    served = np.asarray([0.5, 0.12])
    mon.rearm(served, minsup_rel=0.1)
    # {0} far from minsup -> untracked even if it collapses
    v = mon.check(jnp.asarray(masks), current_rel=np.asarray([0.02, 0.12]))
    assert not v.fired and v.n_border_crossed == 0
    # {1} tracked; dips below minsup but within hysteresis -> no fire
    v = mon.check(jnp.asarray(masks), current_rel=np.asarray([0.5, 0.09]))
    assert not v.fired
    # {1} clears minsup − hysteresis -> border fires
    v = mon.check(jnp.asarray(masks), current_rel=np.asarray([0.5, 0.07]))
    assert v.fired and v.reason == "border" and v.n_border_crossed == 1


def test_chernoff_eps_inverts_sample_size():
    for eps, delta in [(0.1, 0.05), (0.05, 0.1), (0.02, 0.01)]:
        n = sampling.db_sample_size(eps, delta)
        assert chernoff_eps(n, delta) <= eps
        assert chernoff_eps(n - 1, delta) > eps


# ---------------------------------------------------------------------------
# Hot-swap: cache invalidation + generation counter
# ---------------------------------------------------------------------------


def test_cache_clear_counts_invalidations():
    c = QueryCache(capacity=4)
    k = query_key("support", np.asarray([1], np.uint32), 0)
    c.put(k, "v")
    assert c.get(k) == "v" and len(c) == 1
    assert c.clear() == 1
    assert len(c) == 0 and c.stats.invalidations == 1
    assert c.get(k) is None          # data gone, counters survive
    assert c.stats.hits == 1 and c.stats.misses == 1


def test_engine_swap_bumps_generation_and_clears_cache(small_db):
    dense, db, minsup, oracle = small_db
    cache = QueryCache(capacity=64)
    idx1 = FIIndex.from_fi_dict(oracle, db.n_items, db.n_tx)
    # a second index with shifted supports (what a re-mine would publish)
    idx2 = FIIndex.from_fi_dict(
        {s: v + 1 for s, v in oracle.items()}, db.n_items, db.n_tx
    )
    engine = QueryEngine(idx1, batch=16, top_k=3, cache=cache)
    assert engine.generation == 0

    some = sorted(oracle, key=lambda s: (len(s), tuple(sorted(s))))[:4]
    masks = engine.pack(some)
    keys = [query_key("support", m, engine.top_k, engine.generation)
            for m in masks]
    res, miss = cache.split_batch(keys)
    cache.fill_batch(keys, res, miss, list(engine.support(masks)))
    assert len(cache) == len(some)

    gen = engine.swap_indexes(idx2)
    assert gen == 1 and engine.generation == 1
    assert len(cache) == 0 and cache.stats.invalidations == 1
    assert engine.index is idx2 and engine.stats()["generation"] == 1
    # generation-carrying keys make a stale hit structurally impossible:
    # even a raced-in old entry would live under the dead generation's key
    keys2 = [query_key("support", m, engine.top_k, engine.generation)
             for m in masks]
    assert set(keys).isdisjoint(keys2)
    res2, miss2 = cache.split_batch(keys2)
    assert miss2 == list(range(len(some)))   # nothing stale to hit
    got = engine.support(masks)
    np.testing.assert_array_equal(got, [oracle[s] + 1 for s in some])


def test_engine_swap_rejects_item_universe_change(small_db):
    dense, db, minsup, oracle = small_db
    engine = QueryEngine(FIIndex.from_fi_dict(oracle, db.n_items, db.n_tx))
    bad = FIIndex.from_fi_dict({}, db.n_items + 7, db.n_tx)
    with pytest.raises(AssertionError):
        engine.swap_indexes(bad)


# ---------------------------------------------------------------------------
# StreamingMiner end to end on a drifting stream
# ---------------------------------------------------------------------------


def _brute_mine(window, abs_minsup):
    dense = np.asarray(window.to_bitmap_db().dense())
    return eclat.brute_force_fis(dense, abs_minsup)


@pytest.fixture(scope="module")
def streamed():
    p = IBMParams(n_items=20, n_patterns=6, avg_pattern_len=4,
                  avg_tx_len=7, seed=3)
    sp = StreamParams(
        n_blocks=3, block_tx=64, min_support_rel=0.15, min_confidence=0.6,
        eps=0.12, delta=0.05, border_margin=0.03, border_hysteresis=0.02,
        cooldown_blocks=1, batch=32, seed=0,
    )
    sm = StreamingMiner(sp, p.n_items, mine_fn=_brute_mine)
    events = []
    stale_after_remine = []
    parity_checks = 0
    for block, segment in drifting_stream(
        p, n_blocks=10, block_tx=sp.block_tx, breaks=(5,)
    ):
        ev = sm.admit(block)
        # system-level delta invariant at every step the engine is live
        if sm.engine is not None and sm.engine.index.n_fis:
            np.testing.assert_array_equal(
                sm.exact_window_supports(), sm.current_supports
            )
        if ev.remined:
            stale_after_remine.append(sm.staleness())
            # torn-index check at the swap point: the freshly published
            # table must serve the window it was mined from, exactly
            dense = np.asarray(sm.window.to_bitmap_db().dense())
            oracle = eclat.brute_force_fis(dense, sm.abs_minsup)
            assert sm.engine.index.n_fis == len(oracle)
            sets = sorted(oracle, key=lambda s: (len(s), tuple(sorted(s))))
            for lo in range(0, len(sets), sm.engine.batch):
                part = sets[lo: lo + sm.engine.batch]
                np.testing.assert_array_equal(
                    sm.engine.support(sm.engine.pack(part)),
                    [oracle[s] for s in part],
                )
            parity_checks += 1
        events.append((ev, segment))
    return sm, events, stale_after_remine, parity_checks


def test_streaming_miner_initial_mine_and_drift_remine(streamed):
    sm, events, stale_after_remine, _ = streamed
    # engine comes up exactly when the window first fills
    assert all(e.generation == -1 for e, _ in events[:2])
    assert events[2][0].remined and events[2][0].remine_reason == "initial"
    # the scripted drift at block 5 causes at least one later re-mine
    post_drift = [e for e, seg in events if seg == 1 and e.remined]
    assert len(post_drift) >= 1
    assert all(e.remine_reason in ("error", "border") for e in post_drift)
    assert sm.stats.remines == sm.engine.generation + 1
    # a freshly re-mined table serves the exact window it was mined from
    assert stale_after_remine and all(s == 0.0 for s in stale_after_remine)


def test_streaming_miner_parity_at_every_swap(streamed):
    """Every swap passed the torn-index check (done in the fixture at the
    swap point): the published table served its mine-time window exactly,
    full membership and support values."""
    sm, _, _, parity_checks = streamed
    assert parity_checks == sm.stats.remines
    # between swaps the table is allowed to go stale, but the engine still
    # answers exactly what its (immutable) index claims — never torn state
    idx = sm.engine.index
    rows = np.asarray(idx.masks)[: idx.n_fis][:32]
    np.testing.assert_array_equal(
        sm.engine.support(rows), np.asarray(idx.supports)[: idx.n_fis][:32]
    )


def test_streaming_miner_cache_generation_isolation(streamed):
    sm, _, _, _ = streamed
    # every hot-swap invalidated the attached cache
    assert sm.cache.stats.invalidations == sm.engine.generation
    assert sm.engine.stats()["invalidations"] == sm.engine.generation


def test_drifting_stream_deterministic_and_segmented():
    p = IBMParams(n_items=16, n_patterns=5, avg_pattern_len=3,
                  avg_tx_len=6, seed=11)
    a = list(drifting_stream(p, n_blocks=6, block_tx=32, breaks=(2, 4)))
    b = list(drifting_stream(p, n_blocks=6, block_tx=32, breaks=(2, 4)))
    assert [s for _, s in a] == [0, 0, 1, 1, 2, 2]
    for (xa, sa), (xb, sb) in zip(a, b):
        assert sa == sb
        np.testing.assert_array_equal(xa, xb)
    # no-break stream reproduces the flat generator's distribution machinery
    flat = list(drifting_stream(p, n_blocks=2, block_tx=32))
    assert [s for _, s in flat] == [0, 0]
    assert flat[0][0].shape == (32, 16)


def test_drifting_stream_break_changes_distribution():
    p = IBMParams(n_items=24, n_patterns=8, avg_pattern_len=5,
                  avg_tx_len=8, seed=3)
    blocks = list(drifting_stream(p, n_blocks=8, block_tx=256, breaks=(4,)))
    f0 = np.concatenate([b for b, s in blocks if s == 0]).mean(axis=0)
    f1 = np.concatenate([b for b, s in blocks if s == 1]).mean(axis=0)
    # the re-drawn pool moves item frequencies by a detectable margin
    assert np.abs(f0 - f1).max() > 0.05
