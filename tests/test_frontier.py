"""Frontier-batched Eclat: parity with the K=1 oracle path and brute force,
trip-count reduction, and interaction with reservoir / count_only / seeds."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import bitmap as bm, eclat


def _to_dict(res, n_items):
    out = {}
    for k in range(int(res.n_out)):
        mask = np.asarray(bm.unpack_bool(res.items[k], n_items))
        out[frozenset(np.nonzero(mask)[0].tolist())] = int(res.supports[k])
    return out


@pytest.mark.parametrize("frontier", [1, 8, 64])
def test_frontier_mine_all_matches_bruteforce(small_db, frontier):
    """End-to-end: identical FIs + supports vs brute force at every K."""
    dense, db, minsup, oracle = small_db
    res = eclat.mine_all(
        db, minsup,
        config=eclat.EclatConfig(
            max_out=8192, max_stack=2048, frontier_size=frontier
        ),
    )
    assert int(res.stack_overflow) == 0
    assert int(res.n_total) == len(oracle)
    assert _to_dict(res, db.n_items) == oracle


def test_frontier_trip_reduction_ibm_db(small_db):
    """frontier_size=64 must execute ≥5× fewer while_loop trips than the
    single-node miner on the IBM-generator database (the perf contract)."""
    dense, db, minsup, oracle = small_db
    trips = {}
    for k in (1, 64):
        res = eclat.mine_all(
            db, minsup,
            config=eclat.EclatConfig(
                max_out=8192, max_stack=2048, frontier_size=k
            ),
        )
        assert _to_dict(res, db.n_items) == oracle
        trips[k] = int(res.n_iters)
    assert trips[64] * 5 <= trips[1], trips


@pytest.mark.parametrize("frontier", [4, 32])
def test_frontier_seeded_matches_k1(small_db, frontier):
    """mine_seeded over several PBEC seeds: frontier path == K=1 oracle path."""
    dense, db, minsup, oracle = small_db
    I = db.n_items
    # three 1-prefix seeds with suffix extension sets (valid PBECs)
    seed_items = [1, 5, 9]
    prefix = np.zeros((3, I), bool)
    ext = np.zeros((3, I), bool)
    for j, it in enumerate(seed_items):
        prefix[j, it] = True
        ext[j, it + 1:] = True
    tids = jnp.stack([
        bm.tidlist_of_itemset(db, jnp.asarray(prefix[j])) for j in range(3)
    ])
    results = {}
    for k in (1, frontier):
        res = eclat.mine_seeded(
            db.item_bits,
            jnp.asarray(prefix),
            jnp.asarray(ext),
            tids,
            jnp.ones((3,), jnp.bool_),
            jnp.asarray(minsup, jnp.int32),
            jax.random.PRNGKey(0),
            config=eclat.EclatConfig(
                max_out=8192, max_stack=2048, frontier_size=k
            ),
            n_items=I,
        )
        assert int(res.stack_overflow) == 0
        results[k] = _to_dict(res, I)
    assert results[frontier] == results[1]
    want = {
        fs: s for fs, s in oracle.items()
        if len(fs) > 1 and min(fs) in seed_items
    }
    assert results[1] == want


def test_frontier_count_only_and_total(small_db):
    dense, db, minsup, oracle = small_db
    res = eclat.mine_all(
        db, minsup,
        config=eclat.EclatConfig(
            max_out=8192, max_stack=2048, frontier_size=16, count_only=True
        ),
    )
    assert int(res.n_total) == len(oracle)
    # count_only leaves the output buffer untouched
    assert not np.asarray(res.items).any()


def test_frontier_reservoir_stream(small_db):
    """The in-loop reservoir sees the same stream length under batching and
    every reservoir element is a real FI with its true support."""
    dense, db, minsup, oracle = small_db
    R = 32
    res = eclat.mine_all(
        db, minsup,
        config=eclat.EclatConfig(
            max_out=8192, max_stack=2048, frontier_size=8,
            reservoir_size=R, count_only=True,
        ),
        key=jax.random.PRNGKey(7),
    )
    assert int(res.n_total) == len(oracle)
    n_res = min(R, len(oracle))
    for k in range(n_res):
        mask = np.asarray(bm.unpack_bool(res.reservoir_items[k], db.n_items))
        fs = frozenset(np.nonzero(mask)[0].tolist())
        assert fs in oracle
        assert oracle[fs] == int(res.reservoir_supports[k])


def test_frontier_wider_than_stack_clamps():
    """frontier_size > max_stack must clamp, not crash."""
    rng = np.random.default_rng(3)
    dense = rng.random((64, 10)) < 0.4
    db = bm.BitmapDB.from_dense(jnp.asarray(dense))
    oracle = eclat.brute_force_fis(dense, 8)
    res = eclat.mine_all(
        db, 8,
        config=eclat.EclatConfig(max_out=4096, max_stack=32, frontier_size=128),
    )
    assert int(res.stack_overflow) == 0
    assert _to_dict(res, 10) == oracle
