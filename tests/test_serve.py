"""Serving subsystem: subset_query kernel parity, index, engine, cache."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import bitmap as bm
from repro.core import rules as rules_mod
from repro.kernels import ops, ref
from repro.kernels import subset_query as sq
from repro.serve import FIIndex, QueryCache, QueryEngine, RuleIndex
from repro.serve.cache import query_key
from repro.serve.index import build_indexes


def _random_masks(n, n_items, seed, density=0.25):
    rng = np.random.default_rng(seed)
    dense = rng.random((n, n_items)) < density
    return dense, jnp.asarray(np.asarray(bm.pack_bool(jnp.asarray(dense))))


# ---------------------------------------------------------------------------
# subset_query kernel: interpret-mode parity vs the jnp oracle
# ---------------------------------------------------------------------------

# ragged (Q, F, n_items): sub-tile, tile-aligned, prime, multi-word masks
QUERY_SHAPES = [
    (1, 1, 5),
    (7, 33, 17),
    (64, 128, 32),
    (13, 257, 40),
    (130, 517, 96),
    (3, 9, 200),
]


@pytest.mark.parametrize("q,f,n_items", QUERY_SHAPES)
def test_subset_query_kernel_sweep(q, f, n_items):
    qd, qp = _random_masks(q, n_items, seed=q + f, density=0.3)
    fd, fp = _random_masks(f, n_items, seed=q * f + 1, density=0.15)
    want_miss, want_extra = ref.subset_superset_counts_ref(qp, fp)
    got_miss, got_extra = sq.subset_superset_counts_pallas(
        qp, fp, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(got_miss), np.asarray(want_miss))
    np.testing.assert_array_equal(np.asarray(got_extra), np.asarray(want_extra))
    # dense-bool semantics: miss = |f \ q|, extra = |q \ f|
    np.testing.assert_array_equal(
        np.asarray(want_miss), (fd[None, :, :] & ~qd[:, None, :]).sum(-1)
    )
    np.testing.assert_array_equal(
        np.asarray(want_extra), (qd[:, None, :] & ~fd[None, :, :]).sum(-1)
    )


@pytest.mark.parametrize("block_q,block_f,block_w", [
    (8, 8, 1), (16, 64, 2), (128, 128, 8),
])
def test_subset_query_block_shapes(block_q, block_f, block_w):
    _, qp = _random_masks(27, 53, seed=1)
    _, fp = _random_masks(91, 53, seed=2)
    want = ref.subset_superset_counts_ref(qp, fp)
    got = sq.subset_superset_counts_pallas(
        qp, fp, block_q=block_q, block_f=block_f, block_w=block_w,
        interpret=True,
    )
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_subset_query_membership_semantics():
    """miss==0 ⇔ f ⊆ q and extra==0 ⇔ q ⊆ f, cross-checked via python sets."""
    qd, qp = _random_masks(20, 24, seed=5, density=0.4)
    fd, fp = _random_masks(40, 24, seed=6, density=0.2)
    miss, extra = ref.subset_superset_counts_ref(qp, fp)
    for i in range(20):
        qs = set(np.nonzero(qd[i])[0])
        for j in range(40):
            fs = set(np.nonzero(fd[j])[0])
            assert (miss[i, j] == 0) == fs.issubset(qs)
            assert (extra[i, j] == 0) == qs.issubset(fs)


def test_subset_query_ops_dispatch():
    _, qp = _random_masks(9, 30, seed=7)
    _, fp = _random_masks(31, 30, seed=8)
    a = ops.subset_superset_counts(qp, fp)
    b = ops.subset_superset_counts(qp, fp, force="interpret")
    c = ops.subset_superset_counts(qp, fp, force="ref")
    for x, y, z in zip(a, b, c):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        np.testing.assert_array_equal(np.asarray(x), np.asarray(z))


# ---------------------------------------------------------------------------
# FI index
# ---------------------------------------------------------------------------


def test_fi_index_layout_and_bands(small_db):
    dense, db, minsup, oracle = small_db
    idx = FIIndex.from_fi_dict(oracle, db.n_items, db.n_tx)
    assert idx.n_fis == len(oracle)
    sizes = np.asarray(idx.sizes)[: idx.n_fis]
    assert (np.diff(sizes) >= 0).all()  # sorted by size
    for s in range(1, idx.max_size + 1):
        lo, hi = idx.size_band(s)
        assert (sizes[lo:hi] == s).all()
        assert hi - lo == sum(1 for f in oracle if len(f) == s)
    assert idx.size_band(idx.max_size + 3) == (0, 0)
    # row -> itemset -> support roundtrip
    for row in (0, idx.n_fis // 2, idx.n_fis - 1):
        assert oracle[idx.itemset(row)] == int(idx.supports[row])


def test_engine_support_lookup(small_db):
    dense, db, minsup, oracle = small_db
    idx = FIIndex.from_fi_dict(oracle, db.n_items, db.n_tx)
    engine = QueryEngine(idx, batch=64, top_k=3)
    sets = sorted(oracle, key=lambda s: (len(s), tuple(sorted(s))))
    rng = np.random.default_rng(0)
    pick = [sets[i] for i in rng.choice(len(sets), size=40, replace=False)]
    # a known-infrequent probe and the (never-frequent-here) empty set
    pick += [frozenset(range(12)), frozenset()]
    got = engine.support(engine.pack(pick))
    want = [oracle.get(s, -1) for s in pick]
    np.testing.assert_array_equal(got, want)


def test_empty_index_and_rules():
    idx, rules = build_indexes({}, 16, 100, min_confidence=0.5)
    assert idx.n_fis == 0 and rules.n_rules == 0
    engine = QueryEngine(idx, rules, batch=4, top_k=2)
    masks = engine.pack([frozenset({1, 2}), frozenset()])
    np.testing.assert_array_equal(engine.support(masks), [-1, -1])
    rows, _ = engine.rules_for(masks)
    assert (rows == -1).all()
    rows, supp = engine.supersets(masks)
    assert (rows == -1).all() and (supp == -1).all()


# ---------------------------------------------------------------------------
# Engine: rules + supersets vs host brute force
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def served(request):
    small = request.getfixturevalue("small_db")
    dense, db, minsup, oracle = small
    fi_idx, rule_idx = build_indexes(oracle, db.n_items, db.n_tx,
                                     min_confidence=0.6)
    engine = QueryEngine(fi_idx, rule_idx, batch=32, top_k=5)
    return dense, db, oracle, fi_idx, rule_idx, engine


def test_engine_top_rules_vs_host(served):
    dense, db, oracle, fi_idx, rule_idx, engine = served
    all_rules = [rule_idx.rule(j) for j in range(rule_idx.n_rules)]
    baskets = [frozenset(np.nonzero(dense[t])[0].tolist())
               for t in range(12)]
    rows, conf = engine.rules_for(engine.pack(baskets))
    for qi, basket in enumerate(baskets):
        app = sorted(
            (r for r in all_rules
             if r.antecedent <= basket and not r.consequent <= basket),
            key=lambda r: (-r.confidence, -r.support),
        )
        n_hit = int((rows[qi] >= 0).sum())
        assert n_hit == min(5, len(app))
        for j in range(n_hit):
            assert conf[qi, j] == pytest.approx(app[j].confidence, abs=1e-6)
            r = rule_idx.rule(int(rows[qi, j]))
            assert r.antecedent <= basket and not r.consequent <= basket


def test_engine_top_rules_novel_only_off(served):
    dense, db, oracle, fi_idx, rule_idx, engine = served
    baskets = [frozenset(np.nonzero(dense[t])[0].tolist()) for t in range(6)]
    rows_all, _ = engine.rules_for(engine.pack(baskets), novel_only=False)
    for qi, basket in enumerate(baskets):
        for j in range(int((rows_all[qi] >= 0).sum())):
            r = rule_idx.rule(int(rows_all[qi, j]))
            assert r.antecedent <= basket  # consequent may be owned


def test_engine_top_supersets_vs_host(served):
    dense, db, oracle, fi_idx, rule_idx, engine = served
    queries = [frozenset({i}) for i in range(8)] + [frozenset()]
    rows, supp = engine.supersets(engine.pack(queries), proper=True)
    for qi, q in enumerate(queries):
        sups = sorted((s for f, s in oracle.items() if q < f), reverse=True)
        n_hit = int((rows[qi] >= 0).sum())
        assert n_hit == min(5, len(sups))
        np.testing.assert_array_equal(supp[qi][:n_hit], sups[:n_hit])
        for j in range(n_hit):
            assert q < fi_idx.itemset(int(rows[qi, j]))


def test_engine_supersets_includes_self_when_not_proper(served):
    dense, db, oracle, fi_idx, rule_idx, engine = served
    q = max(oracle, key=lambda s: (len(s), oracle[s]))  # a maximal FI
    rows, supp = engine.supersets(engine.pack([q]), proper=False)
    assert rows[0, 0] >= 0
    assert fi_idx.itemset(int(rows[0, 0])) == q
    assert int(supp[0, 0]) == oracle[q]


def test_rule_index_stacked_slab(served):
    """ant_con really is antecedents ∥ consequents (the one-sweep layout)."""
    *_, rule_idx, _ = served
    R = rule_idx.r_pad
    assert rule_idx.ant_con.shape[0] == 2 * R
    np.testing.assert_array_equal(
        np.asarray(rule_idx.ant_con[:R]), np.asarray(rule_idx.antecedents())
    )
    np.testing.assert_array_equal(
        np.asarray(rule_idx.ant_con[R:]), np.asarray(rule_idx.consequents())
    )
    # no antecedent overlaps its consequent
    inter = np.asarray(rule_idx.antecedents()) & np.asarray(
        rule_idx.consequents()
    )
    assert (inter[: rule_idx.n_rules] == 0).all()


# ---------------------------------------------------------------------------
# LRU cache
# ---------------------------------------------------------------------------


def test_cache_lru_eviction_order():
    c = QueryCache(capacity=2)
    ka, kb, kc = (query_key("support", np.asarray([i], np.uint32)) for i in
                  (1, 2, 3))
    c.put(ka, "a"), c.put(kb, "b")
    assert c.get(ka) == "a"       # refreshes a
    c.put(kc, "c")                # evicts b (LRU), not a
    assert c.get(kb) is None
    assert c.get(ka) == "a" and c.get(kc) == "c"
    assert c.stats.evictions == 1


def test_cache_disabled_capacity_zero():
    c = QueryCache(capacity=0)
    k = query_key("support", np.asarray([7], np.uint32))
    c.put(k, "x")
    assert len(c) == 0 and c.get(k) is None
    assert c.stats.misses == 1 and c.stats.hit_rate == 0.0


def test_cache_split_fill_with_duplicates():
    c = QueryCache(capacity=8)
    masks = np.asarray([[1], [2], [1], [3], [2]], np.uint32)
    keys = [query_key("rules", m, 5) for m in masks]
    results, miss = c.split_batch(keys)
    assert miss == [0, 1, 3]      # duplicates dispatch once
    out = c.fill_batch(keys, results, miss, ["r1", "r2", "r3"])
    assert out == ["r1", "r2", "r1", "r3", "r2"]
    # second pass: all hits
    results2, miss2 = c.split_batch(keys)
    assert miss2 == [] and results2 == out
    assert c.stats.hits == 5 and c.stats.misses == 5


def test_cache_key_distinguishes_kind_and_knobs():
    m = np.asarray([9], np.uint32)
    assert query_key("support", m) != query_key("superset", m)
    assert query_key("rules", m, 5) != query_key("rules", m, 10)
    assert query_key("rules", m, 5) == query_key("rules", m.copy(), 5)


# ---------------------------------------------------------------------------
# End to end: mine -> index -> serve round trip on the thesis example
# ---------------------------------------------------------------------------


def test_mine_index_serve_roundtrip(thesis_db):
    from repro.core import eclat

    dense = np.asarray(thesis_db.dense())
    minsup = 5
    oracle = eclat.brute_force_fis(dense, minsup)
    fi_idx, rule_idx = build_indexes(oracle, thesis_db.n_items,
                                     thesis_db.n_tx, min_confidence=0.7)
    engine = QueryEngine(fi_idx, rule_idx, batch=16, top_k=3)
    # every mined itemset is servable at its exact support
    sets = list(oracle)[:16]
    np.testing.assert_array_equal(
        engine.support(engine.pack(sets)), [oracle[s] for s in sets]
    )
    # rules agree with the brute-force generator
    want = rules_mod.brute_force_rules(oracle, thesis_db.n_tx, 0.7)
    got = {rule_idx.rule(j).key() for j in range(rule_idx.n_rules)}
    assert got == set(want)
