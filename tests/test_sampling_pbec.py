"""Sampling theory (Ch. 6), PBEC partitioning (Ch. 8.2), schedulers."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st  # skips @given tests w/o hypothesis

import jax
import jax.numpy as jnp

from repro.core import bitmap as bm, eclat, mfi, pbec, sampling, schedule


def test_sample_size_formulas():
    # Thm 6.1: 1/(2ε²)·ln(2/δ)
    assert sampling.db_sample_size(0.01, 0.1) == int(
        np.ceil(np.log(20) / (2 * 0.01**2))
    )
    # Thm 6.2
    assert sampling.coverage_sample_size(0.1, 0.1, 0.01) == int(
        np.ceil(4 / (0.1**2 * 0.01) * np.log(20))
    )
    # Thm 6.3 monotone in ε and ρ
    a = sampling.reservoir_sample_size(0.05, 0.1, 0.01)
    b = sampling.reservoir_sample_size(0.02, 0.1, 0.01)
    assert b > a > 0


def test_reservoir_inloop_uniformity(small_db):
    """χ²-style sanity: in-loop reservoir hits every FI with ≈equal freq."""
    dense, db, minsup, oracle = small_db
    R = 16
    counts = {}
    trials = 40
    for t in range(trials):
        res = eclat.mine_all(
            db, minsup, key=jax.random.PRNGKey(t),
            config=eclat.EclatConfig(max_out=8192, max_stack=2048, reservoir_size=R),
        )
        for k in range(R):
            m = np.asarray(bm.unpack_bool(res.reservoir_items[k], db.n_items))
            fs = frozenset(np.nonzero(m)[0].tolist())
            # mine_all's root is [∅|B], so singletons are in the stream too
            assert fs in oracle and len(fs) >= 1
            counts[fs] = counts.get(fs, 0) + 1
    n_multi = len(oracle)
    freq = np.array(list(counts.values()))
    expected = trials * R / n_multi
    # generous tolerance: uniform sampling over ~600 itemsets, 640 draws
    assert len(counts) > n_multi * 0.4
    assert freq.max() <= max(6.0 * expected, 6)


def test_reservoir_np_oracle_uniform():
    rng = np.random.default_rng(0)
    hits = np.zeros(100)
    for _ in range(2000):
        s = sampling.reservoir_sample_np(rng, np.arange(100), 10)
        hits[s] += 1
    p = hits / hits.sum()
    assert abs(p.mean() - 0.01) < 1e-9 and p.max() < 0.02


def test_merge_reservoirs_hypergeometric():
    rng = np.random.default_rng(1)
    counts = np.array([100, 50, 10, 0])
    X = sampling.merge_reservoirs(rng, counts, 40)
    assert X.sum() == 40 and (X <= counts).all()
    # expectation proportional to f_i
    Xs = np.mean(
        [sampling.merge_reservoirs(rng, counts, 40) for _ in range(300)], axis=0
    )
    np.testing.assert_allclose(Xs / 40, counts / counts.sum(), atol=0.03)


def test_modified_coverage_samples_are_frequent(small_db):
    dense, db, minsup, oracle = small_db
    r = mfi.mine_all_candidates(
        db, minsup, config=mfi.MFIConfig(max_out=4096, max_stack=2048)
    )
    n = int(r.n_out)
    valid = np.zeros(r.items.shape[0], bool)
    valid[:n] = True
    samp = sampling.modified_coverage_sample(
        jax.random.PRNGKey(2), r.items, jnp.asarray(valid), 128, db.n_items
    )
    sm = np.asarray(bm.unpack_bool(samp, db.n_items))
    for row in sm:
        fs = frozenset(np.nonzero(row)[0].tolist())
        if fs:
            assert fs in oracle


def test_coverage_uniform_host():
    rng = np.random.default_rng(0)
    mfis = np.zeros((2, 6), bool)
    mfis[0, :3] = True   # P(m0) = 8 subsets
    mfis[1, 2:5] = True  # P(m1) = 8 subsets, overlap {2}
    s = sampling.coverage_sample_uniform(rng, mfis, 4000)
    keys = {}
    for row in s:
        keys[tuple(np.nonzero(row)[0])] = keys.get(tuple(np.nonzero(row)[0]), 0) + 1
    # union has 8 + 8 - 2 = 14 distinct itemsets ({}, {2} shared)
    assert len(keys) == 14
    freq = np.array(list(keys.values())) / 4000
    np.testing.assert_allclose(freq, 1 / 14, atol=0.03)


# ---------------------------------------------------------------------------
# PBEC partition properties
# ---------------------------------------------------------------------------


def _ext_supports_fn(db):
    def f(prefix):
        tid = bm.tidlist_of_itemset(db, jnp.asarray(prefix))
        return np.asarray(bm.extension_supports(db.item_bits, tid))

    return f


@given(st.integers(2, 8), st.floats(0.2, 1.0), st.integers(0, 500))
@settings(max_examples=10, deadline=None)
def test_partition_disjoint_and_covering(P, alpha, seed):
    """Prop. 2.22/2.23: classes are disjoint and (with ancestors) cover F."""
    rng = np.random.default_rng(seed)
    dense = rng.random((64, 10)) < 0.45
    db = bm.BitmapDB.from_dense(jnp.asarray(dense))
    minsup = 8
    oracle = eclat.brute_force_fis(dense, minsup)
    if not oracle:
        return
    masks = np.zeros((len(oracle), 10), bool)
    for i, s_ in enumerate(oracle):
        masks[i, sorted(s_)] = True
    classes = pbec.partition(masks, P, alpha, _ext_supports_fn(db), 10)
    disjoint, covered = pbec.verify_disjoint_cover(classes, 10, masks)
    assert disjoint and covered


@given(
    st.lists(st.floats(0.0, 100.0), min_size=1, max_size=60),
    st.integers(1, 12),
)
@settings(max_examples=40, deadline=None)
def test_lpt_43_bound_property(sizes, P):
    """Graham's Lemma 8.2: LPT makespan ≤ 4/3 · OPT lower bound."""
    a = schedule.lpt_schedule(sizes, P)
    assert schedule.lpt_makespan_bound_ok(sizes, a, P)


def test_db_repl_min_improves_sharing(small_db):
    dense, db, minsup, oracle = small_db
    masks = np.zeros((len(oracle), db.n_items), bool)
    for i, s_ in enumerate(oracle):
        masks[i, sorted(s_)] = True
    classes = pbec.partition(masks, 4, 0.5, _ext_supports_fn(db), db.n_items)
    from repro.core.phases import seed_tidlists

    tids = np.asarray(
        seed_tidlists(
            db.item_bits,
            jnp.asarray(np.stack([c.prefix for c in classes])),
            db.all_tids(),
        )
    )
    profit = schedule.pairwise_shared_transactions(tids)
    sizes = [c.est_count for c in classes]
    r = schedule.db_repl_min(np.asarray(sizes), profit, 4, tidlists=tids)
    assert set(r.assignment) <= set(range(4))
    assert len(r.assignment) == len(classes)
    # the reported volume is the exact Σ_p |D'_p| of the returned assignment
    assert r.volume == schedule.replicated_volume(tids, r.assignment, 4)
    # and never better than the no-replication floor |∪ T(U_i)|
    union = np.bitwise_or.reduce(tids.astype(np.uint32), axis=0)
    floor = int(np.unpackbits(union.view(np.uint8)).sum())
    assert r.volume >= floor


def test_schedulers_makespan_and_volume_tradeoff():
    """LPT optimizes the makespan, DB-Repl-Min the replicated volume; on a
    skewed size vector with clustered tidlists each wins its own metric."""
    rng = np.random.default_rng(42)
    C, P, W = 24, 4, 8
    sizes = rng.zipf(1.4, C).astype(np.float64)
    # two tid "clusters": classes sharing a cluster share most transactions
    tids = np.zeros((C, W), np.uint32)
    for i in range(C):
        cluster = i % 2
        base = np.uint32(0x0F0F0F0F if cluster == 0 else 0xF0F0F0F0)
        noise = rng.integers(0, 1 << 32, W, dtype=np.uint64).astype(np.uint32)
        tids[i] = base & noise
    profit = schedule.pairwise_shared_transactions(tids)

    lpt = schedule.lpt_schedule(sizes, P)
    rep = schedule.db_repl_min(sizes, profit, P, tidlists=tids)

    mk_lpt = schedule.makespan_of(sizes, lpt, P)
    mk_rep = schedule.makespan_of(sizes, rep.assignment, P)
    vol_lpt = schedule.replicated_volume(tids, lpt, P)

    # LPT makespan is sound (Graham bound) and no worse than the QKP greedy's
    assert schedule.lpt_makespan_bound_ok(sizes, lpt, P)
    assert mk_lpt <= mk_rep + 1e-9
    # the replication-aware greedy moves fewer (or equal) transactions
    assert rep.volume <= vol_lpt + 1e-9
    # without tidlists no honest volume exists (sizes are FI counts, not
    # transactions) — the report says so with NaN rather than a wrong number
    no_tids = schedule.db_repl_min(sizes, profit, P)
    assert np.array_equal(no_tids.assignment, rep.assignment)
    assert np.isnan(no_tids.volume)
