"""Speedup-loss waterfall (repro.obs.speedup): exact additivity of the
decomposition, the compile/estimation/imbalance term math on synthetic
snapshots, the coarse BENCH-entry split, the perf-ledger key naming, and
the committed golden fixture records."""
import json
from pathlib import Path

import pytest

from repro.obs import perfdb
from repro.obs import runlog
from repro.obs import speedup

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "data"


def _snap(gauges, counters=None):
    return {"counters": counters or {}, "gauges": gauges, "histograms": {}}


def _balanced_gauges(P=2, load=100.0, mine_ms=100.0):
    g = {
        "cluster/imbalance": 1.0,
        "cluster/makespan_trips": load,
        "cluster/load/estimation_error": 0.0,
        "cluster/phase_ms/plan": 1.0,
        "cluster/phase_ms/exchange": 2.0,
        "cluster/phase_ms/mine": mine_ms,
        "cluster/phase_ms/merge": 1.0,
    }
    for p in range(P):
        g[f"cluster/shard{p}/est_load"] = load
        g[f"cluster/shard{p}/obs_load"] = load
    return g


# ---------------------------------------------------------------------------
# from_snapshot: the per-run decomposition
# ---------------------------------------------------------------------------


def test_not_a_cluster_run_returns_none():
    assert speedup.from_snapshot(_snap({})) is None
    assert speedup.from_snapshot(
        _snap({"fimi/n_fis": 3.0, "cluster/phase_ms/mine": 10.0})) is None


def test_balanced_run_decomposes_exactly():
    wf = speedup.from_snapshot(_snap(_balanced_gauges()))
    assert wf is not None and wf.source == "run"
    assert wf.P == 2 and wf.ideal_x == 2.0
    # rho = 100ms / 100 trips; T_ideal = (200/2)*1 = 100ms; TP = 104ms
    assert wf.ideal_ms == pytest.approx(100.0)
    assert wf.wall_ms == pytest.approx(104.0)
    assert wf.measured_x == pytest.approx(2 * 100.0 / 104.0)
    by_name = {t.name: t for t in wf.terms}
    assert by_name["imbalance"].loss_x == pytest.approx(0.0)
    assert by_name["estimation"].loss_x == pytest.approx(0.0)
    assert by_name["exchange"].ms == pytest.approx(2.0)
    assert by_name["host_tail"].ms == pytest.approx(2.0)
    # the gate the acceptance criteria check: terms sum to the gap
    assert wf.additivity_error() < 1e-9


def test_unpredicted_skew_prices_the_estimation_term():
    # planner predicted balance, one shard got all the work
    g = _balanced_gauges()
    g.update({
        "cluster/shard0/obs_load": 200.0,
        "cluster/shard1/obs_load": 0.0,
        "cluster/makespan_trips": 200.0,
        "cluster/phase_ms/mine": 200.0,
        "cluster/imbalance": 2.0,
        "cluster/load/estimation_error": 0.5,
    })
    wf = speedup.from_snapshot(_snap(g))
    by_name = {t.name: t for t in wf.terms}
    # rho = 1 ms/trip, t_ideal 100; obs max share 1.0 vs est 0.5 →
    # d_est = 0.5 * 200 * 1 = 100 ms: ALL the skew was unpredicted
    assert by_name["estimation"].ms == pytest.approx(100.0)
    assert by_name["imbalance"].ms == pytest.approx(0.0)
    assert wf.additivity_error() < 1e-9


def test_planned_skew_stays_in_the_imbalance_term():
    # estimates already said shard0 gets everything: nothing unpredicted
    g = _balanced_gauges()
    g.update({
        "cluster/shard0/est_load": 200.0, "cluster/shard0/obs_load": 200.0,
        "cluster/shard1/est_load": 0.0, "cluster/shard1/obs_load": 0.0,
        "cluster/makespan_trips": 200.0,
        "cluster/phase_ms/mine": 200.0,
        "cluster/imbalance": 2.0,
    })
    wf = speedup.from_snapshot(_snap(g))
    by_name = {t.name: t for t in wf.terms}
    assert by_name["estimation"].ms == pytest.approx(0.0)
    assert by_name["imbalance"].ms == pytest.approx(100.0)
    assert wf.measured_x == pytest.approx(2 * 100.0 / 204.0)
    assert wf.additivity_error() < 1e-9


def test_round0_excess_becomes_the_compile_term():
    g = _balanced_gauges(mine_ms=150.0)
    # two rounds of 50 trips each; round 0 took 100 ms, round 1 took 50:
    # the steady rate is 1 ms/trip, so 50 ms of round 0 is jit warm-up
    g.update({
        "cluster/round0/mine_ms": 100.0, "cluster/round0/max_trips": 50.0,
        "cluster/round1/mine_ms": 50.0, "cluster/round1/max_trips": 50.0,
    })
    wf = speedup.from_snapshot(_snap(g))
    by_name = {t.name: t for t in wf.terms}
    assert by_name["compile"].ms == pytest.approx(50.0)
    # priced at rho: t_ideal = (200/2) * 1 = 100, imbalance absorbs the rest
    assert by_name["imbalance"].ms == pytest.approx(0.0)
    assert wf.additivity_error() < 1e-9


def test_wall_clock_residual_becomes_the_driver_term():
    wf = speedup.from_snapshot(_snap(_balanced_gauges()), wall_ms=110.0)
    by_name = {t.name: t for t in wf.terms}
    assert by_name["driver"].ms == pytest.approx(6.0)   # 110 - 104 in phases
    assert wf.wall_ms == pytest.approx(110.0)
    assert wf.additivity_error() < 1e-9


def test_from_run_uses_manifest_mine_wall(tmp_path):
    run = {
        "manifest": {"mine_wall_s": 0.110},
        "metrics": _snap(_balanced_gauges()),
    }
    wf = speedup.from_run(run)
    assert wf.wall_ms == pytest.approx(110.0)
    assert speedup.from_run({"manifest": {}, "metrics": {}}) is None


def test_gauges_and_publish_roundtrip():
    wf = speedup.from_snapshot(_snap(_balanced_gauges()))
    g = wf.gauges()
    assert g["speedup/ideal_x"] == 2.0
    assert g["speedup/measured_x"] == pytest.approx(wf.measured_x)
    assert g["speedup/gap_x"] == pytest.approx(wf.gap_x)
    assert g["speedup/additivity_err"] < 1e-9
    for t in wf.terms:
        assert g[f"speedup/loss/{t.name}_x"] == pytest.approx(t.loss_x)

    class _FakeReg:
        def __init__(self):
            self.vals = {}

        def gauge(self, name):
            reg = self

            class _G:
                def set(self, v, _n=name):
                    reg.vals[_n] = v
            return _G()

    reg = _FakeReg()
    wf.publish(reg)
    assert reg.vals == pytest.approx(g)


def test_renderers_mention_every_term():
    wf = speedup.from_snapshot(_snap(_balanced_gauges()))
    txt = wf.render_text()
    md = wf.render_markdown()
    for t in wf.terms:
        assert t.name in txt and t.name in md
    assert "ideal 2.00x" in txt
    assert "| term | Δ speedup | why |" in md


# ---------------------------------------------------------------------------
# from_bench_entries: the coarse two-term split over BENCH_cluster.json
# ---------------------------------------------------------------------------

_ENTRIES = [
    {"name": "cluster_speedup", "P": 1, "makespan_trips": 1000.0,
     "imbalance": 1.0},
    {"name": "cluster_speedup", "P": 4, "makespan_trips": 400.0,
     "imbalance": 1.25, "wall_s": 0.5},
    {"name": "cluster_rebalanced", "P": 4, "makespan_trips": 390.0},
]


def test_bench_split_is_exact():
    wfs = speedup.from_bench_entries(_ENTRIES)
    assert sorted(wfs) == [4]           # P=1 is the baseline, not a point
    wf = wfs[4]
    S = 1000.0 / 400.0
    assert wf.measured_x == pytest.approx(S)
    by_name = {t.name: t for t in wf.terms}
    assert by_name["inflation"].loss_x == pytest.approx(4 - S * 1.25)
    assert by_name["imbalance"].loss_x == pytest.approx(S * 0.25)
    assert wf.additivity_error() < 1e-12
    assert wf.source == "bench"


def test_bench_without_baseline_is_empty():
    assert speedup.from_bench_entries(_ENTRIES[1:]) == {}
    assert speedup.from_bench_entries([]) == {}


def test_bench_loss_keys_are_lower_better_for_the_ledger():
    keys = speedup.bench_loss_keys(_ENTRIES)
    assert set(keys) == {"loss_inflation_x_p4", "loss_imbalance_x_p4",
                         "loss_total_x_p4"}
    assert keys["loss_total_x_p4"] == pytest.approx(
        keys["loss_inflation_x_p4"] + keys["loss_imbalance_x_p4"], abs=1e-5)
    # perfdb must read every loss key as lower-is-better — a rising loss
    # is a regression even though it comes from the speedup curve
    for k in keys:
        assert perfdb.direction(k) == "lower"


# ---------------------------------------------------------------------------
# golden fixture records
# ---------------------------------------------------------------------------


def _load_fixture(name):
    return runlog.load_run(str(FIXTURES / name))


def test_healthy_fixture_waterfall():
    wf = speedup.from_run(_load_fixture("run_healthy"))
    assert wf.P == 2
    assert wf.measured_x == pytest.approx(2 * 100.0 / 106.0)
    by_name = {t.name: t for t in wf.terms}
    assert by_name["imbalance"].loss_x == pytest.approx(0.0)
    assert by_name["driver"].ms == pytest.approx(2.0)
    assert wf.additivity_error() < 0.05        # the acceptance gate


def test_skewed_fixture_waterfall_dominated_by_imbalance():
    wf = speedup.from_run(_load_fixture("run_skewed_cluster"))
    assert wf.measured_x == pytest.approx(2 * 100.0 / 206.0)
    by_name = {t.name: t for t in wf.terms}
    # planned skew: the estimation term must NOT absorb it
    assert by_name["estimation"].loss_x == pytest.approx(0.0)
    assert by_name["imbalance"].loss_x == pytest.approx(2 * 100.0 / 206.0)
    assert by_name["imbalance"].loss_x > 0.5 * wf.gap_x
    assert wf.additivity_error() < 0.05
