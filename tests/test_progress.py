"""Sample-grounded live progress/ETA: fake-clock convergence against an
offline oracle (midpoint ETA within tolerance), barrier-aware max-shard
math, warm-up discount, straggler scores, gauge/counter-track export, and
the wired-through ``fimi.run`` result."""
import numpy as np
import pytest

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.progress import ProgressEstimator, ProgressSnapshot


@pytest.fixture(autouse=True)
def _fresh_obs():
    obs_metrics.reset()
    obs_trace.TRACER.disable()
    obs_trace.TRACER.clear()
    yield
    obs_metrics.reset()
    obs_trace.TRACER.disable()
    obs_trace.TRACER.clear()


class FakeClock:
    def __init__(self):
        self.t = 100.0

    def __call__(self):
        return self.t


def _simulate(est, true_rates, dt=1.0, compile_s=0.0, rounds=None):
    """Offline oracle: shards mine at constant true rates, barrier rounds
    every ``dt`` seconds; returns (estimator, wall time actually taken,
    per-update (snapshot, clock time) history)."""
    clock = FakeClock()
    prog = ProgressEstimator(est, clock=clock, publish=False)
    prog.start()
    if compile_s:
        clock.t += compile_s   # jit compile swallowed by the first interval
    done = [0.0] * len(est)
    t_start = clock.t
    hist = []
    r = 0
    while any(d < e for d, e in zip(done, est)):
        clock.t += dt
        delta = []
        for p, rate in enumerate(true_rates):
            d = min(rate * dt, est[p] - done[p])
            done[p] += d
            delta.append(d)
        hist.append((prog.update(delta), clock.t))
        r += 1
        if rounds is not None and r >= rounds:
            break
    return prog, clock.t - t_start, hist, clock


# ---------------------------------------------------------------------------
# ETA convergence vs the oracle
# ---------------------------------------------------------------------------


def test_eta_exact_for_uniform_rates():
    """Constant rates, perfect estimates: ETA is exact after round 2."""
    est = [100.0, 100.0]
    prog, wall, hist, clock = _simulate(est, [10.0, 10.0], dt=1.0)
    for snap, t in hist[1:-1]:
        actual_remaining = (hist[-1][1]) - t
        assert snap.eta_s == pytest.approx(actual_remaining, rel=1e-6)
    assert hist[-1][0].frac == pytest.approx(1.0)


def test_eta_midpoint_within_tolerance_vs_oracle():
    """Skewed shards + compile warm-up: midpoint ETA within 25 %."""
    est = [120.0, 80.0, 100.0]
    prog, wall, hist, clock = _simulate(
        est, [9.0, 11.0, 10.0], dt=1.0, compile_s=3.0)
    mid = next(s for s, _ in hist if s.frac >= 0.5)
    t_mid = next(t for s, t in hist if s is mid)
    actual_remaining = hist[-1][1] - t_mid
    assert mid.eta_s == pytest.approx(actual_remaining, rel=0.25)
    err = prog.finish()
    assert err is not None and err < 0.25


def test_warmup_discount_drops_compile_time():
    """A long first interval (jit compile) must not inflate later ETAs:
    round-2+ rates use the post-first-update window only."""
    est = [100.0]
    # 10s of "compile" inside the first interval, then 10 units/s
    prog, wall, hist, clock = _simulate(
        est, [10.0], dt=1.0, compile_s=10.0)
    # without the discount the round-2 rate would be 20/12 ≈ 1.7 u/s and
    # ETA ≈ 48s; with it the rate is the true 10 u/s
    snap2 = hist[1][0]
    assert snap2.eta_s == pytest.approx(8.0, rel=1e-6)


def test_barrier_eta_is_max_over_shards():
    """ETA tracks the slowest shard's projected finish, not the mean."""
    clock = FakeClock()
    prog = ProgressEstimator([100.0, 100.0], clock=clock, publish=False)
    prog.start()
    clock.t += 1.0
    prog.update([20.0, 5.0])
    clock.t += 1.0
    snap = prog.update([20.0, 5.0])
    # fast shard: 60 left at 20/s → 3s; slow shard: 90 left at 5/s → 18s
    assert snap.eta_s == pytest.approx(18.0, rel=1e-6)
    # fleet-mean math would have said (150 left) / (25/s) = 6s — the
    # barrier-aware number is the honest one
    assert snap.eta_s > 150.0 / 25.0


# ---------------------------------------------------------------------------
# Straggler scores
# ---------------------------------------------------------------------------


def test_straggler_score_from_trips():
    """Trip telemetry: cost per estimated unit, normalized to fleet mean."""
    clock = FakeClock()
    prog = ProgressEstimator([100.0, 100.0], clock=clock, publish=False)
    prog.start()
    clock.t += 1.0
    # shard 1 needed 3x the trips for the same estimated work
    snap = prog.update([50.0, 50.0], trips_delta=[100.0, 300.0])
    assert snap.stragglers[1] == pytest.approx(3.0 * snap.stragglers[0])
    assert sum(snap.stragglers) / 2 == pytest.approx(1.0)


def test_straggler_score_from_rates_fallback():
    clock = FakeClock()
    prog = ProgressEstimator([100.0, 100.0], clock=clock, publish=False)
    prog.start()
    clock.t += 1.0
    prog.update([40.0, 10.0])
    clock.t += 1.0
    snap = prog.update([40.0, 10.0])
    assert snap.stragglers[1] > snap.stragglers[0]


# ---------------------------------------------------------------------------
# Export: gauges, counter track, live line
# ---------------------------------------------------------------------------


def test_update_publishes_gauges_and_counter_track():
    obs_trace.TRACER.enable()
    clock = FakeClock()
    prog = ProgressEstimator([10.0, 10.0], clock=clock)
    prog.start()
    clock.t += 1.0
    prog.update([5.0, 5.0])
    clock.t += 1.0
    prog.update([5.0, 5.0])
    g = obs_metrics.snapshot()["gauges"]
    assert g["progress/frac"] == pytest.approx(1.0)
    assert g["progress/round"] == 2.0
    assert "progress/eta_s" in g
    assert "progress/shard0/straggler" in g
    events = obs_trace.TRACER.export()["traceEvents"]
    counters = [e for e in events if e.get("ph") == "C"
                and e.get("name") == "mining progress"]
    assert counters and {"percent", "eta_s"} <= set(counters[-1]["args"])


def test_finish_publishes_midpoint_error_gauge():
    est = [100.0, 100.0]
    clock = FakeClock()
    prog = ProgressEstimator(est, clock=clock)  # publish=True
    prog.start()
    for _ in range(10):
        clock.t += 1.0
        prog.update([10.0, 10.0])
    err = prog.finish()
    assert err is not None and err == pytest.approx(0.0, abs=1e-9)
    assert obs_metrics.snapshot()["gauges"][
        "progress/eta_rel_err_mid"] == pytest.approx(err)


def test_single_round_run_has_no_midpoint_error():
    clock = FakeClock()
    prog = ProgressEstimator([10.0], clock=clock, publish=False)
    prog.start()
    clock.t += 1.0
    prog.update([10.0])
    assert prog.finish() is None


def test_line_format():
    snap = ProgressSnapshot(frac=0.5, elapsed_s=2.0, eta_s=3.0, rate=5.0,
                            round=2, stragglers=[1.0, 1.3])
    line = snap.line()
    assert "progress  50.0%" in line
    assert "worst-straggler 1.30x" in line
    # no-rate-yet variant renders a placeholder, not a crash
    assert "?" in ProgressSnapshot(
        frac=0.0, elapsed_s=0.0, eta_s=None, rate=0.0, round=1,
        stragglers=[]).line()


# ---------------------------------------------------------------------------
# Wired through the miner
# ---------------------------------------------------------------------------


def test_fimi_run_carries_progress():
    import jax

    from repro.core import eclat, fimi
    from repro.data.ibm_gen import generate_dense, params_from_name

    dense = generate_dense(params_from_name("T0.5I0.024P8PL5TL8"))
    params = fimi.FimiParams(
        min_support_rel=0.08, n_db_sample=256, n_fi_sample=256,
        eclat=eclat.EclatConfig(max_out=1 << 14, max_stack=4096,
                                frontier_size=8),
    )
    res = fimi.run(fimi.shard_db(np.asarray(dense), 2), dense.shape[1],
                   params, jax.random.PRNGKey(0))
    assert res.progress is not None
    assert res.progress.frac == pytest.approx(1.0)
    assert len(res.progress.stragglers) == 2
    assert all(s > 0 for s in res.progress.stragglers)
