"""Integration: the full 4-phase Parallel-FIMI pipeline is EXACT.

The thesis' headline invariant — the method "always computes the set of
frequent itemsets from the whole database" regardless of sampling noise —
is asserted literally: distributed result == brute force, for all three
variants, several P, and under both vmap and (separately, in
test_shard_map_parity) real multi-device shard_map.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import eclat, fimi


@pytest.fixture(scope="module")
def mining_setup(small_db):
    dense, db, minsup, oracle = small_db
    return dense, minsup, oracle


@pytest.mark.parametrize("variant", ["reservoir", "par", "seq"])
@pytest.mark.parametrize("P", [2, 4])
def test_variant_exact(mining_setup, variant, P):
    dense, minsup, oracle = mining_setup
    shards = fimi.shard_db(dense, P)
    params = fimi.FimiParams(
        variant=variant, min_support_rel=0.08, n_db_sample=256,
        n_fi_sample=128, alpha=0.7,
        eclat=eclat.EclatConfig(max_out=4096, max_stack=1024),
    )
    res = fimi.run(shards, 24, params, jax.random.PRNGKey(1), materialize=True)
    assert res.exchange_overflow == 0
    assert res.fi_dict == oracle
    assert res.n_fis == len(oracle)


def test_replication_factor_sane(mining_setup):
    dense, minsup, oracle = mining_setup
    shards = fimi.shard_db(dense, 4)
    params = fimi.FimiParams(
        variant="reservoir", min_support_rel=0.08, n_db_sample=256,
        n_fi_sample=128, alpha=0.7,
        eclat=eclat.EclatConfig(max_out=4096, max_stack=1024),
    )
    res = fimi.run(shards, 24, params, jax.random.PRNGKey(0))
    # Ch. 10: 1 ≤ replication ≤ P
    assert 0.5 <= res.replication <= 4.001


def test_repl_min_scheduler_runs_exact(mining_setup):
    dense, minsup, oracle = mining_setup
    shards = fimi.shard_db(dense, 4)
    params = fimi.FimiParams(
        variant="reservoir", min_support_rel=0.08, n_db_sample=256,
        n_fi_sample=128, alpha=0.7, scheduler="repl_min",
        eclat=eclat.EclatConfig(max_out=4096, max_stack=1024),
    )
    res = fimi.run(shards, 24, params, jax.random.PRNGKey(0), materialize=True)
    assert res.fi_dict == oracle


def test_load_balance_quality(mining_setup):
    """Static balance: max load ≤ 2× mean real work for P=4 (thesis §11.3-ish:
    estimates good enough that no processor gets > ~2/P of the work)."""
    dense, minsup, oracle = mining_setup
    shards = fimi.shard_db(dense, 4)
    params = fimi.FimiParams(
        variant="reservoir", min_support_rel=0.08, n_db_sample=384,
        n_fi_sample=256, alpha=0.4,
        eclat=eclat.EclatConfig(max_out=4096, max_stack=1024),
    )
    res = fimi.run(shards, 24, params, jax.random.PRNGKey(5))
    work = res.work_iters.astype(float)
    assert work.max() <= 2.2 * max(work.mean(), 1.0)
