"""SLO-gated load harness (launch/serve_load): healthy run passes the gate
and records windowed SLO keys into BENCH_serve.json, injected overload trips
the burn-rate alert and exits non-zero, micro-batched dispatch beats
per-query dispatch, and --trace yields a per-request Perfetto timeline."""
import json
from pathlib import Path

import pytest

from repro.launch import serve_load
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

DB = "T0.25I0.016P6PL4TL6"      # 250 tx, 16 items: serving is under test


@pytest.fixture(autouse=True)
def _fresh_obs():
    obs_metrics.reset()
    obs_trace.TRACER.disable()
    obs_trace.TRACER.clear()
    yield
    obs_metrics.reset()
    obs_trace.TRACER.disable()
    obs_trace.TRACER.clear()


def _argv(tmp_path, **over):
    base = {
        "--db": DB, "--qps": "150", "--duration": "1.5", "--ramp": "0.5",
        "--window": "1.0", "--report-every": "0.25", "--replicas": "2",
        "--batch": "32", "--deadline-ms": "4.0",
        "--slo-p99-ms": "500", "--availability": "0.99",
        "--bench-out": str(tmp_path / "BENCH_serve.json"),
    }
    base.update({k: str(v) for k, v in over.items()})
    argv = [a for kv in base.items() for a in kv if a != ""]
    return argv + ["--no-dashboard", "--gate"]


def test_healthy_load_passes_gate_and_records_slo_keys(tmp_path, capsys):
    rc = serve_load.main(_argv(tmp_path) + ["--compare-dispatch"])
    out = capsys.readouterr().out
    assert rc == 0, out
    assert "SLO gate: ok" in out
    bench = json.loads((tmp_path / "BENCH_serve.json").read_text())
    for k in ("slo_target_qps", "slo_qps", "slo_p99_ms",
              "slo_p99_objective_ms", "slo_shed_rate", "slo_burn_rate",
              "slo_alerts_fired", "slo_gate_ok"):
        assert k in bench, k
    assert bench["slo_gate_ok"] is True
    assert bench["slo_alerts_fired"] == 0
    assert bench["slo_p99_ms"] is not None
    assert bench["slo_p99_ms"] <= bench["slo_p99_objective_ms"]
    # acceptance: the fused micro-batch sweep beats per-query dispatch
    assert bench["slo_microbatch_speedup"] > 1.0


def test_injected_overload_trips_burn_alert_and_gate(tmp_path, capsys):
    rc = serve_load.main(_argv(
        tmp_path, **{"--qps": "30000", "--max-queue": "32"}))
    cap = capsys.readouterr()
    assert rc == 1, cap.out
    assert "SLO GATE FAILED" in cap.err
    assert "[slo] slo_alert (availability)" in cap.err
    bench = json.loads((tmp_path / "BENCH_serve.json").read_text())
    assert bench["slo_gate_ok"] is False
    # cumulative, not the final-window rate: once the generator stops the
    # service catches up and the windowed shed rate can decay back to zero
    # before the closing evaluate, but the overload must have shed traffic
    # and burned the budget hard enough to fire the availability alert
    # (asserted on stderr above)
    assert bench["slo_shed_total"] > 0
    assert bench["slo_shed_rate"] >= 0.0


def test_trace_run_records_per_request_timeline(tmp_path, capsys):
    run_dir = tmp_path / "rec"
    rc = serve_load.main(_argv(tmp_path) + ["--trace", str(run_dir)])
    assert rc == 0, capsys.readouterr().out
    trace = json.loads((run_dir / "trace.json").read_text())
    spans = [e for e in trace["traceEvents"] if e.get("ph") == "X"]
    names = {e["name"] for e in spans}
    assert {"service/enqueue", "service/flush", "service/assemble",
            "service/sweep", "service/respond"} <= names
    # request ids thread the chain: every swept id was enqueued
    enq_ids = {e["args"]["req"] for e in spans
               if e["name"] == "service/enqueue"}
    sweep_ids = {i for e in spans if e["name"] == "service/sweep"
                 for i in e["args"]["reqs"]}
    assert sweep_ids and sweep_ids <= enq_ids
    man = json.loads((run_dir / "manifest.json").read_text())
    assert man["name"] == "serve_load" and "partial" not in man
    assert man["slo_gate_ok"] is True and "slo_p99_ms" in man
    metrics = json.loads((run_dir / "metrics.json").read_text())
    assert metrics["counters"]["service/flushes"] > 0
    assert "service/latency_ms" in metrics["histograms"]


def test_merge_bench_preserves_existing_keys(tmp_path):
    p = tmp_path / "BENCH_serve.json"
    p.write_text(json.dumps({"bench": "serve", "entries": [1, 2],
                             "engine_us": 42.0}))
    serve_load.merge_bench(str(p), {"slo_qps": 99.0})
    d = json.loads(p.read_text())
    assert d["entries"] == [1, 2] and d["engine_us"] == 42.0
    assert d["slo_qps"] == 99.0
    # and a fresh file self-initializes
    p2 = tmp_path / "new.json"
    serve_load.merge_bench(str(p2), {"slo_qps": 1.0})
    assert json.loads(p2.read_text())["bench"] == "serve"
