"""Persistent perf trajectory: JSONL append/load round-trip, corrupt-line
tolerance, direction rules, trend extraction, trailing-median regression
detection, the BENCH key folding, and the obs_report history/regress CLI
exit-code contract (0 ok / 1 regression / 2 unusable input)."""
import json

import pytest

from repro.launch import obs_report
from repro.obs import perfdb


def _seed(path, values, key="wall_ms", suite="kernels", **extra_keys):
    for v in values:
        keys = {key: v}
        keys.update(extra_keys)
        perfdb.append(str(path), suite, keys, sha="f00ba4", ts="2026-08-08")


# ---------------------------------------------------------------------------
# Append / load
# ---------------------------------------------------------------------------


def test_append_load_roundtrip(tmp_path):
    p = tmp_path / "hist.jsonl"
    row = perfdb.append(str(p), "io", {"mine_slowdown_streamed": 1.1,
                                       "parity": True, "note": "x"},
                        sha="abc", backend="cpu", ts="T")
    assert row["keys"] == {"mine_slowdown_streamed": 1.1}  # bools/strs dropped
    perfdb.append(str(p), "io", {"mine_slowdown_streamed": 1.2},
                  sha="abc", ts="T")
    rows, corrupt = perfdb.load(str(p))
    assert corrupt == 0 and len(rows) == 2
    assert rows[0]["suite"] == "io" and rows[0]["sha"] == "abc"
    # one whole JSON object per line — the atomicity the O_APPEND write buys
    lines = p.read_text().splitlines()
    assert len(lines) == 2 and all(json.loads(ln) for ln in lines)


def test_load_skips_corrupt_and_malformed_lines(tmp_path):
    p = tmp_path / "hist.jsonl"
    _seed(p, [1.0, 2.0])
    with open(p, "a") as f:
        f.write('{"torn...\n')                    # torn write
        f.write('[1, 2]\n')                       # not an object
        f.write('{"suite": "x"}\n')               # no keys dict
        f.write("\n")                             # blank: not corrupt
    rows, corrupt = perfdb.load(str(p))
    assert len(rows) == 2 and corrupt == 3


def test_default_stamps(tmp_path):
    p = tmp_path / "hist.jsonl"
    row = perfdb.append(str(p), "s", {"x_ms": 1.0})
    assert len(row["ts"]) == 20 and row["ts"].endswith("Z")
    assert isinstance(row["sha"], str)            # '' outside git is fine


# ---------------------------------------------------------------------------
# Direction rules + trends
# ---------------------------------------------------------------------------


def test_direction_rules():
    assert perfdb.direction("mine_wall_ms") == "lower"
    assert perfdb.direction("slo_p99_ms") == "lower"
    assert perfdb.direction("obs_overhead_streamed") == "lower"
    assert perfdb.direction("slo_burn_rate") == "lower"
    assert perfdb.direction("delta_speedup_vs_full") == "higher"
    assert perfdb.direction("rebalance_improvement") == "higher"
    assert perfdb.direction("slo_qps") == "higher"
    assert perfdb.direction("n_fis") is None      # counts are not gated


def test_trends_filtering(tmp_path):
    p = tmp_path / "hist.jsonl"
    _seed(p, [1.0, 2.0], key="a_ms", suite="kernels")
    _seed(p, [3.0], key="a_ms", suite="serve")
    rows, _ = perfdb.load(str(p))
    t = perfdb.trends(rows)
    assert [pt["value"] for pt in t[("kernels", "a_ms")]] == [1.0, 2.0]
    assert ("serve", "a_ms") in t
    only = perfdb.trends(rows, suite="serve")
    assert list(only) == [("serve", "a_ms")]
    assert perfdb.trends(rows, key_match="zzz") == {}


# ---------------------------------------------------------------------------
# Regression detection
# ---------------------------------------------------------------------------


def test_no_regression_on_stable_series(tmp_path):
    p = tmp_path / "h.jsonl"
    _seed(p, [100.0, 104.0, 98.0, 101.0])
    rows, _ = perfdb.load(str(p))
    found, checked = perfdb.check_regressions(rows)
    assert found == [] and checked == 1


def test_lower_better_regression_detected(tmp_path):
    p = tmp_path / "h.jsonl"
    _seed(p, [100.0, 104.0, 98.0, 140.0])       # +39% vs median 101
    rows, _ = perfdb.load(str(p))
    found, _ = perfdb.check_regressions(rows, threshold=0.25)
    assert len(found) == 1
    reg = found[0]
    assert reg.key == "wall_ms" and reg.direction == "lower"
    assert reg.ratio > 1.25 and "worse" in reg.line()


def test_higher_better_regression_detected(tmp_path):
    p = tmp_path / "h.jsonl"
    _seed(p, [10.0, 10.4, 9.8, 6.0], key="x_speedup")
    rows, _ = perfdb.load(str(p))
    found, _ = perfdb.check_regressions(rows, threshold=0.25)
    assert len(found) == 1 and found[0].direction == "higher"


def test_min_history_gates_new_keys(tmp_path):
    p = tmp_path / "h.jsonl"
    _seed(p, [100.0, 900.0])                    # huge jump but only 1 prior
    rows, _ = perfdb.load(str(p))
    found, checked = perfdb.check_regressions(rows, min_history=2)
    assert found == [] and checked == 0
    found, checked = perfdb.check_regressions(rows, min_history=1)
    assert len(found) == 1 and checked == 1


def test_window_limits_trailing_median(tmp_path):
    p = tmp_path / "h.jsonl"
    # ancient fast values must age out of a window of 2
    _seed(p, [10.0, 10.0, 100.0, 104.0, 102.0])
    rows, _ = perfdb.load(str(p))
    found, _ = perfdb.check_regressions(rows, threshold=0.25, window=2)
    assert found == []


def test_degrade_is_a_deterministic_failing_partner(tmp_path):
    p = tmp_path / "h.jsonl"
    _seed(p, [100.0, 100.0, 100.0])
    _seed(p, [50.0, 50.0, 50.0], key="y_qps")
    rows, _ = perfdb.load(str(p))
    assert perfdb.check_regressions(rows)[0] == []
    found, _ = perfdb.check_regressions(rows, degrade=2.0)
    assert {r.key for r in found} == {"wall_ms", "y_qps"}


def test_bench_result_keys_folds_entries():
    bench = {"bench": "kernels", "backend": "cpu", "fast": True,
             "meta": {"git_sha": "x"}, "reps": 5,
             "some_speedup": 3.0,
             "entries": [{"name": "pair_supports", "us": 12.5},
                         {"name": "noname"}]}
    keys = perfdb.bench_result_keys(bench)
    assert keys == {"some_speedup": 3.0, "pair_supports_us": 12.5}


# ---------------------------------------------------------------------------
# CLI exit codes: 0 ok / 1 regression / 2 unusable
# ---------------------------------------------------------------------------


def test_cli_history_and_regress_ok(tmp_path, capsys):
    p = tmp_path / "h.jsonl"
    _seed(p, [100.0, 104.0, 98.0])
    assert obs_report.main(["history", "--history", str(p)]) == 0
    assert "kernels/wall_ms" in capsys.readouterr().out
    assert obs_report.main(["regress", "--history", str(p)]) == 0


def test_cli_regress_exit_1_on_regression(tmp_path, capsys):
    p = tmp_path / "h.jsonl"
    _seed(p, [100.0, 104.0, 98.0, 200.0])
    assert obs_report.main(["regress", "--history", str(p)]) == 1
    assert "REGRESSION" in capsys.readouterr().out


def test_cli_regress_degrade_partner(tmp_path):
    p = tmp_path / "h.jsonl"
    _seed(p, [100.0, 104.0, 98.0])
    assert obs_report.main(["regress", "--history", str(p),
                            "--degrade", "2.0"]) == 1


def test_cli_exit_2_on_missing_or_empty_history(tmp_path):
    with pytest.raises(SystemExit) as e:
        obs_report.main(["regress", "--history", str(tmp_path / "nope")])
    assert e.value.code == 2
    empty = tmp_path / "empty.jsonl"
    empty.write_text("not json at all\n")
    with pytest.raises(SystemExit) as e:
        obs_report.main(["history", "--history", str(empty)])
    assert e.value.code == 2


def test_cli_regress_skips_corrupt_lines(tmp_path, capsys):
    p = tmp_path / "h.jsonl"
    _seed(p, [100.0, 104.0, 98.0])
    with open(p, "a") as f:
        f.write('{"torn\n')
    assert obs_report.main(["regress", "--history", str(p)]) == 0
    assert "skipped 1 corrupt line" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# Direction overrides and the markdown renderings
# ---------------------------------------------------------------------------


def test_direction_override_flips_the_verdict(tmp_path):
    # "n_fis" has no inferable direction: untracked by default...
    rows = []
    p = tmp_path / "h.jsonl"
    _seed(p, [100.0, 100.0, 40.0], key="n_fis")
    rows, _ = perfdb.load(str(p))
    found, checked = perfdb.check_regressions(rows)
    assert checked == 0 and found == []
    # ...an override gates it, and can also flip an inferred direction
    found, checked = perfdb.check_regressions(
        rows, direction_overrides={"n_fis": "higher"})
    assert checked == 1
    assert [f.key for f in found] == ["n_fis"]
    found, _ = perfdb.check_regressions(
        rows, direction_overrides={"n_fis": "lower"})
    assert found == []


def test_cli_regress_direction_flag(tmp_path, capsys):
    p = tmp_path / "h.jsonl"
    _seed(p, [100.0, 100.0, 40.0], key="n_fis")
    assert obs_report.main(["regress", "--history", str(p)]) == 0
    assert obs_report.main(["regress", "--history", str(p),
                            "--direction", "n_fis=up"]) == 1
    assert "REGRESSION" in capsys.readouterr().out
    assert obs_report.main(["regress", "--history", str(p),
                            "--direction", "n_fis=down"]) == 0
    with pytest.raises(SystemExit) as e:
        obs_report.main(["regress", "--history", str(p),
                         "--direction", "n_fis=sideways"])
    assert e.value.code == 2


def test_cli_history_markdown(tmp_path, capsys):
    p = tmp_path / "h.jsonl"
    _seed(p, [100.0, 104.0, 98.0])
    assert obs_report.main(["history", "--history", str(p),
                            "--format", "markdown"]) == 0
    out = capsys.readouterr().out
    assert "### perf history" in out
    assert "| suite/key | dir | min | max |" in out
    assert "`kernels/wall_ms`" in out and "| lower |" in out


def test_cli_regress_markdown(tmp_path, capsys):
    p = tmp_path / "h.jsonl"
    _seed(p, [100.0, 104.0, 98.0, 200.0])
    assert obs_report.main(["regress", "--history", str(p),
                            "--format", "markdown"]) == 1
    out = capsys.readouterr().out
    assert "### perf regressions" in out
    assert "**REGRESSION:** 1 key(s) degraded" in out
    assert "`kernels/wall_ms`" in out
    # the ok path renders too
    _seed(p, [99.0])
    p2 = tmp_path / "ok.jsonl"
    _seed(p2, [100.0, 104.0, 98.0])
    assert obs_report.main(["regress", "--history", str(p2),
                            "--format", "markdown"]) == 0
    assert "ok: no key degraded" in capsys.readouterr().out
