"""Fault-injection harness — the controlled ways a mining run can die.

Every robustness claim in DESIGN.md's "Failure model" is exercised through
these hooks rather than ad-hoc file poking, so the tests *are* the failure
model: each damage class has exactly one injector, and each injector's name
matches the fsck damage kind it should provoke.

  :func:`corrupt_block`    damage one block payload on disk — ``bitflip``
                           (CRC-detectable), ``truncate`` (torn write),
                           ``delete`` (missing file), ``stale`` (valid npy,
                           wrong geometry — a manifest/payload mismatch).
  :func:`orphan_block`     plant a crashed writer's residue: a block file
                           beyond the manifest, optionally torn.
  :func:`fail_nth_read`    make the Nth store block read raise — transient
                           (first ``fail_count`` calls) or persistent.
  :func:`kill_after_round` an executor ``round_hook`` that raises
                           :class:`SimulatedCrash` after round R, right
                           after the round's checkpoint is saved.

Used by ``tests/test_faults.py`` and wired into ``tools/check.sh --faults``.
"""
from __future__ import annotations

import contextlib
import os
from typing import Callable, Iterator, Optional, Type

import numpy as np

from repro.store.store import BLOCK_DIR, TxStore


class SimulatedCrash(Exception):
    """Raised by the kill hook: the process 'died' between rounds."""


def _block_path(store_dir: str, block_index: int) -> str:
    st = TxStore.open(store_dir, verify=False)
    return os.path.join(store_dir, st.manifest.blocks[block_index].file)


def corrupt_block(store_dir: str, block_index: int, mode: str) -> str:
    """Damage one indexed block payload; returns the path touched.

    ``bitflip``  flip a single bit in the middle of the payload (header
                 left intact so the damage is only CRC-detectable);
    ``truncate`` cut the file to half its length (torn ``np.save``);
    ``delete``   remove the file entirely;
    ``stale``    overwrite with a well-formed npy of the wrong row count
                 (reads cleanly, disagrees with the manifest).
    """
    path = _block_path(store_dir, block_index)
    if mode == "bitflip":
        with open(path, "r+b") as f:
            raw = bytearray(f.read())
            # stay clear of the ~128B npy header: flip a payload bit
            pos = len(raw) // 2 + 64
            raw[pos] ^= 0x10
            f.seek(0)
            f.write(raw)
    elif mode == "truncate":
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(size // 2)
    elif mode == "delete":
        os.remove(path)
    elif mode == "stale":
        st = TxStore.open(store_dir, verify=False)
        meta = st.manifest.blocks[block_index]
        wrong = np.zeros((meta.n_tx + 1, st.n_words), np.uint32)
        np.save(path.removesuffix(".npy"), wrong)
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")
    return path


def orphan_block(
    store_dir: str, n_rows: int = 4, *, torn: bool = False,
    index: Optional[int] = None,
) -> str:
    """Plant a post-manifest block file, as a crashed writer would leave it.

    By default the orphan lands at the next contiguous index (adoptable);
    pass ``index`` to plant a gap, or ``torn=True`` for a half-written
    payload.  Returns the orphan's path.
    """
    from repro.store.store import block_file_index

    st = TxStore.open(store_dir, verify=False)
    if index is None:
        # next contiguous name after everything on disk *and* in the
        # manifest, so stacked orphans mimic a writer's sequential appends
        on_disk = (
            block_file_index(f)
            for f in os.listdir(os.path.join(store_dir, BLOCK_DIR))
        )
        indexed = (block_file_index(b.file) for b in st.manifest.blocks)
        index = 1 + max(
            (i for i in (*on_disk, *indexed) if i is not None), default=-1
        )
    path = os.path.join(store_dir, BLOCK_DIR, f"block_{index:06d}.npy")
    rows = np.zeros((n_rows, st.n_words), np.uint32)
    rows[:, 0] = 1  # item 0 present, so adoption visibly changes counts
    np.save(path.removesuffix(".npy"), rows)
    if torn:
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(max(size // 2, 1))
    return path


@contextlib.contextmanager
def fail_nth_read(
    n: int,
    exc: Type[BaseException] = OSError,
    *,
    fail_count: int = 10 ** 9,
) -> Iterator[Callable[[], int]]:
    """Patch ``TxStore.read_block`` so its Nth call (1-based) raises.

    ``fail_count`` bounds how many consecutive calls fail from the Nth on:
    the default is effectively persistent; ``fail_count=2`` models a
    transient fault a 3-attempt retry policy survives.  Yields a zero-arg
    callable returning how many reads were attempted so far.
    """
    calls = {"n": 0}
    real = TxStore.read_block

    def patched(self, i):
        calls["n"] += 1
        if n <= calls["n"] < n + fail_count:
            raise exc(f"injected failure on read #{calls['n']} (block {i})")
        return real(self, i)

    TxStore.read_block = patched
    try:
        yield lambda: calls["n"]
    finally:
        TxStore.read_block = real


def kill_after_round(r: int) -> Callable[[int], None]:
    """Executor ``round_hook`` raising :class:`SimulatedCrash` after round r.

    The executor calls the hook *after* the round's checkpoint is saved, so
    the crash always leaves a resumable state — exactly the contract
    ``--kill-after-round`` exercises end to end.
    """

    def hook(completed_round: int) -> None:
        if completed_round >= r:
            raise SimulatedCrash(f"simulated death after round {completed_round}")

    return hook
