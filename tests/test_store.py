"""Out-of-core transaction store: format round-trips, streamed reader
residency, off-disk Thm 6.1 sampling, and bit-exact mining parity of
``fimi.run(store)`` / ``planner.plan(store)`` vs the dense in-RAM path."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import bitmap as bm
from repro.core import eclat, fimi, sampling
from repro.data.ibm_gen import IBMParams, generate_blocks, generate_dense
from repro.store import (
    BlockReader,
    HostBudgetExceeded,
    StoreWriter,
    TxStore,
    export_dat,
    gather_rows,
    ingest_dat,
    pack_bool_np,
    parse_dat,
    sample_rows,
    streamed_itemset_supports,
    to_device_shards,
    unpack_bool_np,
    write_dat,
    write_ibm_store,
)

FIXTURE = os.path.join(os.path.dirname(__file__), "..", "examples",
                       "retail_tiny.dat")


def _random_dense(n_tx, n_items, seed, density=0.3):
    rng = np.random.default_rng(seed)
    return rng.random((n_tx, n_items)) < density


def _store_from_dense(tmp_path, dense, sizes, name="st"):
    """Build a store whose blocks cover ``dense`` with the given row counts."""
    assert sum(sizes) == dense.shape[0]
    w = StoreWriter(str(tmp_path / name), n_items=dense.shape[1],
                    block_tx=max(sizes) if sizes else 1)
    off = 0
    for sz in sizes:
        w.append_dense(dense[off:off + sz])
        off += sz
    return w.close()


# ---------------------------------------------------------------------------
# Packing + disk format round-trips
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_items", [1, 5, 32, 40, 96])
def test_host_packing_matches_device(n_items):
    dense = _random_dense(23, n_items, seed=n_items)
    packed = pack_bool_np(dense)
    assert np.array_equal(
        packed, np.asarray(bm.pack_bool(jnp.asarray(dense)))
    )
    assert np.array_equal(unpack_bool_np(packed, n_items), dense)


@pytest.mark.parametrize(
    "sizes",
    [
        [8, 8, 8, 8, 5],     # ragged final block
        [37],                # single block
        [8, 0, 8, 8, 0, 13], # empty blocks mid-stream
    ],
)
def test_store_roundtrip_ragged(tmp_path, sizes):
    dense = _random_dense(sum(sizes), 19, seed=1)
    s = _store_from_dense(tmp_path, dense, sizes)
    assert s.n_tx == sum(sizes)
    assert s.block_sizes == sizes
    assert np.array_equal(s.to_dense(), dense)
    # exact global item counts maintained incrementally by the writer
    assert np.array_equal(s.item_counts(), dense.sum(axis=0))
    # a fresh handle reads the same manifest
    s2 = TxStore.open(s.directory)
    assert s2.block_sizes == sizes and s2.n_tx == s.n_tx


def test_block_sketches_are_topk(tmp_path):
    dense = _random_dense(40, 24, seed=2, density=0.4)
    s = _store_from_dense(tmp_path, dense, [40])
    meta = s.manifest.blocks[0]
    counts = dense.sum(axis=0)
    assert len(meta.sketch_items) <= 16
    got = dict(zip(meta.sketch_items, meta.sketch_counts))
    for i, c in got.items():
        assert counts[i] == c
    # the sketch holds the heaviest items
    if meta.sketch_items:
        floor = min(got.values())
        outside = [c for i, c in enumerate(counts) if i not in got]
        assert all(c <= floor for c in outside)


# ---------------------------------------------------------------------------
# FIMI .dat reader/writer
# ---------------------------------------------------------------------------


def test_fimi_dat_write_then_read_bitexact(tmp_path):
    labels0 = ["39", "41", "48", "170", "999", "32"]
    txs = [[3, 1, 2], [2, 5], [1], [5, 3, 2, 1], [0, 4]]
    path = str(tmp_path / "a.dat")
    write_dat(path, txs, labels=labels0)
    got, labels = parse_dat(path)
    want_sets = [{labels0[i] for i in tx} for tx in txs]
    got_sets = [{labels[i] for i in tx} for tx in got]
    assert want_sets == got_sets
    # write∘parse is idempotent: the canonical form round-trips byte-exact
    path2 = str(tmp_path / "b.dat")
    write_dat(path2, got, labels=labels)
    got2, labels2 = parse_dat(path2)
    assert [{labels2[i] for i in tx} for tx in got2] == want_sets
    path3 = str(tmp_path / "c.dat")
    write_dat(path3, got2, labels=labels2)
    assert open(path3).read() == open(path2).read()


def test_ingest_export_roundtrip(tmp_path):
    txs, labels = parse_dat(FIXTURE)
    store = ingest_dat(FIXTURE, str(tmp_path / "st"), block_tx=7)
    assert store.n_tx == len(txs)
    assert store.item_labels == labels
    # store content == densified transactions (dense ids are first-occurrence)
    dense = np.zeros((len(txs), len(labels)), bool)
    for t, tx in enumerate(txs):
        dense[t, tx] = True
    assert np.array_equal(store.to_dense(), dense)
    # export restores the original labels, transaction for transaction
    out = str(tmp_path / "out.dat")
    export_dat(store, out)
    got, labels2 = parse_dat(out)
    assert [{labels2[i] for i in tx} for tx in got] == [
        {labels[i] for i in tx} for tx in txs
    ]


def test_retail_tiny_fixture_frequencies(tmp_path):
    store = ingest_dat(FIXTURE, str(tmp_path / "st"), block_tx=16)
    labels = store.item_labels
    counts = dict(zip(labels, store.item_counts()))
    # 39 and 48 are the fixture's (and the real retail DB's) heavy hitters
    assert counts["39"] > store.n_tx * 0.5
    assert counts["48"] > store.n_tx * 0.5
    pair = np.zeros((1, store.n_items), bool)
    pair[0, labels.index("39")] = True
    pair[0, labels.index("48")] = True
    sup = streamed_itemset_supports(store, jnp.asarray(pack_bool_np(pair)))
    assert sup[0] >= store.n_tx * 0.4  # {39,48} is frequent


# ---------------------------------------------------------------------------
# Streamed reader: residency budget + device assembly parity
# ---------------------------------------------------------------------------


def test_reader_residency_within_budget(tmp_path):
    dense = _random_dense(64, 40, seed=3)
    s = _store_from_dense(tmp_path, dense, [16, 16, 16, 16])
    r = BlockReader(s, host_budget_blocks=2)
    rows = []
    for _, off, dev, n in r.device_blocks():
        rows.append(np.asarray(dev))
    assert np.array_equal(np.concatenate(rows), pack_bool_np(dense))
    # double buffering holds at most two blocks: high-water <= budget
    assert 0 < r.peak_host_bytes <= r.budget_bytes
    with pytest.raises(ValueError):
        BlockReader(s, host_budget_blocks=1)


def test_reader_emits_host_bytes_counter_track(tmp_path):
    """Block residency renders as a Perfetto counter track: live bytes rise
    on read, fall on release; the peak lane matches the recorded high-water."""
    from repro.obs import trace as obs_trace

    dense = _random_dense(64, 40, seed=5)
    s = _store_from_dense(tmp_path, dense, [16, 16, 16, 16])
    r = BlockReader(s, host_budget_blocks=2)
    obs_trace.TRACER.enable()
    try:
        for _ in r.device_blocks():
            pass
        samples = [e for e in obs_trace.TRACER.export()["traceEvents"]
                   if e.get("ph") == "C" and e.get("name") == "host bytes"]
    finally:
        obs_trace.TRACER.disable()
        obs_trace.TRACER.clear()
    assert samples
    lives = [e["args"]["live"] for e in samples]
    assert max(lives) == r.peak_host_bytes > 0
    assert lives[-1] == 0.0                      # everything released
    assert all(e["args"]["peak"] <= r.peak_host_bytes for e in samples)


def test_reader_budget_enforced(tmp_path):
    """A reader that somehow over-holds raises instead of silently growing."""
    dense = _random_dense(32, 16, seed=4)
    s = _store_from_dense(tmp_path, dense, [8, 8, 8, 8])
    r = BlockReader(s, host_budget_blocks=2)
    r.budget_bytes = 1  # simulate a misconfigured (too small) byte budget
    with pytest.raises(HostBudgetExceeded):
        list(r.device_blocks())


@pytest.mark.parametrize(
    "n_tx,P,sizes",
    [
        (37, 2, [8, 8, 8, 8, 5]),    # ragged last + truncation (37 % 2 = 1)
        (32, 4, [32]),               # single block
        (29, 3, [8, 0, 8, 8, 0, 5]), # empty blocks + truncation
    ],
)
def test_to_device_shards_matches_shard_db(tmp_path, n_tx, P, sizes):
    dense = _random_dense(n_tx, 19, seed=n_tx + P)
    s = _store_from_dense(tmp_path, dense, sizes)
    got = to_device_shards(s, P)
    want = fimi.shard_db(dense, P)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_gather_rows_with_duplicates(tmp_path):
    dense = _random_dense(30, 19, seed=5)
    s = _store_from_dense(tmp_path, dense, [8, 8, 8, 6])
    idx = np.array([29, 0, 7, 8, 7, 15, 29, 29])
    got = gather_rows(s, idx)
    assert np.array_equal(got, pack_bool_np(dense)[idx])


# ---------------------------------------------------------------------------
# Off-disk Thm 6.1 sample: bit-exactness + estimation-error bound
# ---------------------------------------------------------------------------


def test_sample_rows_bitexact_vs_inram(tmp_path):
    dense = _random_dense(96, 24, seed=6)
    s = _store_from_dense(tmp_path, dense, [32, 32, 32])
    flat = np.asarray(bm.pack_bool(jnp.asarray(dense)))
    for seed in (0, 7):
        key = jax.random.PRNGKey(seed)
        got = sample_rows(s, key, 40)
        want = bm.sample_transactions(jnp.asarray(flat), key, 40, 96)
        assert np.array_equal(np.asarray(got), np.asarray(want))


def test_offdisk_sample_meets_thm61_bound(tmp_path):
    """Item supports estimated from the off-disk sample stay within the
    Thm 6.1 ε implied by the drawn sample size (same bound, same sampler,
    as the in-RAM path — the rows are bit-identical)."""
    p = IBMParams(n_tx=2048, n_items=24, n_patterns=8, avg_pattern_len=5,
                  avg_tx_len=8, seed=9)
    store = write_ibm_store(p, str(os.path.join(str(tmp_path), "ibm")),
                            block_tx=256)
    eps, delta = 0.05, 0.1
    n = min(sampling.db_sample_size(eps, delta), store.n_tx)
    rows = sample_rows(store, jax.random.PRNGKey(2), n)
    samp = unpack_bool_np(np.asarray(rows), store.n_items)
    est_rel = samp.sum(axis=0) / n
    true_rel = store.item_counts() / store.n_tx
    # the implied eps at the actually-drawn n (n was clipped to |D|)
    eps_eff = np.sqrt(np.log(2.0 / delta) / (2.0 * n))
    assert np.abs(est_rel - true_rel).max() <= eps_eff


def test_streamed_itemset_supports_exact(tmp_path):
    dense = _random_dense(60, 24, seed=8, density=0.35)
    s = _store_from_dense(tmp_path, dense, [16, 16, 0, 16, 12])
    masks_dense = _random_dense(9, 24, seed=9, density=0.12)
    masks_dense[0] = False  # the empty itemset: contained in every row
    got = streamed_itemset_supports(
        s, jnp.asarray(pack_bool_np(masks_dense))
    )
    want = np.array([
        (~(m[None, :] & ~dense).any(axis=1)).sum() for m in masks_dense
    ])
    assert np.array_equal(got, want)
    assert got[0] == 60


# ---------------------------------------------------------------------------
# Out-of-core mining parity: fimi.run(store) == fimi.run(dense), bit for bit
# ---------------------------------------------------------------------------


def _fimi_params():
    return fimi.FimiParams(
        min_support_rel=0.1, n_db_sample=128, n_fi_sample=256,
        eclat=eclat.EclatConfig(max_out=1 << 14, max_stack=2048,
                                frontier_size=8),
    )


@pytest.mark.parametrize(
    "sizes,P",
    [
        ([64, 64, 64, 64, 44], 4),   # ragged last block
        ([300], 4),                  # single block
        ([64, 0, 64, 64, 64, 0, 44], 2),  # empty blocks mid-stream
    ],
)
def test_fimi_run_store_parity(tmp_path, sizes, P):
    p = IBMParams(n_tx=sum(sizes), n_items=24, n_patterns=8,
                  avg_pattern_len=5, avg_tx_len=8, seed=3)
    dense = generate_dense(p)
    s = _store_from_dense(tmp_path, dense, sizes)
    key = jax.random.PRNGKey(0)
    ref = fimi.run(fimi.shard_db(dense, P), 24, _fimi_params(), key,
                   materialize=True)
    got = fimi.run(s, None, _fimi_params(), key, materialize=True, P=P)
    assert len(ref.fi_dict) > 0
    assert got.fi_dict == ref.fi_dict


def test_fimi_run_store_requires_P(tmp_path):
    dense = _random_dense(32, 16, seed=10)
    s = _store_from_dense(tmp_path, dense, [32])
    with pytest.raises(ValueError, match="P"):
        fimi.run(s, None, _fimi_params(), jax.random.PRNGKey(0))


def test_planner_store_parity(tmp_path):
    from repro.cluster import PlannerParams, plan

    p = IBMParams(n_tx=300, n_items=24, n_patterns=8, avg_pattern_len=5,
                  avg_tx_len=8, seed=3)
    dense = generate_dense(p)
    s = _store_from_dense(tmp_path, dense, [64, 64, 64, 64, 44])
    pp = PlannerParams(min_support_rel=0.1, n_db_sample=128, n_fi_sample=256)
    key = jax.random.PRNGKey(0)
    a = plan(fimi.shard_db(dense, 4), 24, pp, key)
    b = plan(s, None, pp, key, P=4)
    assert np.array_equal(a.assignment, b.assignment)
    assert np.array_equal(a.est_sizes, b.est_sizes)
    assert np.array_equal(a.sample_masks, b.sample_masks)
    assert np.array_equal(a.sample_item_rel, b.sample_item_rel)
    assert a.scheduler_used == b.scheduler_used
    assert a.n_db_sample == b.n_db_sample


def test_cluster_execute_from_store_plan(tmp_path):
    """Executor fed the off-disk plan + block-assembled shards stays exact."""
    from repro import cluster

    p = IBMParams(n_tx=240, n_items=24, n_patterns=8, avg_pattern_len=5,
                  avg_tx_len=8, seed=5)
    dense = generate_dense(p)
    s = _store_from_dense(tmp_path, dense, [64, 64, 64, 48])
    key = jax.random.PRNGKey(0)
    params = cluster.ClusterParams(
        planner=cluster.PlannerParams(
            min_support_rel=0.12, n_db_sample=128, n_fi_sample=256
        ),
        eclat=eclat.EclatConfig(max_out=1 << 14, max_stack=2048,
                                frontier_size=8),
    )
    plan = cluster.plan(s, None, params.planner, key, P=4)
    shards = to_device_shards(s, 4)
    res = cluster.execute(shards, 24, params, key, plan=plan)
    minsup = int(np.ceil(0.12 * 240))
    oracle = eclat.brute_force_fis(dense, minsup)
    assert res.table.to_dict() == oracle


# ---------------------------------------------------------------------------
# IBM spill + window spill
# ---------------------------------------------------------------------------


def test_ibm_spill_matches_blocked_generation(tmp_path):
    p = IBMParams(n_tx=100, n_items=24, n_patterns=8, avg_pattern_len=5,
                  avg_tx_len=8, seed=3)
    s = write_ibm_store(p, str(tmp_path / "ibm"), block_tx=32)
    want = np.concatenate(list(generate_blocks(p, 32)))
    assert s.block_sizes == [32, 32, 32, 4]
    assert np.array_equal(s.to_dense(), want)


def test_generate_blocks_single_block_is_generate_dense():
    p = IBMParams(n_tx=64, n_items=24, n_patterns=8, avg_pattern_len=5,
                  avg_tx_len=8, seed=11)
    blocks = list(generate_blocks(p, 64))
    assert len(blocks) == 1
    assert np.array_equal(blocks[0], generate_dense(p))


def test_window_spill_persists_expired_blocks(tmp_path):
    from repro.stream import StreamParams, StreamingMiner

    rng = np.random.default_rng(1)
    sp = StreamParams(
        n_blocks=3, block_tx=16, min_support_rel=0.3,
        spill_dir=str(tmp_path / "hist"),
    )

    def oracle_mine(window, abs_minsup):
        return eclat.brute_force_fis(
            np.asarray(window.to_bitmap_db().dense()), abs_minsup
        )

    m = StreamingMiner(sp, 12, mine_fn=oracle_mine)
    blocks = [rng.random((16, 12)) < 0.3 for _ in range(7)]
    for b in blocks:
        m.admit(b)
    hist = m.spill.store()
    # 7 admitted into a 3-block ring: blocks 0..3 expired, in arrival order
    assert hist.n_blocks == 4 and hist.n_tx == 64
    want = np.concatenate([pack_bool_np(b) for b in blocks[:4]])
    assert np.array_equal(hist.read_all_packed(), want)
    # the spilled history is itself a minable store
    got = fimi.run(hist, None, _fimi_params(), jax.random.PRNGKey(0),
                   materialize=True, P=2)
    ref = fimi.run(fimi.shard_db(np.concatenate(blocks[:4]), 2), 12,
                   _fimi_params(), jax.random.PRNGKey(0), materialize=True)
    assert got.fi_dict == ref.fi_dict


def test_window_spill_resumes_existing_history(tmp_path):
    """A restarted stream appends to the spill store instead of resetting it."""
    from repro.stream.window import SlidingWindow, WindowSpill

    rng = np.random.default_rng(2)
    blocks = [rng.random((8, 12)) < 0.3 for _ in range(6)]
    packed = [pack_bool_np(b) for b in blocks]

    def run_session(blks):
        spill = WindowSpill(str(tmp_path / "hist"), 8, 12)
        win = SlidingWindow.empty(2, 8, 12)
        for b in blks:
            win, expired = win.admit(jnp.asarray(pack_bool_np(b)))
            if expired is not None:
                spill.append(expired)
        return spill.store()

    h1 = run_session(blocks[:4])          # ring of 2 -> blocks 0,1 expire
    assert h1.n_blocks == 2
    h2 = run_session(blocks[3:])          # fresh session, same directory
    assert h2.n_blocks == 3               # resumed: 2 old + 1 newly expired
    want = np.concatenate([packed[0], packed[1], packed[3]])
    assert np.array_equal(h2.read_all_packed(), want)
    # geometry mismatch is refused, never silently reset
    from repro.store.store import StoreWriter

    with pytest.raises(ValueError, match="resume"):
        StoreWriter(str(tmp_path / "hist"), n_items=16, block_tx=8,
                    resume=True)
