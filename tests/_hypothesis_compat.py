"""Optional-hypothesis shim (see requirements-dev.txt).

``hypothesis`` is a dev-only dependency; importing it at test-module top level
would make collection hard-error without it, and ``pytest.importorskip`` would
skip whole modules — including their many non-property tests.  Importing
``given``/``settings``/``st`` from here instead skips exactly the ``@given``
tests when hypothesis is absent and is transparent when it is installed.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``strategies``: any attribute is a no-op factory."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*_a, **_k):
        return pytest.mark.skip(
            reason="hypothesis not installed (pip install -r requirements-dev.txt)"
        )

    def settings(*_a, **_k):
        return lambda f: f
