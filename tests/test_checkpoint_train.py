"""Checkpoint manager: atomicity, retention, elastic restore; training loop
integration (loss decreases; resume reproduces state)."""
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_config
from repro.models import model as M
from repro.models import steps
from repro.optim import adamw

KEY = jax.random.PRNGKey(0)


def _tree_eq(a, b):
    return all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    state = {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones((5,), jnp.bfloat16)}
    mgr.save(3, state, extra={"data_step": 42})
    abstract = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
    restored, extra = mgr.restore(abstract)
    assert _tree_eq(state, restored)
    assert extra["data_step"] == 42


def test_retention_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.asarray([s])})
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_crash_mid_save_is_atomic(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(1, {"x": jnp.asarray([1.0])})
    # simulate a crash: stale tmp dir left behind
    tmp = tmp_path / "step_0000000002.tmp"
    tmp.mkdir()
    (tmp / "garbage").write_text("boom")
    assert mgr.latest_step() == 1
    mgr.save(3, {"x": jnp.asarray([3.0])})  # gc removes the stale tmp
    assert not tmp.exists()
    assert mgr.all_steps() == [1, 3]


def test_shape_mismatch_fails_loudly(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"x": jnp.ones((3,))})
    with pytest.raises(ValueError):
        mgr.restore({"x": jax.ShapeDtypeStruct((4,), jnp.float32)})


def test_train_loss_decreases_and_resumes(tmp_path):
    """Short training on a memorizable stream: loss must drop; a restore must
    reproduce the exact state (deterministic recovery)."""
    cfg = get_config("llama3.2-3b", smoke=True)
    params = M.init(cfg, KEY)
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=60)
    opt = adamw.init(params, opt_cfg)
    step_fn = jax.jit(steps.make_train_step(cfg, opt_cfg, accum=2))

    rng = np.random.default_rng(0)
    fixed = rng.integers(0, cfg.vocab, size=(4, 32))  # one batch → memorize

    mgr = CheckpointManager(tmp_path, keep=2)
    losses = []
    batch = {"tokens": jnp.asarray(fixed)}
    for it in range(25):
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
        if it == 9:
            mgr.save(it, {"params": params, "opt": opt}, extra={"it": it})
    assert losses[-1] < losses[0] * 0.8, losses[:3] + losses[-3:]

    # resume from step 9 and replay one step — same loss as original step 10
    abstract = {
        "params": jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params
        ),
        "opt": jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), opt),
    }
    restored, extra = mgr.restore(abstract)
    assert extra["it"] == 9
    p2, o2, m2 = step_fn(restored["params"], restored["opt"], batch)
    assert abs(float(m2["loss"]) - losses[10]) < 1e-4


def test_elastic_restore_new_sharding(tmp_path):
    """Restore with an explicit target sharding (single-device here, but the
    same path re-shards onto any new mesh)."""
    mgr = CheckpointManager(tmp_path)
    state = {"w": jnp.arange(64.0).reshape(8, 8)}
    mgr.save(1, state)
    dev = jax.devices()[0]
    shardings = {"w": jax.sharding.SingleDeviceSharding(dev)}
    restored, _ = mgr.restore(
        {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}, shardings=shardings
    )
    assert _tree_eq(state, restored)
