"""Kernel profiler: disabled-path transparency (bit-identical results,
<2 % dispatch overhead), analytic cost-model pricing, shape bucketing,
eager timed calls, traced-dispatch tally + while_loop attribution, and the
published ``kernels/*`` gauge scheme ``obs_report kernels`` consumes."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitmap as bm
from repro.kernels import ops
from repro.obs import metrics as obs_metrics
from repro.obs import profile as obs_profile
from repro.obs.machine import CPU_HOST, TPU_V5E, machine_for_backend


@pytest.fixture(autouse=True)
def _fresh_profiler():
    """Every test starts (and leaves) with a disabled, empty profiler."""
    obs_profile.PROFILER.disable()
    obs_profile.PROFILER.clear()
    obs_metrics.reset()
    yield
    obs_profile.PROFILER.disable()
    obs_profile.PROFILER.clear()
    obs_metrics.reset()


def _db(n_tx=96, n_items=12, seed=0):
    rng = np.random.default_rng(seed)
    dense = rng.random((n_tx, n_items)) < 0.35
    return bm.BitmapDB.from_dense(jnp.asarray(dense))


# ---------------------------------------------------------------------------
# Disabled path: the wrapper must be invisible
# ---------------------------------------------------------------------------


def test_disabled_dispatch_bit_identical():
    """Wrapped dispatch == the naked function, profiler off or on."""
    db = _db()
    all_t = db.all_tids()
    prefix_tids = jnp.tile(all_t[None, :], (4, 1))
    q = db.tx_bits[:8]
    f = db.tx_bits[:16]
    blocks = db.tx_bits[:32].reshape(2, 16, -1)
    cases = [
        (ops.extension_supports, (db.item_bits, all_t)),
        (ops.multi_extension_supports, (db.item_bits, prefix_tids)),
        (ops.pair_supports, (db.item_bits, all_t)),
        (ops.subset_superset_counts, (q, f)),
        (ops.block_itemset_supports, (blocks, f)),
    ]
    for fn, args in cases:
        want = jax.tree_util.tree_map(np.asarray, fn.__wrapped__(*args))
        got_off = fn(*args)
        obs_profile.PROFILER.enable()
        got_on = fn(*args)
        obs_profile.PROFILER.disable()
        for w, a, b in zip(
            jax.tree_util.tree_leaves(want),
            jax.tree_util.tree_leaves(got_off),
            jax.tree_util.tree_leaves(got_on),
        ):
            np.testing.assert_array_equal(w, np.asarray(a))
            np.testing.assert_array_equal(w, np.asarray(b))
    # nothing may have been recorded while disabled; one bucket per family
    # while enabled
    rep = obs_profile.PROFILER.report()
    assert all(f["calls"] == 1 for f in rep["families"].values())
    assert set(rep["families"]) == set(obs_profile.FAMILIES)


def test_disabled_overhead_under_2pct():
    """The disabled wrapper adds < 2 % to a real dispatch's wall time.

    An end-to-end A/B of full jnp dispatches is noise-bound (device
    dispatch jitter alone is >2 %), so measure the two costs separately:
    the wrapper's per-call overhead on a pure-Python stub (its disabled
    path does no jax work, so the stub sees the identical code path), and
    an actual eager dispatch as the denominator.
    """
    def stub(a, b):
        return a

    wrapped = ops._profiled("bitmap", lambda a, b: {"I": 1, "W": 1})(stub)
    n = 100_000

    def loop(fn):
        t0 = time.perf_counter()
        for _ in range(n):
            fn(1, 2)
        return time.perf_counter() - t0

    t_stub = min(loop(stub) for _ in range(5))
    t_wrapped = min(loop(wrapped) for _ in range(5))
    overhead_s = max(t_wrapped - t_stub, 0.0) / n

    db = _db()
    all_t = db.all_tids()
    jax.block_until_ready(ops.extension_supports(db.item_bits, all_t))
    t0 = time.perf_counter()
    for _ in range(50):
        ops.extension_supports(db.item_bits, all_t)
    jax.block_until_ready(ops.extension_supports(db.item_bits, all_t))
    dispatch_s = (time.perf_counter() - t0) / 50

    assert overhead_s < 0.02 * dispatch_s, (
        f"disabled-profiler wrapper costs {overhead_s * 1e9:.0f}ns/call = "
        f"{overhead_s / dispatch_s:.2%} of a {dispatch_s * 1e6:.0f}us "
        f"dispatch (>= 2%)"
    )


# ---------------------------------------------------------------------------
# Cost model + bucketing
# ---------------------------------------------------------------------------


def test_cost_model_word_op_counts():
    assert obs_profile.cost_model("bitmap", {"I": 4, "W": 2}) == (
        3.0 * 4 * 2, 4.0 * (4 * 2 + 2 + 4))
    assert obs_profile.cost_model("multi", {"K": 2, "I": 4, "W": 2}) == (
        3.0 * 2 * 4 * 2, 4.0 * (4 * 2 + 2 * 2 + 2 * 4))
    assert obs_profile.cost_model("pair", {"I": 4, "W": 2}) == (
        3.0 * 16 * 2, 4.0 * (4 * 2 + 2 + 16))
    assert obs_profile.cost_model("subset", {"Q": 2, "F": 3, "IW": 2}) == (
        8.0 * 2 * 3 * 2, 4.0 * ((2 + 3) * 2 + 2 * 2 * 3))
    assert obs_profile.cost_model(
        "delta", {"S": 2, "T": 3, "F": 4, "IW": 2}
    ) == (4.0 * 2 * 3 * 4 * 2, 4.0 * (2 * 3 * 2 + 4 * 2 + 2 * 4))
    with pytest.raises(ValueError):
        obs_profile.cost_model("nope", {})


def test_shape_buckets_round_up_to_pow2():
    lbl = obs_profile._bucket_label("multi", {"K": 5, "I": 100, "W": 3})
    assert lbl == "multi[K=8,I=128,W=4]"
    # same bucket for any shape in the pow2 cell → one histogram per cell
    assert lbl == obs_profile._bucket_label(
        "multi", {"K": 8, "I": 65, "W": 4})


def test_machine_for_backend():
    assert machine_for_backend("tpu") is TPU_V5E
    assert machine_for_backend("cpu") is CPU_HOST
    assert TPU_V5E.balance_word_ops_per_byte > CPU_HOST.balance_word_ops_per_byte / 10


# ---------------------------------------------------------------------------
# Eager timing, loop attribution, publish
# ---------------------------------------------------------------------------


def test_eager_call_measured_vs_modeled():
    db = _db()
    obs_profile.PROFILER.enable()
    for _ in range(3):
        ops.pair_supports(db.item_bits, db.all_tids())
    rep = obs_profile.PROFILER.report()
    fam = rep["families"]["pair"]
    assert fam["calls"] == 3 and fam["loop_execs"] == 0
    assert fam["measured_ms"] > 0.0
    assert fam["modeled_ms"] == pytest.approx(
        max(fam["compute_ms"], fam["memory_ms"]))
    assert fam["achieved_frac"] == pytest.approx(
        fam["modeled_ms"] / fam["measured_ms"])
    assert fam["mem_bound"] == (fam["memory_ms"] > fam["compute_ms"])
    assert rep["machine"]["word_ops_peak"] > 0
    b = fam["buckets"][0]
    assert b["min_us"] is not None and b["max_us"] >= b["min_us"]


def test_traced_dispatch_tallied_then_loop_attributed():
    """Inside jit the dispatch is a tracer: tallied, not timed; the real
    work lands via observe_loop with the driver's trip count + wall."""
    db = _db()
    obs_profile.PROFILER.enable()
    fn = jax.jit(lambda ib, t: ops.extension_supports(ib, t))
    jax.block_until_ready(fn(db.item_bits, db.all_tids()))
    rep = obs_profile.PROFILER.report()
    fam = rep["families"]["bitmap"]
    assert fam["trace_dispatches"] >= 1
    assert fam["calls"] == 0 and fam["measured_ms"] == 0.0

    dims = {"I": db.n_items, "W": db.item_bits.shape[1]}
    obs_profile.PROFILER.observe_loop("bitmap", dims, n_exec=10, wall_s=0.5)
    fam = obs_profile.PROFILER.report()["families"]["bitmap"]
    assert fam["loop_execs"] == 10
    assert fam["measured_ms"] == pytest.approx(500.0)
    flops, _ = obs_profile.cost_model("bitmap", dims)
    assert fam["flops"] == pytest.approx(10 * flops)


def test_observe_loop_noop_when_disabled_or_empty():
    obs_profile.PROFILER.observe_loop("multi", {"K": 1, "I": 2, "W": 1},
                                      n_exec=5, wall_s=1.0)
    obs_profile.PROFILER.enable()
    obs_profile.PROFILER.observe_loop("multi", {"K": 1, "I": 2, "W": 1},
                                      n_exec=0, wall_s=1.0)
    assert obs_profile.PROFILER.report()["families"] == {}


def test_publish_gauge_scheme():
    db = _db()
    obs_profile.PROFILER.enable()
    ops.pair_supports(db.item_bits, db.all_tids())
    obs_profile.PROFILER.observe_loop(
        "multi", {"K": 4, "I": db.n_items, "W": db.item_bits.shape[1]},
        n_exec=7, wall_s=0.1)
    obs_profile.PROFILER.publish(obs_metrics.registry())
    snap = obs_metrics.snapshot()
    g, c = snap["gauges"], snap["counters"]
    for field in ("measured_ms", "modeled_ms", "compute_ms", "memory_ms",
                  "flops", "bytes", "achieved_frac", "mem_bound"):
        assert f"kernels/pair/{field}" in g
    assert c["kernels/pair/calls"] == 1
    assert c["kernels/multi/loop_execs"] == 7
    assert g["kernels/machine/word_ops_peak"] > 0
    assert g["kernels/machine/hbm_bw"] > 0
    # live per-bucket histogram recorded at call time
    assert any(k.startswith("kernels/pair/call_us/") for k in
               snap["histograms"])


def test_roofline_constants_are_shared():
    """benchmarks/roofline.py prices with the same machine constants."""
    import sys
    from pathlib import Path

    repo = Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(repo))
    try:
        from benchmarks import roofline
    finally:
        sys.path.remove(str(repo))
    assert roofline.PEAK == TPU_V5E.peak_flops
    assert roofline.HBM == TPU_V5E.hbm_bw
    assert roofline.LINK == TPU_V5E.link_bw
